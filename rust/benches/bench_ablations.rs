//! E5 + E6 — Proposition 3.1 and the design-choice ablations.
//!
//! Sections:
//!   prop31 : the r_ε bound vs empirically-counted modes above ε·λ_max on
//!            synthetic EA gram streams (bound must hold; paper notes it is
//!            loose — we report the looseness factor).
//!   rho    : §4.3 KLD-WRM remark — r_ε as a function of ρ (0.5 vs 0.95
//!            → 2304 vs 29184 retained modes at the paper's constants),
//!            plus the empirical retained-rank of EA streams under each ρ.
//!   rank   : RS-KFAC step error vs target rank r against the exact K-FAC
//!            step (the accuracy knob of Alg. 4), plus n_pwr_it ablation.

use std::sync::Arc;

use rkfac::linalg::{gemm, Matrix, Pcg64};
use rkfac::optim::kfac::KfacOptimizer;
use rkfac::optim::schedules::{KfacSchedules, StepSchedule};
use rkfac::rnla::{decomposition, errors, rsvd, SketchConfig};
use rkfac::util::benchkit::quick_mode;
use rkfac::coordinator::metrics::CsvLogger;

/// Simulate the EA gram stream of eq. (6): M̄_k over k steps with factors
/// M_i (d×n) of bounded singular value.
fn ea_stream(d: usize, n: usize, rho: f64, steps: usize, rng: &mut Pcg64) -> Matrix {
    let mut m_bar = Matrix::eye(d);
    for _ in 0..steps {
        let m = rng.gaussian_matrix(d, n);
        gemm::ea_gram_update(&mut m_bar, rho, &m, n as f64);
    }
    m_bar
}

fn section_prop31(quick: bool) -> anyhow::Result<()> {
    println!("== E5 / Prop 3.1: bound vs empirical spectrum decay ==");
    let mut csv = CsvLogger::create(
        "results/prop31.csv",
        &["rho", "epsilon", "d", "n", "bound", "empirical", "loose_factor"],
    )?;
    let d = if quick { 96 } else { 256 };
    let n = 8;
    let steps = if quick { 120 } else { 400 };
    println!(
        "{:>6} {:>8} {:>6} {:>4} {:>10} {:>10} {:>8}",
        "rho", "eps", "d", "n", "bound", "empirical", "loose"
    );
    for &rho in &[0.5, 0.8, 0.95] {
        for &eps in &[0.03, 0.1] {
            let mut rng = Pcg64::new((rho * 1000.0) as u64 + (eps * 100.0) as u64);
            let m_bar = ea_stream(d, n, rho, steps, &mut rng);
            let evd = rkfac::linalg::evd::sym_evd(&m_bar);
            let empirical = errors::modes_above(&evd.lambda, eps);
            // α from the realized spectrum: λmax vs max per-step σ² ≈ the
            // paper's assumption λ_M ≥ α σ_M²; use α = 0.1 as in §3.
            let bound = errors::prop31_mode_bound(0.1, eps, rho, n, d);
            let loose = bound as f64 / empirical.max(1) as f64;
            println!(
                "{:>6} {:>8} {:>6} {:>4} {:>10} {:>10} {:>8.1}",
                rho, eps, d, n, bound, empirical, loose
            );
            assert!(empirical <= bound, "Prop 3.1 bound violated!");
            csv.row(&[
                rho.to_string(),
                eps.to_string(),
                d.to_string(),
                n.to_string(),
                bound.to_string(),
                empirical.to_string(),
                format!("{loose:.1}"),
            ])?;
        }
    }
    println!("bound holds everywhere (paper: it is loose — see loose factor).\n");
    Ok(())
}

fn section_rho() -> anyhow::Result<()> {
    println!("== E6 / §4.3: r_ε(ρ) — why KLD-WRM (ρ=0.5) benefits more ==");
    println!("{:>6} {:>8} {:>14}", "rho", "r_eps", "r_eps·n (n=256)");
    for &rho in &[0.5, 0.7, 0.8, 0.9, 0.95, 0.99] {
        let re = errors::r_epsilon(0.1, 0.03, rho);
        println!("{:>6} {:>8} {:>14}", rho, re, re * 256);
    }
    println!("paper's two quoted points: ρ=0.95 → 29184, ρ=0.5 → 2304.\n");
    Ok(())
}

fn section_rank(quick: bool) -> anyhow::Result<()> {
    println!("== rank/power-iteration ablation: RS-KFAC step error vs exact K-FAC ==");
    let d_a = if quick { 96 } else { 256 };
    let d_g = if quick { 64 } else { 128 };
    let mut rng = Pcg64::new(9);
    // Decayed EA factors (equilibrium regime — where the paper operates).
    let mk = |d: usize, rng: &mut Pcg64| {
        let q = rkfac::linalg::qr::orthonormalize(&rng.gaussian_matrix(d, d));
        let lam: Vec<f64> = (0..d).map(|i| 3.0 * 0.93f64.powi(i as i32) + 0.01).collect();
        let mut qd = q.clone();
        gemm::scale_cols(&mut qd, &lam);
        gemm::matmul_nt(&qd, &q)
    };
    let a = mk(d_a, &mut rng);
    let g = mk(d_g, &mut rng);
    let grad = rng.gaussian_matrix(d_g, d_a);
    let sched_for = |r: usize, pwr: usize| KfacSchedules {
        rho: 0.95,
        t_ku: 1,
        t_ki: StepSchedule::constant(1.0),
        lambda: StepSchedule::constant(0.1),
        alpha: StepSchedule::constant(1.0),
        rank: StepSchedule::constant(r as f64),
        oversample: StepSchedule::constant(10.0),
        n_power_iter: pwr,
        weight_decay: 0.0,
    };
    let dims = [(d_a, d_g)];
    let exact_step = {
        let mut o =
            KfacOptimizer::new(Arc::new(decomposition::Exact), sched_for(d_a, 0), &dims, 1);
        o.step_with_factors(0, vec![a.clone()], vec![g.clone()], &[&grad]).remove(0)
    };
    let mut csv =
        CsvLogger::create("results/ablation_rank.csv", &["rank", "n_pwr", "rel_err_vs_exact"])?;
    println!("{:>6} {:>7} {:>16}", "rank", "n_pwr", "rel_err_vs_exact");
    let ranks: Vec<usize> = if quick { vec![8, 32, 64] } else { vec![8, 16, 32, 64, 128, 220.min(d_a - 11)] };
    for &r in &ranks {
        for &pwr in &[0usize, 4] {
            let mut o =
                KfacOptimizer::new(Arc::new(decomposition::Rsvd), sched_for(r, pwr), &dims, 2);
            let step =
                o.step_with_factors(0, vec![a.clone()], vec![g.clone()], &[&grad]).remove(0);
            let err = step.rel_err(&exact_step);
            println!("{:>6} {:>7} {:>16.3e}", r, pwr, err);
            csv.row(&[r.to_string(), pwr.to_string(), format!("{err:.3e}")])?;
        }
    }
    println!("expected: error falls rapidly with r (spectrum decay) and mildly with n_pwr.");
    println!("results -> results/ablation_rank.csv\n");
    Ok(())
}

fn section_rsvd_quality(quick: bool) -> anyhow::Result<()> {
    println!("== oversampling ablation: RSVD tail accuracy vs r_l ==");
    let d = if quick { 96 } else { 256 };
    let mut rng = Pcg64::new(11);
    let q = rkfac::linalg::qr::orthonormalize(&rng.gaussian_matrix(d, d));
    let lam: Vec<f64> = (0..d).map(|i| 0.9f64.powi(i as i32)).collect();
    let mut qd = q.clone();
    gemm::scale_cols(&mut qd, &lam);
    let x = gemm::matmul_nt(&qd, &q);
    let r = 24;
    println!("{:>6} {:>16}", "r_l", "total_err");
    for &rl in &[0usize, 2, 5, 10, 20] {
        let mut err = 0.0;
        let trials = if quick { 2 } else { 4 };
        for t in 0..trials {
            let mut rg = Pcg64::new(100 + t);
            let out = rsvd(&x, &SketchConfig::new(r, rl, 1), &mut rg);
            err += (&x - &out.reconstruct_vv()).fro_norm() / trials as f64;
        }
        println!("{:>6} {:>16.6e}", rl, err);
    }
    println!("expected: error decreases then saturates — the paper's 'minimal cost' r_l≈10.\n");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    section_prop31(quick)?;
    section_rho()?;
    section_rank(quick)?;
    section_rsvd_quality(quick)?;
    Ok(())
}
