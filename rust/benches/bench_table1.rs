//! E2 — Table 1: {SENG, K-FAC, RS-KFAC, SRE-KFAC} on the (scaled) CIFAR
//! workload — time to each accuracy target, time per epoch, success counts,
//! epochs to the hardest target; mean ± std across seeded runs.
//!
//! Scaled substitution (EXPERIMENTS.md): synthetic-CIFAR MLP instead of
//! V100-trained VGG16_bn; targets straddle easy/near-asymptotic/hard for
//! this workload. The paper's *shape*: randomized K-FACs ≈2.4× cheaper per
//! epoch than K-FAC, ≈3× faster to target accuracy, SRE slightly cheaper
//! but less reliable at the hardest target; SENG competitive.
//!
//! Quick mode: RKFAC_BENCH_QUICK=1.

use rkfac::coordinator::config::{DataChoice, EngineChoice, ModelChoice, TrainConfig};
use rkfac::coordinator::metrics::{summarize, CsvLogger};
use rkfac::coordinator::trainer;
use rkfac::util::benchkit::quick_mode;

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let (epochs, n_runs, n_train, widths) = if quick {
        (2usize, 1usize, 1280usize, vec![192, 128, 10])
    } else {
        (6, 2, 4096, vec![768, 512, 256, 10])
    };
    let targets = vec![0.60, 0.68, 0.72];
    let solvers = ["seng", "kfac", "rs-kfac", "sre-kfac"];
    let (h, w) = if quick { (8, 8) } else { (16, 16) };

    let mut csv = CsvLogger::create(
        "results/table1.csv",
        &[
            "solver", "t_acc1_mean", "t_acc1_std", "t_acc2_mean", "t_acc2_std", "t_acc3_mean",
            "t_acc3_std", "t_epoch_mean", "t_epoch_std", "hits_acc3", "runs", "epochs_to_acc3",
        ],
    )?;

    println!("== E2 / Table 1: solver comparison ({n_runs} runs × {epochs} epochs) ==");
    let mut summaries = Vec::new();
    for solver in solvers {
        let mut runs = Vec::new();
        for r in 0..n_runs {
            let cfg = TrainConfig {
                solver: solver.into(),
                epochs,
                batch: 128,
                seed: 100 + r as u64,
                model: ModelChoice::Mlp { widths: widths.clone() },
                data: DataChoice::Synthetic { n_train, n_test: n_train / 4, height: h, width: w, channels: 3 },
                engine: EngineChoice::Native,
                targets: targets.clone(),
                augment: false,
                out_dir: "results/table1".into(),
                sched_width: 0,
                ..Default::default()
            };
            eprintln!("[table1] {solver} seed {} ...", cfg.seed);
            let res = trainer::run(&cfg)?;
            res.write_csv(format!("results/table1/{}_{}.csv", solver, cfg.seed))?;
            runs.push(res);
        }
        let s = summarize(&runs, &targets);
        csv.row(&[
            s.solver.clone(),
            format!("{:.2}", s.time_to[0].1),
            format!("{:.2}", s.time_to[0].2),
            format!("{:.2}", s.time_to.get(1).map(|t| t.1).unwrap_or(f64::NAN)),
            format!("{:.2}", s.time_to.get(1).map(|t| t.2).unwrap_or(f64::NAN)),
            format!("{:.2}", s.time_to.last().unwrap().1),
            format!("{:.2}", s.time_to.last().unwrap().2),
            format!("{:.3}", s.t_epoch_mean),
            format!("{:.3}", s.t_epoch_std),
            s.time_to.last().unwrap().3.to_string(),
            s.n_runs.to_string(),
            format!("{:.1}", s.epochs_to_last.1),
        ])?;
        summaries.push(s);
    }

    // Paper-format table.
    println!("\n{:<10} | {:>16} {:>16} {:>16} | {:>14} | {:>10} | {:>8}",
        "solver", "t_acc>=60%", "t_acc>=68%", "t_acc>=72%", "t_epoch", "hits 72%", "N_epochs");
    for s in &summaries {
        let fmt = |i: usize| {
            let (_, m, sd, hits) = s.time_to[i];
            if hits == 0 {
                "—".to_string()
            } else {
                format!("{m:.1}±{sd:.1}")
            }
        };
        println!(
            "{:<10} | {:>16} {:>16} {:>16} | {:>8.2}±{:<5.2} | {:>6}/{:<3} | {:>8.1}",
            s.solver,
            fmt(0),
            fmt(1),
            fmt(2),
            s.t_epoch_mean,
            s.t_epoch_std,
            s.time_to.last().unwrap().3,
            s.n_runs,
            s.epochs_to_last.1,
        );
    }

    // Headline ratios (paper: ≈2.4–2.5× per-epoch, ≈3.3× time-to-target).
    let get = |name: &str| summaries.iter().find(|s| s.solver == name).unwrap();
    let kfac = get("kfac");
    let rs = get("rs-kfac");
    println!("\nheadline ratios vs exact K-FAC (paper: ≈2.4x t_epoch, ≈3.3x time-to-acc):");
    println!("  rs-kfac t_epoch speedup : {:.2}x", kfac.t_epoch_mean / rs.t_epoch_mean);
    if kfac.time_to[0].3 > 0 && rs.time_to[0].3 > 0 {
        println!(
            "  rs-kfac time-to-{:.0}% speedup: {:.2}x",
            kfac.time_to[0].0 * 100.0,
            kfac.time_to[0].1 / rs.time_to[0].1
        );
    }
    println!("\nresults -> results/table1.csv (+ per-run CSVs under results/table1/)");
    Ok(())
}
