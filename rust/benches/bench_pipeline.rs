//! E8 — async factor-refresh pipeline: sync vs async preconditioning on
//! the wide-MLP workload (the regime the paper targets, §4.4).
//!
//! Drives the same step loop four ways:
//!   * `sync`       — inline decompositions (the seed behaviour),
//!   * `async`      — background pipeline, bounded staleness, adaptive
//!     rank, cost-aware `flops-stale` priority scheduling (the default),
//!   * `async-fifo` — identical config but plain FIFO job order, so the
//!     scheduler's contribution is isolated (fifo-vs-priority step time),
//!   * `async-0`    — pipeline with `max_stale_steps = 0`, which must
//!     reproduce the synchronous losses **bitwise** (contract check).
//!
//! Reports mean/max step wall time, the step-loop decomposition blocking
//! time, the background worker compute time, the fifo→priority step-time
//! ratio, and the adaptive per-block ranks. Results go to stdout and
//! `BENCH_pipeline.json` at the repo root.
//!
//! Quick mode: RKFAC_BENCH_QUICK=1.

use std::io::Write as _;
use std::sync::Arc;

use rkfac::linalg::Pcg64;
use rkfac::nn::models;
use rkfac::optim::schedules::{KfacSchedules, StepSchedule};
use rkfac::optim::KfacOptimizer;
use rkfac::pipeline::{OnlineMode, PipelineConfig, Schedule};
use rkfac::rnla::decomposition;
use rkfac::util::benchkit::{format_secs, quick_mode};

struct RunStats {
    label: String,
    mean_step_s: f64,
    max_step_s: f64,
    blocked_s: f64,
    worker_s: f64,
    losses: Vec<f64>,
    ranks: Vec<(usize, usize)>,
    ctl_ranks: Vec<usize>,
    online_updates: usize,
    full_decomps: usize,
}

fn bench_sched(width: usize, t_ki: usize) -> KfacSchedules {
    KfacSchedules {
        rho: 0.95,
        t_ku: 2,
        t_ki: StepSchedule::constant(t_ki as f64),
        lambda: StepSchedule::constant(0.1),
        alpha: StepSchedule::constant(0.1),
        rank: StepSchedule::constant(((width / 2).clamp(16, 220)) as f64),
        oversample: StepSchedule::constant(10.0),
        n_power_iter: 4,
        weight_decay: 0.0,
    }
}

fn run_steps(
    label: &str,
    pipeline: Option<PipelineConfig>,
    online: Option<usize>,
    widths: &[usize],
    batch: usize,
    n_steps: usize,
    t_ki: usize,
    seed: u64,
) -> RunStats {
    let width = *widths.iter().max().unwrap();
    let mut net = models::mlp(widths, seed);
    let dims = net.kfac_dims();
    let mut opt =
        KfacOptimizer::new(Arc::new(decomposition::Rsvd), bench_sched(width, t_ki), &dims, seed);
    if let Some(cfg) = pipeline {
        opt.attach_pipeline(cfg);
    }
    if let Some(correction_every) = online {
        assert!(
            opt.set_online(OnlineMode::Rsvd, correction_every),
            "rsvd must support online updates"
        );
    }
    let mut data_rng = Pcg64::with_stream(seed, 555);
    let mut times = Vec::with_capacity(n_steps);
    let mut losses = Vec::with_capacity(n_steps);
    let lr = opt.sched.alpha.at(0);
    for _ in 0..n_steps {
        let x = data_rng.gaussian_matrix(widths[0], batch);
        let labels: Vec<usize> = (0..batch).map(|_| data_rng.below(widths[widths.len() - 1])).collect();
        let t0 = std::time::Instant::now();
        let (loss, _) = net.train_batch(&x, &labels, true);
        let deltas = {
            let caps = net.kfac_captures();
            opt.step(0, &caps)
        };
        net.apply_steps(&deltas, lr, 0.0);
        times.push(t0.elapsed().as_secs_f64());
        losses.push(loss);
    }
    // Skip step 0: it always pays the mandatory first decomposition.
    let steady = &times[1..];
    let mean_step_s = steady.iter().sum::<f64>() / steady.len() as f64;
    let max_step_s = steady.iter().cloned().fold(0.0, f64::max);
    let (worker_s, ctl_ranks) = match opt.pipeline() {
        Some(p) => (p.worker_seconds(), p.ranks()),
        None => (0.0, vec![]),
    };
    RunStats {
        label: label.to_string(),
        mean_step_s,
        max_step_s,
        blocked_s: opt.decomp_seconds,
        worker_s,
        losses,
        ranks: opt.current_ranks(),
        ctl_ranks,
        online_updates: opt.online_updates(),
        full_decomps: opt.full_decomps(),
    }
}

fn json_ranks(ranks: &[(usize, usize)]) -> String {
    let items: Vec<String> = ranks.iter().map(|(a, g)| format!("[{a}, {g}]")).collect();
    format!("[{}]", items.join(", "))
}

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let width = if quick { 192 } else { 512 };
    let widths = vec![768, width, width, 10];
    let batch = 128;
    let n_steps = if quick { 12 } else { 30 };
    let t_ki = 5;
    let stale = 2 * t_ki; // allow one full refresh round of lag
    let seed = 42;

    println!(
        "== E8: async factor refresh on wide MLP {widths:?} (batch {batch}, {n_steps} steps, \
         T_KI {t_ki}) =="
    );

    let correction_every = 8;

    let sync = run_steps("sync", None, None, &widths, batch, n_steps, t_ki, seed);
    let asynch = run_steps(
        "async",
        Some(PipelineConfig {
            enabled: true,
            workers: 2,
            max_stale_steps: stale,
            schedule: Schedule::FlopsStale,
            adaptive_rank: true,
            prop31_batch: batch,
            ..Default::default()
        }),
        None,
        &widths,
        batch,
        n_steps,
        t_ki,
        seed,
    );
    let async_fifo = run_steps(
        "async-fifo",
        Some(PipelineConfig {
            enabled: true,
            workers: 2,
            max_stale_steps: stale,
            schedule: Schedule::Fifo,
            adaptive_rank: true,
            prop31_batch: batch,
            ..Default::default()
        }),
        None,
        &widths,
        batch,
        n_steps,
        t_ki,
        seed,
    );
    let async0 = run_steps(
        "async-0",
        Some(PipelineConfig {
            enabled: true,
            workers: 2,
            max_stale_steps: 0,
            ..Default::default()
        }),
        None,
        &widths,
        batch,
        n_steps,
        t_ki,
        seed,
    );
    // online-vs-recompute: inline refresh path, but T_KI refreshes rotate
    // the installed basis through the accumulated EA deltas instead of
    // re-sketching the dense factor (full decomposition only every
    // `correction_every` rounds).
    let online =
        run_steps("online", None, Some(correction_every), &widths, batch, n_steps, t_ki, seed);

    let exact_match = sync
        .losses
        .iter()
        .zip(async0.losses.iter())
        .all(|(a, b)| a.to_bits() == b.to_bits());

    println!(
        "{:<12} {:>12} {:>12} {:>12} {:>12}",
        "mode", "mean_step", "max_step", "blocked", "worker_cpu"
    );
    for s in [&sync, &asynch, &async_fifo, &async0, &online] {
        println!(
            "{:<12} {:>12} {:>12} {:>12} {:>12}",
            s.label,
            format_secs(s.mean_step_s),
            format_secs(s.max_step_s),
            format_secs(s.blocked_s),
            format_secs(s.worker_s),
        );
    }
    let speedup = sync.mean_step_s / asynch.mean_step_s.max(1e-12);
    let fifo_to_priority = async_fifo.mean_step_s / asynch.mean_step_s.max(1e-12);
    let online_speedup = sync.mean_step_s / online.mean_step_s.max(1e-12);
    println!("async speedup (mean step): {speedup:.2}x");
    println!("priority vs fifo (mean step, >1 = priority faster): {fifo_to_priority:.2}x");
    println!(
        "online speedup (mean step): {online_speedup:.2}x ({} updates / {} full decompositions)",
        online.online_updates, online.full_decomps
    );
    println!("zero-staleness bitwise match vs sync: {exact_match}");
    println!("adaptive per-block ranks (A, Γ): {:?}", asynch.ranks);
    assert!(exact_match, "async-0 must reproduce the synchronous losses bitwise");

    // Repo-root JSON so the numbers stay comparable across PRs.
    let out = std::env::var("RKFAC_BENCH_OUT")
        .unwrap_or_else(|_| format!("{}/../BENCH_pipeline.json", env!("CARGO_MANIFEST_DIR")));
    let mut f = std::fs::File::create(&out)?;
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"pipeline\",")?;
    writeln!(
        f,
        "  \"workload\": {{\"widths\": {widths:?}, \"batch\": {batch}, \"steps\": {n_steps}, \
         \"t_ki\": {t_ki}, \"solver\": \"rs-kfac\", \"quick\": {quick}}},"
    )?;
    for s in [&sync, &asynch, &async_fifo, &async0, &online] {
        writeln!(
            f,
            "  \"{}\": {{\"mean_step_s\": {:.6e}, \"max_step_s\": {:.6e}, \
             \"blocked_s\": {:.6e}, \"worker_s\": {:.6e}}},",
            s.label, s.mean_step_s, s.max_step_s, s.blocked_s, s.worker_s
        )?;
    }
    writeln!(f, "  \"async_config\": {{\"workers\": 2, \"max_stale_steps\": {stale}, \"adaptive_rank\": true, \"schedule\": \"flops-stale\"}},")?;
    writeln!(f, "  \"online_config\": {{\"mode\": \"rsvd\", \"correction_every\": {correction_every}}},")?;
    writeln!(f, "  \"speedup_mean_step\": {speedup:.4},")?;
    writeln!(f, "  \"priority_vs_fifo_mean_step\": {fifo_to_priority:.4},")?;
    writeln!(f, "  \"online_speedup_mean_step\": {online_speedup:.4},")?;
    writeln!(
        f,
        "  \"online_jobs\": {{\"updates\": {}, \"full\": {}}},",
        online.online_updates, online.full_decomps
    )?;
    writeln!(f, "  \"zero_staleness_exact_match\": {exact_match},")?;
    writeln!(f, "  \"adaptive_block_ranks\": {},", json_ranks(&asynch.ranks))?;
    writeln!(f, "  \"controller_slot_ranks\": {:?}", asynch.ctl_ranks)?;
    writeln!(f, "}}")?;
    println!("results -> {out}");
    Ok(())
}
