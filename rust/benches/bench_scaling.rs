//! E4 — the complexity claim (§4.4): decomposition cost vs layer width.
//!
//! Sweeps d_M and times: exact symmetric EVD (K-FAC, O(d³)), RSVD and
//! SREVD at the paper's rank schedule (r=220, r_l=10, n_pwr=4 — O(d²(r+l))),
//! and the SENG per-layer Woodbury solve (O(d)). Fits log-log slopes and
//! reports the crossover. The paper's shape to reproduce:
//!   EVD slope ≈ 3, randomized slopes ≈ 2, SENG ≈ 1;
//!   randomized beats exact by ≈2.5× at d≈512 and the gap widens.
//!
//! Quick mode: RKFAC_BENCH_QUICK=1 (smaller sweep).

use rkfac::linalg::{chol, evd, gemm, Matrix, Pcg64};
use rkfac::rnla::{rsvd, srevd, SketchConfig};
use rkfac::util::benchkit::{bench, loglog_slope, print_table, quick_mode, write_csv};

fn decaying_psd(rng: &mut Pcg64, d: usize) -> Matrix {
    // EA-K-factor-like: strong decay + identity floor.
    let k = (d / 4).max(8);
    let g = rng.gaussian_matrix(d, k);
    let mut s = gemm::syrk(&g);
    s.add_diag(0.05);
    s
}

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let dims: Vec<usize> =
        if quick { vec![128, 256, 384] } else { vec![256, 384, 512, 768, 1024] };
    let rand_extra: Vec<usize> = if quick { vec![] } else { vec![1536, 2048] };
    let samples = if quick { 1 } else { 2 };
    let rank = 220usize;
    let oversample = 10usize;
    let n_pwr = 4usize;

    let mut all = Vec::new();
    let mut evd_pts = Vec::new();
    let mut rsvd_pts = Vec::new();
    let mut srevd_pts = Vec::new();
    let mut seng_pts = Vec::new();

    for &d in dims.iter().chain(rand_extra.iter()) {
        let mut rng = Pcg64::new(d as u64);
        let x = decaying_psd(&mut rng, d);
        let cfg = SketchConfig::new(rank.min(d / 2), oversample, n_pwr);

        if dims.contains(&d) {
            let s = bench(&format!("evd_d{d}"), 0, samples, || {
                std::hint::black_box(evd::sym_evd(&x));
            });
            evd_pts.push((d as f64, s.mean_s));
            all.push(s);
        }
        let mut r1 = Pcg64::new(1);
        let s = bench(&format!("rsvd_d{d}"), 0, samples, || {
            std::hint::black_box(rsvd(&x, &cfg, &mut r1));
        });
        rsvd_pts.push((d as f64, s.mean_s));
        all.push(s);

        let mut r2 = Pcg64::new(2);
        let s = bench(&format!("srevd_d{d}"), 0, samples, || {
            std::hint::black_box(srevd(&x, &cfg, &mut r2));
        });
        srevd_pts.push((d as f64, s.mean_s));
        all.push(s);

        // SENG-style step: Woodbury with a d×k sketch factor.
        let b = 256.min(d);
        let u = Pcg64::new(3).gaussian_matrix(d, b.min(64));
        let rhs = Pcg64::new(4).gaussian_matrix(d, 1);
        let s = bench(&format!("seng_woodbury_d{d}"), 0, samples, || {
            std::hint::black_box(chol::woodbury_solve(&u, b as f64, 2.0, &rhs).unwrap());
        });
        seng_pts.push((d as f64, s.mean_s));
        all.push(s);
    }

    print_table("E4: decomposition cost vs layer width d_M", &all);

    let slope = |pts: &[(f64, f64)]| {
        let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
        let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
        loglog_slope(&xs, &ys)
    };
    println!("\nfitted scaling exponents (paper: EVD→3, randomized→2, SENG→1):");
    println!("  evd    : {:.2}", slope(&evd_pts));
    println!("  rsvd   : {:.2}", slope(&rsvd_pts));
    println!("  srevd  : {:.2}", slope(&srevd_pts));
    println!("  seng   : {:.2}", slope(&seng_pts));

    println!("\nexact-EVD / RSVD speedup by width (paper: ≈2.5× at VGG16 widths):");
    for (e, r) in evd_pts.iter().zip(rsvd_pts.iter()) {
        let sre = srevd_pts.iter().find(|p| p.0 == e.0).unwrap();
        println!("  d={:<5} {:>6.2}x (srevd {:>6.2}x)", e.0, e.1 / r.1, e.1 / sre.1);
    }
    write_csv("results/scaling.csv", &all)?;
    println!("\nresults -> results/scaling.csv");
    Ok(())
}
