//! E7 — RSVD vs SREVD accuracy anatomy (§2.2.1–§2.3).
//!
//! On EA-K-factor-shaped PSD matrices, measures per method:
//!   * truncation error (the Eckart–Young floor an exact rank-r EVD pays),
//!   * projection error (extra error from randomization),
//!   * total error,
//! for RSVD-V (what RS-KFAC uses), RSVD-U (the worse side — §2.2.2),
//! SREVD (both-side projection — SRE-KFAC), and exact truncation.
//! Also times each decomposition (the accuracy/cost trade the paper
//! discusses in §4.2).

use std::io::Write;

use rkfac::coordinator::metrics::CsvLogger;
use rkfac::linalg::backend::{self, BackendKind, Precision};
use rkfac::linalg::{evd, gemm, qr, Matrix, Pcg64};
use rkfac::pipeline::RankController;
use rkfac::rnla::{errors, rsvd, srevd, FactoredSolve, LowRankFactor, SketchConfig};
use rkfac::util::benchkit::{bench, print_table, quick_mode};
use rkfac::util::cli::Args;

fn ea_like_psd(rng: &mut Pcg64, d: usize, decay: f64) -> Matrix {
    let q = qr::orthonormalize(&rng.gaussian_matrix(d, d));
    let lam: Vec<f64> = (0..d).map(|i| decay.powi(i as i32).max(1e-8)).collect();
    let mut qd = q.clone();
    gemm::scale_cols(&mut qd, &lam);
    gemm::matmul_nt(&qd, &q)
}

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    // Honor the CI matrix's RKFAC_LINALG_{BACKEND,THREADS,PRECISION} env;
    // the per-backend section below sweeps all variants regardless, but the
    // accuracy sections run under whatever the matrix installed.
    let sel = backend::install_from_env();
    println!(
        "linalg backend: {} (threads={}, precision={})",
        sel.kind.name(),
        sel.threads,
        sel.precision.name()
    );
    let d = if quick { 192 } else { 512 };
    let ranks: Vec<usize> = if quick { vec![16, 48] } else { vec![32, 64, 128, 220] };
    let n_trials = if quick { 2 } else { 4 };

    let mut rng = Pcg64::new(42);
    let x = ea_like_psd(&mut rng, d, 0.96);

    let mut csv = CsvLogger::create(
        "results/rnla_accuracy.csv",
        &["method", "rank", "truncation", "projection", "total"],
    )?;

    println!("== E7: error anatomy on a d={d} EA-like K-factor (decay 0.96) ==");
    println!(
        "{:<10} {:>5} {:>14} {:>14} {:>14}",
        "method", "r", "truncation", "projection", "total"
    );
    for &r in &ranks {
        let cfg = SketchConfig::new(r, 10, 4);
        // Accumulate over trials (fresh random sketches).
        let mut rows: Vec<(&str, f64, f64, f64)> = Vec::new();
        let mut acc = |name: &'static str, recon: &dyn Fn(&mut Pcg64) -> Matrix| {
            let mut t = (0.0, 0.0, 0.0);
            for trial in 0..n_trials {
                let mut r2 = Pcg64::new(1000 + trial as u64);
                let split = errors::error_split(&x, &recon(&mut r2), r);
                t.0 += split.truncation / n_trials as f64;
                t.1 += split.projection / n_trials as f64;
                t.2 += split.total / n_trials as f64;
            }
            rows.push((name, t.0, t.1, t.2));
        };
        acc("rsvd-V", &|rg| rsvd(&x, &cfg, rg).reconstruct_vv());
        acc("rsvd-U", &|rg| rsvd(&x, &cfg, rg).reconstruct_uu());
        acc("srevd", &|rg| srevd(&x, &cfg, rg).reconstruct());
        acc("exact-r", &|_| evd::sym_evd(&x).truncate(r).reconstruct());
        for (name, tr, pr, to) in rows {
            println!("{:<10} {:>5} {:>14.6e} {:>14.6e} {:>14.6e}", name, r, tr, pr, to);
            csv.row(&[
                name.to_string(),
                r.to_string(),
                format!("{tr:.6e}"),
                format!("{pr:.6e}"),
                format!("{to:.6e}"),
            ])?;
        }
        println!();
    }
    println!("expected shape: projection(rsvd-V) ≈ 0 ≤ projection(rsvd-U) ≤ projection(srevd);");
    println!("total ≈ truncation for rsvd-V (the paper's 'virtually zero projection error').");

    // Cost side at the paper's rank.
    let cfg = SketchConfig::new(220.min(d / 2), 10, 4);
    let mut samples = Vec::new();
    samples.push(bench("exact_evd", 0, 2, || {
        std::hint::black_box(evd::sym_evd(&x));
    }));
    let mut ra = Pcg64::new(7);
    samples.push(bench("rsvd", 0, 2, || {
        std::hint::black_box(rsvd(&x, &cfg, &mut ra));
    }));
    let mut rb = Pcg64::new(8);
    samples.push(bench("srevd", 0, 2, || {
        std::hint::black_box(srevd(&x, &cfg, &mut rb));
    }));
    print_table(&format!("decomposition cost at d={d}, r+l={}", cfg.subspace(d)), &samples);

    // Per-backend kernel/decomposition timings, written to the repo-root
    // BENCH_linalg.json (placeholder-null schema mirrors BENCH_pipeline.json
    // so the numbers stay comparable across PRs). Each variant runs under a
    // scoped install at the matrix's thread count.
    let sketch_op = Pcg64::new(9).gaussian_matrix(d, cfg.subspace(d));
    let variants: [(&str, BackendKind, Precision); 3] = [
        ("reference", BackendKind::Reference, Precision::F64),
        ("threaded", BackendKind::Threaded, Precision::F64),
        ("threaded_mixed", BackendKind::Threaded, Precision::Mixed),
    ];
    let mut backend_rows: Vec<(&str, f64, f64, f64, f64)> = Vec::new();
    for (label, kind, prec) in variants {
        let _bk = backend::scoped(kind, sel.threads, prec);
        let row = [
            bench(&format!("{label}/gemm"), 1, 2, || {
                std::hint::black_box(gemm::matmul(&x, &sketch_op));
            }),
            bench(&format!("{label}/syrk"), 1, 2, || {
                std::hint::black_box(gemm::syrk(&x));
            }),
            bench(&format!("{label}/qr"), 1, 2, || {
                std::hint::black_box(qr::thin_qr(&sketch_op));
            }),
            {
                let mut rr = Pcg64::new(11);
                bench(&format!("{label}/rsvd"), 0, 2, || {
                    std::hint::black_box(rsvd(&x, &cfg, &mut rr));
                })
            },
        ];
        print_table(&format!("backend {label} (threads={})", sel.threads), &row);
        backend_rows.push((label, row[0].mean_s, row[1].mean_s, row[2].mean_s, row[3].mean_s));
    }
    let out = std::env::var("RKFAC_BENCH_OUT")
        .unwrap_or_else(|_| format!("{}/../BENCH_linalg.json", env!("CARGO_MANIFEST_DIR")));
    let mut jf = std::fs::File::create(&out)?;
    writeln!(jf, "{{")?;
    writeln!(jf, "  \"bench\": \"linalg\",")?;
    writeln!(
        jf,
        "  \"workload\": {{\"d\": {d}, \"rank\": {}, \"subspace\": {}, \"threads\": {}, \
         \"quick\": {quick}}},",
        cfg.rank,
        cfg.subspace(d),
        sel.threads
    )?;
    for (label, g, s, q, r) in &backend_rows {
        writeln!(
            jf,
            "  \"{label}\": {{\"gemm_s\": {g:.6e}, \"syrk_s\": {s:.6e}, \"qr_s\": {q:.6e}, \
             \"rsvd_s\": {r:.6e}}},"
        )?;
    }
    writeln!(jf, "  \"threaded_speedup_rsvd\": {:.4}", backend_rows[0].4 / backend_rows[1].4)?;
    writeln!(jf, "}}")?;
    println!("backend timings -> {out}");

    // Wide-layer arm: one vocab-scale G-side solve, three routes. The
    // woodbury route never forms the o×o gram; the rsvd/exact routes pay
    // the syrk + decomposition a dense engine would. Written to the
    // repo-root BENCH_factored.json (placeholder-null schema, like
    // BENCH_linalg.json) so the numbers stay comparable across PRs.
    let wd = if quick { 1024 } else { 4096 };
    let wk = 128.min(wd / 4);
    let wc = 32;
    let lambda = 0.1;
    let mut wrng = Pcg64::new(21);
    let wu = wrng.gaussian_matrix(wd, wk);
    let wy = wrng.gaussian_matrix(wd, wc);
    let w_build = bench("woodbury/build", 0, 2, || {
        std::hint::black_box(FactoredSolve::build(wu.clone(), 1.0, lambda).unwrap());
    });
    let mut wsolve = FactoredSolve::build(wu.clone(), 1.0, lambda).unwrap();
    let w_apply = bench("woodbury/apply", 0, 2, || {
        std::hint::black_box(wsolve.apply(lambda, &wy));
    });
    let wide_gram = {
        let mut g = gemm::matmul_nt(&wu, &wu);
        g.add_diag(1.0);
        g
    };
    let r_cfg = SketchConfig::new(wk, 10, 2);
    let mut rwrng = Pcg64::new(22);
    let r_dec = bench("rsvd/decompose", 0, 2, || {
        std::hint::black_box(rsvd(&wide_gram, &r_cfg, &mut rwrng));
    });
    let r_factor = {
        let f = rsvd(&wide_gram, &r_cfg, &mut rwrng);
        LowRankFactor::new(f.v.clone(), f.sigma.clone())
    };
    let r_apply = bench("rsvd/apply", 0, 2, || {
        std::hint::black_box(r_factor.damped_inverse_apply(lambda, &wy));
    });
    let e_dec = bench("exact/decompose", 0, 2, || {
        std::hint::black_box(evd::sym_evd(&wide_gram));
    });
    let e_evd = evd::sym_evd(&wide_gram);
    let e_factor = LowRankFactor::new(e_evd.u, e_evd.lambda);
    let e_apply = bench("exact/apply", 0, 2, || {
        std::hint::black_box(e_factor.damped_inverse_apply(lambda, &wy));
    });
    let wide_rows = [
        w_build.clone(),
        w_apply.clone(),
        r_dec.clone(),
        r_apply.clone(),
        e_dec.clone(),
        e_apply.clone(),
    ];
    print_table(
        &format!("wide-layer G solve (o={wd}, retained k={wk}, {wc} gradient columns)"),
        &wide_rows,
    );
    let fout = std::env::var("RKFAC_BENCH_FACTORED_OUT")
        .unwrap_or_else(|_| format!("{}/../BENCH_factored.json", env!("CARGO_MANIFEST_DIR")));
    let mut ff = std::fs::File::create(&fout)?;
    writeln!(ff, "{{")?;
    writeln!(ff, "  \"bench\": \"factored\",")?;
    writeln!(
        ff,
        "  \"workload\": {{\"o\": {wd}, \"k\": {wk}, \"cols\": {wc}, \"lambda\": {lambda}, \
         \"quick\": {quick}}},"
    )?;
    writeln!(
        ff,
        "  \"woodbury\": {{\"build_s\": {:.6e}, \"apply_s\": {:.6e}}},",
        w_build.mean_s, w_apply.mean_s
    )?;
    writeln!(
        ff,
        "  \"rsvd\": {{\"decompose_s\": {:.6e}, \"apply_s\": {:.6e}}},",
        r_dec.mean_s, r_apply.mean_s
    )?;
    writeln!(
        ff,
        "  \"exact\": {{\"decompose_s\": {:.6e}, \"apply_s\": {:.6e}}},",
        e_dec.mean_s, e_apply.mean_s
    )?;
    writeln!(
        ff,
        "  \"woodbury_speedup_vs_exact\": {:.4}",
        (e_dec.mean_s + e_apply.mean_s) / (w_build.mean_s + w_apply.mean_s)
    )?;
    writeln!(ff, "}}")?;
    println!("factored timings -> {fout}");

    // Per-block adaptive rank (pipeline rank controller) at the requested
    // error target — the same machinery the async pipeline uses, so the
    // CSV stays comparable across PRs now that ranks are per layer.
    let target = Args::from_env().get_f64("target", 0.03);
    println!("\n== adaptive rank per block (target rel err {target}) ==");
    let decays: &[f64] = if quick { &[0.9, 0.96] } else { &[0.9, 0.96, 0.99] };
    for (bi, &decay) in decays.iter().enumerate() {
        let xb = ea_like_psd(&mut Pcg64::new(500 + bi as u64), d, decay);
        let mut ctl = RankController::new(d.min(220), d, target, 8, 1.5, 0.95, 0);
        let mut srng = Pcg64::new(900 + bi as u64);
        for _ in 0..12 {
            let f = rsvd(&xb, &SketchConfig::new(ctl.rank, 10, 2), &mut srng);
            ctl.observe(&f.sigma);
        }
        let split = {
            let f = rsvd(&xb, &SketchConfig::new(ctl.rank, 10, 4), &mut srng);
            errors::error_split(&xb, &f.reconstruct_vv(), ctl.rank)
        };
        println!(
            "block {bi} (decay {decay}): chosen rank {:<5} total err {:.3e}",
            ctl.rank, split.total
        );
        csv.row(&[
            "adaptive".to_string(),
            ctl.rank.to_string(),
            format!("{:.6e}", split.truncation),
            format!("{:.6e}", split.projection),
            format!("{:.6e}", split.total),
        ])?;
    }
    println!("results -> results/rnla_accuracy.csv");
    Ok(())
}
