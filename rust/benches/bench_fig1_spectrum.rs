//! E1 — Fig. 1: eigen-spectrum of the EA K-factors over training.
//!
//! Dumps full spectra of two Kronecker blocks on the paper's cadence and
//! summarizes the development of the decay: λ_max growth, #modes above
//! 1% of λ_max, and the #modes needed to decay 1.5 orders of magnitude
//! (paper: flat at low k, then ~1.5 orders within ≈200 modes once the EA
//! reaches equilibrium, independent of layer width).

use rkfac::coordinator::config::{DataChoice, EngineChoice, ModelChoice, TrainConfig};
use rkfac::coordinator::spectrum::{run_probe, spectrum_csv, SpectrumConfig};
use rkfac::rnla::errors;
use rkfac::util::benchkit::quick_mode;

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let cfg = TrainConfig {
        solver: "kfac".into(),
        epochs: 4,
        batch: 128,
        seed: 7,
        // Two different widths (768 and 512) to show width-independence.
        model: ModelChoice::Mlp { widths: vec![768, 512, 256, 10] },
        data: DataChoice::Synthetic {
            n_train: if quick { 1280 } else { 4096 },
            n_test: 256,
            height: 16,
            width: 16,
            channels: 3,
        },
        engine: EngineChoice::Native,
        targets: vec![],
        augment: false,
        out_dir: "results/fig1".into(),
        sched_width: 0,
        ..Default::default()
    };
    let probe = SpectrumConfig {
        early_every: 10,
        early_until: 60,
        late_every: 30,
        blocks: vec![0, 1],
        steps: if quick { 60 } else { 180 },
        t_ku: 3,
        t_ki: 30,
    };
    let mut log = spectrum_csv("results/fig1_spectrum.csv")?;
    println!("== E1 / Fig. 1: EA K-factor spectrum development ==");
    let snaps = run_probe(&cfg, &probe, Some(&mut log))?;
    println!(
        "{:>6} {:>6} {:>4} {:>7} {:>12} {:>14} {:>18}",
        "step", "block", "fac", "dim", "lambda_max", "modes>1%max", "modes_to_1.5ord"
    );
    for s in &snaps {
        println!(
            "{:>6} {:>6} {:>4} {:>7} {:>12.4e} {:>14} {:>18}",
            s.step,
            s.block,
            s.factor,
            s.lambda.len(),
            s.lambda.first().copied().unwrap_or(0.0),
            errors::modes_above(&s.lambda, 0.01),
            s.modes_to_15_orders().map(|m| m.to_string()).unwrap_or_else(|| "—".into()),
        );
    }
    // The paper's two headline observations, checked programmatically:
    let first = snaps.iter().find(|s| s.factor == "A" && s.block == 0).unwrap();
    let last = snaps.iter().rev().find(|s| s.factor == "A" && s.block == 0).unwrap();
    let early_flat = errors::modes_above(&first.lambda, 0.1);
    let late_flat = errors::modes_above(&last.lambda, 0.1);
    println!("\nblock0 A-factor: modes within 10% of λmax: {early_flat} (early) -> {late_flat} (late)");
    println!("shape check: decay developed = {}", late_flat < early_flat);
    // Width-independence: compare modes_to_1.5ord across the two widths.
    let l0 = snaps.iter().rev().find(|s| s.factor == "A" && s.block == 0).and_then(|s| s.modes_to_15_orders());
    let l1 = snaps.iter().rev().find(|s| s.factor == "A" && s.block == 1).and_then(|s| s.modes_to_15_orders());
    println!("modes to 1.5 orders at end: width-768 block {l0:?} vs width-512 block {l1:?}");
    println!("(paper: roughly equal despite different widths)");
    println!("\nfull spectra -> results/fig1_spectrum.csv");
    Ok(())
}
