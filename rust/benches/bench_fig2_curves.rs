//! E3 — Fig. 2: test loss & test accuracy vs epoch AND vs wall time for
//! the four solvers. Emits one CSV per solver with both x-axes so the
//! figure's two panels can be plotted directly.

use rkfac::coordinator::config::{DataChoice, EngineChoice, ModelChoice, TrainConfig};
use rkfac::coordinator::trainer;
use rkfac::util::benchkit::quick_mode;

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let (epochs, n_train, widths) = if quick {
        (2usize, 1280usize, vec![192, 128, 10])
    } else {
        (8, 4096, vec![768, 512, 256, 10])
    };
    let (h, w) = if quick { (8, 8) } else { (16, 16) };
    println!("== E3 / Fig. 2: loss & accuracy curves (epoch and wall-time axes) ==");
    let mut lines = Vec::new();
    for solver in ["seng", "kfac", "rs-kfac", "sre-kfac"] {
        let cfg = TrainConfig {
            solver: solver.into(),
            epochs,
            batch: 128,
            seed: 100,
            model: ModelChoice::Mlp { widths: widths.clone() },
            data: DataChoice::Synthetic { n_train, n_test: n_train / 4, height: h, width: w, channels: 3 },
            engine: EngineChoice::Native,
            targets: vec![],
            augment: false,
            out_dir: "results/fig2".into(),
            sched_width: 0,
            ..Default::default()
        };
        eprintln!("[fig2] {solver} ...");
        let res = trainer::run(&cfg)?;
        res.write_csv(format!("results/fig2/curve_{solver}.csv"))?;
        lines.push((solver.to_string(), res));
    }
    // Joint summary to stdout: per epoch, acc of each solver.
    print!("{:>6}", "epoch");
    for (s, _) in &lines {
        print!(" {:>10}_acc {:>10}_t", s, s);
    }
    println!();
    for e in 0..epochs {
        print!("{e:>6}");
        for (_, r) in &lines {
            let rec = &r.records[e];
            print!(" {:>14.4} {:>12.1}", rec.test_acc, rec.wall_s);
        }
        println!();
    }
    println!("\nper-solver series -> results/fig2/curve_<solver>.csv");
    println!("paper shape: vs wall time the randomized K-FACs' curves shift far left of K-FAC's;");
    println!("vs epochs all K-FAC variants are comparable (truncation does not hurt per-epoch progress).");
    Ok(())
}
