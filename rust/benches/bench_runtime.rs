//! E8 — runtime sanity: PJRT artifact execution latency and model-step
//! throughput vs the native-Rust mirror. Not a paper artifact, but the
//! number that says whether the L3↔PJRT seam could ever be the bottleneck.

use std::sync::Arc;

use rkfac::linalg::{gemm, Matrix, Pcg64};
use rkfac::runtime::{CompiledModel, Engine, HostTensor};
use rkfac::util::benchkit::{bench, print_table, quick_mode};

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let engine = match Engine::new("artifacts") {
        Ok(e) => Arc::new(e),
        Err(e) => {
            println!("bench_runtime skipped: {e:#} (run `make artifacts`)");
            return Ok(());
        }
    };
    let samples = if quick { 3 } else { 10 };
    let mut rng = Pcg64::new(1);
    let mut out = Vec::new();

    // ea_gram kernel: PJRT vs native.
    let d = 256;
    let n = 128;
    let mut old = rng.gaussian_matrix(d, d);
    old.symmetrize();
    let m = rng.gaussian_matrix(d, n);
    let t_old = HostTensor::from_matrix(&old);
    let t_m = HostTensor::from_matrix(&m);
    engine.warmup(&["ea_gram_256x128"])?;
    out.push(bench("ea_gram_pjrt", 1, samples, || {
        std::hint::black_box(engine.execute("ea_gram_256x128", &[t_old.clone(), t_m.clone()]).unwrap());
    }));
    out.push(bench("ea_gram_native", 1, samples, || {
        let mut dst = old.clone();
        gemm::ea_gram_update(&mut dst, 0.95, &m, 128.0);
        std::hint::black_box(dst);
    }));

    // model_step throughput (tiny config).
    let model = CompiledModel::new(engine.clone(), "tiny")?;
    let mut wrng = Pcg64::new(2);
    let ws = model.init_weights(&mut wrng);
    let (a, g) = model.init_factors();
    let x = wrng.gaussian_matrix(model.widths()[0], model.batch());
    let mut y = Matrix::zeros(*model.widths().last().unwrap(), model.batch());
    for b in 0..model.batch() {
        y[(b % 10, b)] = 1.0;
    }
    let s = bench("mlp_step_tiny", 1, samples, || {
        std::hint::black_box(model.step(&ws, &a, &g, &x, &y).unwrap());
    });
    let steps_per_s = 1.0 / s.mean_s;
    out.push(s);

    // marshaling-only cost: build literals for the step inputs.
    out.push(bench("marshal_step_inputs", 1, samples, || {
        let mut v: Vec<HostTensor> = ws.iter().map(HostTensor::from_matrix).collect();
        v.extend(a.iter().map(HostTensor::from_matrix));
        v.extend(g.iter().map(HostTensor::from_matrix));
        v.push(HostTensor::from_matrix(&x));
        v.push(HostTensor::from_matrix(&y));
        std::hint::black_box(v);
    }));

    print_table("E8: PJRT runtime latency", &out);
    println!("\nmlp_step_tiny throughput: {steps_per_s:.1} steps/s (batch {})", model.batch());
    let marshal = out.last().unwrap().mean_s;
    let step = out[2].mean_s;
    println!("marshaling share of step: {:.1}%", 100.0 * marshal / step);
    Ok(())
}
