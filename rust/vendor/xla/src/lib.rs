//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate links a PJRT C-API plugin; this build environment has
//! neither the shared library nor network access, so this stub provides the
//! exact API surface `rkfac::runtime` compiles against. Host-side
//! [`Literal`] marshaling is fully functional (it is pure Rust and unit
//! tested); anything that would actually run XLA — `compile` / `execute` /
//! tuple extraction — returns a descriptive error. Swap this path
//! dependency for the real `xla` crate to enable the PJRT artifact engine.

use std::fmt;
use std::path::Path;

/// Stub error type (implements `std::error::Error`, so it converts into
/// `anyhow::Error` at the call sites).
#[derive(Debug)]
pub struct Error {
    message: String,
}

impl Error {
    fn new(message: impl Into<String>) -> Error {
        Error { message: message.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const OFFLINE_MSG: &str =
    "xla stub: PJRT execution is unavailable in the offline build (rust/vendor/xla is a shim; \
     substitute the real `xla` crate to run artifacts)";

/// Stub PJRT client. Construction succeeds so registry/manifest tooling
/// works; compilation reports the offline limitation.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu (offline xla stub — no PJRT execution)".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(OFFLINE_MSG))
    }
}

/// Parsed HLO module handle. The stub only checks the file exists.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let p = path.as_ref();
        if p.exists() {
            Ok(HloModuleProto { _priv: () })
        } else {
            Err(Error::new(format!("xla stub: HLO text file '{}' not found", p.display())))
        }
    }
}

pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: Clone>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(OFFLINE_MSG))
    }
}

pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(OFFLINE_MSG))
    }
}

/// Conversion out of a literal's f32 storage (stands in for the real
/// crate's `ArrayElement` machinery — only f32/f64 are needed here).
pub trait FromF32: Copy {
    fn from_f32(v: f32) -> Self;
}

impl FromF32 for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

impl FromF32 for f64 {
    fn from_f32(v: f32) -> f64 {
        v as f64
    }
}

/// Host-side literal: row-major f32 data plus dimensions. Fully functional.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Vec<f32>,
}

impl Literal {
    pub fn scalar(v: f32) -> Literal {
        Literal { dims: vec![], data: vec![v] }
    }

    pub fn vec1(v: &[f32]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: v.to_vec() }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count < 0 || count as usize != self.data.len() {
            return Err(Error::new(format!(
                "xla stub: cannot reshape {} elements into {:?}",
                self.data.len(),
                dims
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn to_vec<T: FromF32>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Destructure a tuple literal. Tuples only arise from execution
    /// results, which the stub cannot produce.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::new(OFFLINE_MSG))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_vec1_reshape_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let m = l.reshape(&[2, 3]).unwrap();
        assert_eq!(m.dims(), &[2, 3]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn literal_scalar() {
        let l = Literal::scalar(7.25);
        assert!(l.dims().is_empty());
        assert_eq!(l.to_vec::<f64>().unwrap(), vec![7.25]);
    }

    #[test]
    fn execution_paths_error_cleanly() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let missing = HloModuleProto::from_text_file("/nonexistent/x.hlo.txt");
        assert!(missing.is_err());
        let lit = Literal::scalar(1.0);
        assert!(lit.to_tuple().is_err());
    }
}
