//! Minimal offline stand-in for the `anyhow` crate (1.x API subset).
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides exactly the slice of anyhow the workspace uses: [`Error`],
//! [`Result`], the [`Context`] extension trait, [`Error::msg`], and the
//! `anyhow!` / `bail!` / `ensure!` macros. Error values carry a
//! human-readable context chain — `{}` prints the outermost message,
//! `{:#}` the full chain joined with `": "` (matching anyhow's alternate
//! formatting). No backtraces, no downcasting.

use std::fmt;

/// A string-chain error value. Deliberately does **not** implement
/// `std::error::Error` (same as real anyhow) so the blanket
/// `From<E: std::error::Error>` conversion below stays coherent.
pub struct Error {
    /// Outermost context first, root cause last. Never empty.
    chain: Vec<String>,
}

/// `anyhow::Result<T>` with the usual defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display + Send + Sync + 'static>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (root cause stays last).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Context extension for `Result`, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Attach a context message to the error, if any.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Attach a lazily-evaluated context message to the error, if any.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e = Error::from(io_err()).context("opening config");
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing file");
    }

    #[test]
    fn context_on_result() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: missing file");
        let r2: Result<()> = Err(anyhow!("inner"));
        let e2 = r2.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e2:#}"), "step 3: inner");
    }

    #[test]
    fn macros_work() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero");
        assert_eq!(format!("{}", f(-4).unwrap_err()), "negative input -4");
    }

    #[test]
    fn msg_from_string_like() {
        let e = Error::msg(String::from("owned"));
        assert_eq!(format!("{e}"), "owned");
        let mapped: Result<()> = Err("str error".to_string()).map_err(Error::msg);
        assert_eq!(format!("{}", mapped.unwrap_err()), "str error");
    }

    #[test]
    fn debug_shows_causes() {
        let e = Error::from(io_err()).context("mid").context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("top"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("missing file"));
    }
}
