//! Async factor-refresh pipeline (background decompositions, cost-aware
//! scheduling, adaptive rank).
//!
//! The paper's cost model (§4.2) makes the per-block eigendecomposition the
//! dominant K-FAC expense, and its Prop. 3.1 shows the EA K-factors have
//! rapidly decaying spectra — so the decomposition work is both *truncatable*
//! and, because it is only refreshed every `T_KI` steps, *amortizable*. The
//! seed trainer still blocked the step loop while `optim::kfac` recomputed
//! decompositions inline. This subsystem takes that work off the critical
//! path, "Brand New K-FACs"-style (Puiu, 2022b):
//!
//! * [`service::FactorPipeline`] — the refresh service. At each `T_KI`
//!   boundary the optimizer snapshots its EA factors into jobs; workers run
//!   the truncated decomposition through the shared `dyn`
//!   [`crate::rnla::Decomposition`] strategy (built-in or third-party)
//!   while the trainer keeps stepping. Snapshots are **copy-on-write**:
//!   jobs carry `Arc<Matrix>` clones of the EA factors and the trainer's
//!   update path goes through `Arc::make_mut`, so nothing is deep-copied
//!   unless a job is actually still holding the buffer the trainer wants
//!   to blend into. Worker panics are recovered by re-running the job
//!   inline on the trainer thread with its deterministic RNG.
//! * [`sched::JobQueue`] — the shared scheduler queue
//!   (`Mutex<BinaryHeap>` + `Condvar`). Under the default
//!   [`Schedule::FlopsStale`] discipline jobs are ordered by
//!   [`sched::priority_key`] — `DecompMeta::flops` of the chosen
//!   strategy/rank times the slot's current staleness — so the widest,
//!   stalest blocks decompose first and the bounded-staleness wait loop
//!   converges sooner; [`Schedule::Fifo`] preserves plain enqueue order.
//! * [`slot::FactorSlot`] — double-buffered, step-versioned publication
//!   points: the trainer always preconditions with the latest *published*
//!   inverse while the next one builds. The bounded-staleness contract is
//!   `published_version ≥ refresh_step − max_stale_steps`; the refresh call
//!   blocks only when the bound would be violated. `max_stale_steps = 0`
//!   degenerates to fully synchronous semantics and — because decomposition
//!   RNG streams are derived per (round, block, side), not drawn from a
//!   shared sequential generator — reproduces the inline path bit-for-bit.
//!   Each slot's pending entry remembers the rank its in-flight job was
//!   enqueued with, so a rank-controller change *supersedes* the job
//!   instead of waiting behind it.
//! * [`rank::RankController`] — per-layer adaptive sketch rank. Each
//!   published spectrum is compared against a target relative error ε: the
//!   rank shrinks toward the `modes_above(λ, ε)` count when the retained
//!   tail has decayed below `ε·λ_max`, grows geometrically when it has not,
//!   and is capped by the Prop. 3.1 mode bound `min(r_ε·n_M, d)`. This
//!   replaces the one-global-`r` schedule with a spectrum-driven per-block
//!   rank.
//!
//! Determinism: every decomposition's *value* is a pure function of
//! `(seed, round, block, side)` — never of which worker ran it or in which
//! order the scheduler picked it — and publication is version-monotone. At
//! `max_stale_steps = 0` training is therefore fully deterministic (and
//! bitwise equal to the inline path) under **both** queue disciplines.
//! With a nonzero staleness budget, *which* already-valid version is
//! installed at a refresh depends on worker wall-clock timing, so
//! stale-mode runs trade exact reproducibility for overlap — by design.
//!
//! The same purity makes the refresh *location-transparent*: the
//! [`transport`] submodule abstracts where jobs run behind a
//! [`transport::Transport`] trait — in-process workers (the default), a
//! remote factor server over TCP, or a shared-filesystem mailbox — with
//! the bitwise contract intact and inline fallback when the remote side
//! degrades.

pub mod rank;
pub mod sched;
pub mod service;
pub mod slot;
pub mod transport;

pub use rank::{next_rank, RankController};
pub use sched::{priority_key, JobQueue, Schedule};
pub use service::FactorPipeline;
pub use slot::FactorSlot;
pub use transport::{Transport, TransportKind};

/// Factor side index: the forward/activation factor Ā.
pub const SIDE_A: usize = 0;
/// Factor side index: the backward/gradient factor Γ̄.
pub const SIDE_G: usize = 1;

/// Configuration for the async factor-refresh pipeline (`[pipeline]` in the
/// experiment TOML).
#[derive(Clone, Debug, PartialEq)]
pub struct PipelineConfig {
    /// Route decompositions through the background service.
    pub enabled: bool,
    /// Worker threads (clamped to ≥ 1).
    pub workers: usize,
    /// Bounded-staleness budget: the published decomposition may lag the
    /// refresh step by at most this many steps. 0 = synchronous semantics.
    pub max_stale_steps: usize,
    /// Queue discipline for the worker pool: `"flops-stale"` (cost-aware
    /// priority — widest/stalest blocks first, the default) or `"fifo"`
    /// (plain enqueue order). Published *values* are identical under both;
    /// only latency/staleness profiles differ.
    pub schedule: Schedule,
    /// Per-layer spectrum-driven rank control instead of the global `r`
    /// schedule. (Zero-staleness bitwise equivalence with the inline path
    /// requires this off, since the inline path uses the schedule rank.)
    pub adaptive_rank: bool,
    /// Let the decomposition strategy tune its oversampling and
    /// power-iteration schedule from the controller's rank/error target
    /// ([`crate::rnla::Decomposition::tune`]). Only meaningful with
    /// `adaptive_rank`; off by default (schedule values are used).
    pub adaptive_sketch: bool,
    /// Target relative spectral error ε for the rank controller (paper §3
    /// uses ε = 0.03).
    pub target_rel_err: f64,
    /// Rank floor for the controller.
    pub min_rank: usize,
    /// Geometric growth factor when the retained spectrum has not decayed
    /// below ε·λ_max.
    pub growth: f64,
    /// Per-step factor rank n_M for the Prop. 3.1 cap `min(r_ε·n_M, d)`
    /// (≈ batch size). 0 disables the cap.
    pub prop31_batch: usize,
    /// Where refresh jobs run: `"local"` (in-process pool, the default),
    /// `"tcp"` (remote factor server), or `"dir"` (shared-filesystem
    /// mailbox).
    pub transport: TransportKind,
    /// Remote endpoint: `host:port` for `tcp`, a directory path for `dir`.
    /// Ignored (and validated empty-is-fine) for `local`.
    pub endpoint: String,
    /// TCP connect timeout per attempt, milliseconds.
    pub connect_timeout_ms: u64,
    /// Bound on any blocking receive/heartbeat wait, milliseconds. When it
    /// expires the pipeline falls back to inline decomposition.
    pub io_timeout_ms: u64,
    /// Connect attempts before a submit reports the server unreachable
    /// (exponential backoff between attempts, 50 ms doubling, ≤ 1 s).
    pub max_retries: u32,
    /// Online incremental decomposition updates ("Brand New K-FACs"): when
    /// enabled, refresh rounds hand update-capable strategies a
    /// [`crate::rnla::FactorDelta`] (the EA gram increment since the last
    /// refresh) instead of a full factor snapshot, and full decompositions
    /// become a rare periodic correction. `Off` (the default) preserves the
    /// recompute-from-scratch path bitwise.
    pub online: OnlineMode,
    /// With `online` active, force a full (from-scratch) decomposition
    /// every this many refresh rounds — the periodic correction that stops
    /// incremental truncation error accumulating. Round 0 is always a full
    /// decomposition (there is no basis to update yet). Clamped to ≥ 1.
    pub correction_every: usize,
}

/// Which strategies may take the online update path (`pipeline.online`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OnlineMode {
    /// Never update incrementally — every refresh recomputes from scratch
    /// (the bitwise-golden default).
    Off,
    /// Only the `rsvd` strategy updates incrementally (the configuration
    /// the error-envelope golden suite pins).
    Rsvd,
    /// Any strategy reporting [`crate::rnla::Decomposition::supports_update`]
    /// updates incrementally.
    Auto,
}

impl OnlineMode {
    /// Parse the `pipeline.online` config value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "off" => Some(OnlineMode::Off),
            "rsvd" => Some(OnlineMode::Rsvd),
            "auto" => Some(OnlineMode::Auto),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OnlineMode::Off => "off",
            OnlineMode::Rsvd => "rsvd",
            OnlineMode::Auto => "auto",
        }
    }

    /// Whether `strategy` may take the update path under this mode (the
    /// strategy must still report `supports_update`).
    pub fn allows(&self, strategy_key: &str) -> bool {
        match self {
            OnlineMode::Off => false,
            OnlineMode::Rsvd => strategy_key == "rsvd",
            OnlineMode::Auto => true,
        }
    }
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            enabled: false,
            workers: 2,
            max_stale_steps: 0,
            schedule: Schedule::FlopsStale,
            adaptive_rank: false,
            adaptive_sketch: false,
            target_rel_err: 0.03,
            min_rank: 8,
            growth: 1.5,
            prop31_batch: 0,
            transport: TransportKind::Local,
            endpoint: String::new(),
            connect_timeout_ms: 1000,
            io_timeout_ms: 5000,
            max_retries: 3,
            online: OnlineMode::Off,
            correction_every: 16,
        }
    }
}
