//! The background factor-refresh service: versioned slots + a pluggable
//! job transport.
//!
//! One [`FactorPipeline`] per K-FAC-family optimizer. At every `T_KI`
//! boundary the optimizer calls [`FactorPipeline::refresh`], which
//!
//! 1. drains finished decompositions from the transport and publishes
//!    them into the versioned [`FactorSlot`]s (monotone versions only),
//! 2. enqueues one decomposition job per (block, side) — a *zero-copy*
//!    `Arc` snapshot of the EA factor, not a clone — unless a job that can
//!    still satisfy the staleness bound is in flight *at the rank the
//!    controller currently wants* (a rank change supersedes the pending
//!    job; monotone publication discards the loser),
//! 3. blocks **only** while the bounded-staleness contract
//!    `published_version ≥ refresh_step − max_stale_steps` is violated, and
//! 4. installs the published factors into the optimizer's blocks.
//!
//! Where the jobs run is the [`Transport`]'s business
//! (see [`crate::pipeline::transport`]): the default
//! [`crate::pipeline::transport::LocalTransport`] is the original
//! in-process pool — workers draw jobs from a shared
//! [`crate::pipeline::JobQueue`], under the default
//! [`Schedule::FlopsStale`] discipline ordered by [`priority_key`]
//! (`DecompMeta::flops` × slot staleness) — while `Tcp`/`Dir` ship the
//! same jobs to a shared factor server. A queued job whose version has
//! fallen below the current staleness floor is dropped at pop time
//! wherever the queue lives. Workers never touch optimizer state: all
//! publication happens on the trainer thread inside `refresh`, which is
//! what makes the double buffer race-free without per-slot locking.
//!
//! Snapshots are copy-on-write: jobs hold `Arc<Matrix>` clones of
//! `BlockState::{a_bar, g_bar}`, and the trainer's EA update path goes
//! through `Arc::make_mut` — an in-flight job keeps its snapshot while the
//! trainer keeps blending, and nothing is deep-copied unless both actually
//! overlap.
//!
//! Failure handling: the pipeline retains every in-flight [`JobSpec`], so
//! *any* lost job — a decomposition panic on a worker, a dead worker pool,
//! a transport submit failure, a recv timeout, a dropped connection — is
//! re-run *inline* on the trainer thread with its pristine deterministic
//! RNG (bitwise the result the worker would have produced), counted in
//! `recovered_jobs`. Only a job that fails on a worker *and* on the inline
//! retry aborts training. A degraded remote transport therefore slows the
//! run down but never diverges it.
//!
//! Determinism: each job carries its own RNG, derived from
//! `(seed, round, block, side)` by [`crate::optim::kfac::decomp_rng`] — the
//! same derivation the inline path uses — so results are independent of
//! which worker runs a job (local or remote), in which order the scheduler
//! picks jobs, and in which order results arrive.

use std::sync::Arc;

use crate::obs::{self, clock};
use crate::optim::kfac::{decomp_rng, BlockState};
use crate::pipeline::rank::RankController;
use crate::pipeline::sched::{priority_key, Schedule};
use crate::pipeline::slot::{FactorSlot, Pending};
use crate::pipeline::transport::{
    build_transport, run_spec, JobResult, JobSpec, Transport, UpdateJob,
};
use crate::pipeline::{PipelineConfig, SIDE_A, SIDE_G};
use crate::rnla::{Decomposition, DeltaBuffer, SketchConfig};

/// Background factor-refresh service with double-buffered slots, cost-aware
/// priority scheduling, and per-layer adaptive rank control. See the module
/// docs for the contract.
pub struct FactorPipeline {
    cfg: PipelineConfig,
    /// Slot `2·block + side` holds that factor's published decomposition.
    slots: Vec<FactorSlot>,
    /// Factor dimension per slot (for `DecompMeta` cost estimates).
    slot_dims: Vec<usize>,
    /// Version last installed into the optimizer's blocks, per slot —
    /// lets refresh skip re-cloning factors that haven't changed.
    installed: Vec<Option<u64>>,
    controllers: Vec<RankController>,
    transport: Box<dyn Transport>,
    /// The most recent spec submitted per slot. This is the degradation
    /// contract's anchor: whatever happens to the transport, the spec (an
    /// `Arc` snapshot + pristine RNG) can always be re-run inline.
    inflight: Vec<Option<JobSpec>>,
    /// Current staleness floor (`version − max_stale_steps`); mirrored to
    /// the transport so workers drop jobs that are too old to install.
    floor: u64,
    worker_seconds: f64,
    queue_wait_seconds: f64,
    jobs_completed: usize,
    recovered_jobs: usize,
    superseded_jobs: usize,
    /// Jobs enqueued as incremental basis updates instead of full
    /// decompositions (`[pipeline] online` modes).
    update_jobs: usize,
    /// Warn-once latch for a transport that cannot carry delta frames
    /// (old server banner, dir mailbox): online refreshes silently
    /// degrade to full-snapshot jobs after the first warning.
    delta_unsupported_warned: bool,
    max_queue_depth: usize,
    rounds: usize,
}

impl FactorPipeline {
    /// Build the pipeline for blocks of the given `(d_A, d_G)` dims, with
    /// the transport selected by `cfg` (an in-process worker pool by
    /// default).
    ///
    /// `init_rank` seeds every rank controller (typically the schedule's
    /// epoch-0 rank); `rho` is the EA decay used by the Prop. 3.1 cap.
    pub fn new(
        cfg: PipelineConfig,
        dims: &[(usize, usize)],
        init_rank: usize,
        rho: f64,
    ) -> FactorPipeline {
        let transport = build_transport(&cfg);
        Self::with_transport(cfg, dims, init_rank, rho, transport)
    }

    /// Like [`FactorPipeline::new`] with an explicit transport — the
    /// injection point for the golden suite (and anyone embedding the
    /// pipeline against a custom job channel).
    pub fn with_transport(
        cfg: PipelineConfig,
        dims: &[(usize, usize)],
        init_rank: usize,
        rho: f64,
        transport: Box<dyn Transport>,
    ) -> FactorPipeline {
        let mut slots = Vec::with_capacity(dims.len() * 2);
        let mut slot_dims = Vec::with_capacity(dims.len() * 2);
        let mut controllers = Vec::with_capacity(dims.len() * 2);
        for &(da, dg) in dims {
            for dim in [da, dg] {
                slots.push(FactorSlot::seed(dim));
                slot_dims.push(dim);
                controllers.push(RankController::new(
                    init_rank,
                    dim,
                    cfg.target_rel_err,
                    cfg.min_rank,
                    cfg.growth,
                    rho,
                    cfg.prop31_batch,
                ));
            }
        }
        let installed = vec![None; slots.len()];
        let inflight = vec![None; slots.len()];
        FactorPipeline {
            cfg,
            slots,
            slot_dims,
            installed,
            controllers,
            transport,
            inflight,
            floor: 0,
            worker_seconds: 0.0,
            queue_wait_seconds: 0.0,
            jobs_completed: 0,
            recovered_jobs: 0,
            superseded_jobs: 0,
            update_jobs: 0,
            delta_unsupported_warned: false,
            max_queue_depth: 0,
            rounds: 0,
        }
    }

    /// Whether delta jobs can reach the workers. Checked only when an
    /// online round actually wants to ship one; on the first `false` the
    /// degradation is logged once (warning + counter) and the refresh
    /// falls back to full-snapshot jobs — no retry storm, no divergence.
    fn delta_capable(&mut self) -> bool {
        if self.transport.supports_delta() {
            return true;
        }
        if !self.delta_unsupported_warned {
            self.delta_unsupported_warned = true;
            obs::counter_add("pipeline.delta_unsupported", 1);
            eprintln!(
                "factor pipeline: transport '{}' cannot carry incremental updates \
                 (legacy server or mailbox endpoint); online refresh falls back to \
                 full decompositions",
                self.transport.kind()
            );
        }
        false
    }

    fn publish(&mut self, res: JobResult) {
        self.worker_seconds += res.run_s;
        self.queue_wait_seconds += res.wait_s;
        let factor = match res.outcome {
            Ok(f) => {
                self.jobs_completed += 1;
                obs::observe("pipeline.job.wait_s", res.wait_s);
                obs::observe("pipeline.job.run_s", res.run_s);
                f
            }
            Err(msg) => {
                // Don't resurrect a job that can no longer be installed:
                // below the staleness floor its result would be discarded
                // and its slot already carries a newer job — the same rule
                // the workers apply at pop time. Retrying it could even
                // abort training on a deterministic panic over a snapshot
                // nobody needs anymore.
                if res.version < self.floor {
                    return;
                }
                // A failure anywhere — worker panic, dead pool, transport
                // down — routes here. Re-run the *retained* spec inline on
                // this (trainer) thread with its pristine per-(round,
                // block, side) RNG: bitwise the result the worker would
                // have produced. Only give up if the retry fails too.
                let si = 2 * res.block + res.side;
                let spec = match self.inflight[si].as_ref() {
                    // The retained spec must belong to this result; a
                    // mismatch means the job was superseded and its
                    // replacement is in flight — nothing to recover.
                    Some(spec) if spec.version == res.version => spec.clone(),
                    _ => return,
                };
                let sw = clock::Stopwatch::start();
                let retried = {
                    let _sp = obs::span("pipeline.job.retry")
                        .arg("block", res.block)
                        .arg("side", res.side)
                        .with_backend();
                    run_spec(&spec)
                };
                self.worker_seconds += sw.elapsed_s();
                match retried {
                    Ok(f) => {
                        self.recovered_jobs += 1;
                        self.jobs_completed += 1;
                        f
                    }
                    Err(retry_msg) => panic!(
                        "factor pipeline job for block {} side {} (version {}) failed on the \
                         worker ({}) and again on the inline retry ({retry_msg})",
                        res.block, res.side, res.version, msg
                    ),
                }
            }
        };
        let si = 2 * res.block + res.side;
        let slot = &mut self.slots[si];
        if slot.pending.is_some_and(|p| p.version == res.version) {
            slot.pending = None;
            self.inflight[si] = None;
        }
        // Monotone publication first: a stale result that loses to an
        // already-published newer version must not perturb the rank
        // controller either.
        if slot.publish(res.version, factor) && self.cfg.adaptive_rank {
            // Only the *newest* enqueued job's result may feed the
            // controller: a pending entry surviving the clear above means
            // this result belongs to a replaced job (superseded by a rank
            // change, or re-enqueued past the staleness bound). Publishing
            // it keeps the staleness contract honest, but observing its
            // outdated, possibly differently-truncated spectrum would
            // re-grow the rank the controller just corrected — and the two
            // would oscillate.
            if self.slots[si].pending.is_none() {
                let spectrum = self.slots[si].factor().d.clone();
                self.controllers[si].observe(&spectrum);
            }
        }
    }

    /// One refresh round at optimizer step `version` (see module docs).
    /// `round` is the optimizer's decomposition-round counter — it seeds
    /// the per-job RNG streams exactly like the inline path.
    pub fn refresh(
        &mut self,
        blocks: &mut [BlockState],
        strategy: &Arc<dyn Decomposition>,
        base: &SketchConfig,
        seed: u64,
        round: usize,
        version: u64,
    ) {
        self.refresh_with_deltas(blocks, strategy, base, seed, round, version, None);
    }

    /// [`FactorPipeline::refresh`] with the optimizer's accumulated EA
    /// deltas. When `[pipeline] online` allows the strategy and this is
    /// not a periodic correction round (`round % correction_every == 0`,
    /// which includes round 0), an eligible slot ships an *update* job —
    /// previous published basis + composed delta columns — instead of the
    /// dense snapshot. Eligibility is conservative: the slot must have a
    /// published non-empty basis and no job in flight, so an update is
    /// always rotated out of the exact basis its delta was accumulated
    /// against; anything else (warm-up, superseded jobs, staleness
    /// backlog) gets a full job and its pending delta is discarded — the
    /// fresh snapshot already contains everything the delta described.
    #[allow(clippy::too_many_arguments)]
    pub fn refresh_with_deltas(
        &mut self,
        blocks: &mut [BlockState],
        strategy: &Arc<dyn Decomposition>,
        base: &SketchConfig,
        seed: u64,
        round: usize,
        version: u64,
        mut deltas: Option<&mut DeltaBuffer>,
    ) {
        assert_eq!(blocks.len() * 2, self.slots.len(), "pipeline: block count mismatch");
        // Online eligibility for this round, decided once: the transport
        // handshake is consulted only when a delta could actually ship.
        let correction = round % self.cfg.correction_every.max(1) == 0;
        let want_online = deltas.is_some()
            && !correction
            && self.cfg.online.allows(strategy.key())
            && strategy.supports_update();
        let online_ok = want_online && self.delta_capable();
        let required = version.saturating_sub(self.cfg.max_stale_steps as u64);
        // Publish the new floor *before* draining results, so workers stop
        // wasting time on queued jobs that can no longer be installed and
        // the inline-retry guard in `publish` judges failed jobs against
        // this round's bound, not the previous one's.
        self.floor = required;
        self.transport.set_floor(required);
        // 1. Drain whatever the workers finished since the last round. A
        //    transport error here is not fatal — in-flight work is either
        //    redelivered later or recovered inline in the wait loop below.
        loop {
            match self.transport.try_recv() {
                Ok(Some(res)) => self.publish(res),
                Ok(None) => break,
                Err(_) => break,
            }
        }
        // 2. Enqueue fresh snapshots.
        for (bi, block) in blocks.iter().enumerate() {
            for side in [SIDE_A, SIDE_G] {
                let si = 2 * bi + side;
                // Controller feedback: with `adaptive_sketch` on, the
                // strategy picks its own oversampling/power-iteration
                // schedule for the controller's rank and error target
                // (Decomposition::tune); otherwise only the rank adapts.
                let cfg = if self.cfg.adaptive_rank {
                    let ctl = &self.controllers[si];
                    if self.cfg.adaptive_sketch {
                        strategy.tune(base, ctl.rank, ctl.target)
                    } else {
                        SketchConfig::new(ctl.rank, base.oversample, base.n_power_iter)
                    }
                } else {
                    SketchConfig::new(base.rank, base.oversample, base.n_power_iter)
                };
                // Skip the slot only when the in-flight job both satisfies
                // the staleness bound *and* was enqueued at the rank the
                // controller wants now. A rank change used to be silently
                // ignored for the whole round — adapted ranks lagged an
                // extra T_KI — so instead the pending job is superseded:
                // the replacement enqueues at the new rank, and monotone
                // publication discards whichever result loses.
                if let Some(p) = self.slots[si].pending {
                    if p.version >= required {
                        if p.rank == cfg.rank {
                            continue;
                        }
                        self.superseded_jobs += 1;
                    }
                }
                let matrix = if side == SIDE_A {
                    Arc::clone(&block.a_bar)
                } else {
                    Arc::clone(&block.g_bar)
                };
                // Update jobs only rotate a basis the delta was accumulated
                // against: published, non-empty, nothing in flight. The job
                // still carries the matrix snapshot, so a declined update
                // (or an inline retry) recovers deterministically.
                let eligible = online_ok
                    && self.slots[si].pending.is_none()
                    && self.slots[si].version().is_some()
                    && self.slots[si].factor().rank() > 0;
                let update = if eligible {
                    deltas.as_deref_mut().and_then(|buf| buf.take(bi, side)).map(|delta| {
                        UpdateJob {
                            prev: Arc::new(self.slots[si].factor().clone()),
                            delta: Arc::new(delta),
                        }
                    })
                } else {
                    // This slot gets a full job; the snapshot subsumes any
                    // accumulated delta, so drop it — otherwise it would
                    // wrongly compose into the *next* basis.
                    if let Some(buf) = deltas.as_deref_mut() {
                        buf.take(bi, side);
                    }
                    None
                };
                let flops_pred = match &update {
                    Some(up) => strategy
                        .update_meta(self.slot_dims[si], up.delta.n_cols(), &cfg)
                        .map(|m| m.flops)
                        .unwrap_or_else(|| strategy.meta(self.slot_dims[si], &cfg).flops),
                    None => strategy.meta(self.slot_dims[si], &cfg).flops,
                };
                if update.is_some() {
                    self.update_jobs += 1;
                    obs::counter_add("pipeline.jobs.update", 1);
                } else {
                    obs::counter_add("pipeline.jobs.full", 1);
                }
                let prio = match self.cfg.schedule {
                    Schedule::Fifo => 0.0,
                    Schedule::FlopsStale => {
                        // Never-published (warming) slots are maximally
                        // stale: rank them ahead of every published slot of
                        // the same cost.
                        let stale = self.slots[si]
                            .staleness(version)
                            .unwrap_or(version.saturating_add(1));
                        priority_key(flops_pred, stale)
                    }
                };
                let rank = cfg.rank;
                let spec = JobSpec {
                    block: bi,
                    side,
                    version,
                    strategy: Arc::clone(strategy),
                    cfg,
                    matrix,
                    rng: decomp_rng(seed, round, bi, side),
                    enqueued_ns: clock::now_ns(),
                    flops_pred,
                    span: obs::current_ctx(),
                    update,
                };
                // Record the job *before* submitting: if the submit fails,
                // the synthesized Err below routes through publish()'s
                // retry machinery, which needs the retained spec.
                self.slots[si].pending = Some(Pending { version, rank });
                self.inflight[si] = Some(spec.clone());
                if let Err(e) = self.transport.submit(&spec, prio) {
                    self.publish(JobResult {
                        block: bi,
                        side,
                        version,
                        wait_s: 0.0,
                        run_s: 0.0,
                        outcome: Err(format!("transport submit failed: {e}")),
                    });
                }
            }
        }
        self.max_queue_depth = self.max_queue_depth.max(self.transport.queue_depth());
        // 3. Bounded-staleness wait: block only while the contract is
        //    violated. With max_stale_steps = 0 this waits for the full
        //    round — synchronous semantics. A transport failure (dead
        //    worker pool, server down, timeout, corrupt stream) degrades
        //    to inline execution of the retained specs — slower, never
        //    divergent.
        while self.slots.iter().any(|s| !s.satisfies(required)) {
            match self.transport.recv() {
                Ok(res) => self.publish(res),
                Err(e) => {
                    let msg = format!("transport degraded: {e}");
                    let now = clock::now_ns();
                    let unsatisfied: Vec<usize> = (0..self.slots.len())
                        .filter(|&si| !self.slots[si].satisfies(required))
                        .collect();
                    for si in unsatisfied {
                        // Invariant: every unsatisfied slot was (re-)en-
                        // queued this round or a recent one, so a retained
                        // spec with version ≥ required exists.
                        let spec = self.inflight[si]
                            .as_ref()
                            .expect("unsatisfied slot must have an in-flight spec")
                            .clone();
                        self.publish(JobResult {
                            block: spec.block,
                            side: spec.side,
                            version: spec.version,
                            wait_s: clock::secs_between(spec.enqueued_ns, now),
                            run_s: 0.0,
                            outcome: Err(msg.clone()),
                        });
                    }
                }
            }
        }
        // 4. Install the published (front-buffer) factors — only where the
        //    published version moved since the last install, so unchanged
        //    (still-valid stale) factors are not re-cloned every round.
        for (bi, block) in blocks.iter_mut().enumerate() {
            let sa = 2 * bi + SIDE_A;
            if self.installed[sa] != self.slots[sa].version() {
                block.a_dec = self.slots[sa].factor().clone();
                self.installed[sa] = self.slots[sa].version();
            }
            let sg = 2 * bi + SIDE_G;
            if self.installed[sg] != self.slots[sg].version() {
                block.g_dec = self.slots[sg].factor().clone();
                self.installed[sg] = self.slots[sg].version();
            }
        }
        self.rounds += 1;
    }

    /// Serialize the pipeline's resumable state: per-slot published
    /// versions + rank-controller positions, plus the cumulative counters
    /// the per-round telemetry rows report. The published *factors* are not
    /// written — they are identical to the optimizer's installed
    /// decompositions at a checkpoint boundary, and
    /// [`FactorPipeline::load_state`] rebuilds the slots from those.
    pub(crate) fn save_state(&self, w: &mut crate::util::codec::ByteWriter) {
        w.tag(b"PIP2");
        w.u64(self.slots.len() as u64);
        for (slot, ctl) in self.slots.iter().zip(self.controllers.iter()) {
            match slot.version() {
                Some(v) => {
                    w.u8(1);
                    w.u64(v);
                }
                None => {
                    w.u8(0);
                    w.u64(0);
                }
            }
            w.u64(ctl.rank as u64);
            w.u64(ctl.observations as u64);
        }
        w.u64(self.jobs_completed as u64);
        w.u64(self.recovered_jobs as u64);
        w.u64(self.superseded_jobs as u64);
        w.u64(self.rounds as u64);
        w.u64(self.max_queue_depth as u64);
        w.f64(self.worker_seconds);
        w.f64(self.queue_wait_seconds);
        w.u64(self.update_jobs as u64);
    }

    /// Restore [`FactorPipeline::save_state`] output into a freshly-spawned
    /// pipeline. `blocks` must already hold the checkpointed decompositions
    /// (the optimizer restores them first): each slot's front buffer is
    /// re-published from its block's installed factor at the checkpointed
    /// version, so a post-resume refresh sees exactly the staleness picture
    /// the uninterrupted run would — at `max_stale_steps = 0` the next
    /// round re-enqueues and waits exactly like the original.
    pub(crate) fn load_state(
        &mut self,
        r: &mut crate::util::codec::ByteReader<'_>,
        blocks: &[BlockState],
    ) -> Result<(), String> {
        r.tag(b"PIP2")?;
        let n = r.u64()? as usize;
        if n != self.slots.len() {
            return Err(format!(
                "checkpoint pipeline has {n} slots, this run has {} (model/block mismatch)",
                self.slots.len()
            ));
        }
        if blocks.len() * 2 != n {
            return Err(format!(
                "pipeline restore: {} blocks do not match {n} slots",
                blocks.len()
            ));
        }
        for si in 0..n {
            let has_version = r.u8()? != 0;
            let raw_version = r.u64()?;
            let rank = r.u64()? as usize;
            let observations = r.u64()? as usize;
            let version = if has_version { Some(raw_version) } else { None };
            let bi = si / 2;
            let factor = if si % 2 == SIDE_A {
                blocks[bi].a_dec.clone()
            } else {
                blocks[bi].g_dec.clone()
            };
            self.slots[si].restore(version, factor);
            self.installed[si] = version;
            let ctl = &mut self.controllers[si];
            ctl.rank = rank.clamp(ctl.min_rank, ctl.max_rank);
            ctl.observations = observations;
        }
        self.jobs_completed = r.u64()? as usize;
        self.recovered_jobs = r.u64()? as usize;
        self.superseded_jobs = r.u64()? as usize;
        self.rounds = r.u64()? as usize;
        self.max_queue_depth = r.u64()? as usize;
        self.worker_seconds = r.f64()?;
        self.queue_wait_seconds = r.f64()?;
        self.update_jobs = r.u64()? as usize;
        Ok(())
    }

    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// Published step-version per slot (order: block-major, A then G).
    pub fn published_versions(&self) -> Vec<Option<u64>> {
        self.slots.iter().map(FactorSlot::version).collect()
    }

    /// Current controller rank per slot (order: block-major, A then G).
    pub fn ranks(&self) -> Vec<usize> {
        self.controllers.iter().map(|c| c.rank).collect()
    }

    /// Worst staleness across *published* slots at step `now`.
    /// Never-published slots are excluded — they are reported by
    /// [`FactorPipeline::warming`] instead — so a single cold slot no
    /// longer hides the fleet's worst case mid-warmup. `None` only before
    /// any slot has published.
    pub fn max_staleness(&self, now: u64) -> Option<u64> {
        self.slots.iter().filter_map(|s| s.staleness(now)).max()
    }

    /// Slots that have never published a decomposition (mid-warmup).
    pub fn warming(&self) -> usize {
        self.slots.iter().filter(|s| s.version().is_none()).count()
    }

    /// Total seconds spent inside decompositions — worker threads plus any
    /// trainer-thread inline recoveries (overlapped with training when
    /// `max_stale_steps > 0` and nothing failed).
    pub fn worker_seconds(&self) -> f64 {
        self.worker_seconds
    }

    /// Total seconds jobs spent sitting in the queue before a worker popped
    /// them (enqueue → pop). Disjoint from [`FactorPipeline::worker_seconds`]
    /// — the two used to be conflated into one number.
    pub fn queue_wait_seconds(&self) -> f64 {
        self.queue_wait_seconds
    }

    pub fn jobs_completed(&self) -> usize {
        self.jobs_completed
    }

    /// Jobs that failed on a worker (or were stranded by a dead worker
    /// pool or a degraded transport) and completed via the trainer-thread
    /// inline retry.
    pub fn recovered_jobs(&self) -> usize {
        self.recovered_jobs
    }

    /// In-flight jobs replaced by a newer enqueue after the rank controller
    /// changed its mind before they published.
    pub fn superseded_jobs(&self) -> usize {
        self.superseded_jobs
    }

    /// Jobs enqueued as incremental basis updates rather than full
    /// decompositions (`[pipeline] online` modes). The complement
    /// `jobs_completed − update_jobs` is roughly the full-decomposition
    /// count the online mode is there to shrink.
    pub fn update_jobs(&self) -> usize {
        self.update_jobs
    }

    /// Jobs currently waiting in the scheduler queue, where knowable
    /// (in-flight jobs a worker already popped are not counted; remote
    /// transports report 0 — the queue lives on the server).
    pub fn queue_depth(&self) -> usize {
        self.transport.queue_depth()
    }

    /// High-water mark of the queue depth, sampled after each enqueue round.
    pub fn max_queue_depth(&self) -> usize {
        self.max_queue_depth
    }

    pub fn rounds(&self) -> usize {
        self.rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gemm, qr, Matrix, Pcg64};
    use crate::rnla::{decomposition, DecompMeta, LowRankFactor};
    use std::sync::atomic::{AtomicBool, Ordering};

    fn decayed_psd(rng: &mut Pcg64, d: usize, decay: f64) -> Matrix {
        let q = qr::orthonormalize(&rng.gaussian_matrix(d, d));
        let lam: Vec<f64> = (0..d).map(|i| decay.powi(i as i32)).collect();
        let mut qd = q.clone();
        gemm::scale_cols(&mut qd, &lam);
        gemm::matmul_nt(&qd, &q)
    }

    fn block(rng: &mut Pcg64, da: usize, dg: usize) -> BlockState {
        BlockState {
            a_bar: Arc::new(decayed_psd(rng, da, 0.7)),
            g_bar: Arc::new(decayed_psd(rng, dg, 0.6)),
            a_dec: LowRankFactor::new(Matrix::eye(da), vec![1.0; da]),
            g_dec: LowRankFactor::new(Matrix::eye(dg), vec![1.0; dg]),
            factored: None,
        }
    }

    fn sync_cfg() -> PipelineConfig {
        PipelineConfig { enabled: true, workers: 2, max_stale_steps: 0, ..Default::default() }
    }

    fn two_blocks() -> Vec<BlockState> {
        let mut rng = Pcg64::new(1);
        vec![block(&mut rng, 12, 10), block(&mut rng, 10, 8)]
    }

    #[test]
    fn zero_staleness_bitwise_matches_inline() {
        let blocks = two_blocks();
        let base = SketchConfig::new(6, 4, 2);
        let seed = 42u64;
        let strat: Arc<dyn Decomposition> = Arc::new(decomposition::Rsvd);
        // Inline reference with the shared per-(round, block, side) streams.
        let mut expected = Vec::new();
        for (bi, b) in blocks.iter().enumerate() {
            let mut ra = decomp_rng(seed, 0, bi, SIDE_A);
            let mut rg = decomp_rng(seed, 0, bi, SIDE_G);
            expected.push((
                strat.decompose(&b.a_bar, &base, &mut ra),
                strat.decompose(&b.g_bar, &base, &mut rg),
            ));
        }
        // The golden must hold under both queue disciplines: scheduling
        // order never leaks into values.
        for schedule in [Schedule::Fifo, Schedule::FlopsStale] {
            let cfg = PipelineConfig { schedule, ..sync_cfg() };
            let mut blocks_run = two_blocks();
            let mut p = FactorPipeline::new(cfg, &[(12, 10), (10, 8)], 6, 0.95);
            p.refresh(&mut blocks_run, &strat, &base, seed, 0, 0);
            for (b, (ea, eg)) in blocks_run.iter().zip(expected.iter()) {
                assert_eq!(b.a_dec.u.as_slice(), ea.u.as_slice(), "{schedule:?}");
                assert_eq!(b.a_dec.d, ea.d, "{schedule:?}");
                assert_eq!(b.g_dec.u.as_slice(), eg.u.as_slice(), "{schedule:?}");
                assert_eq!(b.g_dec.d, eg.d, "{schedule:?}");
            }
            assert_eq!(p.jobs_completed(), 4);
            assert_eq!(p.recovered_jobs(), 0);
            assert_eq!(p.rounds(), 1);
            assert!(p.worker_seconds() > 0.0);
            assert!(p.queue_wait_seconds() >= 0.0);
            // Workers may drain the queue before the depth sample, so only
            // the invariant bounds hold.
            assert!(p.max_queue_depth() <= 4);
            assert_eq!(p.queue_depth(), 0, "nothing queued after a synchronous round");
        }
    }

    #[test]
    fn staleness_bound_holds_across_rounds() {
        let mut rng = Pcg64::new(2);
        let mut blocks = vec![block(&mut rng, 10, 10)];
        let base = SketchConfig::new(5, 3, 1);
        let cfg = PipelineConfig {
            enabled: true,
            workers: 1,
            max_stale_steps: 3,
            ..Default::default()
        };
        let strat: Arc<dyn Decomposition> = Arc::new(decomposition::Srevd);
        let mut p = FactorPipeline::new(cfg, &[(10, 10)], 5, 0.95);
        let mut last: Vec<Option<u64>> = vec![None, None];
        for (round, version) in [(0u64, 0u64), (1, 5), (2, 10), (3, 15)] {
            p.refresh(&mut blocks, &strat, &base, 7, round as usize, version);
            let required = version.saturating_sub(3);
            for (vi, v) in p.published_versions().into_iter().enumerate() {
                let v = v.expect("slot published after refresh");
                assert!(v >= required, "slot {vi}: version {v} < required {required}");
                if let Some(prev) = last[vi] {
                    assert!(v >= prev, "published versions must be monotone");
                }
                last[vi] = Some(v);
            }
            assert!(p.max_staleness(version).unwrap() <= 3 + 5, "lag bounded by stale + T_KI");
            assert_eq!(p.warming(), 0, "everything published after the first round");
        }
    }

    #[test]
    fn adaptive_rank_shrinks_on_decayed_spectrum() {
        let mut rng = Pcg64::new(3);
        let mut blocks = vec![block(&mut rng, 24, 24)];
        let base = SketchConfig::new(24, 4, 2);
        let cfg = PipelineConfig {
            enabled: true,
            workers: 2,
            max_stale_steps: 0,
            adaptive_rank: true,
            target_rel_err: 0.05,
            min_rank: 2,
            ..Default::default()
        };
        let strat: Arc<dyn Decomposition> = Arc::new(decomposition::Rsvd);
        let mut p = FactorPipeline::new(cfg, &[(24, 24)], 24, 0.95);
        for round in 0..6u64 {
            p.refresh(&mut blocks, &strat, &base, 11, round as usize, round);
        }
        // decay 0.7 / 0.6 with ε = 0.05 → far fewer than 24 modes needed.
        for &r in p.ranks().iter() {
            assert!(r < 24, "controller should shrink, got {r}");
            assert!(r >= 2);
        }
        // The installed decompositions reflect the adapted (smaller) ranks.
        assert!(blocks[0].a_dec.rank() < 24);
    }

    #[test]
    fn shutdown_joins_workers() {
        let p = FactorPipeline::new(sync_cfg(), &[(6, 6)], 4, 0.95);
        drop(p); // must not hang or panic (transport drop joins the pool)
    }

    /// `adaptive_sketch`: the strategy's `tune` hook picks the sketch
    /// parameters; the refresh loop still converges and installs factors
    /// at the controller's adapted ranks.
    #[test]
    fn adaptive_sketch_routes_through_strategy_tune() {
        let mut rng = Pcg64::new(7);
        let mut blocks = vec![block(&mut rng, 24, 24)];
        let base = SketchConfig::new(24, 4, 4);
        let cfg = PipelineConfig {
            enabled: true,
            workers: 2,
            max_stale_steps: 0,
            adaptive_rank: true,
            adaptive_sketch: true,
            target_rel_err: 0.05,
            min_rank: 2,
            ..Default::default()
        };
        let strat: Arc<dyn Decomposition> = Arc::new(decomposition::Rsvd);
        let mut p = FactorPipeline::new(cfg, &[(24, 24)], 24, 0.95);
        for round in 0..6u64 {
            p.refresh(&mut blocks, &strat, &base, 13, round as usize, round);
        }
        // Controller still shrinks on the decayed spectrum, and the
        // installed factors reflect its ranks.
        for &r in p.ranks().iter() {
            assert!((2..24).contains(&r), "rank {r}");
        }
        assert!(blocks[0].a_dec.rank() < 24);
        assert!(blocks[0].a_dec.u.all_finite());
        assert!(blocks[0].g_dec.u.all_finite());
    }

    /// Regression: `max_staleness` used to collapse to `None` whenever any
    /// slot was unpublished, hiding worst-case staleness mid-warmup. The
    /// published slots must report; the cold ones show up in `warming()`.
    #[test]
    fn max_staleness_reports_published_slots_mid_warmup() {
        let mut p = FactorPipeline::new(sync_cfg(), &[(6, 6), (5, 5)], 4, 0.95);
        assert_eq!(p.max_staleness(3), None, "nothing published yet");
        assert_eq!(p.warming(), 4);
        p.slots[0].publish(3, LowRankFactor::new(Matrix::eye(6), vec![1.0; 6]));
        assert_eq!(p.max_staleness(5), Some(2), "published slot must report its lag");
        assert_eq!(p.warming(), 3);
        p.slots[2].publish(1, LowRankFactor::new(Matrix::eye(5), vec![1.0; 5]));
        assert_eq!(p.max_staleness(5), Some(4), "worst case over published slots");
        assert_eq!(p.warming(), 2);
    }

    /// Checkpoint round-trip: a restored pipeline reproduces the donor's
    /// slot versions, controller ranks, and cumulative counters, so a
    /// resumed run's telemetry continues the interrupted run's.
    #[test]
    fn state_roundtrip_restores_slots_and_counters() {
        use crate::util::codec::{ByteReader, ByteWriter};
        let mut blocks = two_blocks();
        let base = SketchConfig::new(6, 4, 2);
        let strat: Arc<dyn Decomposition> = Arc::new(decomposition::Rsvd);
        let mut p = FactorPipeline::new(sync_cfg(), &[(12, 10), (10, 8)], 6, 0.95);
        p.refresh(&mut blocks, &strat, &base, 42, 0, 0);
        p.refresh(&mut blocks, &strat, &base, 42, 1, 5);
        let mut w = ByteWriter::new();
        p.save_state(&mut w);
        let bytes = w.into_bytes();
        let mut q = FactorPipeline::new(sync_cfg(), &[(12, 10), (10, 8)], 6, 0.95);
        let mut r = ByteReader::new(&bytes);
        q.load_state(&mut r, &blocks).unwrap();
        r.finish().unwrap();
        assert_eq!(q.published_versions(), p.published_versions());
        assert_eq!(q.ranks(), p.ranks());
        assert_eq!(q.jobs_completed(), p.jobs_completed());
        assert_eq!(q.rounds(), p.rounds());
        assert_eq!(q.warming(), 0, "restored slots are published, not warming");
        // The restored front buffers are the blocks' installed factors.
        for (bi, b) in blocks.iter().enumerate() {
            assert_eq!(q.slots[2 * bi + SIDE_A].factor().d, b.a_dec.d);
            assert_eq!(q.slots[2 * bi + SIDE_G].factor().d, b.g_dec.d);
        }
        // A slot-count mismatch is rejected loudly.
        let mut small = FactorPipeline::new(sync_cfg(), &[(12, 10)], 6, 0.95);
        let mut r = ByteReader::new(&bytes);
        assert!(small.load_state(&mut r, &blocks[..1]).is_err());
    }

    /// Online refresh rounds ship update jobs for published slots, consume
    /// the delta buffer, and fall back to full jobs on correction rounds.
    #[test]
    fn online_rounds_ship_update_jobs_and_corrections_full() {
        use crate::pipeline::OnlineMode;
        use crate::rnla::FactorDelta;
        let mut blocks = two_blocks();
        let base = SketchConfig::new(6, 4, 2);
        let strat: Arc<dyn Decomposition> = Arc::new(decomposition::Rsvd);
        let cfg = PipelineConfig {
            online: OnlineMode::Rsvd,
            correction_every: 4,
            ..sync_cfg()
        };
        let mut p = FactorPipeline::new(cfg, &[(12, 10), (10, 8)], 6, 0.95);
        let mut deltas = DeltaBuffer::new(2);
        // Round 0 is a correction round (0 % 4 == 0): everything full.
        p.refresh_with_deltas(&mut blocks, &strat, &base, 42, 0, 0, Some(&mut deltas));
        assert_eq!(p.update_jobs(), 0);
        assert_eq!(p.jobs_completed(), 4);
        // Accumulate one delta per slot and refresh on a non-correction
        // round: every published slot ships an update job.
        let mut rng = Pcg64::new(44);
        let dims = [12usize, 10, 10, 8];
        for (si, &d) in dims.iter().enumerate() {
            deltas.absorb(si / 2, si % 2, FactorDelta::new(rng.gaussian_matrix(d, 1), 0.95));
        }
        p.refresh_with_deltas(&mut blocks, &strat, &base, 42, 1, 1, Some(&mut deltas));
        assert_eq!(p.update_jobs(), 4, "published slots must ride the update path");
        assert_eq!(p.jobs_completed(), 8);
        for si in 0..dims.len() {
            assert!(deltas.peek(si / 2, si % 2).is_none(), "delta consumed for slot {si}");
        }
        assert!(blocks[0].a_dec.u.all_finite());
        assert!(blocks[1].g_dec.u.all_finite());
        // Correction round (4 % 4 == 0): pending deltas are discarded and
        // the jobs go back to full decompositions.
        for (si, &d) in dims.iter().enumerate() {
            deltas.absorb(si / 2, si % 2, FactorDelta::new(rng.gaussian_matrix(d, 1), 0.95));
        }
        p.refresh_with_deltas(&mut blocks, &strat, &base, 42, 4, 4, Some(&mut deltas));
        assert_eq!(p.update_jobs(), 4, "correction round must not add update jobs");
        for si in 0..dims.len() {
            assert!(deltas.peek(si / 2, si % 2).is_none(), "correction discards delta {si}");
        }
    }

    /// `online = off` (the default) must leave the refresh path untouched
    /// even when a delta buffer is handed in: bitwise the plain refresh.
    #[test]
    fn online_off_with_deltas_is_bitwise_plain_refresh() {
        use crate::rnla::FactorDelta;
        let base = SketchConfig::new(6, 4, 2);
        let strat: Arc<dyn Decomposition> = Arc::new(decomposition::Rsvd);
        let mut plain_blocks = two_blocks();
        let mut p = FactorPipeline::new(sync_cfg(), &[(12, 10), (10, 8)], 6, 0.95);
        p.refresh(&mut plain_blocks, &strat, &base, 9, 0, 0);
        p.refresh(&mut plain_blocks, &strat, &base, 9, 1, 1);

        let mut online_blocks = two_blocks();
        let mut q = FactorPipeline::new(sync_cfg(), &[(12, 10), (10, 8)], 6, 0.95);
        let mut deltas = DeltaBuffer::new(2);
        let mut rng = Pcg64::new(44);
        q.refresh_with_deltas(&mut online_blocks, &strat, &base, 9, 0, 0, Some(&mut deltas));
        deltas.absorb(0, 0, FactorDelta::new(rng.gaussian_matrix(12, 1), 0.95));
        q.refresh_with_deltas(&mut online_blocks, &strat, &base, 9, 1, 1, Some(&mut deltas));
        assert_eq!(q.update_jobs(), 0, "online=off must never ship update jobs");
        for (a, b) in plain_blocks.iter().zip(online_blocks.iter()) {
            assert_eq!(a.a_dec.u.as_slice(), b.a_dec.u.as_slice());
            assert_eq!(a.a_dec.d, b.a_dec.d);
            assert_eq!(a.g_dec.u.as_slice(), b.g_dec.u.as_slice());
            assert_eq!(a.g_dec.d, b.g_dec.d);
        }
    }

    /// Rsvd wrapper whose workers can be stalled: `decompose` spins until
    /// the shared gate opens. Lets tests pin jobs in flight deterministically.
    struct Gated {
        open: Arc<AtomicBool>,
    }

    impl Decomposition for Gated {
        fn key(&self) -> &str {
            "gated-rsvd"
        }

        fn decompose(&self, m: &Matrix, cfg: &SketchConfig, rng: &mut Pcg64) -> LowRankFactor {
            while !self.open.load(Ordering::SeqCst) {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            decomposition::Rsvd.decompose(m, cfg, rng)
        }

        fn meta(&self, dim: usize, cfg: &SketchConfig) -> DecompMeta {
            decomposition::Rsvd.meta(dim, cfg)
        }
    }

    /// Regression: an in-flight job used to suppress re-enqueue for the
    /// whole round even after the rank controller changed the rank, so
    /// adapted ranks lagged an extra T_KI. A rank change must supersede the
    /// pending job.
    #[test]
    fn rank_change_supersedes_pending_job() {
        let open = Arc::new(AtomicBool::new(true));
        let strat: Arc<dyn Decomposition> = Arc::new(Gated { open: Arc::clone(&open) });
        let mut rng = Pcg64::new(9);
        let mut blocks = vec![block(&mut rng, 12, 12)];
        let cfg = PipelineConfig {
            enabled: true,
            workers: 1,
            max_stale_steps: 8,
            adaptive_rank: true,
            min_rank: 2,
            ..Default::default()
        };
        let base = SketchConfig::new(8, 4, 1);
        let mut p = FactorPipeline::new(cfg, &[(12, 12)], 8, 0.95);
        // Round 0 publishes everything (gate open), so later rounds are
        // satisfied by version 0 and never block.
        p.refresh(&mut blocks, &strat, &base, 3, 0, 0);
        // Close the gate: round 1's jobs stay pending.
        open.store(false, Ordering::SeqCst);
        p.refresh(&mut blocks, &strat, &base, 3, 1, 1);
        let pend_ranks: Vec<usize> = p
            .slots
            .iter()
            .map(|s| s.pending.expect("jobs must be in flight with the gate closed").rank)
            .collect();
        // Force a controller rank change while the jobs are in flight.
        for (c, &r) in p.controllers.iter_mut().zip(&pend_ranks) {
            c.rank = if r == c.min_rank { c.max_rank } else { c.min_rank };
        }
        let before = p.superseded_jobs();
        p.refresh(&mut blocks, &strat, &base, 3, 2, 2);
        assert_eq!(p.superseded_jobs(), before + 2, "both slots must supersede");
        for (s, &old) in p.slots.iter().zip(&pend_ranks) {
            let pend = s.pending.expect("superseding job pending");
            assert_eq!(pend.version, 2, "pending must track the superseding job");
            assert_ne!(pend.rank, old, "superseding job carries the new rank");
        }
        // Reopen the gate and force a wait: only the newest jobs satisfy
        // the bound; the superseded results are discarded by monotonicity.
        open.store(true, Ordering::SeqCst);
        p.refresh(&mut blocks, &strat, &base, 3, 3, 11);
        for v in p.published_versions() {
            assert_eq!(v, Some(11));
        }
        assert!(blocks[0].a_dec.u.all_finite());
        assert!(blocks[0].g_dec.u.all_finite());
    }
}
