//! The background factor-refresh service: work queue + worker pool.
//!
//! One [`FactorPipeline`] per K-FAC-family optimizer. At every `T_KI`
//! boundary the optimizer calls [`FactorPipeline::refresh`], which
//!
//! 1. drains finished decompositions from the results channel and publishes
//!    them into the versioned [`FactorSlot`]s (monotone versions only),
//! 2. snapshots each block's EA factors into decomposition jobs — one per
//!    (block, side) — unless a new-enough job is already in flight,
//! 3. blocks **only** while the bounded-staleness contract
//!    `published_version ≥ refresh_step − max_stale_steps` is violated, and
//! 4. installs the published factors into the optimizer's blocks.
//!
//! Workers draw jobs from a shared queue (`Arc<Mutex<Receiver>>` — the
//! standard single-consumer-at-a-time pattern; decomposition dominates, so
//! queue contention is irrelevant) and never touch optimizer state: all
//! publication happens on the trainer thread inside `refresh`, which is
//! what makes the double-buffer race-free without per-slot locking.
//!
//! Determinism: each job carries its own RNG, derived from
//! `(seed, round, block, side)` by [`crate::optim::kfac::decomp_rng`] — the
//! same derivation the inline path uses — so results are independent of
//! which worker runs a job and in which order results arrive.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::linalg::{Matrix, Pcg64};
use crate::optim::kfac::{decomp_rng, BlockState};
use crate::pipeline::rank::RankController;
use crate::pipeline::slot::FactorSlot;
use crate::pipeline::{PipelineConfig, SIDE_A, SIDE_G};
use crate::rnla::{Decomposition, LowRankFactor, SketchConfig};

/// One decomposition work item: a snapshot of an EA factor plus the
/// strategy to decompose it with (shared `dyn Decomposition` — workers
/// never know the concrete type).
struct Job {
    block: usize,
    side: usize,
    version: u64,
    strategy: Arc<dyn Decomposition>,
    cfg: SketchConfig,
    matrix: Matrix,
    rng: Pcg64,
}

/// A finished decomposition heading back to the trainer thread. `Err`
/// carries a worker panic message (e.g. non-finite factors), so the
/// trainer surfaces the failure instead of deadlocking in its wait loop.
struct Done {
    block: usize,
    side: usize,
    version: u64,
    seconds: f64,
    factor: Result<LowRankFactor, String>,
}

fn worker_loop(jobs: Arc<Mutex<Receiver<Job>>>, done: Sender<Done>) {
    loop {
        // Hold the lock only while waiting for/receiving one job; the
        // decomposition itself runs unlocked.
        let next = {
            let rx = jobs.lock().expect("factor pipeline queue poisoned");
            rx.recv()
        };
        let mut job = match next {
            Ok(j) => j,
            Err(_) => break, // queue closed: pipeline shut down
        };
        let t0 = Instant::now();
        let factor = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            job.strategy.decompose(&job.matrix, &job.cfg, &mut job.rng)
        }))
        .map_err(|payload| {
            payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "decomposition panicked".to_string())
        });
        let out = Done {
            block: job.block,
            side: job.side,
            version: job.version,
            seconds: t0.elapsed().as_secs_f64(),
            factor,
        };
        if done.send(out).is_err() {
            break;
        }
    }
}

/// Background factor-refresh service with double-buffered slots and
/// per-layer adaptive rank control. See the module docs for the contract.
pub struct FactorPipeline {
    cfg: PipelineConfig,
    /// Slot `2·block + side` holds that factor's published decomposition.
    slots: Vec<FactorSlot>,
    /// Version last installed into the optimizer's blocks, per slot —
    /// lets refresh skip re-cloning factors that haven't changed.
    installed: Vec<Option<u64>>,
    controllers: Vec<RankController>,
    job_tx: Option<Sender<Job>>,
    done_rx: Receiver<Done>,
    handles: Vec<JoinHandle<()>>,
    worker_seconds: f64,
    jobs_completed: usize,
    rounds: usize,
}

impl FactorPipeline {
    /// Spawn the worker pool for blocks of the given `(d_A, d_G)` dims.
    ///
    /// `init_rank` seeds every rank controller (typically the schedule's
    /// epoch-0 rank); `rho` is the EA decay used by the Prop. 3.1 cap.
    pub fn new(
        cfg: PipelineConfig,
        dims: &[(usize, usize)],
        init_rank: usize,
        rho: f64,
    ) -> FactorPipeline {
        let (job_tx, job_rx) = channel::<Job>();
        let (done_tx, done_rx) = channel::<Done>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let n_workers = cfg.workers.max(1);
        let mut handles = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let jobs = Arc::clone(&job_rx);
            let done = done_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("factor-refresh-{w}"))
                .spawn(move || worker_loop(jobs, done))
                .expect("spawning factor-refresh worker");
            handles.push(handle);
        }
        let mut slots = Vec::with_capacity(dims.len() * 2);
        let mut controllers = Vec::with_capacity(dims.len() * 2);
        for &(da, dg) in dims {
            for dim in [da, dg] {
                slots.push(FactorSlot::seed(dim));
                controllers.push(RankController::new(
                    init_rank,
                    dim,
                    cfg.target_rel_err,
                    cfg.min_rank,
                    cfg.growth,
                    rho,
                    cfg.prop31_batch,
                ));
            }
        }
        let installed = vec![None; slots.len()];
        FactorPipeline {
            cfg,
            slots,
            installed,
            controllers,
            job_tx: Some(job_tx),
            done_rx,
            handles,
            worker_seconds: 0.0,
            jobs_completed: 0,
            rounds: 0,
        }
    }

    fn publish(&mut self, done: Done) {
        self.worker_seconds += done.seconds;
        self.jobs_completed += 1;
        let si = 2 * done.block + done.side;
        let factor = match done.factor {
            Ok(f) => f,
            Err(msg) => panic!(
                "factor pipeline worker failed on block {} side {} (version {}): {msg}",
                done.block, done.side, done.version
            ),
        };
        let slot = &mut self.slots[si];
        if slot.pending == Some(done.version) {
            slot.pending = None;
        }
        // Monotone publication first: a stale result that loses to an
        // already-published newer version must not perturb the rank
        // controller either.
        if slot.publish(done.version, factor) && self.cfg.adaptive_rank {
            let spectrum = self.slots[si].factor().d.clone();
            self.controllers[si].observe(&spectrum);
        }
    }

    /// One refresh round at optimizer step `version` (see module docs).
    /// `round` is the optimizer's decomposition-round counter — it seeds
    /// the per-job RNG streams exactly like the inline path.
    pub fn refresh(
        &mut self,
        blocks: &mut [BlockState],
        strategy: &Arc<dyn Decomposition>,
        base: &SketchConfig,
        seed: u64,
        round: usize,
        version: u64,
    ) {
        assert_eq!(blocks.len() * 2, self.slots.len(), "pipeline: block count mismatch");
        // 1. Drain whatever the workers finished since the last round.
        while let Ok(done) = self.done_rx.try_recv() {
            self.publish(done);
        }
        let required = version.saturating_sub(self.cfg.max_stale_steps as u64);
        // 2. Enqueue fresh snapshots. Skip a slot only when a job that can
        //    still satisfy the staleness bound is already in flight.
        for (bi, block) in blocks.iter().enumerate() {
            for side in [SIDE_A, SIDE_G] {
                let si = 2 * bi + side;
                if self.slots[si].pending.is_some_and(|p| p >= required) {
                    continue;
                }
                // Controller feedback: with `adaptive_sketch` on, the
                // strategy picks its own oversampling/power-iteration
                // schedule for the controller's rank and error target
                // (Decomposition::tune); otherwise only the rank adapts.
                let cfg = if self.cfg.adaptive_rank {
                    let ctl = &self.controllers[si];
                    if self.cfg.adaptive_sketch {
                        strategy.tune(base, ctl.rank, ctl.target)
                    } else {
                        SketchConfig::new(ctl.rank, base.oversample, base.n_power_iter)
                    }
                } else {
                    SketchConfig::new(base.rank, base.oversample, base.n_power_iter)
                };
                let matrix =
                    if side == SIDE_A { block.a_bar.clone() } else { block.g_bar.clone() };
                let job = Job {
                    block: bi,
                    side,
                    version,
                    strategy: Arc::clone(strategy),
                    cfg,
                    matrix,
                    rng: decomp_rng(seed, round, bi, side),
                };
                self.job_tx
                    .as_ref()
                    .expect("pipeline already shut down")
                    .send(job)
                    .expect("pipeline workers disconnected");
                self.slots[si].pending = Some(version);
            }
        }
        // 3. Bounded-staleness wait: block only while the contract is
        //    violated. With max_stale_steps = 0 this waits for the full
        //    round — synchronous semantics.
        while self.slots.iter().any(|s| !s.satisfies(required)) {
            let done = self.done_rx.recv().expect("pipeline workers disconnected");
            self.publish(done);
        }
        // 4. Install the published (front-buffer) factors — only where the
        //    published version moved since the last install, so unchanged
        //    (still-valid stale) factors are not re-cloned every round.
        for (bi, block) in blocks.iter_mut().enumerate() {
            let sa = 2 * bi + SIDE_A;
            if self.installed[sa] != self.slots[sa].version() {
                block.a_dec = self.slots[sa].factor().clone();
                self.installed[sa] = self.slots[sa].version();
            }
            let sg = 2 * bi + SIDE_G;
            if self.installed[sg] != self.slots[sg].version() {
                block.g_dec = self.slots[sg].factor().clone();
                self.installed[sg] = self.slots[sg].version();
            }
        }
        self.rounds += 1;
    }

    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// Published step-version per slot (order: block-major, A then G).
    pub fn published_versions(&self) -> Vec<Option<u64>> {
        self.slots.iter().map(FactorSlot::version).collect()
    }

    /// Current controller rank per slot (order: block-major, A then G).
    pub fn ranks(&self) -> Vec<usize> {
        self.controllers.iter().map(|c| c.rank).collect()
    }

    /// Worst staleness across slots at step `now` (`None` before the first
    /// publish).
    pub fn max_staleness(&self, now: u64) -> Option<u64> {
        self.slots.iter().map(|s| s.staleness(now)).collect::<Option<Vec<_>>>().map(|v| {
            v.into_iter().max().unwrap_or(0)
        })
    }

    /// Total seconds workers spent inside decompositions (overlapped with
    /// training when `max_stale_steps > 0`).
    pub fn worker_seconds(&self) -> f64 {
        self.worker_seconds
    }

    pub fn jobs_completed(&self) -> usize {
        self.jobs_completed
    }

    pub fn rounds(&self) -> usize {
        self.rounds
    }
}

impl Drop for FactorPipeline {
    fn drop(&mut self) {
        // Closing the job channel ends the worker loops; join to avoid
        // leaking threads past the optimizer's lifetime.
        drop(self.job_tx.take());
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gemm, qr};
    use crate::rnla::decomposition;

    fn decayed_psd(rng: &mut Pcg64, d: usize, decay: f64) -> Matrix {
        let q = qr::orthonormalize(&rng.gaussian_matrix(d, d));
        let lam: Vec<f64> = (0..d).map(|i| decay.powi(i as i32)).collect();
        let mut qd = q.clone();
        gemm::scale_cols(&mut qd, &lam);
        gemm::matmul_nt(&qd, &q)
    }

    fn block(rng: &mut Pcg64, da: usize, dg: usize) -> BlockState {
        BlockState {
            a_bar: decayed_psd(rng, da, 0.7),
            g_bar: decayed_psd(rng, dg, 0.6),
            a_dec: LowRankFactor::new(Matrix::eye(da), vec![1.0; da]),
            g_dec: LowRankFactor::new(Matrix::eye(dg), vec![1.0; dg]),
        }
    }

    fn sync_cfg() -> PipelineConfig {
        PipelineConfig { enabled: true, workers: 2, max_stale_steps: 0, ..Default::default() }
    }

    #[test]
    fn zero_staleness_bitwise_matches_inline() {
        let mut rng = Pcg64::new(1);
        let mut blocks = vec![block(&mut rng, 12, 10), block(&mut rng, 10, 8)];
        let base = SketchConfig::new(6, 4, 2);
        let seed = 42u64;
        let strat: Arc<dyn Decomposition> = Arc::new(decomposition::Rsvd);
        // Inline reference with the shared per-(round, block, side) streams.
        let mut expected = Vec::new();
        for (bi, b) in blocks.iter().enumerate() {
            let mut ra = decomp_rng(seed, 0, bi, SIDE_A);
            let mut rg = decomp_rng(seed, 0, bi, SIDE_G);
            expected.push((
                strat.decompose(&b.a_bar, &base, &mut ra),
                strat.decompose(&b.g_bar, &base, &mut rg),
            ));
        }
        let mut p = FactorPipeline::new(sync_cfg(), &[(12, 10), (10, 8)], 6, 0.95);
        p.refresh(&mut blocks, &strat, &base, seed, 0, 0);
        for (b, (ea, eg)) in blocks.iter().zip(expected.iter()) {
            assert_eq!(b.a_dec.u.as_slice(), ea.u.as_slice());
            assert_eq!(b.a_dec.d, ea.d);
            assert_eq!(b.g_dec.u.as_slice(), eg.u.as_slice());
            assert_eq!(b.g_dec.d, eg.d);
        }
        assert_eq!(p.jobs_completed(), 4);
        assert_eq!(p.rounds(), 1);
        assert!(p.worker_seconds() > 0.0);
    }

    #[test]
    fn staleness_bound_holds_across_rounds() {
        let mut rng = Pcg64::new(2);
        let mut blocks = vec![block(&mut rng, 10, 10)];
        let base = SketchConfig::new(5, 3, 1);
        let cfg = PipelineConfig {
            enabled: true,
            workers: 1,
            max_stale_steps: 3,
            ..Default::default()
        };
        let strat: Arc<dyn Decomposition> = Arc::new(decomposition::Srevd);
        let mut p = FactorPipeline::new(cfg, &[(10, 10)], 5, 0.95);
        let mut last: Vec<Option<u64>> = vec![None, None];
        for (round, version) in [(0u64, 0u64), (1, 5), (2, 10), (3, 15)] {
            p.refresh(&mut blocks, &strat, &base, 7, round as usize, version);
            let required = version.saturating_sub(3);
            for (vi, v) in p.published_versions().into_iter().enumerate() {
                let v = v.expect("slot published after refresh");
                assert!(v >= required, "slot {vi}: version {v} < required {required}");
                if let Some(prev) = last[vi] {
                    assert!(v >= prev, "published versions must be monotone");
                }
                last[vi] = Some(v);
            }
            assert!(p.max_staleness(version).unwrap() <= 3 + 5, "lag bounded by stale + T_KI");
        }
    }

    #[test]
    fn adaptive_rank_shrinks_on_decayed_spectrum() {
        let mut rng = Pcg64::new(3);
        let mut blocks = vec![block(&mut rng, 24, 24)];
        let base = SketchConfig::new(24, 4, 2);
        let cfg = PipelineConfig {
            enabled: true,
            workers: 2,
            max_stale_steps: 0,
            adaptive_rank: true,
            target_rel_err: 0.05,
            min_rank: 2,
            ..Default::default()
        };
        let strat: Arc<dyn Decomposition> = Arc::new(decomposition::Rsvd);
        let mut p = FactorPipeline::new(cfg, &[(24, 24)], 24, 0.95);
        for round in 0..6u64 {
            p.refresh(&mut blocks, &strat, &base, 11, round as usize, round);
        }
        // decay 0.7 / 0.6 with ε = 0.05 → far fewer than 24 modes needed.
        for &r in p.ranks().iter() {
            assert!(r < 24, "controller should shrink, got {r}");
            assert!(r >= 2);
        }
        // The installed decompositions reflect the adapted (smaller) ranks.
        assert!(blocks[0].a_dec.rank() < 24);
    }

    #[test]
    fn shutdown_joins_workers() {
        let p = FactorPipeline::new(sync_cfg(), &[(6, 6)], 4, 0.95);
        drop(p); // must not hang or panic
    }

    /// `adaptive_sketch`: the strategy's `tune` hook picks the sketch
    /// parameters; the refresh loop still converges and installs factors
    /// at the controller's adapted ranks.
    #[test]
    fn adaptive_sketch_routes_through_strategy_tune() {
        let mut rng = Pcg64::new(7);
        let mut blocks = vec![block(&mut rng, 24, 24)];
        let base = SketchConfig::new(24, 4, 4);
        let cfg = PipelineConfig {
            enabled: true,
            workers: 2,
            max_stale_steps: 0,
            adaptive_rank: true,
            adaptive_sketch: true,
            target_rel_err: 0.05,
            min_rank: 2,
            ..Default::default()
        };
        let strat: Arc<dyn Decomposition> = Arc::new(decomposition::Rsvd);
        let mut p = FactorPipeline::new(cfg, &[(24, 24)], 24, 0.95);
        for round in 0..6u64 {
            p.refresh(&mut blocks, &strat, &base, 13, round as usize, round);
        }
        // Controller still shrinks on the decayed spectrum, and the
        // installed factors reflect its ranks.
        for &r in p.ranks().iter() {
            assert!((2..24).contains(&r), "rank {r}");
        }
        assert!(blocks[0].a_dec.rank() < 24);
        assert!(blocks[0].a_dec.u.all_finite());
        assert!(blocks[0].g_dec.u.all_finite());
    }
}
