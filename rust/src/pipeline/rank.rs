//! Adaptive per-layer sketch-rank control.
//!
//! The paper drives all layers with one scheduled rank `r(e)` (§5). But
//! Prop. 3.1 is a *per-factor* statement: the number of EA eigenvalues
//! above `ε·λ_max` is bounded by `min(r_ε·n_M, d_M)` and in practice varies
//! strongly per block (Fig. 1). The controller here closes the loop with
//! the observed spectra instead: every published decomposition reports its
//! retained eigenvalues, and the rank for that block's *next* refresh is
//!
//! * **shrink** toward `modes_above(λ, ε)` when the retained head already
//!   decays below `ε·λ_max` (damped by [`SHRINK_FLOOR`] per observation to
//!   avoid oscillation), or
//! * **grow** geometrically when it does not — the truncation point was not
//!   yet visible, so the current rank under-resolves the spectrum,
//!
//! clamped to `[min_rank, max_rank]` where `max_rank` incorporates the
//! Prop. 3.1 mode bound. [`next_rank`] is a pure function and is monotone
//! in the error target: a tighter ε never selects a smaller rank (see the
//! property test in `rust/tests/pipeline_contract.rs`).
//!
//! With the `adaptive_sketch` pipeline toggle, the controller's chosen
//! rank and error target also feed the decomposition strategy's
//! [`crate::rnla::Decomposition::tune`] hook, which scales oversampling
//! and the power-iteration count per refresh instead of using the global
//! §5 schedule values.

use crate::rnla::errors;

/// Largest per-observation shrink factor (new rank ≥ 3/4 of the old one).
pub const SHRINK_FLOOR: f64 = 0.75;

/// Eigenvalue-floor constant α of Prop. 3.1 (paper §3 uses 0.1).
pub const PROP31_ALPHA: f64 = 0.1;

/// Pure rank update: given the retained (descending) eigenvalues `lambda`
/// of the last rank-`current` decomposition, pick the next rank for a
/// target relative spectral error `target`.
///
/// Monotone in `target` for fixed `(lambda, current, clamps)`: if
/// `t1 <= t2` then `next_rank(.., t1, ..) >= next_rank(.., t2, ..)`.
pub fn next_rank(
    lambda: &[f64],
    current: usize,
    target: f64,
    min_rank: usize,
    max_rank: usize,
    growth: f64,
) -> usize {
    let needed = errors::modes_above(lambda, target);
    let proposal = if needed < lambda.len() {
        // The spectrum decays below ε·λ_max inside the retained head: shrink
        // toward the observed mode count (damped).
        needed.max((current as f64 * SHRINK_FLOOR).ceil() as usize)
    } else {
        // Every retained eigenvalue still exceeds ε·λ_max — the truncation
        // point is beyond the current rank: grow.
        ((current as f64 * growth).ceil() as usize).max(current + 1)
    };
    proposal.max(min_rank).min(max_rank)
}

/// Per-(block, side) adaptive rank state.
#[derive(Clone, Debug)]
pub struct RankController {
    /// Target relative spectral error ε.
    pub target: f64,
    pub min_rank: usize,
    pub max_rank: usize,
    pub growth: f64,
    /// Rank to use for the next enqueued decomposition.
    pub rank: usize,
    /// Observations consumed (published spectra).
    pub observations: usize,
}

impl RankController {
    /// Build a controller for a factor of dimension `dim`.
    ///
    /// `prop31_batch` > 0 caps the rank with the Prop. 3.1 mode bound
    /// `min(r_ε·n_M, d)` computed from the EA decay `rho`; 0 keeps the cap
    /// at `dim`.
    pub fn new(
        init_rank: usize,
        dim: usize,
        target_rel_err: f64,
        min_rank: usize,
        growth: f64,
        rho: f64,
        prop31_batch: usize,
    ) -> RankController {
        let target = target_rel_err.clamp(1e-6, 0.5);
        let mut max_rank = dim.max(1);
        if prop31_batch > 0 && rho > 0.0 && rho < 1.0 {
            max_rank =
                max_rank.min(errors::prop31_mode_bound(PROP31_ALPHA, target, rho, prop31_batch, dim));
        }
        let min_rank = min_rank.clamp(1, max_rank);
        RankController {
            target,
            min_rank,
            max_rank,
            growth: growth.max(1.01),
            rank: init_rank.clamp(min_rank, max_rank),
            observations: 0,
        }
    }

    /// Consume the retained eigenvalues of the latest published
    /// decomposition of this controller's factor; returns the rank to use
    /// for the next refresh.
    pub fn observe(&mut self, lambda: &[f64]) -> usize {
        self.rank = next_rank(lambda, self.rank, self.target, self.min_rank, self.max_rank, self.growth);
        self.observations += 1;
        self.rank
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// λ_i = decay^i, λ_max = 1.
    fn spectrum(n: usize, decay: f64) -> Vec<f64> {
        (0..n).map(|i| decay.powi(i as i32)).collect()
    }

    #[test]
    fn shrinks_on_decayed_spectrum() {
        // decay 0.5, ε = 0.03 → modes above: 0.5^k >= 0.03 → k <= 5 → 6 modes.
        let lam = spectrum(20, 0.5);
        let r = next_rank(&lam, 20, 0.03, 1, 64, 1.5);
        // Damped: floor at ceil(20 * 0.75) = 15, needed = 6 → 15.
        assert_eq!(r, 15);
        // Next observations keep shrinking toward 6.
        let r2 = next_rank(&lam[..15], r, 0.03, 1, 64, 1.5);
        assert_eq!(r2, 12);
        let mut rank = r2;
        for _ in 0..10 {
            let head = &lam[..rank.min(lam.len())];
            rank = next_rank(head, rank, 0.03, 1, 64, 1.5);
        }
        assert_eq!(rank, 6);
    }

    #[test]
    fn grows_on_flat_spectrum() {
        // No decay inside the head → every mode above ε·λ_max → grow.
        let lam = vec![1.0; 8];
        let r = next_rank(&lam, 8, 0.03, 1, 64, 1.5);
        assert_eq!(r, 12);
        // Growth respects the cap.
        assert_eq!(next_rank(&lam, 8, 0.03, 1, 10, 1.5), 10);
    }

    #[test]
    fn clamps_respected() {
        let lam = spectrum(16, 0.1);
        assert!(next_rank(&lam, 16, 0.4, 5, 64, 1.5) >= 5);
        assert!(next_rank(&vec![1.0; 32], 32, 0.01, 1, 20, 2.0) <= 20);
    }

    #[test]
    fn controller_converges_on_decaying_spectrum() {
        let mut c = RankController::new(32, 64, 0.03, 4, 1.5, 0.95, 0);
        let lam = spectrum(64, 0.6);
        for _ in 0..20 {
            let head: Vec<f64> = lam[..c.rank.min(lam.len())].to_vec();
            c.observe(&head);
        }
        // 0.6^k >= 0.03 → k <= 6.86 → 7 modes.
        assert_eq!(c.rank, 7);
        assert_eq!(c.observations, 20);
    }

    #[test]
    fn prop31_cap_applies() {
        // r_ε(α=0.1, ε=0.03, ρ=0.5) = 9 → cap = min(9·1, 512) = 9.
        let c = RankController::new(64, 512, 0.03, 2, 1.5, 0.5, 1);
        assert_eq!(c.max_rank, 9);
        assert_eq!(c.rank, 9);
        // Without the batch hint, the cap is the dimension.
        let c2 = RankController::new(64, 512, 0.03, 2, 1.5, 0.5, 0);
        assert_eq!(c2.max_rank, 512);
    }

    #[test]
    fn init_rank_clamped() {
        let c = RankController::new(1000, 48, 0.03, 4, 1.5, 0.95, 0);
        assert_eq!(c.rank, 48);
        let c2 = RankController::new(1, 48, 0.03, 4, 1.5, 0.95, 0);
        assert_eq!(c2.rank, 4);
    }
}
