//! The factor server: hosts the PR-3 priority scheduler and serves
//! decompositions to N remote [`crate::pipeline::FactorPipeline`] clients
//! (`rkfac serve-factors`).
//!
//! One shared [`JobQueue`] feeds a pool of worker threads (named
//! `factor-serve-{w}` — deliberately *not* `factor-refresh-*`, which the
//! pipeline contract suite reserves for in-process workers). Jobs arrive
//! over TCP connections or a [`super::dir`] mailbox, each carrying its own
//! deterministic RNG state and obs span context, so a decomposition
//! computed here is bitwise the one the client would have computed inline.
//!
//! Per-client staleness floors work exactly like the local pool's: a
//! queued job whose version fell below its client's floor is dropped at
//! pop time. Failures (unknown strategy, decomposition panic) are returned
//! as `Err` results — the client's inline-retry machinery takes over, so a
//! misbehaving server can slow a trainer down but never wedge it.

use std::io;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::obs::{self, clock};
use crate::pipeline::sched::JobQueue;
use crate::rnla::DecompositionRegistry;
use crate::util::json::Json;

use super::dir::publish_file;
use super::wire::{read_frame, write_frame, Frame, WireJob, WireUpdate};
use super::{run_spec, JobResult, JobSpec, UpdateJob};

/// Where a finished job's result frame goes.
enum ReplySink {
    /// Write back on the submitting client's TCP stream.
    Tcp(Arc<Mutex<TcpStream>>),
    /// Atomic-publish into the mailbox's `results/` directory.
    Dir { dir: PathBuf, name: String },
}

/// One queued decomposition on the server.
struct ServerJob {
    wire: WireJob,
    /// Incremental-basis payload of a [`Frame::SubmitDelta`]; `None` for a
    /// plain full-snapshot submit.
    update: Option<WireUpdate>,
    strategy: Arc<dyn crate::rnla::Decomposition>,
    reply: ReplySink,
    /// The submitting client's staleness floor (shared with its handler).
    floor: Arc<AtomicU64>,
    received_ns: u64,
}

fn send_reply(reply: &ReplySink, result: &JobResult) {
    let frame = Frame::Result {
        result: JobResult {
            block: result.block,
            side: result.side,
            version: result.version,
            wait_s: result.wait_s,
            run_s: result.run_s,
            outcome: result.outcome.clone(),
        },
    };
    match reply {
        ReplySink::Tcp(stream) => {
            let mut s = stream.lock().unwrap_or_else(|e| e.into_inner());
            // A write error means the client is gone; its inline fallback
            // already has the job covered.
            let _ = write_frame(&mut *s, &frame);
        }
        ReplySink::Dir { dir, name } => {
            let mut bytes = Vec::new();
            if write_frame(&mut bytes, &frame).is_ok() {
                let _ = publish_file(dir, name, &bytes);
            }
        }
    }
}

fn worker_loop(queue: Arc<JobQueue<ServerJob>>) {
    while let Some(job) = queue.pop() {
        // Same rule as the local pool: below the client's floor the result
        // could never be installed — skip the decomposition.
        if job.wire.version < job.floor.load(Ordering::Relaxed) {
            continue;
        }
        let pop_ns = clock::now_ns();
        let wait_s = clock::secs_between(job.received_ns, pop_ns);
        let parent = obs::SpanCtx::from_raw(job.wire.span);
        obs::emit_manual(
            "pipeline.job.wait",
            job.received_ns,
            pop_ns,
            parent,
            vec![
                ("block".to_string(), Json::from(job.wire.block)),
                ("side".to_string(), Json::from(job.wire.side)),
            ],
        );
        let rng = job.wire.rng();
        // A SubmitDelta frame ships the incremental-basis state in place of
        // the dense snapshot; `decode_update` already validated the shapes
        // and rho, so the constructors below cannot panic.
        let update = job.update.map(|u| UpdateJob {
            prev: Arc::new(crate::rnla::LowRankFactor::new(u.prev_u, u.prev_d)),
            delta: Arc::new(crate::rnla::FactorDelta::new(u.delta_cols, u.delta_rho)),
        });
        let spec = JobSpec {
            block: job.wire.block,
            side: job.wire.side,
            version: job.wire.version,
            strategy: Arc::clone(&job.strategy),
            cfg: job.wire.cfg.clone(),
            matrix: Arc::new(job.wire.matrix),
            rng,
            enqueued_ns: job.received_ns,
            flops_pred: job.wire.flops_pred,
            span: parent,
            update,
        };
        let outcome = {
            let _sp = obs::span_with_parent("pipeline.job.run", parent)
                .arg("block", spec.block)
                .arg("side", spec.side)
                .arg("strategy", spec.strategy.key())
                .arg("rank", spec.cfg.rank)
                .arg("flops_pred", spec.flops_pred)
                .arg("version", spec.version)
                .arg("op", if spec.update.is_some() { "update" } else { "decompose" })
                .with_backend();
            run_spec(&spec)
        };
        let run_s = clock::secs_between(pop_ns, clock::now_ns());
        send_reply(
            &job.reply,
            &JobResult {
                block: spec.block,
                side: spec.side,
                version: spec.version,
                wait_s,
                run_s,
                outcome,
            },
        );
    }
}

/// Handle to a running factor server; shuts down (and joins every thread)
/// on [`ServerHandle::shutdown`] or drop.
pub struct ServerHandle {
    addr: Option<SocketAddr>,
    stop: Arc<AtomicBool>,
    queue: Arc<JobQueue<ServerJob>>,
    threads: Vec<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl ServerHandle {
    /// The bound TCP address (`None` for a dir-mailbox server). With
    /// `bind = "127.0.0.1:0"` this is where the OS-assigned port lives.
    pub fn addr(&self) -> Option<SocketAddr> {
        self.addr
    }

    /// Stop accepting, close the queue, sever client connections, and join
    /// every server thread. Idempotent.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.queue.close();
        {
            let mut conns = self.conns.lock().unwrap_or_else(|e| e.into_inner());
            for c in conns.drain(..) {
                let _ = c.shutdown(Shutdown::Both);
            }
        }
        // Wake a blocking accept with a throwaway connection (the stop flag
        // is already set, so the accept loop exits on it).
        if let Some(addr) = self.addr {
            let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(100));
        }
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
        let handlers: Vec<_> = {
            let mut hs = self.handlers.lock().unwrap_or_else(|e| e.into_inner());
            hs.drain(..).collect()
        };
        for h in handlers {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Factory for factor-server instances. Stateless — both constructors
/// return a [`ServerHandle`] owning every spawned thread.
pub struct FactorServer;

impl FactorServer {
    /// Serve over TCP. `bind` like `"0.0.0.0:7070"` (tests use
    /// `"127.0.0.1:0"` for an OS-assigned port, read back via
    /// [`ServerHandle::addr`]).
    pub fn spawn_tcp(
        bind: &str,
        workers: usize,
        registry: DecompositionRegistry,
    ) -> io::Result<ServerHandle> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let queue = Arc::new(JobQueue::new());
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let handlers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let mut threads = spawn_workers(workers, &queue);
        {
            let stop = Arc::clone(&stop);
            let queue = Arc::clone(&queue);
            let conns = Arc::clone(&conns);
            let handlers = Arc::clone(&handlers);
            let accept = std::thread::Builder::new()
                .name("factor-serve-accept".into())
                .spawn(move || {
                    for incoming in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let stream = match incoming {
                            Ok(s) => s,
                            Err(_) => continue,
                        };
                        let reply_stream = match stream.try_clone() {
                            Ok(s) => s,
                            Err(_) => continue,
                        };
                        {
                            let mut cs = conns.lock().unwrap_or_else(|e| e.into_inner());
                            cs.push(match stream.try_clone() {
                                Ok(s) => s,
                                Err(_) => continue,
                            });
                        }
                        let queue = Arc::clone(&queue);
                        let registry = registry.clone();
                        let handle = std::thread::Builder::new()
                            .name("factor-serve-conn".into())
                            .spawn(move || {
                                handle_conn(
                                    stream,
                                    Arc::new(Mutex::new(reply_stream)),
                                    queue,
                                    registry,
                                )
                            })
                            .expect("spawning connection handler");
                        handlers.lock().unwrap_or_else(|e| e.into_inner()).push(handle);
                    }
                })
                .expect("spawning accept thread");
            threads.push(accept);
        }
        Ok(ServerHandle { addr: Some(addr), stop, queue, threads, conns, handlers })
    }

    /// Serve a [`super::DirTransport`] mailbox rooted at `root`.
    pub fn spawn_dir(
        root: &Path,
        workers: usize,
        registry: DecompositionRegistry,
    ) -> io::Result<ServerHandle> {
        for d in ["jobs", "claimed", "results"] {
            std::fs::create_dir_all(root.join(d))?;
        }
        let stop = Arc::new(AtomicBool::new(false));
        let queue = Arc::new(JobQueue::new());
        let mut threads = spawn_workers(workers, &queue);
        {
            let stop = Arc::clone(&stop);
            let queue = Arc::clone(&queue);
            let root = root.to_path_buf();
            let scanner = std::thread::Builder::new()
                .name("factor-serve-scan".into())
                .spawn(move || scan_loop(&root, &stop, &queue, &registry))
                .expect("spawning mailbox scanner");
            threads.push(scanner);
        }
        Ok(ServerHandle {
            addr: None,
            stop,
            queue,
            threads,
            conns: Arc::new(Mutex::new(Vec::new())),
            handlers: Arc::new(Mutex::new(Vec::new())),
        })
    }
}

impl FactorServer {
    /// Registry resolution shared by both front ends: an unknown strategy
    /// key becomes an `Err` result the client retries inline.
    fn resolve(
        registry: &DecompositionRegistry,
        key: &str,
    ) -> Result<Arc<dyn crate::rnla::Decomposition>, String> {
        registry.get(key).ok_or_else(|| {
            format!("factor server: unknown strategy '{key}' (known: {:?})", registry.keys())
        })
    }
}

fn spawn_workers(workers: usize, queue: &Arc<JobQueue<ServerJob>>) -> Vec<JoinHandle<()>> {
    (0..workers.max(1))
        .map(|w| {
            let q = Arc::clone(queue);
            std::thread::Builder::new()
                .name(format!("factor-serve-{w}"))
                .spawn(move || worker_loop(q))
                .expect("spawning factor-serve worker")
        })
        .collect()
}

/// Per-connection server loop: decode frames, queue submits, answer
/// control frames inline. Returns (ending the handler thread) on any read
/// error — the client's reconnect-or-fallback machinery owns what happens
/// next.
fn handle_conn(
    mut stream: TcpStream,
    reply: Arc<Mutex<TcpStream>>,
    queue: Arc<JobQueue<ServerJob>>,
    registry: DecompositionRegistry,
) {
    let floor = Arc::new(AtomicU64::new(0));
    loop {
        let frame = match read_frame(&mut stream) {
            Ok((f, n)) => {
                obs::counter_add("transport.frames_rx", 1);
                obs::counter_add("transport.bytes_rx", n as u64);
                f
            }
            Err(_) => break,
        };
        match frame {
            Frame::Hello { .. } => {
                // The "/2" protocol tag advertises SubmitDelta support;
                // clients parse it in `banner_supports_delta` and fall back
                // to full-snapshot submits against unversioned banners.
                let mut s = reply.lock().unwrap_or_else(|e| e.into_inner());
                if write_frame(
                    &mut *s,
                    &Frame::HelloAck { server: "rkfac-factor-server/2".into() },
                )
                .is_err()
                {
                    break;
                }
            }
            Frame::Heartbeat { nonce } => {
                let mut s = reply.lock().unwrap_or_else(|e| e.into_inner());
                if write_frame(&mut *s, &Frame::HeartbeatAck { nonce }).is_err() {
                    break;
                }
            }
            Frame::SetFloor { floor: f } => floor.store(f, Ordering::Relaxed),
            Frame::Submit { job, prio } => {
                queue_submit(&queue, &registry, job, None, prio, &reply, &floor);
            }
            Frame::SubmitDelta { job, update, prio } => {
                queue_submit(&queue, &registry, job, Some(update), prio, &reply, &floor);
            }
            Frame::Shutdown => break,
            // Server-bound protocol only; anything else is a client bug.
            _ => break,
        }
    }
}

/// Shared Submit/SubmitDelta handling for the TCP front end: resolve the
/// strategy and queue the job, or reply `Err` so the client retries inline.
fn queue_submit(
    queue: &Arc<JobQueue<ServerJob>>,
    registry: &DecompositionRegistry,
    job: WireJob,
    update: Option<WireUpdate>,
    prio: f64,
    reply: &Arc<Mutex<TcpStream>>,
    floor: &Arc<AtomicU64>,
) {
    match FactorServer::resolve(registry, &job.strategy_key) {
        Ok(strategy) => {
            queue.push(
                ServerJob {
                    wire: job,
                    update,
                    strategy,
                    reply: ReplySink::Tcp(Arc::clone(reply)),
                    floor: Arc::clone(floor),
                    received_ns: clock::now_ns(),
                },
                prio,
            );
        }
        Err(msg) => send_reply(
            &ReplySink::Tcp(Arc::clone(reply)),
            &JobResult {
                block: job.block,
                side: job.side,
                version: job.version,
                wait_s: 0.0,
                run_s: 0.0,
                outcome: Err(msg),
            },
        ),
    }
}

/// Mailbox file names are `<kind>_<client>_<seq>.frame` (client ids contain
/// no underscores); returns the `<client>` part.
fn client_of(name: &str, kind: &str) -> Option<String> {
    let rest = name.strip_prefix(kind)?.strip_suffix(".frame")?;
    let (client, _seq) = rest.rsplit_once('_')?;
    Some(client.to_string())
}

/// Dir-mailbox server loop: claim job files (atomic rename into
/// `claimed/`), track per-client floors, answer heartbeats, queue work.
fn scan_loop(
    root: &Path,
    stop: &AtomicBool,
    queue: &Arc<JobQueue<ServerJob>>,
    registry: &DecompositionRegistry,
) {
    let jobs = root.join("jobs");
    let claimed = root.join("claimed");
    let results = root.join("results");
    let reply_seq = AtomicU64::new(0);
    let mut floors: std::collections::HashMap<String, Arc<AtomicU64>> =
        std::collections::HashMap::new();
    while !stop.load(Ordering::SeqCst) {
        let mut names: Vec<String> = match std::fs::read_dir(&jobs) {
            Ok(rd) => rd
                .filter_map(|e| e.ok())
                .filter_map(|e| e.file_name().into_string().ok())
                .filter(|n| n.ends_with(".frame"))
                .collect(),
            Err(_) => {
                std::thread::sleep(Duration::from_millis(2));
                continue;
            }
        };
        names.sort();
        // Floors first, so a batch's floor applies to its own jobs.
        for name in names.iter().filter(|n| n.starts_with("floor_")) {
            if let Ok(bytes) = std::fs::read(jobs.join(name)) {
                if let Ok((Frame::SetFloor { floor }, _)) = read_frame(&mut &bytes[..]) {
                    if let Some(client) = name.strip_prefix("floor_").and_then(|r| {
                        r.strip_suffix(".frame").map(str::to_string)
                    }) {
                        floors
                            .entry(client)
                            .or_insert_with(|| Arc::new(AtomicU64::new(0)))
                            .store(floor, Ordering::Relaxed);
                    }
                }
            }
        }
        for name in &names {
            if name.starts_with("hb_") {
                let path = jobs.join(name);
                if let (Ok(bytes), Some(client)) =
                    (std::fs::read(&path), client_of(name, "hb_"))
                {
                    if let Ok((Frame::Heartbeat { nonce }, _)) = read_frame(&mut &bytes[..]) {
                        let mut out = Vec::new();
                        if write_frame(&mut out, &Frame::HeartbeatAck { nonce }).is_ok() {
                            let rn = format!(
                                "res_{client}_{:08}.frame",
                                reply_seq.fetch_add(1, Ordering::Relaxed)
                            );
                            let _ = publish_file(&results, &rn, &out);
                        }
                    }
                }
                let _ = std::fs::remove_file(&path);
                continue;
            }
            if !name.starts_with("job_") {
                continue;
            }
            // Claim by rename: atomic, so exactly one server instance wins.
            let claimed_path = claimed.join(name);
            if std::fs::rename(jobs.join(name), &claimed_path).is_err() {
                continue;
            }
            let bytes = match std::fs::read(&claimed_path) {
                Ok(b) => b,
                Err(_) => continue,
            };
            let _ = std::fs::remove_file(&claimed_path);
            let Some(client) = client_of(name, "job_") else { continue };
            let (frame, n) = match read_frame(&mut &bytes[..]) {
                Ok(ok) => ok,
                Err(e) => {
                    // The client's recv deadline covers this job; it will
                    // fall back inline. Log and move on.
                    eprintln!("factor server: corrupt job file {name}: {e}");
                    continue;
                }
            };
            obs::counter_add("transport.frames_rx", 1);
            obs::counter_add("transport.bytes_rx", n as u64);
            let (job, update, prio) = match frame {
                Frame::Submit { job, prio } => (job, None, prio),
                // DirTransport never advertises delta support, so a delta
                // submit in the mailbox is unexpected — but it decodes
                // fine, so serve it rather than silently dropping it.
                Frame::SubmitDelta { job, update, prio } => (job, Some(update), prio),
                _ => continue,
            };
            let floor = Arc::clone(
                floors.entry(client.clone()).or_insert_with(|| Arc::new(AtomicU64::new(0))),
            );
            let reply_name = format!(
                "res_{client}_{:08}.frame",
                reply_seq.fetch_add(1, Ordering::Relaxed)
            );
            match FactorServer::resolve(registry, &job.strategy_key) {
                Ok(strategy) => {
                    queue.push(
                        ServerJob {
                            wire: job,
                            update,
                            strategy,
                            reply: ReplySink::Dir { dir: results.clone(), name: reply_name },
                            floor,
                            received_ns: clock::now_ns(),
                        },
                        prio,
                    );
                }
                Err(msg) => send_reply(
                    &ReplySink::Dir { dir: results.clone(), name: reply_name },
                    &JobResult {
                        block: job.block,
                        side: job.side,
                        version: job.version,
                        wait_s: 0.0,
                        run_s: 0.0,
                        outcome: Err(msg),
                    },
                ),
            }
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Pcg64;
    use crate::pipeline::transport::{DirTransport, TcpTransport, Transport};
    use crate::rnla::{decomposition, Decomposition, SketchConfig};

    fn spec(version: u64, d: usize) -> (JobSpec, crate::rnla::LowRankFactor) {
        let mut mrng = Pcg64::with_stream(21, 5);
        let matrix = Arc::new(mrng.gaussian_matrix(d, d));
        let strategy: Arc<dyn Decomposition> = Arc::new(decomposition::Rsvd);
        let cfg = SketchConfig::new(4, 2, 1);
        let rng = Pcg64::with_stream(33, 0x77);
        let mut expect_rng = rng.clone();
        let expected = strategy.decompose(&matrix, &cfg, &mut expect_rng);
        (
            JobSpec {
                block: 1,
                side: 0,
                version,
                strategy,
                cfg,
                matrix,
                rng,
                enqueued_ns: clock::now_ns(),
                flops_pred: 2.0,
                span: obs::SpanCtx::ROOT,
                update: None,
            },
            expected,
        )
    }

    #[test]
    fn tcp_roundtrip_is_bitwise_and_heartbeat_answers() {
        let mut server = FactorServer::spawn_tcp(
            "127.0.0.1:0",
            2,
            DecompositionRegistry::with_defaults(),
        )
        .unwrap();
        let addr = server.addr().unwrap().to_string();
        let mut t = TcpTransport::new(&addr, 1000, 5000, 3);
        t.heartbeat().unwrap();
        assert!(t.supports_delta(), "the live server banner advertises protocol 2");
        let (spec, expected) = spec(7, 8);
        t.set_floor(7);
        t.submit(&spec, 1.0).unwrap();
        let res = t.recv().unwrap();
        assert_eq!((res.block, res.side, res.version), (1, 0, 7));
        let got = res.outcome.unwrap();
        assert_eq!(got.u.as_slice(), expected.u.as_slice(), "remote must be bitwise local");
        assert_eq!(got.d, expected.d);
        // Unknown strategy key degrades to an Err result, not a hang.
        let mut bogus = spec.clone();
        struct Alien;
        impl Decomposition for Alien {
            fn key(&self) -> &str {
                "alien"
            }
            fn decompose(
                &self,
                m: &crate::linalg::Matrix,
                cfg: &SketchConfig,
                rng: &mut Pcg64,
            ) -> crate::rnla::LowRankFactor {
                decomposition::Rsvd.decompose(m, cfg, rng)
            }
            fn meta(&self, dim: usize, cfg: &SketchConfig) -> crate::rnla::DecompMeta {
                decomposition::Rsvd.meta(dim, cfg)
            }
        }
        bogus.strategy = Arc::new(Alien);
        t.submit(&bogus, 1.0).unwrap();
        let res = t.recv().unwrap();
        assert!(res.outcome.unwrap_err().contains("unknown strategy 'alien'"));
        server.shutdown();
        drop(server); // second shutdown via drop must be a no-op
    }

    #[test]
    fn tcp_delta_submit_runs_the_update_path_bitwise() {
        use crate::rnla::{FactorDelta, LowRankFactor, UpdateOutcome};
        let mut server = FactorServer::spawn_tcp(
            "127.0.0.1:0",
            1,
            DecompositionRegistry::with_defaults(),
        )
        .unwrap();
        let addr = server.addr().unwrap().to_string();
        let mut t = TcpTransport::new(&addr, 1000, 5000, 3);
        assert!(t.supports_delta());
        let d = 8;
        let mut rng = Pcg64::with_stream(71, 4);
        let basis = crate::linalg::qr::orthonormalize(&rng.gaussian_matrix(d, 3));
        let prev = Arc::new(LowRankFactor::new(basis, vec![4.0, 2.0, 1.0]));
        let delta = Arc::new(FactorDelta::new(rng.gaussian_matrix(d, 2), 0.9));
        let strategy: Arc<dyn Decomposition> = Arc::new(decomposition::Rsvd);
        let cfg = SketchConfig::new(4, 2, 1);
        let job_rng = Pcg64::with_stream(5, 6);
        let expected = match strategy.update(&prev, &delta, &cfg, &mut job_rng.clone()) {
            UpdateOutcome::Updated(f) => f,
            UpdateOutcome::Declined => panic!("rsvd must accept updates"),
        };
        let spec = JobSpec {
            block: 2,
            side: 1,
            version: 5,
            strategy,
            cfg,
            // The delta frame carries no snapshot; the matrix is never
            // encoded, mirroring what the pipeline client sends.
            matrix: Arc::new(crate::linalg::Matrix::zeros(0, 0)),
            rng: job_rng,
            enqueued_ns: clock::now_ns(),
            flops_pred: 1.0,
            span: obs::SpanCtx::ROOT,
            update: Some(UpdateJob { prev, delta }),
        };
        t.submit(&spec, 1.0).unwrap();
        let res = t.recv().unwrap();
        assert_eq!((res.block, res.side, res.version), (2, 1, 5));
        let got = res.outcome.unwrap();
        assert_eq!(got.u.as_slice(), expected.u.as_slice(), "remote update must be bitwise");
        assert_eq!(got.d, expected.d);
        server.shutdown();
    }

    #[test]
    fn dir_roundtrip_is_bitwise() {
        let root = std::env::temp_dir()
            .join(format!("rkfac_srv_dir_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let server =
            FactorServer::spawn_dir(&root, 2, DecompositionRegistry::with_defaults()).unwrap();
        assert!(server.addr().is_none());
        let mut t = DirTransport::new(root.to_str().unwrap(), 5000);
        t.heartbeat().unwrap();
        let (spec, expected) = spec(3, 7);
        t.submit(&spec, 0.5).unwrap();
        let res = t.recv().unwrap();
        assert_eq!(res.version, 3);
        let got = res.outcome.unwrap();
        assert_eq!(got.u.as_slice(), expected.u.as_slice());
        assert_eq!(got.d, expected.d);
        drop(server);
        let _ = std::fs::remove_dir_all(&root);
    }
}
