//! Shared-filesystem transport: a mailbox of [`super::wire`] frames for
//! clusters where trainers and the factor server share a filesystem but
//! cannot open ports.
//!
//! Layout under the endpoint directory:
//!
//! ```text
//! jobs/     job_<client>_<seq>.frame    submits (one Submit frame each)
//!           floor_<client>.frame        latest SetFloor per client
//!           hb_<client>_<seq>.frame     heartbeat requests
//! claimed/                              jobs the server claimed (rename)
//! results/  res_<client>_<seq>.frame    Result / HeartbeatAck frames
//! ```
//!
//! Every file is written atomically (temp file + rename in the same
//! directory), so a reader never sees a half-written frame — and even if a
//! filesystem tears one anyway, the per-frame CRC catches it and the client
//! falls back inline. With no server running, `recv` polls until
//! `io_timeout_ms` and returns [`TransportError::Timeout`] — degraded, not
//! dead.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::obs::{self, clock};

use super::wire::{read_frame, write_frame, write_submit, Frame};
use super::{JobResult, JobSpec, Transport, TransportError};

/// Process-wide client counter: several pipelines in one process (tests,
/// sweeps) must not share a mailbox identity.
static CLIENT_SEQ: AtomicU64 = AtomicU64::new(0);

/// Filesystem mailbox client.
pub struct DirTransport {
    root: PathBuf,
    client: String,
    io_timeout: Duration,
    seq: u64,
    floor: u64,
    ready: bool,
}

/// Atomic single-file publish: write to a temp name in the target
/// directory, then rename into place.
pub(crate) fn publish_file(dir: &Path, name: &str, bytes: &[u8]) -> io::Result<()> {
    let tmp = dir.join(format!(".tmp_{name}"));
    fs::write(&tmp, bytes)?;
    fs::rename(&tmp, dir.join(name))
}

impl DirTransport {
    pub fn new(root: &str, io_timeout_ms: u64) -> DirTransport {
        DirTransport {
            root: PathBuf::from(root),
            client: format!(
                "{}-{}",
                std::process::id(),
                CLIENT_SEQ.fetch_add(1, Ordering::Relaxed)
            ),
            io_timeout: Duration::from_millis(io_timeout_ms.max(1)),
            seq: 0,
            floor: 0,
            ready: false,
        }
    }

    fn jobs_dir(&self) -> PathBuf {
        self.root.join("jobs")
    }

    fn results_dir(&self) -> PathBuf {
        self.root.join("results")
    }

    /// Lazily create the mailbox layout (any party may create it first).
    fn ensure_dirs(&mut self) -> Result<(), TransportError> {
        if self.ready {
            return Ok(());
        }
        for d in ["jobs", "claimed", "results"] {
            fs::create_dir_all(self.root.join(d)).map_err(|e| {
                TransportError::Disconnected(format!(
                    "cannot create mailbox '{}/{d}': {e}",
                    self.root.display()
                ))
            })?;
        }
        self.ready = true;
        Ok(())
    }

    fn publish(&mut self, name: &str, bytes: &[u8]) -> Result<(), TransportError> {
        self.ensure_dirs()?;
        publish_file(&self.jobs_dir(), name, bytes).map_err(|e| {
            TransportError::Disconnected(format!("mailbox write '{name}': {e}"))
        })?;
        obs::counter_add("transport.frames_tx", 1);
        obs::counter_add("transport.bytes_tx", bytes.len() as u64);
        Ok(())
    }

    /// Scan `results/` for this client's oldest frame; decode-and-delete.
    /// `Ok(None)` means nothing is waiting right now.
    fn poll_results(&mut self) -> Result<Option<JobResult>, TransportError> {
        self.ensure_dirs()?;
        let prefix = format!("res_{}_", self.client);
        let mut names: Vec<String> = match fs::read_dir(self.results_dir()) {
            Ok(rd) => rd
                .filter_map(|e| e.ok())
                .filter_map(|e| e.file_name().into_string().ok())
                .filter(|n| n.starts_with(&prefix))
                .collect(),
            Err(e) => {
                return Err(TransportError::Disconnected(format!("mailbox scan: {e}")));
            }
        };
        names.sort();
        for name in names {
            let path = self.results_dir().join(name);
            let bytes = match fs::read(&path) {
                Ok(b) => b,
                // Lost a race (another scan deleted it) — skip.
                Err(_) => continue,
            };
            let _ = fs::remove_file(&path);
            match read_frame(&mut &bytes[..]) {
                Ok((frame, n)) => {
                    obs::counter_add("transport.frames_rx", 1);
                    obs::counter_add("transport.bytes_rx", n as u64);
                    match frame {
                        Frame::Result { result } => return Ok(Some(result)),
                        // Heartbeat acks and other control frames are
                        // absorbed; keep scanning for a result.
                        _ => continue,
                    }
                }
                Err(e) => {
                    return Err(TransportError::Corrupt(format!(
                        "result frame in mailbox: {e}"
                    )));
                }
            }
        }
        Ok(None)
    }

    /// Poll for an ack file produced in answer to a heartbeat.
    fn await_ack(&mut self, nonce: u64, sent_ns: u64) -> Result<(), TransportError> {
        let deadline = Instant::now() + self.io_timeout;
        loop {
            let prefix = format!("res_{}_", self.client);
            let names: Vec<String> = fs::read_dir(self.results_dir())
                .map_err(|e| TransportError::Disconnected(format!("mailbox scan: {e}")))?
                .filter_map(|e| e.ok())
                .filter_map(|e| e.file_name().into_string().ok())
                .filter(|n| n.starts_with(&prefix))
                .collect();
            for name in names {
                let path = self.results_dir().join(&name);
                let bytes = match fs::read(&path) {
                    Ok(b) => b,
                    Err(_) => continue,
                };
                if let Ok((Frame::HeartbeatAck { nonce: n }, _)) = read_frame(&mut &bytes[..]) {
                    if n == nonce {
                        let _ = fs::remove_file(&path);
                        obs::observe(
                            "transport.rtt_s",
                            clock::secs_between(sent_ns, clock::now_ns()),
                        );
                        return Ok(());
                    }
                }
            }
            if Instant::now() >= deadline {
                return Err(TransportError::Timeout(format!(
                    "no heartbeat ack in '{}' within {:?}",
                    self.root.display(),
                    self.io_timeout
                )));
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

impl Transport for DirTransport {
    fn kind(&self) -> &'static str {
        "dir"
    }

    // NOTE: `supports_delta` stays at the trait default (`false`): the
    // mailbox has no handshake channel to learn the server's protocol
    // version, so delta frames are never written to it — the pipeline
    // posts full-snapshot jobs and online refreshes run their updates on
    // the trainer side instead.

    fn submit(&mut self, spec: &JobSpec, prio: f64) -> Result<(), TransportError> {
        self.ensure_dirs()?;
        let mut bytes = Vec::new();
        write_submit(&mut bytes, spec, prio)
            .map_err(|e| TransportError::Disconnected(format!("encode submit: {e}")))?;
        self.seq += 1;
        let name = format!("job_{}_{:08}.frame", self.client, self.seq);
        self.publish(&name, &bytes)
    }

    fn set_floor(&mut self, floor: u64) {
        self.floor = floor;
        let mut bytes = Vec::new();
        if write_frame(&mut bytes, &Frame::SetFloor { floor }).is_ok() {
            // Best-effort, like the TCP floor update: losing it only wastes
            // server work on stale jobs.
            let name = format!("floor_{}.frame", self.client);
            let _ = self.publish(&name, &bytes);
        }
    }

    fn try_recv(&mut self) -> Result<Option<JobResult>, TransportError> {
        self.poll_results()
    }

    fn recv(&mut self) -> Result<JobResult, TransportError> {
        let deadline = Instant::now() + self.io_timeout;
        loop {
            if let Some(res) = self.poll_results()? {
                return Ok(res);
            }
            if Instant::now() >= deadline {
                return Err(TransportError::Timeout(format!(
                    "no result in '{}' within {:?} (factor server down?)",
                    self.root.display(),
                    self.io_timeout
                )));
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    fn heartbeat(&mut self) -> Result<(), TransportError> {
        self.ensure_dirs()?;
        self.seq += 1;
        let nonce = self.seq;
        let mut bytes = Vec::new();
        write_frame(&mut bytes, &Frame::Heartbeat { nonce })
            .map_err(|e| TransportError::Disconnected(format!("encode heartbeat: {e}")))?;
        let sent_ns = clock::now_ns();
        let name = format!("hb_{}_{:08}.frame", self.client, nonce);
        self.publish(&name, &bytes)?;
        self.await_ack(nonce, sent_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Pcg64;
    use crate::rnla::{decomposition, SketchConfig};
    use std::sync::Arc;

    fn tmp_root(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("rkfac_dirt_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn submit_lands_in_jobs_and_recv_times_out_without_server() {
        let root = tmp_root("noserver");
        let mut t = DirTransport::new(root.to_str().unwrap(), 30);
        assert_eq!(t.kind(), "dir");
        let mut rng = Pcg64::with_stream(1, 2);
        let spec = JobSpec {
            block: 0,
            side: 1,
            version: 4,
            strategy: Arc::new(decomposition::Rsvd),
            cfg: SketchConfig::new(3, 2, 1),
            matrix: Arc::new(rng.gaussian_matrix(5, 5)),
            rng: Pcg64::with_stream(8, 8),
            enqueued_ns: 0,
            flops_pred: 1.0,
            span: obs::SpanCtx::ROOT,
            update: None,
        };
        t.submit(&spec, 1.5).unwrap();
        t.set_floor(4);
        let jobs: Vec<_> = fs::read_dir(root.join("jobs")).unwrap().collect();
        assert_eq!(jobs.len(), 2, "one job file + one floor file");
        // No server: recv must time out (degraded), not hang or error hard.
        assert!(matches!(t.recv(), Err(TransportError::Timeout(_))));
        assert!(t.try_recv().unwrap().is_none());
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn corrupt_result_file_reports_corrupt() {
        let root = tmp_root("corrupt");
        let mut t = DirTransport::new(root.to_str().unwrap(), 30);
        t.submit(
            &JobSpec {
                block: 0,
                side: 0,
                version: 0,
                strategy: Arc::new(decomposition::Rsvd),
                cfg: SketchConfig::new(2, 1, 0),
                matrix: Arc::new(Pcg64::with_stream(3, 3).gaussian_matrix(4, 4)),
                rng: Pcg64::with_stream(3, 4),
                enqueued_ns: 0,
                flops_pred: 1.0,
                span: obs::SpanCtx::ROOT,
                update: None,
            },
            0.0,
        )
        .unwrap();
        // Forge a garbage result file addressed to this client.
        let name = format!("res_{}_00000001.frame", t.client);
        publish_file(&root.join("results"), &name, b"not a frame at all").unwrap();
        assert!(matches!(t.try_recv(), Err(TransportError::Corrupt(_))));
        // The poisoned file was consumed; the mailbox recovers.
        assert!(t.try_recv().unwrap().is_none());
        let _ = fs::remove_dir_all(&root);
    }
}
