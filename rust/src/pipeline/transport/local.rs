//! The in-process transport: the original PR-1/PR-3 worker pool (priority
//! [`JobQueue`] + threads) behind the [`Transport`] trait.
//!
//! This is a pure refactor of the pre-transport pipeline internals — worker
//! thread names (`factor-refresh-{w}`), the floor-drop-at-pop rule, and the
//! `pipeline.job.wait` / `pipeline.job.run` span emissions are all
//! preserved bit-for-bit, which is what lets the existing pipeline contract
//! suite (including the worker-panic golden) keep passing unchanged.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::obs::{self, clock};
use crate::pipeline::sched::JobQueue;
use crate::util::json::Json;

use super::{run_spec, JobResult, JobSpec, Transport, TransportError};

/// In-process worker pool. Owns its threads; dropping the transport closes
/// the queue and joins them (the old `Drop for FactorPipeline`).
pub struct LocalTransport {
    queue: Arc<JobQueue<JobSpec>>,
    floor: Arc<AtomicU64>,
    done_rx: Receiver<JobResult>,
    handles: Vec<JoinHandle<()>>,
}

fn worker_loop(queue: Arc<JobQueue<JobSpec>>, floor: Arc<AtomicU64>, done: Sender<JobResult>) {
    while let Some(spec) = queue.pop() {
        // A job whose version already fell below the current staleness
        // floor can never be installed: the wait loop only exits on
        // versions ≥ required, and the refresh that raised the floor
        // re-enqueued a newer job for this slot. Skip the decomposition —
        // the dominant cost — instead of computing a result that monotone
        // publication would discard. Relaxed is enough: a stale read only
        // means doing work the publish path drops anyway, and at
        // `max_stale_steps = 0` every live job has version == floor, so
        // the bitwise contract is untouched.
        if spec.version < floor.load(Ordering::Relaxed) {
            continue;
        }
        let pop_ns = clock::now_ns();
        let wait_s = clock::secs_between(spec.enqueued_ns, pop_ns);
        obs::emit_manual(
            "pipeline.job.wait",
            spec.enqueued_ns,
            pop_ns,
            spec.span,
            vec![
                ("block".to_string(), Json::from(spec.block)),
                ("side".to_string(), Json::from(spec.side)),
            ],
        );
        let result = {
            // Real (not manual) span: it sits on this worker's span stack,
            // so the linalg/rnla kernels inside the decomposition nest
            // under it — the sketch/QR/small-EVD breakdown per job.
            let _sp = obs::span_with_parent("pipeline.job.run", spec.span)
                .arg("block", spec.block)
                .arg("side", spec.side)
                .arg("strategy", spec.strategy.key())
                .arg("rank", spec.cfg.rank)
                .arg("flops_pred", spec.flops_pred)
                .arg("version", spec.version)
                .arg("op", if spec.update.is_some() { "update" } else { "decompose" })
                .with_backend();
            run_spec(&spec)
        };
        let run_s = clock::secs_between(pop_ns, clock::now_ns());
        let out = JobResult {
            block: spec.block,
            side: spec.side,
            version: spec.version,
            wait_s,
            run_s,
            outcome: result,
        };
        if done.send(out).is_err() {
            break;
        }
    }
}

impl LocalTransport {
    /// Spawn `n_workers` worker threads draining a fresh priority queue.
    pub fn spawn(n_workers: usize) -> LocalTransport {
        let queue = Arc::new(JobQueue::new());
        let floor = Arc::new(AtomicU64::new(0));
        let (done_tx, done_rx) = channel::<JobResult>();
        let mut handles = Vec::with_capacity(n_workers.max(1));
        for w in 0..n_workers.max(1) {
            let jobs = Arc::clone(&queue);
            let fl = Arc::clone(&floor);
            let done = done_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("factor-refresh-{w}"))
                .spawn(move || worker_loop(jobs, fl, done))
                .expect("spawning factor-refresh worker");
            handles.push(handle);
        }
        LocalTransport { queue, floor, done_rx, handles }
    }
}

impl Transport for LocalTransport {
    fn kind(&self) -> &'static str {
        "local"
    }

    fn submit(&mut self, spec: &JobSpec, prio: f64) -> Result<(), TransportError> {
        if self.queue.push(spec.clone(), prio) {
            Ok(())
        } else {
            Err(TransportError::Disconnected("job queue closed".into()))
        }
    }

    fn set_floor(&mut self, floor: u64) {
        self.floor.store(floor, Ordering::Relaxed);
    }

    fn try_recv(&mut self) -> Result<Option<JobResult>, TransportError> {
        match self.done_rx.try_recv() {
            Ok(res) => Ok(Some(res)),
            Err(TryRecvError::Empty) => Ok(None),
            // All workers gone: nothing buffered, nothing will arrive. The
            // pipeline treats an empty drain as "move on" and discovers the
            // dead pool on the blocking `recv` below, exactly like the
            // pre-transport code discovered it on the channel.
            Err(TryRecvError::Disconnected) => Ok(None),
        }
    }

    fn recv(&mut self) -> Result<JobResult, TransportError> {
        self.done_rx
            .recv()
            .map_err(|_| TransportError::Disconnected("worker pool disconnected".into()))
    }

    fn heartbeat(&mut self) -> Result<(), TransportError> {
        // The pool lives in this process; liveness is trivially true (a
        // dead pool surfaces as Disconnected on recv and recovers inline).
        Ok(())
    }

    fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    fn supports_delta(&mut self) -> bool {
        // Workers share this process and dispatch through the same
        // `run_spec`; delta jobs need no wire encoding at all.
        true
    }
}

impl Drop for LocalTransport {
    fn drop(&mut self) {
        // Closing the queue ends the worker loops (after draining what is
        // already queued); join to avoid leaking threads past the
        // optimizer's lifetime.
        self.queue.close();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Pcg64;
    use crate::rnla::{decomposition, Decomposition, SketchConfig};

    fn spec(block: usize, side: usize, version: u64, d: usize) -> JobSpec {
        let mut rng = Pcg64::with_stream(5, 77);
        JobSpec {
            block,
            side,
            version,
            strategy: Arc::new(decomposition::Rsvd),
            cfg: SketchConfig::new(3, 2, 1),
            matrix: Arc::new(rng.gaussian_matrix(d, d)),
            rng: Pcg64::with_stream(9, 1),
            enqueued_ns: clock::now_ns(),
            flops_pred: 1.0,
            span: obs::SpanCtx::ROOT,
            update: None,
        }
    }

    #[test]
    fn local_pool_always_supports_delta_jobs() {
        let mut t = LocalTransport::spawn(1);
        assert!(t.supports_delta());
    }

    #[test]
    fn submit_recv_roundtrip_and_clean_drop() {
        let mut t = LocalTransport::spawn(2);
        assert_eq!(t.kind(), "local");
        t.heartbeat().unwrap();
        t.submit(&spec(0, 0, 0, 6), 0.0).unwrap();
        t.submit(&spec(0, 1, 0, 5), 0.0).unwrap();
        let mut got = 0;
        while got < 2 {
            let res = t.recv().unwrap();
            assert!(res.outcome.is_ok());
            assert_eq!(res.version, 0);
            got += 1;
        }
        assert_eq!(t.try_recv().unwrap().map(|_| ()), None);
        drop(t); // must join workers without hanging
    }

    #[test]
    fn floor_drops_stale_queued_jobs() {
        let mut t = LocalTransport::spawn(1);
        // Raise the floor before submitting a stale job: the worker must
        // skip it (no result), then run the live one.
        t.set_floor(10);
        t.submit(&spec(0, 0, 3, 6), 0.0).unwrap();
        t.submit(&spec(0, 1, 10, 6), 0.0).unwrap();
        let res = t.recv().unwrap();
        assert_eq!(res.version, 10, "stale job must be dropped at pop");
        assert!(t.try_recv().unwrap().is_none());
    }
}
