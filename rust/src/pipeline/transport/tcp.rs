//! TCP client transport for a remote [`super::FactorServer`].
//!
//! Frames are the checksummed [`super::wire`] format. The client connects
//! lazily (first submit/heartbeat), bounded by `connect_timeout_ms` per
//! attempt with up to `max_retries` attempts under exponential backoff
//! (50 ms doubling, capped at 1 s). A dedicated reader thread turns the
//! socket into a channel of decoded frames so `try_recv` never blocks on
//! I/O. Any error — connect failure, timeout, checksum mismatch, peer gone
//! — surfaces as a [`TransportError`] and the pipeline falls back to inline
//! decomposition; the connection is re-attempted on the next submit.

use std::collections::{HashMap, VecDeque};
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::obs::{self, clock};

use super::wire::{read_frame, write_frame, write_submit, Frame, WireError};
use super::{JobResult, JobSpec, Transport, TransportError};

struct Conn {
    stream: TcpStream,
    rx: Receiver<Result<Frame, WireError>>,
    reader: Option<JoinHandle<()>>,
    /// Whether this connection's server advertised protocol v2 (delta
    /// Submit frames) in its `HelloAck` banner. Decided synchronously at
    /// connect time — never by frame-arrival timing — so whether a job
    /// travels as a delta or a full snapshot is deterministic.
    peer_delta: bool,
}

/// `HelloAck` banners are `"rkfac-factor-server"` (pre-v2) or
/// `"rkfac-factor-server/<version>"`; delta Submit frames need v2+.
fn banner_supports_delta(server: &str) -> bool {
    server.rsplit_once('/').and_then(|(_, v)| v.parse::<u32>().ok()).map_or(false, |v| v >= 2)
}

/// TCP client end of the factor service.
pub struct TcpTransport {
    endpoint: String,
    connect_timeout: Duration,
    io_timeout: Duration,
    max_retries: u32,
    floor: u64,
    conn: Option<Conn>,
    /// Becomes true after the first successful connect, so the
    /// `transport.reconnects` counter measures actual re-establishments,
    /// not the initial dial.
    ever_connected: bool,
    /// Results drained while waiting for something else (heartbeat acks).
    pending: VecDeque<JobResult>,
    /// Submit timestamps per (block, side, version) for RTT observation.
    sent_at: HashMap<(usize, usize, u64), u64>,
    nonce: u64,
}

impl TcpTransport {
    pub fn new(
        endpoint: &str,
        connect_timeout_ms: u64,
        io_timeout_ms: u64,
        max_retries: u32,
    ) -> TcpTransport {
        TcpTransport {
            endpoint: endpoint.to_string(),
            connect_timeout: Duration::from_millis(connect_timeout_ms.max(1)),
            io_timeout: Duration::from_millis(io_timeout_ms.max(1)),
            max_retries,
            floor: 0,
            conn: None,
            ever_connected: false,
            pending: VecDeque::new(),
            sent_at: HashMap::new(),
            nonce: 0,
        }
    }

    fn connect_once(&self) -> Result<TcpStream, String> {
        let addr = self
            .endpoint
            .to_socket_addrs()
            .map_err(|e| format!("cannot resolve '{}': {e}", self.endpoint))?
            .next()
            .ok_or_else(|| format!("'{}' resolves to no address", self.endpoint))?;
        let stream = TcpStream::connect_timeout(&addr, self.connect_timeout)
            .map_err(|e| format!("connect to {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        Ok(stream)
    }

    /// Establish the connection if absent: bounded retries with exponential
    /// backoff, then Hello + reader-thread spawn + floor re-publication.
    fn ensure_connected(&mut self) -> Result<(), TransportError> {
        if self.conn.is_some() {
            return Ok(());
        }
        let attempts = self.max_retries.max(1);
        let mut backoff = Duration::from_millis(50);
        let mut last_err = String::new();
        for attempt in 0..attempts {
            if attempt > 0 {
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_secs(1));
            }
            match self.connect_once() {
                Ok(mut stream) => {
                    if let Err(e) =
                        write_frame(&mut stream, &Frame::Hello { client: "rkfac-trainer".into() })
                    {
                        last_err = format!("hello: {e}");
                        continue;
                    }
                    let reader_stream = match stream.try_clone() {
                        Ok(s) => s,
                        Err(e) => {
                            last_err = format!("clone stream: {e}");
                            continue;
                        }
                    };
                    let (tx, rx) = channel();
                    let reader = std::thread::Builder::new()
                        .name("factor-tcp-reader".into())
                        .spawn(move || {
                            let mut s = reader_stream;
                            loop {
                                match read_frame(&mut s) {
                                    Ok((frame, n)) => {
                                        obs::counter_add("transport.frames_rx", 1);
                                        obs::counter_add("transport.bytes_rx", n as u64);
                                        if tx.send(Ok(frame)).is_err() {
                                            break;
                                        }
                                    }
                                    Err(e) => {
                                        let _ = tx.send(Err(e));
                                        break;
                                    }
                                }
                            }
                        })
                        .expect("spawning tcp reader thread");
                    if self.ever_connected {
                        obs::counter_add("transport.reconnects", 1);
                    }
                    self.ever_connected = true;
                    self.conn =
                        Some(Conn { stream, rx, reader: Some(reader), peer_delta: false });
                    // A fresh connection knows nothing about our staleness
                    // floor; re-publish it so the server drops stale work.
                    if self.floor > 0 {
                        self.send(&Frame::SetFloor { floor: self.floor });
                    }
                    // Wait (bounded) for the server's HelloAck so protocol
                    // capabilities are settled before the first submit.
                    self.handshake();
                    if self.conn.is_some() {
                        return Ok(());
                    }
                    last_err = "connection lost during handshake".to_string();
                    continue;
                }
                Err(e) => last_err = e,
            }
        }
        Err(TransportError::Disconnected(format!(
            "factor server '{}' unreachable after {attempts} attempts ({last_err})",
            self.endpoint
        )))
    }

    /// Synchronous capability negotiation: consume frames until the
    /// server's `HelloAck` arrives (or the io timeout expires), recording
    /// whether its banner advertises delta-Submit support. A server that
    /// never answers is treated as pre-v2 — plain submits may still work.
    fn handshake(&mut self) {
        let deadline = Instant::now() + self.io_timeout;
        loop {
            let Some(conn) = self.conn.as_ref() else { return };
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return;
            }
            match conn.rx.recv_timeout(remaining) {
                Ok(Ok(Frame::HelloAck { server })) => {
                    let v2 = banner_supports_delta(&server);
                    if let Some(c) = self.conn.as_mut() {
                        c.peer_delta = v2;
                    }
                    return;
                }
                Ok(Ok(frame)) => {
                    if let Some(res) = self.absorb(frame) {
                        self.pending.push_back(res);
                    }
                }
                Ok(Err(_)) | Err(RecvTimeoutError::Disconnected) => {
                    self.drop_conn();
                    return;
                }
                Err(RecvTimeoutError::Timeout) => return,
            }
        }
    }

    /// Best-effort frame write on the live connection; drops the connection
    /// on error and reports whether the write succeeded.
    fn send(&mut self, frame: &Frame) -> bool {
        let ok = match self.conn.as_mut() {
            Some(c) => match write_frame(&mut c.stream, frame) {
                Ok(n) => {
                    obs::counter_add("transport.frames_tx", 1);
                    obs::counter_add("transport.bytes_tx", n as u64);
                    true
                }
                Err(_) => false,
            },
            None => false,
        };
        if !ok {
            self.drop_conn();
        }
        ok
    }

    fn drop_conn(&mut self) {
        if let Some(mut c) = self.conn.take() {
            let _ = c.stream.shutdown(Shutdown::Both);
            if let Some(h) = c.reader.take() {
                let _ = h.join();
            }
        }
    }

    /// Route one decoded frame: results are returned (with RTT observation),
    /// control frames are absorbed.
    fn absorb(&mut self, frame: Frame) -> Option<JobResult> {
        match frame {
            Frame::Result { result } => {
                let key = (result.block, result.side, result.version);
                if let Some(sent_ns) = self.sent_at.remove(&key) {
                    obs::observe("transport.rtt_s", clock::secs_between(sent_ns, clock::now_ns()));
                }
                Some(result)
            }
            // Banner / ack frames carry no payload the pipeline needs.
            _ => None,
        }
    }

    fn map_wire_err(e: WireError) -> TransportError {
        match e {
            WireError::Io(io) => TransportError::Disconnected(format!("peer: {io}")),
            WireError::Corrupt(m) => TransportError::Corrupt(m),
        }
    }
}

impl Transport for TcpTransport {
    fn kind(&self) -> &'static str {
        "tcp"
    }

    fn supports_delta(&mut self) -> bool {
        // Connect (and negotiate) if needed; an unreachable server means no
        // delta path — the pipeline's full-snapshot jobs degrade inline.
        if self.ensure_connected().is_err() {
            return false;
        }
        self.conn.as_ref().map_or(false, |c| c.peer_delta)
    }

    fn submit(&mut self, spec: &JobSpec, prio: f64) -> Result<(), TransportError> {
        self.ensure_connected()?;
        let conn = self.conn.as_mut().expect("ensure_connected leaves a live conn");
        match write_submit(&mut conn.stream, spec, prio) {
            Ok(n) => {
                obs::counter_add("transport.frames_tx", 1);
                obs::counter_add("transport.bytes_tx", n as u64);
                self.sent_at.insert((spec.block, spec.side, spec.version), clock::now_ns());
                Ok(())
            }
            Err(e) => {
                self.drop_conn();
                Err(TransportError::Disconnected(format!("submit write: {e}")))
            }
        }
    }

    fn set_floor(&mut self, floor: u64) {
        self.floor = floor;
        if self.conn.is_some() {
            // Best-effort: a lost floor update only costs the server wasted
            // work on stale jobs; the client-side publish path still drops
            // their results.
            self.send(&Frame::SetFloor { floor });
        }
    }

    fn try_recv(&mut self) -> Result<Option<JobResult>, TransportError> {
        if let Some(res) = self.pending.pop_front() {
            return Ok(Some(res));
        }
        loop {
            if self.conn.is_none() {
                return Ok(None);
            }
            let recv = self.conn.as_ref().expect("checked above").rx.try_recv();
            match recv {
                Ok(Ok(frame)) => {
                    if let Some(res) = self.absorb(frame) {
                        return Ok(Some(res));
                    }
                }
                Ok(Err(werr)) => {
                    self.drop_conn();
                    return Err(Self::map_wire_err(werr));
                }
                Err(std::sync::mpsc::TryRecvError::Empty) => return Ok(None),
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    self.drop_conn();
                    return Ok(None);
                }
            }
        }
    }

    fn recv(&mut self) -> Result<JobResult, TransportError> {
        if let Some(res) = self.pending.pop_front() {
            return Ok(res);
        }
        // No connection ⇒ no in-flight jobs can ever answer; waiting out
        // the io timeout would just stall the fallback.
        if self.conn.is_none() {
            return Err(TransportError::Disconnected(format!(
                "factor server '{}' is not connected",
                self.endpoint
            )));
        }
        let deadline = Instant::now() + self.io_timeout;
        loop {
            if self.conn.is_none() {
                return Err(TransportError::Disconnected("connection lost mid-wait".into()));
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Err(TransportError::Timeout(format!(
                    "no result from '{}' within {:?}",
                    self.endpoint, self.io_timeout
                )));
            }
            let recv = self.conn.as_ref().expect("checked above").rx.recv_timeout(remaining);
            match recv {
                Ok(Ok(frame)) => {
                    if let Some(res) = self.absorb(frame) {
                        return Ok(res);
                    }
                }
                Ok(Err(werr)) => {
                    self.drop_conn();
                    return Err(Self::map_wire_err(werr));
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Err(TransportError::Timeout(format!(
                        "no result from '{}' within {:?}",
                        self.endpoint, self.io_timeout
                    )));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    self.drop_conn();
                    return Err(TransportError::Disconnected("reader thread exited".into()));
                }
            }
        }
    }

    fn heartbeat(&mut self) -> Result<(), TransportError> {
        self.ensure_connected()?;
        self.nonce += 1;
        let nonce = self.nonce;
        let sent_ns = clock::now_ns();
        if !self.send(&Frame::Heartbeat { nonce }) {
            return Err(TransportError::Disconnected("heartbeat write failed".into()));
        }
        let deadline = Instant::now() + self.io_timeout;
        loop {
            if self.conn.is_none() {
                return Err(TransportError::Disconnected("connection lost mid-heartbeat".into()));
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Err(TransportError::Timeout(format!(
                    "heartbeat to '{}' unanswered within {:?}",
                    self.endpoint, self.io_timeout
                )));
            }
            let recv = self.conn.as_ref().expect("checked above").rx.recv_timeout(remaining);
            match recv {
                Ok(Ok(Frame::HeartbeatAck { nonce: n })) if n == nonce => {
                    obs::observe("transport.rtt_s", clock::secs_between(sent_ns, clock::now_ns()));
                    return Ok(());
                }
                Ok(Ok(frame)) => {
                    // Results racing the ack are buffered, not dropped.
                    if let Some(res) = self.absorb(frame) {
                        self.pending.push_back(res);
                    }
                }
                Ok(Err(werr)) => {
                    self.drop_conn();
                    return Err(Self::map_wire_err(werr));
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Err(TransportError::Timeout(format!(
                        "heartbeat to '{}' unanswered within {:?}",
                        self.endpoint, self.io_timeout
                    )));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    self.drop_conn();
                    return Err(TransportError::Disconnected("reader thread exited".into()));
                }
            }
        }
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.drop_conn();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unreachable_endpoint_fails_bounded_not_forever() {
        // Loopback port 1 has no listener: connect refuses fast (or hits
        // the 50 ms connect timeout); with 2 retries the whole dial must
        // stay bounded and report Disconnected.
        let mut t = TcpTransport::new("127.0.0.1:1", 50, 50, 2);
        let start = Instant::now();
        match t.heartbeat() {
            Err(TransportError::Disconnected(m)) => assert!(m.contains("unreachable")),
            other => panic!("expected Disconnected, got {other:?}"),
        }
        assert!(start.elapsed() < Duration::from_secs(5));
        // recv on a never-connected transport must not stall on io_timeout.
        let start = Instant::now();
        assert!(matches!(t.recv(), Err(TransportError::Disconnected(_))));
        assert!(start.elapsed() < Duration::from_millis(40));
        assert!(t.try_recv().unwrap().is_none());
        assert_eq!(t.queue_depth(), 0);
        assert_eq!(t.kind(), "tcp");
    }

    #[test]
    fn unresolvable_endpoint_reports_disconnected() {
        let mut t = TcpTransport::new("not-a-real-host.invalid:7", 50, 50, 1);
        assert!(matches!(t.heartbeat(), Err(TransportError::Disconnected(_))));
        assert!(!t.supports_delta());
    }

    /// Satellite: a pre-refactor server (legacy banner, no delta frames)
    /// must negotiate down to plain submits — the client never puts a
    /// delta frame on the wire, the connection stays healthy, and nothing
    /// retries in a loop.
    #[test]
    fn legacy_server_banner_disables_delta_submits() {
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut frames = 0usize;
            loop {
                match read_frame(&mut s) {
                    // Pre-v2 banner: bare name, no protocol suffix.
                    Ok((Frame::Hello { .. }, _)) => {
                        frames += 1;
                        write_frame(
                            &mut s,
                            &Frame::HelloAck { server: "rkfac-factor-server".into() },
                        )
                        .unwrap();
                    }
                    Ok((Frame::Heartbeat { nonce }, _)) => {
                        frames += 1;
                        write_frame(&mut s, &Frame::HeartbeatAck { nonce }).unwrap();
                    }
                    Ok(_) => frames += 1,
                    Err(_) => break,
                }
            }
            frames
        });
        let mut t = TcpTransport::new(&addr, 1000, 2000, 2);
        assert!(!t.supports_delta(), "legacy banner must disable the delta path");
        // The same (single) connection still serves the plain protocol.
        t.heartbeat().unwrap();
        assert!(!t.supports_delta());
        drop(t);
        let frames = server.join().unwrap();
        // Hello + heartbeat only — no retry storm of rejected submits.
        assert_eq!(frames, 2);
    }

    #[test]
    fn banner_version_parsing_gates_the_delta_path() {
        assert!(!super::banner_supports_delta("rkfac-factor-server"));
        assert!(super::banner_supports_delta("rkfac-factor-server/2"));
        assert!(super::banner_supports_delta("rkfac-factor-server/3"));
        assert!(!super::banner_supports_delta("rkfac-factor-server/1"));
        assert!(!super::banner_supports_delta("rkfac-factor-server/x"));
    }
}
