//! Pluggable transports for the factor-refresh service.
//!
//! ROADMAP item 3 observed that the pipeline's staleness contract is
//! *location-transparent*: a decomposition job is a pure function of
//! `(matrix, cfg, rng)` where the RNG stream is derived from
//! `(seed, round, block, side)` by [`crate::optim::kfac::decomp_rng`] — no
//! part of the result depends on *where* the job runs. This module turns
//! that observation into an interface: [`Transport`] abstracts "submit a
//! decomposition job, receive its result" so the same
//! [`crate::pipeline::FactorPipeline`] drives
//!
//! * [`LocalTransport`] — the original in-process worker pool (priority
//!   [`crate::pipeline::JobQueue`] + threads), refactored behind the trait
//!   with zero behavioural change;
//! * [`TcpTransport`] — a length-prefixed, checksummed TCP client for a
//!   remote [`FactorServer`] (`rkfac serve-factors`), with connect/read
//!   timeouts and bounded exponential-backoff reconnect;
//! * [`DirTransport`] — a shared-filesystem mailbox (atomic
//!   write-to-temp + rename) for clusters without open ports.
//!
//! ## Degradation contract
//!
//! A transport failure is never fatal and never changes values. Every
//! submitted spec is also *retained* by the pipeline ([`JobSpec`] is
//! `Clone`; the matrix snapshot is an `Arc`), so when a submit fails, a
//! receive times out, or the connection drops, the pipeline re-runs the
//! spec inline on the trainer thread with its pristine deterministic RNG —
//! bitwise the result the remote worker would have produced. At
//! `max_stale_steps = 0` a `Tcp` or `Dir` run therefore reproduces the
//! `Local` run bit-for-bit, server up or down (pinned by
//! `rust/tests/transport_golden.rs`).
//!
//! ## Observability
//!
//! Transports feed the obs registry (`transport.frames_tx/rx`,
//! `transport.bytes_tx/rx`, `transport.reconnects` counters and the
//! `transport.rtt_s` histogram), and [`JobSpec::span`] carries the
//! enqueuing refresh's span context across the wire so server-side job
//! spans nest under the trainer's refresh span in a merged trace.

pub mod dir;
pub mod local;
pub mod server;
pub mod tcp;
pub mod wire;

use std::fmt;
use std::sync::Arc;

use crate::linalg::{Matrix, Pcg64};
use crate::obs;
use crate::pipeline::PipelineConfig;
use crate::rnla::{Decomposition, FactorDelta, LowRankFactor, SketchConfig, UpdateOutcome};

pub use dir::DirTransport;
pub use local::LocalTransport;
pub use server::{FactorServer, ServerHandle};
pub use tcp::TcpTransport;

/// Which transport a pipeline's refresh jobs travel over
/// (`[pipeline] transport`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process worker pool (the default — no endpoint needed).
    #[default]
    Local,
    /// Remote factor server over TCP (`endpoint = "host:port"`).
    Tcp,
    /// Shared-filesystem mailbox (`endpoint = <directory>`).
    Dir,
}

impl TransportKind {
    /// Parse the `[pipeline] transport` config value.
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "local" => Some(TransportKind::Local),
            "tcp" => Some(TransportKind::Tcp),
            "dir" => Some(TransportKind::Dir),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Local => "local",
            TransportKind::Tcp => "tcp",
            TransportKind::Dir => "dir",
        }
    }
}

/// Why a transport operation failed. Every variant routes to the same
/// recovery — inline execution on the trainer thread — but they are kept
/// apart so diagnostics (and the `docs/distributed.md` runbook) can tell a
/// dead server from a slow one from a corrupted stream.
#[derive(Debug)]
pub enum TransportError {
    /// No connection (connect failed after bounded retries, or the peer
    /// closed mid-stream).
    Disconnected(String),
    /// The peer is reachable but did not answer within `io_timeout_ms`.
    Timeout(String),
    /// A frame failed its checksum or decoded to garbage; the stream is
    /// desynchronized and the connection has been dropped.
    Corrupt(String),
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::Disconnected(m) => write!(f, "disconnected: {m}"),
            TransportError::Timeout(m) => write!(f, "timeout: {m}"),
            TransportError::Corrupt(m) => write!(f, "corrupt frame: {m}"),
        }
    }
}

/// The incremental half of an update-capable job: the previously published
/// basis plus the composed EA increment since it was installed. Both halves
/// sit behind `Arc`s so retaining/cloning a delta-carrying [`JobSpec`] stays
/// free.
#[derive(Clone)]
pub struct UpdateJob {
    /// The basis the delta was captured against (the factor currently
    /// installed in the job's slot).
    pub prev: Arc<LowRankFactor>,
    /// Composed EA gram increment since `prev` was published.
    pub delta: Arc<FactorDelta>,
}

/// One decomposition work item, transport-agnostic: an `Arc` snapshot of an
/// EA factor plus the strategy to decompose it with. `Clone` is cheap (two
/// `Arc` bumps + the small RNG/config) — the pipeline retains a copy of
/// every submitted spec so a degraded transport can fall back to inline
/// execution with bitwise-identical results.
#[derive(Clone)]
pub struct JobSpec {
    pub block: usize,
    pub side: usize,
    /// Optimizer step at which the matrix snapshot was taken.
    pub version: u64,
    pub strategy: Arc<dyn Decomposition>,
    pub cfg: SketchConfig,
    pub matrix: Arc<Matrix>,
    /// Pristine per-(seed, round, block, side) stream; runners clone it, so
    /// a failed attempt leaves the spec retryable.
    pub rng: Pcg64,
    /// Enqueue timestamp — separates queue-wait from decomposition time.
    pub enqueued_ns: u64,
    /// Scheduler-predicted cost (`DecompMeta::flops` of the path the
    /// scheduler expects to run — update or decompose), carried through to
    /// the run span so `rkfac report` can join predicted vs observed.
    pub flops_pred: f64,
    /// Obs span context of the enqueuing refresh; propagated across the
    /// wire so remote job spans nest under the trainer's refresh span.
    pub span: obs::SpanCtx,
    /// When present, runners try the strategy's incremental
    /// [`Decomposition::update`] path first and fall back to `decompose`
    /// only on decline. Locally-built specs keep the dense `matrix`
    /// alongside (the `Arc` clone is free), so decline and inline-retry
    /// both recover deterministically; wire-decoded delta jobs carry an
    /// empty matrix and surface decline as an `Err` the client retries
    /// inline.
    pub update: Option<UpdateJob>,
}

/// A finished decomposition heading back to the trainer thread. `Err`
/// carries the failure message only — the pipeline retains the original
/// [`JobSpec`] and re-runs it inline, so nothing heavier than a string ever
/// needs to cross a process boundary on failure.
pub struct JobResult {
    pub block: usize,
    pub side: usize,
    pub version: u64,
    /// Seconds the job waited before a worker picked it up.
    pub wait_s: f64,
    /// Seconds spent inside the decomposition itself.
    pub run_s: f64,
    pub outcome: Result<LowRankFactor, String>,
}

/// Run one spec's decomposition with a *copy* of its deterministic RNG, so
/// a failed attempt leaves `spec.rng` pristine for a retry. Panics are
/// caught and surfaced as `Err` messages. Shared by the local workers, the
/// [`FactorServer`] workers, and the pipeline's inline-fallback path — one
/// function, therefore one bitwise behaviour, wherever the job runs.
pub fn run_spec(spec: &JobSpec) -> Result<LowRankFactor, String> {
    let mut rng = spec.rng.clone();
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        if let Some(up) = &spec.update {
            match spec.strategy.update(&up.prev, &up.delta, &spec.cfg, &mut rng) {
                UpdateOutcome::Updated(f) => return Ok(f),
                UpdateOutcome::Declined => {}
            }
        }
        if spec.matrix.rows() == 0 {
            // A wire-decoded delta job travels without its dense snapshot
            // (that is the bandwidth win); a decline here must go back as
            // an Err so the client's retained spec — which *does* hold the
            // snapshot — re-runs inline.
            return Err(format!(
                "strategy '{}' declined the incremental update and the job carries no \
                 factor snapshot",
                spec.strategy.key()
            ));
        }
        Ok(spec.strategy.decompose(spec.matrix.as_ref(), &spec.cfg, &mut rng))
    }));
    match caught {
        Ok(outcome) => outcome,
        Err(payload) => Err(payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "decomposition panicked".to_string())),
    }
}

/// The factor-refresh job channel. One instance per
/// [`crate::pipeline::FactorPipeline`]; implementations own whatever
/// workers/connections/mailboxes they need and release them on drop.
///
/// Error semantics: any `Err` from `submit`/`recv` means "this transport
/// cannot deliver right now" — the caller falls back to inline execution
/// and the run proceeds. Implementations must never block longer than their
/// configured `io_timeout` in `recv`.
pub trait Transport: Send {
    /// Transport name for diagnostics (`"local"` / `"tcp"` / `"dir"`).
    fn kind(&self) -> &'static str;

    /// Enqueue one decomposition job at the given scheduler priority.
    fn submit(&mut self, spec: &JobSpec, prio: f64) -> Result<(), TransportError>;

    /// Publish the current staleness floor: results for versions below it
    /// can never be installed, so workers (local or remote) drop such jobs
    /// at pop time instead of decomposing them.
    fn set_floor(&mut self, floor: u64);

    /// Non-blocking: the next finished result, if one is ready.
    fn try_recv(&mut self) -> Result<Option<JobResult>, TransportError>;

    /// Blocking (bounded by the transport's io timeout): the next finished
    /// result.
    fn recv(&mut self) -> Result<JobResult, TransportError>;

    /// Liveness probe; remote transports measure round-trip time into the
    /// `transport.rtt_s` histogram.
    fn heartbeat(&mut self) -> Result<(), TransportError>;

    /// Jobs currently queued but not yet picked up, where knowable
    /// (remote transports report 0 — the queue lives on the server).
    fn queue_depth(&self) -> usize {
        0
    }

    /// Whether this transport's executor can run delta-carrying
    /// (incremental-update) jobs. `Local` always can (the workers share
    /// this process); `Tcp` answers from the server's handshake banner
    /// (pre-refactor servers cannot decode the delta Submit frame); `Dir`
    /// has no handshake channel and declines. When this is `false` the
    /// pipeline enqueues full-recompute jobs instead — a delta frame is
    /// never put on a wire its peer cannot decode, so an old server causes
    /// one warning and a graceful fallback, not a retry storm.
    fn supports_delta(&mut self) -> bool {
        false
    }
}

/// Build the transport selected by `cfg`. Infallible: remote transports
/// connect lazily, and an unreachable endpoint degrades to inline
/// execution instead of failing construction (endpoint *syntax* is
/// validated at config-resolution time).
pub fn build_transport(cfg: &PipelineConfig) -> Box<dyn Transport> {
    match cfg.transport {
        TransportKind::Local => Box::new(LocalTransport::spawn(cfg.workers.max(1))),
        TransportKind::Tcp => Box::new(TcpTransport::new(
            &cfg.endpoint,
            cfg.connect_timeout_ms,
            cfg.io_timeout_ms,
            cfg.max_retries,
        )),
        TransportKind::Dir => Box::new(DirTransport::new(&cfg.endpoint, cfg.io_timeout_ms)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        assert_eq!(TransportKind::parse("local"), Some(TransportKind::Local));
        assert_eq!(TransportKind::parse("tcp"), Some(TransportKind::Tcp));
        assert_eq!(TransportKind::parse("dir"), Some(TransportKind::Dir));
        assert_eq!(TransportKind::parse("udp"), None);
        assert_eq!(TransportKind::default(), TransportKind::Local);
        for k in [TransportKind::Local, TransportKind::Tcp, TransportKind::Dir] {
            assert_eq!(TransportKind::parse(k.name()), Some(k));
        }
    }

    #[test]
    fn error_display_names_the_failure_class() {
        let d = TransportError::Disconnected("peer gone".into()).to_string();
        let t = TransportError::Timeout("5s".into()).to_string();
        let c = TransportError::Corrupt("crc".into()).to_string();
        assert!(d.contains("disconnected"));
        assert!(t.contains("timeout"));
        assert!(c.contains("corrupt"));
    }
}
