//! The transport wire format: checksummed, length-prefixed frames over the
//! [`crate::util::codec`] little-endian byte codec.
//!
//! ```text
//! frame := magic "RKTF" | payload_len: u32 | payload | crc32(payload): u32
//! ```
//!
//! The payload is a [`Frame`] encoded with [`ByteWriter`]; the length is
//! capped at [`MAX_FRAME_BYTES`] and validated *before* any allocation, and
//! the CRC is verified *before* any decoding — a truncated stream, an
//! oversized length prefix, or a flipped bit all fail loudly with a
//! [`WireError`] instead of deserializing garbage. A decode error
//! desynchronizes the stream by definition, so callers drop the connection
//! (and the pipeline falls back to inline decomposition).
//!
//! The same frames travel over TCP sockets ([`super::tcp`]), filesystem
//! mailboxes ([`super::dir`]), and the remote-sweep cell board
//! (`coordinator::sweep`), so every cross-process byte in the system goes
//! through this one checked codec.

use std::io::{self, Read, Write};
use std::sync::Arc;

use crate::coordinator::metrics::EpochRecord;
use crate::linalg::{Matrix, Pcg64};
use crate::rnla::SketchConfig;
use crate::util::codec::{ByteReader, ByteWriter};

use super::{JobResult, JobSpec};

/// Frame magic — rejects foreign/garbage streams at the first four bytes.
pub const MAGIC: [u8; 4] = *b"RKTF";

/// Upper bound on one frame's payload (1 GiB). A length prefix beyond this
/// is treated as corruption and rejected before any allocation.
pub const MAX_FRAME_BYTES: u32 = 1 << 30;

/// Reading a frame can fail two ways with different consequences: an I/O
/// error (peer gone, timeout — possibly transient) or corruption (bad
/// magic/length/checksum/payload — the stream is desynchronized for good).
#[derive(Debug)]
pub enum WireError {
    Io(io::Error),
    Corrupt(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "i/o: {e}"),
            WireError::Corrupt(m) => write!(f, "corrupt: {m}"),
        }
    }
}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> Self {
        WireError::Io(e)
    }
}

/// IEEE CRC-32 (reflected, poly 0xEDB88320) — the ubiquitous gzip/zip
/// polynomial, hand-rolled because the container vendors no crc crate.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc: u32 = 0xFFFF_FFFF;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// A decomposition job as decoded off the wire: the strategy travels as its
/// registry key (the server resolves it through a
/// [`crate::rnla::DecompositionRegistry`]), the RNG as its raw PCG state,
/// and the span context as its raw id. Everything else round-trips bitwise
/// (f64s as little-endian bytes), which is what lets a remote decomposition
/// reproduce the local one exactly.
pub struct WireJob {
    pub block: usize,
    pub side: usize,
    pub version: u64,
    pub strategy_key: String,
    pub cfg: SketchConfig,
    pub matrix: Matrix,
    pub rng_state: (u128, u128),
    pub flops_pred: f64,
    pub span: u64,
}

impl WireJob {
    /// The job's deterministic RNG, rebuilt mid-stream.
    pub fn rng(&self) -> Pcg64 {
        Pcg64::from_raw(self.rng_state.0, self.rng_state.1)
    }
}

/// The incremental half of a [`Frame::SubmitDelta`] job: the previously
/// published basis plus the composed EA increment. The dense factor
/// snapshot does *not* travel with it — that is the bandwidth win
/// (`d×(r+n)` instead of `d×d`); a server-side decline goes back as an
/// `Err` result and the client's retained spec re-runs inline.
pub struct WireUpdate {
    pub prev_u: Matrix,
    pub prev_d: Vec<f64>,
    pub delta_cols: Matrix,
    pub delta_rho: f64,
}

/// Everything that crosses a transport boundary.
pub enum Frame {
    /// Client banner, first frame on a connection.
    Hello { client: String },
    /// Server banner, reply to `Hello`.
    HelloAck { server: String },
    Heartbeat { nonce: u64 },
    HeartbeatAck { nonce: u64 },
    /// Staleness floor for this client's jobs: the server drops queued jobs
    /// below it at pop time, exactly like the local worker pool.
    SetFloor { floor: u64 },
    /// One decomposition job at a scheduler priority.
    Submit { job: WireJob, prio: f64 },
    /// One *incremental-update* job (protocol v2): the job's `matrix` is
    /// empty and the previous basis + delta travel instead. Pre-v2 servers
    /// reject the unknown discriminant loudly ([`WireError::Corrupt`]) —
    /// which is why clients only send it after the server's `HelloAck`
    /// banner advertises v2 support.
    SubmitDelta { job: WireJob, update: WireUpdate, prio: f64 },
    /// One finished decomposition (or its failure message).
    Result { result: JobResult },
    /// One sweep grid cell for a remote worker (`rkfac worker`).
    Cell { label: String, solver: String, seed: u64, overrides: Vec<(String, String)> },
    /// A completed sweep cell: the manifest entry that makes re-runs skip it.
    CellDone { label: String, solver: String, seed: u64, total_s: f64, records: Vec<EpochRecord> },
    /// Polite connection teardown.
    Shutdown,
}

impl Frame {
    fn discriminant(&self) -> u8 {
        match self {
            Frame::Hello { .. } => 1,
            Frame::HelloAck { .. } => 2,
            Frame::Heartbeat { .. } => 3,
            Frame::HeartbeatAck { .. } => 4,
            Frame::SetFloor { .. } => 5,
            Frame::Submit { .. } => 6,
            Frame::Result { .. } => 7,
            Frame::Cell { .. } => 8,
            Frame::CellDone { .. } => 9,
            Frame::Shutdown => 10,
            // 11 is protocol v2; keep appending — discriminants are wire
            // ABI and must never be renumbered.
            Frame::SubmitDelta { .. } => 11,
        }
    }
}

fn encode_records(w: &mut ByteWriter, records: &[EpochRecord]) {
    w.u64(records.len() as u64);
    for r in records {
        w.u64(r.epoch as u64);
        w.f64(r.wall_s);
        w.f64(r.train_loss);
        w.f64(r.test_loss);
        w.f64(r.test_acc);
        w.f64(r.decomp_s);
    }
}

fn decode_records(r: &mut ByteReader<'_>) -> Result<Vec<EpochRecord>, String> {
    let n = r.u64()? as usize;
    match n.checked_mul(48) {
        Some(b) if b <= r.remaining() => {}
        _ => {
            return Err(format!("corrupt record count {n} for {} remaining bytes", r.remaining()))
        }
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(EpochRecord {
            epoch: r.u64()? as usize,
            wall_s: r.f64()?,
            train_loss: r.f64()?,
            test_loss: r.f64()?,
            test_acc: r.f64()?,
            decomp_s: r.f64()?,
        });
    }
    Ok(out)
}

fn encode_result(w: &mut ByteWriter, res: &JobResult) {
    w.u64(res.block as u64);
    w.u64(res.side as u64);
    w.u64(res.version);
    w.f64(res.wait_s);
    w.f64(res.run_s);
    match &res.outcome {
        Ok(f) => {
            w.u8(1);
            w.matrix(&f.u);
            w.f64s(&f.d);
        }
        Err(msg) => {
            w.u8(0);
            w.str(msg);
        }
    }
}

fn decode_result(r: &mut ByteReader<'_>) -> Result<JobResult, String> {
    let block = r.u64()? as usize;
    let side = r.u64()? as usize;
    let version = r.u64()?;
    let wait_s = r.f64()?;
    let run_s = r.f64()?;
    let outcome = if r.u8()? != 0 {
        let u = r.matrix()?;
        let d = r.f64s()?;
        if u.cols() != d.len() {
            return Err(format!("factor rank mismatch: {} columns vs {} values", u.cols(), d.len()));
        }
        Ok(crate::rnla::LowRankFactor::new(u, d))
    } else {
        Err(r.str()?)
    };
    Ok(JobResult { block, side, version, wait_s, run_s, outcome })
}

fn encode_job_fields(
    w: &mut ByteWriter,
    block: usize,
    side: usize,
    version: u64,
    key: &str,
    cfg: &SketchConfig,
    matrix: &Matrix,
    rng_state: (u128, u128),
    flops_pred: f64,
    span: u64,
    prio: f64,
) {
    w.u64(block as u64);
    w.u64(side as u64);
    w.u64(version);
    w.str(key);
    w.u64(cfg.rank as u64);
    w.u64(cfg.oversample as u64);
    w.u64(cfg.n_power_iter as u64);
    w.matrix(matrix);
    w.u128(rng_state.0);
    w.u128(rng_state.1);
    w.f64(flops_pred);
    w.u64(span);
    w.f64(prio);
}

fn decode_job_fields(r: &mut ByteReader<'_>) -> Result<(WireJob, f64), String> {
    let block = r.u64()? as usize;
    let side = r.u64()? as usize;
    let version = r.u64()?;
    let strategy_key = r.str()?;
    let rank = r.u64()? as usize;
    let oversample = r.u64()? as usize;
    let n_power_iter = r.u64()? as usize;
    let matrix = r.matrix()?;
    let rng_state = (r.u128()?, r.u128()?);
    let flops_pred = r.f64()?;
    let span = r.u64()?;
    let prio = r.f64()?;
    Ok((
        WireJob {
            block,
            side,
            version,
            strategy_key,
            cfg: SketchConfig::new(rank, oversample, n_power_iter),
            matrix,
            rng_state,
            flops_pred,
            span,
        },
        prio,
    ))
}

fn encode_update_fields(
    w: &mut ByteWriter,
    prev_u: &Matrix,
    prev_d: &[f64],
    delta_cols: &Matrix,
    delta_rho: f64,
) {
    w.matrix(prev_u);
    w.f64s(prev_d);
    w.matrix(delta_cols);
    w.f64(delta_rho);
}

fn decode_update(r: &mut ByteReader<'_>) -> Result<WireUpdate, String> {
    let prev_u = r.matrix()?;
    let prev_d = r.f64s()?;
    let delta_cols = r.matrix()?;
    let delta_rho = r.f64()?;
    if prev_u.cols() != prev_d.len() {
        return Err(format!(
            "update basis rank mismatch: {} columns vs {} values",
            prev_u.cols(),
            prev_d.len()
        ));
    }
    if delta_cols.rows() != prev_u.rows() {
        return Err(format!(
            "update delta dim mismatch: {} rows vs basis dim {}",
            delta_cols.rows(),
            prev_u.rows()
        ));
    }
    if !(delta_rho.is_finite() && delta_rho > 0.0 && delta_rho <= 1.0) {
        return Err(format!("update rho {delta_rho} outside (0, 1]"));
    }
    Ok(WireUpdate { prev_u, prev_d, delta_cols, delta_rho })
}

/// Encode one frame into a payload (no framing header yet).
fn encode_payload(frame: &Frame) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.u8(frame.discriminant());
    match frame {
        Frame::Hello { client } => w.str(client),
        Frame::HelloAck { server } => w.str(server),
        Frame::Heartbeat { nonce } | Frame::HeartbeatAck { nonce } => w.u64(*nonce),
        Frame::SetFloor { floor } => w.u64(*floor),
        Frame::Submit { job, prio } => encode_job_fields(
            &mut w,
            job.block,
            job.side,
            job.version,
            &job.strategy_key,
            &job.cfg,
            &job.matrix,
            job.rng_state,
            job.flops_pred,
            job.span,
            *prio,
        ),
        Frame::SubmitDelta { job, update, prio } => {
            encode_job_fields(
                &mut w,
                job.block,
                job.side,
                job.version,
                &job.strategy_key,
                &job.cfg,
                &job.matrix,
                job.rng_state,
                job.flops_pred,
                job.span,
                *prio,
            );
            encode_update_fields(
                &mut w,
                &update.prev_u,
                &update.prev_d,
                &update.delta_cols,
                update.delta_rho,
            );
        }
        Frame::Result { result } => encode_result(&mut w, result),
        Frame::Cell { label, solver, seed, overrides } => {
            w.str(label);
            w.str(solver);
            w.u64(*seed);
            w.u64(overrides.len() as u64);
            for (k, v) in overrides {
                w.str(k);
                w.str(v);
            }
        }
        Frame::CellDone { label, solver, seed, total_s, records } => {
            w.str(label);
            w.str(solver);
            w.u64(*seed);
            w.f64(*total_s);
            encode_records(&mut w, records);
        }
        Frame::Shutdown => {}
    }
    w.into_bytes()
}

fn decode_payload(payload: &[u8]) -> Result<Frame, String> {
    let mut r = ByteReader::new(payload);
    let frame = match r.u8()? {
        1 => Frame::Hello { client: r.str()? },
        2 => Frame::HelloAck { server: r.str()? },
        3 => Frame::Heartbeat { nonce: r.u64()? },
        4 => Frame::HeartbeatAck { nonce: r.u64()? },
        5 => Frame::SetFloor { floor: r.u64()? },
        6 => {
            let (job, prio) = decode_job_fields(&mut r)?;
            Frame::Submit { job, prio }
        }
        7 => Frame::Result { result: decode_result(&mut r)? },
        8 => {
            let label = r.str()?;
            let solver = r.str()?;
            let seed = r.u64()?;
            let n = r.u64()? as usize;
            if n > r.remaining() {
                return Err(format!("corrupt override count {n}"));
            }
            let mut overrides = Vec::with_capacity(n);
            for _ in 0..n {
                overrides.push((r.str()?, r.str()?));
            }
            Frame::Cell { label, solver, seed, overrides }
        }
        9 => Frame::CellDone {
            label: r.str()?,
            solver: r.str()?,
            seed: r.u64()?,
            total_s: r.f64()?,
            records: decode_records(&mut r)?,
        },
        10 => Frame::Shutdown,
        11 => {
            let (job, prio) = decode_job_fields(&mut r)?;
            let update = decode_update(&mut r)?;
            Frame::SubmitDelta { job, update, prio }
        }
        other => return Err(format!("unknown frame discriminant {other}")),
    };
    r.finish()?;
    Ok(frame)
}

fn write_framed(w: &mut impl Write, payload: &[u8]) -> io::Result<usize> {
    debug_assert!(payload.len() <= MAX_FRAME_BYTES as usize, "frame payload too large");
    let mut head = Vec::with_capacity(8);
    head.extend_from_slice(&MAGIC);
    head.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&head)?;
    w.write_all(payload)?;
    w.write_all(&crc32(payload).to_le_bytes())?;
    w.flush()?;
    Ok(8 + payload.len() + 4)
}

/// Write one frame (header + payload + CRC). Returns the bytes written.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> io::Result<usize> {
    write_framed(w, &encode_payload(frame))
}

/// Write a `Submit` frame straight from a [`JobSpec`] — avoids cloning the
/// (potentially large) matrix snapshot into an owned [`WireJob`] first.
/// A spec carrying an update becomes a [`Frame::SubmitDelta`]: the previous
/// basis + delta travel *instead of* the dense snapshot (`d×(r+n)` on the
/// wire instead of `d×d`). Callers must only pass update-carrying specs to
/// peers that negotiated v2 support.
pub fn write_submit(w: &mut impl Write, spec: &JobSpec, prio: f64) -> io::Result<usize> {
    let mut payload = ByteWriter::new();
    match &spec.update {
        None => {
            payload.u8(6);
            encode_job_fields(
                &mut payload,
                spec.block,
                spec.side,
                spec.version,
                spec.strategy.key(),
                &spec.cfg,
                Arc::as_ref(&spec.matrix),
                spec.rng.raw_state(),
                spec.flops_pred,
                spec.span.raw(),
                prio,
            );
        }
        Some(up) => {
            payload.u8(11);
            encode_job_fields(
                &mut payload,
                spec.block,
                spec.side,
                spec.version,
                spec.strategy.key(),
                &spec.cfg,
                &Matrix::zeros(0, 0),
                spec.rng.raw_state(),
                spec.flops_pred,
                spec.span.raw(),
                prio,
            );
            encode_update_fields(
                &mut payload,
                &up.prev.u,
                &up.prev.d,
                &up.delta.cols,
                up.delta.rho,
            );
        }
    }
    write_framed(w, &payload.into_bytes())
}

/// Read one frame. Validates magic, length cap, and CRC before decoding;
/// any mismatch is [`WireError::Corrupt`]. Returns the frame plus the total
/// bytes consumed.
pub fn read_frame(r: &mut impl Read) -> Result<(Frame, usize), WireError> {
    let mut head = [0u8; 8];
    r.read_exact(&mut head)?;
    if head[..4] != MAGIC {
        return Err(WireError::Corrupt(format!(
            "bad magic {:02x?} (expected {:02x?})",
            &head[..4],
            MAGIC
        )));
    }
    let len = u32::from_le_bytes(head[4..8].try_into().unwrap());
    if len > MAX_FRAME_BYTES {
        return Err(WireError::Corrupt(format!(
            "length prefix {len} exceeds the {MAX_FRAME_BYTES}-byte frame cap"
        )));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    let mut crc_bytes = [0u8; 4];
    r.read_exact(&mut crc_bytes)?;
    let expect = u32::from_le_bytes(crc_bytes);
    let got = crc32(&payload);
    if got != expect {
        return Err(WireError::Corrupt(format!(
            "checksum mismatch: computed {got:#010x}, frame claims {expect:#010x}"
        )));
    }
    let frame = decode_payload(&payload).map_err(WireError::Corrupt)?;
    Ok((frame, 8 + payload.len() + 4))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rnla::{decomposition, Decomposition, LowRankFactor};
    use crate::util::prop::{check, ensure};

    fn roundtrip(frame: &Frame) -> Frame {
        let mut buf = Vec::new();
        let n = write_frame(&mut buf, frame).unwrap();
        assert_eq!(n, buf.len());
        let (back, consumed) = read_frame(&mut &buf[..]).unwrap();
        assert_eq!(consumed, buf.len());
        back
    }

    #[test]
    fn crc32_known_vectors() {
        // The standard IEEE check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn control_frames_roundtrip() {
        match roundtrip(&Frame::Hello { client: "trainer-7".into() }) {
            Frame::Hello { client } => assert_eq!(client, "trainer-7"),
            _ => panic!("wrong variant"),
        }
        match roundtrip(&Frame::HeartbeatAck { nonce: 0xDEAD }) {
            Frame::HeartbeatAck { nonce } => assert_eq!(nonce, 0xDEAD),
            _ => panic!("wrong variant"),
        }
        match roundtrip(&Frame::SetFloor { floor: 41 }) {
            Frame::SetFloor { floor } => assert_eq!(floor, 41),
            _ => panic!("wrong variant"),
        }
        assert!(matches!(roundtrip(&Frame::Shutdown), Frame::Shutdown));
    }

    #[test]
    fn submit_from_spec_roundtrips_bitwise() {
        let mut rng = Pcg64::with_stream(3, 99);
        let m = rng.gaussian_matrix(7, 7);
        let spec = JobSpec {
            block: 2,
            side: 1,
            version: 13,
            strategy: std::sync::Arc::new(decomposition::Rsvd),
            cfg: SketchConfig::new(5, 3, 2),
            matrix: std::sync::Arc::new(m.clone()),
            rng: Pcg64::with_stream(17, 0x5A5A),
            enqueued_ns: 0,
            flops_pred: 1.5e6,
            span: crate::obs::SpanCtx::ROOT,
            update: None,
        };
        let mut buf = Vec::new();
        write_submit(&mut buf, &spec, 42.5).unwrap();
        let (frame, _) = read_frame(&mut &buf[..]).unwrap();
        let Frame::Submit { job, prio } = frame else { panic!("wrong variant") };
        assert_eq!(prio, 42.5);
        assert_eq!((job.block, job.side, job.version), (2, 1, 13));
        assert_eq!(job.strategy_key, "rsvd");
        assert_eq!((job.cfg.rank, job.cfg.oversample, job.cfg.n_power_iter), (5, 3, 2));
        assert_eq!(job.matrix.as_slice(), m.as_slice());
        assert_eq!(job.rng_state, Pcg64::with_stream(17, 0x5A5A).raw_state());
        // The restored RNG must continue the stream bitwise — this is the
        // whole remote-determinism story.
        let mut a = spec.rng.clone();
        let mut b = job.rng();
        for _ in 0..16 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    /// A delta-carrying spec travels as SubmitDelta with an *empty* matrix
    /// and the basis + increment intact, bitwise.
    #[test]
    fn submit_delta_roundtrips_without_the_dense_snapshot() {
        let mut rng = Pcg64::with_stream(5, 11);
        let m = rng.gaussian_matrix(9, 9);
        let prev = LowRankFactor::new(rng.gaussian_matrix(9, 4), vec![4.0, 3.0, 2.0, 1.0]);
        let delta = crate::rnla::FactorDelta::new(rng.gaussian_matrix(9, 2), 0.9);
        let spec = JobSpec {
            block: 3,
            side: 0,
            version: 21,
            strategy: std::sync::Arc::new(decomposition::Rsvd),
            cfg: SketchConfig::new(4, 2, 1),
            matrix: std::sync::Arc::new(m.clone()),
            rng: Pcg64::with_stream(8, 0x1234),
            enqueued_ns: 0,
            flops_pred: 7.0e4,
            span: crate::obs::SpanCtx::ROOT,
            update: Some(super::super::UpdateJob {
                prev: std::sync::Arc::new(prev.clone()),
                delta: std::sync::Arc::new(delta.clone()),
            }),
        };
        let mut buf = Vec::new();
        let n = write_submit(&mut buf, &spec, 3.5).unwrap();
        // The dense 9×9 snapshot must not be on the wire: the frame is far
        // smaller than a plain Submit of the same spec.
        let mut plain = Vec::new();
        let mut dense_spec = spec.clone();
        dense_spec.update = None;
        write_submit(&mut plain, &dense_spec, 3.5).unwrap();
        // 9×4 basis + 9×2 delta + 4 eigenvalues < the 9×9 dense snapshot.
        assert!(n < plain.len(), "delta frame did not drop the snapshot");
        let (frame, _) = read_frame(&mut &buf[..]).unwrap();
        let Frame::SubmitDelta { job, update, prio } = frame else { panic!("wrong variant") };
        assert_eq!(prio, 3.5);
        assert_eq!((job.block, job.side, job.version), (3, 0, 21));
        assert_eq!(job.strategy_key, "rsvd");
        assert_eq!(job.matrix.shape(), (0, 0));
        assert_eq!(update.prev_u.as_slice(), prev.u.as_slice());
        assert_eq!(update.prev_d, prev.d);
        assert_eq!(update.delta_cols.as_slice(), delta.cols.as_slice());
        assert_eq!(update.delta_rho, 0.9);

        // Malformed update payloads are rejected at decode, not at use.
        let bogus = Frame::SubmitDelta {
            job: WireJob {
                block: 0,
                side: 0,
                version: 1,
                strategy_key: "rsvd".into(),
                cfg: SketchConfig::new(2, 1, 0),
                matrix: Matrix::zeros(0, 0),
                rng_state: (1, 2),
                flops_pred: 0.0,
                span: 0,
            },
            update: WireUpdate {
                prev_u: Matrix::zeros(5, 2),
                prev_d: vec![1.0, 0.5],
                delta_cols: Matrix::zeros(5, 1),
                delta_rho: 2.0, // outside (0, 1]
            },
            prio: 0.0,
        };
        let mut bad = Vec::new();
        write_frame(&mut bad, &bogus).unwrap();
        match read_frame(&mut &bad[..]) {
            Err(WireError::Corrupt(msg)) => assert!(msg.contains("rho")),
            other => panic!("bad rho decoded: {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn result_frames_roundtrip_ok_and_err() {
        let f = LowRankFactor::new(Matrix::from_fn(4, 2, |i, j| (i + 2 * j) as f64), vec![3.0, 1.0]);
        let ok = Frame::Result {
            result: JobResult {
                block: 1,
                side: 0,
                version: 9,
                wait_s: 0.25,
                run_s: 1.5,
                outcome: Ok(f.clone()),
            },
        };
        match roundtrip(&ok) {
            Frame::Result { result } => {
                assert_eq!((result.block, result.side, result.version), (1, 0, 9));
                assert_eq!(result.wait_s, 0.25);
                let got = result.outcome.unwrap();
                assert_eq!(got.u.as_slice(), f.u.as_slice());
                assert_eq!(got.d, f.d);
            }
            _ => panic!("wrong variant"),
        }
        let err = Frame::Result {
            result: JobResult {
                block: 0,
                side: 1,
                version: 2,
                wait_s: 0.0,
                run_s: 0.0,
                outcome: Err("worker exploded".into()),
            },
        };
        match roundtrip(&err) {
            Frame::Result { result } => {
                assert_eq!(result.outcome.unwrap_err(), "worker exploded");
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn cell_frames_roundtrip() {
        let cell = Frame::Cell {
            label: "rs-kfac[pipeline.max_stale_steps=4]".into(),
            solver: "rs-kfac".into(),
            seed: 3,
            overrides: vec![("pipeline.max_stale_steps".into(), "4".into())],
        };
        match roundtrip(&cell) {
            Frame::Cell { label, solver, seed, overrides } => {
                assert_eq!(label, "rs-kfac[pipeline.max_stale_steps=4]");
                assert_eq!(solver, "rs-kfac");
                assert_eq!(seed, 3);
                assert_eq!(overrides, vec![("pipeline.max_stale_steps".into(), "4".into())]);
            }
            _ => panic!("wrong variant"),
        }
        let done = Frame::CellDone {
            label: "kfac".into(),
            solver: "kfac".into(),
            seed: 1,
            total_s: 12.5,
            records: vec![EpochRecord {
                epoch: 0,
                wall_s: 1.0,
                train_loss: 2.0,
                test_loss: 2.1,
                test_acc: 0.4,
                decomp_s: 0.3,
            }],
        };
        match roundtrip(&done) {
            Frame::CellDone { records, total_s, .. } => {
                assert_eq!(total_s, 12.5);
                assert_eq!(records.len(), 1);
                assert_eq!(records[0].test_acc, 0.4);
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Shutdown).unwrap();
        buf[0] = b'X';
        match read_frame(&mut &buf[..]) {
            Err(WireError::Corrupt(m)) => assert!(m.contains("magic")),
            Err(WireError::Io(e)) => panic!("expected corrupt-magic, got i/o: {e}"),
            Ok(_) => panic!("frame decoded despite bad magic"),
        }
    }

    #[test]
    fn oversized_length_prefix_rejected_before_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC);
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        // No payload follows; if the length were trusted this would try to
        // allocate 4 GiB. It must fail on the cap check instead.
        match read_frame(&mut &buf[..]) {
            Err(WireError::Corrupt(m)) => assert!(m.contains("frame cap")),
            Err(WireError::Io(e)) => panic!("expected corrupt-length, got i/o: {e}"),
            Ok(_) => panic!("frame decoded despite oversized length"),
        }
    }

    #[test]
    fn flipped_bits_fail_the_checksum() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::SetFloor { floor: 7 }).unwrap();
        // Flip one payload bit (past the 8-byte header).
        buf[10] ^= 0x40;
        match read_frame(&mut &buf[..]) {
            Err(WireError::Corrupt(m)) => assert!(m.contains("checksum")),
            Err(WireError::Io(e)) => panic!("expected checksum failure, got i/o: {e}"),
            Ok(_) => panic!("frame decoded despite a flipped bit"),
        }
    }

    #[test]
    fn truncation_is_an_io_error_mid_stream() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Hello { client: "c".into() }).unwrap();
        // Every proper prefix must fail with Io (simulated disconnect), and
        // never panic or yield a frame.
        for cut in 0..buf.len() {
            match read_frame(&mut &buf[..cut]) {
                Err(WireError::Io(e)) => {
                    assert_eq!(e.kind(), io::ErrorKind::UnexpectedEof, "cut at {cut}");
                }
                Err(WireError::Corrupt(_)) => panic!("truncation at {cut} misread as corruption"),
                Ok(_) => panic!("truncated frame decoded at {cut}"),
            }
        }
    }

    /// Property: random single-byte mutations anywhere in a frame never
    /// panic and never silently deserialize a *Submit* payload with a
    /// different meaning — they either fail (checksum/decode) or, if the
    /// mutation cancels out (it cannot for a single byte under CRC-32,
    /// which detects all 1- and 2-bit errors), decode identically.
    #[test]
    fn random_mutations_never_deserialize_garbage() {
        check("wire-mutation-rejection", 64, |g| {
            let d = g.usize_in(3, 8);
            let m = g.matrix(d, d);
            let spec = JobSpec {
                block: g.usize_in(0, 7),
                side: g.usize_in(0, 1),
                version: g.usize_in(0, 1000) as u64,
                strategy: std::sync::Arc::new(decomposition::Srevd),
                cfg: SketchConfig::new(g.usize_in(1, d), 2, 1),
                matrix: std::sync::Arc::new(m),
                rng: Pcg64::with_stream(g.usize_in(0, 9999) as u64, 7),
                enqueued_ns: 0,
                flops_pred: g.f64_in(1.0, 1e9),
                span: crate::obs::SpanCtx::ROOT,
                update: None,
            };
            let mut buf = Vec::new();
            write_submit(&mut buf, &spec, g.f64_in(0.0, 1e6)).unwrap();
            let pos = g.usize_in(0, buf.len() - 1);
            let flip = 1u8 << g.usize_in(0, 7);
            buf[pos] ^= flip;
            match read_frame(&mut &buf[..]) {
                Err(_) => Ok(()),
                Ok(_) => ensure(
                    false,
                    format!("single-byte flip at {pos} (mask {flip:#04x}) decoded successfully"),
                ),
            }
        });
    }
}
