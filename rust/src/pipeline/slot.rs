//! Double-buffered, versioned publication slots for decomposed factors.
//!
//! One slot per (block, side). The *published* factor is what the trainer
//! preconditions with; the *pending* entry tracks the newest job enqueued
//! to the worker pool — together they form the double buffer: readers never
//! see a half-built decomposition, and a newly published factor replaces
//! the front buffer atomically from the trainer thread's perspective (all
//! publication happens on the thread draining the results channel).
//!
//! A pending entry also remembers the sketch rank its job was enqueued
//! with: when the adaptive rank controller changes its mind before the job
//! publishes, the refresh loop *supersedes* the stale job — enqueues a
//! replacement at the new rank — and the version-monotone `publish` below
//! guarantees the loser is discarded whichever order the two results
//! arrive in.
//!
//! Versions are the optimizer step counts at which the source EA factors
//! were snapshotted, so `version` directly measures staleness in steps.

use crate::linalg::Matrix;
use crate::rnla::LowRankFactor;

/// One in-flight decomposition job (enqueued, not yet published).
/// Crate-internal bookkeeping — nothing public returns it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct Pending {
    /// Optimizer step at which the job's snapshot was taken.
    pub version: u64,
    /// Sketch rank the job was enqueued with — a controller rank change
    /// supersedes the job (see `FactorPipeline::refresh`).
    pub rank: usize,
}

/// A versioned factor slot.
#[derive(Clone)]
pub struct FactorSlot {
    published: LowRankFactor,
    version: Option<u64>,
    /// Newest job enqueued but not yet published (worker in flight).
    pub(crate) pending: Option<Pending>,
}

impl FactorSlot {
    /// Fresh slot holding the identity decomposition (the EA factors start
    /// at `I`, Alg. 1), with no published version yet: the first refresh
    /// always waits for a real decomposition before preconditioning.
    pub fn seed(dim: usize) -> FactorSlot {
        FactorSlot {
            published: LowRankFactor::new(Matrix::eye(dim), vec![1.0; dim]),
            version: None,
            pending: None,
        }
    }

    /// Publish a decomposition. Only monotone versions are accepted: a slow
    /// worker delivering an older result than what is already published is
    /// discarded. Returns whether the slot was updated.
    pub fn publish(&mut self, version: u64, factor: LowRankFactor) -> bool {
        if let Some(v) = self.version {
            if version < v {
                return false;
            }
        }
        self.published = factor;
        self.version = Some(version);
        true
    }

    /// Restore a checkpointed publication state (resume path): install
    /// `factor` as the published front buffer at `version` and clear any
    /// pending entry. Unlike [`FactorSlot::publish`] this is not monotone —
    /// it *defines* the slot's history, which is exactly what re-entering a
    /// run mid-schedule needs.
    pub(crate) fn restore(&mut self, version: Option<u64>, factor: LowRankFactor) {
        self.published = factor;
        self.version = version;
        self.pending = None;
    }

    /// The currently published factor.
    pub fn factor(&self) -> &LowRankFactor {
        &self.published
    }

    /// Step version of the published factor (`None` until first publish).
    pub fn version(&self) -> Option<u64> {
        self.version
    }

    /// Bounded-staleness check: is the published factor new enough?
    pub fn satisfies(&self, required_version: u64) -> bool {
        self.version.is_some_and(|v| v >= required_version)
    }

    /// Steps of lag relative to `now` (`None` until first publish).
    pub fn staleness(&self, now: u64) -> Option<u64> {
        self.version.map(|v| now.saturating_sub(v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn factor(dim: usize, scale: f64) -> LowRankFactor {
        LowRankFactor::new(Matrix::eye(dim), vec![scale; dim])
    }

    #[test]
    fn seed_slot_is_identity_and_unversioned() {
        let s = FactorSlot::seed(4);
        assert_eq!(s.factor().rank(), 4);
        assert_eq!(s.version(), None);
        assert!(!s.satisfies(0));
        assert_eq!(s.staleness(10), None);
    }

    #[test]
    fn publish_is_monotone() {
        let mut s = FactorSlot::seed(3);
        assert!(s.publish(5, factor(3, 2.0)));
        assert_eq!(s.version(), Some(5));
        // Older result from a slow worker is discarded.
        assert!(!s.publish(3, factor(3, 9.0)));
        assert_eq!(s.factor().d[0], 2.0);
        // Same-version republish (same round, e.g. forced re-enqueue) wins.
        assert!(s.publish(5, factor(3, 4.0)));
        assert_eq!(s.factor().d[0], 4.0);
        assert!(s.publish(8, factor(3, 1.0)));
        assert_eq!(s.version(), Some(8));
    }

    #[test]
    fn staleness_accounting() {
        let mut s = FactorSlot::seed(2);
        s.publish(10, factor(2, 1.0));
        assert!(s.satisfies(10));
        assert!(s.satisfies(7));
        assert!(!s.satisfies(11));
        assert_eq!(s.staleness(14), Some(4));
        assert_eq!(s.staleness(9), Some(0));
    }
}
