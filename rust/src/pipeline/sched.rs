//! Cost-aware job scheduling for the factor-refresh worker pool.
//!
//! With asynchronous decompositions the *order* in which blocks refresh
//! dominates both wall-clock and staleness: the widest blocks cost the most
//! ([`crate::rnla::DecompMeta::flops`] grows quadratically in the factor
//! dimension at fixed rank) and hurt the most when stale. A FIFO queue lets
//! a burst of cheap narrow-layer jobs starve the one wide block the
//! bounded-staleness wait loop is actually blocked on. [`JobQueue`] is the
//! replacement: a max-priority queue (shared `Mutex<BinaryHeap>` +
//! `Condvar`) with FIFO tie-breaking, so under [`Schedule::FlopsStale`] the
//! widest/stalest blocks decompose first and the wait loop converges
//! sooner, while [`Schedule::Fifo`] reproduces the original enqueue order
//! exactly (all priorities equal → sequence number decides).
//!
//! Scheduling never affects *values*: every job's RNG stream is keyed by
//! `(seed, round, block, side)` and slot publication is version-monotone,
//! so published factors are bitwise independent of the queue discipline —
//! the `zero_staleness_bitwise_matches_inline` golden holds under both
//! schedules (see `rust/tests/pipeline_contract.rs`).

use std::collections::BinaryHeap;
use std::sync::{Condvar, Mutex, MutexGuard};

/// Queue discipline for the refresh worker pool (`[pipeline] schedule`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Schedule {
    /// Strict enqueue order — the original mpsc behaviour, kept for
    /// ablations and as the bitwise-equivalence reference.
    Fifo,
    /// Cost-aware priority: order jobs by [`priority_key`] (decomposition
    /// flops × slot staleness), widest/stalest first.
    #[default]
    FlopsStale,
}

impl Schedule {
    /// Parse the `[pipeline] schedule` config value.
    pub fn parse(s: &str) -> Option<Schedule> {
        match s {
            "fifo" => Some(Schedule::Fifo),
            "flops-stale" => Some(Schedule::FlopsStale),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Schedule::Fifo => "fifo",
            Schedule::FlopsStale => "flops-stale",
        }
    }
}

/// Priority of one decomposition job: its flop cost scaled by how stale the
/// target slot already is, so among equally stale slots the widest (most
/// expensive, and most staleness-sensitive) block runs first, and a slot
/// close to violating the staleness bound outranks a fresh one of equal
/// cost. Callers pass `staleness_steps = version + 1` for never-published
/// (warming) slots, which makes them strictly more urgent than any
/// published slot of the same cost.
pub fn priority_key(flops: f64, staleness_steps: u64) -> f64 {
    flops.max(1.0) * (1.0 + staleness_steps as f64)
}

/// One queued item with its scheduling key. Ordering: higher priority
/// first, then lower sequence number (FIFO among equal priorities — this
/// is what makes [`Schedule::Fifo`], which enqueues everything at equal
/// priority, reproduce strict enqueue order).
struct Entry<T> {
    prio: f64,
    seq: u64,
    item: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // `total_cmp` gives a total order on the (finite) priorities;
        // BinaryHeap is a max-heap, so reverse the seq comparison to pop
        // older entries first within one priority level.
        self.prio.total_cmp(&other.prio).then_with(|| other.seq.cmp(&self.seq))
    }
}

struct State<T> {
    heap: BinaryHeap<Entry<T>>,
    next_seq: u64,
    closed: bool,
}

/// Shared priority work queue: producers `push` with a priority, consumers
/// block in `pop` until an item or `close()` arrives. Closing lets
/// consumers drain what is already queued, then return `None` — the same
/// shutdown semantics as dropping an mpsc sender.
pub struct JobQueue<T> {
    state: Mutex<State<T>>,
    ready: Condvar,
}

impl<T> JobQueue<T> {
    pub fn new() -> JobQueue<T> {
        JobQueue {
            state: Mutex::new(State { heap: BinaryHeap::new(), next_seq: 0, closed: false }),
            ready: Condvar::new(),
        }
    }

    /// Lock the queue state, recovering from poisoning: the state is a
    /// plain heap that is consistent between operations, and the trainer
    /// must still be able to drain the queue inline after a worker died
    /// mid-operation (the whole point of the failure-recovery path).
    fn lock(&self) -> MutexGuard<'_, State<T>> {
        self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Enqueue an item at the given priority. Returns `false` (dropping the
    /// item) if the queue is already closed.
    pub fn push(&self, item: T, prio: f64) -> bool {
        let mut st = self.lock();
        if st.closed {
            return false;
        }
        let seq = st.next_seq;
        st.next_seq += 1;
        st.heap.push(Entry { prio, seq, item });
        drop(st);
        self.ready.notify_one();
        true
    }

    /// Blocking pop: waits for an item; `None` once the queue is closed
    /// *and* empty (queued items are still drained after `close`).
    pub fn pop(&self) -> Option<T> {
        let mut st = self.lock();
        loop {
            if let Some(e) = st.heap.pop() {
                return Some(e.item);
            }
            if st.closed {
                return None;
            }
            st = self.ready.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }

    /// Non-blocking pop (used by the trainer to drain the queue inline when
    /// the worker pool is gone).
    pub fn try_pop(&self) -> Option<T> {
        self.lock().heap.pop().map(|e| e.item)
    }

    /// Items currently queued (excluding in-flight jobs already popped).
    pub fn len(&self) -> usize {
        self.lock().heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: consumers drain the remaining items, then see
    /// `None`; further pushes are rejected.
    pub fn close(&self) {
        self.lock().closed = true;
        self.ready.notify_all();
    }
}

impl<T> Default for JobQueue<T> {
    fn default() -> Self {
        JobQueue::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn schedule_parse_roundtrip() {
        assert_eq!(Schedule::parse("fifo"), Some(Schedule::Fifo));
        assert_eq!(Schedule::parse("flops-stale"), Some(Schedule::FlopsStale));
        assert_eq!(Schedule::parse("lifo"), None);
        assert_eq!(Schedule::default(), Schedule::FlopsStale);
        for s in [Schedule::Fifo, Schedule::FlopsStale] {
            assert_eq!(Schedule::parse(s.name()), Some(s));
        }
    }

    #[test]
    fn priority_key_orders_wide_and_stale_first() {
        // Wider (more flops) beats narrower at equal staleness.
        assert!(priority_key(1e9, 2) > priority_key(1e6, 2));
        // Staler beats fresher at equal cost.
        assert!(priority_key(1e6, 5) > priority_key(1e6, 0));
        // Monotone in both arguments.
        assert!(priority_key(2e6, 3) > priority_key(1e6, 3));
        // Zero staleness still yields a positive key.
        assert!(priority_key(1e6, 0) > 0.0);
    }

    #[test]
    fn equal_priorities_pop_fifo() {
        let q = JobQueue::new();
        for i in 0..5 {
            assert!(q.push(i, 1.0));
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.try_pop()).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn higher_priority_pops_first() {
        let q = JobQueue::new();
        q.push("cheap-fresh", priority_key(1e3, 0));
        q.push("wide-stale", priority_key(1e9, 4));
        q.push("wide-fresh", priority_key(1e9, 0));
        q.push("cheap-stale", priority_key(1e3, 4));
        let order: Vec<&str> = std::iter::from_fn(|| q.try_pop()).collect();
        assert_eq!(order, vec!["wide-stale", "wide-fresh", "cheap-stale", "cheap-fresh"]);
    }

    #[test]
    fn close_drains_then_none() {
        let q = JobQueue::new();
        q.push(1, 0.0);
        q.push(2, 0.0);
        q.close();
        assert!(!q.push(3, 0.0), "push after close must be rejected");
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn blocking_pop_wakes_on_push_and_close() {
        let q = Arc::new(JobQueue::new());
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(v) = q2.pop() {
                got.push(v);
            }
            got
        });
        q.push(7, 1.0);
        q.push(8, 2.0);
        q.close();
        let got = h.join().unwrap();
        assert_eq!(got.len(), 2);
        assert!(got.contains(&7) && got.contains(&8));
    }

    #[test]
    fn len_tracks_queue_depth() {
        let q = JobQueue::new();
        assert_eq!(q.len(), 0);
        q.push(1, 0.5);
        q.push(2, 0.25);
        assert_eq!(q.len(), 2);
        q.try_pop();
        assert_eq!(q.len(), 1);
    }
}
