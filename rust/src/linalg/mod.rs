//! Dense linear-algebra substrate.
//!
//! The build environment ships no BLAS/LAPACK (and no crates beyond the
//! `xla` closure), so everything the paper's algorithms need is implemented
//! here from scratch: a row-major [`Matrix`], blocked matmul/syrk kernels
//! ([`gemm`]), Householder QR ([`qr`]), symmetric EVD ([`evd`]) — the O(d³)
//! operation vanilla K-FAC performs and Randomized K-FACs avoid — one-sided
//! Jacobi SVD ([`svd`]), Cholesky/Woodbury solves ([`chol`]) for the SENG
//! baseline, and a seeded PCG64 RNG ([`rng`]).

pub mod backend;
pub mod chol;
pub mod evd;
pub mod gemm;
pub mod matrix;
pub mod qr;
pub mod rng;
pub mod svd;

pub use matrix::Matrix;
pub use rng::Pcg64;
