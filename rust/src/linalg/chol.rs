//! Cholesky factorization and SPD solves.
//!
//! Used by the SENG baseline (Sherman–Morrison–Woodbury core solve of the
//! sketched empirical Fisher) and by tests as an independent SPD oracle.

use crate::linalg::{gemm, qr, Matrix};

/// Lower-triangular Cholesky factor `A = L Lᵀ` of an SPD matrix.
/// Sequential on every backend (the SENG core solve is k×k, k ≪ d); the
/// span's backend attribute still records what was installed.
pub fn cholesky(a: &Matrix) -> Result<Matrix, String> {
    let n = a.rows();
    if !a.is_square() {
        return Err("cholesky: matrix not square".into());
    }
    let _sp = crate::obs::span("linalg.chol").arg("dim", n).with_backend();
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 {
                    return Err(format!("cholesky: not positive definite at pivot {i} (s={s:.3e})"));
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Solve `A X = B` for SPD `A` via Cholesky.
pub fn spd_solve(a: &Matrix, b: &Matrix) -> Result<Matrix, String> {
    let l = cholesky(a)?;
    // L y = b ; Lᵀ x = y
    let y = qr::solve_lower_triangular(&l, b);
    let x = qr::solve_upper_triangular(&l.transpose(), &y);
    Ok(x)
}

/// Solve `(U Uᵀ / n + λ I) X = B` with tall-skinny `U` (d×k, k ≪ d) by
/// Sherman–Morrison–Woodbury — the O(d·k²) solve that gives SENG its linear
/// scaling in layer width:
///
/// `(λI + UUᵀ/n)^{-1} = λ^{-1} I − λ^{-2} U (n I_k + λ^{-1} UᵀU)^{-1} Uᵀ`
pub fn woodbury_solve(u: &Matrix, n_scale: f64, lambda: f64, b: &Matrix) -> Result<Matrix, String> {
    assert!(lambda > 0.0, "woodbury_solve: lambda must be positive");
    let k = u.cols();
    // Core k×k SPD system: (n I + λ^{-1} UᵀU)
    let utu = gemm::matmul_tn(u, u);
    let mut core = &utu * (1.0 / lambda);
    core.add_diag(n_scale);
    let utb = gemm::matmul_tn(u, b);
    let core_inv_utb = spd_solve(&core, &utb)?;
    let correction = gemm::matmul(u, &core_inv_utb);
    let mut x = b.clone();
    x.scale_inplace(1.0 / lambda);
    x.axpy(-1.0 / (lambda * lambda), &correction);
    debug_assert_eq!(x.shape(), b.shape());
    let _ = k;
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Pcg64;

    fn random_spd(rng: &mut Pcg64, n: usize) -> Matrix {
        let m = rng.gaussian_matrix(n, n + 2);
        let mut s = gemm::syrk(&m);
        s.add_diag(0.5);
        s
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Pcg64::new(1);
        for &n in &[1usize, 2, 7, 23, 50] {
            let a = random_spd(&mut rng, n);
            let l = cholesky(&a).unwrap();
            let llt = gemm::matmul_nt(&l, &l);
            assert!(llt.rel_err(&a) < 1e-11, "n={n}");
            // L lower-triangular.
            for i in 0..n {
                for j in (i + 1)..n {
                    assert_eq!(l[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn spd_solve_correct() {
        let mut rng = Pcg64::new(2);
        let a = random_spd(&mut rng, 15);
        let b = rng.gaussian_matrix(15, 3);
        let x = spd_solve(&a, &b).unwrap();
        assert!(gemm::matmul(&a, &x).rel_err(&b) < 1e-9);
    }

    #[test]
    fn woodbury_matches_dense_solve() {
        let mut rng = Pcg64::new(3);
        let d = 40;
        let k = 6;
        let u = rng.gaussian_matrix(d, k);
        let lambda = 0.3;
        let n_scale = 8.0;
        let b = rng.gaussian_matrix(d, 2);
        // Dense reference: (UUᵀ/n + λI) x = b
        let mut dense = gemm::matmul_nt(&u, &u);
        dense.scale_inplace(1.0 / n_scale);
        dense.add_diag(lambda);
        let x_ref = spd_solve(&dense, &b).unwrap();
        let x = woodbury_solve(&u, n_scale, lambda, &b).unwrap();
        assert!(x.rel_err(&x_ref) < 1e-9, "err {}", x.rel_err(&x_ref));
    }

    #[test]
    fn woodbury_reduces_to_scaled_identity_for_zero_u() {
        let u = Matrix::zeros(10, 3);
        let b = Matrix::ones(10, 1);
        let x = woodbury_solve(&u, 4.0, 0.5, &b).unwrap();
        for i in 0..10 {
            assert!((x[(i, 0)] - 2.0).abs() < 1e-12);
        }
    }
}
