//! Dense row-major `f64` matrix — the workhorse type of the whole stack.
//!
//! No BLAS/LAPACK is available in this environment; every factorization in
//! `linalg/` is written against this type. The layout is row-major,
//! contiguous, which keeps `row(i)` a plain slice and makes the blocked
//! matmul kernels in [`crate::linalg::gemm`] cache-friendly.

use std::fmt;
use std::ops::{Add, AddAssign, Index, IndexMut, Mul, Neg, Sub, SubAssign};

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix of shape `(rows, cols)`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix of ones.
    pub fn ones(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![1.0; rows * cols] }
    }

    /// Identity of size `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector.
    ///
    /// Panics if `data.len() != rows * cols`.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "from_vec: data length mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from a function of the index.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Build a diagonal matrix from a slice.
    pub fn from_diag(d: &[f64]) -> Self {
        let n = d.len();
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = d[i];
        }
        m
    }

    /// Column vector (n×1) from a slice.
    pub fn col_vector(v: &[f64]) -> Self {
        Matrix::from_vec(v.len(), 1, v.to_vec())
    }

    /// Row vector (1×n) from a slice.
    pub fn row_vector(v: &[f64]) -> Self {
        Matrix::from_vec(1, v.len(), v.to_vec())
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)` pair.
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Number of stored elements.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Whether the matrix is square.
    #[inline]
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Raw row-major data slice.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw row-major data slice.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the row-major data vector.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i` as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Copy of column `j`.
    pub fn col(&self, j: usize) -> Vec<f64> {
        debug_assert!(j < self.cols);
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Write `v` into column `j`.
    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows, "set_col: length mismatch");
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    /// Copy of the main diagonal.
    pub fn diag(&self) -> Vec<f64> {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// Sub-matrix copy: rows `r0..r1`, cols `c0..c1` (half-open).
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r0 <= r1 && r1 <= self.rows && c0 <= c1 && c1 <= self.cols, "slice out of range");
        let mut out = Matrix::zeros(r1 - r0, c1 - c0);
        for i in r0..r1 {
            out.row_mut(i - r0).copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// First `k` columns (copy) — the usual truncation step after an EVD/SVD.
    pub fn first_cols(&self, k: usize) -> Matrix {
        self.slice(0, self.rows, 0, k.min(self.cols))
    }

    /// Paste `block` with its top-left corner at `(r0, c0)`.
    pub fn set_block(&mut self, r0: usize, c0: usize, block: &Matrix) {
        assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols, "set_block out of range");
        for i in 0..block.rows {
            let dst = &mut self.row_mut(r0 + i)[c0..c0 + block.cols];
            dst.copy_from_slice(block.row(i));
        }
    }

    /// Horizontal concatenation `[self | other]`.
    pub fn hcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "hcat: row mismatch");
        let mut out = Matrix::zeros(self.rows, self.cols + other.cols);
        out.set_block(0, 0, self);
        out.set_block(0, self.cols, other);
        out
    }

    /// Vertical concatenation `[self; other]`.
    pub fn vcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "vcat: col mismatch");
        let mut out = Matrix::zeros(self.rows + other.rows, self.cols);
        out.set_block(0, 0, self);
        out.set_block(self.rows, 0, other);
        out
    }

    /// Element-wise map.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// In-place element-wise map.
    pub fn map_inplace(&mut self, f: impl Fn(f64) -> f64) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// Scale by a scalar, in place.
    pub fn scale_inplace(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// `self += alpha * other` (axpy).
    pub fn axpy(&mut self, alpha: f64, other: &Matrix) {
        assert_eq!(self.shape(), other.shape(), "axpy: shape mismatch");
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// `self = rho*self + (1-rho)*other` — the EA blend used for K-factors.
    pub fn ea_blend(&mut self, rho: f64, other: &Matrix) {
        assert_eq!(self.shape(), other.shape(), "ea_blend: shape mismatch");
        let c = 1.0 - rho;
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a = rho * *a + c * b;
        }
    }

    /// Add `lambda` to the diagonal (Tikhonov damping), in place.
    pub fn add_diag(&mut self, lambda: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += lambda;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |entry|.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0_f64, |m, &x| m.max(x.abs()))
    }

    /// Sum of all entries.
    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    /// Trace (sum of diagonal).
    pub fn trace(&self) -> f64 {
        self.diag().iter().sum()
    }

    /// Symmetrize in place: `A <- (A + Aᵀ)/2`. Cheap guard against numeric
    /// asymmetry drift in the EA K-factors.
    pub fn symmetrize(&mut self) {
        assert!(self.is_square(), "symmetrize: not square");
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    /// Max |A - Aᵀ| — asymmetry measure used by tests/invariant checks.
    pub fn asymmetry(&self) -> f64 {
        assert!(self.is_square());
        let mut m = 0.0_f64;
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                m = m.max((self[(i, j)] - self[(j, i)]).abs());
            }
        }
        m
    }

    /// Are all entries finite?
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// `||self - other||_F / max(1, ||other||_F)` — relative error helper.
    pub fn rel_err(&self, other: &Matrix) -> f64 {
        assert_eq!(self.shape(), other.shape(), "rel_err: shape mismatch");
        let mut num = 0.0;
        for (a, b) in self.data.iter().zip(other.data.iter()) {
            num += (a - b) * (a - b);
        }
        num.sqrt() / other.fro_norm().max(1.0)
    }

    /// Convert to `f32` row-major buffer (for PJRT literals).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    /// Build from an `f32` row-major buffer (from PJRT literals).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols, "from_f32: length mismatch");
        Matrix { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols, "index ({i},{j}) out of {:?}", self.shape());
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols, "index ({i},{j}) out of {:?}", self.shape());
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(8);
        let show_c = self.cols.min(8);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                write!(f, "{:>12.5e} ", self[(i, j)])?;
            }
            if self.cols > show_c {
                write!(f, "...")?;
            }
            writeln!(f)?;
        }
        if self.rows > show_r {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

impl Add<&Matrix> for &Matrix {
    type Output = Matrix;
    fn add(self, rhs: &Matrix) -> Matrix {
        assert_eq!(self.shape(), rhs.shape(), "add: shape mismatch");
        let mut out = self.clone();
        out.axpy(1.0, rhs);
        out
    }
}

impl Sub<&Matrix> for &Matrix {
    type Output = Matrix;
    fn sub(self, rhs: &Matrix) -> Matrix {
        assert_eq!(self.shape(), rhs.shape(), "sub: shape mismatch");
        let mut out = self.clone();
        out.axpy(-1.0, rhs);
        out
    }
}

impl AddAssign<&Matrix> for Matrix {
    fn add_assign(&mut self, rhs: &Matrix) {
        self.axpy(1.0, rhs);
    }
}

impl SubAssign<&Matrix> for Matrix {
    fn sub_assign(&mut self, rhs: &Matrix) {
        self.axpy(-1.0, rhs);
    }
}

impl Mul<f64> for &Matrix {
    type Output = Matrix;
    fn mul(self, s: f64) -> Matrix {
        self.map(|x| x * s)
    }
}

impl Neg for &Matrix {
    type Output = Matrix;
    fn neg(self) -> Matrix {
        self.map(|x| -x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(1, 2)], 6.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!(m.col(1), vec![2., 5.]);
    }

    #[test]
    fn eye_and_diag() {
        let i = Matrix::eye(3);
        assert_eq!(i.trace(), 3.0);
        assert_eq!(i.diag(), vec![1., 1., 1.]);
        let d = Matrix::from_diag(&[2., 3.]);
        assert_eq!(d[(0, 0)], 2.0);
        assert_eq!(d[(0, 1)], 0.0);
        assert_eq!(d[(1, 1)], 3.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Matrix::from_fn(17, 5, |i, j| (i * 5 + j) as f64);
        let t = m.transpose();
        assert_eq!(t.shape(), (5, 17));
        assert_eq!(t.transpose(), m);
        assert_eq!(m[(3, 2)], t[(2, 3)]);
    }

    #[test]
    fn slice_and_blocks() {
        let m = Matrix::from_fn(6, 6, |i, j| (i * 10 + j) as f64);
        let s = m.slice(1, 3, 2, 5);
        assert_eq!(s.shape(), (2, 3));
        assert_eq!(s[(0, 0)], 12.0);
        assert_eq!(s[(1, 2)], 24.0);
        let mut z = Matrix::zeros(6, 6);
        z.set_block(2, 2, &s);
        assert_eq!(z[(2, 2)], 12.0);
        assert_eq!(z[(3, 4)], 24.0);
    }

    #[test]
    fn concat() {
        let a = Matrix::ones(2, 2);
        let b = Matrix::zeros(2, 1);
        let h = a.hcat(&b);
        assert_eq!(h.shape(), (2, 3));
        assert_eq!(h[(0, 2)], 0.0);
        let v = a.vcat(&Matrix::zeros(1, 2));
        assert_eq!(v.shape(), (3, 2));
        assert_eq!(v[(2, 1)], 0.0);
    }

    #[test]
    fn ea_blend_matches_formula() {
        let mut a = Matrix::ones(2, 2);
        let b = Matrix::from_fn(2, 2, |_, _| 3.0);
        a.ea_blend(0.95, &b);
        for i in 0..2 {
            for j in 0..2 {
                assert!((a[(i, j)] - (0.95 + 0.05 * 3.0)).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn norms() {
        let m = Matrix::from_vec(1, 2, vec![3., 4.]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-15);
        assert_eq!(m.max_abs(), 4.0);
        assert_eq!(m.sum(), 7.0);
    }

    #[test]
    fn symmetrize_and_asymmetry() {
        let mut m = Matrix::from_vec(2, 2, vec![1., 2., 4., 1.]);
        assert_eq!(m.asymmetry(), 2.0);
        m.symmetrize();
        assert_eq!(m.asymmetry(), 0.0);
        assert_eq!(m[(0, 1)], 3.0);
    }

    #[test]
    fn f32_roundtrip() {
        let m = Matrix::from_fn(3, 4, |i, j| i as f64 - j as f64 * 0.5);
        let m2 = Matrix::from_f32(3, 4, &m.to_f32());
        assert!(m.rel_err(&m2) < 1e-7);
    }

    #[test]
    fn add_diag_damping() {
        let mut m = Matrix::zeros(3, 3);
        m.add_diag(0.1);
        assert!((m.trace() - 0.3).abs() < 1e-15);
    }

    #[test]
    #[should_panic]
    fn from_vec_len_mismatch_panics() {
        let _ = Matrix::from_vec(2, 2, vec![1.0; 3]);
    }
}
