//! Pluggable dense-linalg compute backend.
//!
//! Every preconditioner in the repo — kfac, ekfac, seng, and all rnla
//! strategies — bottoms out in the kernels of `linalg::{gemm,qr,evd}`. This
//! module makes that substrate *selectable* without touching any call site:
//!
//! * [`BackendKind::Reference`] — today's single-threaded blocked kernels,
//!   byte-for-byte the behavior every bitwise golden in the repo was
//!   recorded against.
//! * [`BackendKind::Threaded`] — cache-blocked GEMM/SYRK with a
//!   register-tiled microkernel and a scoped worker pool that partitions
//!   **disjoint output tiles** across threads, plus a parallel trailing
//!   update for the Householder QR and a batched small-EVD for per-block
//!   spectra.
//!
//! # Determinism contract (disjoint output tiles)
//!
//! The threaded backend is required to be **bitwise identical** to the
//! reference backend at *any* thread count. This is achieved structurally,
//! not by tolerance:
//!
//! 1. The output matrix is partitioned into disjoint row (or triangle-row)
//!    blocks; each output element is computed by exactly one thread. No
//!    atomics, no reductions across threads, nothing order-dependent.
//! 2. Within a block, each element's f64 accumulation visits the inner
//!    (`k`) dimension in exactly the same ascending order as the reference
//!    kernel — the register-tiled microkernel reorders work *across*
//!    output elements (which is free) but never *within* one element's
//!    chain of adds.
//!
//! Changing `linalg.threads` therefore changes only how the disjoint blocks
//! are distributed, never any per-element rounding sequence, so all bitwise
//! golden suites (registry, pipeline contract, transport, obs, resume) hold
//! under `linalg.backend = "threaded"` verbatim.
//!
//! # Precision policy
//!
//! [`Precision::Mixed`] (f32 storage, f64 accumulation) is scoped to the
//! *sketching* GEMMs of the RSVD/Nystrom range finder (`rnla::sketch`),
//! where the paper's own argument applies: the sketch already injects
//! randomness, so the leading subspace only needs modest precision
//! (arXiv 2206.15397 §4; cf. EKFAC, arXiv 1806.03884). Exact and
//! truncated-EVD paths are pinned f64 and solver specs that consist only of
//! those paths are *rejected* at config resolution when `precision =
//! "mixed"` — see [`mixed_precision_supported`]. The mixed kernels keep the
//! same disjoint-tile partitioning, so they too are deterministic in the
//! thread count (but NOT bitwise-equal to the f64 kernels — equality is
//! tolerance-bounded, see `tests/prop_invariants.rs`).
//!
//! # Selection
//!
//! The backend is process-global (one relaxed atomic per knob, mirroring
//! `obs::enabled()`): `Session::wire_native` installs it from the
//! `[linalg]` config section before building the solver, pipeline workers
//! are same-process threads and inherit it automatically, and
//! `rkfac serve-factors` installs it from its own `--config` so remote
//! factor services match the coordinator. Note that sweep cells sharing a
//! process (`[sweep] max_workers > 1`) also share the selection —
//! last-writer-wins; harmless for `backend`/`threads` (bitwise-identical
//! by contract) but do not sweep `linalg.precision` with parallel cells
//! (see docs/linalg.md).

pub mod threaded;

use crate::linalg::evd::{self, Evd};
use crate::linalg::gemm;
use crate::linalg::Matrix;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};

/// Which kernel family executes dense linalg.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Single-threaded blocked kernels — the golden-producing originals.
    Reference,
    /// Disjoint-tile multi-threaded kernels, bitwise-equal to `Reference`.
    Threaded,
}

impl BackendKind {
    /// Parse a `[linalg] backend = "..."` value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "reference" => Some(BackendKind::Reference),
            "threaded" => Some(BackendKind::Threaded),
            _ => None,
        }
    }

    /// Canonical config-file spelling (also the obs span attribute value).
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Reference => "reference",
            BackendKind::Threaded => "threaded",
        }
    }
}

/// Storage/accumulation precision for the *sketching* GEMM paths only.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Everything f64 — the default; required for bitwise goldens.
    F64,
    /// Range-finder GEMMs read f32 operands, accumulate in f64. Exact/EVD
    /// paths stay pinned f64 regardless.
    Mixed,
}

impl Precision {
    /// Parse a `[linalg] precision = "..."` value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "f64" => Some(Precision::F64),
            "mixed" => Some(Precision::Mixed),
            _ => None,
        }
    }

    /// Canonical config-file spelling.
    pub fn name(&self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::Mixed => "mixed",
        }
    }
}

/// The resolved process-global selection: kind + effective thread count +
/// precision. Surfaced in `DecompMeta` cost metadata and obs span
/// attributes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Selection {
    pub kind: BackendKind,
    /// Effective worker count (>= 1; `threads = 0` in config resolves to
    /// the machine's available parallelism at install time).
    pub threads: usize,
    pub precision: Precision,
}

const KIND_REFERENCE: u8 = 0;
const KIND_THREADED: u8 = 1;
const PREC_F64: u8 = 0;
const PREC_MIXED: u8 = 1;

static KIND: AtomicU8 = AtomicU8::new(KIND_REFERENCE);
static THREADS: AtomicUsize = AtomicUsize::new(1);
static PRECISION: AtomicU8 = AtomicU8::new(PREC_F64);

/// Serializes [`install`] against an outstanding [`ScopedInstall`]: a test
/// holding a scoped selection must not see a concurrent `Session` in the
/// same binary overwrite it mid-assertion. `install` holds this only for
/// the three stores; do not call `install` while the same thread holds a
/// `ScopedInstall` guard (it would self-deadlock).
static INSTALL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Install the process-global backend selection. `threads = 0` means
/// "auto": resolve to `std::thread::available_parallelism()` now, so every
/// later [`current`] read sees a concrete count. Returns the resolved
/// selection (computed locally, so it is race-free even if another thread
/// reinstalls immediately after).
pub fn install(kind: BackendKind, threads: usize, precision: Precision) -> Selection {
    let _lock = INSTALL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    install_unlocked(kind, threads, precision)
}

fn install_unlocked(kind: BackendKind, threads: usize, precision: Precision) -> Selection {
    let t = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    };
    KIND.store(
        match kind {
            BackendKind::Reference => KIND_REFERENCE,
            BackendKind::Threaded => KIND_THREADED,
        },
        Ordering::Relaxed,
    );
    THREADS.store(t.max(1), Ordering::Relaxed);
    PRECISION.store(
        match precision {
            Precision::F64 => PREC_F64,
            Precision::Mixed => PREC_MIXED,
        },
        Ordering::Relaxed,
    );
    Selection { kind, threads: t.max(1), precision }
}

/// The currently installed selection (three relaxed loads).
pub fn current() -> Selection {
    let kind = if KIND.load(Ordering::Relaxed) == KIND_THREADED {
        BackendKind::Threaded
    } else {
        BackendKind::Reference
    };
    let precision = if PRECISION.load(Ordering::Relaxed) == PREC_MIXED {
        Precision::Mixed
    } else {
        Precision::F64
    };
    Selection { kind, threads: THREADS.load(Ordering::Relaxed).max(1), precision }
}

/// Install from `RKFAC_LINALG_BACKEND` / `RKFAC_LINALG_THREADS` /
/// `RKFAC_LINALG_PRECISION` (bench binaries and CI equivalence runs;
/// unset vars keep defaults). Returns the resolved selection.
pub fn install_from_env() -> Selection {
    let kind = std::env::var("RKFAC_LINALG_BACKEND")
        .ok()
        .and_then(|s| BackendKind::parse(&s))
        .unwrap_or(BackendKind::Reference);
    let threads = std::env::var("RKFAC_LINALG_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(0);
    let precision = std::env::var("RKFAC_LINALG_PRECISION")
        .ok()
        .and_then(|s| Precision::parse(&s))
        .unwrap_or(Precision::F64);
    install(kind, threads, precision)
}

/// May this solver spec run under `precision = "mixed"`? Only specs whose
/// decomposition strategy actually routes through the sketching GEMMs (or
/// uses no decomposition at all) qualify; `exact` and `trunc` are pure
/// EVD paths pinned to f64, so requesting mixed precision for them would
/// silently be a no-op — we reject it instead so the config says what runs.
pub fn mixed_precision_supported(strategy: Option<&str>) -> bool {
    !matches!(strategy, Some("exact") | Some("trunc"))
}

/// Scoped install for tests/benches: holds a global lock (kernels from
/// concurrent tests in one binary would otherwise race the selection) and
/// restores the previous selection on drop.
pub struct ScopedInstall {
    prev: Selection,
    _lock: std::sync::MutexGuard<'static, ()>,
}

/// Install `sel` until the returned guard drops.
pub fn scoped(kind: BackendKind, threads: usize, precision: Precision) -> ScopedInstall {
    let lock = INSTALL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = current();
    install_unlocked(kind, threads, precision);
    ScopedInstall { prev, _lock: lock }
}

impl Drop for ScopedInstall {
    fn drop(&mut self) {
        install_unlocked(self.prev.kind, self.prev.threads, self.prev.precision);
    }
}

/// The kernel surface a backend must provide. `linalg::gemm`'s public free
/// functions keep their asserts and obs spans and dispatch here; the
/// Householder QR threads its trailing update through the same partition
/// primitive ([`threaded::run_chunks`]) rather than through this trait
/// (the factorization itself is inherently sequential per reflector).
pub trait Backend: Sync {
    /// Selection-name this backend answers to.
    fn name(&self) -> &'static str;
    /// `C += alpha * A · B`.
    fn gemm_acc(&self, c: &mut Matrix, alpha: f64, a: &Matrix, b: &Matrix);
    /// `C = Aᵀ · B` (A: k×m, B: k×n).
    fn matmul_tn(&self, a: &Matrix, b: &Matrix) -> Matrix;
    /// `C = A · Bᵀ` (A: m×k, B: n×k).
    fn matmul_nt(&self, a: &Matrix, b: &Matrix) -> Matrix;
    /// `S = M · Mᵀ`, symmetric.
    fn syrk(&self, m: &Matrix) -> Matrix;
    /// `dst = rho*dst + (1-rho)/denom * M·Mᵀ`, symmetric.
    fn ea_gram_update(&self, dst: &mut Matrix, rho: f64, m: &Matrix, denom: f64);
    /// Independent symmetric EVDs (one per input), order-preserving.
    fn sym_evd_batch(&self, mats: &[&Matrix]) -> Vec<Evd>;
}

/// Reference backend: delegates to the original sequential kernel bodies.
pub struct Reference;

impl Backend for Reference {
    fn name(&self) -> &'static str {
        "reference"
    }
    fn gemm_acc(&self, c: &mut Matrix, alpha: f64, a: &Matrix, b: &Matrix) {
        gemm::gemm_acc_seq(c, alpha, a, b);
    }
    fn matmul_tn(&self, a: &Matrix, b: &Matrix) -> Matrix {
        gemm::matmul_tn_seq(a, b)
    }
    fn matmul_nt(&self, a: &Matrix, b: &Matrix) -> Matrix {
        gemm::matmul_nt_seq(a, b)
    }
    fn syrk(&self, m: &Matrix) -> Matrix {
        gemm::syrk_seq(m)
    }
    fn ea_gram_update(&self, dst: &mut Matrix, rho: f64, m: &Matrix, denom: f64) {
        gemm::ea_gram_update_seq(dst, rho, m, denom);
    }
    fn sym_evd_batch(&self, mats: &[&Matrix]) -> Vec<Evd> {
        mats.iter().map(|m| evd::sym_evd(m)).collect()
    }
}

static REFERENCE: Reference = Reference;
static THREADED: threaded::Threaded = threaded::Threaded;

/// The backend matching the installed [`BackendKind`].
pub fn active() -> &'static dyn Backend {
    match current().kind {
        BackendKind::Reference => &REFERENCE,
        BackendKind::Threaded => &THREADED,
    }
}

/// `C = A·B` on the sketch path: dispatches on the installed [`Precision`].
/// Only `rnla::sketch::range_finder` routes through here — every other
/// GEMM in the repo goes straight to the pinned-f64 kernels.
pub fn sketch_matmul(a: &Matrix, b: &Matrix) -> Matrix {
    match current().precision {
        Precision::F64 => gemm::matmul(a, b),
        Precision::Mixed => threaded::mixed_matmul(a, b),
    }
}

/// `C = Aᵀ·B` on the sketch path (precision-dispatched like
/// [`sketch_matmul`]).
pub fn sketch_matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    match current().precision {
        Precision::F64 => gemm::matmul_tn(a, b),
        Precision::Mixed => threaded::mixed_matmul_tn(a, b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for k in [BackendKind::Reference, BackendKind::Threaded] {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
        }
        for p in [Precision::F64, Precision::Mixed] {
            assert_eq!(Precision::parse(p.name()), Some(p));
        }
        assert_eq!(BackendKind::parse("openblas"), None);
        assert_eq!(Precision::parse("f32"), None);
    }

    #[test]
    fn scoped_install_restores() {
        let before = current();
        {
            let _g = scoped(BackendKind::Threaded, 3, Precision::Mixed);
            let sel = current();
            assert_eq!(sel.kind, BackendKind::Threaded);
            assert_eq!(sel.threads, 3);
            assert_eq!(sel.precision, Precision::Mixed);
            assert_eq!(active().name(), "threaded");
        }
        assert_eq!(current(), before);
    }

    #[test]
    fn auto_threads_resolve_to_concrete_count() {
        let _g = scoped(BackendKind::Threaded, 0, Precision::F64);
        assert!(current().threads >= 1);
    }

    #[test]
    fn mixed_policy_rejects_exact_paths() {
        assert!(!mixed_precision_supported(Some("exact")));
        assert!(!mixed_precision_supported(Some("trunc")));
        assert!(mixed_precision_supported(Some("rsvd")));
        assert!(mixed_precision_supported(Some("srevd")));
        assert!(mixed_precision_supported(Some("nystrom")));
        assert!(mixed_precision_supported(None));
    }
}
