//! Disjoint-tile multi-threaded kernels, bitwise-equal to the reference.
//!
//! Every kernel here follows the same recipe: partition the *output* into
//! disjoint row blocks, hand each block to one scoped worker thread, and
//! inside a block run a loop whose per-element f64 accumulation order is
//! exactly the reference kernel's (inner dimension strictly ascending,
//! panel by panel). Threads never share an output element, so there is no
//! reduction order to get wrong — see the module docs of
//! [`super`](crate::linalg::backend) for the full determinism contract.
//!
//! Workers are `std::thread::scope` threads spawned per call (the work-size
//! gate [`plan_threads`] keeps spawn overhead out of small kernels); no
//! external thread-pool crate is available in this build environment and
//! none is needed — the kernels that matter run for milliseconds.

use crate::linalg::backend::{current, Backend, BackendKind};
use crate::linalg::evd::{self, Evd};
use crate::linalg::gemm::{self, KC};
use crate::linalg::Matrix;

/// Flop threshold below which a kernel stays on the calling thread: thread
/// spawn/join costs ~tens of microseconds, which a sub-millisecond kernel
/// cannot amortize. Gating is a pure perf heuristic — results are bitwise
/// identical either way.
const PAR_MIN_WORK: f64 = 2e6;

/// Effective worker count for a kernel of `work` estimated flops under the
/// installed selection (1 = run inline on the calling thread).
pub(crate) fn plan_threads(work: f64) -> usize {
    let sel = current();
    if sel.kind != BackendKind::Threaded || work < PAR_MIN_WORK {
        1
    } else {
        sel.threads
    }
}

/// Even split of `n` units across `t` workers: returns `t + 1` monotonic
/// bounds starting at 0 and ending at `n` (earlier chunks take the
/// remainder).
pub(crate) fn even_bounds(n: usize, t: usize) -> Vec<usize> {
    let t = t.clamp(1, n.max(1));
    let base = n / t;
    let rem = n % t;
    let mut bounds = Vec::with_capacity(t + 1);
    let mut acc = 0;
    bounds.push(0);
    for i in 0..t {
        acc += base + usize::from(i < rem);
        bounds.push(acc);
    }
    bounds
}

/// Area-balanced split of the rows of a `d × d` upper triangle: row `i`
/// covers `d - i` entries, so equal-width row blocks would leave the first
/// worker with almost all the flops. Bounds equalize the triangle area
/// `cost(r) = Σ_{i<r} (d - i)` instead.
pub(crate) fn triangle_bounds(d: usize, t: usize) -> Vec<usize> {
    let t = t.clamp(1, d.max(1));
    let cost = |r: usize| r * d - r * (r - 1) / 2;
    let total = cost(d);
    let mut bounds = Vec::with_capacity(t + 1);
    bounds.push(0);
    let mut r = 0usize;
    for i in 1..t {
        let target = total * i / t;
        while r < d && cost(r) < target {
            r += 1;
        }
        bounds.push(r);
    }
    bounds.push(d);
    bounds
}

/// Run `body` over disjoint chunks of `data` on scoped threads. `bounds`
/// are monotonic unit indices (as from [`even_bounds`]), each unit spanning
/// `unit` elements of `data`; `body(first_unit, chunk)` owns its chunk
/// exclusively. Empty chunks are skipped; a single non-empty chunk runs
/// inline. The final chunk always runs on the calling thread, so `t`
/// workers means `t - 1` spawns.
pub(crate) fn run_chunks<T: Send>(
    data: &mut [T],
    unit: usize,
    bounds: &[usize],
    body: &(dyn Fn(usize, &mut [T]) + Sync),
) {
    let spans: Vec<(usize, usize)> =
        bounds.windows(2).map(|w| (w[0], w[1])).filter(|&(lo, hi)| hi > lo).collect();
    match spans.len() {
        0 => {}
        1 => {
            let (lo, hi) = spans[0];
            body(lo, &mut data[lo * unit..hi * unit]);
        }
        _ => {
            std::thread::scope(|s| {
                let mut rest = &mut data[spans[0].0 * unit..];
                let mut off = spans[0].0;
                for (idx, &(lo, hi)) in spans.iter().enumerate() {
                    if lo > off {
                        let (_, tail) = rest.split_at_mut((lo - off) * unit);
                        rest = tail;
                    }
                    let (chunk, tail) = rest.split_at_mut((hi - lo) * unit);
                    rest = tail;
                    off = hi;
                    if idx + 1 == spans.len() {
                        body(lo, chunk);
                    } else {
                        s.spawn(move || body(lo, chunk));
                    }
                }
            });
        }
    }
}

/// The threaded backend (see module docs).
pub struct Threaded;

impl Backend for Threaded {
    fn name(&self) -> &'static str {
        "threaded"
    }

    fn gemm_acc(&self, c: &mut Matrix, alpha: f64, a: &Matrix, b: &Matrix) {
        let (m, k) = a.shape();
        let n = b.cols();
        let t = plan_threads(2.0 * m as f64 * k as f64 * n as f64);
        if t <= 1 {
            gemm::gemm_acc_seq(c, alpha, a, b);
            return;
        }
        let bounds = even_bounds(m, t);
        run_chunks(c.as_mut_slice(), n, &bounds, &|lo, block| {
            gemm_rows(block, lo, alpha, a, b);
        });
    }

    fn matmul_tn(&self, a: &Matrix, b: &Matrix) -> Matrix {
        let (k, m) = a.shape();
        let n = b.cols();
        let t = plan_threads(2.0 * m as f64 * k as f64 * n as f64);
        if t <= 1 {
            return gemm::matmul_tn_seq(a, b);
        }
        let mut c = Matrix::zeros(m, n);
        let bounds = even_bounds(m, t);
        run_chunks(c.as_mut_slice(), n, &bounds, &|lo, block| {
            tn_rows(block, lo, a, b);
        });
        c
    }

    fn matmul_nt(&self, a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let n = b.rows();
        let t = plan_threads(2.0 * m as f64 * k as f64 * n as f64);
        if t <= 1 {
            return gemm::matmul_nt_seq(a, b);
        }
        let mut c = Matrix::zeros(m, n);
        let bounds = even_bounds(m, t);
        run_chunks(c.as_mut_slice(), n, &bounds, &|lo, block| {
            let rows = block.len() / n;
            for r in 0..rows {
                let arow = a.row(lo + r);
                let crow = &mut block[r * n..(r + 1) * n];
                for (j, cj) in crow.iter_mut().enumerate() {
                    *cj = gemm::dot(arow, b.row(j));
                }
            }
        });
        c
    }

    fn syrk(&self, m: &Matrix) -> Matrix {
        let (d, cols) = m.shape();
        let t = plan_threads(d as f64 * d as f64 * cols as f64);
        if t <= 1 {
            return gemm::syrk_seq(m);
        }
        let mut s = Matrix::zeros(d, d);
        let bounds = triangle_bounds(d, t);
        run_chunks(s.as_mut_slice(), d, &bounds, &|lo, block| {
            let rows = block.len() / d;
            for r in 0..rows {
                let i = lo + r;
                let mi = m.row(i);
                let srow = &mut block[r * d..(r + 1) * d];
                for (j, sj) in srow.iter_mut().enumerate().skip(i) {
                    *sj = gemm::dot(mi, m.row(j));
                }
            }
        });
        mirror_upper(&mut s);
        s
    }

    fn ea_gram_update(&self, dst: &mut Matrix, rho: f64, m: &Matrix, denom: f64) {
        let (d, cols) = m.shape();
        let t = plan_threads(d as f64 * d as f64 * cols as f64);
        if t <= 1 {
            gemm::ea_gram_update_seq(dst, rho, m, denom);
            return;
        }
        let c = (1.0 - rho) / denom;
        let bounds = triangle_bounds(d, t);
        run_chunks(dst.as_mut_slice(), d, &bounds, &|lo, block| {
            let rows = block.len() / d;
            for r in 0..rows {
                let i = lo + r;
                let mi = m.row(i);
                let drow = &mut block[r * d..(r + 1) * d];
                for (j, dj) in drow.iter_mut().enumerate().skip(i) {
                    let acc = gemm::dot(mi, m.row(j));
                    *dj = rho * *dj + c * acc;
                }
            }
        });
        mirror_upper(dst);
    }

    fn sym_evd_batch(&self, mats: &[&Matrix]) -> Vec<Evd> {
        let work: f64 = mats.iter().map(|m| 8.0 * (m.rows() as f64).powi(3)).sum();
        let t = plan_threads(work).min(mats.len().max(1));
        if t <= 1 {
            return mats.iter().map(|m| evd::sym_evd(m)).collect();
        }
        let mut out: Vec<Option<Evd>> = (0..mats.len()).map(|_| None).collect();
        let bounds = even_bounds(mats.len(), t);
        run_chunks(&mut out, 1, &bounds, &|lo, chunk| {
            for (r, slot) in chunk.iter_mut().enumerate() {
                *slot = Some(evd::sym_evd(mats[lo + r]));
            }
        });
        out.into_iter().map(|e| e.expect("sym_evd_batch: worker skipped a slot")).collect()
    }
}

/// Mirror the upper triangle into the lower (sequential O(d²) pass — the
/// reference kernels mirror element-by-element as they go; the final matrix
/// is identical either way since every value is written exactly once).
fn mirror_upper(s: &mut Matrix) {
    let d = s.rows();
    for i in 0..d {
        for j in (i + 1)..d {
            s[(j, i)] = s[(i, j)];
        }
    }
}

/// `C_block += alpha * A[lo..lo+rows] · B` with the register-tiled 1×4
/// microkernel. Per output element the accumulation visits `p` in the same
/// ascending panel order as `gemm_acc_seq`: the registers round-trip
/// through memory between k-panels (f64 store/load is exact), and within a
/// panel each register sees `+= (alpha·a[i,p])·b[p,j]` for ascending `p` —
/// so the result is bitwise the reference's.
fn gemm_rows(c: &mut [f64], lo: usize, alpha: f64, a: &Matrix, b: &Matrix) {
    let k = a.cols();
    let n = b.cols();
    let rows = c.len() / n.max(1);
    for pc in (0..k).step_by(KC) {
        let pe = (pc + KC).min(k);
        for r in 0..rows {
            let arow = a.row(lo + r);
            let crow = &mut c[r * n..(r + 1) * n];
            let mut j = 0;
            while j + 4 <= n {
                let mut c0 = crow[j];
                let mut c1 = crow[j + 1];
                let mut c2 = crow[j + 2];
                let mut c3 = crow[j + 3];
                for p in pc..pe {
                    let aip = alpha * arow[p];
                    let brow = b.row(p);
                    c0 += aip * brow[j];
                    c1 += aip * brow[j + 1];
                    c2 += aip * brow[j + 2];
                    c3 += aip * brow[j + 3];
                }
                crow[j] = c0;
                crow[j + 1] = c1;
                crow[j + 2] = c2;
                crow[j + 3] = c3;
                j += 4;
            }
            for jj in j..n {
                let mut acc = crow[jj];
                for p in pc..pe {
                    acc += (alpha * arow[p]) * b.row(p)[jj];
                }
                crow[jj] = acc;
            }
        }
    }
}

/// `C_block = (Aᵀ·B)[lo..lo+rows]` — the reference's p-outer rank-1 stream
/// restricted to a row range of the output (per element, `p` ascending,
/// exactly as `matmul_tn_seq`).
fn tn_rows(c: &mut [f64], lo: usize, a: &Matrix, b: &Matrix) {
    let k = a.rows();
    let n = b.cols();
    let rows = c.len() / n.max(1);
    for p in 0..k {
        let arow = a.row(p);
        let brow = b.row(p);
        for r in 0..rows {
            let aip = arow[lo + r];
            let crow = &mut c[r * n..(r + 1) * n];
            for (j, cj) in crow.iter_mut().enumerate() {
                *cj += aip * brow[j];
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Mixed-precision sketch kernels (f32 storage, f64 accumulation).
//
// Operands are demoted to f32 once up front; every partial product is
// computed as `(a32 as f64) * (b32 as f64)` and accumulated in f64, so the
// only precision loss is the one rounding per operand — the regime the
// paper's noise-tolerance argument covers. Same disjoint-row partitioning
// and ascending-p order as the f64 kernels: deterministic in the thread
// count (though of course not bitwise-equal to the f64 path).
// ---------------------------------------------------------------------------

fn demote(m: &Matrix) -> Vec<f32> {
    m.as_slice().iter().map(|&v| v as f32).collect()
}

fn mixed_rows(c: &mut [f64], lo: usize, a32: &[f32], k: usize, b32: &[f32], n: usize) {
    let rows = c.len() / n.max(1);
    for r in 0..rows {
        let arow = &a32[(lo + r) * k..(lo + r + 1) * k];
        let crow = &mut c[r * n..(r + 1) * n];
        for (p, &ap) in arow.iter().enumerate() {
            let aip = ap as f64;
            let brow = &b32[p * n..(p + 1) * n];
            for (j, cj) in crow.iter_mut().enumerate() {
                *cj += aip * brow[j] as f64;
            }
        }
    }
}

/// Mixed-precision `C = A · B` (sketch path only).
pub fn mixed_matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "mixed_matmul: inner dim mismatch");
    let (m, k) = a.shape();
    let n = b.cols();
    let _sp = crate::obs::span_kernel(
        "linalg.gemm",
        2.0 * m as f64 * k as f64 * n as f64,
        crate::obs::GEMM_SPAN_MIN_WORK,
    )
    .arg("precision", "mixed");
    let a32 = demote(a);
    let b32 = demote(b);
    let mut c = Matrix::zeros(m, n);
    let t = plan_threads(2.0 * m as f64 * k as f64 * n as f64);
    let bounds = even_bounds(m, t);
    run_chunks(c.as_mut_slice(), n, &bounds, &|lo, block| {
        mixed_rows(block, lo, &a32, k, &b32, n);
    });
    c
}

/// Mixed-precision `C = Aᵀ · B` (sketch path only; A: k×m, B: k×n).
pub fn mixed_matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "mixed_matmul_tn: inner dim mismatch");
    let (k, m) = a.shape();
    let n = b.cols();
    let _sp = crate::obs::span_kernel(
        "linalg.gemm_tn",
        2.0 * m as f64 * k as f64 * n as f64,
        crate::obs::GEMM_SPAN_MIN_WORK,
    )
    .arg("precision", "mixed");
    let a32 = demote(a);
    let b32 = demote(b);
    let mut c = Matrix::zeros(m, n);
    let t = plan_threads(2.0 * m as f64 * k as f64 * n as f64);
    let bounds = even_bounds(m, t);
    run_chunks(c.as_mut_slice(), n, &bounds, &|lo, block| {
        let rows = block.len() / n.max(1);
        for p in 0..k {
            let arow = &a32[p * m..(p + 1) * m];
            let brow = &b32[p * n..(p + 1) * n];
            for r in 0..rows {
                let aip = arow[lo + r] as f64;
                let crow = &mut block[r * n..(r + 1) * n];
                for (j, cj) in crow.iter_mut().enumerate() {
                    *cj += aip * brow[j] as f64;
                }
            }
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_bounds_cover_and_balance() {
        for &(n, t) in &[(10, 3), (7, 7), (5, 8), (1, 4), (0, 2), (64, 4)] {
            let b = even_bounds(n, t);
            assert_eq!(*b.first().unwrap(), 0);
            assert_eq!(*b.last().unwrap(), n);
            for w in b.windows(2) {
                assert!(w[1] >= w[0]);
                assert!(w[1] - w[0] <= n / t.clamp(1, n.max(1)) + 1);
            }
        }
    }

    #[test]
    fn triangle_bounds_cover_and_roughly_balance() {
        let d = 100;
        let t = 4;
        let b = triangle_bounds(d, t);
        assert_eq!(b.len(), t + 1);
        assert_eq!(b[0], 0);
        assert_eq!(b[t], d);
        let cost = |lo: usize, hi: usize| -> usize { (lo..hi).map(|i| d - i).sum() };
        let total = cost(0, d);
        for w in b.windows(2) {
            // No chunk should exceed ~2x its fair share of the triangle.
            assert!(cost(w[0], w[1]) <= 2 * total / t + d);
        }
    }

    #[test]
    fn run_chunks_partitions_exclusively() {
        let mut data = vec![0usize; 12];
        let bounds = even_bounds(4, 3); // 4 rows of 3 elements
        run_chunks(&mut data, 3, &bounds, &|lo, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = lo * 3 + i + 1;
            }
        });
        let expect: Vec<usize> = (1..=12).collect();
        assert_eq!(data, expect);
    }

    #[test]
    fn run_chunks_skips_empty_spans() {
        let mut data = vec![0u8; 4];
        run_chunks(&mut data, 1, &[0, 0, 2, 2, 4], &|lo, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = (lo + i) as u8 + 1;
            }
        });
        assert_eq!(data, vec![1, 2, 3, 4]);
    }
}
