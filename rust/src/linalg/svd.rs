//! Singular value decomposition.
//!
//! Two routes, matching how they are used in the randomized algorithms:
//! - [`jacobi_svd`]: one-sided Jacobi — high accuracy, fine for the *small*
//!   `(r+l)×n` matrix `B` inside RSVD (Alg. 2 line 7), where the (r+l)²·n
//!   cost is part of the advertised complexity budget.
//! - [`thin_svd`]: convenience wrapper that picks an orientation so the
//!   Jacobi sweep happens on the smaller side.

use crate::linalg::{gemm, Matrix};

/// Thin SVD `X = U Σ Vᵀ`, singular values descending.
pub struct Svd {
    pub u: Matrix,     // m × p
    pub sigma: Vec<f64>, // p
    pub v: Matrix,     // n × p  (NOT transposed)
}

impl Svd {
    /// Reconstruct `U Σ Vᵀ` (test helper).
    pub fn reconstruct(&self) -> Matrix {
        let mut us = self.u.clone();
        gemm::scale_cols(&mut us, &self.sigma);
        gemm::matmul_nt(&us, &self.v)
    }

    /// Truncate to rank r.
    pub fn truncate(&self, r: usize) -> Svd {
        let r = r.min(self.sigma.len());
        Svd {
            u: self.u.first_cols(r),
            sigma: self.sigma[..r].to_vec(),
            v: self.v.first_cols(r),
        }
    }
}

/// One-sided Jacobi SVD of `a` (m×n, m ≥ n): rotates column pairs of a
/// working copy of A until they are mutually orthogonal; the column norms
/// are then the singular values, the normalized columns are U, and the
/// accumulated rotations give V.
/// Perf note (EXPERIMENTS.md §Perf): the sweep operates on the *transposed*
/// working buffer — each column of A is a contiguous row — so the per-pair
/// gram and the rotation stream sequential memory (931 ms → ~200 ms on the
/// RSVD-sized 768×230 case).
pub fn jacobi_svd(a: &Matrix) -> Svd {
    let (m, n) = a.shape();
    assert!(m >= n, "jacobi_svd requires m >= n; transpose first");
    // Backend-annotated but inherently sequential: the Jacobi sweep's
    // rotations form one long dependency chain; only the small RSVD core
    // matrix ever comes through here, so threading it would buy nothing.
    let _sp = crate::obs::span("linalg.svd").arg("m", m).arg("n", n).with_backend();
    // wt row j == column j of A; vt row j == column j of V.
    let mut wt = a.transpose();
    let mut vt = Matrix::eye(n);
    let max_sweeps = 60;
    let eps = 1e-15;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0_f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Contiguous row pair (p < q).
                let (head, tail) = wt.as_mut_slice().split_at_mut(q * m);
                let wp = &mut head[p * m..(p + 1) * m];
                let wq = &mut tail[..m];
                // 2x2 gram of the pair.
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..m {
                    let xp = wp[i];
                    let xq = wq[i];
                    app += xp * xp;
                    aqq += xq * xq;
                    apq += xp * xq;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation zeroing the off-diagonal of the 2x2 gram.
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let xp = wp[i];
                    let xq = wq[i];
                    wp[i] = c * xp - s * xq;
                    wq[i] = s * xp + c * xq;
                }
                let (vhead, vtail) = vt.as_mut_slice().split_at_mut(q * n);
                let vp = &mut vhead[p * n..(p + 1) * n];
                let vq = &mut vtail[..n];
                for i in 0..n {
                    let a0 = vp[i];
                    let b0 = vq[i];
                    vp[i] = c * a0 - s * b0;
                    vq[i] = s * a0 + c * b0;
                }
            }
        }
        if off < 1e-14 {
            break;
        }
    }
    // Extract singular values (row norms of wt) and normalize.
    let mut sigma: Vec<f64> = (0..n)
        .map(|j| wt.row(j).iter().map(|x| x * x).sum::<f64>().sqrt())
        .collect();
    for j in 0..n {
        if sigma[j] > 1e-300 {
            let inv = 1.0 / sigma[j];
            for x in wt.row_mut(j) {
                *x *= inv;
            }
        }
    }
    // Sort descending (reorder rows of wt/vt, then transpose back).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| sigma[j].partial_cmp(&sigma[i]).unwrap());
    let mut ut_s = Matrix::zeros(n, m);
    let mut vt_s = Matrix::zeros(n, n);
    let mut sig_s = vec![0.0; n];
    for (new_j, &old_j) in order.iter().enumerate() {
        sig_s[new_j] = sigma[old_j];
        ut_s.row_mut(new_j).copy_from_slice(wt.row(old_j));
        vt_s.row_mut(new_j).copy_from_slice(vt.row(old_j));
    }
    sigma = sig_s;
    Svd { u: ut_s.transpose(), sigma, v: vt_s.transpose() }
}

/// Thin SVD of an arbitrary matrix; transposes internally when m < n so the
/// Jacobi sweep always runs on the thin side, and swaps U/V back.
pub fn thin_svd(a: &Matrix) -> Svd {
    let (m, n) = a.shape();
    if m >= n {
        jacobi_svd(a)
    } else {
        let svd_t = jacobi_svd(&a.transpose());
        Svd { u: svd_t.v, sigma: svd_t.sigma, v: svd_t.u }
    }
}

/// Spectral norm estimate via a few power iterations (used in error
/// estimators where a full SVD would be overkill).
pub fn spectral_norm_est(a: &Matrix, iters: usize, seed: u64) -> f64 {
    use crate::linalg::rng::Pcg64;
    let mut rng = Pcg64::new(seed);
    let n = a.cols();
    let mut x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
    let norm = |v: &[f64]| v.iter().map(|x| x * x).sum::<f64>().sqrt();
    let mut est = 0.0;
    for _ in 0..iters.max(1) {
        let ax = gemm::gemv(a, &x);
        let atax = gemm::gemv_t(a, &ax);
        let nrm = norm(&atax);
        if nrm < 1e-300 {
            return 0.0;
        }
        est = (nrm / norm(&x).max(1e-300)).sqrt();
        let inv = 1.0 / nrm;
        x = atax.into_iter().map(|v| v * inv).collect();
    }
    est
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::orthogonality_defect;
    use crate::linalg::rng::Pcg64;

    #[test]
    fn svd_reconstructs() {
        let mut rng = Pcg64::new(1);
        for &(m, n) in &[(1, 1), (5, 5), (20, 7), (7, 20), (48, 31)] {
            let a = rng.gaussian_matrix(m, n);
            let svd = thin_svd(&a);
            let rec = svd.reconstruct();
            assert!(rec.rel_err(&a) < 1e-10, "({m},{n}): {}", rec.rel_err(&a));
            assert!(orthogonality_defect(&svd.u) < 1e-10);
            assert!(orthogonality_defect(&svd.v) < 1e-10);
        }
    }

    #[test]
    fn singular_values_descending_nonnegative() {
        let mut rng = Pcg64::new(2);
        let a = rng.gaussian_matrix(15, 10);
        let svd = thin_svd(&a);
        for w in svd.sigma.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(svd.sigma.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn known_diagonal_singular_values() {
        let a = Matrix::from_diag(&[3.0, 5.0, 1.0]);
        let svd = thin_svd(&a);
        let expect = [5.0, 3.0, 1.0];
        for (s, e) in svd.sigma.iter().zip(expect.iter()) {
            assert!((s - e).abs() < 1e-12);
        }
    }

    #[test]
    fn rank_one_matrix() {
        let mut rng = Pcg64::new(3);
        let u = rng.gaussian_matrix(12, 1);
        let v = rng.gaussian_matrix(1, 8);
        let a = gemm::matmul(&u, &v);
        let svd = thin_svd(&a);
        assert!(svd.sigma[0] > 1e-8);
        for &s in &svd.sigma[1..] {
            assert!(s < 1e-10 * svd.sigma[0]);
        }
        assert!(svd.reconstruct().rel_err(&a) < 1e-10);
    }

    #[test]
    fn truncation_error_is_tail_energy() {
        // Eckart–Young sanity: ||A - A_r||_F² ≈ Σ_{i>r} σ_i².
        let mut rng = Pcg64::new(4);
        let a = rng.gaussian_matrix(20, 12);
        let svd = thin_svd(&a);
        let r = 5;
        let rec = svd.truncate(r).reconstruct();
        let err = (&a - &rec).fro_norm();
        let tail: f64 = svd.sigma[r..].iter().map(|s| s * s).sum::<f64>().sqrt();
        assert!((err - tail).abs() < 1e-8 * tail.max(1.0));
    }

    #[test]
    fn svd_agrees_with_evd_on_spd() {
        let mut rng = Pcg64::new(5);
        let m = rng.gaussian_matrix(10, 14);
        let s = gemm::syrk(&m);
        let svd = thin_svd(&s);
        let evd = crate::linalg::evd::sym_evd(&s);
        for (sv, ev) in svd.sigma.iter().zip(evd.lambda.iter()) {
            assert!((sv - ev).abs() < 1e-8 * evd.lambda[0], "{sv} vs {ev}");
        }
    }

    #[test]
    fn spectral_norm_est_close_to_sigma_max() {
        let mut rng = Pcg64::new(6);
        let a = rng.gaussian_matrix(25, 18);
        let svd = thin_svd(&a);
        let est = spectral_norm_est(&a, 30, 7);
        assert!((est - svd.sigma[0]).abs() < 1e-3 * svd.sigma[0]);
    }
}
