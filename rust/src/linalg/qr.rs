//! Householder QR decompositions.
//!
//! `thin_qr` is the orthonormalization step of the randomized range finder
//! (Alg. 2/3, line 5) — its cost O(m(r+l)²) is part of the paper's
//! complexity accounting, so it is implemented directly (not via Gram–
//! Schmidt, which loses orthogonality for the ill-conditioned sketches that
//! power iteration produces).

use crate::linalg::backend::threaded::{even_bounds, plan_threads, run_chunks};
use crate::linalg::{gemm, Matrix};

/// Result of a thin QR: `A = Q R` with Q m×n orthonormal columns, R n×n
/// upper-triangular (requires m ≥ n).
pub struct ThinQr {
    pub q: Matrix,
    pub r: Matrix,
}

/// Householder thin QR of `a` (m×n, m ≥ n).
///
/// Perf note (EXPERIMENTS.md §Perf): the factorization runs on the
/// *transposed* working buffer — each column of A is a contiguous row of
/// `wt` — so every reflector dot/axpy streams sequential memory instead of
/// striding by `n`. This took the 768×230 case from 145 ms to ~20 ms.
///
/// Under the threaded backend the per-reflector trailing update and the
/// backward Q accumulation fan their *independent column rows* out over the
/// disjoint-tile partition primitive: each trailing column is touched by
/// exactly one thread and its dot/axpy runs the identical sequential code,
/// so the factorization stays bitwise-equal to the reference at any thread
/// count (the reflector construction itself is inherently sequential).
pub fn thin_qr(a: &Matrix) -> ThinQr {
    let (m, n) = a.shape();
    assert!(m >= n, "thin_qr requires m >= n, got {m}x{n}");
    let _sp = crate::obs::span("linalg.qr").arg("m", m).arg("n", n).with_backend();
    // wt row j == column j of A (length m).
    let mut wt = a.transpose();
    let mut betas = vec![0.0; n];
    for k in 0..n {
        // Split so the reflector row (k) and the trailing rows borrow apart.
        let (head, tail) = wt.as_mut_slice().split_at_mut((k + 1) * m);
        let col_k = &mut head[k * m..];
        // Build the Householder reflector from col_k[k..m].
        let mut norm2 = 0.0;
        for &v in &col_k[k..] {
            norm2 += v * v;
        }
        let norm = norm2.sqrt();
        if norm == 0.0 {
            betas[k] = 0.0;
            continue;
        }
        let alpha = if col_k[k] >= 0.0 { -norm } else { norm };
        let v0 = col_k[k] - alpha;
        let vtv = norm2 - col_k[k] * col_k[k] + v0 * v0;
        let beta = if vtv == 0.0 { 0.0 } else { 2.0 / vtv };
        // Normalize the stored vector to implicit-leading-1 form.
        col_k[k] = 1.0;
        let inv_v0 = 1.0 / v0;
        for v in &mut col_k[k + 1..] {
            *v *= inv_v0;
        }
        let beta_n = beta * v0 * v0;
        betas[k] = beta_n;
        // Apply the reflector to the trailing columns (= rows of wt),
        // partitioned disjointly across backend threads (each trailing
        // column's update is independent and runs the same scalar code).
        let v = &col_k[k..];
        let trailing = n - k - 1;
        let t = plan_threads(4.0 * trailing as f64 * (m - k) as f64);
        let bounds = even_bounds(trailing, t);
        run_chunks(&mut tail[..trailing * m], m, &bounds, &|_lo, chunk| {
            let rows = chunk.len() / m;
            for j in 0..rows {
                let row = &mut chunk[j * m + k..j * m + m];
                let s = gemm::dot(v, row);
                let sb = beta_n * s;
                for (r, &vi) in row.iter_mut().zip(v.iter()) {
                    *r -= sb * vi;
                }
            }
        });
        // Row k of R is written on the fly below via alpha; remember it.
        col_k[k] = alpha; // temporarily hold alpha; restored to 1 implicitly
        // (the Q accumulation below re-reads col_k[k+1..] only).
    }

    // Extract R (upper n×n): R[i][j] = wt[j][i] for i ≤ j; diag from alphas.
    let mut r = Matrix::zeros(n, n);
    for j in 0..n {
        let col_j = &wt.as_slice()[j * m..(j + 1) * m];
        for i in 0..=j {
            r[(i, j)] = col_j[i];
        }
    }

    // Accumulate Q in transposed form: qt row j == column j of Q (length m).
    let mut qt = Matrix::zeros(n, m);
    for i in 0..n {
        qt[(i, i)] = 1.0;
    }
    for k in (0..n).rev() {
        let beta = betas[k];
        if beta == 0.0 {
            continue;
        }
        let wrow = &wt.as_slice()[k * m..(k + 1) * m];
        // Columns of Q (rows of qt) update independently: same disjoint
        // row partition as the trailing update above.
        let t = plan_threads(4.0 * n as f64 * (m - k) as f64);
        let bounds = even_bounds(n, t);
        run_chunks(qt.as_mut_slice(), m, &bounds, &|_lo, chunk| {
            let rows = chunk.len() / m;
            for j in 0..rows {
                let qrow = &mut chunk[j * m + k..(j + 1) * m];
                // v̂ = [1, wrow[k+1..]]
                let mut s = qrow[0];
                s += gemm::dot(&wrow[k + 1..], &qrow[1..]);
                let sb = beta * s;
                qrow[0] -= sb;
                for (q, &vi) in qrow[1..].iter_mut().zip(wrow[k + 1..].iter()) {
                    *q -= sb * vi;
                }
            }
        });
    }
    ThinQr { q: qt.transpose(), r }
}

/// Orthonormalize the columns of `a` (the `orth` routine used between power
/// iterations in the range finder). Returns Q with the same span.
pub fn orthonormalize(a: &Matrix) -> Matrix {
    thin_qr(a).q
}

/// Back-substitution solve `R x = b` for upper-triangular R (n×n), b n×k.
pub fn solve_upper_triangular(r: &Matrix, b: &Matrix) -> Matrix {
    let n = r.rows();
    assert!(r.is_square() && b.rows() == n, "solve_upper_triangular: shape");
    let k = b.cols();
    let mut x = b.clone();
    for col in 0..k {
        for i in (0..n).rev() {
            let mut s = x[(i, col)];
            for j in (i + 1)..n {
                s -= r[(i, j)] * x[(j, col)];
            }
            let d = r[(i, i)];
            assert!(d.abs() > 1e-300, "solve_upper_triangular: singular R at {i}");
            x[(i, col)] = s / d;
        }
    }
    x
}

/// Forward-substitution solve `L x = b` for lower-triangular L.
pub fn solve_lower_triangular(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert!(l.is_square() && b.rows() == n, "solve_lower_triangular: shape");
    let k = b.cols();
    let mut x = b.clone();
    for col in 0..k {
        for i in 0..n {
            let mut s = x[(i, col)];
            for j in 0..i {
                s -= l[(i, j)] * x[(j, col)];
            }
            let d = l[(i, i)];
            assert!(d.abs() > 1e-300, "solve_lower_triangular: singular L at {i}");
            x[(i, col)] = s / d;
        }
    }
    x
}

/// `||QᵀQ - I||_max` — orthogonality defect, used by tests and invariants.
pub fn orthogonality_defect(q: &Matrix) -> f64 {
    let qtq = gemm::matmul_tn(q, q);
    let mut m = 0.0_f64;
    for i in 0..qtq.rows() {
        for j in 0..qtq.cols() {
            let target = if i == j { 1.0 } else { 0.0 };
            m = m.max((qtq[(i, j)] - target).abs());
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Pcg64;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Pcg64::new(1);
        for &(m, n) in &[(4, 4), (10, 3), (33, 17), (64, 64), (100, 10)] {
            let a = rng.gaussian_matrix(m, n);
            let ThinQr { q, r } = thin_qr(&a);
            assert_eq!(q.shape(), (m, n));
            assert_eq!(r.shape(), (n, n));
            let qr = gemm::matmul(&q, &r);
            assert!(qr.rel_err(&a) < 1e-11, "({m},{n}): err {}", qr.rel_err(&a));
            assert!(orthogonality_defect(&q) < 1e-11, "({m},{n}): defect");
        }
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Pcg64::new(2);
        let a = rng.gaussian_matrix(20, 8);
        let ThinQr { r, .. } = thin_qr(&a);
        for i in 0..8 {
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn qr_rank_deficient_does_not_blow_up() {
        // Column 2 = column 0 + column 1: rank-deficient input.
        let mut rng = Pcg64::new(3);
        let b = rng.gaussian_matrix(12, 2);
        let c2 = Matrix::from_fn(12, 1, |i, _| b[(i, 0)] + b[(i, 1)]);
        let a = b.hcat(&c2);
        let ThinQr { q, r } = thin_qr(&a);
        let qr = gemm::matmul(&q, &r);
        assert!(qr.rel_err(&a) < 1e-10);
        assert!(q.all_finite());
    }

    #[test]
    fn orthonormalize_spans_same_space() {
        let mut rng = Pcg64::new(4);
        let a = rng.gaussian_matrix(30, 5);
        let q = orthonormalize(&a);
        assert!(orthogonality_defect(&q) < 1e-11);
        // Projection of A onto span(Q) must reproduce A.
        let proj = gemm::matmul(&q, &gemm::matmul_tn(&q, &a));
        assert!(proj.rel_err(&a) < 1e-10);
    }

    #[test]
    fn triangular_solves() {
        let mut rng = Pcg64::new(5);
        let a = rng.gaussian_matrix(9, 9);
        let ThinQr { r, .. } = thin_qr(&a);
        let b = rng.gaussian_matrix(9, 3);
        let x = solve_upper_triangular(&r, &b);
        assert!(gemm::matmul(&r, &x).rel_err(&b) < 1e-10);

        let l = r.transpose();
        let y = solve_lower_triangular(&l, &b);
        assert!(gemm::matmul(&l, &y).rel_err(&b) < 1e-10);
    }
}
