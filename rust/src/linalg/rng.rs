//! Deterministic pseudo-random numbers: PCG64 core + Gaussian sampling.
//!
//! No `rand` crate is available offline; this is a self-contained PCG-XSL-RR
//! 128/64 generator (O'Neill 2014) with Box–Muller normals. Everything that
//! consumes randomness in the library (RSVD test matrices, synthetic data,
//! initialization, dropout) threads one of these through explicitly, so all
//! experiments are reproducible from a seed.

use crate::linalg::Matrix;

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

/// PCG-XSL-RR 128/64 pseudo-random generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

impl Pcg64 {
    /// Seed with a stream id of 1.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 1)
    }

    /// Seed with an explicit stream (distinct streams never collide).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next uniform u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift; bias is negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (one draw per call; the pair's twin is
    /// discarded for simplicity — throughput is not a concern here).
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Normal with given mean / std.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Gaussian matrix — the Ω test matrix of RSVD/SREVD (Alg. 2/3 line 3).
    pub fn gaussian_matrix(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| self.gaussian())
    }

    /// Uniform matrix in [lo, hi).
    pub fn uniform_matrix(&mut self, rows: usize, cols: usize, lo: f64, hi: f64) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| self.uniform_in(lo, hi))
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k ≤ n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k > n");
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher–Yates: only the first k positions need finishing.
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Split off an independent child generator (for per-worker streams).
    pub fn split(&mut self) -> Pcg64 {
        let seed = self.next_u64();
        let stream = self.next_u64() | 1;
        Pcg64::with_stream(seed, stream)
    }

    /// Raw generator state `(state, inc)` — the full position of this
    /// stream, for checkpointing. Restoring via [`Pcg64::from_raw`]
    /// continues the sequence exactly where it left off.
    pub fn raw_state(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Pcg64::raw_state`] output. The restored
    /// generator produces the identical continuation of the stream.
    pub fn from_raw(state: u128, inc: u128) -> Pcg64 {
        Pcg64 { state, inc }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Pcg64::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::new(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.gaussian();
            s1 += g;
            s2 += g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Pcg64::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = rng.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(11);
        let idx = rng.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(13);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn raw_state_roundtrip_continues_stream() {
        let mut a = Pcg64::with_stream(42, 31337);
        for _ in 0..17 {
            a.next_u64(); // advance mid-stream
        }
        let (state, inc) = a.raw_state();
        let mut b = Pcg64::from_raw(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // Gaussians (Box–Muller consumes a variable number of uniforms)
        // continue identically too.
        for _ in 0..50 {
            assert_eq!(a.gaussian().to_bits(), b.gaussian().to_bits());
        }
    }

    #[test]
    fn split_streams_independent() {
        let mut parent = Pcg64::new(5);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }
}
