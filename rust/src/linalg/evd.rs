//! Symmetric eigendecomposition — the O(d³) kernel that vanilla K-FAC spends
//! its time in (Alg. 1 line 12) and that RS-KFAC / SRE-KFAC replace.
//!
//! Implementation: Householder tridiagonalization with accumulation of the
//! orthogonal transform (EISPACK `tred2`), followed by implicit-shift QL
//! iteration (`tql2`). Eigenvalues are returned in *descending* order, to
//! match the paper's convention (λ₁ = λ_max, truncation keeps the first r).

use crate::linalg::Matrix;

/// Eigendecomposition `A = U diag(λ) Uᵀ` of a symmetric matrix,
/// eigenvalues descending.
pub struct Evd {
    /// Orthonormal eigenvectors, one per column, ordered like `lambda`.
    pub u: Matrix,
    /// Eigenvalues, descending.
    pub lambda: Vec<f64>,
}

impl Evd {
    /// Reconstruct `U diag(λ) Uᵀ` (test helper).
    pub fn reconstruct(&self) -> Matrix {
        let n = self.u.rows();
        let k = self.lambda.len();
        let mut scaled = self.u.clone();
        crate::linalg::gemm::scale_cols(&mut scaled, &self.lambda);
        let ut = self.u.slice(0, n, 0, k).transpose();
        crate::linalg::gemm::matmul(&scaled, &ut)
    }

    /// Truncate to the top-r modes.
    pub fn truncate(&self, r: usize) -> Evd {
        let r = r.min(self.lambda.len());
        Evd { u: self.u.first_cols(r), lambda: self.lambda[..r].to_vec() }
    }
}

/// Symmetric eigendecomposition. Panics if `a` is not square; symmetry is
/// assumed (only the lower triangle is read during tridiagonalization).
pub fn sym_evd(a: &Matrix) -> Evd {
    let n = a.rows();
    assert!(a.is_square(), "sym_evd: matrix must be square");
    if n == 0 {
        return Evd { u: Matrix::zeros(0, 0), lambda: vec![] };
    }
    let _sp = crate::obs::span("linalg.evd").arg("dim", n).with_backend();
    let mut z = a.clone(); // will become the eigenvector matrix
    let mut d = vec![0.0; n]; // diagonal
    let mut e = vec![0.0; n]; // off-diagonal
    tred2(&mut z, &mut d, &mut e);
    tql2(&mut z, &mut d, &mut e);
    // Sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| d[j].partial_cmp(&d[i]).unwrap());
    let lambda: Vec<f64> = order.iter().map(|&i| d[i]).collect();
    let mut u = Matrix::zeros(n, n);
    for (new_j, &old_j) in order.iter().enumerate() {
        for i in 0..n {
            u[(i, new_j)] = z[(i, old_j)];
        }
    }
    Evd { u, lambda }
}

/// Batched symmetric EVD: one independent [`sym_evd`] per input, results in
/// input order. Under the threaded backend the matrices are partitioned
/// disjointly across workers (per-block K-factor spectra are many small
/// EVDs — ideal embarrassing parallelism); each decomposition runs the
/// identical sequential code, so results are bitwise-equal to mapping
/// [`sym_evd`] at any thread count.
pub fn sym_evd_batch(mats: &[&Matrix]) -> Vec<Evd> {
    let _sp = crate::obs::span("linalg.evd_batch").arg("count", mats.len()).with_backend();
    crate::linalg::backend::active().sym_evd_batch(mats)
}

/// Householder reduction of a real symmetric matrix to tridiagonal form,
/// accumulating the transformation in `z` (EISPACK tred2).
fn tred2(z: &mut Matrix, d: &mut [f64], e: &mut [f64]) {
    let n = z.rows();
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        let mut scale = 0.0;
        if l > 0 {
            for k in 0..=l {
                scale += z[(i, k)].abs();
            }
            if scale == 0.0 {
                e[i] = z[(i, l)];
            } else {
                for k in 0..=l {
                    let v = z[(i, k)] / scale;
                    z[(i, k)] = v;
                    h += v * v;
                }
                let mut f = z[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[(i, l)] = f - g;
                let mut f_acc = 0.0;
                for j in 0..=l {
                    z[(j, i)] = z[(i, j)] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z[(j, k)] * z[(i, k)];
                    }
                    for k in (j + 1)..=l {
                        g += z[(k, j)] * z[(i, k)];
                    }
                    e[j] = g / h;
                    f_acc += e[j] * z[(i, j)];
                }
                let hh = f_acc / (h + h);
                for j in 0..=l {
                    f = z[(i, j)];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        let delta = f * e[k] + g * z[(i, k)];
                        z[(j, k)] -= delta;
                    }
                }
            }
        } else {
            e[i] = z[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += z[(i, k)] * z[(k, j)];
                }
                for k in 0..i {
                    let delta = g * z[(k, i)];
                    z[(k, j)] -= delta;
                }
            }
        }
        d[i] = z[(i, i)];
        z[(i, i)] = 1.0;
        for j in 0..i {
            z[(j, i)] = 0.0;
            z[(i, j)] = 0.0;
        }
    }
}

/// Implicit-shift QL iteration on a symmetric tridiagonal matrix, with
/// eigenvector accumulation (EISPACK tql2).
fn tql2(z: &mut Matrix, d: &mut [f64], e: &mut [f64]) {
    let n = z.rows();
    if n <= 1 {
        return;
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find small subdiagonal element.
            let mut m = l;
            while m < n - 1 {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter <= 50, "tql2: too many iterations");
            // Form shift.
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            let sign_r = if g >= 0.0 { r.abs() } else { -r.abs() };
            g = d[m] - d[l] + e[l] / (g + sign_r);
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // Accumulate eigenvectors.
                for k in 0..n {
                    f = z[(k, i + 1)];
                    z[(k, i + 1)] = s * z[(k, i)] + c * f;
                    z[(k, i)] = c * z[(k, i)] - s * f;
                }
            }
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
}

/// Damped inverse application via full EVD: `(A + λI)^{-1} B` for symmetric
/// PSD `A` — exactly what K-FAC does with each Kronecker factor.
pub fn evd_damped_inverse_apply(evd: &Evd, lambda: f64, b: &Matrix) -> Matrix {
    use crate::linalg::gemm;
    // (U D Uᵀ + λI)^{-1} B = U (D+λ)^{-1} Uᵀ B   (U full orthonormal)
    let utb = gemm::matmul_tn(&evd.u, b);
    let inv: Vec<f64> = evd.lambda.iter().map(|&l| 1.0 / (l + lambda)).collect();
    let mut scaled = utb;
    gemm::scale_rows(&mut scaled, &inv);
    gemm::matmul(&evd.u, &scaled)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm;
    use crate::linalg::qr::orthogonality_defect;
    use crate::linalg::rng::Pcg64;

    fn random_spd(rng: &mut Pcg64, n: usize) -> Matrix {
        let m = rng.gaussian_matrix(n, n.max(2));
        let mut s = gemm::syrk(&m);
        s.add_diag(0.1);
        s
    }

    #[test]
    fn evd_reconstructs_symmetric() {
        let mut rng = Pcg64::new(1);
        for &n in &[1usize, 2, 3, 5, 16, 40, 77] {
            let a = random_spd(&mut rng, n);
            let evd = sym_evd(&a);
            let rec = evd.reconstruct();
            assert!(rec.rel_err(&a) < 1e-10, "n={n}: err {}", rec.rel_err(&a));
            assert!(orthogonality_defect(&evd.u) < 1e-10, "n={n}");
        }
    }

    #[test]
    fn eigenvalues_descending_and_nonnegative_for_psd() {
        let mut rng = Pcg64::new(2);
        let a = random_spd(&mut rng, 25);
        let evd = sym_evd(&a);
        for w in evd.lambda.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(evd.lambda.iter().all(|&l| l > 0.0));
    }

    #[test]
    fn known_eigenvalues_diagonal() {
        let a = Matrix::from_diag(&[3.0, -1.0, 7.0, 0.5]);
        let evd = sym_evd(&a);
        let expect = [7.0, 3.0, 0.5, -1.0];
        for (l, &e) in evd.lambda.iter().zip(expect.iter()) {
            assert!((l - e).abs() < 1e-12, "{l} vs {e}");
        }
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Matrix::from_vec(2, 2, vec![2., 1., 1., 2.]);
        let evd = sym_evd(&a);
        assert!((evd.lambda[0] - 3.0).abs() < 1e-12);
        assert!((evd.lambda[1] - 1.0).abs() < 1e-12);
        // Eigenvector of λ=3 is (1,1)/√2 up to sign.
        let v = evd.u.col(0);
        assert!((v[0].abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-10);
        assert!((v[0] - v[1]).abs() < 1e-10);
    }

    #[test]
    fn eigen_relation_av_eq_lv() {
        let mut rng = Pcg64::new(3);
        let a = random_spd(&mut rng, 30);
        let evd = sym_evd(&a);
        for j in [0usize, 5, 29] {
            let v = evd.u.col(j);
            let av = gemm::gemv(&a, &v);
            for i in 0..30 {
                assert!(
                    (av[i] - evd.lambda[j] * v[i]).abs() < 1e-8 * evd.lambda[0].max(1.0),
                    "mode {j}, row {i}"
                );
            }
        }
    }

    #[test]
    fn repeated_eigenvalues() {
        // I * 4 has all eigenvalues 4; any orthonormal U is valid.
        let a = &Matrix::eye(6) * 4.0;
        let evd = sym_evd(&a);
        for &l in &evd.lambda {
            assert!((l - 4.0).abs() < 1e-12);
        }
        assert!(evd.reconstruct().rel_err(&a) < 1e-12);
    }

    #[test]
    fn damped_inverse_apply_matches_direct() {
        let mut rng = Pcg64::new(4);
        let a = random_spd(&mut rng, 12);
        let evd = sym_evd(&a);
        let b = rng.gaussian_matrix(12, 4);
        let x = evd_damped_inverse_apply(&evd, 0.3, &b);
        // Verify (A + 0.3 I) x == b
        let mut adamp = a.clone();
        adamp.add_diag(0.3);
        let ax = gemm::matmul(&adamp, &x);
        assert!(ax.rel_err(&b) < 1e-9);
    }

    #[test]
    fn truncate_keeps_top_modes() {
        let mut rng = Pcg64::new(5);
        let a = random_spd(&mut rng, 10);
        let evd = sym_evd(&a);
        let t = evd.truncate(3);
        assert_eq!(t.u.shape(), (10, 3));
        assert_eq!(t.lambda.len(), 3);
        assert_eq!(t.lambda[..], evd.lambda[..3]);
    }

    #[test]
    fn batch_matches_individual_bitwise() {
        let mut rng = Pcg64::new(6);
        let mats: Vec<Matrix> = [3usize, 11, 7, 1].iter().map(|&n| random_spd(&mut rng, n)).collect();
        let refs: Vec<&Matrix> = mats.iter().collect();
        let batch = sym_evd_batch(&refs);
        assert_eq!(batch.len(), mats.len());
        for (m, e) in mats.iter().zip(batch.iter()) {
            let single = sym_evd(m);
            assert_eq!(single.lambda.len(), e.lambda.len());
            for (a, b) in single.lambda.iter().zip(e.lambda.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert!(single.u == e.u, "batch eigenvectors must match bitwise");
        }
    }
}
