//! Matrix multiplication kernels.
//!
//! These are the hot loops of the whole optimizer stack: the EA gram update
//! `ρĀ + (1-ρ)MMᵀ` (syrk), the RSVD sketch `XΩ` (gemm), `B = QᵀX` (gemm_tn)
//! and the low-rank inverse application (gemm chains). They are written as
//! cache-blocked row-major kernels with an explicitly transposed-B inner
//! loop so the innermost accumulation always streams contiguous memory.

use crate::linalg::backend;
use crate::linalg::Matrix;

/// Loop blocking size for the k-dimension panels (shared with the threaded
/// backend so its per-element accumulation order matches panel-for-panel).
pub(crate) const KC: usize = 256;
/// Loop blocking size for rows of A.
const MC: usize = 64;

/// Coarse 2mnk flop estimate gating the size-thresholded gemm spans.
fn gemm_work(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

/// `C = A · B`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul: inner dim mismatch {:?}x{:?}", a.shape(), b.shape());
    let _sp = crate::obs::span_kernel(
        "linalg.gemm",
        gemm_work(a.rows(), a.cols(), b.cols()),
        crate::obs::GEMM_SPAN_MIN_WORK,
    );
    let mut c = Matrix::zeros(a.rows(), b.cols());
    backend::active().gemm_acc(&mut c, 1.0, a, b);
    c
}

/// `C += alpha * A · B`, dispatched to the installed backend.
pub fn gemm_acc(c: &mut Matrix, alpha: f64, a: &Matrix, b: &Matrix) {
    let (m, k) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(k, kb, "gemm_acc: inner dim mismatch");
    assert_eq!(c.shape(), (m, n), "gemm_acc: output shape mismatch");
    backend::active().gemm_acc(c, alpha, a, b);
}

/// `C += alpha * A · B` — the reference blocked kernel body.
///
/// Row-major A (m×k), row-major B (k×n). For each k-panel we walk B by rows,
/// broadcasting `a[i][p]` against the contiguous row `b[p][..]`, which keeps
/// the inner loop a pure fused-multiply-add over sequential memory (good for
/// auto-vectorization on a single core).
///
/// Dense contract: every partial product `alpha·a[i,p]·b[p,j]` is added, in
/// ascending-`p` order, with no data-dependent skips — NaN/inf in either
/// operand propagate exactly as IEEE addition dictates. (An earlier version
/// skipped `alpha·a[i,p] == 0.0` panels as a fast path; that silently broke
/// NaN propagation and signed-zero semantics versus this contract, and since
/// an accumulator seeded at +0.0 can never round to -0.0, dropping the skip
/// changes no finite result bitwise. Pinned by `dense_contract_*` tests.)
pub(crate) fn gemm_acc_seq(c: &mut Matrix, alpha: f64, a: &Matrix, b: &Matrix) {
    let (m, k) = a.shape();
    let n = b.cols();
    for pc in (0..k).step_by(KC) {
        let pe = (pc + KC).min(k);
        for ic in (0..m).step_by(MC) {
            let ie = (ic + MC).min(m);
            for i in ic..ie {
                let arow = a.row(i);
                let crow = c.row_mut(i);
                for p in pc..pe {
                    let aip = alpha * arow[p];
                    let brow = b.row(p);
                    // innermost: contiguous axpy over row of B and C
                    for j in 0..n {
                        crow[j] += aip * brow[j];
                    }
                }
            }
        }
    }
}

/// `C = Aᵀ · B` without materializing the transpose (A: k×m, B: k×n → C: m×n).
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let (k, m) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(k, kb, "matmul_tn: inner dim mismatch");
    let _sp = crate::obs::span_kernel(
        "linalg.gemm_tn",
        gemm_work(m, k, n),
        crate::obs::GEMM_SPAN_MIN_WORK,
    );
    backend::active().matmul_tn(a, b)
}

/// Reference body for [`matmul_tn`] (same dense no-skip contract as
/// [`gemm_acc_seq`]).
pub(crate) fn matmul_tn_seq(a: &Matrix, b: &Matrix) -> Matrix {
    let (k, m) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    // Stream over rows of A and B simultaneously: rank-1 update per p.
    for p in 0..k {
        let arow = a.row(p);
        let brow = b.row(p);
        for i in 0..m {
            let aip = arow[i];
            let crow = c.row_mut(i);
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
    }
    c
}

/// `C = A · Bᵀ` without materializing the transpose (A: m×k, B: n×k → C: m×n).
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let (n, kb) = b.shape();
    assert_eq!(k, kb, "matmul_nt: inner dim mismatch");
    let _sp = crate::obs::span_kernel(
        "linalg.gemm_nt",
        gemm_work(m, k, n),
        crate::obs::GEMM_SPAN_MIN_WORK,
    );
    backend::active().matmul_nt(a, b)
}

/// Reference body for [`matmul_nt`].
pub(crate) fn matmul_nt_seq(a: &Matrix, b: &Matrix) -> Matrix {
    let m = a.rows();
    let n = b.rows();
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..n {
            crow[j] = dot(arow, b.row(j));
        }
    }
    c
}

/// Symmetric rank-k update `S = M · Mᵀ` (M: d×n → S: d×d), computing only the
/// upper triangle and mirroring. This is the K-factor gram kernel: `AAᵀ`,
/// `GGᵀ` (Alg. 1 lines 4/8). Roughly half the flops of a general matmul.
pub fn syrk(m: &Matrix) -> Matrix {
    let (d, _n) = m.shape();
    let _sp = crate::obs::span_kernel(
        "linalg.syrk",
        gemm_work(d, m.cols(), d) / 2.0,
        crate::obs::GEMM_SPAN_MIN_WORK,
    );
    backend::active().syrk(m)
}

/// Reference body for [`syrk`].
pub(crate) fn syrk_seq(m: &Matrix) -> Matrix {
    let d = m.rows();
    let mut s = Matrix::zeros(d, d);
    for i in 0..d {
        let mi = m.row(i);
        for j in i..d {
            let acc = dot(mi, m.row(j));
            s[(i, j)] = acc;
            s[(j, i)] = acc;
        }
    }
    s
}

/// Fused EA gram update: `dst = rho*dst + (1-rho)/denom * M·Mᵀ`.
///
/// `denom` is the batch normalization constant (e.g. batch size for the
/// forward factor). Only the upper triangle is computed, then mirrored —
/// this is the L3-native mirror of the L1 `ea_gram` Pallas kernel.
pub fn ea_gram_update(dst: &mut Matrix, rho: f64, m: &Matrix, denom: f64) {
    let (d, _n) = m.shape();
    assert_eq!(dst.shape(), (d, d), "ea_gram_update: shape mismatch");
    backend::active().ea_gram_update(dst, rho, m, denom);
}

/// Reference body for [`ea_gram_update`].
pub(crate) fn ea_gram_update_seq(dst: &mut Matrix, rho: f64, m: &Matrix, denom: f64) {
    let d = m.rows();
    let c = (1.0 - rho) / denom;
    for i in 0..d {
        for j in i..d {
            let acc = dot(m.row(i), m.row(j));
            let v = rho * dst[(i, j)] + c * acc;
            dst[(i, j)] = v;
            dst[(j, i)] = v;
        }
    }
}

/// Matrix–vector product `y = A x`.
pub fn gemv(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len(), "gemv: dim mismatch");
    (0..a.rows())
        .map(|i| {
            let row = a.row(i);
            let mut acc = 0.0;
            for p in 0..x.len() {
                acc += row[p] * x[p];
            }
            acc
        })
        .collect()
}

/// `y = Aᵀ x` (same dense no-skip contract as [`gemm_acc_seq`]).
pub fn gemv_t(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len(), "gemv_t: dim mismatch");
    let mut y = vec![0.0; a.cols()];
    for p in 0..a.rows() {
        let row = a.row(p);
        let xp = x[p];
        for j in 0..y.len() {
            y[j] += xp * row[j];
        }
    }
    y
}

/// Dot product — 4 independent accumulators to break the FP-add latency
/// chain (≈2× on long vectors; EXPERIMENTS.md §Perf).
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let (mut a0, mut a1, mut a2, mut a3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = 4 * c;
        a0 += x[i] * y[i];
        a1 += x[i + 1] * y[i + 1];
        a2 += x[i + 2] * y[i + 2];
        a3 += x[i + 3] * y[i + 3];
    }
    let mut acc = (a0 + a1) + (a2 + a3);
    for i in 4 * chunks..n {
        acc += x[i] * y[i];
    }
    acc
}

/// Scale columns: `A · diag(d)` in place.
pub fn scale_cols(a: &mut Matrix, d: &[f64]) {
    assert_eq!(a.cols(), d.len(), "scale_cols: dim mismatch");
    for i in 0..a.rows() {
        let row = a.row_mut(i);
        for j in 0..d.len() {
            row[j] *= d[j];
        }
    }
}

/// Scale rows: `diag(d) · A` in place.
pub fn scale_rows(a: &mut Matrix, d: &[f64]) {
    assert_eq!(a.rows(), d.len(), "scale_rows: dim mismatch");
    for i in 0..a.rows() {
        let di = d[i];
        for v in a.row_mut(i) {
            *v *= di;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Pcg64;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut acc = 0.0;
                for p in 0..a.cols() {
                    acc += a[(i, p)] * b[(p, j)];
                }
                c[(i, j)] = acc;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg64::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 33, 9), (64, 65, 66), (130, 7, 257)] {
            let a = rng.gaussian_matrix(m, k);
            let b = rng.gaussian_matrix(k, n);
            let c = matmul(&a, &b);
            let c0 = naive_matmul(&a, &b);
            assert!(c.rel_err(&c0) < 1e-12, "({m},{k},{n}) err={}", c.rel_err(&c0));
        }
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::new(2);
        let a = rng.gaussian_matrix(13, 13);
        let i = Matrix::eye(13);
        assert!(matmul(&a, &i).rel_err(&a) < 1e-14);
        assert!(matmul(&i, &a).rel_err(&a) < 1e-14);
    }

    #[test]
    fn tn_and_nt_match_explicit_transpose() {
        let mut rng = Pcg64::new(3);
        let a = rng.gaussian_matrix(20, 12);
        let b = rng.gaussian_matrix(20, 7);
        let c = matmul_tn(&a, &b);
        let c0 = matmul(&a.transpose(), &b);
        assert!(c.rel_err(&c0) < 1e-12);

        let d = rng.gaussian_matrix(9, 20);
        let e = rng.gaussian_matrix(11, 20);
        let f = matmul_nt(&d, &e);
        let f0 = matmul(&d, &e.transpose());
        assert!(f.rel_err(&f0) < 1e-12);
    }

    #[test]
    fn syrk_matches_mmt_and_is_symmetric() {
        let mut rng = Pcg64::new(4);
        let m = rng.gaussian_matrix(15, 31);
        let s = syrk(&m);
        let s0 = matmul_nt(&m, &m);
        assert!(s.rel_err(&s0) < 1e-12);
        assert!(s.asymmetry() < 1e-14);
    }

    #[test]
    fn ea_gram_update_matches_formula() {
        let mut rng = Pcg64::new(5);
        let m = rng.gaussian_matrix(10, 6);
        let mut dst = rng.gaussian_matrix(10, 10);
        dst.symmetrize();
        let mut expect = dst.clone();
        expect.scale_inplace(0.9);
        let mut g = syrk(&m);
        g.scale_inplace(0.1 / 6.0);
        expect += &g;
        ea_gram_update(&mut dst, 0.9, &m, 6.0);
        assert!(dst.rel_err(&expect) < 1e-12);
        assert!(dst.asymmetry() < 1e-13);
    }

    #[test]
    fn gemv_matches_matmul() {
        let mut rng = Pcg64::new(6);
        let a = rng.gaussian_matrix(8, 5);
        let x: Vec<f64> = (0..5).map(|i| i as f64 - 2.0).collect();
        let y = gemv(&a, &x);
        let y0 = matmul(&a, &Matrix::col_vector(&x));
        for i in 0..8 {
            assert!((y[i] - y0[(i, 0)]).abs() < 1e-12);
        }
        let z = gemv_t(&a, &y);
        let z0 = matmul_tn(&a, &Matrix::col_vector(&y));
        for j in 0..5 {
            assert!((z[j] - z0[(j, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn scale_rows_cols() {
        let mut rng = Pcg64::new(7);
        let a0 = rng.gaussian_matrix(6, 4);
        let d: Vec<f64> = (0..4).map(|i| (i + 1) as f64).collect();
        let mut a = a0.clone();
        scale_cols(&mut a, &d);
        assert!(a.rel_err(&matmul(&a0, &Matrix::from_diag(&d))) < 1e-13);
        let r: Vec<f64> = (0..6).map(|i| 0.5 + i as f64).collect();
        let mut b = a0.clone();
        scale_rows(&mut b, &r);
        assert!(b.rel_err(&matmul(&Matrix::from_diag(&r), &a0)) < 1e-13);
    }

    #[test]
    fn gemm_acc_accumulates() {
        let mut rng = Pcg64::new(8);
        let a = rng.gaussian_matrix(5, 5);
        let b = rng.gaussian_matrix(5, 5);
        let mut c = Matrix::eye(5);
        gemm_acc(&mut c, 2.0, &a, &b);
        let mut expect = matmul(&a, &b);
        expect.scale_inplace(2.0);
        expect += &Matrix::eye(5);
        assert!(c.rel_err(&expect) < 1e-12);
    }

    /// Pins the dense no-skip contract (ISSUE 8 satellite): a NaN anywhere
    /// in B poisons every output element it participates in, even when the
    /// matching A entry is exactly zero — the old `if aip != 0.0` fast path
    /// silently suppressed this.
    #[test]
    fn dense_contract_nan_propagates_through_zero_rows() {
        let mut a = Matrix::zeros(2, 3);
        a[(1, 1)] = 2.0; // row 0 of A is all exact zeros
        let mut b = Matrix::ones(3, 2);
        b[(1, 0)] = f64::NAN;
        let c = matmul(&a, &b);
        assert!(c[(0, 0)].is_nan(), "0 * NaN must produce NaN, not be skipped");
        assert!(c[(1, 0)].is_nan());
        assert_eq!(c[(0, 1)], 0.0);
        assert_eq!(c[(1, 1)], 2.0);
        // gemv_t follows the same contract: xp == 0.0 no longer skips a row.
        let y = gemv_t(&b, &[0.0, 0.0, 1.0]);
        assert!(y[0].is_nan(), "0 * NaN must poison gemv_t too");
        assert_eq!(y[1], 1.0);
    }

    /// For finite inputs the dropped skip is bitwise-neutral: exact zeros
    /// in A (ReLU activations produce them in real runs) yield the same
    /// bits as the naive triple loop, and a +0.0-seeded accumulator never
    /// becomes -0.0 whatever the sign mix of the partial products.
    #[test]
    fn dense_contract_exact_zeros_bitwise_match_naive() {
        let mut rng = Pcg64::new(9);
        let mut a = rng.gaussian_matrix(7, 9);
        // Sprinkle exact signed zeros like a ReLU mask would.
        for i in 0..7 {
            for p in 0..9 {
                if (i + p) % 3 == 0 {
                    a[(i, p)] = 0.0;
                }
                if (i + p) % 4 == 0 {
                    a[(i, p)] = -0.0;
                }
            }
        }
        let b = rng.gaussian_matrix(9, 5);
        let c = matmul(&a, &b);
        let c0 = naive_matmul(&a, &b);
        for i in 0..7 {
            for j in 0..5 {
                assert_eq!(
                    c[(i, j)].to_bits(),
                    c0[(i, j)].to_bits(),
                    "bit mismatch at ({i},{j})"
                );
            }
        }
        // All-zero row times anything is +0.0, never -0.0.
        let z = matmul(&Matrix::zeros(1, 4), &rng.gaussian_matrix(4, 3));
        for j in 0..3 {
            assert_eq!(z[(0, j)].to_bits(), 0.0f64.to_bits());
        }
    }
}
