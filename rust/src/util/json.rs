//! Minimal JSON parser and writer.
//!
//! No serde is available offline; this is a small recursive-descent parser
//! supporting the full JSON value grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null), plus a compact serializer
//! (`Display`) used by the obs exporters (JSONL event stream, Chrome
//! trace). Readers: the PJRT `artifacts/manifest.json` at startup and
//! `rkfac report` re-ingesting a run's JSONL.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj[key]` convenience; returns None for non-objects/missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}

impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}

impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}

impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}

/// Escape a string per the JSON grammar (quotes, backslash, control chars).
pub fn escape_into(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl Json {
    fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // JSON has no NaN/Inf literals; degrade to null so the
                // emitted document always re-parses.
                if n.is_finite() {
                    out.push_str(&format!("{n}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => escape_into(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(out, k);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write_into(&mut s);
        f.write_str(&s)
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                            code = code * 16 + d;
                        }
                        // Surrogate pairs are not needed for our manifests;
                        // map unpaired surrogates to the replacement char.
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = (start + width).min(self.bytes.len());
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(), Some("c"));
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A");
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = parse("\"λ±é\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "λ±é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn serializer_roundtrips_through_parser() {
        let mut obj = BTreeMap::new();
        obj.insert("name".to_string(), Json::Str("a\"b\\c\nd\u{1}".into()));
        obj.insert("n".to_string(), Json::Num(-3.5));
        obj.insert("i".to_string(), Json::Num(42.0));
        obj.insert("flag".to_string(), Json::Bool(true));
        obj.insert("none".to_string(), Json::Null);
        obj.insert(
            "arr".to_string(),
            Json::Arr(vec![Json::Num(1.0), Json::Str("λ±é".into())]),
        );
        let v = Json::Obj(obj);
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
        // Integral floats print without a trailing ".0" (compact form).
        assert!(text.contains("\"i\":42"));
    }

    #[test]
    fn serializer_maps_nonfinite_to_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn from_impls() {
        assert_eq!(Json::from(3usize), Json::Num(3.0));
        assert_eq!(Json::from(7u64), Json::Num(7.0));
        assert_eq!(Json::from("x"), Json::Str("x".into()));
        assert_eq!(Json::from(false), Json::Bool(false));
    }

    #[test]
    fn manifest_shape_roundtrip() {
        let text = r#"{"version": 1, "artifacts": [
            {"name": "m", "file": "m.hlo.txt",
             "inputs": [{"shape": [64, 32], "dtype": "float32"}],
             "outputs": [{"shape": [], "dtype": "float32"}]}]}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        let ins = arts[0].get("inputs").unwrap().as_arr().unwrap();
        let shape: Vec<usize> =
            ins[0].get("shape").unwrap().as_arr().unwrap().iter().map(|d| d.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![64, 32]);
        let out_shape = arts[0].get("outputs").unwrap().as_arr().unwrap()[0].get("shape").unwrap();
        assert_eq!(out_shape.as_arr().unwrap().len(), 0);
    }
}
