//! Benchmark toolkit — criterion is unavailable offline, so the
//! `rust/benches/*` harness=false targets share this: warmup + N timed
//! samples, mean ± std, simple table/CSV output, and a log-log slope fit
//! for the scaling experiments (E4).

use crate::coordinator::metrics::mean_std;
use crate::obs::clock::Stopwatch;

/// Timing result of one benchmark case.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub mean_s: f64,
    pub std_s: f64,
    pub n: usize,
}

/// Time `f` with `warmup` unmeasured runs then `samples` measured runs.
pub fn bench(name: &str, warmup: usize, samples: usize, mut f: impl FnMut()) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let sw = Stopwatch::start();
        f();
        times.push(sw.elapsed_s());
    }
    let (mean_s, std_s) = mean_std(&times);
    Sample { name: name.to_string(), mean_s, std_s, n: times.len() }
}

/// Pretty-print a set of samples as an aligned table.
pub fn print_table(title: &str, samples: &[Sample]) {
    println!("\n== {title} ==");
    let w = samples.iter().map(|s| s.name.len()).max().unwrap_or(8).max(8);
    println!("{:<w$} {:>12} {:>12} {:>4}", "case", "mean", "std", "n", w = w);
    for s in samples {
        println!(
            "{:<w$} {:>12} {:>12} {:>4}",
            s.name,
            format_secs(s.mean_s),
            format_secs(s.std_s),
            s.n,
            w = w
        );
    }
}

/// Human-scale seconds.
pub fn format_secs(s: f64) -> String {
    if s.is_nan() {
        "—".into()
    } else if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

/// Least-squares slope of log(y) vs log(x) — the empirical scaling
/// exponent: ~3 for EVD, ~2 for the randomized decompositions (E4).
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least two points");
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.ln()).collect();
    let n = lx.len() as f64;
    let mx = lx.iter().sum::<f64>() / n;
    let my = ly.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for i in 0..lx.len() {
        num += (lx[i] - mx) * (ly[i] - my);
        den += (lx[i] - mx) * (lx[i] - mx);
    }
    num / den
}

/// Quick-mode switch for CI-speed bench runs: `RKFAC_BENCH_QUICK=1`.
pub fn quick_mode() -> bool {
    std::env::var("RKFAC_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Write samples as CSV under results/.
pub fn write_csv(path: &str, samples: &[Sample]) -> anyhow::Result<()> {
    let mut log = crate::coordinator::metrics::CsvLogger::create(path, &["case", "mean_s", "std_s", "n"])?;
    for s in samples {
        log.row(&[s.name.clone(), format!("{}", s.mean_s), format!("{}", s.std_s), s.n.to_string()])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let s = bench("spin", 1, 3, || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            std::hint::black_box(acc);
        });
        assert!(s.mean_s > 0.0);
        assert_eq!(s.n, 3);
    }

    #[test]
    fn slope_of_cubic_is_three() {
        let xs = [64.0, 128.0, 256.0, 512.0];
        let ys: Vec<f64> = xs.iter().map(|x| 1e-9 * x * x * x).collect();
        let slope = loglog_slope(&xs, &ys);
        assert!((slope - 3.0).abs() < 1e-9, "slope {slope}");
    }

    #[test]
    fn slope_of_quadratic_is_two() {
        let xs = [64.0, 128.0, 256.0];
        let ys: Vec<f64> = xs.iter().map(|x| 5e-7 * x * x).collect();
        assert!((loglog_slope(&xs, &ys) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn format_ranges() {
        assert_eq!(format_secs(2.5), "2.500s");
        assert_eq!(format_secs(0.0025), "2.500ms");
        assert_eq!(format_secs(2.5e-6), "2.5µs");
    }
}
