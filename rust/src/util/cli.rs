//! Minimal CLI argument parser (no clap offline): subcommand + `--key value`
//! flags + `--switch` booleans.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, flags, positionals.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    /// Last value per flag (`--key value`; repeats overwrite).
    pub flags: BTreeMap<String, String>,
    /// Every `(flag, value)` pair in command-line order, across flags —
    /// what repeatable flags (`--set a=1 --set b=2`) and order-sensitive
    /// merges (layered config overrides) consume.
    pub ordered: Vec<(String, String)>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        if let Some(first) = iter.peek() {
            if !first.starts_with('-') {
                out.subcommand = iter.next();
            }
        }
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                match iter.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = iter.next().unwrap();
                        out.ordered.push((name.to_string(), v.clone()));
                        out.flags.insert(name.to_string(), v);
                    }
                    _ => out.switches.push(name.to_string()),
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Every occurrence of a repeatable flag, in command-line order.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.ordered.iter().filter(|(f, _)| f == key).map(|(_, v)| v.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --config c.toml --epochs 5 --quiet");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("config"), Some("c.toml"));
        assert_eq!(a.get_usize("epochs", 0), 5);
        assert!(a.has("quiet"));
        assert!(!a.has("verbose"));
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--x 1");
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get("x"), Some("1"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_or("solver", "rs-kfac"), "rs-kfac");
        assert_eq!(a.get_f64("lr", 0.3), 0.3);
    }

    #[test]
    fn repeated_flags_collected_in_order() {
        let a = parse("train --set a=1 --set b=2 --set a=3");
        assert_eq!(a.get_all("set"), vec!["a=1", "b=2", "a=3"]);
        assert_eq!(a.get("set"), Some("a=3"), "scalar view keeps last");
        assert!(a.get_all("missing").is_empty());
    }

    #[test]
    fn ordered_preserves_cross_flag_order() {
        let a = parse("train --set a=1 --epochs 5 --set b=2");
        let got: Vec<(&str, &str)> =
            a.ordered.iter().map(|(f, v)| (f.as_str(), v.as_str())).collect();
        assert_eq!(got, vec![("set", "a=1"), ("epochs", "5"), ("set", "b=2")]);
    }

    #[test]
    fn negative_number_as_value() {
        // "--lr -0.5": '-0.5' does not start with '--' → treated as value.
        let a = parse("x --lr -0.5");
        assert_eq!(a.get_f64("lr", 0.0), -0.5);
    }
}
