//! Mini property-testing framework (proptest is unavailable offline).
//!
//! Seeded generators + a runner that reports the failing case number and
//! seed, so failures reproduce deterministically. Used by the coordinator
//! invariants in `rust/tests/prop_invariants.rs`.

use crate::linalg::{Matrix, Pcg64};

/// Number of cases per property (override with RKFAC_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("RKFAC_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(32)
}

/// A generation context handed to generators and properties.
pub struct Gen<'a> {
    pub rng: &'a mut Pcg64,
}

impl<'a> Gen<'a> {
    /// usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    /// f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.uniform() < 0.5
    }

    /// Gaussian matrix with the given shape.
    pub fn matrix(&mut self, rows: usize, cols: usize) -> Matrix {
        self.rng.gaussian_matrix(rows, cols)
    }

    /// Random symmetric PSD matrix with a decaying spectrum (the EA
    /// K-factor shape) of dimension `n` and decay rate in (0, 1).
    pub fn decaying_psd(&mut self, n: usize, decay: f64) -> Matrix {
        let g = self.matrix(n, n);
        let q = crate::linalg::qr::orthonormalize(&g);
        let lam: Vec<f64> = (0..n).map(|i| decay.powi(i as i32)).collect();
        let mut qd = q.clone();
        crate::linalg::gemm::scale_cols(&mut qd, &lam);
        crate::linalg::gemm::matmul_nt(&qd, &q)
    }

    /// Class labels in [0, classes).
    pub fn labels(&mut self, n: usize, classes: usize) -> Vec<usize> {
        (0..n).map(|_| self.rng.below(classes)).collect()
    }
}

/// Run `prop` for `cases` seeded cases; panics with the case index + seed
/// on the first failure (re-run with that seed to reproduce).
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen<'_>) -> Result<(), String>) {
    let base_seed = 0xC0FFEE_u64;
    for case in 0..cases {
        let seed = base_seed.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Pcg64::new(seed);
        let mut g = Gen { rng: &mut rng };
        if let Err(msg) = prop(&mut g) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assertion helpers for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * b.abs().max(1.0) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("sum-commutes", 16, |g| {
            let a = g.f64_in(-5.0, 5.0);
            let b = g.f64_in(-5.0, 5.0);
            ensure_close(a + b, b + a, 1e-15, "a+b")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn check_reports_failure_with_seed() {
        check("always-fails", 4, |_| Err("nope".into()));
    }

    #[test]
    fn decaying_psd_is_psd_with_decay() {
        check("psd-gen", 8, |g| {
            let n = g.usize_in(3, 12);
            let m = g.decaying_psd(n, 0.6);
            ensure(m.asymmetry() < 1e-10, "symmetric")?;
            let e = crate::linalg::evd::sym_evd(&m);
            ensure(e.lambda.iter().all(|&l| l > -1e-10), "PSD")?;
            ensure((e.lambda[0] - 1.0).abs() < 1e-8, "λmax = 1")
        });
    }

    #[test]
    fn gen_ranges() {
        check("ranges", 16, |g| {
            let u = g.usize_in(2, 5);
            ensure((2..=5).contains(&u), format!("usize_in out of range: {u}"))?;
            let f = g.f64_in(-1.0, 1.0);
            ensure((-1.0..1.0).contains(&f), "f64_in out of range")?;
            let l = g.labels(10, 3);
            ensure(l.iter().all(|&x| x < 3), "labels in range")
        });
    }
}
