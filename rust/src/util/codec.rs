//! Little-endian binary codec for checkpoint payloads.
//!
//! The checkpoint subsystem (`coordinator::checkpoint`, the solvers'
//! `Preconditioner::{save_state, load_state}` blobs, the pipeline's slot
//! snapshot) serializes through these two types instead of ad-hoc
//! `to_le_bytes` calls. Every variable-length field is length-prefixed and
//! every read is bounds-checked against the remaining buffer *before* any
//! allocation, so a truncated or corrupted file fails with a positioned
//! error instead of an abort or a silent partial load.
//!
//! Errors are `String`s (the solver layer's error currency); the
//! checkpoint layer wraps them into `anyhow` with file context.

use crate::linalg::Matrix;

/// Append-only little-endian encoder.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u128(&mut self, v: u128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Fixed 4-byte section/blob tag (no length prefix).
    pub fn tag(&mut self, t: &[u8; 4]) {
        self.buf.extend_from_slice(t);
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed `f64` slice.
    pub fn f64s(&mut self, v: &[f64]) {
        self.u64(v.len() as u64);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Matrix as `rows, cols` (u64 each) + row-major values.
    pub fn matrix(&mut self, m: &Matrix) {
        self.u64(m.rows() as u64);
        self.u64(m.cols() as u64);
        for x in m.as_slice() {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed opaque nested blob (lets a reader skip a section it
    /// does not want without understanding its contents).
    pub fn blob(&mut self, b: &[u8]) {
        self.u64(b.len() as u64);
        self.buf.extend_from_slice(b);
    }
}

/// Bounds-checked little-endian decoder over a byte slice.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if n > self.remaining() {
            return Err(format!(
                "truncated data: needed {n} bytes at offset {}, only {} left",
                self.pos,
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    /// Read exactly `n` raw bytes (no length prefix).
    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8], String> {
        self.take(n)
    }

    pub fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn u128(&mut self) -> Result<u128, String> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a fixed tag and verify it.
    pub fn tag(&mut self, expect: &[u8; 4]) -> Result<(), String> {
        let got = self.take(4)?;
        if got != expect {
            return Err(format!(
                "bad tag: expected {:?}, got {:?}",
                String::from_utf8_lossy(expect),
                String::from_utf8_lossy(got)
            ));
        }
        Ok(())
    }

    /// Length-prefixed count, validated against the remaining bytes at
    /// `elem_size` bytes per element (rejects bogus huge counts from
    /// corrupted files before any allocation).
    fn checked_count(&mut self, elem_size: usize) -> Result<usize, String> {
        let n = self.u64()? as usize;
        match n.checked_mul(elem_size) {
            Some(bytes) if bytes <= self.remaining() => Ok(n),
            _ => Err(format!(
                "corrupt length: {n} elements ({elem_size} B each) exceed the {} remaining bytes",
                self.remaining()
            )),
        }
    }

    pub fn str(&mut self) -> Result<String, String> {
        let n = self.checked_count(1)?;
        String::from_utf8(self.take(n)?.to_vec()).map_err(|e| format!("invalid UTF-8 string: {e}"))
    }

    pub fn f64s(&mut self) -> Result<Vec<f64>, String> {
        let n = self.checked_count(8)?;
        let raw = self.take(8 * n)?;
        Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn matrix(&mut self) -> Result<Matrix, String> {
        let rows = self.u64()? as usize;
        let cols = self.u64()? as usize;
        let n = rows
            .checked_mul(cols)
            .filter(|&n| n.checked_mul(8).is_some_and(|b| b <= self.remaining()))
            .ok_or_else(|| {
                format!(
                    "corrupt matrix header: {rows}x{cols} exceeds the {} remaining bytes",
                    self.remaining()
                )
            })?;
        let raw = self.take(8 * n)?;
        let data: Vec<f64> =
            raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect();
        Ok(Matrix::from_vec(rows, cols, data))
    }

    /// Length-prefixed opaque blob.
    pub fn blob(&mut self) -> Result<&'a [u8], String> {
        let n = self.checked_count(1)?;
        self.take(n)
    }

    /// Assert the buffer is fully consumed (trailing garbage is an error —
    /// a half-understood checkpoint must fail loudly, not load a prefix).
    pub fn finish(&self) -> Result<(), String> {
        if self.remaining() != 0 {
            return Err(format!(
                "{} trailing bytes after the last declared field",
                self.remaining()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.u32(0xdead_beef);
        w.u64(u64::MAX - 3);
        w.u128(1u128 << 100);
        w.f64(-0.125);
        w.str("kfac+rsvd");
        w.f64s(&[1.0, 2.5, -3.0]);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xdead_beef);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.u128().unwrap(), 1u128 << 100);
        assert_eq!(r.f64().unwrap(), -0.125);
        assert_eq!(r.str().unwrap(), "kfac+rsvd");
        assert_eq!(r.f64s().unwrap(), vec![1.0, 2.5, -3.0]);
        r.finish().unwrap();
    }

    #[test]
    fn matrix_roundtrip_bitwise() {
        let m = Matrix::from_fn(3, 5, |i, j| (i * 5 + j) as f64 * 0.3 - 1.0);
        let mut w = ByteWriter::new();
        w.matrix(&m);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        let back = r.matrix().unwrap();
        assert_eq!(back.shape(), (3, 5));
        assert_eq!(back.as_slice(), m.as_slice());
        r.finish().unwrap();
    }

    #[test]
    fn truncated_and_garbage_rejected() {
        let mut w = ByteWriter::new();
        w.f64s(&[1.0, 2.0, 3.0]);
        let bytes = w.into_bytes();
        // Truncation inside the payload.
        let mut r = ByteReader::new(&bytes[..bytes.len() - 4]);
        assert!(r.f64s().is_err());
        // A bogus huge length fails before allocating.
        let mut bad = Vec::new();
        bad.extend_from_slice(&u64::MAX.to_le_bytes());
        let mut r = ByteReader::new(&bad);
        assert!(r.f64s().is_err());
        // Trailing bytes are an error.
        let mut w = ByteWriter::new();
        w.u8(1);
        let mut bytes = w.into_bytes();
        bytes.push(0xff);
        let mut r = ByteReader::new(&bytes);
        r.u8().unwrap();
        assert!(r.finish().is_err());
    }

    /// Encode one multi-field record (the checkpoint-blob shape: tag +
    /// scalar + string + f64 list + matrix) with generator-drawn sizes.
    fn sample_record(g: &mut crate::util::prop::Gen<'_>) -> Vec<u8> {
        let (rows, cols) = (g.usize_in(1, 6), g.usize_in(1, 6));
        let m = g.matrix(rows, cols);
        let vals: Vec<f64> = (0..g.usize_in(0, 9)).map(|_| g.f64_in(-2.0, 2.0)).collect();
        let s = &"strategies"[..g.usize_in(0, 10)];
        let mut w = ByteWriter::new();
        w.tag(b"PT01");
        w.u64(vals.len() as u64);
        w.str(s);
        w.f64s(&vals);
        w.matrix(&m);
        w.into_bytes()
    }

    fn decode_record(buf: &[u8]) -> Result<(Vec<f64>, Matrix), String> {
        let mut r = ByteReader::new(buf);
        r.tag(b"PT01")?;
        r.u64()?;
        r.str()?;
        let v = r.f64s()?;
        let m = r.matrix()?;
        r.finish()?;
        Ok((v, m))
    }

    /// Property: every truncation of a valid record fails loudly at some
    /// field — no prefix ever decodes to completion (the fields have fixed
    /// declared sizes, so cutting any suffix starves a later read or
    /// `finish`).
    #[test]
    fn prop_truncations_never_decode_fully() {
        use crate::util::prop::{check, ensure};
        check("codec truncation fails loudly", 64, |g| {
            let bytes = sample_record(g);
            ensure(decode_record(&bytes).is_ok(), "full payload must decode")?;
            let cut = g.usize_in(0, bytes.len() - 1);
            ensure(
                decode_record(&bytes[..cut]).is_err(),
                format!("truncation to {cut}/{} bytes must fail", bytes.len()),
            )
        });
    }

    /// Property: flipping any single bit of a record either errors or
    /// decodes into structures whose sizes are bounded by the buffer — a
    /// corrupted length prefix can never fabricate a huge allocation or a
    /// matrix larger than the bytes that back it.
    #[test]
    fn prop_bit_flips_fail_loudly_or_stay_bounded() {
        use crate::util::prop::{check, ensure};
        check("codec bit flips are safe", 128, |g| {
            let mut bytes = sample_record(g);
            let i = g.usize_in(0, bytes.len() - 1);
            bytes[i] ^= 1 << g.usize_in(0, 7);
            match decode_record(&bytes) {
                // The flip hit payload bytes: values differ but the
                // structure is intact and backed by real bytes.
                Ok((v, m)) => {
                    ensure(v.len() * 8 <= bytes.len(), "f64s len bounded by payload")?;
                    ensure(
                        m.rows() * m.cols() * 8 <= bytes.len(),
                        "matrix size bounded by payload",
                    )
                }
                // The flip hit a tag/length/structure byte: loud error.
                Err(_) => Ok(()),
            }
        });
    }

    /// Oversized dimension headers are rejected by the overflow-checked
    /// size computation — before any allocation happens.
    #[test]
    fn matrix_header_overflow_rejected_before_alloc() {
        let mut w = ByteWriter::new();
        w.u64(u64::MAX);
        w.u64(u64::MAX);
        let bytes = w.into_bytes();
        let err = ByteReader::new(&bytes).matrix().unwrap_err();
        assert!(err.contains("corrupt matrix header"), "{err}");
        // rows*cols fits in usize but rows*cols*8 overflows.
        let mut w = ByteWriter::new();
        w.u64(1u64 << 62);
        w.u64(4);
        let bytes = w.into_bytes();
        let err = ByteReader::new(&bytes).matrix().unwrap_err();
        assert!(err.contains("corrupt matrix header"), "{err}");
    }

    #[test]
    fn tag_and_blob() {
        let mut inner = ByteWriter::new();
        inner.u64(42);
        let mut w = ByteWriter::new();
        w.tag(b"KF01");
        w.blob(&inner.into_bytes());
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert!(r.tag(b"XXXX").is_err());
        let mut r = ByteReader::new(&bytes);
        r.tag(b"KF01").unwrap();
        let blob = r.blob().unwrap();
        r.finish().unwrap();
        let mut br = ByteReader::new(blob);
        assert_eq!(br.u64().unwrap(), 42);
    }
}
