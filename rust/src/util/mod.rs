//! Cross-cutting utilities built in-repo (the offline environment has no
//! serde/clap/criterion/proptest — see DESIGN.md §Offline-dependency note).

pub mod benchkit;
pub mod cli;
pub mod codec;
pub mod json;
pub mod prop;
