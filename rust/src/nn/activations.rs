//! Parameter-free layers: ReLU and (inverted) Dropout.

use crate::linalg::{Matrix, Pcg64};

/// Rectified linear unit.
#[derive(Default)]
pub struct ReLU {
    mask: Option<Matrix>,
}

impl ReLU {
    pub fn new() -> Self {
        ReLU { mask: None }
    }

    pub fn forward(&mut self, x: &Matrix) -> Matrix {
        let mask = x.map(|v| if v > 0.0 { 1.0 } else { 0.0 });
        let out = x.map(|v| v.max(0.0));
        self.mask = Some(mask);
        out
    }

    pub fn backward(&self, dz: &Matrix) -> Matrix {
        let mask = self.mask.as_ref().expect("ReLU::backward before forward");
        assert_eq!(mask.shape(), dz.shape());
        let mut out = dz.clone();
        for (o, m) in out.as_mut_slice().iter_mut().zip(mask.as_slice()) {
            *o *= m;
        }
        out
    }
}

/// Inverted dropout: scales kept units by 1/(1-p) at train time, identity at
/// eval time. The paper's VGG16_bn variant adds dropout(p=0.5) before the
/// final FC layer (§5 footnote 9).
pub struct Dropout {
    pub p: f64,
    mask: Option<Matrix>,
}

impl Dropout {
    pub fn new(p: f64) -> Self {
        assert!((0.0..1.0).contains(&p), "Dropout: p in [0,1)");
        Dropout { p, mask: None }
    }

    pub fn forward(&mut self, x: &Matrix, train: bool, rng: &mut Pcg64) -> Matrix {
        if !train || self.p == 0.0 {
            self.mask = None;
            return x.clone();
        }
        let keep = 1.0 - self.p;
        let mask = Matrix::from_fn(x.rows(), x.cols(), |_, _| {
            if rng.uniform() < keep {
                1.0 / keep
            } else {
                0.0
            }
        });
        let mut out = x.clone();
        for (o, m) in out.as_mut_slice().iter_mut().zip(mask.as_slice()) {
            *o *= m;
        }
        self.mask = Some(mask);
        out
    }

    pub fn backward(&self, dz: &Matrix) -> Matrix {
        match &self.mask {
            None => dz.clone(),
            Some(mask) => {
                let mut out = dz.clone();
                for (o, m) in out.as_mut_slice().iter_mut().zip(mask.as_slice()) {
                    *o *= m;
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_forward_backward() {
        let mut r = ReLU::new();
        let x = Matrix::from_vec(2, 2, vec![1.0, -2.0, 0.0, 3.0]);
        let y = r.forward(&x);
        assert_eq!(y.as_slice(), &[1.0, 0.0, 0.0, 3.0]);
        let dz = Matrix::ones(2, 2);
        let dx = r.backward(&dz);
        assert_eq!(dx.as_slice(), &[1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn dropout_eval_is_identity() {
        let mut d = Dropout::new(0.5);
        let mut rng = Pcg64::new(1);
        let x = rng.gaussian_matrix(4, 4);
        let y = d.forward(&x, false, &mut rng);
        assert!(y.rel_err(&x) < 1e-15);
        // backward with no mask is pass-through
        assert!(d.backward(&x).rel_err(&x) < 1e-15);
    }

    #[test]
    fn dropout_train_preserves_mean() {
        let mut d = Dropout::new(0.3);
        let mut rng = Pcg64::new(2);
        let x = Matrix::ones(100, 100);
        let y = d.forward(&x, true, &mut rng);
        let mean = y.sum() / 10_000.0;
        assert!((mean - 1.0).abs() < 0.05, "mean {mean}");
        // Backward applies the same mask.
        let dx = d.backward(&x);
        assert!(dx.rel_err(&y) < 1e-15);
    }

    #[test]
    fn dropout_zero_p_is_identity_in_train() {
        let mut d = Dropout::new(0.0);
        let mut rng = Pcg64::new(3);
        let x = rng.gaussian_matrix(3, 3);
        assert!(d.forward(&x, true, &mut rng).rel_err(&x) < 1e-15);
    }
}
