//! Native neural-network substrate (the paper's VGG16_bn workload).
//!
//! A column-batch (features × batch) layer stack with K-factor capture:
//! Linear and Conv2d layers record the (A^(l), G^(l)) factor sources that
//! feed the optimizers' EA grams (Alg. 1 lines 3/7). This native engine is
//! the oracle for the PJRT artifact path (`runtime::CompiledModel`) and the
//! engine for architectures (conv/BN) not compiled into artifacts.

pub mod activations;
pub mod batchnorm;
pub mod conv;
pub mod linear;
pub mod loss;
pub mod models;
pub mod network;

pub use conv::MapShape;
pub use network::{KfacCapture, Layer, Network};
