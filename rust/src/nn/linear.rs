//! Fully-connected layer with K-factor capture.
//!
//! Forward: `Z = W X` with X (d_in, B) column-batch; the layer records
//! A = X (the paper's forward factor source) during forward and
//! G = B·(dL/dZ) during backward, plus the weight gradient
//! `dW = (dL/dZ) Xᵀ`. These are exactly the K-FAC quantities of
//! Martens & Grosse (2015) for FC layers (empirical-NG flavour).

use crate::linalg::{gemm, Matrix, Pcg64};

/// Fully-connected layer `Z = W X` (no bias; see DESIGN.md).
pub struct Linear {
    pub w: Matrix,
    pub grad: Matrix,
    /// Captured input activations A^(l) = X (d_in, B).
    pub a_factor: Option<Matrix>,
    /// Captured scaled pre-activation grads G^(l) = B·dL/dZ (d_out, B).
    pub g_factor: Option<Matrix>,
    input: Option<Matrix>,
}

impl Linear {
    pub fn new(d_out: usize, d_in: usize, rng: &mut Pcg64) -> Self {
        // He initialization (matches python model.init_params).
        let scale = (2.0 / d_in as f64).sqrt();
        Linear {
            w: Matrix::from_fn(d_out, d_in, |_, _| scale * rng.gaussian()),
            grad: Matrix::zeros(d_out, d_in),
            a_factor: None,
            g_factor: None,
            input: None,
        }
    }

    pub fn d_in(&self) -> usize {
        self.w.cols()
    }

    pub fn d_out(&self) -> usize {
        self.w.rows()
    }

    pub fn forward(&mut self, x: &Matrix, capture: bool) -> Matrix {
        assert_eq!(x.rows(), self.d_in(), "Linear: input dim mismatch");
        if capture {
            self.a_factor = Some(x.clone());
        }
        self.input = Some(x.clone());
        gemm::matmul(&self.w, x)
    }

    /// `dz`: dL/dZ (d_out, B). Returns dL/dX.
    pub fn backward(&mut self, dz: &Matrix, capture: bool) -> Matrix {
        let x = self.input.as_ref().expect("Linear::backward before forward");
        let batch = x.cols() as f64;
        self.grad = gemm::matmul_nt(dz, x);
        if capture {
            let mut g = dz.clone();
            g.scale_inplace(batch);
            self.g_factor = Some(g);
        }
        gemm::matmul_tn(&self.w, dz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_is_matmul() {
        let mut rng = Pcg64::new(1);
        let mut l = Linear::new(4, 6, &mut rng);
        let x = rng.gaussian_matrix(6, 3);
        let z = l.forward(&x, true);
        assert!(z.rel_err(&gemm::matmul(&l.w, &x)) < 1e-14);
        assert_eq!(l.a_factor.as_ref().unwrap().shape(), (6, 3));
    }

    #[test]
    fn backward_grad_and_gfactor() {
        let mut rng = Pcg64::new(2);
        let mut l = Linear::new(4, 6, &mut rng);
        let x = rng.gaussian_matrix(6, 3);
        let _ = l.forward(&x, true);
        let dz = rng.gaussian_matrix(4, 3);
        let dx = l.backward(&dz, true);
        assert!(l.grad.rel_err(&gemm::matmul_nt(&dz, &x)) < 1e-13);
        assert!(dx.rel_err(&gemm::matmul_tn(&l.w, &dz)) < 1e-13);
        // K-FAC identity: grad = (G/B) Aᵀ.
        let g = l.g_factor.as_ref().unwrap();
        let a = l.a_factor.as_ref().unwrap();
        let mut recon = gemm::matmul_nt(g, a);
        recon.scale_inplace(1.0 / 3.0);
        assert!(recon.rel_err(&l.grad) < 1e-12);
    }

    #[test]
    fn finite_difference_weight_grad() {
        // loss = sum(Z) -> dZ = ones; check dW numerically.
        let mut rng = Pcg64::new(3);
        let mut l = Linear::new(3, 5, &mut rng);
        let x = rng.gaussian_matrix(5, 2);
        let _ = l.forward(&x, false);
        let dz = Matrix::ones(3, 2);
        let _ = l.backward(&dz, false);
        let eps = 1e-6;
        for &(i, j) in &[(0, 0), (2, 4), (1, 2)] {
            let mut wp = l.w.clone();
            wp[(i, j)] += eps;
            let lp = gemm::matmul(&wp, &x).sum();
            let mut wm = l.w.clone();
            wm[(i, j)] -= eps;
            let lm = gemm::matmul(&wm, &x).sum();
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - l.grad[(i, j)]).abs() < 1e-6, "({i},{j})");
        }
    }
}
