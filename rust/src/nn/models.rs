//! Model zoo: MLPs and the scaled VGG16_bn of the paper's evaluation.

use crate::linalg::Pcg64;
use crate::nn::activations::{Dropout, ReLU};
use crate::nn::batchnorm::BatchNorm;
use crate::nn::conv::{Conv2d, MapShape, MaxPool2};
use crate::nn::linear::Linear;
use crate::nn::network::{Layer, Network};

/// Plain ReLU MLP with the given layer widths (last layer linear).
pub fn mlp(widths: &[usize], seed: u64) -> Network {
    assert!(widths.len() >= 2, "mlp: need at least input+output widths");
    let mut rng = Pcg64::new(seed);
    let mut layers = Vec::new();
    for i in 0..widths.len() - 1 {
        layers.push(Layer::Linear(Linear::new(widths[i + 1], widths[i], &mut rng)));
        if i + 2 < widths.len() {
            layers.push(Layer::ReLU(ReLU::new()));
        }
    }
    Network::new(layers, seed)
}

/// Tiny conv net for tests: conv3x3-bn-relu → pool → conv3x3-relu → pool → fc.
pub fn conv_tiny(c_in: usize, h: usize, w: usize, classes: usize, seed: u64) -> Network {
    let mut rng = Pcg64::new(seed);
    let s0 = MapShape::new(c_in, h, w);
    let conv1 = Conv2d::new(8, s0, 3, 1, &mut rng);
    let s1 = conv1.out_shape();
    let pool1 = MaxPool2::new(s1);
    let s1p = pool1.out_shape();
    let conv2 = Conv2d::new(8, s1p, 3, 1, &mut rng);
    let s2 = conv2.out_shape();
    let pool2 = MaxPool2::new(s2);
    let s2p = pool2.out_shape();
    let layers = vec![
        Layer::Conv(conv1),
        Layer::Bn(BatchNorm::new(s1.c, s1.h * s1.w)),
        Layer::ReLU(ReLU::new()),
        Layer::Pool(pool1),
        Layer::Conv(conv2),
        Layer::ReLU(ReLU::new()),
        Layer::Pool(pool2),
        Layer::Linear(Linear::new(classes, s2p.flat(), &mut rng)),
    ];
    Network::new(layers, seed)
}

/// VGG16_bn, channel-scaled by `1/scale_div`, for (3, 32, 32) inputs —
/// the paper's evaluation network (§5), including its modification: an
/// extra 512-in/512-out (scaled) FC layer with dropout p=0.5 before the
/// final classifier (footnote 9).
///
/// `scale_div = 1` gives the real VGG16_bn (≈15M params); the experiment
/// configs use `scale_div = 8` so a single CPU core can train it.
pub fn vgg16_bn(classes: usize, scale_div: usize, seed: u64) -> Network {
    assert!(scale_div >= 1);
    let ch = |c: usize| (c / scale_div).max(4);
    let plan: &[&[usize]] = &[
        &[64, 64],
        &[128, 128],
        &[256, 256, 256],
        &[512, 512, 512],
        &[512, 512, 512],
    ];
    let mut rng = Pcg64::new(seed);
    let mut layers = Vec::new();
    let mut shape = MapShape::new(3, 32, 32);
    for block in plan {
        for &c in *block {
            let conv = Conv2d::new(ch(c), shape, 3, 1, &mut rng);
            let out = conv.out_shape();
            layers.push(Layer::Conv(conv));
            layers.push(Layer::Bn(BatchNorm::new(out.c, out.h * out.w)));
            layers.push(Layer::ReLU(ReLU::new()));
            shape = out;
        }
        let pool = MaxPool2::new(shape);
        let out = pool.out_shape();
        layers.push(Layer::Pool(pool));
        shape = out;
    }
    // 32/2^5 = 1: feature map is (ch(512), 1, 1) → flat classifier input.
    let feat = shape.flat();
    let hidden = ch(512);
    // Paper modification: 512→512 FC + dropout(0.5) before the final FC.
    layers.push(Layer::Linear(Linear::new(hidden, feat, &mut rng)));
    layers.push(Layer::ReLU(ReLU::new()));
    layers.push(Layer::Dropout(Dropout::new(0.5)));
    layers.push(Layer::Linear(Linear::new(classes, hidden, &mut rng)));
    Network::new(layers, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    #[test]
    fn mlp_structure() {
        let net = mlp(&[10, 6, 4, 10], 1);
        // Linear, ReLU, Linear, ReLU, Linear
        assert_eq!(net.layers.len(), 5);
        assert_eq!(net.kfac_dims(), vec![(10, 6), (6, 4), (4, 10)]);
    }

    #[test]
    fn vgg_scaled_runs_forward() {
        let mut net = vgg16_bn(10, 16, 2);
        let mut rng = Pcg64::new(3);
        let x = rng.gaussian_matrix(3 * 32 * 32, 2);
        let logits = net.forward(&x, true, false);
        assert_eq!(logits.shape(), (10, 2));
        assert!(logits.all_finite());
        // 13 conv + 2 fc Kronecker blocks, like the real VGG16.
        assert_eq!(net.kfac_dims().len(), 15);
    }

    #[test]
    fn vgg_full_scale_param_count_near_15m() {
        // Structural check only (no forward): the unscaled net has ≈15M params.
        let net = vgg16_bn(10, 1, 4);
        let p = net.param_count();
        assert!(p > 14_000_000 && p < 16_500_000, "params {p}");
    }

    #[test]
    fn vgg_backward_produces_factors() {
        let mut net = vgg16_bn(10, 32, 5);
        let mut rng = Pcg64::new(6);
        let x = rng.gaussian_matrix(3 * 32 * 32, 2);
        let (loss, _) = net.train_batch(&x, &[1, 2], true);
        assert!(loss.is_finite());
        let caps = net.kfac_captures();
        assert_eq!(caps.len(), 15);
        // Conv factor dims: first block d_A = 3*9 = 27.
        assert_eq!(caps[0].a.rows(), 27);
        // n ∝ batch: first conv has n = B·32·32.
        assert_eq!(caps[0].a.cols(), 2 * 32 * 32);
    }

    #[test]
    fn deterministic_init() {
        let a = mlp(&[8, 4, 10], 42);
        let b = mlp(&[8, 4, 10], 42);
        let (wa, wb) = match (&a.layers[0], &b.layers[0]) {
            (Layer::Linear(x), Layer::Linear(y)) => (x.w.clone(), y.w.clone()),
            _ => unreachable!(),
        };
        assert_eq!(wa, wb);
        let _ = Matrix::zeros(1, 1);
    }
}
