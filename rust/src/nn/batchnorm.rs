//! Per-channel batch normalization (the `_bn` in VGG16_bn).
//!
//! Normalizes each channel over (batch × spatial) with learnable per-channel
//! scale γ and shift β. K-FAC treats BN parameters outside the Kronecker
//! blocks (they get a plain SGD-style update in all the paper's solvers), so
//! this layer exposes grads but no K-factors.

use crate::linalg::Matrix;

/// BatchNorm over a (C·H·W, B) column-batch map with C channels.
pub struct BatchNorm {
    pub c: usize,
    /// spatial size H·W (1 for a post-flatten FC BatchNorm).
    pub spatial: usize,
    pub gamma: Vec<f64>,
    pub beta: Vec<f64>,
    pub dgamma: Vec<f64>,
    pub dbeta: Vec<f64>,
    pub running_mean: Vec<f64>,
    pub running_var: Vec<f64>,
    pub momentum: f64,
    pub eps: f64,
    // cached forward state (train mode)
    xhat: Option<Matrix>,
    inv_std: Vec<f64>,
}

impl BatchNorm {
    pub fn new(c: usize, spatial: usize) -> Self {
        BatchNorm {
            c,
            spatial,
            gamma: vec![1.0; c],
            beta: vec![0.0; c],
            dgamma: vec![0.0; c],
            dbeta: vec![0.0; c],
            running_mean: vec![0.0; c],
            running_var: vec![1.0; c],
            momentum: 0.1,
            eps: 1e-5,
            xhat: None,
            inv_std: vec![],
        }
    }

    fn channel_of(&self, row: usize) -> usize {
        row / self.spatial
    }

    pub fn forward(&mut self, x: &Matrix, train: bool) -> Matrix {
        let rows = x.rows();
        assert_eq!(rows, self.c * self.spatial, "BatchNorm: dim mismatch");
        let b = x.cols();
        let n = (b * self.spatial) as f64;
        let mut out = Matrix::zeros(rows, b);
        if train {
            let mut mean = vec![0.0; self.c];
            let mut var = vec![0.0; self.c];
            for r in 0..rows {
                let ch = self.channel_of(r);
                for bi in 0..b {
                    mean[ch] += x[(r, bi)];
                }
            }
            for m in &mut mean {
                *m /= n;
            }
            for r in 0..rows {
                let ch = self.channel_of(r);
                for bi in 0..b {
                    let d = x[(r, bi)] - mean[ch];
                    var[ch] += d * d;
                }
            }
            for v in &mut var {
                *v /= n;
            }
            self.inv_std = var.iter().map(|&v| 1.0 / (v + self.eps).sqrt()).collect();
            let mut xhat = Matrix::zeros(rows, b);
            for r in 0..rows {
                let ch = self.channel_of(r);
                for bi in 0..b {
                    let xh = (x[(r, bi)] - mean[ch]) * self.inv_std[ch];
                    xhat[(r, bi)] = xh;
                    out[(r, bi)] = self.gamma[ch] * xh + self.beta[ch];
                }
            }
            for ch in 0..self.c {
                self.running_mean[ch] =
                    (1.0 - self.momentum) * self.running_mean[ch] + self.momentum * mean[ch];
                self.running_var[ch] =
                    (1.0 - self.momentum) * self.running_var[ch] + self.momentum * var[ch];
            }
            self.xhat = Some(xhat);
        } else {
            for r in 0..rows {
                let ch = self.channel_of(r);
                let inv = 1.0 / (self.running_var[ch] + self.eps).sqrt();
                for bi in 0..b {
                    out[(r, bi)] =
                        self.gamma[ch] * (x[(r, bi)] - self.running_mean[ch]) * inv + self.beta[ch];
                }
            }
        }
        out
    }

    pub fn backward(&mut self, dz: &Matrix) -> Matrix {
        let xhat = self.xhat.as_ref().expect("BatchNorm::backward before train forward");
        let rows = dz.rows();
        let b = dz.cols();
        let n = (b * self.spatial) as f64;
        // Per-channel reductions.
        let mut sum_dz = vec![0.0; self.c];
        let mut sum_dz_xhat = vec![0.0; self.c];
        for r in 0..rows {
            let ch = self.channel_of(r);
            for bi in 0..b {
                sum_dz[ch] += dz[(r, bi)];
                sum_dz_xhat[ch] += dz[(r, bi)] * xhat[(r, bi)];
            }
        }
        self.dbeta = sum_dz.clone();
        self.dgamma = sum_dz_xhat.clone();
        let mut dx = Matrix::zeros(rows, b);
        for r in 0..rows {
            let ch = self.channel_of(r);
            let g = self.gamma[ch] * self.inv_std[ch];
            for bi in 0..b {
                dx[(r, bi)] = g
                    * (dz[(r, bi)] - sum_dz[ch] / n - xhat[(r, bi)] * sum_dz_xhat[ch] / n);
            }
        }
        dx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Pcg64;

    #[test]
    fn forward_normalizes_channels() {
        let mut bn = BatchNorm::new(2, 4);
        let mut rng = Pcg64::new(1);
        let x = rng.uniform_matrix(8, 10, -3.0, 7.0);
        let y = bn.forward(&x, true);
        // each channel of y ~ zero mean unit var
        for ch in 0..2 {
            let mut s = 0.0;
            let mut s2 = 0.0;
            for r in ch * 4..(ch + 1) * 4 {
                for bi in 0..10 {
                    s += y[(r, bi)];
                    s2 += y[(r, bi)] * y[(r, bi)];
                }
            }
            let n = 40.0;
            assert!((s / n).abs() < 1e-10);
            assert!((s2 / n - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn eval_uses_running_stats() {
        let mut bn = BatchNorm::new(1, 1);
        let mut rng = Pcg64::new(2);
        // Train several batches to populate running stats.
        for _ in 0..200 {
            let x = rng.uniform_matrix(1, 32, 4.0, 6.0);
            let _ = bn.forward(&x, true);
        }
        // At eval, a value at the running mean maps to ~beta.
        let x = Matrix::from_vec(1, 1, vec![bn.running_mean[0]]);
        let y = bn.forward(&x, false);
        assert!(y[(0, 0)].abs() < 1e-6);
    }

    #[test]
    fn backward_finite_difference() {
        let mut rng = Pcg64::new(3);
        let x = rng.gaussian_matrix(6, 5); // 3 channels × spatial 2
        let make = || {
            let mut bn = BatchNorm::new(3, 2);
            bn.gamma = vec![1.5, 0.5, 2.0];
            bn.beta = vec![0.1, -0.2, 0.0];
            bn
        };
        // loss = Σ y²/2 so dz = y.
        let mut bn = make();
        let y = bn.forward(&x, true);
        let dx = bn.backward(&y);
        let eps = 1e-6;
        for &(r, b) in &[(0usize, 0usize), (3, 2), (5, 4)] {
            let mut xp = x.clone();
            xp[(r, b)] += eps;
            let yp = make().forward(&xp, true);
            let lp: f64 = yp.as_slice().iter().map(|v| v * v / 2.0).sum();
            let mut xm = x.clone();
            xm[(r, b)] -= eps;
            let ym = make().forward(&xm, true);
            let lm: f64 = ym.as_slice().iter().map(|v| v * v / 2.0).sum();
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - dx[(r, b)]).abs() < 1e-5, "({r},{b}): {fd} vs {}", dx[(r, b)]);
        }
        // gamma/beta grads by finite differences.
        for ch in 0..3 {
            let mut bp = make();
            bp.gamma[ch] += eps;
            let yp = bp.forward(&x, true);
            let lp: f64 = yp.as_slice().iter().map(|v| v * v / 2.0).sum();
            let mut bm = make();
            bm.gamma[ch] -= eps;
            let ym = bm.forward(&x, true);
            let lm: f64 = ym.as_slice().iter().map(|v| v * v / 2.0).sum();
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - bn.dgamma[ch]).abs() < 1e-4, "gamma {ch}");
        }
    }
}
