//! Sequential network container with K-factor plumbing.
//!
//! The network owns the layers; optimizers own the EA K-factor state. After
//! each captured fwd/bwd, [`Network::kfac_captures`] exposes the fresh
//! (A^(l), G^(l)) factor matrices of every Kronecker-blocked layer (Linear /
//! Conv2d) — the `M_i` streams of the paper's eq. (6) — while BatchNorm
//! parameters are updated with a plain SGD rule, as in all of the paper's
//! K-FAC-family solvers.

use crate::linalg::{Matrix, Pcg64};
use crate::nn::activations::{Dropout, ReLU};
use crate::nn::batchnorm::BatchNorm;
use crate::nn::conv::{Conv2d, MaxPool2};
use crate::nn::linear::Linear;
use crate::nn::loss::softmax_xent;

/// A layer in a sequential network.
pub enum Layer {
    Linear(Linear),
    Conv(Conv2d),
    Bn(BatchNorm),
    ReLU(ReLU),
    Dropout(Dropout),
    Pool(MaxPool2),
}

/// Borrowed view of one Kronecker-blocked layer's capture state.
pub struct KfacCapture<'a> {
    /// Index into `Network::layers`.
    pub layer_idx: usize,
    /// Forward factor source A^(l) (d_A, n).
    pub a: &'a Matrix,
    /// Backward factor source G^(l) (d_G, n).
    pub g: &'a Matrix,
    /// Current weight gradient.
    pub grad: &'a Matrix,
}

/// Sequential network.
pub struct Network {
    pub layers: Vec<Layer>,
    /// RNG for dropout masks.
    pub rng: Pcg64,
}

impl Network {
    pub fn new(layers: Vec<Layer>, seed: u64) -> Self {
        Network { layers, rng: Pcg64::with_stream(seed, 77) }
    }

    /// Forward pass. `train` controls dropout/BN mode; `capture` records
    /// K-factor sources on Linear/Conv layers.
    pub fn forward(&mut self, x: &Matrix, train: bool, capture: bool) -> Matrix {
        let mut h = x.clone();
        for layer in &mut self.layers {
            h = match layer {
                Layer::Linear(l) => l.forward(&h, capture),
                Layer::Conv(c) => c.forward(&h, capture),
                Layer::Bn(b) => b.forward(&h, train),
                Layer::ReLU(r) => r.forward(&h),
                Layer::Dropout(d) => d.forward(&h, train, &mut self.rng),
                Layer::Pool(p) => p.forward(&h),
            };
        }
        h
    }

    /// Backward pass from dL/dlogits; fills every layer's grads.
    pub fn backward(&mut self, dlogits: &Matrix, capture: bool) {
        let mut d = dlogits.clone();
        for layer in self.layers.iter_mut().rev() {
            d = match layer {
                Layer::Linear(l) => l.backward(&d, capture),
                Layer::Conv(c) => c.backward(&d, capture),
                Layer::Bn(b) => b.backward(&d),
                Layer::ReLU(r) => r.backward(&d),
                Layer::Dropout(dr) => dr.backward(&d),
                Layer::Pool(p) => p.backward(&d),
            };
        }
    }

    /// Full train-mode step compute on one batch: forward, loss, backward.
    /// Returns (loss, #correct). Gradients and captures are left on layers.
    pub fn train_batch(&mut self, x: &Matrix, labels: &[usize], capture: bool) -> (f64, usize) {
        let logits = self.forward(x, true, capture);
        let (loss, dlogits, correct) = softmax_xent(&logits, labels);
        self.backward(&dlogits, capture);
        (loss, correct)
    }

    /// Eval-mode loss/accuracy on one batch (no grads kept meaningful).
    pub fn eval_batch(&mut self, x: &Matrix, labels: &[usize]) -> (f64, usize) {
        let logits = self.forward(x, false, false);
        let (loss, _, correct) = softmax_xent(&logits, labels);
        (loss, correct)
    }

    /// K-factor captures of every Kronecker-blocked layer, in layer order.
    /// Panics if called before a captured fwd/bwd.
    pub fn kfac_captures(&self) -> Vec<KfacCapture<'_>> {
        let mut out = Vec::new();
        for (i, layer) in self.layers.iter().enumerate() {
            match layer {
                Layer::Linear(l) => out.push(KfacCapture {
                    layer_idx: i,
                    a: l.a_factor.as_ref().expect("no capture on Linear"),
                    g: l.g_factor.as_ref().expect("no capture on Linear"),
                    grad: &l.grad,
                }),
                Layer::Conv(c) => out.push(KfacCapture {
                    layer_idx: i,
                    a: c.a_factor.as_ref().expect("no capture on Conv"),
                    g: c.g_factor.as_ref().expect("no capture on Conv"),
                    grad: &c.grad,
                }),
                _ => {}
            }
        }
        out
    }

    /// (d_A, d_G) dimensions of each Kronecker block, without needing a
    /// capture (used to size EA factor state at init).
    pub fn kfac_dims(&self) -> Vec<(usize, usize)> {
        self.layers
            .iter()
            .filter_map(|l| match l {
                Layer::Linear(lin) => Some((lin.d_in(), lin.d_out())),
                Layer::Conv(c) => Some((c.in_shape.c * c.k * c.k, c.w.rows())),
                _ => None,
            })
            .collect()
    }

    /// Current weight gradients of the Kronecker-blocked layers.
    pub fn kfac_grads(&self) -> Vec<&Matrix> {
        self.layers
            .iter()
            .filter_map(|l| match l {
                Layer::Linear(lin) => Some(&lin.grad),
                Layer::Conv(c) => Some(&c.grad),
                _ => None,
            })
            .collect()
    }

    /// Apply per-block weight deltas `w += delta` (deltas in block order),
    /// with weight decay `wd` folded in as `w += delta - lr*wd*w`, and give
    /// non-Kronecker parameters (BatchNorm γ/β) a plain SGD update.
    pub fn apply_steps(&mut self, deltas: &[Matrix], lr: f64, wd: f64) {
        let mut bi = 0;
        for layer in &mut self.layers {
            match layer {
                Layer::Linear(l) => {
                    let delta = &deltas[bi];
                    bi += 1;
                    assert_eq!(delta.shape(), l.w.shape());
                    for (w, d) in l.w.as_mut_slice().iter_mut().zip(delta.as_slice()) {
                        *w = *w * (1.0 - lr * wd) + d;
                    }
                }
                Layer::Conv(c) => {
                    let delta = &deltas[bi];
                    bi += 1;
                    assert_eq!(delta.shape(), c.w.shape());
                    for (w, d) in c.w.as_mut_slice().iter_mut().zip(delta.as_slice()) {
                        *w = *w * (1.0 - lr * wd) + d;
                    }
                }
                Layer::Bn(b) => {
                    for (g, dg) in b.gamma.iter_mut().zip(b.dgamma.iter()) {
                        *g -= lr * (dg + wd * *g);
                    }
                    for (be, db) in b.beta.iter_mut().zip(b.dbeta.iter()) {
                        *be -= lr * db;
                    }
                }
                _ => {}
            }
        }
        assert_eq!(bi, deltas.len(), "apply_steps: delta count mismatch");
    }

    /// Total trainable parameter count.
    pub fn param_count(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                Layer::Linear(lin) => lin.w.len(),
                Layer::Conv(c) => c.w.len(),
                Layer::Bn(b) => 2 * b.c,
                _ => 0,
            })
            .sum()
    }

    /// Flatten all weights into one vector (checkpointing).
    pub fn state_vector(&self) -> Vec<f64> {
        let mut out = Vec::new();
        for l in &self.layers {
            match l {
                Layer::Linear(lin) => out.extend_from_slice(lin.w.as_slice()),
                Layer::Conv(c) => out.extend_from_slice(c.w.as_slice()),
                Layer::Bn(b) => {
                    out.extend_from_slice(&b.gamma);
                    out.extend_from_slice(&b.beta);
                    out.extend_from_slice(&b.running_mean);
                    out.extend_from_slice(&b.running_var);
                }
                _ => {}
            }
        }
        out
    }

    /// Restore from [`Network::state_vector`] output.
    pub fn load_state_vector(&mut self, state: &[f64]) {
        let mut pos = 0;
        let mut take = |n: usize| {
            let s = &state[pos..pos + n];
            pos += n;
            s.to_vec()
        };
        for l in &mut self.layers {
            match l {
                Layer::Linear(lin) => {
                    let n = lin.w.len();
                    lin.w.as_mut_slice().copy_from_slice(&take(n));
                }
                Layer::Conv(c) => {
                    let n = c.w.len();
                    c.w.as_mut_slice().copy_from_slice(&take(n));
                }
                Layer::Bn(b) => {
                    b.gamma = take(b.c);
                    b.beta = take(b.c);
                    b.running_mean = take(b.c);
                    b.running_var = take(b.c);
                }
                _ => {}
            }
        }
        assert_eq!(pos, state.len(), "load_state_vector: length mismatch");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models;

    #[test]
    fn mlp_forward_shapes_and_loss() {
        let mut net = models::mlp(&[20, 16, 10], 1);
        let mut rng = Pcg64::new(2);
        let x = rng.gaussian_matrix(20, 5);
        let (loss, correct) = net.train_batch(&x, &[0, 1, 2, 3, 4], true);
        assert!(loss > 0.0 && loss < 10.0);
        assert!(correct <= 5);
        let caps = net.kfac_captures();
        assert_eq!(caps.len(), 2);
        assert_eq!(caps[0].a.shape(), (20, 5));
        assert_eq!(caps[0].g.shape(), (16, 5));
        assert_eq!(caps[1].a.shape(), (16, 5));
        assert_eq!(caps[1].g.shape(), (10, 5));
    }

    #[test]
    fn kfac_dims_match_captures() {
        let mut net = models::mlp(&[12, 8, 10], 3);
        let mut rng = Pcg64::new(4);
        let x = rng.gaussian_matrix(12, 4);
        net.train_batch(&x, &[0, 1, 2, 3], true);
        let dims = net.kfac_dims();
        let caps = net.kfac_captures();
        assert_eq!(dims.len(), caps.len());
        for (d, c) in dims.iter().zip(caps.iter()) {
            assert_eq!(d.0, c.a.rows());
            assert_eq!(d.1, c.g.rows());
        }
    }

    #[test]
    fn sgd_style_steps_descend() {
        let mut net = models::mlp(&[10, 8, 10], 5);
        let mut rng = Pcg64::new(6);
        let x = rng.gaussian_matrix(10, 8);
        let labels: Vec<usize> = (0..8).map(|i| i % 10).collect();
        let (loss0, _) = net.train_batch(&x, &labels, true);
        for _ in 0..20 {
            net.train_batch(&x, &labels, false);
            let deltas: Vec<Matrix> = net.kfac_grads().iter().map(|g| *g * (-0.5)).collect();
            net.apply_steps(&deltas, 0.5, 0.0);
        }
        let (loss1, _) = net.eval_batch(&x, &labels);
        assert!(loss1 < loss0 * 0.7, "{loss0} -> {loss1}");
    }

    #[test]
    fn state_vector_roundtrip() {
        let mut net = models::mlp(&[6, 5, 10], 7);
        let state = net.state_vector();
        let mut rng = Pcg64::new(8);
        let x = rng.gaussian_matrix(6, 3);
        let before = net.forward(&x, false, false);
        // perturb then restore
        let perturbed: Vec<f64> = state.iter().map(|v| v + 1.0).collect();
        net.load_state_vector(&perturbed);
        let mid = net.forward(&x, false, false);
        assert!(mid.rel_err(&before) > 1e-3);
        net.load_state_vector(&state);
        let after = net.forward(&x, false, false);
        assert!(after.rel_err(&before) < 1e-14);
    }

    #[test]
    fn conv_net_end_to_end() {
        let mut net = models::conv_tiny(3, 8, 8, 10, 9);
        let mut rng = Pcg64::new(10);
        let x = rng.gaussian_matrix(3 * 8 * 8, 4);
        let (loss, _) = net.train_batch(&x, &[0, 1, 2, 3], true);
        assert!(loss.is_finite() && loss > 0.0);
        let caps = net.kfac_captures();
        assert!(!caps.is_empty());
        for c in &caps {
            assert!(c.a.all_finite() && c.g.all_finite());
        }
        assert!(net.param_count() > 0);
    }
}
