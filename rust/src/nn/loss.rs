//! Softmax cross-entropy loss (the paper's eq. (1) objective) + metrics.

use crate::linalg::Matrix;

/// Mean softmax cross-entropy over a column batch.
///
/// `logits`: (C, B); `labels`: class index per column.
/// Returns (loss, dL/dlogits, #correct).
pub fn softmax_xent(logits: &Matrix, labels: &[usize]) -> (f64, Matrix, usize) {
    let (c, b) = logits.shape();
    assert_eq!(labels.len(), b, "softmax_xent: label count mismatch");
    let mut dlogits = Matrix::zeros(c, b);
    let mut loss = 0.0;
    let mut correct = 0;
    for bi in 0..b {
        let col = logits.col(bi);
        let zmax = col.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = col.iter().map(|&z| (z - zmax).exp()).collect();
        let denom: f64 = exps.iter().sum();
        let label = labels[bi];
        assert!(label < c, "label {label} out of range {c}");
        loss += -(col[label] - zmax - denom.ln());
        let pred = col
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if pred == label {
            correct += 1;
        }
        for ci in 0..c {
            let p = exps[ci] / denom;
            dlogits[(ci, bi)] = (p - if ci == label { 1.0 } else { 0.0 }) / b as f64;
        }
    }
    (loss / b as f64, dlogits, correct)
}

/// One-hot encode labels as a (C, B) matrix (the PJRT model-step input).
pub fn one_hot(labels: &[usize], classes: usize) -> Matrix {
    let mut y = Matrix::zeros(classes, labels.len());
    for (bi, &l) in labels.iter().enumerate() {
        assert!(l < classes, "label {l} out of range {classes}");
        y[(l, bi)] = 1.0;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Pcg64;

    #[test]
    fn uniform_logits_loss_is_log_c() {
        let logits = Matrix::zeros(10, 4);
        let (loss, _, _) = softmax_xent(&logits, &[0, 3, 5, 9]);
        assert!((loss - (10f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn perfect_prediction_low_loss() {
        let mut logits = Matrix::zeros(3, 2);
        logits[(1, 0)] = 50.0;
        logits[(2, 1)] = 50.0;
        let (loss, _, correct) = softmax_xent(&logits, &[1, 2]);
        assert!(loss < 1e-10);
        assert_eq!(correct, 2);
    }

    #[test]
    fn gradient_finite_difference() {
        let mut rng = Pcg64::new(1);
        let logits = rng.gaussian_matrix(5, 3);
        let labels = [2usize, 0, 4];
        let (_, dl, _) = softmax_xent(&logits, &labels);
        let eps = 1e-6;
        for &(i, j) in &[(0usize, 0usize), (2, 1), (4, 2)] {
            let mut lp = logits.clone();
            lp[(i, j)] += eps;
            let (fp, _, _) = softmax_xent(&lp, &labels);
            let mut lm = logits.clone();
            lm[(i, j)] -= eps;
            let (fm, _, _) = softmax_xent(&lm, &labels);
            let fd = (fp - fm) / (2.0 * eps);
            assert!((fd - dl[(i, j)]).abs() < 1e-8, "({i},{j})");
        }
    }

    #[test]
    fn grad_columns_sum_to_zero() {
        let mut rng = Pcg64::new(2);
        let logits = rng.gaussian_matrix(6, 4);
        let (_, dl, _) = softmax_xent(&logits, &[0, 1, 2, 3]);
        for bi in 0..4 {
            let s: f64 = dl.col(bi).iter().sum();
            assert!(s.abs() < 1e-12);
        }
    }

    #[test]
    fn one_hot_encoding() {
        let y = one_hot(&[1, 0, 2], 3);
        assert_eq!(y[(1, 0)], 1.0);
        assert_eq!(y[(0, 1)], 1.0);
        assert_eq!(y[(2, 2)], 1.0);
        assert!((y.sum() - 3.0).abs() < 1e-14);
    }
}
