//! 2-D convolution (im2col) and max-pool, with conv K-factor capture.
//!
//! Feature maps are stored column-batch: a (C·H·W, B) matrix whose row
//! index is `c*H*W + y*W + x`. Convolution follows Grosse & Martens (2016):
//! the forward factor A^(l) collects the im2col patch vectors over all
//! spatial positions (d_A = C_in·k², n_A = B·H_out·W_out — note n ∝ batch
//! size, exactly the paper's `n_M ∝ n_BS`), the backward factor G^(l)
//! collects the per-position pre-activation gradients (d_G = C_out).

use crate::linalg::{gemm, Matrix, Pcg64};

/// Spatial shape of a feature map.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MapShape {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl MapShape {
    pub fn new(c: usize, h: usize, w: usize) -> Self {
        MapShape { c, h, w }
    }

    pub fn flat(&self) -> usize {
        self.c * self.h * self.w
    }
}

/// `kxk` same/valid convolution, stride 1.
pub struct Conv2d {
    /// Weight (C_out, C_in·k·k).
    pub w: Matrix,
    pub grad: Matrix,
    pub in_shape: MapShape,
    pub k: usize,
    pub pad: usize,
    /// im2col patches of the last forward: (C_in·k², B·H_out·W_out).
    pub a_factor: Option<Matrix>,
    /// per-position scaled output grads: (C_out, B·H_out·W_out).
    pub g_factor: Option<Matrix>,
    cols: Option<Matrix>,
    batch: usize,
}

impl Conv2d {
    pub fn new(c_out: usize, in_shape: MapShape, k: usize, pad: usize, rng: &mut Pcg64) -> Self {
        let fan_in = in_shape.c * k * k;
        let scale = (2.0 / fan_in as f64).sqrt();
        Conv2d {
            w: Matrix::from_fn(c_out, fan_in, |_, _| scale * rng.gaussian()),
            grad: Matrix::zeros(c_out, fan_in),
            in_shape,
            k,
            pad,
            a_factor: None,
            g_factor: None,
            cols: None,
            batch: 0,
        }
    }

    pub fn out_shape(&self) -> MapShape {
        let h = self.in_shape.h + 2 * self.pad + 1 - self.k;
        let w = self.in_shape.w + 2 * self.pad + 1 - self.k;
        MapShape::new(self.w.rows(), h, w)
    }

    /// im2col: extract k×k patches of every (sample, output position) into
    /// columns. Output: (C_in·k², B·H_out·W_out), column index is
    /// `b*H_out*W_out + oy*W_out + ox`.
    fn im2col(&self, x: &Matrix) -> Matrix {
        let MapShape { c, h, w } = self.in_shape;
        let out = self.out_shape();
        let b = x.cols();
        let k = self.k;
        let pad = self.pad as isize;
        let mut cols = Matrix::zeros(c * k * k, b * out.h * out.w);
        for bi in 0..b {
            for oy in 0..out.h {
                for ox in 0..out.w {
                    let col = bi * out.h * out.w + oy * out.w + ox;
                    for ci in 0..c {
                        for ky in 0..k {
                            let iy = oy as isize + ky as isize - pad;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for kx in 0..k {
                                let ix = ox as isize + kx as isize - pad;
                                if ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                let row_in = ci * h * w + iy as usize * w + ix as usize;
                                let row_out = ci * k * k + ky * k + kx;
                                cols[(row_out, col)] = x[(row_in, bi)];
                            }
                        }
                    }
                }
            }
        }
        cols
    }

    /// Scatter-add the transpose of im2col (for input gradients).
    fn col2im(&self, dcols: &Matrix, batch: usize) -> Matrix {
        let MapShape { c, h, w } = self.in_shape;
        let out = self.out_shape();
        let k = self.k;
        let pad = self.pad as isize;
        let mut dx = Matrix::zeros(c * h * w, batch);
        for bi in 0..batch {
            for oy in 0..out.h {
                for ox in 0..out.w {
                    let col = bi * out.h * out.w + oy * out.w + ox;
                    for ci in 0..c {
                        for ky in 0..k {
                            let iy = oy as isize + ky as isize - pad;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for kx in 0..k {
                                let ix = ox as isize + kx as isize - pad;
                                if ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                let row_in = ci * h * w + iy as usize * w + ix as usize;
                                let row_out = ci * k * k + ky * k + kx;
                                dx[(row_in, bi)] += dcols[(row_out, col)];
                            }
                        }
                    }
                }
            }
        }
        dx
    }

    /// Reorder (C_out·H·W, B) map into (C_out, B·H·W) position-major form.
    fn map_to_positions(&self, z: &Matrix, out: MapShape, batch: usize) -> Matrix {
        let mut p = Matrix::zeros(out.c, batch * out.h * out.w);
        for bi in 0..batch {
            for co in 0..out.c {
                for pos in 0..out.h * out.w {
                    p[(co, bi * out.h * out.w + pos)] = z[(co * out.h * out.w + pos, bi)];
                }
            }
        }
        p
    }

    fn positions_to_map(&self, p: &Matrix, out: MapShape, batch: usize) -> Matrix {
        let mut z = Matrix::zeros(out.flat(), batch);
        for bi in 0..batch {
            for co in 0..out.c {
                for pos in 0..out.h * out.w {
                    z[(co * out.h * out.w + pos, bi)] = p[(co, bi * out.h * out.w + pos)];
                }
            }
        }
        z
    }

    pub fn forward(&mut self, x: &Matrix, capture: bool) -> Matrix {
        assert_eq!(x.rows(), self.in_shape.flat(), "Conv2d: input dim mismatch");
        self.batch = x.cols();
        let cols = self.im2col(x);
        let zp = gemm::matmul(&self.w, &cols); // (C_out, B·Ho·Wo)
        if capture {
            self.a_factor = Some(cols.clone());
        }
        self.cols = Some(cols);
        self.positions_to_map(&zp, self.out_shape(), self.batch)
    }

    pub fn backward(&mut self, dz: &Matrix, capture: bool) -> Matrix {
        let out = self.out_shape();
        let cols = self.cols.as_ref().expect("Conv2d::backward before forward");
        let dzp = self.map_to_positions(dz, out, self.batch); // (C_out, B·Ho·Wo)
        self.grad = gemm::matmul_nt(&dzp, cols);
        if capture {
            // Scale like the FC case: G = B·dL/dZ per position (the spatial
            // sum is the Grosse–Martens expectation over positions).
            let mut g = dzp.clone();
            g.scale_inplace(self.batch as f64);
            self.g_factor = Some(g);
        }
        let dcols = gemm::matmul_tn(&self.w, &dzp);
        self.col2im(&dcols, self.batch)
    }
}

/// 2×2 max-pool, stride 2.
pub struct MaxPool2 {
    pub in_shape: MapShape,
    argmax: Option<Vec<usize>>, // flat index into input per output element
    batch: usize,
}

impl MaxPool2 {
    pub fn new(in_shape: MapShape) -> Self {
        assert!(in_shape.h % 2 == 0 && in_shape.w % 2 == 0, "MaxPool2: odd input");
        MaxPool2 { in_shape, argmax: None, batch: 0 }
    }

    pub fn out_shape(&self) -> MapShape {
        MapShape::new(self.in_shape.c, self.in_shape.h / 2, self.in_shape.w / 2)
    }

    pub fn forward(&mut self, x: &Matrix) -> Matrix {
        let MapShape { c, h, w } = self.in_shape;
        let out = self.out_shape();
        let b = x.cols();
        self.batch = b;
        let mut y = Matrix::zeros(out.flat(), b);
        let mut arg = vec![0usize; out.flat() * b];
        for bi in 0..b {
            for ci in 0..c {
                for oy in 0..out.h {
                    for ox in 0..out.w {
                        let mut best = f64::NEG_INFINITY;
                        let mut best_idx = 0;
                        for dy in 0..2 {
                            for dx in 0..2 {
                                let iy = oy * 2 + dy;
                                let ix = ox * 2 + dx;
                                let idx = ci * h * w + iy * w + ix;
                                if x[(idx, bi)] > best {
                                    best = x[(idx, bi)];
                                    best_idx = idx;
                                }
                            }
                        }
                        let orow = ci * out.h * out.w + oy * out.w + ox;
                        y[(orow, bi)] = best;
                        arg[orow * b + bi] = best_idx;
                    }
                }
            }
        }
        self.argmax = Some(arg);
        y
    }

    pub fn backward(&self, dz: &Matrix) -> Matrix {
        let arg = self.argmax.as_ref().expect("MaxPool2::backward before forward");
        let out = self.out_shape();
        let b = self.batch;
        let mut dx = Matrix::zeros(self.in_shape.flat(), b);
        for orow in 0..out.flat() {
            for bi in 0..b {
                dx[(arg[orow * b + bi], bi)] += dz[(orow, bi)];
            }
        }
        dx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_identity_kernel() {
        // 1x1 conv with identity weight reproduces the input.
        let mut rng = Pcg64::new(1);
        let shape = MapShape::new(2, 3, 3);
        let mut conv = Conv2d::new(2, shape, 1, 0, &mut rng);
        conv.w = Matrix::eye(2);
        let x = rng.gaussian_matrix(shape.flat(), 2);
        let y = conv.forward(&x, false);
        assert!(y.rel_err(&x) < 1e-14);
    }

    #[test]
    fn conv_known_3x3_sum_kernel() {
        // All-ones 3x3 kernel, pad 1, single channel: output = local sums.
        let mut rng = Pcg64::new(2);
        let shape = MapShape::new(1, 3, 3);
        let mut conv = Conv2d::new(1, shape, 3, 1, &mut rng);
        conv.w = Matrix::ones(1, 9);
        let x = Matrix::from_vec(9, 1, (1..=9).map(|v| v as f64).collect());
        let y = conv.forward(&x, false);
        // center output = sum(1..9) = 45
        assert!((y[(4, 0)] - 45.0).abs() < 1e-12);
        // corner (0,0) = 1+2+4+5 = 12
        assert!((y[(0, 0)] - 12.0).abs() < 1e-12);
    }

    #[test]
    fn conv_grad_finite_difference() {
        let mut rng = Pcg64::new(3);
        let shape = MapShape::new(2, 4, 4);
        let mut conv = Conv2d::new(3, shape, 3, 1, &mut rng);
        let x = rng.gaussian_matrix(shape.flat(), 2);
        let y = conv.forward(&x, true);
        let dz = Matrix::ones(y.rows(), y.cols());
        let dx = conv.backward(&dz, true);
        let eps = 1e-6;
        // weight grad
        for &(i, j) in &[(0, 0), (2, 17), (1, 9)] {
            let mut wp = conv.w.clone();
            wp[(i, j)] += eps;
            let mut cp = Conv2d { w: wp, ..Conv2d::new(3, shape, 3, 1, &mut Pcg64::new(0)) };
            let lp = cp.forward(&x, false).sum();
            let mut wm = conv.w.clone();
            wm[(i, j)] -= eps;
            let mut cm = Conv2d { w: wm, ..Conv2d::new(3, shape, 3, 1, &mut Pcg64::new(0)) };
            let lm = cm.forward(&x, false).sum();
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - conv.grad[(i, j)]).abs() < 1e-5, "w({i},{j}): {fd} vs {}", conv.grad[(i, j)]);
        }
        // input grad
        for &(r, b) in &[(0usize, 0usize), (15, 1), (31, 0)] {
            let mut xp = x.clone();
            xp[(r, b)] += eps;
            let lp = conv.forward(&xp, false).sum();
            let mut xm = x.clone();
            xm[(r, b)] -= eps;
            let lm = conv.forward(&xm, false).sum();
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - dx[(r, b)]).abs() < 1e-5, "x({r},{b})");
        }
    }

    #[test]
    fn conv_kfac_identity() {
        // grad = (G/B) Aᵀ / (Ho·Wo)… for conv: grad = dzp · colsᵀ and
        // G = B·dzp, A = cols, so grad = (G Aᵀ)/B exactly.
        let mut rng = Pcg64::new(4);
        let shape = MapShape::new(2, 4, 4);
        let mut conv = Conv2d::new(3, shape, 3, 1, &mut rng);
        let x = rng.gaussian_matrix(shape.flat(), 2);
        let y = conv.forward(&x, true);
        let dz = rng.gaussian_matrix(y.rows(), y.cols());
        let _ = conv.backward(&dz, true);
        let g = conv.g_factor.as_ref().unwrap();
        let a = conv.a_factor.as_ref().unwrap();
        let mut recon = gemm::matmul_nt(g, a);
        recon.scale_inplace(1.0 / 2.0);
        assert!(recon.rel_err(&conv.grad) < 1e-12);
        // factor dims: d_A = C_in·k² , n = B·Ho·Wo
        assert_eq!(a.shape(), (2 * 9, 2 * 16));
        assert_eq!(g.shape(), (3, 2 * 16));
    }

    #[test]
    fn maxpool_forward_backward() {
        let shape = MapShape::new(1, 4, 4);
        let mut pool = MaxPool2::new(shape);
        let x = Matrix::from_fn(16, 1, |i, _| i as f64);
        let y = pool.forward(&x);
        // each 2x2 block max is bottom-right: 5, 7, 13, 15
        assert_eq!(y.col(0), vec![5.0, 7.0, 13.0, 15.0]);
        let dz = Matrix::ones(4, 1);
        let dx = pool.backward(&dz);
        assert_eq!(dx[(5, 0)], 1.0);
        assert_eq!(dx[(0, 0)], 0.0);
        assert_eq!(dx[(15, 0)], 1.0);
        assert!((dx.sum() - 4.0).abs() < 1e-14);
    }
}
