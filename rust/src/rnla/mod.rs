//! Randomized numerical linear algebra — the paper's toolbox (§2.2–2.3),
//! organized around the open [`Decomposition`] trait.
//!
//! ## Architecture
//!
//! The *kernels* (free functions over [`crate::linalg::Matrix`]) do the
//! math; the [`decomposition`] module wraps each one in a strategy object
//! so optimizers, the async pipeline, and third-party backends all dispatch
//! through `dyn Decomposition` instead of a closed enum:
//!
//! - [`sketch`]: Gaussian range finder with power iteration — the stage
//!   shared by every randomized strategy ([`SketchConfig`] carries the
//!   `(r, r_l, n_pwr-it)` knobs).
//! - [`mod@rsvd`]: Algorithm 2 — randomized SVD; RS-KFAC uses the `Ṽ Σ̃ Ṽᵀ`
//!   symmetric reconstruction (§2.2.2).
//! - [`mod@srevd`]: Algorithm 3 — symmetric randomized EVD; cheaper
//!   constant, projection error on both sides.
//! - [`mod@nystrom`]: Nyström PSD approximation — same sketch cost class as
//!   SRE-EVD, tighter for PSD inputs (NYS-KFAC).
//! - [`lowrank`]: the eq. (13) damped low-rank inverse application — the
//!   common output format ([`LowRankFactor`]) every strategy produces.
//! - [`errors`]: truncation-vs-projection error split (§2.2.1) and the
//!   Prop. 3.1 `r_ε` spectrum-decay bound machinery (§3).
//! - [`decomposition`]: the [`Decomposition`] trait, its built-in impls,
//!   the [`DecompositionRegistry`], and the [`DecompMeta`] cost/error
//!   channel that lets rank controllers tune oversampling and
//!   power-iteration schedules per strategy.
//! - [`factored`]: the Woodbury / sketched-core factored-solve subsystem —
//!   [`FactoredSolve`] applies `(UUᵀ + (γ+λ)I)⁻¹` through a Cholesky-
//!   factored k×k core without ever materializing the o×o factor, the
//!   route to vocab-scale output layers the eigen path cannot touch.
//! - [`update`]: online incremental basis maintenance — [`FactorDelta`]
//!   captures the EA gram increment, [`rank_update`] rotates an installed
//!   eigenbasis through it, and the [`Decomposition::update`] hook lets
//!   strategies opt in (the "Brand New K-FACs" route that amortizes the
//!   periodic full refresh away).
//!
//! ## Adding a strategy
//!
//! Implement [`Decomposition`] (a pure function of `(matrix, cfg, rng)` —
//! see the trait docs for the determinism contract), register it in a
//! [`DecompositionRegistry`], and every solver family in
//! [`crate::optim::registry`] can build with it as `kfac+<key>`.

pub mod decomposition;
pub mod errors;
pub mod factored;
pub mod lowrank;
pub mod nystrom;
pub mod rsvd;
pub mod sketch;
pub mod srevd;
pub mod update;

pub use decomposition::{tuned_sketch, DecompMeta, Decomposition, DecompositionRegistry};
pub use factored::{FactoredSolve, SketchedCore, Woodbury};
pub use lowrank::LowRankFactor;
pub use nystrom::nystrom;
pub use rsvd::{rsvd, Rsvd};
pub use sketch::{range_finder, SketchConfig};
pub use srevd::{srevd, Srevd};
pub use update::{rank_update, update_flops, DeltaBuffer, FactorDelta, UpdateOutcome};
