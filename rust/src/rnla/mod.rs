//! Randomized numerical linear algebra — the paper's toolbox (§2.2–2.3).
//!
//! - [`sketch`]: Gaussian range finder with power iteration (shared stage).
//! - [`rsvd`]: Algorithm 2 — randomized SVD; RS-KFAC uses the `Ṽ Σ̃ Ṽᵀ`
//!   symmetric reconstruction (paper §2.2.2).
//! - [`srevd`]: Algorithm 3 — symmetric randomized EVD; cheaper, but with
//!   projection error on both sides (SRE-KFAC).
//! - [`lowrank`]: equation (13) damped low-rank inverse application.
//! - [`errors`]: truncation-vs-projection error split (§2.2.1) and the
//!   Prop. 3.1 `r_ε` spectrum-decay bound machinery (§3).
//! - [`nystrom`]: Nyström PSD approximation — wired into the optimizer
//!   family as the fourth `Inversion` strategy (NYS-KFAC).

pub mod errors;
pub mod nystrom;
pub mod lowrank;
pub mod rsvd;
pub mod sketch;
pub mod srevd;

pub use lowrank::LowRankFactor;
pub use nystrom::nystrom;
pub use rsvd::{rsvd, Rsvd};
pub use sketch::{range_finder, SketchConfig};
pub use srevd::{srevd, Srevd};
