//! Factored solves: identity-minus-low-rank inverse application through a
//! Cholesky-factored T×T core — the Woodbury route to vocab-scale layers.
//!
//! The eigendecomposition output ([`crate::rnla::LowRankFactor`]) needs the
//! o×o factor `G` materialized before anything can be decomposed; for an
//! LM-style head with `o ≈ 50k` even *forming* `G = UUᵀ` is prohibitive.
//! But the empirical-Fisher G-side factor is rank-T (T = batch tokens ≪ o),
//! so with the per-step gradient columns retained as `U` (d×k, k ≪ d) the
//! damped inverse applies *exactly* through the Sherman–Morrison–Woodbury
//! identity:
//!
//! ```text
//!   (U Uᵀ + λ'I)⁻¹ Y  =  Y/λ'  −  U S⁻¹ (Uᵀ Y) / λ'²
//!   S = I_k + UᵀU/λ'          (k×k, Cholesky-factored once per refresh)
//! ```
//!
//! at O(o·k² + k³) instead of O(o³) — without ever allocating an o×o block.
//! [`FactoredSolve`] is that representation: the retained columns, their
//! k×k gram, and the cached Cholesky factor of the core, rebuilt lazily
//! when the damping changes (an O(k³) cost that never touches `U`).
//!
//! Two [`Decomposition`] strategies produce it:
//!
//! * [`Woodbury`] — the exact core `S = I + UᵀU/λ'` (TensorScope's
//!   `WOODBURY_KFAC_REFACTOR` shape);
//! * [`SketchedCore`] — SENG's B×B sketched core: the gram is estimated
//!   from a `col_sample`-row subsample of `U` (unbiased `d/k` rescale),
//!   cutting the gram build from O(o·k²) to O(col_sample·k²) while the
//!   apply still uses the full `U`.
//!
//! Both register in the [`crate::rnla::DecompositionRegistry`] under
//! `"woodbury"` / `"sketchcore"` and are consumed by the K-FAC engine's
//! width-policy layer ([`crate::optim::preconditioner::FactoredPolicy`]),
//! which routes wide blocks here and narrow blocks to the eigen path.
//!
//! The damped EA recursion `Ḡ_t = ρ Ḡ_{t-1} + (1-ρ)/n · U_t U_tᵀ` with
//! `Ḡ_0 = I` is represented losslessly as `Ḡ_t = R_t R_tᵀ + γ_t I` where
//! `R_t = [√ρ·R_{t-1} | √((1-ρ)/n)·U_t]` and `γ_t = ρᵗ`; the engine keeps
//! `R_t` (window-trimmed) and `γ_t`, and solves `(Ḡ_t + λI)⁻¹Y` as a
//! factored solve at damping `λ' = γ_t + λ`.

use crate::linalg::{chol, gemm, qr, Matrix, Pcg64};
use crate::obs;
use crate::rnla::decomposition::{DecompMeta, Decomposition};
use crate::rnla::lowrank::LowRankFactor;
use crate::rnla::sketch::SketchConfig;

use crate::linalg::backend;

/// Identity-minus-low-rank damped inverse: `(U Uᵀ + (γ+λ)I)⁻¹` applied
/// through a Cholesky-factored k×k core, never materializing the d×d
/// operator. `γ` is the identity coefficient of the represented factor
/// (`X = UUᵀ + γI`), folded into the effective damping at apply time.
#[derive(Clone)]
pub struct FactoredSolve {
    /// Retained columns, d × k (already EA-scaled by the producer).
    u: Matrix,
    /// k×k core-basis gram: `UᵀU` exactly ([`Woodbury`]) or a sketched
    /// unbiased estimate ([`SketchedCore`]).
    gram: Matrix,
    /// Identity coefficient γ of the represented factor `UUᵀ + γI`.
    gamma: f64,
    /// The damping λ the cached `core_l` was built for.
    lambda: f64,
    /// Cholesky factor L of `S = I_k + gram/(γ+λ)` (k×k lower-triangular).
    core_l: Matrix,
}

/// Cholesky of `S = I_k + gram/(γ+λ)` — the only O(k³) piece, wrapped in
/// the `factored.core_chol` obs span.
fn chol_core(gram: &Matrix, gamma: f64, lambda: f64) -> Result<Matrix, String> {
    let k = gram.rows();
    if k == 0 {
        return Ok(Matrix::zeros(0, 0));
    }
    let _sp = obs::span("factored.core_chol").arg("k", k as f64);
    let lambda_eff = gamma + lambda;
    if !(lambda_eff > 0.0) {
        return Err(format!(
            "factored core: effective damping γ+λ = {lambda_eff} must be positive"
        ));
    }
    let mut s = gram * (1.0 / lambda_eff);
    s.add_diag(1.0);
    chol::cholesky(&s).map_err(|e| format!("factored core Cholesky: {e}"))
}

impl FactoredSolve {
    /// Exact-core build: `gram = UᵀU`. `S = I + UᵀU/(γ+λ)` is SPD for any
    /// finite `U` (including rank-deficient / duplicate columns), so this
    /// only fails on non-finite input or non-positive effective damping.
    pub fn build(u: Matrix, gamma: f64, lambda: f64) -> Result<FactoredSolve, String> {
        let gram = gemm::matmul_tn(&u, &u);
        Self::from_parts(u, gram, gamma, lambda)
    }

    /// SENG-style sketched-core build: the gram is estimated from
    /// `col_sample` uniformly-sampled rows of `U`, rescaled by `d/k` so it
    /// is unbiased; the apply still uses the full `U`. Falls back to the
    /// exact gram when `col_sample >= d`.
    pub fn build_sketched(
        u: Matrix,
        gamma: f64,
        lambda: f64,
        col_sample: usize,
        rng: &mut Pcg64,
    ) -> Result<FactoredSolve, String> {
        let d = u.rows();
        let ks = col_sample.min(d);
        if ks == 0 || ks == d {
            return Self::build(u, gamma, lambda);
        }
        let idx = rng.sample_indices(d, ks);
        let mut us = Matrix::zeros(ks, u.cols());
        for (r, &i) in idx.iter().enumerate() {
            us.row_mut(r).copy_from_slice(u.row(i));
        }
        let mut gram = gemm::matmul_tn(&us, &us);
        gram.scale_inplace(d as f64 / ks as f64);
        Self::from_parts(u, gram, gamma, lambda)
    }

    /// Rebuild from serialized parts (checkpoint restore): the Cholesky
    /// refactorization is deterministic in `(gram, γ, λ)`, so a restored
    /// solve continues bitwise.
    pub fn from_parts(
        u: Matrix,
        gram: Matrix,
        gamma: f64,
        lambda: f64,
    ) -> Result<FactoredSolve, String> {
        if gram.rows() != u.cols() || gram.cols() != u.cols() {
            return Err(format!(
                "factored core: gram is {}×{} but U has {} columns",
                gram.rows(),
                gram.cols(),
                u.cols()
            ));
        }
        let core_l = chol_core(&gram, gamma, lambda)?;
        Ok(FactoredSolve { u, gram, gamma, lambda, core_l })
    }

    /// Apply `(UUᵀ + (γ+λ)I)⁻¹ Y`. Takes `&mut self` for the lazy core
    /// rebuild when `lambda` differs from the cached factorization's — an
    /// O(k³) refresh that never touches `U`. A rebuild failure (non-finite
    /// core) poisons the output with NaN rather than panicking, so a bad
    /// batch surfaces as a non-finite step the trainer can see.
    pub fn apply(&mut self, lambda: f64, y: &Matrix) -> Matrix {
        assert_eq!(y.rows(), self.dim(), "FactoredSolve::apply: dim mismatch");
        let _sp = obs::span("factored.apply")
            .arg("k", self.rank() as f64)
            .arg("d", self.dim() as f64);
        if lambda != self.lambda {
            match chol_core(&self.gram, self.gamma, lambda) {
                Ok(l) => {
                    self.core_l = l;
                    self.lambda = lambda;
                }
                Err(_) => return Matrix::from_fn(y.rows(), y.cols(), |_, _| f64::NAN),
            }
        }
        let lambda_eff = self.gamma + lambda;
        let inv_l = 1.0 / lambda_eff;
        if self.rank() == 0 {
            let mut out = y.clone();
            out.scale_inplace(inv_l);
            return out;
        }
        // W = Uᵀ Y (k×c), then the two triangular solves: S Z = W.
        let w = gemm::matmul_tn(&self.u, y);
        let z0 = qr::solve_lower_triangular(&self.core_l, &w);
        let z = qr::solve_upper_triangular(&self.core_l.transpose(), &z0);
        // Y/λ' − U Z / λ'².
        let correction = gemm::matmul(&self.u, &z);
        let mut out = y.clone();
        out.scale_inplace(inv_l);
        out.axpy(-inv_l * inv_l, &correction);
        out
    }

    /// Number of retained columns k (the core dimension).
    pub fn rank(&self) -> usize {
        self.u.cols()
    }

    /// Operator dimension d.
    pub fn dim(&self) -> usize {
        self.u.rows()
    }

    /// The retained columns (serialization / diagnostics).
    pub fn u(&self) -> &Matrix {
        &self.u
    }

    /// The k×k core-basis gram (serialization; exact or sketched).
    pub fn gram(&self) -> &Matrix {
        &self.gram
    }

    /// Identity coefficient γ of the represented factor.
    pub fn gamma(&self) -> f64 {
        self.gamma
    }

    /// The damping the cached core factorization was built for.
    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// Dense reconstruction `UUᵀ + γI` (tests only — O(d²) memory, exactly
    /// what the factored path exists to avoid).
    pub fn reconstruct(&self) -> Matrix {
        let mut x = gemm::matmul_nt(&self.u, &self.u);
        x.add_diag(self.gamma);
        x
    }
}

/// Coarse flop count of one factored refresh + apply at dimension `d` with
/// `k` retained columns: gram build + core Cholesky + the apply GEMMs.
fn factored_flops(d: usize, k: usize) -> f64 {
    let (d, k) = (d as f64, k as f64);
    2.0 * d * k * k + k * k * k / 3.0 + 4.0 * d * k
}

/// The exact-core factored strategy: consumes per-step gradient columns
/// `U` instead of the accumulated o×o gram. The dense [`Decomposition::decompose`]
/// entry point falls back to an exact EVD — it is only reached for the
/// A-side (input) factor or when a caller hands a dense matrix to a
/// column-factoring strategy; the G-side of designated wide blocks routes
/// through [`Decomposition::factor_columns`] and never forms the gram.
pub struct Woodbury;

impl Decomposition for Woodbury {
    fn key(&self) -> &str {
        "woodbury"
    }

    fn decompose(&self, m: &Matrix, _cfg: &SketchConfig, _rng: &mut Pcg64) -> LowRankFactor {
        let e = crate::linalg::evd::sym_evd(m);
        LowRankFactor::new(e.u, e.lambda)
    }

    fn meta(&self, dim: usize, cfg: &SketchConfig) -> DecompMeta {
        DecompMeta {
            key: "woodbury".into(),
            flops: factored_flops(dim, cfg.rank),
            randomized: false,
            projection_sides: 0,
            backend: backend::current(),
        }
    }

    fn factors_columns(&self) -> bool {
        true
    }

    fn factor_columns(
        &self,
        u: &Matrix,
        gamma: f64,
        lambda: f64,
        _col_sample: usize,
        _rng: &mut Pcg64,
    ) -> Result<FactoredSolve, String> {
        FactoredSolve::build(u.clone(), gamma, lambda)
    }
}

/// SENG's sketched-core strategy through the same representation: the k×k
/// core gram is estimated from a row subsample of `U` (unbiased rescale),
/// so one refresh costs O(col_sample·k²) instead of O(o·k²); the apply is
/// unchanged. Randomized — draws its row sample from the per-(round,
/// block, side) decomposition RNG stream, like every sketched strategy.
pub struct SketchedCore;

impl Decomposition for SketchedCore {
    fn key(&self) -> &str {
        "sketchcore"
    }

    fn decompose(&self, m: &Matrix, _cfg: &SketchConfig, _rng: &mut Pcg64) -> LowRankFactor {
        let e = crate::linalg::evd::sym_evd(m);
        LowRankFactor::new(e.u, e.lambda)
    }

    fn meta(&self, dim: usize, cfg: &SketchConfig) -> DecompMeta {
        DecompMeta {
            key: "sketchcore".into(),
            // The d·k² gram build shrinks to col_sample·k²; meta has no
            // policy in scope, so report the official SENG default (128).
            flops: factored_flops(128.min(dim), cfg.rank) + 4.0 * (dim * cfg.rank) as f64,
            randomized: true,
            projection_sides: 1,
            backend: backend::current(),
        }
    }

    fn factors_columns(&self) -> bool {
        true
    }

    fn factor_columns(
        &self,
        u: &Matrix,
        gamma: f64,
        lambda: f64,
        col_sample: usize,
        rng: &mut Pcg64,
    ) -> Result<FactoredSolve, String> {
        FactoredSolve::build_sketched(u.clone(), gamma, lambda, col_sample, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::spd_solve;

    /// The factored apply must equal the dense `(UUᵀ + γI + λI)⁻¹Y` solve.
    #[test]
    fn apply_matches_dense_solve() {
        let mut rng = Pcg64::new(1);
        for &(d, k, c) in &[(12usize, 4usize, 3usize), (30, 7, 2), (9, 9, 4), (16, 1, 1)] {
            let u = rng.gaussian_matrix(d, k);
            let y = rng.gaussian_matrix(d, c);
            for &(gamma, lambda) in &[(0.0, 0.3), (0.5, 0.1), (1.0, 1e-3)] {
                let mut f = FactoredSolve::build(u.clone(), gamma, lambda).unwrap();
                let got = f.apply(lambda, &y);
                let mut dense = f.reconstruct();
                dense.add_diag(lambda);
                let expect = spd_solve(&dense, &y).unwrap();
                assert!(
                    got.rel_err(&expect) < 1e-10,
                    "d={d} k={k} γ={gamma} λ={lambda}: rel err {}",
                    got.rel_err(&expect)
                );
            }
        }
    }

    /// Changing λ between applies triggers the lazy core rebuild and still
    /// matches the dense solve at the new damping.
    #[test]
    fn lazy_core_rebuild_on_lambda_change() {
        let mut rng = Pcg64::new(2);
        let u = rng.gaussian_matrix(20, 5);
        let y = rng.gaussian_matrix(20, 2);
        let mut f = FactoredSolve::build(u, 0.25, 0.5).unwrap();
        let _ = f.apply(0.5, &y);
        let got = f.apply(0.05, &y);
        assert_eq!(f.lambda(), 0.05, "cache must track the new damping");
        let mut dense = f.reconstruct();
        dense.add_diag(0.05);
        let expect = spd_solve(&dense, &y).unwrap();
        assert!(got.rel_err(&expect) < 1e-10);
    }

    /// Rank-deficient and duplicate-column U: `S = I + UᵀU/λ'` stays SPD,
    /// the build succeeds, and the apply still matches the dense solve.
    #[test]
    fn rank_deficient_and_duplicate_columns() {
        let mut rng = Pcg64::new(3);
        let base = rng.gaussian_matrix(14, 2);
        // Columns: [b0, b1, b0, b0+b1, 0] — rank 2 out of 5.
        let mut u = Matrix::zeros(14, 5);
        for r in 0..14 {
            u[(r, 0)] = base[(r, 0)];
            u[(r, 1)] = base[(r, 1)];
            u[(r, 2)] = base[(r, 0)];
            u[(r, 3)] = base[(r, 0)] + base[(r, 1)];
            u[(r, 4)] = 0.0;
        }
        let y = rng.gaussian_matrix(14, 3);
        let mut f = FactoredSolve::build(u, 0.0, 0.2).unwrap();
        let got = f.apply(0.2, &y);
        let mut dense = f.reconstruct();
        dense.add_diag(0.2);
        let expect = spd_solve(&dense, &y).unwrap();
        assert!(got.rel_err(&expect) < 1e-9, "rel err {}", got.rel_err(&expect));
    }

    /// A NaN in the retained columns must surface as a non-finite output,
    /// not silently vanish in the core solve.
    #[test]
    fn nan_propagates_through_core_solve() {
        let mut rng = Pcg64::new(4);
        let mut u = rng.gaussian_matrix(10, 3);
        u[(5, 1)] = f64::NAN;
        let y = Matrix::ones(10, 2);
        match FactoredSolve::build(u, 0.0, 0.5) {
            // Either the Cholesky rejects the poisoned core outright…
            Err(_) => {}
            // …or the NaN flows through the factorization into the output.
            Ok(mut f) => assert!(!f.apply(0.5, &y).all_finite()),
        }
    }

    /// Rank-0 (no retained columns): the operator is `γI`, the apply is
    /// `Y/(γ+λ)`.
    #[test]
    fn rank_zero_is_scaled_identity() {
        let mut f = FactoredSolve::build(Matrix::zeros(6, 0), 1.0, 0.5).unwrap();
        let out = f.apply(0.5, &Matrix::ones(6, 2));
        for i in 0..6 {
            for j in 0..2 {
                assert!((out[(i, j)] - 1.0 / 1.5).abs() < 1e-14);
            }
        }
    }

    /// `from_parts` rebuilds the identical factorization: bitwise-equal
    /// applies (the checkpoint-restore contract).
    #[test]
    fn from_parts_restores_bitwise() {
        let mut rng = Pcg64::new(5);
        let u = rng.gaussian_matrix(18, 6);
        let y = rng.gaussian_matrix(18, 3);
        let mut f = FactoredSolve::build(u, 0.7, 0.3).unwrap();
        let mut g = FactoredSolve::from_parts(
            f.u().clone(),
            f.gram().clone(),
            f.gamma(),
            f.lambda(),
        )
        .unwrap();
        assert_eq!(f.apply(0.3, &y).as_slice(), g.apply(0.3, &y).as_slice());
        // Shape mismatch between gram and U fails loudly.
        assert!(FactoredSolve::from_parts(
            Matrix::zeros(4, 2),
            Matrix::zeros(3, 3),
            0.0,
            0.1
        )
        .is_err());
    }

    /// The sketched core is unbiased: averaging many sketched grams
    /// approaches the exact one, and `col_sample >= d` is exactly exact.
    #[test]
    fn sketched_core_unbiased_and_exact_at_full_sample() {
        let mut rng = Pcg64::new(6);
        let u = rng.gaussian_matrix(256, 6);
        let exact = gemm::matmul_tn(&u, &u);
        let mut acc = Matrix::zeros(6, 6);
        let trials = 80;
        let mut srng = Pcg64::new(77);
        for _ in 0..trials {
            let f = FactoredSolve::build_sketched(u.clone(), 0.0, 0.5, 32, &mut srng).unwrap();
            acc.axpy(1.0 / trials as f64, f.gram());
        }
        assert!(acc.rel_err(&exact) < 0.25, "rel err {}", acc.rel_err(&exact));
        // Full sample degrades to the exact build.
        let full = FactoredSolve::build_sketched(u.clone(), 0.0, 0.5, 10_000, &mut srng).unwrap();
        assert_eq!(full.gram().as_slice(), exact.as_slice());
    }

    /// Strategy plumbing: keys, column-factoring flags, and the dense
    /// fallback decompose.
    #[test]
    fn strategies_expose_column_factoring() {
        use crate::rnla::decomposition::{Exact, Rsvd};
        assert!(Woodbury.factors_columns());
        assert!(SketchedCore.factors_columns());
        assert!(!Exact.factors_columns());
        assert!(!Rsvd.factors_columns());
        // Non-factoring strategies reject factor_columns with their key.
        let mut rng = Pcg64::new(8);
        let u = Matrix::ones(4, 2);
        let err = Exact.factor_columns(&u, 0.0, 0.1, 64, &mut rng).unwrap_err();
        assert!(err.contains("exact"), "{err}");
        // Woodbury ignores the sample budget: exact core.
        let f = Woodbury.factor_columns(&u, 0.0, 0.1, 1, &mut rng).unwrap();
        assert_eq!(f.gram().as_slice(), gemm::matmul_tn(&u, &u).as_slice());
        // Metadata: factored solves are far cheaper than the dense EVD at
        // k ≪ d, and the strategies fall back to exact EVD on dense input.
        let cfg = SketchConfig::new(64, 10, 4);
        let m = Woodbury.meta(50_000, &cfg);
        assert!(m.flops < crate::rnla::decomposition::Exact.meta(50_000, &cfg).flops / 1e3);
        assert!(!m.randomized);
        assert!(SketchedCore.meta(50_000, &cfg).randomized);
        let x = {
            let g = rng.gaussian_matrix(8, 10);
            gemm::syrk(&g)
        };
        let e = Woodbury.decompose(&x, &cfg, &mut rng);
        assert_eq!(e.rank(), 8);
    }
}
