//! Randomized SVD — Algorithm 2 of the paper (after Halko–Martinsson–Tropp).
//!
//! For the square-symmetric-PSD K-factor case the paper's §2.2.2 note
//! applies: the returned `Ṽ` approximates the leading eigenvectors better
//! than `Ũ` does (Saibaba 2018), so RS-KFAC reconstructs with
//! `Ṽ Σ̃ Ṽᵀ` — "virtually zero projection error". Both factors are returned
//! so benches can measure the U-vs-V gap (experiment E7).

use crate::linalg::{gemm, svd, Matrix, Pcg64};
use crate::rnla::sketch::{range_finder, SketchConfig};

/// Rank-r randomized SVD `X ≈ Ũ Σ̃ Ṽᵀ`, singular values descending.
pub struct Rsvd {
    pub u: Matrix,       // m × r
    pub sigma: Vec<f64>, // r
    pub v: Matrix,       // n × r
}

impl Rsvd {
    /// `Ũ Σ̃ Ṽᵀ` reconstruction.
    pub fn reconstruct_uv(&self) -> Matrix {
        let mut us = self.u.clone();
        gemm::scale_cols(&mut us, &self.sigma);
        gemm::matmul_nt(&us, &self.v)
    }

    /// Symmetric reconstruction `Ṽ Σ̃ Ṽᵀ` — what RS-KFAC uses for the
    /// square-symmetric PSD K-factors (paper §2.2.2).
    pub fn reconstruct_vv(&self) -> Matrix {
        let mut vs = self.v.clone();
        gemm::scale_cols(&mut vs, &self.sigma);
        gemm::matmul_nt(&vs, &self.v)
    }

    /// Symmetric reconstruction from the U factor (for the E7 comparison).
    pub fn reconstruct_uu(&self) -> Matrix {
        let mut us = self.u.clone();
        gemm::scale_cols(&mut us, &self.sigma);
        gemm::matmul_nt(&us, &self.u)
    }
}

/// Algorithm 2: rank-`cfg.rank` randomized SVD of `x` (m×n).
///
/// Complexity O(mn(r+r_l) + n²(r+r_l)): sketch + QR + `B = QᵀX` + SVD of the
/// small `(r+l)×n` matrix `B` (done on `Bᵀ` so the Jacobi sweep runs on the
/// thin side), + back-projection `Ũ = Q U_B`.
///
/// Precision policy: only the range-finder GEMMs inside [`range_finder`]
/// honor `[linalg] precision = "mixed"`; `B = QᵀX`, the Jacobi SVD, and the
/// back-projection below stay pinned f64, so the factor handed to the
/// optimizer carries full-precision singular pairs of the (possibly
/// mixed-precision-found) subspace.
pub fn rsvd(x: &Matrix, cfg: &SketchConfig, rng: &mut Pcg64) -> Rsvd {
    let (m, n) = x.shape();
    let q = range_finder(x, cfg, rng); // m × s
    let b = gemm::matmul_tn(&q, x); // s × n
    // SVD of B via Bᵀ (n × s, n ≥ s): Bᵀ = V_B Σ U_Bᵀ.
    let svd_bt = svd::thin_svd(&b.transpose());
    let r = cfg.rank.min(svd_bt.sigma.len());
    let u_b = svd_bt.v.first_cols(r); // s × r
    let v = svd_bt.u.first_cols(r); // n × r  (the "more accurate" factor)
    let sigma = svd_bt.sigma[..r].to_vec();
    let u = gemm::matmul(&q, &u_b); // m × r
    debug_assert_eq!(u.shape(), (m, r));
    debug_assert_eq!(v.shape(), (n, r));
    Rsvd { u, sigma, v }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::orthogonality_defect;
    use crate::linalg::svd::thin_svd;

    fn decaying_psd(rng: &mut Pcg64, n: usize, decay: f64) -> Matrix {
        // U diag(decay^i) Uᵀ with random orthonormal U.
        let g = rng.gaussian_matrix(n, n);
        let q = crate::linalg::qr::orthonormalize(&g);
        let d: Vec<f64> = (0..n).map(|i| decay.powi(i as i32)).collect();
        let mut qd = q.clone();
        gemm::scale_cols(&mut qd, &d);
        gemm::matmul_nt(&qd, &q)
    }

    #[test]
    fn rsvd_recovers_low_rank_exactly() {
        let mut rng = Pcg64::new(1);
        let u = rng.gaussian_matrix(40, 4);
        let v = rng.gaussian_matrix(4, 30);
        let x = gemm::matmul(&u, &v);
        let out = rsvd(&x, &SketchConfig::new(4, 4, 2), &mut rng);
        assert!(out.reconstruct_uv().rel_err(&x) < 1e-9);
        assert!(orthogonality_defect(&out.u) < 1e-9);
        assert!(orthogonality_defect(&out.v) < 1e-9);
    }

    #[test]
    fn rsvd_sigma_matches_svd_head() {
        let mut rng = Pcg64::new(2);
        let x = decaying_psd(&mut rng, 50, 0.7);
        let exact = thin_svd(&x);
        let out = rsvd(&x, &SketchConfig::new(8, 6, 3), &mut rng);
        for i in 0..8 {
            let rel = (out.sigma[i] - exact.sigma[i]).abs() / exact.sigma[i];
            assert!(rel < 1e-6, "σ_{i}: {} vs {}", out.sigma[i], exact.sigma[i]);
        }
    }

    #[test]
    fn rsvd_near_optimal_truncation_error() {
        // Halko et al.: with oversampling + power iteration, the RSVD error
        // is close to the optimal (Eckart–Young) rank-r error.
        let mut rng = Pcg64::new(3);
        let x = decaying_psd(&mut rng, 60, 0.8);
        let exact = thin_svd(&x);
        let r = 10;
        let optimal: f64 = exact.sigma[r..].iter().map(|s| s * s).sum::<f64>().sqrt();
        let out = rsvd(&x, &SketchConfig::new(r, 8, 3), &mut rng);
        let err = (&x - &out.reconstruct_uv()).fro_norm();
        assert!(err < 1.5 * optimal + 1e-12, "err {err} vs optimal {optimal}");
    }

    #[test]
    fn v_reconstruction_beats_u_on_symmetric_psd() {
        // Paper §2.2.2 / Saibaba 2018: Ṽ Σ̃ Ṽᵀ is the better symmetric
        // reconstruction. Check on EA-like PSD matrices (averaged trials).
        let mut trials_v = 0.0;
        let mut trials_u = 0.0;
        for seed in 0..6 {
            let mut rng = Pcg64::new(10 + seed);
            let x = decaying_psd(&mut rng, 48, 0.75);
            let out = rsvd(&x, &SketchConfig::new(6, 4, 1), &mut rng);
            trials_v += (&x - &out.reconstruct_vv()).fro_norm();
            trials_u += (&x - &out.reconstruct_uu()).fro_norm();
        }
        assert!(
            trials_v <= trials_u * 1.001,
            "V-recon should be at least as good: V={trials_v} U={trials_u}"
        );
    }

    #[test]
    fn rank_clamped_when_exceeding_dim() {
        let mut rng = Pcg64::new(4);
        let x = rng.gaussian_matrix(12, 6);
        let out = rsvd(&x, &SketchConfig::new(10, 5, 1), &mut rng);
        assert!(out.sigma.len() <= 6);
        assert_eq!(out.u.rows(), 12);
        assert_eq!(out.v.rows(), 6);
    }

    #[test]
    fn deterministic_given_seed() {
        let x = Pcg64::new(7).gaussian_matrix(20, 20);
        let a = rsvd(&x, &SketchConfig::new(5, 3, 2), &mut Pcg64::new(42));
        let b = rsvd(&x, &SketchConfig::new(5, 3, 2), &mut Pcg64::new(42));
        assert_eq!(a.sigma, b.sigma);
        assert!(a.u.rel_err(&b.u) < 1e-15);
    }
}
