//! Nyström approximation — the third classic randomized PSD factorization,
//! included as the paper's "future work: refining the RS-KFAC and SRE-KFAC
//! algorithms" direction.
//!
//! For PSD X and a sketch basis Q (from the same range finder):
//!
//! ```text
//!     X ≈ (XQ) (QᵀXQ)⁻¹ (XQ)ᵀ
//! ```
//!
//! Unlike SREVD (which Rayleigh–Ritz-projects X into span(Q)), the Nyström
//! form reuses the *unprojected* product XQ on both outer sides, which is
//! known to be strictly more accurate than the projection for PSD matrices
//! at identical sketch cost (Gittens & Mahoney 2016). We convert the result
//! to the same `Ũ D̃ Ũᵀ` eigen-form the optimizers consume, so it drops
//! into the K-FAC family as the `nystrom` [`crate::rnla::Decomposition`]
//! strategy (NYS-KFAC).

use crate::linalg::{evd, gemm, qr, Matrix, Pcg64};
use crate::rnla::sketch::{range_finder, SketchConfig};
use crate::rnla::srevd::Srevd;

/// Rank-r Nyström eigen-approximation of a square symmetric PSD matrix.
///
/// Returns the same struct shape as SREVD (`Ũ`, descending `λ̃`).
///
/// Precision policy: only the [`range_finder`] sketch honors `[linalg]
/// precision = "mixed"`; the core solve, thin QR, and small EVDs below are
/// pinned f64 (they set the factor's numerical quality, not the subspace).
pub fn nystrom(x: &Matrix, cfg: &SketchConfig, rng: &mut Pcg64) -> Srevd {
    assert!(x.is_square(), "nystrom: matrix must be square symmetric PSD");
    let q = range_finder(x, cfg, rng); // n × s
    let y = gemm::matmul(x, &q); // XQ : n × s
    let mut c = gemm::matmul_tn(&q, &y); // QᵀXQ : s × s
    c.symmetrize();
    // Shifted pseudo-inverse square root of the core for numerical safety:
    // X̃ = Y C⁺ Yᵀ = (Y C^{-1/2}) (Y C^{-1/2})ᵀ, via EVD of C.
    let ec = evd::sym_evd(&c);
    let s = ec.lambda.len();
    // Tolerance relative to the largest core eigenvalue.
    let tol = ec.lambda.first().copied().unwrap_or(0.0).max(0.0) * 1e-12;
    let inv_sqrt: Vec<f64> =
        ec.lambda.iter().map(|&l| if l > tol { 1.0 / l.sqrt() } else { 0.0 }).collect();
    // B = Y · U_c · diag(λ^{-1/2}) : n × s, so X̃ = B Bᵀ.
    let mut ucs = ec.u.clone();
    gemm::scale_cols(&mut ucs, &inv_sqrt);
    let b = gemm::matmul(&y, &ucs);
    // Eigen-form of B Bᵀ via thin QR + small EVD: B = Q_b R, B Bᵀ =
    // Q_b (R Rᵀ) Q_bᵀ.
    let f = qr::thin_qr(&b);
    let mut rrt = gemm::matmul_nt(&f.r, &f.r);
    rrt.symmetrize();
    let er = evd::sym_evd(&rrt);
    let r = cfg.rank.min(s);
    let u = gemm::matmul(&f.q, &er.u.first_cols(r));
    Srevd { u, lambda: er.lambda[..r].to_vec() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::orthogonality_defect;
    use crate::rnla::srevd::srevd;

    fn decaying_psd(rng: &mut Pcg64, n: usize, decay: f64) -> Matrix {
        let q = qr::orthonormalize(&rng.gaussian_matrix(n, n));
        let lam: Vec<f64> = (0..n).map(|i| decay.powi(i as i32)).collect();
        let mut qd = q.clone();
        gemm::scale_cols(&mut qd, &lam);
        gemm::matmul_nt(&qd, &q)
    }

    #[test]
    fn recovers_low_rank_psd_exactly() {
        let mut rng = Pcg64::new(1);
        let g = rng.gaussian_matrix(40, 5);
        let x = gemm::syrk(&g);
        let out = nystrom(&x, &SketchConfig::new(5, 5, 2), &mut rng);
        assert!(out.reconstruct().rel_err(&x) < 1e-7, "err {}", out.reconstruct().rel_err(&x));
        assert!(orthogonality_defect(&out.u) < 1e-8);
    }

    #[test]
    fn eigenvalues_match_exact_head() {
        let mut rng = Pcg64::new(2);
        let x = decaying_psd(&mut rng, 50, 0.7);
        let exact = evd::sym_evd(&x);
        let out = nystrom(&x, &SketchConfig::new(8, 6, 3), &mut rng);
        for i in 0..8 {
            let rel = (out.lambda[i] - exact.lambda[i]).abs() / exact.lambda[i];
            assert!(rel < 1e-4, "λ_{i}: {} vs {}", out.lambda[i], exact.lambda[i]);
        }
    }

    #[test]
    fn at_least_as_accurate_as_srevd() {
        // Gittens–Mahoney: Nyström ≥ projection accuracy for PSD inputs
        // (checked in aggregate over seeds).
        let (mut err_nys, mut err_sre) = (0.0, 0.0);
        for seed in 0..6 {
            let mut rng = Pcg64::new(30 + seed);
            let x = decaying_psd(&mut rng, 44, 0.8);
            let cfg = SketchConfig::new(6, 4, 1);
            let mut ra = Pcg64::new(70 + seed);
            let mut rb = Pcg64::new(70 + seed);
            err_nys += (&x - &nystrom(&x, &cfg, &mut ra).reconstruct()).fro_norm();
            err_sre += (&x - &srevd(&x, &cfg, &mut rb).reconstruct()).fro_norm();
        }
        assert!(
            err_nys <= err_sre * 1.02,
            "Nyström {err_nys} should beat/match SREVD {err_sre}"
        );
    }

    #[test]
    fn handles_rank_deficient_core() {
        // Core QᵀXQ singular (X rank < sketch size): pseudo-inverse path.
        let mut rng = Pcg64::new(4);
        let g = rng.gaussian_matrix(30, 2);
        let x = gemm::syrk(&g); // rank 2
        let out = nystrom(&x, &SketchConfig::new(6, 4, 1), &mut rng);
        assert!(out.u.all_finite());
        assert!(out.reconstruct().rel_err(&x) < 1e-6);
    }

    #[test]
    fn eigenvalues_descending_nonnegative() {
        let mut rng = Pcg64::new(5);
        let x = decaying_psd(&mut rng, 24, 0.6);
        let out = nystrom(&x, &SketchConfig::new(8, 4, 1), &mut rng);
        for w in out.lambda.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(out.lambda.iter().all(|&l| l >= -1e-10));
    }
}
