//! Randomized range finder — the sketching stage shared by RSVD (Alg. 2,
//! lines 3–5) and SREVD (Alg. 3, lines 3–5).
//!
//! Given X (m×n) and a target subspace size s = r + r_l, draw a Gaussian
//! test matrix Ω (n×s), form Y = XΩ, optionally refine with `n_pwr_it`
//! power iterations Y ← X(XᵀY) (re-orthonormalizing between steps to stop
//! the columns collapsing onto the dominant mode), and return the
//! orthonormal basis Q = qr(Y).Q.
//!
//! The paper uses n_pwr_it = 4 in its experiments (§5).

use crate::linalg::{backend, gemm, qr, Matrix, Pcg64};

/// Configuration for the randomized range finder.
#[derive(Clone, Debug)]
pub struct SketchConfig {
    /// Target rank r.
    pub rank: usize,
    /// Oversampling parameter r_l (paper: 10, +1 at epochs 22/30).
    pub oversample: usize,
    /// Number of power iterations n_pwr-it (paper: 4).
    pub n_power_iter: usize,
}

impl SketchConfig {
    pub fn new(rank: usize, oversample: usize, n_power_iter: usize) -> Self {
        SketchConfig { rank, oversample, n_power_iter }
    }

    /// Subspace size s = r + r_l, clamped to the matrix dimension `n`.
    pub fn subspace(&self, n: usize) -> usize {
        (self.rank + self.oversample).min(n)
    }
}

/// Orthonormal basis for the approximate range of `x`.
///
/// Works for arbitrary (also non-symmetric) X; for the symmetric K-factor
/// case the power iteration is `Y ← X (X Y)` with symmetric X, but we keep
/// the general Xᵀ form so the routine is reusable for rectangular sketches.
///
/// These three GEMMs are the *only* call sites in the repo that honor
/// `[linalg] precision = "mixed"` (f32 operands, f64 accumulation): the
/// sketch already injects Gaussian randomness, so the subspace it finds is
/// noise-tolerant by construction (arXiv 2206.15397 §4) — whereas the
/// exact/EVD paths stay pinned f64. The QR orthonormalizations between the
/// power iterations remain full f64 so the returned basis is orthonormal
/// to f64 working precision regardless of the knob.
pub fn range_finder(x: &Matrix, cfg: &SketchConfig, rng: &mut Pcg64) -> Matrix {
    let (m, n) = x.shape();
    let s = cfg.subspace(n.min(m));
    assert!(s > 0, "range_finder: empty subspace");
    let _sp = crate::obs::span("rnla.sketch")
        .arg("m", m)
        .arg("n", n)
        .arg("s", s)
        .arg("n_power_iter", cfg.n_power_iter)
        .arg("precision", backend::current().precision.name());
    let omega = rng.gaussian_matrix(n, s);
    // Y = X Ω : m × s
    let mut y = backend::sketch_matmul(x, &omega);
    // Power iterations with re-orthonormalization (Halko et al. Alg. 4.4).
    for _ in 0..cfg.n_power_iter {
        let q = qr::orthonormalize(&y);
        let z = backend::sketch_matmul_tn(x, &q); // n × s
        let qz = qr::orthonormalize(&z);
        y = backend::sketch_matmul(x, &qz); // m × s
    }
    qr::orthonormalize(&y)
}

/// Residual-based posterior error estimate `||X − QQᵀX||_F` (exact, by
/// explicit computation — used in tests/benches, not on the hot path).
pub fn range_residual(x: &Matrix, q: &Matrix) -> f64 {
    let qtx = gemm::matmul_tn(q, x);
    let proj = gemm::matmul(q, &qtx);
    (x - &proj).fro_norm()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic matrix with known rank-k structure + noise floor.
    fn low_rank_plus_noise(
        rng: &mut Pcg64,
        m: usize,
        n: usize,
        k: usize,
        noise: f64,
    ) -> Matrix {
        let u = rng.gaussian_matrix(m, k);
        let v = rng.gaussian_matrix(k, n);
        let mut x = gemm::matmul(&u, &v);
        let e = rng.gaussian_matrix(m, n);
        x.axpy(noise, &e);
        x
    }

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Pcg64::new(1);
        let x = low_rank_plus_noise(&mut rng, 60, 40, 5, 1e-6);
        let q = range_finder(&x, &SketchConfig::new(5, 4, 2), &mut rng);
        assert_eq!(q.shape(), (60, 9));
        assert!(qr::orthogonality_defect(&q) < 1e-10);
    }

    #[test]
    fn captures_low_rank_range() {
        let mut rng = Pcg64::new(2);
        let x = low_rank_plus_noise(&mut rng, 80, 50, 6, 1e-9);
        let q = range_finder(&x, &SketchConfig::new(6, 6, 2), &mut rng);
        let res = range_residual(&x, &q);
        assert!(res < 1e-6 * x.fro_norm(), "residual {res}");
    }

    #[test]
    fn power_iteration_improves_noisy_case() {
        let mut rng = Pcg64::new(3);
        let x = low_rank_plus_noise(&mut rng, 100, 100, 8, 0.05);
        let mut r0 = 0.0;
        let mut r3 = 0.0;
        // Average over a few draws to avoid flaky comparisons.
        for trial in 0..5 {
            let mut rng_a = Pcg64::new(100 + trial);
            let mut rng_b = Pcg64::new(100 + trial);
            r0 += range_residual(&x, &range_finder(&x, &SketchConfig::new(8, 4, 0), &mut rng_a));
            r3 += range_residual(&x, &range_finder(&x, &SketchConfig::new(8, 4, 3), &mut rng_b));
        }
        assert!(r3 <= r0, "power iters should not hurt: {r3} vs {r0}");
    }

    #[test]
    fn subspace_clamped_to_dim() {
        let cfg = SketchConfig::new(100, 50, 1);
        assert_eq!(cfg.subspace(30), 30);
        let mut rng = Pcg64::new(4);
        let x = rng.gaussian_matrix(20, 10);
        let q = range_finder(&x, &cfg, &mut rng);
        assert_eq!(q.cols(), 10);
    }

    #[test]
    fn exact_for_full_subspace() {
        // s = n: the sketch spans the whole column space → zero residual.
        let mut rng = Pcg64::new(5);
        let x = rng.gaussian_matrix(25, 10);
        let q = range_finder(&x, &SketchConfig::new(10, 0, 0), &mut rng);
        assert!(range_residual(&x, &q) < 1e-9 * x.fro_norm());
    }
}
