//! Error decomposition and spectrum-decay analysis utilities.
//!
//! Two concerns from the paper:
//! 1. §2.2.1 — split the RSVD/SREVD error into *truncation* error (what an
//!    exact rank-r SVD would lose) and *projection* error (extra error from
//!    the random subspace). Used by experiment E7 and the rNLA benches.
//! 2. §3 (Prop. 3.1) — the `r_ε` bound on how many eigenvalues of an EA
//!    K-factor can sit above `ε·λ_max`, and empirical spectrum statistics.

use crate::linalg::{evd, Matrix};

/// Error split for a symmetric rank-r approximation `approx ≈ x`.
#[derive(Clone, Debug)]
pub struct ErrorSplit {
    /// ‖X − X_r‖_F for the exact rank-r truncation X_r (Eckart–Young floor).
    pub truncation: f64,
    /// ‖X_r − approx‖_F — extra error from randomization.
    pub projection: f64,
    /// ‖X − approx‖_F.
    pub total: f64,
}

/// Compute the truncation/projection error split of a symmetric rank-r
/// approximation against the exact EVD (O(d³) — diagnostics only).
pub fn error_split(x: &Matrix, approx: &Matrix, r: usize) -> ErrorSplit {
    assert!(x.is_square() && approx.shape() == x.shape());
    let e = evd::sym_evd(x);
    let xr = e.truncate(r).reconstruct();
    ErrorSplit {
        truncation: (x - &xr).fro_norm(),
        projection: (&xr - approx).fro_norm(),
        total: (x - approx).fro_norm(),
    }
}

/// Proposition 3.1: `r_ε = ⌈ log(αε) / log(ρ) ⌉`.
///
/// With EA decay factor ρ, eigenvalue floor assumption λ_max ≥ α·σ_M², and
/// tolerance ε, at most `r_ε · n_M` eigenvalues of the EA K-factor exceed
/// `ε·λ_max` (n_M = per-step rank, ∝ batch size).
pub fn r_epsilon(alpha: f64, epsilon: f64, rho: f64) -> usize {
    assert!(alpha > 0.0 && alpha < 1.0, "alpha in (0,1)");
    assert!(epsilon > 0.0 && epsilon < 1.0, "epsilon in (0,1)");
    assert!(rho > 0.0 && rho < 1.0, "rho in (0,1)");
    ((alpha * epsilon).ln() / rho.ln()).ceil() as usize
}

/// The Prop. 3.1 bound on retained modes: `min(r_ε·n_M, d_M)`.
pub fn prop31_mode_bound(alpha: f64, epsilon: f64, rho: f64, n_m: usize, d_m: usize) -> usize {
    (r_epsilon(alpha, epsilon, rho) * n_m).min(d_m)
}

/// Empirical count of eigenvalues above `epsilon * λ_max` in a descending
/// eigenvalue list.
pub fn modes_above(lambda: &[f64], epsilon: f64) -> usize {
    let lmax = lambda.first().copied().unwrap_or(0.0);
    if lmax <= 0.0 {
        return 0;
    }
    lambda.iter().take_while(|&&l| l >= epsilon * lmax).count()
}

/// Spectrum-decay summary used by the Fig. 1 probe: how many modes it takes
/// to decay `orders` orders of magnitude below λ_max (paper: 1.5 orders in
/// ~200 modes at equilibrium).
pub fn modes_to_decay(lambda: &[f64], orders: f64) -> Option<usize> {
    let lmax = lambda.first().copied().unwrap_or(0.0);
    if lmax <= 0.0 {
        return None;
    }
    let floor = lmax * 10f64.powf(-orders);
    lambda.iter().position(|&l| l < floor)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gemm, qr, Pcg64};
    use crate::rnla::rsvd::rsvd;
    use crate::rnla::sketch::SketchConfig;
    use crate::rnla::srevd::srevd;

    fn decaying_psd(rng: &mut Pcg64, n: usize, decay: f64) -> Matrix {
        let g = rng.gaussian_matrix(n, n);
        let q = qr::orthonormalize(&g);
        let d: Vec<f64> = (0..n).map(|i| decay.powi(i as i32)).collect();
        let mut qd = q.clone();
        gemm::scale_cols(&mut qd, &d);
        gemm::matmul_nt(&qd, &q)
    }

    #[test]
    fn r_epsilon_paper_values() {
        // Paper §3: ε=0.03, α=0.1, ρ=0.95, n_M=256 → r_ε·n_M = 29184.
        let re = r_epsilon(0.1, 0.03, 0.95);
        assert_eq!(re, 114);
        assert_eq!(re * 256, 29184);
        // §4.3: ρ=0.5 reduces it to 2304 = 9·256.
        let re_kld = r_epsilon(0.1, 0.03, 0.5);
        assert_eq!(re_kld, 9);
        assert_eq!(re_kld * 256, 2304);
    }

    #[test]
    fn mode_bound_clamps_to_dim() {
        assert_eq!(prop31_mode_bound(0.1, 0.03, 0.95, 256, 512), 512);
        assert_eq!(prop31_mode_bound(0.1, 0.03, 0.5, 4, 512), 36);
    }

    #[test]
    fn modes_above_counts_correctly() {
        let lambda = [10.0, 5.0, 1.0, 0.2, 0.01];
        assert_eq!(modes_above(&lambda, 0.09), 3); // ≥ 0.9
        assert_eq!(modes_above(&lambda, 0.5), 2); // ≥ 5.0
        assert_eq!(modes_above(&lambda, 1e-4), 5);
        assert_eq!(modes_above(&[], 0.1), 0);
    }

    #[test]
    fn modes_to_decay_finds_threshold() {
        // λ = 10^0, 10^-1, 10^-2, ...
        let lambda: Vec<f64> = (0..6).map(|i| 10f64.powi(-i)).collect();
        assert_eq!(modes_to_decay(&lambda, 1.5), Some(2)); // first < 10^-1.5 is idx 2
        assert_eq!(modes_to_decay(&lambda, 10.0), None);
    }

    #[test]
    fn error_split_consistency() {
        // total² ≈ truncation² + projection² only when projection ⟂
        // truncation — not exact, but total ≤ truncation + projection
        // (triangle) must always hold, and projection must be small for
        // RSVD on a decaying spectrum.
        let mut rng = Pcg64::new(1);
        let x = decaying_psd(&mut rng, 40, 0.7);
        let r = 8;
        let out = rsvd(&x, &SketchConfig::new(r, 6, 2), &mut rng);
        let split = error_split(&x, &out.reconstruct_vv(), r);
        assert!(split.total <= split.truncation + split.projection + 1e-9);
        assert!(split.projection < 0.2 * split.truncation.max(1e-12),
            "projection {} vs truncation {}", split.projection, split.truncation);
    }

    #[test]
    fn srevd_projection_error_exceeds_rsvd() {
        let (mut p_sre, mut p_rsv) = (0.0, 0.0);
        for seed in 0..6 {
            let mut rng = Pcg64::new(30 + seed);
            let x = decaying_psd(&mut rng, 40, 0.8);
            let cfg = SketchConfig::new(6, 4, 1);
            let mut ra = Pcg64::new(7 + seed);
            let mut rb = Pcg64::new(7 + seed);
            p_sre += error_split(&x, &srevd(&x, &cfg, &mut ra).reconstruct(), 6).projection;
            p_rsv += error_split(&x, &rsvd(&x, &cfg, &mut rb).reconstruct_vv(), 6).projection;
        }
        assert!(p_sre >= p_rsv * 0.999, "SREVD proj {p_sre} vs RSVD proj {p_rsv}");
    }
}
