//! Low-rank damped inverse application — equation (13) of the paper.
//!
//! Given a rank-r approximation `X ≈ Ũ D̃ Ũᵀ` and damping λ:
//!
//! `(Ũ D̃ Ũᵀ + λI)^{-1} V  =  Ũ [ (D̃+λI)^{-1} − λ^{-1} I ] Ũᵀ V  +  λ^{-1} V`
//!
//! which costs O(r·d + 2r·d²)… in the paper's accounting; here V is a d×c
//! matrix so the cost is O(r·d·c) — strictly cheaper than the O(d³)-ish
//! dense-inverse application it replaces in Alg. 1 line 15.

use crate::linalg::{gemm, Matrix};

/// A rank-r eigen/singular approximation `Ũ D̃ Ũᵀ` of a symmetric PSD matrix,
/// as produced by RSVD (V-factor) or SREVD, ready for damped inverse applies.
#[derive(Clone)]
pub struct LowRankFactor {
    /// d × r, (approximately) orthonormal columns.
    pub u: Matrix,
    /// r leading eigenvalues, descending.
    pub d: Vec<f64>,
}

impl LowRankFactor {
    pub fn new(u: Matrix, d: Vec<f64>) -> Self {
        assert_eq!(u.cols(), d.len(), "LowRankFactor: rank mismatch");
        LowRankFactor { u, d }
    }

    /// Identity-like placeholder of dimension d and rank 0: applying the
    /// damped inverse gives `V/(λ+1)`… no — rank-0 means the EA factor is
    /// treated as `0·I`, so the apply is `V/λ`. Used before the first
    /// decomposition is available (EA factors start at I, so callers
    /// normally seed with [`LowRankFactor::identity_seed`] instead).
    pub fn empty(dim: usize) -> Self {
        LowRankFactor { u: Matrix::zeros(dim, 0), d: vec![] }
    }

    /// Rank-0 factor representing the *identity* initialization of the EA
    /// K-factors: `X = I` is captured exactly by shifting λ by 1 at apply
    /// time; instead we keep it simple and return an explicit factor with
    /// no modes — callers that need exact-I behaviour apply with λ+1.
    pub fn identity_seed(dim: usize) -> Self {
        Self::empty(dim)
    }

    pub fn dim(&self) -> usize {
        self.u.rows()
    }

    pub fn rank(&self) -> usize {
        self.d.len()
    }

    /// Equation (13): `(ŨD̃Ũᵀ + λI)^{-1} V`.
    ///
    /// Cost: two thin gemms (d×r · r×c) plus an axpy — O(d·r·c).
    pub fn damped_inverse_apply(&self, lambda: f64, v: &Matrix) -> Matrix {
        assert!(lambda > 0.0, "damped_inverse_apply: λ must be > 0");
        assert_eq!(v.rows(), self.dim(), "damped_inverse_apply: dim mismatch");
        let inv_l = 1.0 / lambda;
        if self.rank() == 0 {
            let mut out = v.clone();
            out.scale_inplace(inv_l);
            return out;
        }
        // W = Ũᵀ V : r × c
        let mut w = gemm::matmul_tn(&self.u, v);
        // scale rows by ((d_i + λ)^{-1} − λ^{-1})
        let coeff: Vec<f64> = self.d.iter().map(|&di| 1.0 / (di + lambda) - inv_l).collect();
        gemm::scale_rows(&mut w, &coeff);
        // out = Ũ W + λ^{-1} V
        let mut out = gemm::matmul(&self.u, &w);
        out.axpy(inv_l, v);
        out
    }

    /// Apply `V (ŨD̃Ũᵀ + λI)^{-1}` from the right (for the forward factor Ā
    /// in the K-FAC step): equals `((ŨD̃Ũᵀ+λI)^{-1} Vᵀ)ᵀ`, computed without
    /// materializing the big transpose chain twice.
    pub fn damped_inverse_apply_right(&self, lambda: f64, v: &Matrix) -> Matrix {
        assert_eq!(v.cols(), self.dim(), "damped_inverse_apply_right: dim mismatch");
        let inv_l = 1.0 / lambda;
        if self.rank() == 0 {
            let mut out = v.clone();
            out.scale_inplace(inv_l);
            return out;
        }
        // W = V Ũ : c × r
        let mut w = gemm::matmul(v, &self.u);
        let coeff: Vec<f64> = self.d.iter().map(|&di| 1.0 / (di + lambda) - inv_l).collect();
        gemm::scale_cols(&mut w, &coeff);
        // out = W Ũᵀ + λ^{-1} V
        let mut out = gemm::matmul_nt(&w, &self.u);
        out.axpy(inv_l, v);
        out
    }

    /// Dense reconstruction `Ũ D̃ Ũᵀ` (for tests / spectrum dumps).
    pub fn reconstruct(&self) -> Matrix {
        if self.rank() == 0 {
            return Matrix::zeros(self.dim(), self.dim());
        }
        let mut us = self.u.clone();
        gemm::scale_cols(&mut us, &self.d);
        gemm::matmul_nt(&us, &self.u)
    }

    /// Largest retained eigenvalue (0 if rank 0).
    pub fn lambda_max(&self) -> f64 {
        self.d.first().copied().unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::spd_solve;
    use crate::linalg::evd::sym_evd;
    use crate::linalg::{Pcg64};

    fn psd_with_evd(rng: &mut Pcg64, n: usize) -> (Matrix, LowRankFactor) {
        let g = rng.gaussian_matrix(n, n + 3);
        let x = gemm::syrk(&g);
        let e = sym_evd(&x);
        let f = LowRankFactor::new(e.u.clone(), e.lambda.clone());
        (x, f)
    }

    #[test]
    fn full_rank_apply_matches_dense_solve() {
        let mut rng = Pcg64::new(1);
        let (x, f) = psd_with_evd(&mut rng, 14);
        let v = rng.gaussian_matrix(14, 3);
        let lambda = 0.4;
        let got = f.damped_inverse_apply(lambda, &v);
        let mut xd = x.clone();
        xd.add_diag(lambda);
        let expect = spd_solve(&xd, &v).unwrap();
        assert!(got.rel_err(&expect) < 1e-9, "err {}", got.rel_err(&expect));
    }

    #[test]
    fn eq13_identity_on_truncated_factor() {
        // For a *truncated* factor the formula must equal the dense inverse
        // of (U_r D_r U_rᵀ + λI) — verify against explicit reconstruction.
        let mut rng = Pcg64::new(2);
        let (_, f_full) = psd_with_evd(&mut rng, 12);
        let f = LowRankFactor::new(f_full.u.first_cols(4), f_full.d[..4].to_vec());
        let v = rng.gaussian_matrix(12, 2);
        let lambda = 0.25;
        let got = f.damped_inverse_apply(lambda, &v);
        let mut dense = f.reconstruct();
        dense.add_diag(lambda);
        let expect = spd_solve(&dense, &v).unwrap();
        assert!(got.rel_err(&expect) < 1e-9);
    }

    #[test]
    fn right_apply_is_transpose_of_left() {
        let mut rng = Pcg64::new(3);
        let (_, f_full) = psd_with_evd(&mut rng, 10);
        let f = LowRankFactor::new(f_full.u.first_cols(3), f_full.d[..3].to_vec());
        let v = rng.gaussian_matrix(4, 10);
        let right = f.damped_inverse_apply_right(0.7, &v);
        let left_t = f.damped_inverse_apply(0.7, &v.transpose()).transpose();
        assert!(right.rel_err(&left_t) < 1e-11);
    }

    #[test]
    fn rank_zero_is_scaled_identity() {
        let f = LowRankFactor::empty(6);
        let v = Matrix::ones(6, 2);
        let out = f.damped_inverse_apply(0.5, &v);
        for i in 0..6 {
            for j in 0..2 {
                assert!((out[(i, j)] - 2.0).abs() < 1e-14);
            }
        }
        let out_r = f.damped_inverse_apply_right(0.5, &Matrix::ones(2, 6));
        assert!((out_r[(0, 0)] - 2.0).abs() < 1e-14);
    }

    #[test]
    fn apply_cheaper_than_dense_is_consistent_on_wide_v() {
        let mut rng = Pcg64::new(4);
        let (_, f_full) = psd_with_evd(&mut rng, 20);
        let f = LowRankFactor::new(f_full.u.first_cols(5), f_full.d[..5].to_vec());
        // Compare against eq-13 left-hand side computed naively.
        let v = rng.gaussian_matrix(20, 20);
        let lambda = 0.9;
        let got = f.damped_inverse_apply(lambda, &v);
        let mut dense = f.reconstruct();
        dense.add_diag(lambda);
        let expect = spd_solve(&dense, &v).unwrap();
        assert!(got.rel_err(&expect) < 1e-9);
    }
}
