//! Symmetric Randomized EVD — Algorithm 3 of the paper.
//!
//! Exploits symmetry of the K-factor: project both sides onto the sketch
//! basis, `C = QᵀXQ` ((r+l)×(r+l)), eigendecompose the tiny `C`, and lift
//! `Ũ = Q P_C`. Same O(n²(r+l)) complexity class as RSVD but a smaller
//! constant — at the price of *projection error* on both sides (the paper's
//! §2.3 discussion, and the reason SRE-KFAC is slightly less accurate than
//! RS-KFAC in Table 1).

use crate::linalg::{evd, gemm, Matrix, Pcg64};
use crate::rnla::sketch::{range_finder, SketchConfig};

/// Rank-r symmetric randomized EVD `X ≈ Ũ D̃ Ũᵀ`, eigenvalues descending.
pub struct Srevd {
    pub u: Matrix,        // n × r
    pub lambda: Vec<f64>, // r
}

impl Srevd {
    /// `Ũ D̃ Ũᵀ` reconstruction.
    pub fn reconstruct(&self) -> Matrix {
        let mut us = self.u.clone();
        gemm::scale_cols(&mut us, &self.lambda);
        gemm::matmul_nt(&us, &self.u)
    }
}

/// Algorithm 3: rank-`cfg.rank` randomized EVD of square symmetric PSD `x`.
pub fn srevd(x: &Matrix, cfg: &SketchConfig, rng: &mut Pcg64) -> Srevd {
    assert!(x.is_square(), "srevd: matrix must be square symmetric");
    let q = range_finder(x, cfg, rng); // n × s
    let xq = gemm::matmul(x, &q); // n × s
    let c = gemm::matmul_tn(&q, &xq); // s × s  (= QᵀXQ)
    // The tiny EVD — O((r+l)³), "virtually free".
    let mut c_sym = c;
    c_sym.symmetrize();
    let e = evd::sym_evd(&c_sym);
    let r = cfg.rank.min(e.lambda.len());
    let p_c = e.u.first_cols(r); // s × r
    let u = gemm::matmul(&q, &p_c); // n × r
    Srevd { u, lambda: e.lambda[..r].to_vec() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::{orthogonality_defect, orthonormalize};
    use crate::rnla::rsvd::rsvd;

    fn decaying_psd(rng: &mut Pcg64, n: usize, decay: f64) -> Matrix {
        let g = rng.gaussian_matrix(n, n);
        let q = orthonormalize(&g);
        let d: Vec<f64> = (0..n).map(|i| decay.powi(i as i32)).collect();
        let mut qd = q.clone();
        gemm::scale_cols(&mut qd, &d);
        gemm::matmul_nt(&qd, &q)
    }

    #[test]
    fn srevd_recovers_low_rank_psd() {
        let mut rng = Pcg64::new(1);
        let g = rng.gaussian_matrix(40, 5);
        let x = gemm::syrk(&g); // rank 5 PSD
        let out = srevd(&x, &SketchConfig::new(5, 5, 2), &mut rng);
        assert!(out.reconstruct().rel_err(&x) < 1e-8);
        assert!(orthogonality_defect(&out.u) < 1e-9);
    }

    #[test]
    fn eigenvalues_match_exact_head() {
        let mut rng = Pcg64::new(2);
        let x = decaying_psd(&mut rng, 50, 0.7);
        let exact = evd::sym_evd(&x);
        let out = srevd(&x, &SketchConfig::new(8, 6, 3), &mut rng);
        for i in 0..8 {
            let rel = (out.lambda[i] - exact.lambda[i]).abs() / exact.lambda[i];
            assert!(rel < 1e-5, "λ_{i}: {} vs {}", out.lambda[i], exact.lambda[i]);
        }
    }

    #[test]
    fn eigenvalues_descending() {
        let mut rng = Pcg64::new(3);
        let x = decaying_psd(&mut rng, 30, 0.8);
        let out = srevd(&x, &SketchConfig::new(10, 4, 1), &mut rng);
        for w in out.lambda.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
    }

    #[test]
    fn projection_error_at_least_rsvd_v() {
        // Paper §2.3: SREVD projects both sides onto Q, so its error should
        // be >= the RSVD V-reconstruction error (averaged over seeds).
        let (mut err_sre, mut err_rsv) = (0.0, 0.0);
        for seed in 0..6 {
            let mut rng = Pcg64::new(20 + seed);
            let x = decaying_psd(&mut rng, 48, 0.75);
            let cfg = SketchConfig::new(6, 4, 1);
            let mut rng_a = Pcg64::new(99 + seed);
            let mut rng_b = Pcg64::new(99 + seed);
            err_sre += (&x - &srevd(&x, &cfg, &mut rng_a).reconstruct()).fro_norm();
            err_rsv += (&x - &rsvd(&x, &cfg, &mut rng_b).reconstruct_vv()).fro_norm();
        }
        assert!(
            err_sre >= err_rsv * 0.999,
            "SREVD {err_sre} should be >= RSVD-V {err_rsv}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let x = decaying_psd(&mut Pcg64::new(5), 24, 0.6);
        let a = srevd(&x, &SketchConfig::new(4, 3, 2), &mut Pcg64::new(42));
        let b = srevd(&x, &SketchConfig::new(4, 3, 2), &mut Pcg64::new(42));
        assert_eq!(a.lambda, b.lambda);
        assert!(a.u.rel_err(&b.u) < 1e-15);
    }
}
