//! Online (incremental) decomposition updates — the "Brand New K-FACs"
//! route (arXiv 2210.08494, same author as the source paper).
//!
//! The EA recurrence `X ← ρX + (1−ρ)/n · U Uᵀ` is an additive rank-n
//! perturbation of a matrix whose eigenbasis we *already hold* from the
//! last refresh. Instead of re-sketching the d×d factor from scratch every
//! `T_KI` rounds, [`rank_update`] rotates the installed basis through the
//! increment directly:
//!
//! 1. Split the increment columns `C` into in-basis and residual parts:
//!    `W = VᵀC`, `Resid = C − VW`, thin-QR the residual into `Q·S`.
//! 2. Assemble the small `(r+n)×(r+n)` core
//!    `K = [[ρ·diag(D) + WWᵀ, WSᵀ], [SWᵀ, SSᵀ]]` — exactly the compression
//!    of `ρ·VDVᵀ + CCᵀ` onto `span([V|Q])`.
//! 3. EVD the core, truncate to the configured rank, and rotate:
//!    `U_new = [V|Q] · E_u`.
//!
//! Within `span([V|Q])` this is *exact*: the only approximation error is
//! the final truncation plus whatever error the previous factor already
//! carried. Cost is `O(d(r+n)²)` instead of the `O(d²s)`-and-up sketch
//! cost — the refresh amortizes away by roughly `T_KI×`.
//!
//! Determinism contract: [`rank_update`] is a pure function of
//! `(prev, delta, cfg)` — it draws no randomness at all — so online runs
//! are bit-reproducible regardless of scheduling, and the
//! [`crate::rnla::Decomposition::update`] hook can be evaluated locally or
//! remotely with identical results.

use crate::linalg::{evd, gemm, qr, Matrix};
use crate::obs;
use crate::rnla::lowrank::LowRankFactor;
use crate::rnla::sketch::SketchConfig;

/// A rank-n additive increment to an EA-averaged factor: the factor the
/// delta was captured against evolves as `X_new = rho·X_prev + cols·colsᵀ`.
#[derive(Clone)]
pub struct FactorDelta {
    /// d × n pre-scaled update columns `C` (for one EA gram update this is
    /// `√((1−ρ)/n) · U`, see [`FactorDelta::from_capture`]).
    pub cols: Matrix,
    /// Total decay applied to the previous factor across the captured
    /// updates: `X_new = rho·X_prev + cols·colsᵀ`.
    pub rho: f64,
}

impl FactorDelta {
    pub fn new(cols: Matrix, rho: f64) -> Self {
        assert!(rho.is_finite() && rho > 0.0 && rho <= 1.0, "FactorDelta: bad rho {rho}");
        FactorDelta { cols, rho }
    }

    /// Capture one EA gram update `X ← ρX + (1−ρ)/denom · U Uᵀ` as a delta:
    /// the additive term is `C·Cᵀ` with `C = √((1−ρ)/denom) · U`.
    pub fn from_capture(u: &Matrix, rho: f64, denom: f64) -> Self {
        assert!(denom > 0.0, "FactorDelta::from_capture: denom must be > 0");
        let scale = ((1.0 - rho) / denom).sqrt();
        Self::new(u * scale, rho)
    }

    /// Fold a newer capture into this one. Applying `self` then `next` to a
    /// factor is `next.rho·(self.rho·X + C₀C₀ᵀ) + C₁C₁ᵀ`, i.e. a single
    /// delta with `rho = self.rho·next.rho` and
    /// `cols = [√next.rho·C₀ | C₁]`.
    pub fn compose(&mut self, next: &FactorDelta) {
        let scaled = &self.cols * next.rho.sqrt();
        self.cols = scaled.hcat(&next.cols);
        self.rho *= next.rho;
    }

    /// Factor dimension d.
    pub fn dim(&self) -> usize {
        self.cols.rows()
    }

    /// Number of update columns n accumulated so far.
    pub fn n_cols(&self) -> usize {
        self.cols.cols()
    }
}

/// What a strategy's [`crate::rnla::Decomposition::update`] hook did.
pub enum UpdateOutcome {
    /// The installed basis was rotated through the delta.
    Updated(LowRankFactor),
    /// The strategy has no incremental path (or the previous factor cannot
    /// seed one) — the caller must fall back to a full decomposition.
    Declined,
}

/// Coarse flop estimate for one [`rank_update`] of a `dim`-dimensional
/// rank-`rank` factor by `n_cols` update columns: the two thin gemms, the
/// residual QR, the small core EVD, and the basis rotation.
pub fn update_flops(dim: usize, rank: usize, n_cols: usize) -> f64 {
    let (d, r, n) = (dim as f64, rank as f64, n_cols as f64);
    4.0 * d * r * n + 4.0 * d * n * n + 9.0 * (r + n).powi(3) + 2.0 * d * (r + n) * (r + n)
}

/// Rotate `prev = V D Vᵀ` through the increment
/// `X_new = delta.rho · VDVᵀ + C·Cᵀ`, truncating the result to `cfg.rank`.
///
/// Exact on `span([V | Q])` (see module docs); deterministic — no RNG.
/// Requires `prev.rank() > 0`: an empty basis has nothing to rotate, and
/// callers (the `Decomposition::update` impls) decline in that case.
pub fn rank_update(prev: &LowRankFactor, delta: &FactorDelta, cfg: &SketchConfig) -> LowRankFactor {
    let d = prev.dim();
    let r = prev.rank();
    assert!(r > 0, "rank_update: previous factor must have a non-empty basis");
    assert_eq!(delta.dim(), d, "rank_update: delta dim mismatch");
    let n = delta.n_cols();
    let _sp = obs::span("rnla.update")
        .arg("dim", d)
        .arg("prev_rank", r)
        .arg("delta_cols", n)
        .arg("rank", cfg.rank)
        .arg("flops_pred", update_flops(d, r, n))
        .with_backend();

    let c = &delta.cols;
    // In-basis component W = VᵀC and residual Resid = C − V·W.
    let w = gemm::matmul_tn(&prev.u, c); // r × n
    let mut resid = c.clone();
    resid.axpy(-1.0, &gemm::matmul(&prev.u, &w));
    // Thin-QR the residual: Resid = Q·S with Q orthonormal to V's columns
    // up to roundoff (S is the triangular factor, recomputed as QᵀResid so
    // near-zero residual columns contribute nothing instead of noise).
    let q_basis = qr::orthonormalize(&resid); // d × n
    let s = gemm::matmul_tn(&q_basis, &resid); // n × n

    // Core K = compression of ρ·VDVᵀ + CCᵀ onto span([V|Q]).
    let m = r + n;
    let mut k = Matrix::zeros(m, m);
    let mut tl = gemm::matmul_nt(&w, &w); // WWᵀ : r × r
    for i in 0..r {
        tl.row_mut(i)[i] += delta.rho * prev.d[i];
    }
    k.set_block(0, 0, &tl);
    let ws = gemm::matmul_nt(&w, &s); // r × n
    k.set_block(0, r, &ws);
    k.set_block(r, 0, &ws.transpose());
    k.set_block(r, r, &gemm::matmul_nt(&s, &s));
    k.symmetrize();

    let e = evd::sym_evd(&k).truncate(cfg.rank.min(m).min(d));
    let basis = prev.u.hcat(&q_basis); // d × (r+n)
    LowRankFactor::new(gemm::matmul(&basis, &e.u), e.lambda)
}

/// Per-(block, side) accumulator for deltas captured between refreshes.
/// Index layout matches the pipeline's slot layout: `2·block + side`.
pub struct DeltaBuffer {
    slots: Vec<Option<FactorDelta>>,
}

impl DeltaBuffer {
    pub fn new(n_blocks: usize) -> Self {
        DeltaBuffer { slots: (0..2 * n_blocks).map(|_| None).collect() }
    }

    fn idx(&self, block: usize, side: usize) -> usize {
        let i = 2 * block + side;
        assert!(side < 2 && i < self.slots.len(), "DeltaBuffer: bad (block, side)");
        i
    }

    /// Fold a freshly captured delta into the accumulator for this factor
    /// (composes with any delta already pending there).
    pub fn absorb(&mut self, block: usize, side: usize, delta: FactorDelta) {
        let i = self.idx(block, side);
        match &mut self.slots[i] {
            Some(acc) => acc.compose(&delta),
            none => *none = Some(delta),
        }
    }

    /// Remove and return the pending delta for this factor, if any.
    pub fn take(&mut self, block: usize, side: usize) -> Option<FactorDelta> {
        let i = self.idx(block, side);
        self.slots[i].take()
    }

    /// Pending delta for this factor without consuming it.
    pub fn peek(&self, block: usize, side: usize) -> Option<&FactorDelta> {
        self.slots[self.idx(block, side)].as_ref()
    }

    /// Drop every pending delta (after a full-correction round).
    pub fn clear(&mut self) {
        for s in &mut self.slots {
            *s = None;
        }
    }

    /// Number of (block, side) slots (2 × blocks).
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Pcg64;

    fn decayed_psd(rng: &mut Pcg64, d: usize, decay: f64) -> Matrix {
        let q = qr::orthonormalize(&rng.gaussian_matrix(d, d));
        let lam: Vec<f64> = (0..d).map(|i| decay.powi(i as i32)).collect();
        let mut qd = q.clone();
        gemm::scale_cols(&mut qd, &lam);
        gemm::matmul_nt(&qd, &q)
    }

    fn truncated_evd(x: &Matrix, r: usize) -> LowRankFactor {
        let e = evd::sym_evd(x).truncate(r);
        LowRankFactor::new(e.u, e.lambda)
    }

    /// The update is exact on span([V|Q]): starting from an exact rank-r
    /// basis, one rank_update must match the truncated EVD of the densely
    /// updated matrix to roundoff.
    #[test]
    fn update_matches_dense_truncated_evd() {
        let mut rng = Pcg64::new(11);
        let d = 24;
        let x0 = decayed_psd(&mut rng, d, 0.6);
        let rho = 0.9;
        let u = rng.gaussian_matrix(d, 4);
        let delta = FactorDelta::from_capture(&u, rho, u.cols() as f64);

        // Full-rank previous basis → zero prior error; the whole updated
        // matrix lives in span([V|Q]).
        let prev = truncated_evd(&x0, d);
        let cfg = SketchConfig::new(d, 0, 0);
        let got = rank_update(&prev, &delta, &cfg);

        let mut dense = x0.clone();
        gemm::ea_gram_update(&mut dense, rho, &u, u.cols() as f64);
        let expect = truncated_evd(&dense, d);
        let err = got.reconstruct().rel_err(&expect.reconstruct());
        assert!(err < 1e-10, "exact-span update drifted: {err}");

        // Truncated previous basis: error bounded by the discarded tail.
        let r = 8;
        let prev = truncated_evd(&x0, r);
        let cfg = SketchConfig::new(r, 0, 0);
        let got = rank_update(&prev, &delta, &cfg);
        assert_eq!(got.rank(), r);
        assert!(got.u.all_finite());
        let err = got.reconstruct().rel_err(&truncated_evd(&dense, r).reconstruct());
        assert!(err < 0.05, "truncated update error envelope blown: {err}");
    }

    /// Two sequential updates must equal the single composed update —
    /// this is what lets the optimizer hand the pipeline one delta per
    /// refresh even when T_KU < T_KI.
    #[test]
    fn compose_equals_sequential_application() {
        let mut rng = Pcg64::new(7);
        let d = 18;
        let x0 = decayed_psd(&mut rng, d, 0.7);
        let prev = truncated_evd(&x0, d);
        let cfg = SketchConfig::new(d, 0, 0);

        let u0 = rng.gaussian_matrix(d, 3);
        let u1 = rng.gaussian_matrix(d, 3);
        let d0 = FactorDelta::from_capture(&u0, 0.9, 3.0);
        let d1 = FactorDelta::from_capture(&u1, 0.8, 3.0);

        let step = rank_update(&rank_update(&prev, &d0, &cfg), &d1, &cfg);

        let mut composed = d0.clone();
        composed.compose(&d1);
        assert!((composed.rho - 0.9 * 0.8).abs() < 1e-15);
        assert_eq!(composed.n_cols(), 6);
        let once = rank_update(&prev, &composed, &cfg);

        let err = once.reconstruct().rel_err(&step.reconstruct());
        assert!(err < 1e-9, "composed vs sequential drifted: {err}");
    }

    /// from_capture's scaling must reproduce gemm::ea_gram_update exactly:
    /// ρX + CCᵀ with C = √((1−ρ)/n)·U.
    #[test]
    fn capture_scaling_matches_ea_gram_update() {
        let mut rng = Pcg64::new(5);
        let d = 10;
        let x0 = decayed_psd(&mut rng, d, 0.5);
        let u = rng.gaussian_matrix(d, 4);
        let rho = 0.95;

        let delta = FactorDelta::from_capture(&u, rho, u.cols() as f64);
        let mut via_delta = x0.clone();
        via_delta.scale_inplace(rho);
        via_delta.axpy(1.0, &gemm::syrk(&delta.cols));

        let mut expect = x0.clone();
        gemm::ea_gram_update(&mut expect, rho, &u, u.cols() as f64);
        assert!(via_delta.rel_err(&expect) < 1e-12);
    }

    #[test]
    fn delta_buffer_absorbs_and_takes() {
        let mut rng = Pcg64::new(3);
        let mut buf = DeltaBuffer::new(2);
        assert_eq!(buf.slot_count(), 4);
        assert!(buf.peek(1, 0).is_none());
        let u = rng.gaussian_matrix(6, 2);
        buf.absorb(1, 0, FactorDelta::from_capture(&u, 0.9, 2.0));
        buf.absorb(1, 0, FactorDelta::from_capture(&u, 0.8, 2.0));
        let got = buf.peek(1, 0).unwrap();
        assert_eq!(got.n_cols(), 4);
        assert!((got.rho - 0.72).abs() < 1e-15);
        let taken = buf.take(1, 0).unwrap();
        assert_eq!(taken.n_cols(), 4);
        assert!(buf.peek(1, 0).is_none());
        buf.absorb(0, 1, FactorDelta::from_capture(&u, 0.9, 2.0));
        buf.clear();
        assert!(buf.peek(0, 1).is_none());
    }
}
