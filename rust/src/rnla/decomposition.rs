//! The [`Decomposition`] trait: pluggable factor-decomposition strategies.
//!
//! The paper's core observation is that the K-FAC inversion strategy is
//! *swappable* — exact EVD (Alg. 1), RSVD (Alg. 2/4), SRE-EVD (Alg. 3/5),
//! Nyström (the "refining the algorithms" direction) — while everything
//! around it (EA factor maintenance, eq. (13) damped inverse application,
//! the T_KU/T_KI cadence) stays fixed. This module makes that axis an open
//! trait instead of a closed enum:
//!
//! * [`Decomposition`] — one strategy: `decompose` a symmetric PSD factor
//!   into a [`LowRankFactor`], plus cost/error metadata ([`DecompMeta`])
//!   and a controller-feedback hook ([`Decomposition::tune`]).
//! * [`Exact`], [`ExactTruncated`], [`Rsvd`], [`Srevd`], [`Nystrom`] — the
//!   built-in strategies, thin shims over the computational kernels in
//!   [`mod@crate::rnla::rsvd`], [`mod@crate::rnla::srevd`],
//!   [`mod@crate::rnla::nystrom`] and [`crate::linalg::evd`]; their outputs
//!   are bit-identical to what the old `Inversion` enum dispatch produced.
//! * [`DecompositionRegistry`] — string key → strategy, so new backends
//!   (third-party included) register without editing core files. The
//!   solver registry in [`crate::optim::registry`] resolves the
//!   `kfac+<key>` half of a solver spec here.
//!
//! Determinism contract: a strategy must be a pure function of
//! `(matrix, cfg, rng)` — no interior mutability, no global state — because
//! the async pipeline ([`crate::pipeline`]) relies on per-(round, block,
//! side) RNG streams to make background refreshes bitwise-reproducible.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::linalg::backend::{self, Selection};
use crate::linalg::{evd, Matrix, Pcg64};
use crate::rnla::factored::FactoredSolve;
use crate::rnla::lowrank::LowRankFactor;
use crate::rnla::nystrom::nystrom;
use crate::rnla::rsvd::rsvd;
use crate::rnla::sketch::SketchConfig;
use crate::rnla::srevd::srevd;
use crate::rnla::update::{rank_update, update_flops, FactorDelta, UpdateOutcome};

/// Cost/error metadata for one strategy at a given problem size — the
/// channel through which schedulers (e.g. the pipeline's rank controller or
/// a priority queue over blocks) can reason about strategies they did not
/// hard-code.
#[derive(Clone, Debug)]
pub struct DecompMeta {
    /// Strategy key (same string as [`Decomposition::key`]).
    pub key: String,
    /// Coarse flop estimate for one decomposition of a `dim × dim` factor
    /// under `cfg` (order-of-magnitude, for relative-cost scheduling only).
    pub flops: f64,
    /// Whether the result depends on the RNG stream.
    pub randomized: bool,
    /// How many sides of the reconstruction carry sketch-projection error:
    /// 0 = exact/truncation-only, 1 = RSVD-V / Nyström, 2 = SRE-EVD.
    pub projection_sides: u8,
    /// The linalg compute backend this decomposition would execute on
    /// (captured from the process-global selection at `meta()` time), so
    /// cost metadata says not just *how many* flops but *how* they run —
    /// the `flops` field is backend-independent; wall-clock predictions
    /// must divide by the backend's effective throughput.
    pub backend: Selection,
}

/// One factor-decomposition strategy (the paper's Algorithms 1/2/3 and
/// extensions). Implementations must be deterministic given `(m, cfg, rng)`
/// and are shared across pipeline worker threads, hence `Send + Sync`.
pub trait Decomposition: Send + Sync {
    /// Short stable key, e.g. `"rsvd"` — the `<strategy>` half of a
    /// `kfac+<strategy>` solver spec and the registry lookup key.
    fn key(&self) -> &str;

    /// Decompose a symmetric PSD `m` into `Ũ D̃ Ũᵀ` eigen-form at the rank
    /// requested by `cfg`, drawing any randomness from `rng` only.
    fn decompose(&self, m: &Matrix, cfg: &SketchConfig, rng: &mut Pcg64) -> LowRankFactor;

    /// Cost/error metadata at problem size `dim` under `cfg`.
    fn meta(&self, dim: usize, cfg: &SketchConfig) -> DecompMeta;

    /// Controller feedback: pick sketch parameters for a controller-chosen
    /// `rank` and error target. The default keeps the schedule's
    /// oversampling/power-iteration values and only swaps the rank —
    /// exactly the pre-feedback behaviour; randomized strategies override
    /// this with [`tuned_sketch`]. Only consulted when the pipeline's
    /// `adaptive_sketch` toggle is on.
    fn tune(&self, base: &SketchConfig, rank: usize, target_rel_err: f64) -> SketchConfig {
        let _ = target_rel_err;
        SketchConfig::new(rank, base.oversample, base.n_power_iter)
    }

    /// Whether this strategy can consume per-step gradient *columns* `U`
    /// directly (the Woodbury route), instead of the accumulated d×d gram.
    /// Strategies returning `true` here let the K-FAC engine skip forming
    /// `G = UUᵀ` entirely for designated wide blocks — the factored-solve
    /// subsystem in [`crate::rnla::factored`].
    fn factors_columns(&self) -> bool {
        false
    }

    /// Column-factored entry point: build a [`FactoredSolve`] for the
    /// factor `UUᵀ + γI` at damping `lambda`, drawing any randomness (e.g.
    /// a sketched-core row sample) from `rng` only — the same determinism
    /// contract as [`Decomposition::decompose`]. `col_sample` is the
    /// sketched-core row budget; exact-core strategies ignore it. The
    /// default declines, so dense-only strategies need no changes.
    fn factor_columns(
        &self,
        u: &Matrix,
        gamma: f64,
        lambda: f64,
        col_sample: usize,
        rng: &mut Pcg64,
    ) -> Result<FactoredSolve, String> {
        let _ = (u, gamma, lambda, col_sample, rng);
        Err(format!("decomposition '{}' has no column-factored (Woodbury) path", self.key()))
    }

    /// Whether this strategy can maintain an installed basis *online* — the
    /// [`Decomposition::update`] hook rotates the previous factor through a
    /// rank-n EA increment instead of recomputing from scratch. Strategies
    /// returning `false` here always decline.
    fn supports_update(&self) -> bool {
        false
    }

    /// Incremental entry point: rotate `prev = ŨD̃Ũᵀ` through
    /// `delta.rho·prev + delta.cols·delta.colsᵀ`, truncated to `cfg.rank`.
    /// The default declines, so existing strategies keep the
    /// recompute-from-scratch behaviour with no changes; implementations
    /// must also decline when `prev` cannot seed an update (empty basis).
    /// Like `decompose`, the result must be a pure function of the inputs
    /// (the built-in update kernel draws no randomness at all; `rng` is
    /// passed for strategies whose update path wants it).
    fn update(
        &self,
        prev: &LowRankFactor,
        delta: &FactorDelta,
        cfg: &SketchConfig,
        rng: &mut Pcg64,
    ) -> UpdateOutcome {
        let _ = (prev, delta, cfg, rng);
        UpdateOutcome::Declined
    }

    /// Cost metadata for one incremental update of a `dim × dim` factor by
    /// `delta_cols` columns — `None` when the strategy has no update path,
    /// so schedulers can price update-vs-recompute without hard-coding
    /// strategies. Must be `Some` exactly when [`Self::supports_update`]
    /// returns `true`.
    fn update_meta(&self, dim: usize, delta_cols: usize, cfg: &SketchConfig) -> Option<DecompMeta> {
        let _ = (dim, delta_cols, cfg);
        None
    }
}

/// Shared `update`/`update_meta` implementation for the strategies whose
/// output is an eigenbasis the online kernel can rotate (RSVD's V-side and
/// SRE-EVD both produce `Ũ D̃ Ũᵀ` with orthonormal `Ũ`).
fn eigenbasis_update(
    prev: &LowRankFactor,
    delta: &FactorDelta,
    cfg: &SketchConfig,
) -> UpdateOutcome {
    if prev.rank() == 0 {
        // Nothing to rotate (identity seed, pre-first-refresh) — the
        // caller's recompute path owns warm-up.
        return UpdateOutcome::Declined;
    }
    UpdateOutcome::Updated(rank_update(prev, delta, cfg))
}

fn eigenbasis_update_meta(key: &str, dim: usize, delta_cols: usize, cfg: &SketchConfig) -> DecompMeta {
    DecompMeta {
        key: key.into(),
        flops: update_flops(dim, cfg.rank, delta_cols),
        // The update kernel is deterministic and introduces truncation
        // error only — no sketch projection on either side.
        randomized: false,
        projection_sides: 0,
        backend: backend::current(),
    }
}

/// Controller-driven sketch parameters for the randomized strategies (the
/// `adaptive_sketch` toggle): oversampling scales with the target rank
/// (`r/10`, floored at the schedule value) so the tail-capture probability
/// stays uniform as the controller grows the rank (Halko et al. keep a
/// small additive constant only because their `r` is fixed), and the
/// power-iteration count is derived from the error target — the range
/// residual contracts like `(σ_{r+1}/σ_r)^{2q+1}`, so a loose ε needs fewer
/// iterations than the paper's fixed 4. The schedule's count is a hard cap:
/// a `n_power_iter = 0` ablation config stays at zero.
pub fn tuned_sketch(base: &SketchConfig, rank: usize, target_rel_err: f64) -> SketchConfig {
    let oversample = base.oversample.max((rank + 9) / 10);
    let wanted = (1.0 / target_rel_err.clamp(1e-6, 0.5)).log10().ceil() as usize;
    let n_power_iter = wanted.min(base.n_power_iter);
    SketchConfig::new(rank, oversample, n_power_iter)
}

/// Coarse flop count of the shared range-finder stage (sketch gemm, power
/// iterations with re-orthonormalization, final QR).
fn sketch_flops(d: usize, s: usize, n_pwr: usize) -> f64 {
    let (d, s, p) = (d as f64, s as f64, n_pwr as f64);
    2.0 * d * d * s + p * (4.0 * d * d * s + 4.0 * d * s * s) + 2.0 * d * s * s
}

/// Full symmetric EVD — vanilla K-FAC (O(d³)).
pub struct Exact;

impl Decomposition for Exact {
    fn key(&self) -> &str {
        "exact"
    }

    fn decompose(&self, m: &Matrix, _cfg: &SketchConfig, _rng: &mut Pcg64) -> LowRankFactor {
        let e = evd::sym_evd(m);
        LowRankFactor::new(e.u, e.lambda)
    }

    fn meta(&self, dim: usize, _cfg: &SketchConfig) -> DecompMeta {
        DecompMeta {
            key: "exact".into(),
            flops: 9.0 * (dim as f64).powi(3),
            randomized: false,
            projection_sides: 0,
            backend: backend::current(),
        }
    }
}

/// Exact EVD then truncation to rank r — isolates truncation error from
/// projection error (the E7 ablation baseline).
pub struct ExactTruncated;

impl Decomposition for ExactTruncated {
    fn key(&self) -> &str {
        "trunc"
    }

    fn decompose(&self, m: &Matrix, cfg: &SketchConfig, _rng: &mut Pcg64) -> LowRankFactor {
        let e = evd::sym_evd(m).truncate(cfg.rank.min(m.rows()));
        LowRankFactor::new(e.u, e.lambda)
    }

    fn meta(&self, dim: usize, _cfg: &SketchConfig) -> DecompMeta {
        DecompMeta {
            key: "trunc".into(),
            flops: 9.0 * (dim as f64).powi(3),
            randomized: false,
            projection_sides: 0,
            backend: backend::current(),
        }
    }
}

/// Randomized SVD with V-side symmetric reconstruction — RS-KFAC (Alg. 2;
/// §2.2.2: `Ṽ Σ̃ Ṽᵀ` is the more accurate side for symmetric PSD inputs).
pub struct Rsvd;

impl Decomposition for Rsvd {
    fn key(&self) -> &str {
        "rsvd"
    }

    fn decompose(&self, m: &Matrix, cfg: &SketchConfig, rng: &mut Pcg64) -> LowRankFactor {
        let out = rsvd(m, cfg, rng);
        LowRankFactor::new(out.v, out.sigma)
    }

    fn meta(&self, dim: usize, cfg: &SketchConfig) -> DecompMeta {
        let s = cfg.subspace(dim);
        DecompMeta {
            key: "rsvd".into(),
            // range finder + B = QᵀX + SVD of the thin s×d panel.
            flops: sketch_flops(dim, s, cfg.n_power_iter)
                + 2.0 * (dim * dim * s) as f64
                + 20.0 * (dim * s * s) as f64,
            randomized: true,
            projection_sides: 1,
            backend: backend::current(),
        }
    }

    fn tune(&self, base: &SketchConfig, rank: usize, target_rel_err: f64) -> SketchConfig {
        tuned_sketch(base, rank, target_rel_err)
    }

    fn supports_update(&self) -> bool {
        true
    }

    fn update(
        &self,
        prev: &LowRankFactor,
        delta: &FactorDelta,
        cfg: &SketchConfig,
        _rng: &mut Pcg64,
    ) -> UpdateOutcome {
        eigenbasis_update(prev, delta, cfg)
    }

    fn update_meta(&self, dim: usize, delta_cols: usize, cfg: &SketchConfig) -> Option<DecompMeta> {
        Some(eigenbasis_update_meta("rsvd", dim, delta_cols, cfg))
    }
}

/// Symmetric randomized EVD — SRE-KFAC (Alg. 3; both sides projected, so a
/// smaller constant than RSVD at slightly higher error).
pub struct Srevd;

impl Decomposition for Srevd {
    fn key(&self) -> &str {
        "srevd"
    }

    fn decompose(&self, m: &Matrix, cfg: &SketchConfig, rng: &mut Pcg64) -> LowRankFactor {
        let out = srevd(m, cfg, rng);
        LowRankFactor::new(out.u, out.lambda)
    }

    fn meta(&self, dim: usize, cfg: &SketchConfig) -> DecompMeta {
        let s = cfg.subspace(dim);
        DecompMeta {
            key: "srevd".into(),
            // range finder + XQ + the tiny s×s EVD.
            flops: sketch_flops(dim, s, cfg.n_power_iter)
                + 4.0 * (dim * dim * s) as f64
                + 9.0 * (s as f64).powi(3),
            randomized: true,
            projection_sides: 2,
            backend: backend::current(),
        }
    }

    fn tune(&self, base: &SketchConfig, rank: usize, target_rel_err: f64) -> SketchConfig {
        tuned_sketch(base, rank, target_rel_err)
    }

    fn supports_update(&self) -> bool {
        true
    }

    fn update(
        &self,
        prev: &LowRankFactor,
        delta: &FactorDelta,
        cfg: &SketchConfig,
        _rng: &mut Pcg64,
    ) -> UpdateOutcome {
        eigenbasis_update(prev, delta, cfg)
    }

    fn update_meta(&self, dim: usize, delta_cols: usize, cfg: &SketchConfig) -> Option<DecompMeta> {
        Some(eigenbasis_update_meta("srevd", dim, delta_cols, cfg))
    }
}

/// Nyström PSD approximation — NYS-KFAC (same sketch cost class as SRE-EVD,
/// strictly tighter for PSD inputs; Gittens & Mahoney 2016).
pub struct Nystrom;

impl Decomposition for Nystrom {
    fn key(&self) -> &str {
        "nystrom"
    }

    fn decompose(&self, m: &Matrix, cfg: &SketchConfig, rng: &mut Pcg64) -> LowRankFactor {
        let out = nystrom(m, cfg, rng);
        LowRankFactor::new(out.u, out.lambda)
    }

    fn meta(&self, dim: usize, cfg: &SketchConfig) -> DecompMeta {
        let s = cfg.subspace(dim);
        DecompMeta {
            key: "nystrom".into(),
            // range finder + XQ + core EVD + thin QR of the n×s panel.
            flops: sketch_flops(dim, s, cfg.n_power_iter)
                + 4.0 * (dim * dim * s) as f64
                + 9.0 * (s as f64).powi(3)
                + 4.0 * (dim * s * s) as f64,
            randomized: true,
            projection_sides: 1,
            backend: backend::current(),
        }
    }

    fn tune(&self, base: &SketchConfig, rank: usize, target_rel_err: f64) -> SketchConfig {
        tuned_sketch(base, rank, target_rel_err)
    }
}

/// String key → strategy. New decompositions — including third-party ones —
/// register here and immediately become buildable as `kfac+<key>` /
/// `ekfac+<key>` through the solver registry, with no edits to `optim/*`.
#[derive(Clone)]
pub struct DecompositionRegistry {
    map: BTreeMap<String, Arc<dyn Decomposition>>,
}

impl DecompositionRegistry {
    /// Registry with no strategies (building blocks for tests / embedders).
    pub fn empty() -> Self {
        DecompositionRegistry { map: BTreeMap::new() }
    }

    /// The built-in strategies under their canonical keys: the five dense
    /// decompositions plus the two column-factored (Woodbury-route)
    /// strategies from [`crate::rnla::factored`].
    pub fn with_defaults() -> Self {
        let mut r = Self::empty();
        r.register(Arc::new(Exact));
        r.register(Arc::new(ExactTruncated));
        r.register(Arc::new(Rsvd));
        r.register(Arc::new(Srevd));
        r.register(Arc::new(Nystrom));
        r.register(Arc::new(crate::rnla::factored::Woodbury));
        r.register(Arc::new(crate::rnla::factored::SketchedCore));
        r
    }

    /// Register under the strategy's own [`Decomposition::key`]. Returns
    /// the strategy previously registered under that key, if any.
    pub fn register(&mut self, d: Arc<dyn Decomposition>) -> Option<Arc<dyn Decomposition>> {
        self.map.insert(d.key().to_string(), d)
    }

    pub fn get(&self, key: &str) -> Option<Arc<dyn Decomposition>> {
        self.map.get(key).cloned()
    }

    /// Registered keys, sorted.
    pub fn keys(&self) -> Vec<&str> {
        self.map.keys().map(String::as_str).collect()
    }
}

impl Default for DecompositionRegistry {
    fn default() -> Self {
        Self::with_defaults()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gemm, qr};

    fn decayed_psd(rng: &mut Pcg64, d: usize, decay: f64) -> Matrix {
        let q = qr::orthonormalize(&rng.gaussian_matrix(d, d));
        let lam: Vec<f64> = (0..d).map(|i| decay.powi(i as i32)).collect();
        let mut qd = q.clone();
        gemm::scale_cols(&mut qd, &lam);
        gemm::matmul_nt(&qd, &q)
    }

    /// Each trait impl must reproduce its legacy kernel composition bitwise
    /// (this is what keeps the registry path golden-equivalent to the old
    /// enum dispatch).
    #[test]
    fn impls_bitwise_match_kernels() {
        let x = decayed_psd(&mut Pcg64::new(3), 20, 0.7);
        let cfg = SketchConfig::new(6, 4, 2);

        let via_trait = Rsvd.decompose(&x, &cfg, &mut Pcg64::new(9));
        let raw = rsvd(&x, &cfg, &mut Pcg64::new(9));
        assert_eq!(via_trait.u.as_slice(), raw.v.as_slice());
        assert_eq!(via_trait.d, raw.sigma);

        let via_trait = Srevd.decompose(&x, &cfg, &mut Pcg64::new(9));
        let raw = srevd(&x, &cfg, &mut Pcg64::new(9));
        assert_eq!(via_trait.u.as_slice(), raw.u.as_slice());
        assert_eq!(via_trait.d, raw.lambda);

        let via_trait = Nystrom.decompose(&x, &cfg, &mut Pcg64::new(9));
        let raw = nystrom(&x, &cfg, &mut Pcg64::new(9));
        assert_eq!(via_trait.u.as_slice(), raw.u.as_slice());
        assert_eq!(via_trait.d, raw.lambda);

        let e = Exact.decompose(&x, &cfg, &mut Pcg64::new(9));
        assert_eq!(e.rank(), 20);
        let t = ExactTruncated.decompose(&x, &cfg, &mut Pcg64::new(9));
        assert_eq!(t.rank(), 6);
        assert_eq!(&e.d[..6], &t.d[..]);
    }

    #[test]
    fn registry_defaults_and_override() {
        let reg = DecompositionRegistry::with_defaults();
        assert_eq!(
            reg.keys(),
            vec!["exact", "nystrom", "rsvd", "sketchcore", "srevd", "trunc", "woodbury"]
        );
        assert!(reg.get("rsvd").is_some());
        assert!(reg.get("adam").is_none());
        // Re-registering a key replaces (and returns) the old strategy.
        let mut reg = reg;
        let displaced = reg.register(Arc::new(Rsvd));
        assert_eq!(displaced.unwrap().key(), "rsvd");
    }

    #[test]
    fn meta_reports_cost_ordering() {
        let cfg = SketchConfig::new(32, 10, 4);
        let d = 512;
        let exact = Exact.meta(d, &cfg);
        let rs = Rsvd.meta(d, &cfg);
        let sre = Srevd.meta(d, &cfg);
        assert!(!exact.randomized && rs.randomized);
        assert_eq!(exact.projection_sides, 0);
        assert_eq!(rs.projection_sides, 1);
        assert_eq!(sre.projection_sides, 2);
        // The whole point of the paper: sketched decompositions are far
        // cheaper than the full EVD at r ≪ d.
        assert!(rs.flops < exact.flops);
        assert!(sre.flops < exact.flops);
    }

    /// Cost metadata must say which compute backend it was captured under.
    #[test]
    fn meta_surfaces_installed_backend() {
        use crate::linalg::backend::{scoped, BackendKind, Precision};
        let cfg = SketchConfig::new(8, 4, 2);
        let _g = scoped(BackendKind::Threaded, 2, Precision::F64);
        let m = Rsvd.meta(64, &cfg);
        assert_eq!(m.backend.kind, BackendKind::Threaded);
        assert_eq!(m.backend.threads, 2);
        assert_eq!(m.backend.precision, Precision::F64);
    }

    /// Update support is an opt-in axis: the eigenbasis strategies rotate,
    /// everything else declines (and prices accordingly), and an empty
    /// previous basis always declines.
    #[test]
    fn update_hooks_decline_by_default_and_rotate_for_eigenbasis_strategies() {
        let x = decayed_psd(&mut Pcg64::new(4), 16, 0.6);
        let cfg = SketchConfig::new(6, 4, 1);
        let prev = Rsvd.decompose(&x, &cfg, &mut Pcg64::new(9));
        let u = Pcg64::new(13).gaussian_matrix(16, 3);
        let delta = FactorDelta::from_capture(&u, 0.9, 3.0);
        let mut rng = Pcg64::new(1);

        assert!(Rsvd.supports_update() && Srevd.supports_update());
        assert!(!Exact.supports_update() && !ExactTruncated.supports_update());
        assert!(!Nystrom.supports_update());

        match Rsvd.update(&prev, &delta, &cfg, &mut rng) {
            UpdateOutcome::Updated(f) => {
                assert_eq!((f.dim(), f.rank()), (16, 6));
                assert!(f.u.all_finite());
            }
            UpdateOutcome::Declined => panic!("rsvd must update a non-empty basis"),
        }
        assert!(matches!(Exact.update(&prev, &delta, &cfg, &mut rng), UpdateOutcome::Declined));
        let empty = LowRankFactor::identity_seed(16);
        assert!(matches!(Srevd.update(&empty, &delta, &cfg, &mut rng), UpdateOutcome::Declined));

        // Pricing: supported strategies expose update cost metadata, and an
        // update is far cheaper than the sketch it replaces at r ≪ d.
        assert!(Exact.update_meta(512, 32, &cfg).is_none());
        let um = Rsvd.update_meta(512, 32, &SketchConfig::new(32, 10, 4)).unwrap();
        assert!(!um.randomized && um.projection_sides == 0);
        assert!(um.flops < Rsvd.meta(512, &SketchConfig::new(32, 10, 4)).flops);
    }

    #[test]
    fn tune_scales_oversample_and_power_iters() {
        let base = SketchConfig::new(220, 10, 4);
        // Big controller rank → oversampling grows past the schedule's 10.
        let t = tuned_sketch(&base, 220, 0.03);
        assert_eq!(t.rank, 220);
        assert_eq!(t.oversample, 22);
        // ε = 0.03 → ceil(log10(33.3)) = 2 power iters (< the paper's 4).
        assert_eq!(t.n_power_iter, 2);
        // Tight ε is capped at the schedule's power-iteration budget.
        assert_eq!(tuned_sketch(&base, 32, 1e-6).n_power_iter, 4);
        // A zero-power-iteration ablation schedule stays at zero.
        assert_eq!(tuned_sketch(&SketchConfig::new(8, 4, 0), 8, 0.03).n_power_iter, 0);
        // Small ranks keep the schedule's oversampling floor.
        assert_eq!(tuned_sketch(&base, 16, 0.03).oversample, 10);
        // Default (non-randomized) tune keeps base params, swaps rank only.
        let d = Exact.tune(&base, 64, 0.03);
        assert_eq!((d.rank, d.oversample, d.n_power_iter), (64, 10, 4));
    }
}
