//! `rkfac` — leader entrypoint / CLI.
//!
//! Subcommands:
//!   train     --config <toml> [--solver S] [--epochs N] [--seed K] [--out DIR]
//!   compare   --config <toml> --solvers a,b,c [--runs R]     (Table-1 style)
//!   spectrum  --config <toml> [--steps N] [--csv CSV]        (Fig-1 probe)
//!   artifacts                                                 (list manifest)
//!   info                                                      (build info)

use anyhow::{bail, Result};

use rkfac::coordinator::{config::TrainConfig, metrics, spectrum, trainer};
use rkfac::util::cli::Args;

fn load_config(args: &Args) -> Result<TrainConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => TrainConfig::from_file(path)?,
        None => TrainConfig::default(),
    };
    if let Some(s) = args.get("solver") {
        cfg.solver = s.to_string();
    }
    if let Some(e) = args.get("epochs") {
        cfg.epochs = e.parse()?;
    }
    if let Some(s) = args.get("seed") {
        cfg.seed = s.parse()?;
    }
    if let Some(b) = args.get("batch") {
        cfg.batch = b.parse()?;
    }
    if let Some(o) = args.get("out") {
        cfg.out_dir = o.to_string();
    }
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    eprintln!(
        "[rkfac] training: solver={} epochs={} batch={} seed={}",
        cfg.solver, cfg.epochs, cfg.batch, cfg.seed
    );
    let result = trainer::run(&cfg)?;
    for r in &result.records {
        println!(
            "epoch {:>3}  wall {:>8.2}s  train_loss {:.4}  test_loss {:.4}  test_acc {:.4}  decomp {:>7.2}s",
            r.epoch, r.wall_s, r.train_loss, r.test_loss, r.test_acc, r.decomp_s
        );
    }
    for &t in &cfg.targets {
        match result.time_to_acc(t) {
            Some(s) => println!("time to {:.1}%: {s:.2}s", t * 100.0),
            None => println!("time to {:.1}%: not reached", t * 100.0),
        }
    }
    let csv = format!("{}/run_{}_{}.csv", cfg.out_dir, result.solver, result.seed);
    result.write_csv(&csv)?;
    eprintln!("[rkfac] per-epoch series -> {csv}");
    if !result.rank_trace.is_empty() {
        let rank_csv = format!("{}/ranks_{}_{}.csv", cfg.out_dir, result.solver, result.seed);
        result.write_rank_csv(&rank_csv)?;
        eprintln!("[rkfac] per-block rank trace -> {rank_csv}");
    }
    if !result.pipe_trace.is_empty() {
        let pipe_csv = format!("{}/pipeline_{}_{}.csv", cfg.out_dir, result.solver, result.seed);
        result.write_pipeline_csv(&pipe_csv)?;
        eprintln!("[rkfac] per-round pipeline telemetry -> {pipe_csv}");
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let base = load_config(args)?;
    let solvers: Vec<String> = args
        .get_or("solvers", "seng,kfac,rs-kfac,sre-kfac")
        .split(',')
        .map(str::to_string)
        .collect();
    let runs = args.get_usize("runs", 3);
    let mut all_summaries = Vec::new();
    for solver in &solvers {
        let mut results = Vec::new();
        for r in 0..runs {
            let mut cfg = base.clone();
            cfg.solver = solver.clone();
            cfg.seed = base.seed + r as u64;
            eprintln!("[rkfac] {solver} run {}/{runs}", r + 1);
            let res = trainer::run(&cfg)?;
            res.write_csv(format!("{}/cmp_{}_{}.csv", cfg.out_dir, solver, cfg.seed))?;
            results.push(res);
        }
        all_summaries.push(metrics::summarize(&results, &base.targets));
    }
    // Table-1 style printout.
    print!("{:<10} ", "solver");
    for &t in &base.targets {
        print!("t_acc>={:<6.2} ", t);
    }
    println!("{:<14} {:<8} epochs_to_last", "t_epoch", "hits");
    for s in &all_summaries {
        print!("{:<10} ", s.solver);
        for (_, m, sd, _) in &s.time_to {
            if m.is_nan() {
                print!("{:<13} ", "—");
            } else {
                print!("{m:>6.1}±{sd:<5.1} ");
            }
        }
        let hits = s.time_to.last().map(|t| t.3).unwrap_or(0);
        println!(
            "{:>6.2}±{:<5.2} {:>2}/{:<4} {:.1}±{:.1}",
            s.t_epoch_mean, s.t_epoch_std, hits, s.n_runs, s.epochs_to_last.1, s.epochs_to_last.2
        );
    }
    Ok(())
}

fn cmd_spectrum(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let probe = spectrum::SpectrumConfig {
        steps: args.get_usize("steps", 600),
        ..Default::default()
    };
    let out = args.get_or("csv", "results/fig1_spectrum.csv");
    let mut log = spectrum::spectrum_csv(out)?;
    let snaps = spectrum::run_probe(&cfg, &probe, Some(&mut log))?;
    println!("spectrum probe: {} snapshots -> {out}", snaps.len());
    for s in snaps.iter().rev().take(4) {
        println!(
            "step {:>5} block {} {}: λmax {:.3e}, 1.5-order decay within {:?} modes",
            s.step,
            s.block,
            s.factor,
            s.lambda.first().unwrap_or(&0.0),
            s.modes_to_15_orders()
        );
    }
    Ok(())
}

fn cmd_artifacts() -> Result<()> {
    let engine = rkfac::runtime::Engine::new("artifacts")?;
    println!("platform: {}", engine.platform());
    for name in engine.registry().names() {
        let spec = engine.registry().get(name)?;
        println!(
            "  {:<28} {:>2} in / {:>2} out   {}",
            spec.name,
            spec.inputs.len(),
            spec.outputs.len(),
            spec.kind.as_deref().unwrap_or("-")
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("compare") => cmd_compare(&args),
        Some("spectrum") => cmd_spectrum(&args),
        Some("artifacts") => cmd_artifacts(),
        Some("info") | None => {
            println!("rkfac — Randomized K-FACs (Puiu, 2022) reproduction");
            println!("subcommands: train, compare, spectrum, artifacts, info");
            println!("see README.md and configs/*.toml");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand '{other}' (try: train, compare, spectrum, artifacts)"),
    }
}
