//! `rkfac` — leader entrypoint / CLI over the Experiment/Session API.
//!
//! Subcommands:
//!   train     --config <toml> [--solver S] [--epochs N] [--seed K] [--out DIR]
//!             [--set key=value]... [--early-stop] [--checkpoint-every N]
//!             [--spectrum-csv PATH] [--resume CKPT] [--obs]
//!   compare   --config <toml> --solvers a,b,c [--runs R] [--jobs J]
//!             [--remote BOARD] [--set key=value]...       (Table-1 style sweep)
//!   serve-factors  [--bind HOST:PORT | --dir MAILBOX] [--workers N]
//!             [--config <toml>]              (host decompositions for trainers)
//!   worker    --config <toml> --board BOARD [--solvers a,b,c] [--runs R]
//!             [--max-cells N]                (claim & run sweep cells preemptibly)
//!   spectrum  --config <toml> [--steps N] [--csv CSV]     (Fig-1 probe)
//!   report    <run_dir>                                   (obs cost-model report)
//!   artifacts                                             (list manifest)
//!   info                                                  (build info)
//!
//! Config precedence: TOML file < builder defaults < `--set key=value`
//! (and the legacy convenience flags --solver/--epochs/--seed/--batch/--out
//! are sugar for the corresponding `--set`). A bad value errors with the
//! layer that set it.

use anyhow::{bail, Result};

use rkfac::coordinator::experiment::{ExperimentBuilder, ExperimentSpec};
use rkfac::coordinator::hooks::{
    CheckpointHook, CsvMetricsHook, EarlyStopHook, RunCtx, RunHook, SpectrumHook,
};
use rkfac::coordinator::{metrics, spectrum, sweep::Sweep};
use rkfac::pipeline::transport::FactorServer;
use rkfac::rnla::DecompositionRegistry;
use rkfac::util::cli::Args;

/// Assemble the layered spec: TOML (if given), then every `--set`, with
/// the legacy convenience flags lowered onto their canonical keys.
fn build_spec(args: &Args) -> Result<ExperimentSpec> {
    let mut b = ExperimentBuilder::new();
    if let Some(path) = args.get("config") {
        b = b.toml_file(path)?;
    }
    b = b.cli_args(
        args,
        &[
            ("solver", "train.solver"),
            ("epochs", "train.epochs"),
            ("seed", "train.seed"),
            ("batch", "train.batch"),
            ("out", "train.out_dir"),
        ],
    )?;
    // `--obs` is sugar for `--set obs.enabled=true` (the other [obs] flags
    // keep their defaults: JSONL + Chrome trace + summary all on).
    if args.has("obs") {
        b = b.set("obs.enabled", "true");
    }
    b.build()
}

fn cmd_train(args: &Args) -> Result<()> {
    let spec = build_spec(args)?;
    let cfg = spec.cfg().clone();
    eprintln!(
        "[rkfac] training: solver={} epochs={} batch={} seed={}",
        cfg.solver, cfg.epochs, cfg.batch, cfg.seed
    );
    // The CSV hook runs by hand around the session (write *after* the
    // results print), but its fail-fast out_dir check still runs up
    // front — an unwritable directory must not cost a full training run.
    // A resumed segment only carries the post-checkpoint epochs, so it
    // writes under its own `resume_` prefix (traces off) instead of
    // clobbering the interrupted run's recorded series.
    let mut csv = CsvMetricsHook::new(cfg.out_dir.clone());
    if args.get("resume").is_some() {
        csv = csv.with_prefix("resume").traces(false);
    }
    csv.on_run_start(&RunCtx {
        cfg: &cfg,
        solver_name: &cfg.solver,
        start_rounds: 0,
        start_step: 0,
    })?;
    let mut session = spec.session();
    if args.has("early-stop") {
        match cfg.targets.last() {
            Some(&t) => {
                session.add_hook(Box::new(EarlyStopHook::new(t)));
                eprintln!("[rkfac] early stop armed at test_acc >= {t}");
            }
            None => eprintln!("[rkfac] --early-stop ignored: no [train] targets configured"),
        }
    }
    if let Some(every) = args.get("checkpoint-every") {
        session.add_hook(Box::new(CheckpointHook::new(cfg.out_dir.clone(), every.parse()?)));
    }
    if let Some(path) = args.get("spectrum-csv") {
        let every = args.get_usize("spectrum-every", 30);
        session.add_hook(Box::new(SpectrumHook::new(path, every, vec![])));
    }
    // `--resume <ckpt>` restores the full v2 checkpoint (params, solver EA
    // factors/counters, RNG streams) and re-enters the step loop at the
    // checkpointed epoch — bitwise-continuing the interrupted run. All
    // other flags (hooks, --set overrides) apply to the resumed segment.
    let mut result = match args.get("resume") {
        Some(ckpt) => {
            eprintln!("[rkfac] resuming from {ckpt}");
            session.resume(ckpt)?
        }
        None => session.run()?,
    };
    for r in &result.records {
        println!(
            "epoch {:>3}  wall {:>8.2}s  train_loss {:.4}  test_loss {:.4}  test_acc {:.4}  decomp {:>7.2}s",
            r.epoch, r.wall_s, r.train_loss, r.test_loss, r.test_acc, r.decomp_s
        );
    }
    for &t in &cfg.targets {
        match result.time_to_acc(t) {
            Some(s) => println!("time to {:.1}%: {s:.2}s", t * 100.0),
            None => println!("time to {:.1}%: not reached", t * 100.0),
        }
    }
    // CSVs are written *after* the results print, so a full disk cannot
    // swallow the training output (the hook stays the naming authority).
    csv.on_run_end(&mut result)?;
    for p in &csv.written {
        eprintln!("[rkfac] wrote {}", p.display());
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let spec = build_spec(args)?;
    let targets = spec.cfg().targets.clone();
    let solvers: Vec<String> = args
        .get_or("solvers", "seng,kfac,rs-kfac,sre-kfac")
        .split(',')
        .map(str::to_string)
        .collect();
    let runs = args.get_usize("runs", 3);
    let jobs = args.get_usize("jobs", 1);
    let sweep = Sweep::new(spec).solvers(solvers)?.runs_per_solver(runs).max_workers(jobs);
    // `--remote <board>` executes the same grid against a shared cell
    // board: completed cells are skipped, interrupted cells resume from
    // their checkpoints, and any `rkfac worker` on the board shares the
    // load. Without it, the grid runs in-process as before.
    let result = match args.get("remote") {
        Some(board) => {
            eprintln!("[rkfac] sweep: {} cells on board {board}", sweep.len());
            sweep.run_remote(board)?
        }
        None => {
            eprintln!("[rkfac] sweep: {} runs ({} workers)", sweep.len(), jobs);
            sweep.write_csvs(true).run()?
        }
    };
    print!("{}", metrics::render_table1(&result.summaries, &targets));
    for (solver, seed, err) in &result.failures {
        eprintln!("[rkfac] FAILED cell ({solver}, seed {seed}): {err}");
    }
    if !result.is_complete() {
        bail!(
            "{} of {} sweep cells failed (completed cells summarized above)",
            result.failures.len(),
            result.failures.len() + result.runs.len()
        );
    }
    Ok(())
}

/// `rkfac serve-factors`: host the decomposition service for remote
/// trainers (`[pipeline] transport = "tcp"` / `"dir"`). The strategy
/// registry is the spec's when `--config` is given (so registered
/// third-party decompositions are servable), the built-in five otherwise.
fn cmd_serve_factors(args: &Args) -> Result<()> {
    let decomps = match args.get("config") {
        Some(_) => {
            let spec = build_spec(args)?;
            // Remote factor workers compute decompositions on the
            // coordinator's behalf: install the spec's [linalg] selection
            // so served factors use the same kernels (and, in f64 mode,
            // the same bits) as a local run of this config.
            let l = &spec.cfg().linalg;
            rkfac::linalg::backend::install(l.backend, l.threads, l.precision);
            spec.registry().decompositions().clone()
        }
        None => DecompositionRegistry::with_defaults(),
    };
    let workers = args.get_usize("workers", 2);
    let _handle = match (args.get("bind"), args.get("dir")) {
        (Some(_), Some(_)) => bail!("pass --bind or --dir, not both"),
        (None, None) => bail!("serve-factors needs --bind <host:port> or --dir <mailbox>"),
        (Some(bind), None) => {
            let handle = FactorServer::spawn_tcp(bind, workers, decomps)?;
            let addr = handle.addr().map_or_else(|| bind.to_string(), |a| a.to_string());
            eprintln!("[rkfac] factor server listening on tcp {addr} ({workers} workers)");
            handle
        }
        (None, Some(dir)) => {
            let handle = FactorServer::spawn_dir(std::path::Path::new(dir), workers, decomps)?;
            eprintln!("[rkfac] factor server scanning mailbox {dir} ({workers} workers)");
            handle
        }
    };
    eprintln!("[rkfac] serving until killed (ctrl-c to stop)");
    loop {
        std::thread::park();
    }
}

/// `rkfac worker`: claim and run sweep cells from a shared board until none
/// are pending (or `--max-cells` is hit). Must be launched with the same
/// config and solver/run axes as the coordinating `compare --remote` so
/// both sides agree on the grid.
fn cmd_worker(args: &Args) -> Result<()> {
    let Some(board) = args.get("board") else {
        bail!("worker needs --board <dir> (the sweep cell board)");
    };
    let board = board.to_string();
    let spec = build_spec(args)?;
    let solvers: Vec<String> = args
        .get_or("solvers", "seng,kfac,rs-kfac,sre-kfac")
        .split(',')
        .map(str::to_string)
        .collect();
    let runs = args.get_usize("runs", 3);
    let max_cells = args.get_usize("max-cells", 0);
    let sweep = Sweep::new(spec).solvers(solvers)?.runs_per_solver(runs);
    eprintln!("[rkfac] worker on board {board}: grid has {} cells", sweep.len());
    let done = sweep.work_board(&board, max_cells)?;
    eprintln!("[rkfac] worker finished: {done} cells completed this run");
    Ok(())
}

fn cmd_spectrum(args: &Args) -> Result<()> {
    let spec = build_spec(args)?;
    let probe = spectrum::SpectrumConfig {
        steps: args.get_usize("steps", 600),
        ..Default::default()
    };
    let out = args.get_or("csv", "results/fig1_spectrum.csv");
    let mut log = spectrum::spectrum_csv(out)?;
    let snaps = spectrum::run_probe(spec.cfg(), &probe, Some(&mut log))?;
    println!("spectrum probe: {} snapshots -> {out}", snaps.len());
    for s in snaps.iter().rev().take(4) {
        println!(
            "step {:>5} block {} {}: λmax {:.3e}, 1.5-order decay within {:?} modes",
            s.step,
            s.block,
            s.factor,
            s.lambda.first().unwrap_or(&0.0),
            s.modes_to_15_orders()
        );
    }
    Ok(())
}

/// `rkfac report <run_dir>`: read the `obs_*.jsonl` streams a `--obs` run
/// wrote and print per-run step/refresh breakdowns plus the cost-model
/// validation table (scheduler-predicted FLOPs vs observed span durations
/// per (block, strategy, rank)).
fn cmd_report(args: &Args) -> Result<()> {
    let dir = match args.positional.first() {
        Some(d) => d.clone(),
        None => args.get_or("dir", "results").to_string(),
    };
    let text = rkfac::obs::report::run_report(std::path::Path::new(&dir))?;
    print!("{text}");
    Ok(())
}

fn cmd_artifacts() -> Result<()> {
    let engine = rkfac::runtime::Engine::new("artifacts")?;
    println!("platform: {}", engine.platform());
    for name in engine.registry().names() {
        let spec = engine.registry().get(name)?;
        println!(
            "  {:<28} {:>2} in / {:>2} out   {}",
            spec.name,
            spec.inputs.len(),
            spec.outputs.len(),
            spec.kind.as_deref().unwrap_or("-")
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("compare") => cmd_compare(&args),
        Some("serve-factors") => cmd_serve_factors(&args),
        Some("worker") => cmd_worker(&args),
        Some("spectrum") => cmd_spectrum(&args),
        Some("report") => cmd_report(&args),
        Some("artifacts") => cmd_artifacts(),
        Some("info") | None => {
            println!("rkfac — Randomized K-FACs (Puiu, 2022) reproduction");
            println!(
                "subcommands: train, compare, serve-factors, worker, spectrum, report, \
                 artifacts, info"
            );
            println!("config precedence: TOML < builder < --set key=value");
            println!("see README.md and the coordinator::experiment module docs");
            Ok(())
        }
        Some(other) => bail!(
            "unknown subcommand '{other}' (try: train, compare, serve-factors, worker, \
             spectrum, report, artifacts)"
        ),
    }
}
