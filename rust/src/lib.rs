//! # rkfac — Randomized K-FACs in Rust + JAX + Pallas
//!
//! Reproduction of *"Randomized K-FACs: Speeding up K-FAC with Randomized
//! Numerical Linear Algebra"* (C. O. Puiu, 2022). See DESIGN.md for the
//! architecture and EXPERIMENTS.md for the paper-vs-measured results.
//!
//! Layer map:
//! - [`linalg`] / [`rnla`]: the dense + randomized NLA substrate (Alg. 2/3,
//!   eq. 13, Prop. 3.1 machinery).
//! - [`pipeline`]: async factor-refresh service — background decompositions
//!   with bounded staleness and per-layer adaptive rank control.
//! - [`obs`]: process-wide tracing/metrics — hierarchical spans, a metrics
//!   registry, JSONL/Chrome-trace exporters, and the cost-model report.
//! - [`runtime`]: PJRT execution of the AOT-compiled JAX/Pallas artifacts.
//! - [`util`]: offline-built JSON/CLI/bench/property-test utilities.
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod nn;
pub mod obs;
pub mod optim;
pub mod pipeline;
pub mod rnla;
pub mod runtime;
pub mod util;
