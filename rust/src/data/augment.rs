//! Training-time augmentation: random crop (with padding) + horizontal flip
//! — the standard CIFAR-10 recipe used by the reference K-FAC/SENG setups.

use crate::linalg::{Matrix, Pcg64};

/// Augmentation configuration for (C, H, W) image batches.
#[derive(Clone, Debug)]
pub struct Augment {
    pub channels: usize,
    pub height: usize,
    pub width: usize,
    /// Zero-pad margin for random crops (CIFAR standard: 4).
    pub pad: usize,
    pub hflip: bool,
}

impl Augment {
    pub fn cifar(channels: usize, height: usize, width: usize) -> Self {
        Augment { channels, height, width, pad: 4, hflip: true }
    }

    /// Identity augmentation (eval path).
    pub fn none(channels: usize, height: usize, width: usize) -> Self {
        Augment { channels, height, width, pad: 0, hflip: false }
    }

    /// Apply in place to a (C·H·W, B) batch.
    pub fn apply(&self, x: &mut Matrix, rng: &mut Pcg64) {
        let (c, h, w) = (self.channels, self.height, self.width);
        assert_eq!(x.rows(), c * h * w, "Augment: dim mismatch");
        if self.pad == 0 && !self.hflip {
            return;
        }
        let b = x.cols();
        for bi in 0..b {
            let flip = self.hflip && rng.uniform() < 0.5;
            let (dy, dx) = if self.pad > 0 {
                (
                    rng.below(2 * self.pad + 1) as isize - self.pad as isize,
                    rng.below(2 * self.pad + 1) as isize - self.pad as isize,
                )
            } else {
                (0, 0)
            };
            if !flip && dy == 0 && dx == 0 {
                continue;
            }
            let col = x.col(bi);
            for ci in 0..c {
                for oy in 0..h {
                    for ox in 0..w {
                        let sx = if flip { w - 1 - ox } else { ox } as isize + dx;
                        let sy = oy as isize + dy;
                        let v = if sy >= 0 && sy < h as isize && sx >= 0 && sx < w as isize {
                            col[ci * h * w + sy as usize * w + sx as usize]
                        } else {
                            0.0
                        };
                        x[(ci * h * w + oy * w + ox, bi)] = v;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_identity() {
        let aug = Augment::none(1, 4, 4);
        let mut rng = Pcg64::new(1);
        let x0 = rng.gaussian_matrix(16, 3);
        let mut x = x0.clone();
        aug.apply(&mut x, &mut rng);
        assert!(x.rel_err(&x0) < 1e-15);
    }

    #[test]
    fn flip_reverses_rows() {
        let aug = Augment { channels: 1, height: 1, width: 4, pad: 0, hflip: true };
        // Find a seed that flips the single sample.
        let x0 = Matrix::from_vec(4, 1, vec![1.0, 2.0, 3.0, 4.0]);
        let mut flipped_seen = false;
        for seed in 0..20 {
            let mut rng = Pcg64::new(seed);
            let mut x = x0.clone();
            aug.apply(&mut x, &mut rng);
            if x.col(0) == vec![4.0, 3.0, 2.0, 1.0] {
                flipped_seen = true;
            } else {
                assert_eq!(x.col(0), vec![1.0, 2.0, 3.0, 4.0]);
            }
        }
        assert!(flipped_seen);
    }

    #[test]
    fn crop_preserves_values_or_zeros() {
        let aug = Augment { channels: 1, height: 4, width: 4, pad: 2, hflip: false };
        let mut rng = Pcg64::new(3);
        let x0 = Matrix::from_fn(16, 1, |i, _| (i + 1) as f64);
        let mut x = x0.clone();
        aug.apply(&mut x, &mut rng);
        // Every output pixel is either 0 (padding) or one of the inputs.
        for v in x.as_slice() {
            assert!(*v == 0.0 || (*v >= 1.0 && *v <= 16.0));
        }
    }
}
