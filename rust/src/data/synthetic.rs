//! Synthetic CIFAR-like dataset — the documented substitution for CIFAR-10
//! (DESIGN.md §Substitutions: no network access in the build sandbox).
//!
//! Generator design goals, so the optimizer dynamics exercised are the ones
//! the paper cares about:
//!  * 10 classes, 3×H×W images in [0,1] — same tensor shapes as CIFAR-10;
//!  * learnable but non-trivial class structure: each class is a random
//!    smooth template (low-frequency Fourier mixture) + per-sample smooth
//!    deformation + pixel noise, so test accuracy climbs over epochs
//!    instead of saturating after one;
//!  * class-conditional correlations across pixels → K-factor spectra with
//!    genuine decaying structure (not white noise).

use crate::data::dataset::Dataset;
use crate::linalg::{Matrix, Pcg64};

/// Configuration for the synthetic image generator.
#[derive(Clone, Debug)]
pub struct SyntheticConfig {
    pub classes: usize,
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    /// Number of Fourier modes per class template.
    pub modes: usize,
    /// Amplitude of the per-sample smooth deformation.
    pub deform: f64,
    /// Std of the per-pixel noise.
    pub noise: f64,
    /// Class-template amplitude (weak signal → slower accuracy climb,
    /// giving time-to-accuracy experiments resolution).
    pub signal: f64,
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        SyntheticConfig {
            classes: 10,
            height: 16,
            width: 16,
            channels: 3,
            modes: 6,
            deform: 2.6,
            noise: 1.3,
            signal: 0.42,
        }
    }
}

impl SyntheticConfig {
    /// Full CIFAR-10 geometry (32×32×3).
    pub fn cifar_shape() -> Self {
        SyntheticConfig { height: 32, width: 32, ..Default::default() }
    }

    pub fn dim(&self) -> usize {
        self.channels * self.height * self.width
    }
}

/// A smooth random field: sum of `modes` random low-frequency cosines.
struct SmoothField {
    amps: Vec<f64>,
    fx: Vec<f64>,
    fy: Vec<f64>,
    phase: Vec<f64>,
}

impl SmoothField {
    fn sample(modes: usize, rng: &mut Pcg64) -> Self {
        SmoothField {
            amps: (0..modes).map(|_| rng.gaussian()).collect(),
            fx: (0..modes).map(|_| rng.uniform_in(0.5, 3.0)).collect(),
            fy: (0..modes).map(|_| rng.uniform_in(0.5, 3.0)).collect(),
            phase: (0..modes).map(|_| rng.uniform_in(0.0, std::f64::consts::TAU)).collect(),
        }
    }

    fn at(&self, u: f64, v: f64) -> f64 {
        let mut s = 0.0;
        for m in 0..self.amps.len() {
            s += self.amps[m]
                * (std::f64::consts::TAU * (self.fx[m] * u + self.fy[m] * v) + self.phase[m]).cos();
        }
        s / (self.amps.len() as f64).sqrt()
    }
}

/// Generate `n` samples. Deterministic in `seed`; class templates depend
/// only on `seed` so train/test generated with different `n` share classes.
pub fn generate(cfg: &SyntheticConfig, n: usize, seed: u64) -> Dataset {
    let mut template_rng = Pcg64::with_stream(seed, 101);
    // One smooth template per (class, channel).
    let templates: Vec<Vec<SmoothField>> = (0..cfg.classes)
        .map(|_| (0..cfg.channels).map(|_| SmoothField::sample(cfg.modes, &mut template_rng)).collect())
        .collect();
    let mut rng = Pcg64::with_stream(seed, 202);
    let mut x = Matrix::zeros(cfg.dim(), n);
    let mut y = Vec::with_capacity(n);
    for s in 0..n {
        let class = s % cfg.classes; // balanced classes
        y.push(class);
        // Per-sample smooth deformation field + global shift/contrast.
        let deform = SmoothField::sample(cfg.modes.max(2), &mut rng);
        let contrast = 1.0 + 0.2 * rng.gaussian();
        let shift = 0.1 * rng.gaussian();
        for c in 0..cfg.channels {
            for iy in 0..cfg.height {
                for ix in 0..cfg.width {
                    let u = ix as f64 / cfg.width as f64;
                    let v = iy as f64 / cfg.height as f64;
                    let base = cfg.signal * templates[class][c].at(u, v);
                    let val = contrast * (base + cfg.deform * deform.at(u, v))
                        + shift
                        + cfg.noise * rng.gaussian();
                    // squash into [0,1] like pixel data
                    let px = 0.5 + 0.25 * val;
                    let row = c * cfg.height * cfg.width + iy * cfg.width + ix;
                    x[(row, s)] = px.clamp(0.0, 1.0);
                }
            }
        }
    }
    Dataset::new(x, y, cfg.classes)
}

/// Convenience: train+test pair with disjoint sample streams but identical
/// class templates (same seed → same classes).
pub fn generate_split(cfg: &SyntheticConfig, n_train: usize, n_test: usize, seed: u64) -> (Dataset, Dataset) {
    let all = generate(cfg, n_train + n_test, seed);
    all.split_tail(n_test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let cfg = SyntheticConfig::default();
        let ds = generate(&cfg, 50, 1);
        assert_eq!(ds.dim(), 3 * 16 * 16);
        assert_eq!(ds.len(), 50);
        assert!(ds.x.as_slice().iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn balanced_classes() {
        let cfg = SyntheticConfig::default();
        let ds = generate(&cfg, 100, 2);
        for class in 0..10 {
            let count = ds.y.iter().filter(|&&l| l == class).count();
            assert_eq!(count, 10);
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let cfg = SyntheticConfig::default();
        let a = generate(&cfg, 20, 7);
        let b = generate(&cfg, 20, 7);
        assert!(a.x.rel_err(&b.x) < 1e-15);
        let c = generate(&cfg, 20, 8);
        assert!(a.x.rel_err(&c.x) > 1e-3);
    }

    #[test]
    fn classes_are_linearly_separable_enough() {
        // A nearest-class-mean classifier on raw pixels should beat chance
        // clearly (the signal exists), but not be perfect (noise exists).
        let cfg = SyntheticConfig::default();
        let (train, test) = generate_split(&cfg, 400, 100, 3);
        let d = train.dim();
        let mut means = vec![vec![0.0; d]; 10];
        let mut counts = vec![0usize; 10];
        for s in 0..train.len() {
            counts[train.y[s]] += 1;
            for r in 0..d {
                means[train.y[s]][r] += train.x[(r, s)];
            }
        }
        for k in 0..10 {
            for v in &mut means[k] {
                *v /= counts[k] as f64;
            }
        }
        let mut correct = 0;
        for s in 0..test.len() {
            let mut best = (f64::INFINITY, 0usize);
            for k in 0..10 {
                let mut dist = 0.0;
                for r in 0..d {
                    let diff = test.x[(r, s)] - means[k][r];
                    dist += diff * diff;
                }
                if dist < best.0 {
                    best = (dist, k);
                }
            }
            if best.1 == test.y[s] {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.2, "NCM accuracy {acc} — classes too hard");
        assert!(acc < 1.0, "NCM accuracy {acc} — classes trivially separable");
    }

    #[test]
    fn pixel_correlations_nontrivial() {
        // Neighbouring pixels must correlate (smooth fields) — this is what
        // gives the K-factors their decaying spectrum.
        let cfg = SyntheticConfig::default();
        let ds = generate(&cfg, 200, 4);
        let r0: Vec<f64> = (0..200).map(|s| ds.x[(0, s)]).collect();
        let r1: Vec<f64> = (0..200).map(|s| ds.x[(1, s)]).collect();
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let (m0, m1) = (mean(&r0), mean(&r1));
        let mut cov = 0.0;
        let mut v0 = 0.0;
        let mut v1 = 0.0;
        for i in 0..200 {
            cov += (r0[i] - m0) * (r1[i] - m1);
            v0 += (r0[i] - m0) * (r0[i] - m0);
            v1 += (r1[i] - m1) * (r1[i] - m1);
        }
        let corr = cov / (v0 * v1).sqrt();
        assert!(corr > 0.3, "adjacent-pixel corr {corr} too low");
    }
}
