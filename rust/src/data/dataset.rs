//! Dataset abstraction: fixed-size image classification sets held in memory
//! as (features × samples) column batches.

use crate::linalg::{Matrix, Pcg64};

/// An in-memory labelled dataset (column-major samples).
pub struct Dataset {
    /// (d, N): one column per sample.
    pub x: Matrix,
    /// N class labels.
    pub y: Vec<usize>,
    pub classes: usize,
}

impl Dataset {
    pub fn new(x: Matrix, y: Vec<usize>, classes: usize) -> Self {
        assert_eq!(x.cols(), y.len(), "Dataset: sample count mismatch");
        assert!(y.iter().all(|&l| l < classes), "Dataset: label out of range");
        Dataset { x, y, classes }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.x.rows()
    }

    /// Materialize a batch from sample indices.
    pub fn gather(&self, idx: &[usize]) -> (Matrix, Vec<usize>) {
        let mut xb = Matrix::zeros(self.dim(), idx.len());
        let mut yb = Vec::with_capacity(idx.len());
        for (j, &i) in idx.iter().enumerate() {
            for r in 0..self.dim() {
                xb[(r, j)] = self.x[(r, i)];
            }
            yb.push(self.y[i]);
        }
        (xb, yb)
    }

    /// Split off the last `n` samples as a held-out set.
    pub fn split_tail(self, n: usize) -> (Dataset, Dataset) {
        assert!(n < self.len(), "split_tail: n too large");
        let ntrain = self.len() - n;
        let train_x = self.x.slice(0, self.dim(), 0, ntrain);
        let test_x = self.x.slice(0, self.dim(), ntrain, self.len());
        let train = Dataset::new(train_x, self.y[..ntrain].to_vec(), self.classes);
        let test = Dataset::new(test_x, self.y[ntrain..].to_vec(), self.classes);
        (train, test)
    }

    /// Normalize features to zero mean / unit std per row (in place),
    /// returning the (mean, std) so a test set can reuse train statistics.
    pub fn normalize(&mut self) -> (Vec<f64>, Vec<f64>) {
        let n = self.len() as f64;
        let d = self.dim();
        let mut mean = vec![0.0; d];
        let mut std = vec![0.0; d];
        for r in 0..d {
            let row = self.x.row(r);
            mean[r] = row.iter().sum::<f64>() / n;
            let var = row.iter().map(|&v| (v - mean[r]) * (v - mean[r])).sum::<f64>() / n;
            std[r] = var.sqrt().max(1e-8);
        }
        self.apply_normalization(&mean, &std);
        (mean, std)
    }

    /// Apply externally-computed normalization statistics.
    pub fn apply_normalization(&mut self, mean: &[f64], std: &[f64]) {
        for r in 0..self.dim() {
            let (m, s) = (mean[r], std[r]);
            for v in self.x.row_mut(r) {
                *v = (*v - m) / s;
            }
        }
    }
}

/// Epoch iterator producing shuffled fixed-size batches (last partial batch
/// dropped, as in the reference K-FAC training loops).
pub struct Batcher {
    order: Vec<usize>,
    batch: usize,
    pos: usize,
}

impl Batcher {
    pub fn new(n: usize, batch: usize, rng: &mut Pcg64) -> Self {
        assert!(batch > 0 && batch <= n, "Batcher: bad batch size {batch} for {n}");
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        Batcher { order, batch, pos: 0 }
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.order.len() / self.batch
    }
}

impl Iterator for Batcher {
    type Item = Vec<usize>;
    fn next(&mut self) -> Option<Vec<usize>> {
        if self.pos + self.batch > self.order.len() {
            return None;
        }
        let b = self.order[self.pos..self.pos + self.batch].to_vec();
        self.pos += self.batch;
        Some(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize) -> Dataset {
        let x = Matrix::from_fn(3, n, |r, c| (r * n + c) as f64);
        let y: Vec<usize> = (0..n).map(|i| i % 4).collect();
        Dataset::new(x, y, 4)
    }

    #[test]
    fn gather_selects_columns() {
        let ds = toy(6);
        let (xb, yb) = ds.gather(&[4, 1]);
        assert_eq!(xb.shape(), (3, 2));
        assert_eq!(xb[(0, 0)], 4.0);
        assert_eq!(xb[(0, 1)], 1.0);
        assert_eq!(yb, vec![0, 1]);
    }

    #[test]
    fn split_tail_partitions() {
        let (train, test) = toy(10).split_tail(3);
        assert_eq!(train.len(), 7);
        assert_eq!(test.len(), 3);
        assert_eq!(test.x[(0, 0)], 7.0);
    }

    #[test]
    fn normalize_zero_mean_unit_std() {
        let mut ds = toy(8);
        ds.normalize();
        for r in 0..3 {
            let row = ds.x.row(r);
            let mean: f64 = row.iter().sum::<f64>() / 8.0;
            let var: f64 = row.iter().map(|v| v * v).sum::<f64>() / 8.0 - mean * mean;
            assert!(mean.abs() < 1e-12);
            assert!((var - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn batcher_covers_each_sample_once() {
        let mut rng = Pcg64::new(1);
        let b = Batcher::new(10, 3, &mut rng);
        assert_eq!(b.batches_per_epoch(), 3);
        let mut seen = Vec::new();
        for batch in b {
            assert_eq!(batch.len(), 3);
            seen.extend(batch);
        }
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 9); // 10th dropped (partial batch)
    }

    #[test]
    fn batcher_shuffles_between_seeds() {
        let o1: Vec<_> = Batcher::new(30, 30, &mut Pcg64::new(1)).next().unwrap();
        let o2: Vec<_> = Batcher::new(30, 30, &mut Pcg64::new(2)).next().unwrap();
        assert_ne!(o1, o2);
    }
}
