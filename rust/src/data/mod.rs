//! Data pipeline: real CIFAR-10 (binary format) when present, synthetic
//! CIFAR-like data otherwise (DESIGN.md §Substitutions), plus batching and
//! the standard crop/flip augmentation.

pub mod augment;
pub mod cifar;
pub mod dataset;
pub mod synthetic;

pub use augment::Augment;
pub use dataset::{Batcher, Dataset};
pub use synthetic::{generate, generate_split, SyntheticConfig};
