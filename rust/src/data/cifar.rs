//! Real CIFAR-10 loader (binary version).
//!
//! Reads the standard `cifar-10-batches-bin` format: each record is
//! 1 label byte + 3072 pixel bytes (R plane, G plane, B plane, row-major
//! 32×32). If the files are present (the sandbox has no network, so the
//! user must supply them), experiments run on real CIFAR-10; otherwise the
//! synthetic generator (`data::synthetic`) is the documented stand-in.

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::dataset::Dataset;
use crate::linalg::Matrix;

const RECORD: usize = 1 + 3072;
pub const CIFAR_DIM: usize = 3072;
pub const CIFAR_CLASSES: usize = 10;

/// Parse one or more CIFAR-10 .bin files into a dataset.
pub fn load_bins(paths: &[impl AsRef<Path>]) -> Result<Dataset> {
    let mut xs: Vec<f64> = Vec::new();
    let mut ys: Vec<usize> = Vec::new();
    for p in paths {
        let p = p.as_ref();
        let mut buf = Vec::new();
        std::fs::File::open(p)
            .with_context(|| format!("opening {}", p.display()))?
            .read_to_end(&mut buf)?;
        if buf.len() % RECORD != 0 {
            bail!("{}: size {} is not a multiple of the 3073-byte record", p.display(), buf.len());
        }
        for rec in buf.chunks_exact(RECORD) {
            let label = rec[0] as usize;
            if label >= CIFAR_CLASSES {
                bail!("{}: label {} out of range", p.display(), label);
            }
            ys.push(label);
            xs.extend(rec[1..].iter().map(|&b| b as f64 / 255.0));
        }
    }
    if ys.is_empty() {
        bail!("no CIFAR records found");
    }
    // xs is sample-major; transpose into (3072, N) column-batch.
    let n = ys.len();
    let mut x = Matrix::zeros(CIFAR_DIM, n);
    for s in 0..n {
        for r in 0..CIFAR_DIM {
            x[(r, s)] = xs[s * CIFAR_DIM + r];
        }
    }
    Ok(Dataset::new(x, ys, CIFAR_CLASSES))
}

/// Standard layout: `<root>/data_batch_{1..5}.bin` + `<root>/test_batch.bin`.
/// Returns (train, test).
pub fn load_standard(root: impl AsRef<Path>) -> Result<(Dataset, Dataset)> {
    let root = root.as_ref();
    let train_paths: Vec<_> = (1..=5).map(|i| root.join(format!("data_batch_{i}.bin"))).collect();
    let train = load_bins(&train_paths)?;
    let test = load_bins(&[root.join("test_batch.bin")])?;
    Ok((train, test))
}

/// True if the standard CIFAR-10 binary layout exists under `root`.
pub fn is_available(root: impl AsRef<Path>) -> bool {
    let root = root.as_ref();
    (1..=5).all(|i| root.join(format!("data_batch_{i}.bin")).exists())
        && root.join("test_batch.bin").exists()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn fake_bin(dir: &Path, name: &str, records: usize, seed: u8) -> std::path::PathBuf {
        let p = dir.join(name);
        let mut f = std::fs::File::create(&p).unwrap();
        for r in 0..records {
            let label = ((r as u8).wrapping_add(seed)) % 10;
            f.write_all(&[label]).unwrap();
            let pixels: Vec<u8> = (0..3072u32).map(|i| ((i as usize + r) % 256) as u8).collect();
            f.write_all(&pixels).unwrap();
        }
        p
    }

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("rkfac_cifar_test_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn parses_record_format() {
        let d = tmpdir();
        let p = fake_bin(&d, "batch_a.bin", 7, 3);
        let ds = load_bins(&[p]).unwrap();
        assert_eq!(ds.len(), 7);
        assert_eq!(ds.dim(), 3072);
        assert_eq!(ds.y[0], 3);
        assert_eq!(ds.y[1], 4);
        // pixel 0 of record 0 is 0/255
        assert!((ds.x[(0, 0)] - 0.0).abs() < 1e-12);
        // pixel 5 of record 2 is (5+2)%256 / 255
        assert!((ds.x[(5, 2)] - 7.0 / 255.0).abs() < 1e-12);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn rejects_truncated_file() {
        let d = tmpdir();
        let p = d.join("bad.bin");
        std::fs::write(&p, vec![0u8; 100]).unwrap();
        assert!(load_bins(&[p]).is_err());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn is_available_detects_layout() {
        let d = tmpdir().join("cifar_layout");
        std::fs::create_dir_all(&d).unwrap();
        assert!(!is_available(&d));
        for i in 1..=5 {
            fake_bin(&d, &format!("data_batch_{i}.bin"), 2, 0);
        }
        fake_bin(&d, "test_batch.bin", 2, 0);
        assert!(is_available(&d));
        let (train, test) = load_standard(&d).unwrap();
        assert_eq!(train.len(), 10);
        assert_eq!(test.len(), 2);
        std::fs::remove_dir_all(&d).ok();
    }
}
