//! Named metrics registry: counters, gauges, and summary histograms.
//!
//! This is the single sink behind which the repo's one-off telemetry
//! plumbing (`PipelineDiagnostics` sampling, rank traces, queue depths)
//! is mirrored when obs is enabled. Metrics never feed back into
//! computation — they are write-only until a snapshot is taken at run end.
//!
//! All operations are no-ops behind the obs enable gate, so the
//! instrumented call sites cost one relaxed atomic load when disabled.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// One registered metric value.
#[derive(Clone, Debug, PartialEq)]
pub enum Metric {
    /// Monotone event count.
    Counter(u64),
    /// Last observed point-in-time value.
    Gauge(f64),
    /// Streaming summary of observed samples.
    Hist { count: u64, sum: f64, min: f64, max: f64 },
}

impl Metric {
    /// Exporter tag: `"counter"`, `"gauge"`, or `"hist"`.
    pub fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Hist { .. } => "hist",
        }
    }
}

static REGISTRY: Mutex<BTreeMap<String, Metric>> = Mutex::new(BTreeMap::new());

fn with_registry<R>(f: impl FnOnce(&mut BTreeMap<String, Metric>) -> R) -> R {
    let mut reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    f(&mut reg)
}

/// Add to a monotone counter (creates it at 0 first).
pub fn counter_add(name: &str, delta: u64) {
    if !crate::obs::enabled() {
        return;
    }
    with_registry(|reg| {
        match reg.entry(name.to_string()).or_insert(Metric::Counter(0)) {
            Metric::Counter(c) => *c += delta,
            other => *other = Metric::Counter(delta),
        }
    });
}

/// Set a monotone counter to an absolute cumulative value (used when the
/// source — e.g. `PipelineDiagnostics` — already accumulates). Monotone:
/// never moves backwards.
pub fn counter_set(name: &str, value: u64) {
    if !crate::obs::enabled() {
        return;
    }
    with_registry(|reg| {
        match reg.entry(name.to_string()).or_insert(Metric::Counter(0)) {
            Metric::Counter(c) => *c = (*c).max(value),
            other => *other = Metric::Counter(value),
        }
    });
}

/// Set a point-in-time gauge.
pub fn gauge_set(name: &str, value: f64) {
    if !crate::obs::enabled() {
        return;
    }
    with_registry(|reg| {
        reg.insert(name.to_string(), Metric::Gauge(value));
    });
}

/// Record one sample into a summary histogram.
pub fn observe(name: &str, value: f64) {
    if !crate::obs::enabled() {
        return;
    }
    with_registry(|reg| {
        let empty =
            Metric::Hist { count: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY };
        match reg.entry(name.to_string()).or_insert(empty) {
            Metric::Hist { count, sum, min, max } => {
                *count += 1;
                *sum += value;
                *min = min.min(value);
                *max = max.max(value);
            }
            other => {
                *other = Metric::Hist { count: 1, sum: value, min: value, max: value };
            }
        }
    });
}

/// Drain the registry (name → metric), resetting it for the next run.
pub(crate) fn take_metrics() -> BTreeMap<String, Metric> {
    with_registry(std::mem::take)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_registry_stays_empty() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(false);
        let _ = take_metrics();
        counter_add("c", 1);
        gauge_set("g", 2.0);
        observe("h", 3.0);
        assert!(take_metrics().is_empty());
    }

    #[test]
    fn counter_gauge_hist_semantics() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(true);
        let _ = take_metrics();
        counter_add("jobs", 2);
        counter_add("jobs", 3);
        counter_set("rounds", 7);
        counter_set("rounds", 4); // monotone: must not regress
        gauge_set("depth", 5.0);
        gauge_set("depth", 1.0);
        observe("wait_s", 0.5);
        observe("wait_s", 1.5);
        crate::obs::set_enabled(false);
        let m = take_metrics();
        assert_eq!(m["jobs"], Metric::Counter(5));
        assert_eq!(m["rounds"], Metric::Counter(7));
        assert_eq!(m["depth"], Metric::Gauge(1.0));
        assert_eq!(m["wait_s"], Metric::Hist { count: 2, sum: 2.0, min: 0.5, max: 1.5 });
        assert_eq!(m["wait_s"].kind(), "hist");
    }
}
