//! Exporters for an obs snapshot: JSONL event stream, Chrome-trace
//! (`trace_event`) file, and aggregated per-phase summary tables.
//!
//! JSONL schema (one JSON object per line, see docs/observability.md):
//!   {"type":"meta","schema":1,"solver":...,"seed":...}
//!   {"type":"span","name":...,"id":n,"parent":n,"tid":n,
//!    "ts_us":f,"dur_us":f,"args":{...}}
//!   {"type":"metric","kind":"counter"|"gauge"|"hist","name":...,...}
//!
//! The Chrome trace is a `traceEvents` array of complete ("ph":"X") events
//! in microseconds, loadable in about:tracing or Perfetto.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::obs::metrics::Metric;
use crate::obs::span::SpanEvent;
use crate::obs::ObsSnapshot;
use crate::util::json::Json;

fn args_obj(args: &[(String, Json)]) -> Json {
    Json::Obj(args.iter().cloned().collect())
}

fn span_line(ev: &SpanEvent) -> Json {
    let mut o = BTreeMap::new();
    o.insert("type".into(), Json::from("span"));
    o.insert("name".into(), Json::from(ev.name.clone()));
    o.insert("id".into(), Json::from(ev.id));
    o.insert("parent".into(), Json::from(ev.parent));
    o.insert("tid".into(), Json::from(ev.tid));
    o.insert("ts_us".into(), Json::from(ev.start_ns as f64 / 1e3));
    o.insert("dur_us".into(), Json::from(ev.end_ns.saturating_sub(ev.start_ns) as f64 / 1e3));
    if !ev.args.is_empty() {
        o.insert("args".into(), args_obj(&ev.args));
    }
    Json::Obj(o)
}

fn metric_line(name: &str, m: &Metric) -> Json {
    let mut o = BTreeMap::new();
    o.insert("type".into(), Json::from("metric"));
    o.insert("kind".into(), Json::from(m.kind()));
    o.insert("name".into(), Json::from(name));
    match m {
        Metric::Counter(c) => {
            o.insert("value".into(), Json::from(*c));
        }
        Metric::Gauge(g) => {
            o.insert("value".into(), Json::from(*g));
        }
        Metric::Hist { count, sum, min, max } => {
            o.insert("count".into(), Json::from(*count));
            o.insert("sum".into(), Json::from(*sum));
            o.insert("min".into(), Json::from(*min));
            o.insert("max".into(), Json::from(*max));
        }
    }
    Json::Obj(o)
}

/// Write the JSONL event stream. `meta` entries are merged into the leading
/// meta line (after the fixed `type`/`schema` keys).
pub fn write_jsonl(path: &Path, meta: &[(String, Json)], snap: &ObsSnapshot) -> Result<()> {
    let file = File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    let mut head = BTreeMap::new();
    head.insert("type".to_string(), Json::from("meta"));
    head.insert("schema".to_string(), Json::from(1u64));
    head.insert("dropped_events".to_string(), Json::from(snap.dropped));
    for (k, v) in meta {
        head.insert(k.clone(), v.clone());
    }
    writeln!(w, "{}", Json::Obj(head))?;
    for ev in &snap.events {
        writeln!(w, "{}", span_line(ev))?;
    }
    for (name, m) in &snap.metrics {
        writeln!(w, "{}", metric_line(name, m))?;
    }
    w.flush()?;
    Ok(())
}

/// Write a Chrome `trace_event` file (complete events, microseconds).
pub fn write_chrome_trace(path: &Path, snap: &ObsSnapshot) -> Result<()> {
    let file = File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    let events: Vec<Json> = snap
        .events
        .iter()
        .map(|ev| {
            let mut o = BTreeMap::new();
            o.insert("name".into(), Json::from(ev.name.clone()));
            o.insert("cat".into(), Json::from(category_of(&ev.name)));
            o.insert("ph".into(), Json::from("X"));
            o.insert("ts".into(), Json::from(ev.start_ns as f64 / 1e3));
            o.insert(
                "dur".into(),
                Json::from(ev.end_ns.saturating_sub(ev.start_ns) as f64 / 1e3),
            );
            o.insert("pid".into(), Json::from(1u64));
            o.insert("tid".into(), Json::from(ev.tid));
            if !ev.args.is_empty() {
                o.insert("args".into(), args_obj(&ev.args));
            }
            Json::Obj(o)
        })
        .collect();
    let mut doc = BTreeMap::new();
    doc.insert("traceEvents".to_string(), Json::Arr(events));
    doc.insert("displayTimeUnit".to_string(), Json::from("ms"));
    writeln!(w, "{}", Json::Obj(doc))?;
    w.flush()?;
    Ok(())
}

/// First dot-segment of a span name — the Chrome-trace category
/// (`step.precondition` → `step`).
fn category_of(name: &str) -> &str {
    name.split('.').next().unwrap_or(name)
}

/// One row of the per-phase summary: all spans sharing a name, aggregated.
#[derive(Clone, Debug)]
pub struct PhaseRow {
    pub name: String,
    pub count: usize,
    pub total_s: f64,
    pub mean_s: f64,
}

/// Aggregate spans by name, sorted by total time descending.
pub fn phase_summary(events: &[SpanEvent]) -> Vec<PhaseRow> {
    let mut acc: BTreeMap<&str, (usize, f64)> = BTreeMap::new();
    for ev in events {
        let e = acc.entry(&ev.name).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += ev.dur_s();
    }
    let mut rows: Vec<PhaseRow> = acc
        .into_iter()
        .map(|(name, (count, total_s))| PhaseRow {
            name: name.to_string(),
            count,
            total_s,
            mean_s: total_s / count as f64,
        })
        .collect();
    rows.sort_by(|a, b| b.total_s.partial_cmp(&a.total_s).unwrap());
    rows
}

/// Render phase rows as an aligned text table (empty string for no rows).
pub fn render_phase_table(title: &str, rows: &[PhaseRow]) -> String {
    use crate::util::benchkit::format_secs;
    if rows.is_empty() {
        return String::new();
    }
    let w = rows.iter().map(|r| r.name.len()).max().unwrap_or(5).max(5);
    let mut out = format!("== {title} ==\n");
    out.push_str(&format!(
        "{:<w$} {:>7} {:>12} {:>12}\n",
        "phase", "count", "total", "mean",
        w = w
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<w$} {:>7} {:>12} {:>12}\n",
            r.name,
            r.count,
            format_secs(r.total_s),
            format_secs(r.mean_s),
            w = w
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn sample_snapshot() -> ObsSnapshot {
        let events = vec![
            SpanEvent {
                name: "step.precondition".into(),
                id: 1,
                parent: 0,
                tid: 1,
                start_ns: 1_000,
                end_ns: 4_000,
                args: vec![("epoch".into(), Json::Num(0.0))],
            },
            SpanEvent {
                name: "step.precondition".into(),
                id: 2,
                parent: 0,
                tid: 1,
                start_ns: 5_000,
                end_ns: 6_000,
                args: vec![],
            },
            SpanEvent {
                name: "linalg.qr".into(),
                id: 3,
                parent: 1,
                tid: 2,
                start_ns: 2_000,
                end_ns: 3_000,
                args: vec![("m".into(), Json::Num(64.0))],
            },
        ];
        let mut metrics = BTreeMap::new();
        metrics.insert("pipeline.jobs_completed".to_string(), Metric::Counter(4));
        metrics.insert("pipeline.queue_depth".to_string(), Metric::Gauge(2.0));
        metrics.insert(
            "pipeline.job.wait_s".to_string(),
            Metric::Hist { count: 2, sum: 0.3, min: 0.1, max: 0.2 },
        );
        ObsSnapshot { events, metrics, dropped: 0 }
    }

    #[test]
    fn jsonl_lines_parse_and_lead_with_meta() {
        let dir = std::env::temp_dir()
            .join(format!("rkfac_obs_export_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        let meta = vec![
            ("solver".to_string(), Json::from("rs-kfac")),
            ("seed".to_string(), Json::from(5u64)),
        ];
        write_jsonl(&path, &meta, &sample_snapshot()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 1 + 3 + 3);
        let head = json::parse(lines[0]).unwrap();
        assert_eq!(head.get("type").unwrap().as_str(), Some("meta"));
        assert_eq!(head.get("schema").unwrap().as_usize(), Some(1));
        assert_eq!(head.get("solver").unwrap().as_str(), Some("rs-kfac"));
        for line in &lines[1..] {
            let v = json::parse(line).unwrap();
            let ty = v.get("type").unwrap().as_str().unwrap();
            assert!(ty == "span" || ty == "metric");
            if ty == "span" {
                assert!(v.get("dur_us").unwrap().as_f64().unwrap() >= 0.0);
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn chrome_trace_is_wellformed() {
        let dir = std::env::temp_dir()
            .join(format!("rkfac_obs_chrome_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        write_chrome_trace(&path, &sample_snapshot()).unwrap();
        let doc = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 3);
        for ev in events {
            assert_eq!(ev.get("ph").unwrap().as_str(), Some("X"));
            assert!(ev.get("ts").unwrap().as_f64().is_some());
            assert!(ev.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            assert!(ev.get("tid").unwrap().as_usize().is_some());
        }
        assert_eq!(events[2].get("cat").unwrap().as_str(), Some("linalg"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn phase_summary_aggregates_by_name() {
        let snap = sample_snapshot();
        let rows = phase_summary(&snap.events);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].name, "step.precondition");
        assert_eq!(rows[0].count, 2);
        assert!((rows[0].total_s - 4e-6).abs() < 1e-12);
        let table = render_phase_table("phases", &rows);
        assert!(table.contains("step.precondition"));
        assert!(table.contains("linalg.qr"));
        assert!(render_phase_table("empty", &[]).is_empty());
    }
}
