//! Hierarchical spans: scoped RAII timers that nest within a thread via a
//! thread-local stack and *across* threads via explicit parent handoff
//! ([`current_ctx`] → [`span_with_parent`]), so a pipeline worker's
//! decomposition nests under the trainer's refresh span.
//!
//! Non-perturbation contract: a span only ever (a) reads the monotonic
//! clock and (b) appends to a global event buffer — it never feeds a value
//! back into computation. When the obs gate is off, [`span`] returns an
//! inert guard after a single relaxed atomic load: no allocation, no lock,
//! no clock read.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::obs::clock;
use crate::util::json::Json;

/// Event-buffer capacity; beyond it events are counted as dropped instead
/// of growing memory without bound (a smoke run emits a few thousand).
const EVENT_CAP: usize = 1 << 20;

static EVENTS: Mutex<Vec<SpanEvent>> = Mutex::new(Vec::new());
static DROPPED: AtomicU64 = AtomicU64::new(0);
/// Span ids are process-unique and never 0 (0 = "no parent").
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);
/// Small dense thread labels for the exporters (ThreadId has no stable
/// integer form); assigned on each thread's first span.
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: Cell<u64> = const { Cell::new(0) };
    static STACK: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

/// One completed span, as handed to the exporters.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    pub name: String,
    pub id: u64,
    /// Parent span id; 0 = root.
    pub parent: u64,
    /// Dense per-thread label (1 = first thread to emit a span).
    pub tid: u64,
    pub start_ns: u64,
    pub end_ns: u64,
    pub args: Vec<(String, Json)>,
}

impl SpanEvent {
    pub fn dur_s(&self) -> f64 {
        clock::secs_between(self.start_ns, self.end_ns)
    }

    pub fn arg(&self, key: &str) -> Option<&Json> {
        self.args.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }
}

/// Cross-thread span context: pass the value of [`current_ctx`] to a worker
/// so its spans nest under the caller's.
#[derive(Clone, Copy, Debug, Default)]
pub struct SpanCtx(pub(crate) u64);

impl SpanCtx {
    /// The "no parent" context.
    pub const ROOT: SpanCtx = SpanCtx(0);

    /// The raw span id, for carrying a context across a process boundary
    /// (the transport wire format ships it as a u64).
    pub(crate) fn raw(self) -> u64 {
        self.0
    }

    /// Rebuild a context from [`SpanCtx::raw`] on the far side of the wire,
    /// so server-side job spans nest under the trainer's refresh span in a
    /// merged trace.
    pub(crate) fn from_raw(raw: u64) -> SpanCtx {
        SpanCtx(raw)
    }
}

fn this_tid() -> u64 {
    TID.with(|t| {
        if t.get() == 0 {
            t.set(NEXT_TID.fetch_add(1, Ordering::Relaxed));
        }
        t.get()
    })
}

fn record(ev: SpanEvent) {
    let mut buf = EVENTS.lock().unwrap_or_else(|e| e.into_inner());
    if buf.len() >= EVENT_CAP {
        DROPPED.fetch_add(1, Ordering::Relaxed);
    } else {
        buf.push(ev);
    }
}

/// Drain the event buffer; returns `(events, dropped_count)` and resets both.
pub(crate) fn take_events() -> (Vec<SpanEvent>, u64) {
    let events = {
        let mut buf = EVENTS.lock().unwrap_or_else(|e| e.into_inner());
        std::mem::take(&mut *buf)
    };
    (events, DROPPED.swap(0, Ordering::Relaxed))
}

/// RAII span: records a [`SpanEvent`] on drop. Inert (field `None`) when
/// obs was disabled at creation time.
pub struct SpanGuard {
    rec: Option<Rec>,
}

struct Rec {
    name: String,
    id: u64,
    parent: u64,
    start_ns: u64,
    args: Vec<(String, Json)>,
}

impl SpanGuard {
    pub(crate) fn inert() -> SpanGuard {
        SpanGuard { rec: None }
    }

    fn active(name: &str, parent: u64) -> SpanGuard {
        let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
        STACK.with(|s| s.borrow_mut().push(id));
        SpanGuard {
            rec: Some(Rec {
                name: name.to_string(),
                id,
                parent,
                start_ns: clock::now_ns(),
                args: Vec::new(),
            }),
        }
    }

    /// Attach a key/value annotation (no-op on an inert guard).
    pub fn arg(mut self, key: &str, value: impl Into<Json>) -> Self {
        if let Some(rec) = self.rec.as_mut() {
            rec.args.push((key.to_string(), value.into()));
        }
        self
    }

    /// This span's context, for handing to worker threads. Inert guards
    /// return [`SpanCtx::ROOT`] so disabled runs pass a harmless 0 around.
    pub fn ctx(&self) -> SpanCtx {
        SpanCtx(self.rec.as_ref().map_or(0, |r| r.id))
    }

    /// Annotate with the installed linalg backend selection
    /// (`backend`/`threads` attributes). No-op — not even an atomic load —
    /// on an inert guard, so disabled runs pay nothing.
    pub fn with_backend(self) -> Self {
        if self.rec.is_none() {
            return self;
        }
        let sel = crate::linalg::backend::current();
        self.arg("backend", sel.kind.name()).arg("threads", sel.threads)
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(rec) = self.rec.take() {
            // Pop our own id (robust against out-of-order drops: remove by
            // value rather than blindly popping the top).
            STACK.with(|s| {
                let mut st = s.borrow_mut();
                if let Some(pos) = st.iter().rposition(|&id| id == rec.id) {
                    st.remove(pos);
                }
            });
            record(SpanEvent {
                name: rec.name,
                id: rec.id,
                parent: rec.parent,
                tid: this_tid(),
                start_ns: rec.start_ns,
                end_ns: clock::now_ns(),
                args: rec.args,
            });
        }
    }
}

/// Open a span nested under the current thread's innermost open span.
pub fn span(name: &str) -> SpanGuard {
    if !crate::obs::enabled() {
        return SpanGuard::inert();
    }
    let parent = STACK.with(|s| s.borrow().last().copied().unwrap_or(0));
    SpanGuard::active(name, parent)
}

/// Open a span under an explicit parent from another thread.
pub fn span_with_parent(name: &str, parent: SpanCtx) -> SpanGuard {
    if !crate::obs::enabled() {
        return SpanGuard::inert();
    }
    SpanGuard::active(name, parent.0)
}

/// Size-gated span for hot kernels: records only when obs is enabled *and*
/// the work estimate clears `min_work` — keeps gemm-sized call volumes from
/// flooding the event buffer while still catching decomposition-scale calls.
pub fn span_sized(name: &str, work: f64, min_work: f64) -> SpanGuard {
    if work < min_work {
        return SpanGuard::inert();
    }
    span(name)
}

/// [`span_sized`] plus the linalg backend annotation: the canonical span
/// constructor for dense-kernel call sites (`linalg.gemm` and friends gain
/// `backend`/`threads` attributes so traces say *how* a kernel ran).
pub fn span_kernel(name: &str, work: f64, min_work: f64) -> SpanGuard {
    span_sized(name, work, min_work).with_backend()
}

/// Context of the current thread's innermost open span.
pub fn current_ctx() -> SpanCtx {
    SpanCtx(STACK.with(|s| s.borrow().last().copied().unwrap_or(0)))
}

/// Record an already-measured interval (e.g. a queue-wait whose start was
/// stamped on another thread) as a complete span under `parent`.
pub fn emit_manual(
    name: &str,
    start_ns: u64,
    end_ns: u64,
    parent: SpanCtx,
    args: Vec<(String, Json)>,
) {
    if !crate::obs::enabled() {
        return;
    }
    record(SpanEvent {
        name: name.to_string(),
        id: NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed),
        parent: parent.0,
        tid: this_tid(),
        start_ns,
        end_ns,
        args,
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(false);
        let (_, _) = take_events(); // clear anything stale
        {
            let _s = span("never").arg("k", 1.0);
            let _m = span_sized("tiny", 10.0, 1e6);
        }
        let (events, dropped) = take_events();
        assert!(events.is_empty());
        assert_eq!(dropped, 0);
    }

    #[test]
    fn spans_nest_within_a_thread() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(true);
        let _ = take_events();
        {
            let outer = span("outer");
            let outer_id = outer.ctx().0;
            {
                let inner = span("inner");
                assert_ne!(inner.ctx().0, outer_id);
            }
            let sibling = span("sibling");
            drop(sibling);
            drop(outer);
        }
        crate::obs::set_enabled(false);
        let (events, _) = take_events();
        let by_name = |n: &str| events.iter().find(|e| e.name == n).unwrap();
        let outer = by_name("outer");
        assert_eq!(outer.parent, 0);
        assert_eq!(by_name("inner").parent, outer.id);
        assert_eq!(by_name("sibling").parent, outer.id);
        for e in &events {
            assert!(e.end_ns >= e.start_ns);
        }
    }

    #[test]
    fn spans_nest_across_threads_via_ctx() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(true);
        let _ = take_events();
        let parent_id;
        {
            let parent = span("dispatch");
            let ctx = parent.ctx();
            parent_id = ctx.0;
            let handles: Vec<_> = (0..3)
                .map(|i| {
                    std::thread::spawn(move || {
                        let child =
                            span_with_parent("work", ctx).arg("worker", i as f64);
                        // A nested span on the worker chains off `work`,
                        // not off the cross-thread parent directly.
                        let grand = span("work.sub");
                        drop(grand);
                        drop(child);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        }
        crate::obs::set_enabled(false);
        let (events, _) = take_events();
        let children: Vec<_> = events.iter().filter(|e| e.name == "work").collect();
        assert_eq!(children.len(), 3);
        for c in &children {
            assert_eq!(c.parent, parent_id);
            let sub = events
                .iter()
                .find(|e| e.name == "work.sub" && e.parent == c.id);
            assert!(sub.is_some(), "grandchild must nest under its worker span");
        }
        // Worker threads carry distinct tids, all different from the parent's.
        let parent_tid = events.iter().find(|e| e.id == parent_id).unwrap().tid;
        let mut tids: Vec<u64> = children.iter().map(|c| c.tid).collect();
        tids.sort_unstable();
        tids.dedup();
        assert_eq!(tids.len(), 3);
        assert!(!tids.contains(&parent_tid));
    }

    #[test]
    fn manual_emit_and_args_roundtrip() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(true);
        let _ = take_events();
        emit_manual(
            "queue.wait",
            100,
            400,
            SpanCtx::ROOT,
            vec![("block".into(), Json::Num(2.0))],
        );
        crate::obs::set_enabled(false);
        let (events, _) = take_events();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].arg("block").and_then(|j| j.as_f64()), Some(2.0));
        assert!((events[0].dur_s() - 300e-9).abs() < 1e-15);
    }
}
