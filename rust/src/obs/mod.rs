//! Process-wide, deterministic-safe tracing and metrics.
//!
//! One subsystem replaces the ad-hoc `Instant::now()` sites and one-off CSV
//! plumbing that grew around the solver and pipeline stack:
//!
//! * [`span`] / [`span_with_parent`] — hierarchical RAII phase timers that
//!   nest within a thread (thread-local stack) and across threads (explicit
//!   [`SpanCtx`] handoff to pipeline workers), decomposing a training step
//!   into data/forward-backward/precondition/apply and a refresh job into
//!   queue-wait vs sketch vs QR vs small-EVD.
//! * [`metrics`] — a registry of named counters/gauges/histograms behind
//!   one sink API ([`counter_add`], [`gauge_set`], [`observe`]).
//! * [`export`] — JSONL event stream, Chrome-trace (`trace_event`) file,
//!   and per-phase summary tables, driven by the `ObsHook` run hook.
//! * [`report`] — `rkfac report <run_dir>`: joins scheduler-predicted
//!   FLOPs against observed durations per (block, strategy, rank).
//!
//! Determinism contract: obs is strictly *read-only* with respect to
//! training. Spans and metrics read the wall clock and write to buffers
//! that nothing in the compute path ever reads back, so every bitwise
//! golden holds with observability fully enabled. When disabled (the
//! default), each instrumentation point costs one relaxed atomic load —
//! no allocation, no lock, no syscall.
//!
//! Naming convention (see docs/observability.md): dot-separated lowercase
//! `<subsystem>.<operation>[.<detail>]`, e.g. `step.precondition`,
//! `kfac.refresh.rsvd`, `pipeline.job.wait`, `linalg.qr`.

pub mod clock;
pub mod export;
pub mod metrics;
pub mod report;
pub mod span;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};

pub use metrics::{counter_add, counter_set, gauge_set, observe, Metric};
pub use span::{
    current_ctx, emit_manual, span, span_kernel, span_sized, span_with_parent, SpanCtx, SpanEvent,
    SpanGuard,
};

/// Work threshold (coarse flop estimate) below which hot-kernel spans
/// ([`span_sized`]) are skipped to bound event volume.
pub const GEMM_SPAN_MIN_WORK: f64 = 4e6;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is event/metric recording on? One relaxed load — the entire cost of a
/// disabled instrumentation point.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn recording on or off (the `ObsHook` flips this around a run).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Everything recorded since the last reset: drained span events, the
/// metrics registry, and the count of events dropped at the buffer cap.
pub struct ObsSnapshot {
    pub events: Vec<SpanEvent>,
    pub metrics: BTreeMap<String, Metric>,
    pub dropped: u64,
}

/// Drain all recorded state (events + metrics), resetting for the next run.
pub fn take_snapshot() -> ObsSnapshot {
    let (events, dropped) = span::take_events();
    ObsSnapshot { events, metrics: metrics::take_metrics(), dropped }
}

/// Discard any recorded state (run start, so a prior aborted run's events
/// cannot leak into this run's export).
pub fn reset() {
    let _ = take_snapshot();
}

/// Configuration for the obs subsystem (`[obs]` in the experiment TOML,
/// `--obs` on the CLI).
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// Master switch: record spans/metrics and export at run end.
    pub enabled: bool,
    /// Write the per-run JSONL event stream (`obs_<solver>_<seed>.jsonl`).
    pub jsonl: bool,
    /// Write the Chrome-trace file (`trace_<solver>_<seed>.json`).
    pub chrome_trace: bool,
    /// Print the per-phase summary table at run end.
    pub summary: bool,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig { enabled: false, jsonl: true, chrome_trace: true, summary: true }
    }
}

/// Serialize tests that flip the global enable gate or drain the global
/// buffers (cargo runs tests on parallel threads within one binary).
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_drains_everything() {
        let _g = test_guard();
        set_enabled(true);
        reset();
        {
            let _s = span("a");
        }
        counter_add("c", 1);
        set_enabled(false);
        let snap = take_snapshot();
        assert_eq!(snap.events.len(), 1);
        assert_eq!(snap.metrics.len(), 1);
        assert_eq!(snap.dropped, 0);
        let empty = take_snapshot();
        assert!(empty.events.is_empty() && empty.metrics.is_empty());
    }

    #[test]
    fn default_config_is_off_but_full_featured() {
        let c = ObsConfig::default();
        assert!(!c.enabled);
        assert!(c.jsonl && c.chrome_trace && c.summary);
    }
}
