//! `rkfac report <run_dir>` — post-hoc analysis of a run's obs JSONL
//! stream: per-phase summaries (step breakdown, refresh breakdown) and the
//! cost-model validation table joining scheduler-predicted FLOPs against
//! observed span durations per (block, strategy, rank).
//!
//! The `flops-stale` queue discipline orders refresh jobs by
//! `DecompMeta::flops × staleness`; this report checks the FLOPs half of
//! that product: if the predicted-cost ordering of (block, strategy, rank)
//! groups disagrees with their measured mean durations, the priority queue
//! is dispatching in the wrong order and the affected rows are flagged.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::obs::export::{phase_summary, render_phase_table, PhaseRow};
use crate::obs::span::SpanEvent;
use crate::util::benchkit::format_secs;
use crate::util::json::{self, Json};

/// Re-ingest the span lines of one obs JSONL file (metric/meta lines are
/// skipped; timestamps are rebuilt from `ts_us`/`dur_us`).
pub fn read_spans(path: &Path) -> Result<Vec<SpanEvent>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    let mut events = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(line)
            .with_context(|| format!("{}:{}: bad JSON", path.display(), lineno + 1))?;
        if v.get("type").and_then(Json::as_str) != Some("span") {
            continue;
        }
        let num = |key: &str| v.get(key).and_then(Json::as_f64).unwrap_or(0.0);
        let start_ns = (num("ts_us") * 1e3) as u64;
        let args = v
            .get("args")
            .and_then(Json::as_obj)
            .map(|o| o.iter().map(|(k, val)| (k.clone(), val.clone())).collect())
            .unwrap_or_default();
        events.push(SpanEvent {
            name: v.get("name").and_then(Json::as_str).unwrap_or("?").to_string(),
            id: num("id") as u64,
            parent: num("parent") as u64,
            tid: num("tid") as u64,
            start_ns,
            end_ns: start_ns + (num("dur_us") * 1e3) as u64,
            args,
        });
    }
    Ok(events)
}

/// One (block, strategy, op, rank) group of refresh-work spans.
#[derive(Clone, Debug)]
pub struct CostRow {
    pub block: usize,
    pub strategy: String,
    /// What the span did: `"decompose"` (full recomputation — also the
    /// default for spans from before the op annotation existed) or
    /// `"update"` (online incremental basis rotation).
    pub op: String,
    pub rank: usize,
    pub n: usize,
    pub flops_pred: f64,
    pub mean_s: f64,
    /// Set when this row's observed cost ordering contradicts the
    /// predicted-FLOPs ordering relative to another group.
    pub flagged: bool,
}

/// Join predicted FLOPs against observed durations per (block, strategy,
/// op, rank), using the refresh-work spans (`pipeline.job.run` from the
/// worker pool, `kfac.refresh.<strategy>` from the inline path). The `op`
/// dimension keeps online incremental updates and full decompositions in
/// separate rows — their cost models differ by an order of magnitude, so
/// pooling them would always flag a false inversion. Rows come back
/// sorted by predicted FLOPs ascending; `flagged` marks rows out of
/// measured-cost order (adjacent inversions under that sort).
pub fn cost_model_rows(events: &[SpanEvent]) -> Vec<CostRow> {
    let mut groups: BTreeMap<(usize, String, String, usize), (usize, f64, f64)> = BTreeMap::new();
    for ev in events {
        let is_work = ev.name == "pipeline.job.run" || ev.name.starts_with("kfac.refresh.");
        if !is_work {
            continue;
        }
        let (Some(block), Some(flops)) = (
            ev.arg("block").and_then(Json::as_usize),
            ev.arg("flops_pred").and_then(Json::as_f64),
        ) else {
            continue;
        };
        let strategy = ev
            .arg("strategy")
            .and_then(Json::as_str)
            .unwrap_or("?")
            .to_string();
        let op = ev
            .arg("op")
            .and_then(Json::as_str)
            .unwrap_or("decompose")
            .to_string();
        let rank = ev.arg("rank").and_then(Json::as_usize).unwrap_or(0);
        let e = groups.entry((block, strategy, op, rank)).or_insert((0, 0.0, 0.0));
        e.0 += 1;
        e.1 += flops;
        e.2 += ev.dur_s();
    }
    let mut rows: Vec<CostRow> = groups
        .into_iter()
        .map(|((block, strategy, op, rank), (n, flops_sum, dur_sum))| CostRow {
            block,
            strategy,
            op,
            rank,
            n,
            flops_pred: flops_sum / n as f64,
            mean_s: dur_sum / n as f64,
            flagged: false,
        })
        .collect();
    rows.sort_by(|a, b| a.flops_pred.partial_cmp(&b.flops_pred).unwrap());
    // Under a correct cost model, mean duration should be non-decreasing
    // along the predicted-FLOPs sort; flag both sides of each inversion.
    for i in 1..rows.len() {
        if rows[i].mean_s < rows[i - 1].mean_s {
            rows[i].flagged = true;
            rows[i - 1].flagged = true;
        }
    }
    rows
}

fn render_cost_table(rows: &[CostRow]) -> String {
    if rows.is_empty() {
        return "== cost model (flops-stale) ==\n(no refresh-work spans with \
                cost annotations found)\n"
            .to_string();
    }
    let mut out = String::from("== cost model (flops-stale): predicted vs observed ==\n");
    out.push_str(&format!(
        "{:>5} {:>9} {:>9} {:>5} {:>4} {:>12} {:>12} {:>12}  {}\n",
        "block", "strategy", "op", "rank", "n", "pred_flops", "mean_obs", "flops/s", "order"
    ));
    for r in rows {
        let rate = if r.mean_s > 0.0 { r.flops_pred / r.mean_s } else { 0.0 };
        out.push_str(&format!(
            "{:>5} {:>9} {:>9} {:>5} {:>4} {:>12.3e} {:>12} {:>12.3e}  {}\n",
            r.block,
            r.strategy,
            r.op,
            r.rank,
            r.n,
            r.flops_pred,
            format_secs(r.mean_s),
            rate,
            if r.flagged { "MISORDERED" } else { "ok" }
        ));
    }
    let n_flagged = rows.iter().filter(|r| r.flagged).count();
    if n_flagged > 0 {
        out.push_str(&format!(
            "{n_flagged} group(s) where the flops-stale priority ordering \
             disagrees with measured cost\n"
        ));
    } else {
        out.push_str("predicted-FLOPs ordering agrees with measured cost\n");
    }
    out
}

fn split_phases(rows: Vec<PhaseRow>) -> (Vec<PhaseRow>, Vec<PhaseRow>) {
    let is_refresh = |name: &str| {
        name.starts_with("kfac.refresh")
            || name.starts_with("pipeline.")
            || name.starts_with("linalg.")
            || name.starts_with("rnla.")
    };
    rows.into_iter().partition(|r| !is_refresh(&r.name))
}

/// Render the full report for one obs JSONL file.
pub fn report_for_file(path: &Path) -> Result<String> {
    let events = read_spans(path)?;
    let mut out = format!("# {} ({} spans)\n\n", path.display(), events.len());
    let (step_rows, refresh_rows) = split_phases(phase_summary(&events));
    out.push_str(&render_phase_table("step breakdown", &step_rows));
    out.push('\n');
    out.push_str(&render_phase_table("refresh breakdown", &refresh_rows));
    out.push('\n');
    out.push_str(&render_cost_table(&cost_model_rows(&events)));
    Ok(out)
}

/// Render the report for every `obs_*.jsonl` under `run_dir`.
pub fn run_report(run_dir: &Path) -> Result<String> {
    if !run_dir.is_dir() {
        bail!("{} is not a directory", run_dir.display());
    }
    let mut files: Vec<_> = std::fs::read_dir(run_dir)
        .with_context(|| format!("read {}", run_dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("obs_") && n.ends_with(".jsonl"))
        })
        .collect();
    files.sort();
    if files.is_empty() {
        bail!(
            "no obs_*.jsonl in {} — run training with --obs (or [obs] enabled) first",
            run_dir.display()
        );
    }
    let mut out = String::new();
    for (i, f) in files.iter().enumerate() {
        if i > 0 {
            out.push('\n');
        }
        out.push_str(&report_for_file(f)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn work_span(
        id: u64,
        name: &str,
        block: usize,
        strategy: &str,
        rank: usize,
        flops: f64,
        dur_ns: u64,
    ) -> SpanEvent {
        SpanEvent {
            name: name.to_string(),
            id,
            parent: 0,
            tid: 1,
            start_ns: 0,
            end_ns: dur_ns,
            args: vec![
                ("block".into(), Json::from(block)),
                ("strategy".into(), Json::from(strategy)),
                ("rank".into(), Json::from(rank)),
                ("flops_pred".into(), Json::from(flops)),
            ],
        }
    }

    #[test]
    fn cost_rows_join_and_flag_inversions() {
        // Group A predicted cheap but observed slow; group B the reverse.
        let events = vec![
            work_span(1, "pipeline.job.run", 0, "rsvd", 8, 1e6, 9_000_000),
            work_span(2, "pipeline.job.run", 0, "rsvd", 8, 1e6, 11_000_000),
            work_span(3, "kfac.refresh.rsvd", 1, "rsvd", 16, 5e6, 2_000_000),
            // No cost args → excluded from the join.
            SpanEvent {
                name: "pipeline.job.run".into(),
                id: 4,
                parent: 0,
                tid: 1,
                start_ns: 0,
                end_ns: 1,
                args: vec![],
            },
        ];
        let rows = cost_model_rows(&events);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].block, 0);
        assert_eq!(rows[0].n, 2);
        assert!((rows[0].mean_s - 0.010).abs() < 1e-12);
        assert!(rows[0].flagged && rows[1].flagged, "inversion must be flagged");
        let table = render_cost_table(&rows);
        assert!(table.contains("MISORDERED"));
        assert!(table.contains("disagrees with measured cost"));
    }

    #[test]
    fn cost_rows_split_update_and_decompose_ops() {
        // Same (block, strategy, rank): an online update is predicted (and
        // observed) far cheaper than the full decomposition. Separate rows,
        // no false inversion — and spans without an op annotation pool with
        // the "decompose" row.
        let mut upd = work_span(1, "kfac.refresh.rsvd", 0, "rsvd", 8, 1e5, 200_000);
        upd.args.push(("op".into(), Json::from("update")));
        let mut full = work_span(2, "pipeline.job.run", 0, "rsvd", 8, 5e6, 8_000_000);
        full.args.push(("op".into(), Json::from("decompose")));
        let legacy = work_span(3, "pipeline.job.run", 0, "rsvd", 8, 5e6, 8_000_000);
        let rows = cost_model_rows(&[upd, full, legacy]);
        assert_eq!(rows.len(), 2);
        assert_eq!((rows[0].op.as_str(), rows[0].n), ("update", 1));
        assert_eq!((rows[1].op.as_str(), rows[1].n), ("decompose", 2));
        assert!(rows.iter().all(|r| !r.flagged), "op split must prevent false inversions");
        let table = render_cost_table(&rows);
        assert!(table.contains("update") && table.contains("decompose"));
    }

    #[test]
    fn cost_rows_agreeing_order_unflagged() {
        let events = vec![
            work_span(1, "pipeline.job.run", 0, "rsvd", 8, 1e6, 1_000_000),
            work_span(2, "pipeline.job.run", 1, "rsvd", 16, 4e6, 3_000_000),
        ];
        let rows = cost_model_rows(&events);
        assert!(rows.iter().all(|r| !r.flagged));
        assert!(render_cost_table(&rows).contains("agrees with measured cost"));
    }

    #[test]
    fn jsonl_roundtrip_through_report() {
        let dir = std::env::temp_dir()
            .join(format!("rkfac_obs_report_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("obs_rs-kfac_5.jsonl");
        let snap = crate::obs::ObsSnapshot {
            events: vec![
                SpanEvent {
                    name: "step.precondition".into(),
                    id: 1,
                    parent: 0,
                    tid: 1,
                    start_ns: 1_000,
                    end_ns: 2_000_000,
                    args: vec![],
                },
                work_span(2, "kfac.refresh.rsvd", 0, "rsvd", 8, 2e6, 500_000),
            ],
            metrics: Default::default(),
            dropped: 0,
        };
        crate::obs::export::write_jsonl(
            &path,
            &[("solver".to_string(), Json::from("rs-kfac"))],
            &snap,
        )
        .unwrap();
        let text = run_report(&dir).unwrap();
        assert!(text.contains("step breakdown"));
        assert!(text.contains("refresh breakdown"));
        assert!(text.contains("step.precondition"));
        assert!(text.contains("kfac.refresh.rsvd"));
        assert!(text.contains("cost model"));
        // Directory without obs files errors with guidance.
        let empty = dir.join("empty");
        std::fs::create_dir_all(&empty).unwrap();
        assert!(run_report(&empty).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
