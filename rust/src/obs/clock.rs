//! Monotonic clock shared by every timing consumer in the crate.
//!
//! All wall-time in the repo — obs spans, the solver's `decomp_seconds`,
//! the pipeline's wait/run split, `util::benchkit` samples — reads this one
//! abstraction, so phase durations from different subsystems are directly
//! comparable and the span exporter can place every event on a single
//! process-relative timeline.
//!
//! The clock is *always on* (it never consults the obs enable gate): timing
//! feeds user-visible metrics like `EpochRecord::wall_s` whether or not
//! tracing is recording. It only ever reads `std::time::Instant`; nothing
//! downstream of it can perturb computation or RNG streams.

use std::sync::OnceLock;
use std::time::Instant;

/// Process-wide epoch all timestamps are relative to (first clock use).
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process clock epoch.
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// Seconds between two `now_ns` readings (saturating: out-of-order
/// readings from racing threads clamp to zero rather than underflowing).
pub fn secs_between(start_ns: u64, end_ns: u64) -> f64 {
    end_ns.saturating_sub(start_ns) as f64 * 1e-9
}

/// Scoped elapsed-time reader — the drop-in replacement for the ad-hoc
/// `let t0 = Instant::now(); ... t0.elapsed().as_secs_f64()` pattern.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    start_ns: u64,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start_ns: now_ns() }
    }

    /// Nanosecond timestamp at which this stopwatch started.
    pub fn start_ns(&self) -> u64 {
        self.start_ns
    }

    /// Seconds elapsed since `start`.
    pub fn elapsed_s(&self) -> f64 {
        secs_between(self.start_ns, now_ns())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_and_positive() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
        let sw = Stopwatch::start();
        let mut acc = 0u64;
        for i in 0..10_000u64 {
            acc = acc.wrapping_add(i);
        }
        std::hint::black_box(acc);
        assert!(sw.elapsed_s() >= 0.0);
    }

    #[test]
    fn secs_between_saturates() {
        assert_eq!(secs_between(100, 50), 0.0);
        assert!((secs_between(0, 1_500_000_000) - 1.5).abs() < 1e-12);
    }
}
