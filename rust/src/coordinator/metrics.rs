//! Metrics: CSV logging + run summary statistics (mean ± std across seeds,
//! time-to-accuracy — the quantities Table 1 reports).

use std::io::Write;
use std::path::Path;

use anyhow::Result;

/// Append-style CSV writer with a fixed header.
pub struct CsvLogger {
    file: std::fs::File,
    pub path: std::path::PathBuf,
    cols: usize,
}

impl CsvLogger {
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> Result<CsvLogger> {
        Self::create_annotated(path, None, header)
    }

    /// Like [`CsvLogger::create`], but with an optional `#`-prefixed comment
    /// line *above* the header — used to version a file's schema in-band
    /// (consumers that split on lines must skip `#` lines).
    pub fn create_annotated(
        path: impl AsRef<Path>,
        comment: Option<&str>,
        header: &[&str],
    ) -> Result<CsvLogger> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::fs::File::create(&path)?;
        if let Some(c) = comment {
            writeln!(file, "# {c}")?;
        }
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvLogger { file, path, cols: header.len() })
    }

    pub fn row(&mut self, values: &[String]) -> Result<()> {
        assert_eq!(values.len(), self.cols, "CsvLogger: column count mismatch");
        writeln!(self.file, "{}", values.join(","))?;
        self.file.flush()?;
        Ok(())
    }

    pub fn row_f64(&mut self, values: &[f64]) -> Result<()> {
        self.row(&values.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }
}

/// Mean ± sample standard deviation.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

/// One epoch's record from a training run.
#[derive(Clone, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    /// Cumulative wall-clock seconds at the end of this epoch.
    pub wall_s: f64,
    pub train_loss: f64,
    pub test_loss: f64,
    pub test_acc: f64,
    /// Cumulative seconds spent in K-factor decompositions.
    pub decomp_s: f64,
}

/// One (refresh round, block) entry of the adaptive rank trace: the
/// decomposition ranks *installed* — i.e. what the solver preconditions
/// with — right after that refresh round returned. With the async pipeline
/// under a nonzero staleness budget, a round may legally return while its
/// own jobs are still in flight, so the installed ranks can lag the
/// round's request by up to `max_stale_steps`; at `max_stale_steps = 0`
/// (and for inline refreshes) they are exactly the round's output.
#[derive(Clone, Debug)]
pub struct RankTraceRow {
    /// Decomposition-refresh round (0-based, monotone across the run).
    pub round: usize,
    pub epoch: usize,
    /// Global step index at which the round returned.
    pub step: usize,
    pub block: usize,
    pub rank_a: usize,
    pub rank_g: usize,
}

/// One refresh round's pipeline telemetry: scheduler queue depth plus the
/// recovery/supersede/warm-up counters, sampled right after the round
/// returned. Only populated when the async refresh pipeline is attached.
#[derive(Clone, Debug)]
pub struct PipeTraceRow {
    /// Decomposition-refresh round (0-based, monotone across the run).
    pub round: usize,
    pub epoch: usize,
    /// Global step index at which the round returned.
    pub step: usize,
    /// Jobs still waiting in the scheduler queue after the round.
    pub queue_depth: usize,
    /// High-water mark of the queue depth so far (cumulative).
    pub max_queue_depth: usize,
    /// Cumulative jobs recovered via the trainer-thread inline retry.
    pub recovered_jobs: usize,
    /// Cumulative pending jobs superseded by a controller rank change.
    pub superseded_jobs: usize,
    /// Slots that have not published their first decomposition yet.
    pub warming_slots: usize,
    /// Worst staleness (steps) across published slots at the probe;
    /// `None` before any slot has published (logged as an empty CSV cell).
    pub max_staleness: Option<u64>,
    /// Cumulative seconds jobs sat in the scheduler queue before a worker
    /// popped them (schema 2; previously conflated into the decomposition
    /// time).
    pub wait_s: f64,
    /// Cumulative seconds workers spent inside decompositions (schema 2).
    pub run_s: f64,
}

/// Full result of one training run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub solver: String,
    pub seed: u64,
    pub records: Vec<EpochRecord>,
    pub total_s: f64,
    /// Per-block decomposition ranks at every refresh round (empty for
    /// solvers without Kronecker-factor decompositions). With the pipeline
    /// rank controller on, this is the adaptive per-layer rank trace.
    pub rank_trace: Vec<RankTraceRow>,
    /// Per-round scheduler/staleness telemetry (empty without an attached
    /// refresh pipeline).
    pub pipe_trace: Vec<PipeTraceRow>,
}

impl RunResult {
    /// Wall seconds until test accuracy first reached `target` (None if never).
    pub fn time_to_acc(&self, target: f64) -> Option<f64> {
        self.records.iter().find(|r| r.test_acc >= target).map(|r| r.wall_s)
    }

    /// Epochs (1-based) until test accuracy first reached `target`.
    pub fn epochs_to_acc(&self, target: f64) -> Option<usize> {
        self.records.iter().find(|r| r.test_acc >= target).map(|r| r.epoch + 1)
    }

    /// Mean seconds per epoch.
    pub fn time_per_epoch(&self) -> f64 {
        if self.records.is_empty() {
            return f64::NAN;
        }
        self.total_s / self.records.len() as f64
    }

    pub fn best_acc(&self) -> f64 {
        self.records.iter().map(|r| r.test_acc).fold(0.0, f64::max)
    }

    pub fn final_loss(&self) -> f64 {
        self.records.last().map(|r| r.test_loss).unwrap_or(f64::NAN)
    }

    /// Write per-epoch series to CSV (`fig2`-style output).
    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut log = CsvLogger::create(
            path,
            &["solver", "seed", "epoch", "wall_s", "train_loss", "test_loss", "test_acc", "decomp_s"],
        )?;
        for r in &self.records {
            log.row(&[
                self.solver.clone(),
                self.seed.to_string(),
                r.epoch.to_string(),
                format!("{:.3}", r.wall_s),
                format!("{:.5}", r.train_loss),
                format!("{:.5}", r.test_loss),
                format!("{:.5}", r.test_acc),
                format!("{:.3}", r.decomp_s),
            ])?;
        }
        Ok(())
    }

    /// Write the per-block rank trace to CSV (one row per refresh round
    /// and block).
    pub fn write_rank_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut log = CsvLogger::create(
            path,
            &["solver", "seed", "round", "epoch", "step", "block", "rank_a", "rank_g"],
        )?;
        for r in &self.rank_trace {
            log.row(&[
                self.solver.clone(),
                self.seed.to_string(),
                r.round.to_string(),
                r.epoch.to_string(),
                r.step.to_string(),
                r.block.to_string(),
                r.rank_a.to_string(),
                r.rank_g.to_string(),
            ])?;
        }
        Ok(())
    }

    /// Write the per-round pipeline telemetry (queue depth, recoveries,
    /// supersedes, warm-up, worst staleness) to CSV.
    pub fn write_pipeline_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut log = CsvLogger::create_annotated(
            path,
            Some("pipeline-trace schema=2: wait_s (queue wait) and run_s (decomposition) are \
                  cumulative and disjoint — schema 1 conflated them"),
            &[
                "solver",
                "seed",
                "round",
                "epoch",
                "step",
                "queue_depth",
                "max_queue_depth",
                "recovered_jobs",
                "superseded_jobs",
                "warming_slots",
                "max_staleness",
                "wait_s",
                "run_s",
            ],
        )?;
        for r in &self.pipe_trace {
            log.row(&[
                self.solver.clone(),
                self.seed.to_string(),
                r.round.to_string(),
                r.epoch.to_string(),
                r.step.to_string(),
                r.queue_depth.to_string(),
                r.max_queue_depth.to_string(),
                r.recovered_jobs.to_string(),
                r.superseded_jobs.to_string(),
                r.warming_slots.to_string(),
                r.max_staleness.map(|s| s.to_string()).unwrap_or_default(),
                format!("{:.3}", r.wait_s),
                format!("{:.3}", r.run_s),
            ])?;
        }
        Ok(())
    }
}

/// Aggregate Table-1 style statistics across seeds for one solver.
#[derive(Debug)]
pub struct SolverSummary {
    pub solver: String,
    pub n_runs: usize,
    /// (target, mean t, std t, #runs that hit it) per accuracy target.
    pub time_to: Vec<(f64, f64, f64, usize)>,
    /// (target, mean epochs, std epochs) for the hardest target.
    pub epochs_to_last: (f64, f64, f64),
    pub t_epoch_mean: f64,
    pub t_epoch_std: f64,
    /// True when the runs behind this row had `[obs]` requested but
    /// force-disabled (sweep cells interleave on worker threads, so their
    /// spans would mix into one process-wide stream). Surfaced as a note
    /// under the Table-1 block instead of only an eprintln at launch.
    pub obs_forced_off: bool,
}

/// Build the Table-1 row for a set of same-solver runs.
pub fn summarize(runs: &[RunResult], targets: &[f64]) -> SolverSummary {
    assert!(!runs.is_empty());
    let solver = runs[0].solver.clone();
    let mut time_to = Vec::new();
    for &t in targets {
        let hits: Vec<f64> = runs.iter().filter_map(|r| r.time_to_acc(t)).collect();
        let (m, s) = mean_std(&hits);
        time_to.push((t, m, s, hits.len()));
    }
    let last_target = *targets.last().unwrap_or(&1.0);
    let epochs: Vec<f64> =
        runs.iter().filter_map(|r| r.epochs_to_acc(last_target).map(|e| e as f64)).collect();
    let (em, es) = mean_std(&epochs);
    // Per-epoch times pooled across runs (paper: 50 epochs × 10 runs).
    let per_epoch: Vec<f64> = runs
        .iter()
        .flat_map(|r| {
            let mut prev = 0.0;
            r.records
                .iter()
                .map(move |rec| {
                    let dt = rec.wall_s - prev;
                    prev = rec.wall_s;
                    dt
                })
                .collect::<Vec<_>>()
        })
        .collect();
    let (tm, ts) = mean_std(&per_epoch);
    SolverSummary {
        solver,
        n_runs: runs.len(),
        time_to,
        epochs_to_last: (last_target, em, es),
        t_epoch_mean: tm,
        t_epoch_std: ts,
        obs_forced_off: false,
    }
}

/// Render the Table-1 style comparison block for a set of per-solver
/// summaries (one row per solver: time-to-target columns, t_epoch,
/// hit counts, epochs-to-last-target). This is the text `rkfac compare`
/// prints; it lives here so sweep callers and tests share one format.
pub fn render_table1(summaries: &[SolverSummary], targets: &[f64]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = write!(out, "{:<10} ", "solver");
    for &t in targets {
        let _ = write!(out, "t_acc>={:<6.2} ", t);
    }
    let _ = writeln!(out, "{:<14} {:<8} epochs_to_last", "t_epoch", "hits");
    for s in summaries {
        let _ = write!(out, "{:<10} ", s.solver);
        for (_, m, sd, _) in &s.time_to {
            if m.is_nan() {
                let _ = write!(out, "{:<13} ", "—");
            } else {
                let _ = write!(out, "{m:>6.1}±{sd:<5.1} ");
            }
        }
        let hits = s.time_to.last().map(|t| t.3).unwrap_or(0);
        let _ = writeln!(
            out,
            "{:>6.2}±{:<5.2} {:>2}/{:<4} {:.1}±{:.1}",
            s.t_epoch_mean, s.t_epoch_std, hits, s.n_runs, s.epochs_to_last.1, s.epochs_to_last.2
        );
    }
    if summaries.iter().any(|s| s.obs_forced_off) {
        let _ = writeln!(
            out,
            "note: [obs] was requested but disabled for these sweep cells (cells interleave \
             on worker threads; run `rkfac train --obs` on a single cell to trace it)"
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_run(solver: &str, seed: u64, accs: &[f64], dt: f64) -> RunResult {
        let records = accs
            .iter()
            .enumerate()
            .map(|(e, &acc)| EpochRecord {
                epoch: e,
                wall_s: dt * (e + 1) as f64,
                train_loss: 1.0 / (e + 1) as f64,
                test_loss: 1.2 / (e + 1) as f64,
                test_acc: acc,
                decomp_s: 0.1 * (e + 1) as f64,
            })
            .collect::<Vec<_>>();
        let total = dt * accs.len() as f64;
        RunResult {
            solver: solver.into(),
            seed,
            records,
            total_s: total,
            rank_trace: vec![],
            pipe_trace: vec![],
        }
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        let (m1, s1) = mean_std(&[5.0]);
        assert_eq!((m1, s1), (5.0, 0.0));
    }

    #[test]
    fn time_to_acc_first_crossing() {
        let r = fake_run("kfac", 0, &[0.3, 0.6, 0.8, 0.85], 10.0);
        assert_eq!(r.time_to_acc(0.6), Some(20.0));
        assert_eq!(r.epochs_to_acc(0.6), Some(2));
        assert_eq!(r.time_to_acc(0.9), None);
        assert!((r.time_per_epoch() - 10.0).abs() < 1e-12);
        assert!((r.best_acc() - 0.85).abs() < 1e-12);
    }

    #[test]
    fn summary_counts_successes() {
        let runs = vec![
            fake_run("rs-kfac", 0, &[0.5, 0.9], 5.0),
            fake_run("rs-kfac", 1, &[0.5, 0.7], 5.0),
            fake_run("rs-kfac", 2, &[0.85, 0.95], 4.0),
        ];
        let s = summarize(&runs, &[0.8, 0.9]);
        assert_eq!(s.n_runs, 3);
        assert_eq!(s.time_to[0].3, 2); // 0.8 hit by runs 0 and 2
        assert_eq!(s.time_to[1].3, 2); // 0.9 hit by runs 0 and 2
        assert!((s.t_epoch_mean - (5.0 * 4.0 + 4.0 * 2.0) / 6.0).abs() < 1e-9);
    }

    #[test]
    fn rank_trace_csv_shape() {
        let dir = std::env::temp_dir().join(format!("rkfac_ranks_{}", std::process::id()));
        let p = dir.join("ranks.csv");
        let mut r = fake_run("rs-kfac", 3, &[0.2], 1.0);
        r.rank_trace = vec![
            RankTraceRow { round: 0, epoch: 0, step: 0, block: 0, rank_a: 16, rank_g: 12 },
            RankTraceRow { round: 0, epoch: 0, step: 0, block: 1, rank_a: 12, rank_g: 10 },
            RankTraceRow { round: 1, epoch: 0, step: 5, block: 0, rank_a: 14, rank_g: 12 },
        ];
        r.write_rank_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0], "solver,seed,round,epoch,step,block,rank_a,rank_g");
        assert_eq!(lines[1], "rs-kfac,3,0,0,0,0,16,12");
        assert_eq!(lines[3], "rs-kfac,3,1,0,5,0,14,12");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pipeline_trace_csv_shape() {
        let dir = std::env::temp_dir().join(format!("rkfac_pipe_{}", std::process::id()));
        let p = dir.join("pipe.csv");
        let mut r = fake_run("rs-kfac", 5, &[0.2], 1.0);
        r.pipe_trace = vec![
            PipeTraceRow {
                round: 0,
                epoch: 0,
                step: 0,
                queue_depth: 0,
                max_queue_depth: 4,
                recovered_jobs: 0,
                superseded_jobs: 0,
                warming_slots: 2,
                max_staleness: None,
                wait_s: 0.0,
                run_s: 0.125,
            },
            PipeTraceRow {
                round: 1,
                epoch: 0,
                step: 5,
                queue_depth: 2,
                max_queue_depth: 4,
                recovered_jobs: 1,
                superseded_jobs: 2,
                warming_slots: 0,
                max_staleness: Some(3),
                wait_s: 0.5,
                run_s: 0.25,
            },
        ];
        r.write_pipeline_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("# pipeline-trace schema=2"), "{}", lines[0]);
        assert_eq!(
            lines[1],
            "solver,seed,round,epoch,step,queue_depth,max_queue_depth,recovered_jobs,\
             superseded_jobs,warming_slots,max_staleness,wait_s,run_s"
        );
        assert_eq!(lines[2], "rs-kfac,5,0,0,0,0,4,0,0,2,,0.000,0.125");
        assert_eq!(lines[3], "rs-kfac,5,1,0,5,2,4,1,2,0,3,0.500,0.250");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn table1_rendering_shape() {
        let runs_a = vec![fake_run("rs-kfac", 0, &[0.5, 0.9], 5.0)];
        let runs_b = vec![fake_run("seng", 0, &[0.4, 0.6], 7.0)];
        let targets = [0.8];
        let summaries = vec![summarize(&runs_a, &targets), summarize(&runs_b, &targets)];
        let text = render_table1(&summaries, &targets);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "{text}");
        assert!(lines[0].starts_with("solver"));
        assert!(lines[1].starts_with("rs-kfac"));
        assert!(lines[2].starts_with("seng"));
        // seng never hits 0.8 → em-dash cell.
        assert!(lines[2].contains('—'), "{text}");
        // Forced-off obs surfaces as a trailing note, not just an eprintln.
        let mut summaries = summaries;
        summaries[1].obs_forced_off = true;
        let text = render_table1(&summaries, &targets);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4, "{text}");
        assert!(lines[3].starts_with("note: [obs] was requested but disabled"), "{text}");
    }

    #[test]
    fn csv_roundtrip_shape() {
        let dir = std::env::temp_dir().join(format!("rkfac_metrics_{}", std::process::id()));
        let p = dir.join("run.csv");
        let r = fake_run("sgd", 7, &[0.2, 0.4], 1.0);
        r.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("solver,seed,epoch"));
        assert!(lines[1].starts_with("sgd,7,0,"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
