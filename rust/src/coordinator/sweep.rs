//! The [`Sweep`] runner: `{solvers × axes × seeds}` grids from one spec,
//! executed on [`parallel::run_jobs`] workers (or a shared-filesystem cell
//! board, preemptibly) and aggregated into the Table-1 [`SolverSummary`]
//! statistics in a single invocation.
//!
//! The paper's headline numbers are *comparisons* — mean ± std
//! time-to-accuracy across seeds, per solver. Before this runner that
//! required N separate CLI runs and a by-hand `summarize` call; a sweep is
//! now one object: take an [`ExperimentSpec`], widen the solver and seed
//! axes (plus any `[sweep]` config axes the spec declares), run every cell
//! (each cell is an independent, deterministic
//! [`Session`](crate::coordinator::session::Session) with its own derived
//! config), and summarize per cell group. The per-cell results are
//! bitwise-identical to running each cell by itself, whatever
//! `max_workers` is — runs share nothing but the read-only registry.
//!
//! # Config axes
//!
//! A `[sweep]` section in the experiment TOML maps ordinary config keys to
//! value lists (`pipeline.max_stale_steps = [0, 4]`). [`Sweep::cells`]
//! crosses them with the solver and seed axes; each cell's values are
//! applied through the `--set` layer
//! ([`ExperimentSpec::with_overrides`]), so a bad axis value fails with a
//! layer-citing error before any cell runs. Cells with axis overrides are
//! labeled `solver[key=value,...]` and summarized per label.
//!
//! # Preemptible remote execution
//!
//! [`Sweep::run_remote`] executes the same grid against a *cell board* — a
//! shared directory of [`wire`]-framed files any `rkfac worker` pointed at
//! the same board can work from:
//!
//! ```text
//! pending/  cell_<label>_<seed>.frame   unclaimed cells (Frame::Cell)
//! claimed/                              claim = atomic rename from pending/
//! done/     cell_<label>_<seed>.frame   manifest (Frame::CellDone + records)
//! ckpt/<cell>/                          per-epoch v2 checkpoints
//! partial/<cell>.rows                   fixed-width per-epoch record log
//! ```
//!
//! Completed cells are skipped on re-run (their `done/` manifest is the
//! authority), and a cell interrupted mid-run resumes from its latest
//! checkpoint via [`Session::resume`] — bitwise on the native engine — with
//! the already-finished epochs recovered from the partial-rows log. A
//! coordinator restart therefore costs at most one epoch of work per
//! in-flight cell.

use std::fs;
use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::config::TrainConfig;
use crate::coordinator::experiment::ExperimentSpec;
use crate::coordinator::hooks::{CheckpointHook, CsvMetricsHook, EpochCtx, HookAction, RunHook};
use crate::coordinator::metrics::{summarize, EpochRecord, RunResult, SolverSummary};
use crate::coordinator::parallel;
use crate::coordinator::session::Session;
use crate::pipeline::transport::dir::publish_file;
use crate::pipeline::transport::wire::{self, Frame};
use crate::util::codec::{ByteReader, ByteWriter};

/// A `{solvers × axes × seeds}` grid over one base spec.
pub struct Sweep {
    spec: ExperimentSpec,
    solvers: Vec<String>,
    seeds: Vec<u64>,
    max_workers: usize,
    write_csvs: bool,
}

/// One cell of the sweep grid: a solver, a seed, and the `[sweep]` axis
/// values the cell's config overrides.
#[derive(Clone, Debug)]
pub struct CellSpec {
    /// Group label for summaries: the solver spec, suffixed
    /// `[key=value,...]` when axis overrides are present.
    pub label: String,
    pub solver: String,
    pub seed: u64,
    /// Axis assignments, applied through the `--set` layer.
    pub overrides: Vec<(String, String)>,
}

/// All completed runs of a sweep (label-major, seed-minor) plus the
/// per-label Table-1 summaries. Failed cells are reported, not fatal: a
/// grid that trained for hours keeps every finished cell even if one
/// seed's run errored or panicked (summaries cover the labels with at
/// least one completed run).
pub struct SweepResult {
    pub runs: Vec<RunResult>,
    pub summaries: Vec<SolverSummary>,
    /// Cells that failed: `(label, seed, error text)`.
    pub failures: Vec<(String, u64, String)>,
}

impl SweepResult {
    pub fn summary_for(&self, solver: &str) -> Option<&SolverSummary> {
        self.summaries.iter().find(|s| s.solver == solver)
    }

    pub fn is_complete(&self) -> bool {
        self.failures.is_empty()
    }
}

impl Sweep {
    /// A 1×1 sweep over the spec's own solver and seed; widen with
    /// [`solvers`](Sweep::solvers) / [`seeds`](Sweep::seeds). `[sweep]`
    /// axes declared by the spec widen the grid automatically.
    pub fn new(spec: ExperimentSpec) -> Self {
        let solvers = vec![spec.cfg().solver.clone()];
        let seeds = vec![spec.cfg().seed];
        Sweep { spec, solvers, seeds, max_workers: 1, write_csvs: false }
    }

    /// Set the solver axis. Every spec is validated against the sweep's
    /// registry up front — a typo fails here, not after hours of runs.
    pub fn solvers<I, S>(mut self, solvers: I) -> Result<Self>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.solvers = solvers.into_iter().map(Into::into).collect();
        if self.solvers.is_empty() {
            return Err(anyhow!("sweep needs at least one solver"));
        }
        for s in &self.solvers {
            self.spec.registry().validate_spec(s).map_err(anyhow::Error::msg)?;
        }
        Ok(self)
    }

    /// Set the seed axis explicitly.
    pub fn seeds(mut self, seeds: &[u64]) -> Self {
        self.seeds = seeds.to_vec();
        self
    }

    /// Seed axis `base, base+1, …, base+n-1` from the spec's base seed —
    /// the paper's "R runs" convention.
    pub fn runs_per_solver(mut self, n: usize) -> Self {
        let base = self.spec.cfg().seed;
        self.seeds = (0..n.max(1) as u64).map(|r| base + r).collect();
        self
    }

    /// Execute up to `n` runs concurrently (default 1: sequential, which
    /// keeps wall-clock-based statistics uncontaminated on a shared box).
    pub fn max_workers(mut self, n: usize) -> Self {
        self.max_workers = n.max(1);
        self
    }

    /// Also write `cmp_<solver>_<seed>.csv` per run into the spec's
    /// `out_dir` (what `rkfac compare` has always produced).
    pub fn write_csvs(mut self, on: bool) -> Self {
        self.write_csvs = on;
        self
    }

    /// Total grid size (`solvers × axis combinations × seeds`).
    pub fn len(&self) -> usize {
        let axis: usize = self.spec.sweep_axes().iter().map(|(_, v)| v.len()).product();
        self.solvers.len() * self.seeds.len() * axis
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The full grid, label-major then seed-minor: every solver crossed
    /// with every `[sweep]` axis combination crossed with every seed.
    pub fn cells(&self) -> Vec<CellSpec> {
        let mut combos: Vec<Vec<(String, String)>> = vec![Vec::new()];
        for (key, vals) in self.spec.sweep_axes() {
            let mut next = Vec::with_capacity(combos.len() * vals.len());
            for combo in &combos {
                for v in vals {
                    let mut c = combo.clone();
                    c.push((key.clone(), v.clone()));
                    next.push(c);
                }
            }
            combos = next;
        }
        let mut out = Vec::with_capacity(self.solvers.len() * combos.len() * self.seeds.len());
        for solver in &self.solvers {
            for combo in &combos {
                let label = if combo.is_empty() {
                    solver.clone()
                } else {
                    let kvs: Vec<String> =
                        combo.iter().map(|(k, v)| format!("{k}={v}")).collect();
                    format!("{solver}[{}]", kvs.join(","))
                };
                for &seed in &self.seeds {
                    out.push(CellSpec {
                        label: label.clone(),
                        solver: solver.clone(),
                        seed,
                        overrides: combo.clone(),
                    });
                }
            }
        }
        out
    }

    /// One cell's fully-resolved config: axis overrides through the
    /// `--set` layer, then the solver/seed pinned and obs disabled (the
    /// obs streams are process-wide and cells interleave on workers).
    fn cell_cfg(&self, cell: &CellSpec) -> Result<TrainConfig> {
        let mut cfg = if cell.overrides.is_empty() {
            self.spec.cfg().clone()
        } else {
            self.spec.with_overrides(&cell.overrides)?.cfg().clone()
        };
        cfg.solver = cell.solver.clone();
        cfg.seed = cell.seed;
        cfg.obs.enabled = false;
        Ok(cfg)
    }

    /// Run the grid in-process and summarize per label against the spec's
    /// accuracy targets.
    pub fn run(&self) -> Result<SweepResult> {
        if self.seeds.is_empty() {
            return Err(anyhow!("sweep needs at least one seed"));
        }
        if self.spec.cfg().obs.enabled {
            eprintln!(
                "[rkfac] note: [obs] is process-wide and sweep cells interleave on worker \
                 threads, so their spans would mix into one stream — obs is disabled for the \
                 sweep's cells (run `rkfac train --obs` on a single cell to trace it)"
            );
        }
        let cells = self.cells();
        // Resolve every cell config up front — an invalid axis value fails
        // here, not after hours of completed cells.
        let mut jobs = Vec::with_capacity(cells.len());
        for cell in &cells {
            let cfg = self.cell_cfg(cell)?;
            let registry = self.spec.registry().clone();
            let write_csvs = self.write_csvs;
            let label = cell.label.clone();
            jobs.push(move || {
                let mut session = Session::with_registry(cfg, registry);
                if write_csvs {
                    let out_dir = session.cfg().out_dir.clone();
                    // `cmp_` series only — exactly what the legacy
                    // compare path wrote; the unprefixed trace names
                    // would collide with a train run's. The cell label
                    // (not the bare solver) names the file so axis
                    // variants of one solver don't clobber each other.
                    session.add_hook(Box::new(
                        CsvMetricsHook::new(out_dir)
                            .with_prefix("cmp")
                            .traces(false)
                            .series_label(label.clone()),
                    ));
                }
                session.run().map(|mut run| {
                    // Group results under the cell label so axis variants
                    // of one solver summarize separately.
                    run.solver = label;
                    run
                })
            });
        }
        let results: Vec<Result<RunResult, String>> = parallel::run_jobs(jobs, self.max_workers)
            .into_iter()
            .map(|r| r.map_err(|e| format!("{e:#}")))
            .collect();
        let mut result = aggregate(&cells, results, &self.spec.cfg().targets)?;
        self.mark_obs_forced_off(&mut result);
        Ok(result)
    }

    /// Execute the grid preemptibly on a shared cell board. Completed
    /// cells (a `done/` manifest frame) are skipped; interrupted cells
    /// resume from their latest checkpoint. This call first moves stale
    /// claims (from dead workers) back to `pending/` — so start it only
    /// when no worker is mid-cell — then seeds missing cells, runs cells
    /// itself until none are pending, waits for any cells other `rkfac
    /// worker` processes still hold, and aggregates every cell's manifest
    /// exactly like [`Sweep::run`]. Remote results carry the per-epoch
    /// records but not the rank/pipeline traces (those stay with the
    /// worker that produced them).
    pub fn run_remote(&self, board_dir: &str) -> Result<SweepResult> {
        if self.seeds.is_empty() {
            return Err(anyhow!("sweep needs at least one seed"));
        }
        let board = Board::new(board_dir)?;
        board.reset_claims()?;
        self.work_board(board_dir, 0)?;
        let cells = self.cells();
        let mut results = Vec::with_capacity(cells.len());
        for cell in &cells {
            let name = format!("{}.frame", cell_id(cell));
            let run = loop {
                if let Some(r) = board.done_result(&name)? {
                    break r;
                }
                if !board.dir("claimed").join(&name).exists()
                    && !board.dir("pending").join(&name).exists()
                {
                    // Re-check once: the holder may have published its
                    // manifest between our two looks.
                    if let Some(r) = board.done_result(&name)? {
                        break r;
                    }
                    bail!(
                        "cell '{name}' is neither done, pending, nor claimed on the board — \
                         its worker failed; re-run to reset and retry it"
                    );
                }
                std::thread::sleep(Duration::from_millis(50));
            };
            results.push(Ok(run));
        }
        let mut result = aggregate(&cells, results, &self.spec.cfg().targets)?;
        self.mark_obs_forced_off(&mut result);
        Ok(result)
    }

    /// Record on every summary when the cells ran with `[obs]` requested
    /// but force-disabled, so `rkfac compare` output carries the note
    /// (the launch-time eprintln alone is easy to scroll past).
    fn mark_obs_forced_off(&self, result: &mut SweepResult) {
        if self.spec.cfg().obs.enabled {
            for s in &mut result.summaries {
                s.obs_forced_off = true;
            }
        }
    }

    /// Claim-and-run loop over a shared cell board — the `rkfac worker`
    /// body. Seeds any cells missing from the board (idempotent: cells
    /// already done, claimed, or pending are left alone), then claims
    /// pending cells one at a time and runs them, resuming mid-cell from
    /// the board's checkpoints when present. `max_cells = 0` means run
    /// until no pending cell remains. Returns the number of cells this
    /// call completed. A cell that *errors* keeps its claim (so the
    /// failure is investigated, not retried in a loop); the next
    /// [`Sweep::run_remote`] resets it.
    pub fn work_board(&self, board_dir: &str, max_cells: usize) -> Result<usize> {
        let board = Board::new(board_dir)?;
        let cells = self.cells();
        board.seed_cells(&cells)?;
        let mut completed = 0usize;
        while max_cells == 0 || completed < max_cells {
            let Some(name) = board.claim_next() else { break };
            let id = name.strip_suffix(".frame").unwrap_or(&name).to_string();
            let Some(cell) = cells.iter().find(|c| cell_id(c) == id) else {
                bail!(
                    "board cell '{id}' is not in this sweep's grid — coordinator and worker \
                     must be built from the same config"
                );
            };
            let result = self
                .run_cell(&board, cell)
                .with_context(|| format!("running board cell '{id}'"))?;
            board.mark_done(&name, cell, &result)?;
            completed += 1;
        }
        Ok(completed)
    }

    /// Run one board cell: fresh, or resumed from its latest checkpoint
    /// with the earlier epochs' records recovered from the partial-rows
    /// log. Every epoch appends a row *then* checkpoints, so the rows file
    /// always covers at least the checkpointed epochs.
    fn run_cell(&self, board: &Board, cell: &CellSpec) -> Result<RunResult> {
        let cfg = self.cell_cfg(cell)?;
        let id = cell_id(cell);
        let ckpt_dir = board.dir("ckpt").join(&id);
        fs::create_dir_all(&ckpt_dir)
            .with_context(|| format!("creating '{}'", ckpt_dir.display()))?;
        let rows_path = board.dir("partial").join(format!("{id}.rows"));
        let mut session = Session::with_registry(cfg.clone(), self.spec.registry().clone());
        session.add_hook(Box::new(PartialRowsHook { path: rows_path.clone() }));
        session.add_hook(Box::new(CheckpointHook::new(
            ckpt_dir.to_string_lossy().into_owned(),
            1,
        )));
        match latest_checkpoint(&ckpt_dir, &cfg.solver, cfg.seed) {
            Some((epoch, _)) if epoch + 1 >= cfg.epochs => {
                // Interrupted after the final epoch's checkpoint but before
                // the done manifest: every record is already in the rows
                // file — nothing left to train.
                let records = read_partial_rows(&rows_path, cfg.epochs);
                if records.len() != cfg.epochs {
                    bail!(
                        "cell '{id}': final-epoch checkpoint present but only {}/{} epoch \
                         rows recovered — delete '{}' to re-run the cell from scratch",
                        records.len(),
                        cfg.epochs,
                        ckpt_dir.display()
                    );
                }
                let total_s = records.last().map(|r| r.wall_s).unwrap_or(0.0);
                Ok(RunResult {
                    solver: cfg.solver.clone(),
                    seed: cfg.seed,
                    records,
                    total_s,
                    rank_trace: Vec::new(),
                    pipe_trace: Vec::new(),
                })
            }
            Some((_, path)) => {
                let tail = session.resume(&path)?;
                let first = tail.records.first().map(|r| r.epoch).unwrap_or(cfg.epochs);
                let mut records = read_partial_rows(&rows_path, first);
                records.extend(tail.records.iter().cloned());
                Ok(RunResult { records, ..tail })
            }
            None => session.run(),
        }
    }
}

/// Group label-contiguous cell results into runs/summaries/failures —
/// shared by the in-process and board execution paths.
fn aggregate(
    cells: &[CellSpec],
    results: Vec<Result<RunResult, String>>,
    targets: &[f64],
) -> Result<SweepResult> {
    let mut runs = Vec::new();
    let mut failures = Vec::new();
    let mut summaries = Vec::new();
    let mut group: Vec<RunResult> = Vec::new();
    let mut group_label: Option<String> = None;
    for (cell, res) in cells.iter().zip(results) {
        if group_label.as_deref() != Some(cell.label.as_str()) {
            if !group.is_empty() {
                summaries.push(summarize(&group, targets));
                runs.append(&mut group);
            }
            group_label = Some(cell.label.clone());
        }
        match res {
            Ok(run) => group.push(run),
            Err(e) => failures.push((cell.label.clone(), cell.seed, e)),
        }
    }
    if !group.is_empty() {
        summaries.push(summarize(&group, targets));
        runs.append(&mut group);
    }
    if runs.is_empty() {
        if let Some((label, seed, e)) = failures.first() {
            return Err(anyhow!("every sweep cell failed; first: ({label}, seed {seed}): {e}"));
        }
        return Err(anyhow!("sweep grid is empty"));
    }
    Ok(SweepResult { runs, summaries, failures })
}

// ---------------------------------------------------------------------------
// The cell board.
// ---------------------------------------------------------------------------

/// Board-safe cell file stem: the label with every non-alphanumeric
/// character collapsed to `-`, plus the seed.
fn cell_id(cell: &CellSpec) -> String {
    let sane: String = cell
        .label
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '-' })
        .collect();
    format!("cell_{sane}_{}", cell.seed)
}

/// The shared-directory cell board (see the module docs for the layout).
struct Board {
    root: PathBuf,
}

impl Board {
    fn new(root: &str) -> Result<Board> {
        let root = PathBuf::from(root);
        for d in ["pending", "claimed", "done", "ckpt", "partial"] {
            fs::create_dir_all(root.join(d))
                .with_context(|| format!("creating board dir '{}/{d}'", root.display()))?;
        }
        Ok(Board { root })
    }

    fn dir(&self, name: &str) -> PathBuf {
        self.root.join(name)
    }

    /// Publish pending frames for cells with no board presence yet.
    /// Idempotent across coordinator and workers.
    fn seed_cells(&self, cells: &[CellSpec]) -> Result<()> {
        for cell in cells {
            let name = format!("{}.frame", cell_id(cell));
            if self.dir("done").join(&name).exists()
                || self.dir("claimed").join(&name).exists()
                || self.dir("pending").join(&name).exists()
            {
                continue;
            }
            write_frame_file(
                &self.dir("pending"),
                &name,
                &Frame::Cell {
                    label: cell.label.clone(),
                    solver: cell.solver.clone(),
                    seed: cell.seed,
                    overrides: cell.overrides.clone(),
                },
            )?;
        }
        Ok(())
    }

    /// Move stale claims back to `pending/` (a claim without a done
    /// manifest belongs to a dead worker — only call when no worker is
    /// live, i.e. at coordinator start).
    fn reset_claims(&self) -> Result<()> {
        for entry in fs::read_dir(self.dir("claimed"))
            .with_context(|| format!("scanning '{}/claimed'", self.root.display()))?
        {
            let entry = entry?;
            let name = entry.file_name();
            if self.dir("done").join(&name).exists() {
                let _ = fs::remove_file(entry.path());
            } else {
                let _ = fs::rename(entry.path(), self.dir("pending").join(&name));
            }
        }
        Ok(())
    }

    /// Claim the alphabetically-first pending cell by atomic rename into
    /// `claimed/` — exactly one contender wins each cell.
    fn claim_next(&self) -> Option<String> {
        let rd = fs::read_dir(self.dir("pending")).ok()?;
        let mut names: Vec<String> = rd
            .filter_map(|e| e.ok())
            .filter_map(|e| e.file_name().into_string().ok())
            .filter(|n| n.ends_with(".frame"))
            .collect();
        names.sort();
        for name in names {
            if fs::rename(self.dir("pending").join(&name), self.dir("claimed").join(&name))
                .is_ok()
            {
                return Some(name);
            }
        }
        None
    }

    /// Publish the cell's done manifest and release its claim.
    fn mark_done(&self, name: &str, cell: &CellSpec, result: &RunResult) -> Result<()> {
        write_frame_file(
            &self.dir("done"),
            name,
            &Frame::CellDone {
                label: cell.label.clone(),
                solver: cell.solver.clone(),
                seed: cell.seed,
                total_s: result.total_s,
                records: result.records.clone(),
            },
        )?;
        let _ = fs::remove_file(self.dir("claimed").join(name));
        Ok(())
    }

    /// Decode one done manifest into a [`RunResult`] (`None` when the cell
    /// has no manifest yet).
    fn done_result(&self, name: &str) -> Result<Option<RunResult>> {
        let path = self.dir("done").join(name);
        let bytes = match fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(anyhow!("reading '{}': {e}", path.display())),
        };
        match wire::read_frame(&mut &bytes[..])
            .map_err(|e| anyhow!("manifest '{}': {e}", path.display()))?
        {
            (Frame::CellDone { label, seed, total_s, records, .. }, _) => Ok(Some(RunResult {
                solver: label,
                seed,
                records,
                total_s,
                rank_trace: Vec::new(),
                pipe_trace: Vec::new(),
            })),
            _ => bail!("'{}' is not a CellDone frame", path.display()),
        }
    }
}

fn write_frame_file(dir: &Path, name: &str, frame: &Frame) -> Result<()> {
    let mut bytes = Vec::new();
    wire::write_frame(&mut bytes, frame)
        .map_err(|e| anyhow!("encoding board frame '{name}': {e}"))?;
    publish_file(dir, name, &bytes)
        .with_context(|| format!("publishing '{}/{name}'", dir.display()))?;
    Ok(())
}

/// Appends one fixed-width (48-byte) binary row per finished epoch — the
/// durable copy of the records a mid-cell resume cannot recover from
/// [`Session::resume`] alone (resume returns only the tail). Installed
/// *before* the checkpoint hook, so every checkpointed epoch has its row.
struct PartialRowsHook {
    path: PathBuf,
}

impl RunHook for PartialRowsHook {
    fn name(&self) -> &str {
        "sweep-partial-rows"
    }

    fn on_epoch_end(&mut self, ctx: &EpochCtx<'_>) -> Result<HookAction> {
        let mut w = ByteWriter::new();
        w.u64(ctx.record.epoch as u64);
        w.f64(ctx.record.wall_s);
        w.f64(ctx.record.train_loss);
        w.f64(ctx.record.test_loss);
        w.f64(ctx.record.test_acc);
        w.f64(ctx.record.decomp_s);
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| format!("opening partial-rows log '{}'", self.path.display()))?;
        std::io::Write::write_all(&mut f, &w.into_bytes())
            .with_context(|| format!("appending to '{}'", self.path.display()))?;
        Ok(HookAction::Continue)
    }
}

/// Parse the rows log back into records for epochs `< before_epoch`. A torn
/// trailing row (interrupt mid-append) is ignored; a duplicate epoch (crash
/// between row append and checkpoint, then re-run) keeps its first
/// occurrence — the deterministic fields are identical either way.
fn read_partial_rows(path: &Path, before_epoch: usize) -> Vec<EpochRecord> {
    let Ok(bytes) = fs::read(path) else { return Vec::new() };
    let mut out: Vec<EpochRecord> = Vec::new();
    for chunk in bytes.chunks_exact(48) {
        let mut r = ByteReader::new(chunk);
        let (Ok(epoch), Ok(wall_s), Ok(train_loss), Ok(test_loss), Ok(test_acc), Ok(decomp_s)) =
            (r.u64(), r.f64(), r.f64(), r.f64(), r.f64(), r.f64())
        else {
            break;
        };
        let epoch = epoch as usize;
        if epoch >= before_epoch || out.iter().any(|e| e.epoch == epoch) {
            continue;
        }
        out.push(EpochRecord { epoch, wall_s, train_loss, test_loss, test_acc, decomp_s });
    }
    out.sort_by_key(|r| r.epoch);
    out
}

/// The newest `ckpt_<solver>_<seed>_eNNNN.bin` under `dir`, as
/// `(epoch, path)`.
fn latest_checkpoint(dir: &Path, solver: &str, seed: u64) -> Option<(usize, PathBuf)> {
    let prefix = format!("ckpt_{solver}_{seed}_e");
    let rd = fs::read_dir(dir).ok()?;
    let mut best: Option<(usize, PathBuf)> = None;
    for e in rd.filter_map(|e| e.ok()) {
        let name = match e.file_name().into_string() {
            Ok(n) => n,
            Err(_) => continue,
        };
        let Some(rest) = name.strip_prefix(&prefix).and_then(|r| r.strip_suffix(".bin")) else {
            continue;
        };
        let Ok(epoch) = rest.parse::<usize>() else { continue };
        match &best {
            Some((b, _)) if epoch <= *b => {}
            _ => best = Some((epoch, e.path())),
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::ExperimentBuilder;

    fn tiny_spec() -> ExperimentSpec {
        ExperimentBuilder::new()
            .toml_str(
                "[model]\nkind = \"mlp\"\nwidths = [108, 32, 10]\n\
                 [data]\nkind = \"synthetic\"\nn_train = 160\nn_test = 64\nheight = 6\nwidth = 6\n\
                 [train]\nepochs = 1\nbatch = 32\ntargets = [0.15]\n",
            )
            .unwrap()
            .build()
            .unwrap()
    }

    #[test]
    fn grid_expansion_and_validation() {
        let sweep = Sweep::new(tiny_spec()).solvers(["sgd", "seng"]).unwrap().seeds(&[0, 1, 2]);
        assert_eq!(sweep.len(), 6);
        assert!(Sweep::new(tiny_spec()).solvers(["not-a-solver"]).is_err());
        assert!(Sweep::new(tiny_spec()).solvers(Vec::<String>::new()).is_err());
        // An empty seed axis is a Result error at run(), not a panic in
        // summarize.
        assert!(Sweep::new(tiny_spec()).seeds(&[]).run().is_err());
    }

    #[test]
    fn runs_per_solver_derives_seeds_from_base() {
        let sweep = Sweep::new(tiny_spec()).runs_per_solver(3);
        assert_eq!(sweep.seeds, vec![0, 1, 2]);
    }

    /// `[sweep]` axes widen the grid: labels carry the axis values, cells
    /// are label-contiguous (what `aggregate` groups on), and `len()`
    /// counts the full cross product.
    #[test]
    fn cells_expand_axes_with_labels() {
        let spec = ExperimentBuilder::new()
            .toml_str(
                "[model]\nkind = \"mlp\"\nwidths = [108, 32, 10]\n\
                 [data]\nkind = \"synthetic\"\nn_train = 160\nn_test = 64\nheight = 6\nwidth = 6\n\
                 [train]\nepochs = 1\nbatch = 32\ntargets = [0.15]\n\
                 [sweep]\ntrain.batch = [16, 32]\n",
            )
            .unwrap()
            .build()
            .unwrap();
        let sweep = Sweep::new(spec).solvers(["sgd"]).unwrap().seeds(&[0, 1]);
        assert_eq!(sweep.len(), 4);
        let cells = sweep.cells();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].label, "sgd[train.batch=16]");
        assert_eq!((cells[0].seed, cells[1].seed), (0, 1));
        assert_eq!(cells[2].label, "sgd[train.batch=32]");
        assert_eq!(cells[2].overrides, vec![("train.batch".to_string(), "32".to_string())]);
        // Cell ids are filesystem-safe and unique.
        assert_eq!(cell_id(&cells[0]), "cell_sgd-train-batch-16-_0");
        let mut ids: Vec<String> = cells.iter().map(cell_id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 4);
    }

    /// An axis-widened run groups summaries per label and applies each
    /// cell's overrides for real (batch 16 vs 32 produce different
    /// trajectories from the same spec).
    #[test]
    fn run_expands_axes_and_summarizes_per_label() {
        let spec = ExperimentBuilder::new()
            .toml_str(
                "[model]\nkind = \"mlp\"\nwidths = [108, 32, 10]\n\
                 [data]\nkind = \"synthetic\"\nn_train = 160\nn_test = 64\nheight = 6\nwidth = 6\n\
                 [train]\nepochs = 1\nbatch = 32\ntargets = [0.15]\n\
                 [sweep]\ntrain.batch = [16, 32]\n",
            )
            .unwrap()
            .build()
            .unwrap();
        let result = Sweep::new(spec).solvers(["sgd"]).unwrap().seeds(&[0]).run().unwrap();
        assert_eq!(result.runs.len(), 2);
        assert_eq!(result.summaries.len(), 2, "one summary per axis value");
        assert_eq!(result.summaries[0].solver, "sgd[train.batch=16]");
        assert_eq!(result.summaries[1].solver, "sgd[train.batch=32]");
        assert!(result.summary_for("sgd[train.batch=16]").is_some());
        assert_ne!(
            result.runs[0].records[0].train_loss, result.runs[1].records[0].train_loss,
            "different batch sizes must produce different trajectories"
        );
    }

    /// Axis variants of one solver at the same seed write distinct
    /// `cmp_<label>_<seed>.csv` files — the label carries the axis
    /// suffix, so two cells can no longer clobber one file. Without axes
    /// the label equals the solver name (legacy names pinned above by
    /// `cells_expand_axes_with_labels`).
    #[test]
    fn axis_cells_write_distinct_csvs() {
        let dir = std::env::temp_dir().join(format!("rkfac_cmpcsv_{}", std::process::id()));
        let spec = ExperimentBuilder::new()
            .toml_str(
                "[model]\nkind = \"mlp\"\nwidths = [108, 32, 10]\n\
                 [data]\nkind = \"synthetic\"\nn_train = 160\nn_test = 64\nheight = 6\nwidth = 6\n\
                 [train]\nepochs = 1\nbatch = 32\ntargets = [0.15]\n\
                 [sweep]\ntrain.batch = [16, 32]\n",
            )
            .unwrap()
            .set("train.out_dir", dir.to_str().unwrap())
            .build()
            .unwrap();
        Sweep::new(spec).solvers(["sgd"]).unwrap().seeds(&[0]).write_csvs(true).run().unwrap();
        assert!(dir.join("cmp_sgd[train.batch=16]_0.csv").exists());
        assert!(dir.join("cmp_sgd[train.batch=32]_0.csv").exists());
        assert!(!dir.join("cmp_sgd_0.csv").exists(), "bare-solver name must not be written");
        fs::remove_dir_all(&dir).ok();
    }

    /// A failing cell is reported per (solver, seed) and does not discard
    /// the completed cells.
    #[test]
    fn sweep_keeps_completed_cells_on_partial_failure() {
        use crate::coordinator::experiment::ExperimentBuilder;
        // A family whose factory refuses seed 1 — every other cell runs.
        let spec = ExperimentBuilder::new()
            .toml_str(
                "[model]\nkind = \"mlp\"\nwidths = [108, 32, 10]\n\
                 [data]\nkind = \"synthetic\"\nn_train = 160\nn_test = 64\nheight = 6\nwidth = 6\n\
                 [train]\nepochs = 1\nbatch = 32\ntargets = [0.15]\n\
                 [registry]\nextensions = [\"flaky\"]\n",
            )
            .unwrap()
            .extension("flaky", |reg| {
                reg.register_family("flaky", |ctx| {
                    if ctx.seed == 1 {
                        return Err("flaky family refuses seed 1".into());
                    }
                    Ok(Box::new(crate::optim::SgdOptimizer::new(
                        crate::optim::SgdConfig::default(),
                        ctx.dims.len(),
                    )) as Box<dyn crate::optim::Preconditioner>)
                });
            })
            .build()
            .unwrap();
        let result =
            Sweep::new(spec).solvers(["flaky", "sgd"]).unwrap().seeds(&[0, 1]).run().unwrap();
        assert_eq!(result.runs.len(), 3, "three cells completed");
        assert_eq!(result.failures.len(), 1);
        assert!(!result.is_complete());
        let (solver, seed, err) = &result.failures[0];
        assert_eq!((solver.as_str(), *seed), ("flaky", 1));
        assert!(err.contains("refuses seed 1"), "{err}");
        // Both solvers still summarize (flaky over its one surviving run).
        assert_eq!(result.summaries.len(), 2);
        assert_eq!(result.summary_for("flaky").unwrap().n_runs, 1);
        assert_eq!(result.summary_for("sgd").unwrap().n_runs, 2);
    }

    #[test]
    fn sweep_produces_one_summary_per_solver() {
        let result =
            Sweep::new(tiny_spec()).solvers(["sgd", "seng"]).unwrap().seeds(&[0, 1]).run().unwrap();
        assert_eq!(result.runs.len(), 4);
        assert_eq!(result.summaries.len(), 2);
        assert_eq!(result.summaries[0].solver, "sgd");
        assert_eq!(result.summaries[1].solver, "seng");
        for s in &result.summaries {
            assert_eq!(s.n_runs, 2);
        }
        assert!(result.summary_for("seng").is_some());
        assert!(result.summary_for("kfac").is_none());
        // Solver-major layout: runs[0..2] = sgd seeds 0,1.
        assert_eq!((&*result.runs[0].solver, result.runs[0].seed), ("sgd", 0));
        assert_eq!((&*result.runs[3].solver, result.runs[3].seed), ("seng", 1));
    }

    /// Torn tails and duplicate epochs in the rows log are handled: the
    /// reader keeps one record per epoch below the cutoff and ignores a
    /// partial trailing row.
    #[test]
    fn partial_rows_roundtrip_tolerates_torn_tail() {
        let dir =
            std::env::temp_dir().join(format!("rkfac_rows_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.rows");
        let rec = |epoch, wall_s| EpochRecord {
            epoch,
            wall_s,
            train_loss: 0.5,
            test_loss: 0.6,
            test_acc: 0.2,
            decomp_s: 0.1,
        };
        let mut hook = PartialRowsHook { path: path.clone() };
        let rng = crate::linalg::Pcg64::with_stream(0, 0);
        for r in [rec(0, 1.0), rec(1, 2.0), rec(1, 2.5)] {
            // Duplicate epoch 1 simulates a crash between row and ckpt.
            hook.on_epoch_end(&EpochCtx {
                epoch: r.epoch,
                step: 0,
                record: &r,
                solver: &crate::optim::SgdOptimizer::new(Default::default(), 1),
                net: None,
                data_rng: &rng,
            })
            .unwrap();
        }
        // Torn tail: an interrupted append.
        {
            use std::io::Write as _;
            let mut f = fs::OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(&[0u8; 13]).unwrap();
        }
        let rows = read_partial_rows(&path, 2);
        assert_eq!(rows.len(), 2);
        assert_eq!((rows[0].epoch, rows[1].epoch), (0, 1));
        assert_eq!(rows[1].wall_s, 2.0, "first occurrence of a duplicate epoch wins");
        assert!(read_partial_rows(&path, 1).len() == 1, "cutoff filters epochs");
        fs::remove_dir_all(&dir).ok();
    }

    /// Mid-cell preemption: a worker dies after epoch 0 of 2, leaving a
    /// claim, a checkpoint, and one partial row. `run_remote` resets the
    /// claim, resumes the cell from the checkpoint, merges the recovered
    /// epoch-0 record, and the result matches the uninterrupted sweep on
    /// every deterministic field.
    #[test]
    fn run_remote_resumes_interrupted_cell_bitwise() {
        struct StopAfterEpoch(usize);
        impl RunHook for StopAfterEpoch {
            fn name(&self) -> &str {
                "stop-after"
            }
            fn on_epoch_end(&mut self, ctx: &EpochCtx<'_>) -> Result<HookAction> {
                Ok(if ctx.epoch >= self.0 { HookAction::Stop } else { HookAction::Continue })
            }
        }

        let spec = || {
            ExperimentBuilder::new()
                .toml_str(
                    "[model]\nkind = \"mlp\"\nwidths = [108, 32, 10]\n\
                     [data]\nkind = \"synthetic\"\nn_train = 160\nn_test = 64\n\
                     height = 6\nwidth = 6\n\
                     [train]\nsolver = \"rs-kfac\"\nepochs = 2\nbatch = 32\n\
                     seed = 1\ntargets = [0.15]\n",
                )
                .unwrap()
                .build()
                .unwrap()
        };
        let board_dir =
            std::env::temp_dir().join(format!("rkfac_board_resume_{}", std::process::id()));
        let _ = fs::remove_dir_all(&board_dir);
        let board_str = board_dir.to_str().unwrap().to_string();

        let uninterrupted = Sweep::new(spec()).run().unwrap();

        // Simulate a preempted worker: claim the cell, train one epoch with
        // the board's hooks, die without publishing a manifest.
        let sweep = Sweep::new(spec());
        let cells = sweep.cells();
        assert_eq!(cells.len(), 1);
        let board = Board::new(&board_str).unwrap();
        board.seed_cells(&cells).unwrap();
        let name = board.claim_next().unwrap();
        {
            let id = cell_id(&cells[0]);
            let ckpt_dir = board.dir("ckpt").join(&id);
            fs::create_dir_all(&ckpt_dir).unwrap();
            let cfg = sweep.cell_cfg(&cells[0]).unwrap();
            let mut session = Session::with_registry(cfg, sweep.spec.registry().clone());
            session.add_hook(Box::new(PartialRowsHook {
                path: board.dir("partial").join(format!("{id}.rows")),
            }));
            session.add_hook(Box::new(CheckpointHook::new(
                ckpt_dir.to_string_lossy().into_owned(),
                1,
            )));
            session.add_hook(Box::new(StopAfterEpoch(0)));
            let partial = session.run().unwrap();
            assert_eq!(partial.records.len(), 1, "died after epoch 0");
        }
        assert!(board.dir("claimed").join(&name).exists(), "claim left behind");
        assert!(!board.dir("done").join(&name).exists());

        // The coordinator re-runs the sweep: claim reset, cell resumed.
        let result = sweep.run_remote(&board_str).unwrap();
        assert!(result.is_complete());
        assert_eq!(result.runs.len(), 1);
        let (got, want) = (&result.runs[0], &uninterrupted.runs[0]);
        assert_eq!(got.records.len(), 2, "epoch 0 recovered + epoch 1 resumed");
        for (g, w) in got.records.iter().zip(want.records.iter()) {
            assert_eq!(g.epoch, w.epoch);
            assert_eq!(g.train_loss, w.train_loss, "epoch {}", g.epoch);
            assert_eq!(g.test_loss, w.test_loss, "epoch {}", g.epoch);
            assert_eq!(g.test_acc, w.test_acc, "epoch {}", g.epoch);
        }
        assert!(board.dir("done").join(&name).exists());
        fs::remove_dir_all(&board_dir).ok();
    }
}
