//! The [`Sweep`] runner: `{solvers × seeds}` grids from one spec, executed
//! on [`parallel::run_jobs`] workers and aggregated into the Table-1
//! [`SolverSummary`] statistics in a single invocation.
//!
//! The paper's headline numbers are *comparisons* — mean ± std
//! time-to-accuracy across seeds, per solver. Before this runner that
//! required N separate CLI runs and a by-hand `summarize` call; a sweep is
//! now one object: take an [`ExperimentSpec`], widen the solver and seed
//! axes, run every cell (each cell is an independent, deterministic
//! [`Session`](crate::coordinator::session::Session) with its own derived
//! config), and summarize per solver. The
//! per-cell results are bitwise-identical to running each cell by itself,
//! whatever `max_workers` is — runs share nothing but the read-only
//! registry.

use anyhow::{anyhow, Result};

use crate::coordinator::experiment::ExperimentSpec;
use crate::coordinator::hooks::CsvMetricsHook;
use crate::coordinator::metrics::{summarize, RunResult, SolverSummary};
use crate::coordinator::parallel;

/// A `{solvers × seeds}` grid over one base spec.
pub struct Sweep {
    spec: ExperimentSpec,
    solvers: Vec<String>,
    seeds: Vec<u64>,
    max_workers: usize,
    write_csvs: bool,
}

/// All completed runs of a sweep (solver-major, seed-minor) plus the
/// per-solver Table-1 summaries. Failed cells are reported, not fatal: a
/// grid that trained for hours keeps every finished cell even if one
/// seed's run errored or panicked (summaries cover the solvers with at
/// least one completed run).
pub struct SweepResult {
    pub runs: Vec<RunResult>,
    pub summaries: Vec<SolverSummary>,
    /// Cells that failed: `(solver, seed, error text)`.
    pub failures: Vec<(String, u64, String)>,
}

impl SweepResult {
    pub fn summary_for(&self, solver: &str) -> Option<&SolverSummary> {
        self.summaries.iter().find(|s| s.solver == solver)
    }

    pub fn is_complete(&self) -> bool {
        self.failures.is_empty()
    }
}

impl Sweep {
    /// A 1×1 sweep over the spec's own solver and seed; widen with
    /// [`solvers`](Sweep::solvers) / [`seeds`](Sweep::seeds).
    pub fn new(spec: ExperimentSpec) -> Self {
        let solvers = vec![spec.cfg().solver.clone()];
        let seeds = vec![spec.cfg().seed];
        Sweep { spec, solvers, seeds, max_workers: 1, write_csvs: false }
    }

    /// Set the solver axis. Every spec is validated against the sweep's
    /// registry up front — a typo fails here, not after hours of runs.
    pub fn solvers<I, S>(mut self, solvers: I) -> Result<Self>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.solvers = solvers.into_iter().map(Into::into).collect();
        if self.solvers.is_empty() {
            return Err(anyhow!("sweep needs at least one solver"));
        }
        for s in &self.solvers {
            self.spec.registry().validate_spec(s).map_err(anyhow::Error::msg)?;
        }
        Ok(self)
    }

    /// Set the seed axis explicitly.
    pub fn seeds(mut self, seeds: &[u64]) -> Self {
        self.seeds = seeds.to_vec();
        self
    }

    /// Seed axis `base, base+1, …, base+n-1` from the spec's base seed —
    /// the paper's "R runs" convention.
    pub fn runs_per_solver(mut self, n: usize) -> Self {
        let base = self.spec.cfg().seed;
        self.seeds = (0..n.max(1) as u64).map(|r| base + r).collect();
        self
    }

    /// Execute up to `n` runs concurrently (default 1: sequential, which
    /// keeps wall-clock-based statistics uncontaminated on a shared box).
    pub fn max_workers(mut self, n: usize) -> Self {
        self.max_workers = n.max(1);
        self
    }

    /// Also write `cmp_<solver>_<seed>.csv` per run into the spec's
    /// `out_dir` (what `rkfac compare` has always produced).
    pub fn write_csvs(mut self, on: bool) -> Self {
        self.write_csvs = on;
        self
    }

    /// Total grid size.
    pub fn len(&self) -> usize {
        self.solvers.len() * self.seeds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Run the grid and summarize per solver against the spec's accuracy
    /// targets.
    pub fn run(&self) -> Result<SweepResult> {
        if self.seeds.is_empty() {
            return Err(anyhow!("sweep needs at least one seed"));
        }
        if self.spec.cfg().obs.enabled {
            eprintln!(
                "[rkfac] note: [obs] is process-wide and sweep cells interleave on worker \
                 threads, so their spans would mix into one stream — obs is disabled for the \
                 sweep's cells (run `rkfac train --obs` on a single cell to trace it)"
            );
        }
        let mut jobs = Vec::with_capacity(self.len());
        for solver in &self.solvers {
            for &seed in &self.seeds {
                let mut cfg = self.spec.cfg().clone();
                cfg.solver = solver.clone();
                cfg.seed = seed;
                cfg.obs.enabled = false;
                let registry = self.spec.registry().clone();
                let write_csvs = self.write_csvs;
                jobs.push(move || {
                    let mut session =
                        crate::coordinator::session::Session::with_registry(cfg, registry);
                    if write_csvs {
                        let out_dir = session.cfg().out_dir.clone();
                        // `cmp_` series only — exactly what the legacy
                        // compare path wrote; the unprefixed trace names
                        // would collide with a train run's.
                        session.add_hook(Box::new(
                            CsvMetricsHook::new(out_dir).with_prefix("cmp").traces(false),
                        ));
                    }
                    session.run()
                });
            }
        }
        let mut results = parallel::run_jobs(jobs, self.max_workers).into_iter();
        let targets = &self.spec.cfg().targets;
        let mut runs = Vec::new();
        let mut failures = Vec::new();
        let mut summaries = Vec::new();
        for solver in &self.solvers {
            let mut group = Vec::new();
            for &seed in &self.seeds {
                match results.next().expect("run_jobs returns one result per job") {
                    Ok(run) => group.push(run),
                    Err(e) => failures.push((solver.clone(), seed, format!("{e:#}"))),
                }
            }
            if !group.is_empty() {
                summaries.push(summarize(&group, targets));
            }
            runs.extend(group);
        }
        if runs.is_empty() {
            let (solver, seed, e) = &failures[0];
            return Err(anyhow!(
                "every sweep cell failed; first: ({solver}, seed {seed}): {e}"
            ));
        }
        Ok(SweepResult { runs, summaries, failures })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::ExperimentBuilder;

    fn tiny_spec() -> ExperimentSpec {
        ExperimentBuilder::new()
            .toml_str(
                "[model]\nkind = \"mlp\"\nwidths = [108, 32, 10]\n\
                 [data]\nkind = \"synthetic\"\nn_train = 160\nn_test = 64\nheight = 6\nwidth = 6\n\
                 [train]\nepochs = 1\nbatch = 32\ntargets = [0.15]\n",
            )
            .unwrap()
            .build()
            .unwrap()
    }

    #[test]
    fn grid_expansion_and_validation() {
        let sweep = Sweep::new(tiny_spec()).solvers(["sgd", "seng"]).unwrap().seeds(&[0, 1, 2]);
        assert_eq!(sweep.len(), 6);
        assert!(Sweep::new(tiny_spec()).solvers(["not-a-solver"]).is_err());
        assert!(Sweep::new(tiny_spec()).solvers(Vec::<String>::new()).is_err());
        // An empty seed axis is a Result error at run(), not a panic in
        // summarize.
        assert!(Sweep::new(tiny_spec()).seeds(&[]).run().is_err());
    }

    #[test]
    fn runs_per_solver_derives_seeds_from_base() {
        let sweep = Sweep::new(tiny_spec()).runs_per_solver(3);
        assert_eq!(sweep.seeds, vec![0, 1, 2]);
    }

    /// A failing cell is reported per (solver, seed) and does not discard
    /// the completed cells.
    #[test]
    fn sweep_keeps_completed_cells_on_partial_failure() {
        use crate::coordinator::experiment::ExperimentBuilder;
        // A family whose factory refuses seed 1 — every other cell runs.
        let spec = ExperimentBuilder::new()
            .toml_str(
                "[model]\nkind = \"mlp\"\nwidths = [108, 32, 10]\n\
                 [data]\nkind = \"synthetic\"\nn_train = 160\nn_test = 64\nheight = 6\nwidth = 6\n\
                 [train]\nepochs = 1\nbatch = 32\ntargets = [0.15]\n\
                 [registry]\nextensions = [\"flaky\"]\n",
            )
            .unwrap()
            .extension("flaky", |reg| {
                reg.register_family("flaky", |ctx| {
                    if ctx.seed == 1 {
                        return Err("flaky family refuses seed 1".into());
                    }
                    Ok(Box::new(crate::optim::SgdOptimizer::new(
                        crate::optim::SgdConfig::default(),
                        ctx.dims.len(),
                    )) as Box<dyn crate::optim::Preconditioner>)
                });
            })
            .build()
            .unwrap();
        let result =
            Sweep::new(spec).solvers(["flaky", "sgd"]).unwrap().seeds(&[0, 1]).run().unwrap();
        assert_eq!(result.runs.len(), 3, "three cells completed");
        assert_eq!(result.failures.len(), 1);
        assert!(!result.is_complete());
        let (solver, seed, err) = &result.failures[0];
        assert_eq!((solver.as_str(), *seed), ("flaky", 1));
        assert!(err.contains("refuses seed 1"), "{err}");
        // Both solvers still summarize (flaky over its one surviving run).
        assert_eq!(result.summaries.len(), 2);
        assert_eq!(result.summary_for("flaky").unwrap().n_runs, 1);
        assert_eq!(result.summary_for("sgd").unwrap().n_runs, 2);
    }

    #[test]
    fn sweep_produces_one_summary_per_solver() {
        let result =
            Sweep::new(tiny_spec()).solvers(["sgd", "seng"]).unwrap().seeds(&[0, 1]).run().unwrap();
        assert_eq!(result.runs.len(), 4);
        assert_eq!(result.summaries.len(), 2);
        assert_eq!(result.summaries[0].solver, "sgd");
        assert_eq!(result.summaries[1].solver, "seng");
        for s in &result.summaries {
            assert_eq!(s.n_runs, 2);
        }
        assert!(result.summary_for("seng").is_some());
        assert!(result.summary_for("kfac").is_none());
        // Solver-major layout: runs[0..2] = sgd seeds 0,1.
        assert_eq!((&*result.runs[0].solver, result.runs[0].seed), ("sgd", 0));
        assert_eq!((&*result.runs[3].solver, result.runs[3].seed), ("seng", 1));
    }
}
