//! Parallel execution substrate: data-parallel gradient workers and the
//! run-level job pool the sweep runner schedules on.
//!
//! [`WorkerPool`] is Megatron-style synchronous data parallelism, scaled
//! to this testbed: the leader broadcasts parameters, each worker owns a
//! model replica and computes gradients + K-factor gram contributions on
//! its batch shard, and the leader averages (allreduce) before the solver
//! step. On a 1-core box this adds no speed — it exists so the
//! coordinator's topology, and the gradient-equivalence invariant, are
//! real and tested. Restriction: MLP models (BatchNorm statistics do not
//! average across shards; the paper's solvers treat BN outside the
//! Kronecker blocks).
//!
//! [`run_jobs`] is the coarser axis: independent, order-preserving jobs
//! (whole training runs in a [`Sweep`](crate::coordinator::sweep::Sweep))
//! pulled from a shared queue by up to `max_workers` scoped threads. Each
//! job is deterministic given its own seed, so the result vector is
//! identical whatever the interleaving.

use std::collections::VecDeque;
use std::sync::{mpsc, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::linalg::{gemm, Matrix};
use crate::nn::models;

/// Run one job with panic isolation: a panicking job becomes an `Err` in
/// its own slot instead of tearing down the whole grid (mirroring the
/// refresh pipeline's worker-panic recovery contract).
fn run_caught<T, F: FnOnce() -> Result<T>>(job: F) -> Result<T> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)) {
        Ok(r) => r,
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".into());
            Err(anyhow!("job panicked: {msg}"))
        }
    }
}

/// Run independent jobs on at most `max_workers` threads, returning the
/// results in job order (a panicking job yields an `Err` in its slot, it
/// does not abort the others). `max_workers <= 1` degenerates to
/// sequential in-place execution (no threads spawned) — the default for
/// sweeps, since concurrent runs on a shared box would contaminate each
/// other's wall-clock timings.
pub fn run_jobs<T, F>(jobs: Vec<F>, max_workers: usize) -> Vec<Result<T>>
where
    T: Send,
    F: FnOnce() -> Result<T> + Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = max_workers.max(1).min(n);
    if workers == 1 {
        return jobs.into_iter().map(run_caught).collect();
    }
    let queue: Mutex<VecDeque<(usize, F)>> = Mutex::new(jobs.into_iter().enumerate().collect());
    let (tx, rx) = mpsc::channel::<(usize, Result<T>)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let queue = &queue;
            scope.spawn(move || loop {
                let job = queue.lock().unwrap().pop_front();
                match job {
                    Some((i, f)) => {
                        if tx.send((i, run_caught(f))).is_err() {
                            break;
                        }
                    }
                    None => break,
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<Result<T>>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter()
            .map(|slot| slot.expect("run_jobs: worker exited without reporting its job"))
            .collect()
    })
}

/// Per-shard worker output: loss, per-block grads, per-block gram sums.
pub struct ShardGrad {
    pub loss: f64,
    pub shard_size: usize,
    pub grads: Vec<Matrix>,
    /// Σ A Aᵀ over the shard (unnormalized).
    pub a_grams: Vec<Matrix>,
    /// Σ G Gᵀ over the shard (unnormalized, G in per-sample scale).
    pub g_grams: Vec<Matrix>,
}

/// Synchronous data-parallel gradient pool over MLP replicas.
pub struct WorkerPool {
    pub widths: Vec<usize>,
    pub n_workers: usize,
    seed: u64,
}

impl WorkerPool {
    pub fn new(widths: Vec<usize>, n_workers: usize, seed: u64) -> Result<Self> {
        if n_workers == 0 {
            bail!("WorkerPool: need at least one worker");
        }
        Ok(WorkerPool { widths, n_workers, seed })
    }

    /// Compute gradients for one global batch split evenly across workers.
    /// `state` is the broadcast parameter vector; shards must be equal-size
    /// for exact mean-gradient equivalence.
    pub fn compute(
        &self,
        state: &[f64],
        x: &Matrix,
        labels: &[usize],
    ) -> Result<ShardGrad> {
        let b = labels.len();
        if b % self.n_workers != 0 {
            bail!("batch {b} not divisible by {} workers", self.n_workers);
        }
        let shard = b / self.n_workers;
        let (tx, rx) = mpsc::channel::<(usize, ShardGrad)>();
        std::thread::scope(|scope| {
            for w in 0..self.n_workers {
                let tx = tx.clone();
                let widths = self.widths.clone();
                let seed = self.seed;
                let xs = x.slice(0, x.rows(), w * shard, (w + 1) * shard);
                let ys = labels[w * shard..(w + 1) * shard].to_vec();
                let state = state.to_vec();
                scope.spawn(move || {
                    let mut net = models::mlp(&widths, seed);
                    net.load_state_vector(&state);
                    let (loss, _) = net.train_batch(&xs, &ys, true);
                    let caps = net.kfac_captures();
                    let grads: Vec<Matrix> = caps.iter().map(|c| c.grad.clone()).collect();
                    let a_grams: Vec<Matrix> = caps.iter().map(|c| gemm::syrk(c.a)).collect();
                    // G captures are per-sample-scale already (G = B·dL/dz
                    // with mean loss), so the gram sum is shard-invariant.
                    let g_grams: Vec<Matrix> = caps.iter().map(|c| gemm::syrk(c.g)).collect();
                    let _ = tx.send((
                        w,
                        ShardGrad { loss, shard_size: shard, grads, a_grams, g_grams },
                    ));
                });
            }
        });
        drop(tx);
        // Allreduce: average grads/losses, sum grams.
        let mut acc: Option<ShardGrad> = None;
        for (_, sg) in rx {
            acc = Some(match acc {
                None => sg,
                Some(mut a) => {
                    a.loss += sg.loss;
                    for (dst, src) in a.grads.iter_mut().zip(sg.grads.iter()) {
                        *dst += src;
                    }
                    for (dst, src) in a.a_grams.iter_mut().zip(sg.a_grams.iter()) {
                        *dst += src;
                    }
                    for (dst, src) in a.g_grams.iter_mut().zip(sg.g_grams.iter()) {
                        *dst += src;
                    }
                    a.shard_size += sg.shard_size;
                    a
                }
            });
        }
        let mut out = acc.expect("no worker output");
        let k = self.n_workers as f64;
        out.loss /= k;
        for g in &mut out.grads {
            g.scale_inplace(1.0 / k);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Pcg64;

    #[test]
    fn two_workers_match_single_worker_grads() {
        let widths = vec![12, 8, 10];
        let mut rng = Pcg64::new(1);
        let x = rng.gaussian_matrix(12, 8);
        let labels: Vec<usize> = (0..8).map(|i| i % 10).collect();
        let net = models::mlp(&widths, 7);
        let state = net.state_vector();

        let single = WorkerPool::new(widths.clone(), 1, 7).unwrap();
        let multi = WorkerPool::new(widths.clone(), 2, 7).unwrap();
        let g1 = single.compute(&state, &x, &labels).unwrap();
        let g2 = multi.compute(&state, &x, &labels).unwrap();
        assert!((g1.loss - g2.loss).abs() < 1e-12, "{} vs {}", g1.loss, g2.loss);
        for (a, b) in g1.grads.iter().zip(g2.grads.iter()) {
            assert!(a.rel_err(b) < 1e-12);
        }
        // Grams are sums → identical regardless of sharding.
        for (a, b) in g1.a_grams.iter().zip(g2.a_grams.iter()) {
            assert!(a.rel_err(b) < 1e-12);
        }
        for (a, b) in g1.g_grams.iter().zip(g2.g_grams.iter()) {
            assert!(a.rel_err(b) < 1e-10);
        }
    }

    #[test]
    fn four_workers_also_match() {
        let widths = vec![6, 5, 10];
        let mut rng = Pcg64::new(2);
        let x = rng.gaussian_matrix(6, 16);
        let labels: Vec<usize> = (0..16).map(|i| i % 10).collect();
        let state = models::mlp(&widths, 3).state_vector();
        let g1 = WorkerPool::new(widths.clone(), 1, 3).unwrap().compute(&state, &x, &labels).unwrap();
        let g4 = WorkerPool::new(widths, 4, 3).unwrap().compute(&state, &x, &labels).unwrap();
        for (a, b) in g1.grads.iter().zip(g4.grads.iter()) {
            assert!(a.rel_err(b) < 1e-12);
        }
    }

    #[test]
    fn indivisible_batch_rejected() {
        let widths = vec![4, 10];
        let pool = WorkerPool::new(widths.clone(), 3, 1).unwrap();
        let state = models::mlp(&widths, 1).state_vector();
        let x = Matrix::zeros(4, 8);
        assert!(pool.compute(&state, &x, &[0; 8]).is_err());
    }

    #[test]
    fn run_jobs_preserves_order_and_errors() {
        for workers in [1, 3, 16] {
            let jobs: Vec<_> = (0..7)
                .map(|i| move || if i == 3 { bail!("job {i} failed") } else { Ok(i * 10) })
                .collect();
            let out = run_jobs(jobs, workers);
            assert_eq!(out.len(), 7);
            for (i, r) in out.iter().enumerate() {
                if i == 3 {
                    assert!(r.is_err(), "workers={workers}");
                } else {
                    assert_eq!(*r.as_ref().unwrap(), i * 10, "workers={workers}");
                }
            }
        }
        assert!(run_jobs(Vec::<fn() -> Result<u8>>::new(), 4).is_empty());
    }

    #[test]
    fn run_jobs_isolates_panicking_jobs() {
        for workers in [1, 4] {
            let jobs: Vec<_> = (0..4)
                .map(|i| {
                    move || {
                        if i == 2 {
                            panic!("boom {i}");
                        }
                        Ok(i)
                    }
                })
                .collect();
            let out = run_jobs(jobs, workers);
            assert_eq!(out.len(), 4, "workers={workers}");
            let err = out[2].as_ref().unwrap_err().to_string();
            assert!(err.contains("panicked") && err.contains("boom 2"), "{err}");
            assert_eq!(*out[0].as_ref().unwrap(), 0);
            assert_eq!(*out[3].as_ref().unwrap(), 3);
        }
    }
}
