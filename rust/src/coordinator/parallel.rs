//! Data-parallel gradient computation (std::thread workers + allreduce).
//!
//! Megatron-style synchronous data parallelism, scaled to this testbed:
//! the leader broadcasts parameters, each worker owns a model replica and
//! computes gradients + K-factor gram contributions on its batch shard, and
//! the leader averages (allreduce) before the solver step. On a 1-core box
//! this adds no speed — it exists so the coordinator's topology, and the
//! gradient-equivalence invariant, are real and tested.
//!
//! Restriction: MLP models (BatchNorm statistics do not average across
//! shards; the paper's solvers treat BN outside the Kronecker blocks).

use std::sync::mpsc;

use anyhow::{bail, Result};

use crate::linalg::{gemm, Matrix};
use crate::nn::models;

/// Per-shard worker output: loss, per-block grads, per-block gram sums.
pub struct ShardGrad {
    pub loss: f64,
    pub shard_size: usize,
    pub grads: Vec<Matrix>,
    /// Σ A Aᵀ over the shard (unnormalized).
    pub a_grams: Vec<Matrix>,
    /// Σ G Gᵀ over the shard (unnormalized, G in per-sample scale).
    pub g_grams: Vec<Matrix>,
}

/// Synchronous data-parallel gradient pool over MLP replicas.
pub struct WorkerPool {
    pub widths: Vec<usize>,
    pub n_workers: usize,
    seed: u64,
}

impl WorkerPool {
    pub fn new(widths: Vec<usize>, n_workers: usize, seed: u64) -> Result<Self> {
        if n_workers == 0 {
            bail!("WorkerPool: need at least one worker");
        }
        Ok(WorkerPool { widths, n_workers, seed })
    }

    /// Compute gradients for one global batch split evenly across workers.
    /// `state` is the broadcast parameter vector; shards must be equal-size
    /// for exact mean-gradient equivalence.
    pub fn compute(
        &self,
        state: &[f64],
        x: &Matrix,
        labels: &[usize],
    ) -> Result<ShardGrad> {
        let b = labels.len();
        if b % self.n_workers != 0 {
            bail!("batch {b} not divisible by {} workers", self.n_workers);
        }
        let shard = b / self.n_workers;
        let (tx, rx) = mpsc::channel::<(usize, ShardGrad)>();
        std::thread::scope(|scope| {
            for w in 0..self.n_workers {
                let tx = tx.clone();
                let widths = self.widths.clone();
                let seed = self.seed;
                let xs = x.slice(0, x.rows(), w * shard, (w + 1) * shard);
                let ys = labels[w * shard..(w + 1) * shard].to_vec();
                let state = state.to_vec();
                scope.spawn(move || {
                    let mut net = models::mlp(&widths, seed);
                    net.load_state_vector(&state);
                    let (loss, _) = net.train_batch(&xs, &ys, true);
                    let caps = net.kfac_captures();
                    let grads: Vec<Matrix> = caps.iter().map(|c| c.grad.clone()).collect();
                    let a_grams: Vec<Matrix> = caps.iter().map(|c| gemm::syrk(c.a)).collect();
                    // G captures are per-sample-scale already (G = B·dL/dz
                    // with mean loss), so the gram sum is shard-invariant.
                    let g_grams: Vec<Matrix> = caps.iter().map(|c| gemm::syrk(c.g)).collect();
                    let _ = tx.send((
                        w,
                        ShardGrad { loss, shard_size: shard, grads, a_grams, g_grams },
                    ));
                });
            }
        });
        drop(tx);
        // Allreduce: average grads/losses, sum grams.
        let mut acc: Option<ShardGrad> = None;
        for (_, sg) in rx {
            acc = Some(match acc {
                None => sg,
                Some(mut a) => {
                    a.loss += sg.loss;
                    for (dst, src) in a.grads.iter_mut().zip(sg.grads.iter()) {
                        *dst += src;
                    }
                    for (dst, src) in a.a_grams.iter_mut().zip(sg.a_grams.iter()) {
                        *dst += src;
                    }
                    for (dst, src) in a.g_grams.iter_mut().zip(sg.g_grams.iter()) {
                        *dst += src;
                    }
                    a.shard_size += sg.shard_size;
                    a
                }
            });
        }
        let mut out = acc.expect("no worker output");
        let k = self.n_workers as f64;
        out.loss /= k;
        for g in &mut out.grads {
            g.scale_inplace(1.0 / k);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Pcg64;

    #[test]
    fn two_workers_match_single_worker_grads() {
        let widths = vec![12, 8, 10];
        let mut rng = Pcg64::new(1);
        let x = rng.gaussian_matrix(12, 8);
        let labels: Vec<usize> = (0..8).map(|i| i % 10).collect();
        let net = models::mlp(&widths, 7);
        let state = net.state_vector();

        let single = WorkerPool::new(widths.clone(), 1, 7).unwrap();
        let multi = WorkerPool::new(widths.clone(), 2, 7).unwrap();
        let g1 = single.compute(&state, &x, &labels).unwrap();
        let g2 = multi.compute(&state, &x, &labels).unwrap();
        assert!((g1.loss - g2.loss).abs() < 1e-12, "{} vs {}", g1.loss, g2.loss);
        for (a, b) in g1.grads.iter().zip(g2.grads.iter()) {
            assert!(a.rel_err(b) < 1e-12);
        }
        // Grams are sums → identical regardless of sharding.
        for (a, b) in g1.a_grams.iter().zip(g2.a_grams.iter()) {
            assert!(a.rel_err(b) < 1e-12);
        }
        for (a, b) in g1.g_grams.iter().zip(g2.g_grams.iter()) {
            assert!(a.rel_err(b) < 1e-10);
        }
    }

    #[test]
    fn four_workers_also_match() {
        let widths = vec![6, 5, 10];
        let mut rng = Pcg64::new(2);
        let x = rng.gaussian_matrix(6, 16);
        let labels: Vec<usize> = (0..16).map(|i| i % 10).collect();
        let state = models::mlp(&widths, 3).state_vector();
        let g1 = WorkerPool::new(widths.clone(), 1, 3).unwrap().compute(&state, &x, &labels).unwrap();
        let g4 = WorkerPool::new(widths, 4, 3).unwrap().compute(&state, &x, &labels).unwrap();
        for (a, b) in g1.grads.iter().zip(g4.grads.iter()) {
            assert!(a.rel_err(b) < 1e-12);
        }
    }

    #[test]
    fn indivisible_batch_rejected() {
        let widths = vec![4, 10];
        let pool = WorkerPool::new(widths.clone(), 3, 1).unwrap();
        let state = models::mlp(&widths, 1).state_vector();
        let x = Matrix::zeros(4, 8);
        assert!(pool.compute(&state, &x, &[0; 8]).is_err());
    }
}
