//! Eigen-spectrum probe — the machinery behind Fig. 1 and the empirical
//! side of Proposition 3.1 (§3 "Numerical Investigation").
//!
//! Trains with a K-FAC-family solver and dumps the full eigen-spectrum of
//! chosen layers' EA K-factors on the paper's cadence: every `early_every`
//! steps while `k < early_until`, every `late_every` steps after.

use anyhow::Result;

use crate::coordinator::config::TrainConfig;
use crate::coordinator::metrics::CsvLogger;
use crate::coordinator::trainer::{build_schedules, load_data};
use crate::data::Batcher;
use crate::linalg::Pcg64;
use crate::nn::models;
use crate::optim::KfacOptimizer;
use crate::rnla::{decomposition, errors};

/// Probe cadence (paper: every 30 steps if k < 300, every 300 after, with
/// T_KU = T_KI = 30).
#[derive(Clone, Debug)]
pub struct SpectrumConfig {
    pub early_every: usize,
    pub early_until: usize,
    pub late_every: usize,
    /// Which Kronecker blocks to dump (paper shows layers 7 and 11).
    pub blocks: Vec<usize>,
    /// Total steps to run.
    pub steps: usize,
    /// K-factor update / inverse periods during the probe (paper: 30/30).
    pub t_ku: usize,
    pub t_ki: usize,
}

impl Default for SpectrumConfig {
    fn default() -> Self {
        SpectrumConfig {
            early_every: 30,
            early_until: 300,
            late_every: 300,
            blocks: vec![],
            steps: 1200,
            t_ku: 30,
            t_ki: 30,
        }
    }
}

/// One spectrum snapshot.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub step: usize,
    pub block: usize,
    /// "A" or "G".
    pub factor: &'static str,
    pub lambda: Vec<f64>,
}

impl Snapshot {
    /// Modes needed to decay 1.5 orders of magnitude (paper's headline).
    pub fn modes_to_15_orders(&self) -> Option<usize> {
        errors::modes_to_decay(&self.lambda, 1.5)
    }
}

/// Run the probe; returns all snapshots (also streamed to `csv` if given).
pub fn run_probe(
    cfg: &TrainConfig,
    probe: &SpectrumConfig,
    mut csv: Option<&mut CsvLogger>,
) -> Result<Vec<Snapshot>> {
    let (train, _test) = load_data(cfg)?;
    let mut net = match &cfg.model {
        crate::coordinator::config::ModelChoice::Mlp { widths } => models::mlp(widths, cfg.seed),
        crate::coordinator::config::ModelChoice::Vgg16Bn { scale_div } => {
            models::vgg16_bn(10, *scale_div, cfg.seed)
        }
    };
    let mut sched = build_schedules(cfg);
    // Paper's probe setting: T_KU = T_KI = 30 (configurable for tests).
    sched.t_ku = probe.t_ku.max(1);
    sched.t_ki = crate::optim::StepSchedule::constant(probe.t_ki.max(1) as f64);
    let dims = net.kfac_dims();
    let blocks: Vec<usize> = if probe.blocks.is_empty() {
        // default: one early conv/fc block and one late block
        vec![dims.len() / 2, dims.len() - 1]
    } else {
        probe.blocks.clone()
    };
    let mut opt =
        KfacOptimizer::new(std::sync::Arc::new(decomposition::Exact), sched, &dims, cfg.seed);
    let mut rng = Pcg64::with_stream(cfg.seed, 555);
    let mut snaps = Vec::new();
    let mut step = 0usize;
    'outer: for epoch in 0..usize::MAX {
        for idx in Batcher::new(train.len(), cfg.batch, &mut rng) {
            let (xb, yb) = train.gather(&idx);
            net.train_batch(&xb, &yb, true);
            let deltas = {
                let caps = net.kfac_captures();
                opt.step(epoch.min(cfg.epochs.saturating_sub(1)), &caps)
            };
            let (lr, wd) = (opt.sched.alpha.at(0), opt.sched.weight_decay);
            net.apply_steps(&deltas, lr, wd);
            let due = if step < probe.early_until {
                step % probe.early_every == 0
            } else {
                step % probe.late_every == 0
            };
            if due {
                let sa = opt.a_spectra();
                let sg = opt.g_spectra();
                for &b in &blocks {
                    for (name, spec) in [("A", &sa[b]), ("G", &sg[b])] {
                        let snap =
                            Snapshot { step, block: b, factor: name, lambda: spec.clone() };
                        if let Some(log) = csv.as_deref_mut() {
                            write_spectrum_rows(log, step, b, name, &snap.lambda)?;
                        }
                        snaps.push(snap);
                    }
                }
            }
            step += 1;
            if step >= probe.steps {
                break 'outer;
            }
        }
    }
    Ok(snaps)
}

/// CSV header for spectrum dumps.
pub fn spectrum_csv(path: &str) -> Result<CsvLogger> {
    CsvLogger::create(path, &["step", "block", "factor", "mode", "lambda"])
}

/// Stream one spectrum snapshot (one row per mode) into a
/// [`spectrum_csv`]-shaped logger — shared by [`run_probe`] and the
/// session's [`SpectrumHook`](crate::coordinator::hooks::SpectrumHook).
pub fn write_spectrum_rows(
    log: &mut CsvLogger,
    step: usize,
    block: usize,
    factor: &str,
    lambda: &[f64],
) -> Result<()> {
    for (i, &l) in lambda.iter().enumerate() {
        log.row(&[
            step.to_string(),
            block.to_string(),
            factor.to_string(),
            i.to_string(),
            format!("{l:.6e}"),
        ])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{DataChoice, EngineChoice, ModelChoice};

    fn probe_cfg() -> TrainConfig {
        TrainConfig {
            solver: "kfac".into(),
            epochs: 2,
            batch: 16,
            seed: 2,
            model: ModelChoice::Mlp { widths: vec![48, 24, 10] },
            data: DataChoice::Synthetic { n_train: 160, n_test: 32, height: 4, width: 4, channels: 3 },
            engine: EngineChoice::Native,
            targets: vec![],
            augment: false,
            out_dir: "/tmp".into(),
            sched_width: 0,
            ..Default::default()
        }
    }

    #[test]
    fn spectra_decay_develops_over_steps() {
        // The core §3 claim: early spectra are flat (identity init), later
        // spectra decay. Compare #modes within 10% of λ_max at k=0 vs k=end.
        let mut cfg = probe_cfg();
        cfg.data = DataChoice::Synthetic { n_train: 320, n_test: 32, height: 4, width: 4, channels: 3 };
        let probe = SpectrumConfig {
            early_every: 10,
            early_until: 40,
            late_every: 20,
            blocks: vec![0],
            steps: 100,
            t_ku: 1,
            t_ki: 10,
        };
        let snaps = run_probe(&cfg, &probe, None).unwrap();
        let first_a = snaps.iter().find(|s| s.factor == "A").unwrap();
        let last_a = snaps.iter().rev().find(|s| s.factor == "A").unwrap();
        // 10%-of-λmax cut: right after init every mode sits above it (the
        // 0.95·I floor vs λmax ≈ 1+ε), at equilibrium the tail falls under.
        let flat0 = errors::modes_above(&first_a.lambda, 0.1);
        let flat1 = errors::modes_above(&last_a.lambda, 0.1);
        assert!(flat0 > first_a.lambda.len() / 2, "step0 spectrum unexpectedly decayed: {flat0}");
        assert!(flat1 < flat0, "spectrum did not develop decay: {flat0} -> {flat1}");
    }

    #[test]
    fn snapshots_on_expected_cadence() {
        let cfg = probe_cfg();
        let probe = SpectrumConfig {
            early_every: 5,
            early_until: 20,
            late_every: 10,
            blocks: vec![0, 1],
            steps: 40,
            t_ku: 5,
            t_ki: 5,
        };
        let snaps = run_probe(&cfg, &probe, None).unwrap();
        let steps: Vec<usize> = snaps.iter().map(|s| s.step).collect();
        // expected: 0,5,10,15 (early), 20,30 (late) × 2 blocks × 2 factors
        let mut uniq = steps.clone();
        uniq.dedup();
        let mut expect = vec![0, 5, 10, 15, 20, 30];
        expect.retain(|&s| s < 40);
        let mut uniq_sorted = uniq.clone();
        uniq_sorted.sort_unstable();
        uniq_sorted.dedup();
        assert_eq!(uniq_sorted, expect);
        assert_eq!(snaps.len(), expect.len() * 2 * 2);
    }
}
