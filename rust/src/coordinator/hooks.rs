//! Run hooks: the ordered observation/control interface of a [`Session`].
//!
//! Everything the old trainer did *around* the optimization math — metrics
//! CSVs, rank/pipeline traces, checkpointing, the Fig. 1 spectrum probe,
//! early time-to-accuracy stopping — is a [`RunHook`] implementation here
//! instead of inline trainer code. Hooks run in installation order at five
//! points of the loop (`on_run_start` / `on_epoch_start` / `on_step` /
//! `on_epoch_end` / `on_run_end`) and are strictly *observers with a stop
//! vote*: they see the solver through `&dyn Preconditioner`, never mutate
//! training state, and therefore cannot perturb the bitwise-pinned step
//! sequence. `on_epoch_end` may return [`HookAction::Stop`] to end the run
//! early (the time-to-accuracy hook); `on_run_end` may rewrite the
//! [`RunResult`] (the trace hook installs its rows there).
//!
//! [`Session`]: crate::coordinator::session::Session

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::coordinator::checkpoint;
use crate::coordinator::config::TrainConfig;
use crate::coordinator::metrics::{EpochRecord, PipeTraceRow, RankTraceRow, RunResult};
use crate::coordinator::spectrum;
use crate::linalg::Pcg64;
use crate::nn::Network;
use crate::obs::{self, ObsConfig};
use crate::optim::Preconditioner;
use crate::util::json::Json;

/// A hook's vote at an epoch boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HookAction {
    Continue,
    /// End the run after this epoch (remaining hooks still fire; the
    /// partial record set is returned as usual).
    Stop,
}

/// Context at `on_run_start`.
pub struct RunCtx<'a> {
    pub cfg: &'a TrainConfig,
    /// The solver's display name (`rs-kfac`, `kfac+rsvd`, …).
    pub solver_name: &'a str,
    /// Decomposition-refresh rounds already completed before this run
    /// segment — nonzero only when resuming from a checkpoint (hooks that
    /// count rounds must start here, not at 0).
    pub start_rounds: usize,
    /// Global step index this segment starts at (nonzero only on resume).
    pub start_step: usize,
}

/// Context after each optimization step (weights already updated).
pub struct StepCtx<'a> {
    pub epoch: usize,
    /// Global step index (0-based, monotone across epochs).
    pub step: usize,
    /// This batch's training loss.
    pub batch_loss: f64,
    pub solver: &'a dyn Preconditioner,
}

/// Context after each epoch's evaluation.
pub struct EpochCtx<'a> {
    pub epoch: usize,
    /// Global step count at the end of this epoch.
    pub step: usize,
    pub record: &'a EpochRecord,
    pub solver: &'a dyn Preconditioner,
    /// The native-engine network (`None` on the PJRT artifact path, where
    /// parameters live in flat weight matrices, not a `Network`).
    pub net: Option<&'a Network>,
    /// The trainer's data-stream RNG (batch shuffle + augmentation) at the
    /// epoch boundary — what a full-state checkpoint snapshots so a resume
    /// replays the remaining epochs' batch order exactly.
    pub data_rng: &'a Pcg64,
}

/// One ordered observer of a session run. All methods default to no-ops so
/// a hook implements only the points it cares about.
pub trait RunHook: Send {
    /// Short display name (diagnostics / error contexts).
    fn name(&self) -> &str;

    fn on_run_start(&mut self, _ctx: &RunCtx<'_>) -> Result<()> {
        Ok(())
    }

    fn on_epoch_start(&mut self, _epoch: usize) -> Result<()> {
        Ok(())
    }

    fn on_step(&mut self, _ctx: &StepCtx<'_>) -> Result<()> {
        Ok(())
    }

    fn on_epoch_end(&mut self, _ctx: &EpochCtx<'_>) -> Result<HookAction> {
        Ok(HookAction::Continue)
    }

    /// Last call of the run; may rewrite the result (e.g. install traces).
    fn on_run_end(&mut self, _result: &mut RunResult) -> Result<()> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// 1. Rank / pipeline trace (the old inline `RankTracer`).
// ---------------------------------------------------------------------------

/// Collects the per-block adaptive rank trace plus — with the async
/// pipeline attached — per-round scheduler telemetry: after each step, if
/// the solver ran a refresh round since the last probe, record the
/// per-block decomposition ranks it *installed* (see [`RankTraceRow`] for
/// the stale-pipeline caveat) and the pipeline's queue-depth / recovery /
/// supersede / warm-up counters for that round. Installed into
/// [`RunResult::rank_trace`] / [`RunResult::pipe_trace`] at `on_run_end`.
///
/// A [`Session`](crate::coordinator::session::Session) installs this hook
/// by default, so the legacy `trainer::run` shim keeps returning the same
/// traces bitwise.
#[derive(Default)]
pub struct TraceHook {
    last_rounds: usize,
    rows: Vec<RankTraceRow>,
    pipe_rows: Vec<PipeTraceRow>,
}

impl TraceHook {
    pub fn new() -> Self {
        Self::default()
    }
}

impl RunHook for TraceHook {
    fn name(&self) -> &str {
        "trace"
    }

    fn on_run_start(&mut self, ctx: &RunCtx<'_>) -> Result<()> {
        // A session can be run more than once; the trace must restart each
        // time — from round 0 on a fresh run, or from the checkpointed
        // round count on a resume (otherwise the first post-resume step
        // would spuriously record the pre-resume rounds as one new row).
        self.last_rounds = ctx.start_rounds;
        self.rows.clear();
        self.pipe_rows.clear();
        Ok(())
    }

    fn on_step(&mut self, ctx: &StepCtx<'_>) -> Result<()> {
        let diag = ctx.solver.diagnostics();
        if diag.n_decomps <= self.last_rounds {
            return Ok(());
        }
        self.last_rounds = diag.n_decomps;
        for (block, &(rank_a, rank_g)) in diag.block_ranks.iter().enumerate() {
            self.rows.push(RankTraceRow {
                round: diag.n_decomps - 1,
                epoch: ctx.epoch,
                step: ctx.step,
                block,
                rank_a,
                rank_g,
            });
        }
        if let Some(p) = &diag.pipeline {
            self.pipe_rows.push(PipeTraceRow {
                round: diag.n_decomps - 1,
                epoch: ctx.epoch,
                step: ctx.step,
                queue_depth: p.queue_depth,
                max_queue_depth: p.max_queue_depth,
                recovered_jobs: p.recovered_jobs,
                superseded_jobs: p.superseded_jobs,
                warming_slots: p.warming_slots,
                max_staleness: p.max_staleness,
                wait_s: p.queue_wait_seconds,
                run_s: p.worker_seconds,
            });
        }
        Ok(())
    }

    fn on_run_end(&mut self, result: &mut RunResult) -> Result<()> {
        result.rank_trace = std::mem::take(&mut self.rows);
        result.pipe_trace = std::mem::take(&mut self.pipe_rows);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// 2. Metrics CSVs.
// ---------------------------------------------------------------------------

/// Writes the run's CSV artifacts at `on_run_end`: the per-epoch series
/// (`<prefix>_<solver>_<seed>.csv`) and — with [`traces`](Self::traces)
/// on, the default — the per-block rank trace
/// (`ranks_<solver>_<seed>.csv`) and per-round pipeline telemetry
/// (`pipeline_<solver>_<seed>.csv`) when non-empty. Exactly the files the
/// `train` subcommand has always produced; sweep cells run with
/// `with_prefix("cmp").traces(false)` so concurrent grids can share an
/// `out_dir` with a train run without clobbering its trace files.
pub struct CsvMetricsHook {
    out_dir: String,
    prefix: String,
    write_traces: bool,
    /// Overrides the solver part of the file names (sweep cells pass their
    /// cell label, e.g. `rs-kfac[pipeline.max_stale_steps=4]`).
    series_label: Option<String>,
    /// Paths written by the last run (for logging / tests).
    pub written: Vec<PathBuf>,
}

impl CsvMetricsHook {
    pub fn new(out_dir: impl Into<String>) -> Self {
        CsvMetricsHook {
            out_dir: out_dir.into(),
            prefix: "run".into(),
            write_traces: true,
            series_label: None,
            written: Vec::new(),
        }
    }

    /// Use a different per-epoch series prefix (`cmp` for sweep runs).
    pub fn with_prefix(mut self, prefix: impl Into<String>) -> Self {
        self.prefix = prefix.into();
        self
    }

    /// Name the files after `label` instead of the run's solver. Sweep
    /// cells pass their cell label so axis variants of one solver —
    /// `rs-kfac[train.batch=16]` vs `rs-kfac[train.batch=32]`, same seed —
    /// write distinct CSVs instead of clobbering each other; without axes
    /// the label equals the solver name and the legacy file names are
    /// unchanged.
    pub fn series_label(mut self, label: impl Into<String>) -> Self {
        self.series_label = Some(label.into());
        self
    }

    /// Toggle the unprefixed rank/pipeline trace CSVs (their names carry
    /// no prefix, so runs sharing an `out_dir` would overwrite each
    /// other's).
    pub fn traces(mut self, on: bool) -> Self {
        self.write_traces = on;
        self
    }
}

impl RunHook for CsvMetricsHook {
    fn name(&self) -> &str {
        "csv-metrics"
    }

    fn on_run_start(&mut self, _ctx: &RunCtx<'_>) -> Result<()> {
        // Fail fast on an unwritable output directory — before the run
        // trains for hours, not after.
        std::fs::create_dir_all(&self.out_dir)
            .with_context(|| format!("csv-metrics hook: creating out_dir '{}'", self.out_dir))?;
        Ok(())
    }

    fn on_run_end(&mut self, result: &mut RunResult) -> Result<()> {
        self.written.clear();
        let solver_part = self.series_label.as_deref().unwrap_or(&result.solver);
        let tag = format!("{}_{}", solver_part, result.seed);
        let series = format!("{}/{}_{tag}.csv", self.out_dir, self.prefix);
        result.write_csv(&series)?;
        self.written.push(series.into());
        if self.write_traces && !result.rank_trace.is_empty() {
            let p = format!("{}/ranks_{tag}.csv", self.out_dir);
            result.write_rank_csv(&p)?;
            self.written.push(p.into());
        }
        if self.write_traces && !result.pipe_trace.is_empty() {
            let p = format!("{}/pipeline_{tag}.csv", self.out_dir);
            result.write_pipeline_csv(&p)?;
            self.written.push(p.into());
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// 3. Checkpointing.
// ---------------------------------------------------------------------------

/// Saves the full training state every `every` epochs (native engine only
/// — the PJRT path owns its weights outside a `Network` and is skipped
/// with a one-time note). Each file is a v2 checkpoint
/// ([`checkpoint::save_full`]): network parameters, the solver's EA
/// factors / decompositions / counters / EK-FAC scalings, and the trainer
/// cursor (epoch, step, RNG stream positions) — everything
/// `Session::resume` needs to continue the run bitwise. Writes are atomic
/// (`.tmp` + rename), so an interrupt mid-save never corrupts the file a
/// resume would read.
pub struct CheckpointHook {
    dir: String,
    every: usize,
    solver: String,
    seed: u64,
    warned: bool,
    /// Checkpoints written by the last run.
    pub written: Vec<PathBuf>,
}

impl CheckpointHook {
    /// `every = 0` is clamped to 1 (checkpoint after every epoch).
    pub fn new(dir: impl Into<String>, every: usize) -> Self {
        CheckpointHook {
            dir: dir.into(),
            every: every.max(1),
            solver: String::new(),
            seed: 0,
            warned: false,
            written: Vec::new(),
        }
    }
}

impl RunHook for CheckpointHook {
    fn name(&self) -> &str {
        "checkpoint"
    }

    fn on_run_start(&mut self, ctx: &RunCtx<'_>) -> Result<()> {
        self.solver = ctx.cfg.solver.clone();
        self.seed = ctx.cfg.seed;
        self.written.clear();
        self.warned = false;
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("checkpoint hook: creating dir '{}'", self.dir))?;
        Ok(())
    }

    fn on_epoch_end(&mut self, ctx: &EpochCtx<'_>) -> Result<HookAction> {
        if (ctx.epoch + 1) % self.every != 0 {
            return Ok(HookAction::Continue);
        }
        match ctx.net {
            Some(net) => {
                let path = checkpoint::epoch_path(&self.dir, &self.solver, self.seed, ctx.epoch);
                let trainer = checkpoint::TrainerState {
                    next_epoch: ctx.epoch + 1,
                    global_step: ctx.step,
                    seed: self.seed,
                    wall_s: ctx.record.wall_s,
                    data_rng: ctx.data_rng.raw_state(),
                    net_rng: net.rng.raw_state(),
                };
                checkpoint::save_full(net, ctx.solver, &trainer, &path)?;
                self.written.push(path);
            }
            None if !self.warned => {
                self.warned = true;
                eprintln!(
                    "[rkfac] note: checkpoint hook skipped — the PJRT engine path has no \
                     native Network to snapshot"
                );
            }
            None => {}
        }
        Ok(HookAction::Continue)
    }
}

// ---------------------------------------------------------------------------
// 4. Fig. 1 spectrum probe.
// ---------------------------------------------------------------------------

/// Streams the exact eigen-spectra of the EA K-factors to a CSV on a fixed
/// step cadence — the Fig. 1 probe riding an ordinary training run instead
/// of the dedicated `spectrum::run_probe` driver. No-ops (once, with a
/// note) for solvers that expose no factor spectra.
pub struct SpectrumHook {
    csv_path: String,
    every: usize,
    blocks: Vec<usize>,
    log: Option<crate::coordinator::metrics::CsvLogger>,
    warned: bool,
    /// Snapshots written (step, block) by the last run.
    pub snapshots: usize,
}

impl SpectrumHook {
    /// Dump the spectra of `blocks` (empty = all) every `every` steps.
    pub fn new(csv_path: impl Into<String>, every: usize, blocks: Vec<usize>) -> Self {
        SpectrumHook {
            csv_path: csv_path.into(),
            every: every.max(1),
            blocks,
            log: None,
            warned: false,
            snapshots: 0,
        }
    }
}

impl RunHook for SpectrumHook {
    fn name(&self) -> &str {
        "spectrum"
    }

    fn on_run_start(&mut self, _ctx: &RunCtx<'_>) -> Result<()> {
        self.log = Some(spectrum::spectrum_csv(&self.csv_path)?);
        self.snapshots = 0;
        self.warned = false;
        Ok(())
    }

    fn on_step(&mut self, ctx: &StepCtx<'_>) -> Result<()> {
        if ctx.step % self.every != 0 {
            return Ok(());
        }
        let Some(spectra) = ctx.solver.spectra() else {
            if !self.warned {
                self.warned = true;
                eprintln!(
                    "[rkfac] note: spectrum hook inactive — solver '{}' exposes no factor \
                     spectra",
                    ctx.solver.name()
                );
            }
            return Ok(());
        };
        if let Some(&bad) = self.blocks.iter().find(|&&b| b >= spectra.a.len()) {
            bail!(
                "spectrum hook: block {bad} out of range (model has {} Kronecker blocks)",
                spectra.a.len()
            );
        }
        let log = self.log.as_mut().expect("on_run_start created the logger");
        let all: Vec<usize> = (0..spectra.a.len()).collect();
        let blocks = if self.blocks.is_empty() { &all } else { &self.blocks };
        for &b in blocks {
            for (factor, lambda) in [("A", &spectra.a[b]), ("G", &spectra.g[b])] {
                spectrum::write_spectrum_rows(log, ctx.step, b, factor, lambda)?;
                self.snapshots += 1;
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// 5. Observability: span/metric recording + export.
// ---------------------------------------------------------------------------

/// Flips the process-wide [`crate::obs`] subsystem on around a run and
/// exports what it recorded at `on_run_end`: the JSONL event stream
/// (`obs_<solver>_<seed>.jsonl`), the Chrome-trace file
/// (`trace_<solver>_<seed>.json`), and a per-phase summary table — each
/// gated by its [`ObsConfig`] flag. Per step it folds the solver's cheap
/// diagnostics into the metrics registry (queue depth gauge, job counters,
/// …), which is what absorbed the old one-off diagnostics plumbing.
///
/// Installing this hook cannot perturb training: obs recording is strictly
/// read-only with respect to the compute path (see the [`crate::obs`]
/// module docs), so every bitwise golden holds with it enabled.
pub struct ObsHook {
    cfg: ObsConfig,
    out_dir: String,
    /// Files written by the last run.
    pub written: Vec<PathBuf>,
}

impl ObsHook {
    pub fn new(out_dir: impl Into<String>, cfg: ObsConfig) -> Self {
        ObsHook { cfg, out_dir: out_dir.into(), written: Vec::new() }
    }
}

impl RunHook for ObsHook {
    fn name(&self) -> &str {
        "obs"
    }

    fn on_run_start(&mut self, _ctx: &RunCtx<'_>) -> Result<()> {
        self.written.clear();
        std::fs::create_dir_all(&self.out_dir)
            .with_context(|| format!("obs hook: creating out_dir '{}'", self.out_dir))?;
        // Drop anything a prior (aborted) run left in the global buffers,
        // then start recording.
        obs::reset();
        obs::set_enabled(true);
        Ok(())
    }

    fn on_step(&mut self, ctx: &StepCtx<'_>) -> Result<()> {
        let diag = ctx.solver.diagnostics();
        obs::counter_set("solver.n_decomps", diag.n_decomps as u64);
        obs::gauge_set("solver.decomp_seconds", diag.decomp_seconds);
        if let Some(p) = &diag.pipeline {
            obs::gauge_set("pipeline.queue_depth", p.queue_depth as f64);
            obs::counter_set("pipeline.max_queue_depth", p.max_queue_depth as u64);
            obs::counter_set("pipeline.jobs_completed", p.jobs_completed as u64);
            obs::counter_set("pipeline.recovered_jobs", p.recovered_jobs as u64);
            obs::counter_set("pipeline.superseded_jobs", p.superseded_jobs as u64);
            obs::gauge_set("pipeline.worker_seconds", p.worker_seconds);
            obs::gauge_set("pipeline.queue_wait_seconds", p.queue_wait_seconds);
            if let Some(s) = p.max_staleness {
                obs::observe("pipeline.max_staleness", s as f64);
            }
        }
        Ok(())
    }

    fn on_run_end(&mut self, result: &mut RunResult) -> Result<()> {
        // Stop recording before the export so the exporters' own work never
        // shows up in the data they write.
        obs::set_enabled(false);
        let snap = obs::take_snapshot();
        let tag = format!("{}_{}", result.solver, result.seed);
        if self.cfg.jsonl {
            let p = PathBuf::from(format!("{}/obs_{tag}.jsonl", self.out_dir));
            let meta = vec![
                ("solver".to_string(), Json::from(result.solver.as_str())),
                ("seed".to_string(), Json::from(result.seed)),
            ];
            obs::export::write_jsonl(&p, &meta, &snap)?;
            self.written.push(p);
        }
        if self.cfg.chrome_trace {
            let p = PathBuf::from(format!("{}/trace_{tag}.json", self.out_dir));
            obs::export::write_chrome_trace(&p, &snap)?;
            self.written.push(p);
        }
        if self.cfg.summary {
            let rows = obs::export::phase_summary(&snap.events);
            let table = obs::export::render_phase_table(&format!("obs phases ({tag})"), &rows);
            if !table.is_empty() {
                println!("{table}");
            }
        }
        if snap.dropped > 0 {
            eprintln!(
                "[rkfac] note: obs event buffer overflowed — {} span(s) dropped (the JSONL \
                 meta line records the count)",
                snap.dropped
            );
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// 6. Early time-to-accuracy stopping.
// ---------------------------------------------------------------------------

/// Stops the run at the first epoch whose test accuracy reaches `target` —
/// the Table-1 time-to-accuracy protocol without paying for the remaining
/// epochs. The partial record set still flows into `summarize` (its
/// time-to-target statistics only need the first crossing).
pub struct EarlyStopHook {
    target: f64,
    /// Epoch (0-based) at which the target was hit, if it was.
    pub stopped_at: Option<usize>,
}

impl EarlyStopHook {
    pub fn new(target: f64) -> Self {
        EarlyStopHook { target, stopped_at: None }
    }
}

impl RunHook for EarlyStopHook {
    fn name(&self) -> &str {
        "early-stop"
    }

    fn on_run_start(&mut self, _ctx: &RunCtx<'_>) -> Result<()> {
        self.stopped_at = None;
        Ok(())
    }

    fn on_epoch_end(&mut self, ctx: &EpochCtx<'_>) -> Result<HookAction> {
        if ctx.record.test_acc >= self.target {
            self.stopped_at = Some(ctx.epoch);
            return Ok(HookAction::Stop);
        }
        Ok(HookAction::Continue)
    }
}
