//! Experiment configuration: a TOML-subset parser (no serde offline) plus
//! the typed `TrainConfig` the trainer consumes.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string
//! (`"…"`), integer, float, boolean, and homogeneous arrays (`[1, 2]`,
//! `["a", "b"]`); `#` comments. This covers everything in `configs/*.toml`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::linalg::backend::{BackendKind, Precision};
use crate::obs::ObsConfig;
use crate::optim::{StepSchedule, StrategySchedule, StrategySchedules};
use crate::pipeline::{OnlineMode, PipelineConfig, Schedule, TransportKind};

/// A parsed TOML-subset value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlVal {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlVal>),
}

impl TomlVal {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlVal::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlVal::Float(f) => Some(*f),
            TomlVal::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlVal::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlVal::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        match self {
            TomlVal::Arr(a) => a.iter().map(TomlVal::as_usize).collect(),
            _ => None,
        }
    }

    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        match self {
            TomlVal::Arr(a) => a.iter().map(TomlVal::as_f64).collect(),
            _ => None,
        }
    }
}

/// Sections → keys → values.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlVal>>;

pub(crate) fn parse_value(raw: &str, line_no: usize) -> Result<TomlVal> {
    let raw = raw.trim();
    if raw.starts_with('"') {
        if !raw.ends_with('"') || raw.len() < 2 {
            bail!("line {line_no}: unterminated string");
        }
        return Ok(TomlVal::Str(raw[1..raw.len() - 1].to_string()));
    }
    if raw == "true" {
        return Ok(TomlVal::Bool(true));
    }
    if raw == "false" {
        return Ok(TomlVal::Bool(false));
    }
    if raw.starts_with('[') {
        if !raw.ends_with(']') {
            bail!("line {line_no}: unterminated array");
        }
        let inner = &raw[1..raw.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part, line_no)?);
            }
        }
        return Ok(TomlVal::Arr(items));
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(TomlVal::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(TomlVal::Float(f));
    }
    bail!("line {line_no}: cannot parse value '{raw}'")
}

/// Strip a trailing `#` comment from one line, honouring string literals:
/// the comment starts at the first `#` that is *outside* a double-quoted
/// string, so `out_dir = "res#1"  # trailing` keeps the `#` in the value
/// and still drops the comment.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse a TOML-subset document.
pub fn parse_toml(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::new();
    let mut section = String::new();
    doc.insert(String::new(), BTreeMap::new());
    for (i, raw_line) in text.lines().enumerate() {
        let line_no = i + 1;
        let line = strip_comment(raw_line).trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                bail!("line {line_no}: bad section header");
            }
            section = line[1..line.len() - 1].trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| anyhow!("line {line_no}: expected key = value"))?;
        let key = line[..eq].trim().to_string();
        let val = parse_value(&line[eq + 1..], line_no)?;
        doc.get_mut(&section).unwrap().insert(key, val);
    }
    Ok(doc)
}

/// Dense-linalg compute backend selection (`[linalg]` section).
///
/// Selecting `backend = "threaded"` changes wall-clock only, never bits:
/// every threaded kernel partitions disjoint output tiles with a
/// thread-count-independent per-element accumulation order (see
/// `docs/linalg.md`). `precision = "mixed"` is the one numerics-affecting
/// knob and is scoped to the RNLA sketch GEMMs; it is rejected at resolve
/// time for solver specs whose strategy has no sketch path.
#[derive(Clone, Debug, PartialEq)]
pub struct LinalgConfig {
    /// Kernel set: `"reference"` (sequential, the historical kernels) or
    /// `"threaded"` (cache-blocked + worker pool, bitwise-identical).
    pub backend: BackendKind,
    /// Worker-thread count for the threaded backend; `0` = one per
    /// available core, resolved at install time. Ignored by `reference`.
    pub threads: usize,
    /// `"f64"` (default) or `"mixed"` (f32-storage, f64-accumulate sketch
    /// GEMMs). Exact/EVD paths stay pinned f64 either way.
    pub precision: Precision,
}

impl Default for LinalgConfig {
    fn default() -> Self {
        LinalgConfig { backend: BackendKind::Reference, threads: 0, precision: Precision::F64 }
    }
}

/// Factored (Woodbury / sketched-core) G-side solve policy (`[factored]`
/// section) — routes wide blocks around the o×o gram entirely (see
/// `docs/factored.md`). `mode = "off"` (the default) leaves every solver
/// bitwise the legacy eigen path.
#[derive(Clone, Debug, PartialEq)]
pub struct FactoredConfig {
    /// `"off"`, `"all"`, or `"hybrid"` (route blocks at least
    /// `width_threshold` wide, keep the eigen path for the rest).
    pub mode: String,
    /// Minimum G-side width a block needs to be routed under `"hybrid"`.
    pub width_threshold: usize,
    /// Core strategy key (a registered column-factoring decomposition:
    /// `"woodbury"` exact T×T core, `"sketchcore"` SENG's sketched core).
    pub core: String,
    /// Retained-column window per factored block (memory O(o·max_cols)).
    pub max_cols: usize,
    /// Sketched-core row-sample budget (ignored by `"woodbury"`).
    pub col_sample: usize,
}

impl Default for FactoredConfig {
    fn default() -> Self {
        FactoredConfig {
            mode: "off".into(),
            width_threshold: 4096,
            core: "woodbury".into(),
            max_cols: 256,
            col_sample: 64,
        }
    }
}

/// Which compute engine drives fwd/bwd.
#[derive(Clone, Debug, PartialEq)]
pub enum EngineChoice {
    /// Native Rust nn (supports conv/BN; the oracle path).
    Native,
    /// PJRT artifacts compiled from the JAX model (`mlp_step_<name>`).
    Pjrt { config: String },
}

/// Which model to train.
#[derive(Clone, Debug, PartialEq)]
pub enum ModelChoice {
    Mlp { widths: Vec<usize> },
    Vgg16Bn { scale_div: usize },
}

/// Which dataset to use.
#[derive(Clone, Debug, PartialEq)]
pub enum DataChoice {
    Synthetic { n_train: usize, n_test: usize, height: usize, width: usize, channels: usize },
    Cifar { root: String, n_train: usize, n_test: usize },
}

/// Full experiment configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub solver: String,
    pub epochs: usize,
    pub batch: usize,
    pub seed: u64,
    pub model: ModelChoice,
    pub data: DataChoice,
    pub engine: EngineChoice,
    /// Test-accuracy targets for time-to-accuracy reporting (Table 1).
    pub targets: Vec<f64>,
    /// Augmentation on/off.
    pub augment: bool,
    /// Output directory for metrics CSVs.
    pub out_dir: String,
    /// Max width hint for schedule scaling (0 = derive from model).
    pub sched_width: usize,
    /// Async factor-refresh pipeline settings (`[pipeline]` section).
    pub pipeline: PipelineConfig,
    /// Per-strategy epoch-indexed sketch schedules (`[schedules]` section),
    /// applied through `Decomposition::tune` at every epoch boundary.
    /// Empty = the global §5 block only (the pre-override behaviour).
    pub schedules: StrategySchedules,
    /// Tracing/metrics settings (`[obs]` section, `--obs` on the CLI).
    /// Recording is off by default and, when on, is strictly read-only with
    /// respect to training (see the [`crate::obs`] module docs).
    pub obs: ObsConfig,
    /// Dense-linalg backend selection (`[linalg]` section). Installed
    /// process-wide by `Session` before the first kernel runs.
    pub linalg: LinalgConfig,
    /// Factored G-side solve policy (`[factored]` section). Off by
    /// default; resolved into an `optim::FactoredPolicy` by the session.
    pub factored: FactoredConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            solver: "rs-kfac".into(),
            epochs: 10,
            batch: 128,
            seed: 0,
            model: ModelChoice::Mlp { widths: vec![768, 256, 256, 10] },
            data: DataChoice::Synthetic { n_train: 2560, n_test: 512, height: 16, width: 16, channels: 3 },
            engine: EngineChoice::Native,
            targets: vec![0.80, 0.85, 0.88],
            augment: false,
            out_dir: "results".into(),
            sched_width: 0,
            pipeline: PipelineConfig::default(),
            schedules: StrategySchedules::default(),
            obs: ObsConfig::default(),
            linalg: LinalgConfig::default(),
            factored: FactoredConfig::default(),
        }
    }
}

impl TrainConfig {
    /// Load from a TOML-subset file.
    pub fn from_file(path: &str) -> Result<TrainConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    /// Lenient legacy parse: unknown keys and wrong-typed values are
    /// ignored, kind-less `[model]`/`[data]` sections are skipped. Shares
    /// [`apply_config`] with the strict layer-citing resolver in
    /// `coordinator::experiment` — the two mappings cannot drift because
    /// they are one mapping parameterized over a [`ConfigSource`].
    pub fn from_toml(text: &str) -> Result<TrainConfig> {
        let doc = parse_toml(text)?;
        apply_config(&LenientDoc(&doc))
    }

    /// Input feature dimension implied by the data choice.
    pub fn input_dim(&self) -> usize {
        match &self.data {
            DataChoice::Synthetic { height, width, channels, .. } => channels * height * width,
            DataChoice::Cifar { .. } => 3072,
        }
    }
}

/// One key/value view over a configuration, parameterized over error
/// semantics. There are exactly two implementations:
///
/// - [`LenientDoc`] — the legacy `TrainConfig::from_toml` behaviour:
///   wrong-typed values read as absent, inapplicable keys are ignored,
///   errors carry no provenance (deliberate, so embedders whose documents
///   contain out-of-tree keys keep working);
/// - the strict `Merged` view in `coordinator::experiment` — type
///   mismatches and dangling companion keys error, citing the config
///   layer that set the offending value.
///
/// [`apply_config`] is the *single* section-by-section mapping onto
/// [`TrainConfig`], shared by both — the two parsers cannot drift apart
/// because there is only one.
pub(crate) trait ConfigSource {
    fn str_of(&self, key: &str) -> Result<Option<String>>;
    fn usize_of(&self, key: &str) -> Result<Option<usize>>;
    fn f64_of(&self, key: &str) -> Result<Option<f64>>;
    fn bool_of(&self, key: &str) -> Result<Option<bool>>;
    fn usize_vec_of(&self, key: &str) -> Result<Option<Vec<usize>>>;
    fn f64_vec_of(&self, key: &str) -> Result<Option<Vec<f64>>>;

    fn u64_of(&self, key: &str) -> Result<Option<u64>> {
        Ok(self.usize_of(key)?.map(|v| v as u64))
    }

    /// The `[schedules]` section keys (bare, without the section prefix).
    fn schedules(&self) -> BTreeMap<String, TomlVal>;

    /// Enforce that `key`, if present, is meaningful under the resolved
    /// value of its controlling `controller` key (e.g. `model.widths`
    /// under `model.kind = "mlp"`). Lenient sources ignore inapplicable
    /// keys (the legacy contract); the strict source errors with a layer
    /// cite unless a higher-precedence layer superseded the controller.
    fn require_applicable(
        &self,
        key: &str,
        applies: bool,
        controller: &str,
        requirement: &str,
    ) -> Result<()>;

    /// Error for an invalid value at `key` (unknown kind, bad enum). Both
    /// sources error; the strict one appends the layer cite.
    fn invalid(&self, key: &str, msg: String) -> anyhow::Error;
}

/// The lenient legacy [`ConfigSource`] over a parsed TOML document.
pub(crate) struct LenientDoc<'a>(pub(crate) &'a TomlDoc);

impl LenientDoc<'_> {
    fn val(&self, key: &str) -> Option<&TomlVal> {
        let (section, name) = key.split_once('.').unwrap_or(("", key));
        self.0.get(section).and_then(|s| s.get(name))
    }
}

impl ConfigSource for LenientDoc<'_> {
    fn str_of(&self, key: &str) -> Result<Option<String>> {
        Ok(self.val(key).and_then(TomlVal::as_str).map(str::to_string))
    }

    fn usize_of(&self, key: &str) -> Result<Option<usize>> {
        Ok(self.val(key).and_then(TomlVal::as_usize))
    }

    fn f64_of(&self, key: &str) -> Result<Option<f64>> {
        Ok(self.val(key).and_then(TomlVal::as_f64))
    }

    fn bool_of(&self, key: &str) -> Result<Option<bool>> {
        Ok(self.val(key).and_then(TomlVal::as_bool))
    }

    fn usize_vec_of(&self, key: &str) -> Result<Option<Vec<usize>>> {
        Ok(self.val(key).and_then(TomlVal::as_usize_vec))
    }

    fn f64_vec_of(&self, key: &str) -> Result<Option<Vec<f64>>> {
        Ok(self.val(key).and_then(TomlVal::as_f64_vec))
    }

    fn schedules(&self) -> BTreeMap<String, TomlVal> {
        self.0.get("schedules").cloned().unwrap_or_default()
    }

    fn require_applicable(
        &self,
        _key: &str,
        _applies: bool,
        _controller: &str,
        _requirement: &str,
    ) -> Result<()> {
        Ok(())
    }

    fn invalid(&self, _key: &str, msg: String) -> anyhow::Error {
        anyhow!("{msg}")
    }
}

/// The one TOML→[`TrainConfig`] mapping, section by section. Both the
/// lenient legacy `from_toml` and the strict experiment resolver call
/// this; their different error semantics live entirely in the
/// [`ConfigSource`] implementations (pinned against each other by
/// `experiment::tests::resolver_matches_legacy_from_toml`).
pub(crate) fn apply_config<S: ConfigSource>(src: &S) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();

    // [train]
    if let Some(v) = src.str_of("train.solver")? {
        cfg.solver = v;
    }
    if let Some(v) = src.usize_of("train.epochs")? {
        cfg.epochs = v;
    }
    if let Some(v) = src.usize_of("train.batch")? {
        cfg.batch = v;
    }
    if let Some(v) = src.u64_of("train.seed")? {
        cfg.seed = v;
    }
    if let Some(v) = src.f64_vec_of("train.targets")? {
        cfg.targets = v;
    }
    if let Some(v) = src.bool_of("train.augment")? {
        cfg.augment = v;
    }
    if let Some(v) = src.str_of("train.out_dir")? {
        cfg.out_dir = v;
    }
    if let Some(v) = src.usize_of("train.sched_width")? {
        cfg.sched_width = v;
    }

    // [model]
    let model_kind = src.str_of("model.kind")?;
    match model_kind.as_deref() {
        Some("mlp") => {
            let widths = src.usize_vec_of("model.widths")?.ok_or_else(|| {
                src.invalid("model.kind", "model.kind = \"mlp\" requires model.widths".into())
            })?;
            cfg.model = ModelChoice::Mlp { widths };
        }
        Some("vgg16_bn") => {
            cfg.model = ModelChoice::Vgg16Bn {
                scale_div: src.usize_of("model.scale_div")?.unwrap_or(8),
            };
        }
        Some(other) => {
            return Err(src.invalid("model.kind", format!("unknown model kind '{other}'")))
        }
        None => {}
    }
    src.require_applicable(
        "model.widths",
        model_kind.as_deref() == Some("mlp"),
        "model.kind",
        "model.kind = \"mlp\"",
    )?;
    src.require_applicable(
        "model.scale_div",
        model_kind.as_deref() == Some("vgg16_bn"),
        "model.kind",
        "model.kind = \"vgg16_bn\"",
    )?;

    // [data]
    let data_kind = src.str_of("data.kind")?;
    match data_kind.as_deref() {
        Some("synthetic") => {
            cfg.data = DataChoice::Synthetic {
                n_train: src.usize_of("data.n_train")?.unwrap_or(2560),
                n_test: src.usize_of("data.n_test")?.unwrap_or(512),
                height: src.usize_of("data.height")?.unwrap_or(16),
                width: src.usize_of("data.width")?.unwrap_or(16),
                channels: src.usize_of("data.channels")?.unwrap_or(3),
            };
        }
        Some("cifar") => {
            cfg.data = DataChoice::Cifar {
                root: src
                    .str_of("data.root")?
                    .unwrap_or_else(|| "data/cifar-10-batches-bin".to_string()),
                n_train: src.usize_of("data.n_train")?.unwrap_or(50000),
                n_test: src.usize_of("data.n_test")?.unwrap_or(10000),
            };
        }
        Some(other) => {
            return Err(src.invalid("data.kind", format!("unknown data kind '{other}'")))
        }
        None => {}
    }
    if data_kind.is_none() {
        // The lenient parser ignores a kind-less [data] section, so the
        // strict source must refuse its keys rather than guess a dataset.
        for key in ["data.n_train", "data.n_test", "data.height", "data.width", "data.channels"] {
            src.require_applicable(
                key,
                false,
                "data.kind",
                "an explicit data.kind (\"synthetic\" or \"cifar\")",
            )?;
        }
    }
    src.require_applicable(
        "data.root",
        data_kind.as_deref() == Some("cifar"),
        "data.kind",
        "data.kind = \"cifar\"",
    )?;
    if data_kind.as_deref() == Some("cifar") {
        for key in ["data.height", "data.width", "data.channels"] {
            src.require_applicable(key, false, "data.kind", "data.kind = \"synthetic\"")?;
        }
    }

    // [engine]
    let engine_kind = src.str_of("engine.kind")?;
    match engine_kind.as_deref() {
        Some("native") | None => {}
        Some("pjrt") => {
            cfg.engine = EngineChoice::Pjrt {
                config: src.str_of("engine.config")?.unwrap_or_else(|| "quick".to_string()),
            };
        }
        Some(other) => {
            return Err(src.invalid("engine.kind", format!("unknown engine kind '{other}'")))
        }
    }
    src.require_applicable(
        "engine.config",
        engine_kind.as_deref() == Some("pjrt"),
        "engine.kind",
        "engine.kind = \"pjrt\"",
    )?;

    // [pipeline]
    if let Some(v) = src.bool_of("pipeline.enabled")? {
        cfg.pipeline.enabled = v;
    }
    if let Some(v) = src.usize_of("pipeline.workers")? {
        cfg.pipeline.workers = v;
    }
    if let Some(v) = src.usize_of("pipeline.max_stale_steps")? {
        cfg.pipeline.max_stale_steps = v;
    }
    if let Some(v) = src.str_of("pipeline.schedule")? {
        cfg.pipeline.schedule = Schedule::parse(&v).ok_or_else(|| {
            src.invalid(
                "pipeline.schedule",
                format!("unknown [pipeline] schedule '{v}' (expected \"flops-stale\" or \"fifo\")"),
            )
        })?;
    }
    if let Some(v) = src.bool_of("pipeline.adaptive_rank")? {
        cfg.pipeline.adaptive_rank = v;
    }
    if let Some(v) = src.bool_of("pipeline.adaptive_sketch")? {
        cfg.pipeline.adaptive_sketch = v;
    }
    if let Some(v) = src.f64_of("pipeline.target_rel_err")? {
        cfg.pipeline.target_rel_err = v;
    }
    if let Some(v) = src.usize_of("pipeline.min_rank")? {
        cfg.pipeline.min_rank = v;
    }
    if let Some(v) = src.f64_of("pipeline.growth")? {
        cfg.pipeline.growth = v;
    }
    if let Some(v) = src.usize_of("pipeline.prop31_batch")? {
        cfg.pipeline.prop31_batch = v;
    }
    if let Some(v) = src.str_of("pipeline.transport")? {
        cfg.pipeline.transport = TransportKind::parse(&v).ok_or_else(|| {
            src.invalid(
                "pipeline.transport",
                format!(
                    "unknown [pipeline] transport '{v}' (expected \"local\", \"tcp\", or \"dir\")"
                ),
            )
        })?;
    }
    if let Some(v) = src.str_of("pipeline.endpoint")? {
        cfg.pipeline.endpoint = v;
    }
    if let Some(v) = src.u64_of("pipeline.connect_timeout_ms")? {
        cfg.pipeline.connect_timeout_ms = v;
    }
    if let Some(v) = src.u64_of("pipeline.io_timeout_ms")? {
        cfg.pipeline.io_timeout_ms = v;
    }
    if let Some(v) = src.u64_of("pipeline.max_retries")? {
        cfg.pipeline.max_retries = v.min(u32::MAX as u64) as u32;
    }
    if let Some(v) = src.str_of("pipeline.online")? {
        cfg.pipeline.online = OnlineMode::parse(&v).ok_or_else(|| {
            src.invalid(
                "pipeline.online",
                format!(
                    "unknown [pipeline] online mode '{v}' (expected \"off\", \"rsvd\", or \
                     \"auto\")"
                ),
            )
        })?;
    }
    if let Some(v) = src.usize_of("pipeline.correction_every")? {
        if v == 0 {
            return Err(src.invalid(
                "pipeline.correction_every",
                "correction_every must be ≥ 1 (1 = full decomposition every round)".to_string(),
            ));
        }
        cfg.pipeline.correction_every = v;
    }
    if cfg.pipeline.transport != TransportKind::Local && cfg.pipeline.endpoint.is_empty() {
        return Err(src.invalid(
            "pipeline.endpoint",
            format!(
                "transport \"{}\" needs an endpoint (host:port for tcp, a directory for dir)",
                cfg.pipeline.transport.name()
            ),
        ));
    }

    // [linalg]
    if let Some(v) = src.str_of("linalg.backend")? {
        cfg.linalg.backend = BackendKind::parse(&v).ok_or_else(|| {
            src.invalid(
                "linalg.backend",
                format!(
                    "unknown [linalg] backend '{v}' (expected \"reference\" or \"threaded\")"
                ),
            )
        })?;
    }
    if let Some(v) = src.usize_of("linalg.threads")? {
        cfg.linalg.threads = v;
    }
    if let Some(v) = src.str_of("linalg.precision")? {
        cfg.linalg.precision = Precision::parse(&v).ok_or_else(|| {
            src.invalid(
                "linalg.precision",
                format!("unknown [linalg] precision '{v}' (expected \"f64\" or \"mixed\")"),
            )
        })?;
    }

    // [factored]
    if let Some(v) = src.str_of("factored.mode")? {
        if !["off", "all", "hybrid"].contains(&v.as_str()) {
            return Err(src.invalid(
                "factored.mode",
                format!(
                    "unknown [factored] mode '{v}' (expected \"off\", \"all\", or \"hybrid\")"
                ),
            ));
        }
        cfg.factored.mode = v;
    }
    if let Some(v) = src.usize_of("factored.width_threshold")? {
        cfg.factored.width_threshold = v;
    }
    if let Some(v) = src.str_of("factored.core")? {
        cfg.factored.core = v;
    }
    if let Some(v) = src.usize_of("factored.max_cols")? {
        if v == 0 {
            return Err(src.invalid(
                "factored.max_cols",
                "factored.max_cols must be at least 1 (the retained-column window)".into(),
            ));
        }
        cfg.factored.max_cols = v;
    }
    if let Some(v) = src.usize_of("factored.col_sample")? {
        cfg.factored.col_sample = v;
    }
    if cfg.factored.mode != "off" && cfg.pipeline.enabled {
        // Factored G-side state is inline-only: retained-U jobs do not
        // ship over the factor transport wire format (a dense o×o result
        // slot is exactly what the factored path never materializes).
        return Err(src.invalid(
            "factored.mode",
            format!(
                "factored.mode = \"{}\" is incompatible with pipeline.enabled = true: factored \
                 G-side refreshes are inline-only — retained-U jobs do not ship over the factor \
                 transport wire format; disable the [pipeline] section for factored runs",
                cfg.factored.mode
            ),
        ));
    }

    // [obs]
    if let Some(v) = src.bool_of("obs.enabled")? {
        cfg.obs.enabled = v;
    }
    if let Some(v) = src.bool_of("obs.jsonl")? {
        cfg.obs.jsonl = v;
    }
    if let Some(v) = src.bool_of("obs.chrome_trace")? {
        cfg.obs.chrome_trace = v;
    }
    if let Some(v) = src.bool_of("obs.summary")? {
        cfg.obs.summary = v;
    }

    // [schedules] (free-form; validated by its own parser)
    let sched_map = src.schedules();
    if !sched_map.is_empty() {
        cfg.schedules = parse_schedules_section(&sched_map)?;
    }

    Ok(cfg)
}

/// The `[schedules]` key fields recognized per strategy; anything else in
/// the section is rejected with this list in the error.
const SCHED_FIELDS: [&str; 5] = [
    "oversample_base",
    "oversample_steps",
    "power_iter_base",
    "power_iter_steps",
    "target_rel_err",
];

/// Split a `[schedules]` key of the form `<strategy>_<field>` on the known
/// field suffixes (strategy keys may themselves contain underscores).
fn split_sched_key(key: &str) -> Result<(&str, &str)> {
    for field in SCHED_FIELDS {
        if let Some(strategy) =
            key.strip_suffix(field).and_then(|p| p.strip_suffix('_')).filter(|s| !s.is_empty())
        {
            return Ok((strategy, field));
        }
    }
    bail!(
        "[schedules] unrecognized key '{key}' (expected <strategy>_<field> with field one of: {})",
        SCHED_FIELDS.join(", ")
    )
}

/// Parse a flat `[e0, d0, e1, d1, …]` array into `StepSchedule` steps.
fn parse_step_pairs(key: &str, v: &TomlVal) -> Result<Vec<(usize, f64)>> {
    let arr = match v {
        TomlVal::Arr(a) => a,
        _ => bail!("[schedules] {key}: expected a flat [epoch, delta, …] array"),
    };
    if arr.len() % 2 != 0 {
        bail!("[schedules] {key}: flat (epoch, delta) list must have even length");
    }
    let mut out = Vec::with_capacity(arr.len() / 2);
    for pair in arr.chunks(2) {
        let e = pair[0]
            .as_usize()
            .ok_or_else(|| anyhow!("[schedules] {key}: epoch must be a non-negative integer"))?;
        let d = pair[1]
            .as_f64()
            .ok_or_else(|| anyhow!("[schedules] {key}: delta must be numeric"))?;
        out.push((e, d));
    }
    Ok(out)
}

/// Parse the `[schedules]` section: `<strategy>_oversample_base = 10`,
/// `<strategy>_oversample_steps = [22, 1, 30, 1]` (flat epoch/delta
/// pairs — deltas may be negative), `<strategy>_power_iter_{base,steps}`,
/// `<strategy>_target_rel_err`.
pub fn parse_schedules_section(sec: &BTreeMap<String, TomlVal>) -> Result<StrategySchedules> {
    #[derive(Default)]
    struct Partial {
        os_base: Option<f64>,
        os_steps: Option<Vec<(usize, f64)>>,
        pi_base: Option<f64>,
        pi_steps: Option<Vec<(usize, f64)>>,
        target: Option<f64>,
    }
    let mut partials: BTreeMap<String, Partial> = BTreeMap::new();
    for (key, val) in sec {
        let (strategy, field) = split_sched_key(key)?;
        let numeric =
            || val.as_f64().ok_or_else(|| anyhow!("[schedules] {key}: expected a number"));
        let p = partials.entry(strategy.to_string()).or_default();
        match field {
            "oversample_base" => p.os_base = Some(numeric()?),
            "oversample_steps" => p.os_steps = Some(parse_step_pairs(key, val)?),
            "power_iter_base" => p.pi_base = Some(numeric()?),
            "power_iter_steps" => p.pi_steps = Some(parse_step_pairs(key, val)?),
            "target_rel_err" => p.target = Some(numeric()?),
            _ => unreachable!("split_sched_key only returns known fields"),
        }
    }
    let mut set = StrategySchedules::default();
    for (strategy, p) in partials {
        let assemble = |base: Option<f64>, steps: Option<Vec<(usize, f64)>>, what: &str| {
            match (base, steps) {
                (Some(b), steps) => Ok(Some(StepSchedule::new(b, steps.unwrap_or_default()))),
                (None, Some(_)) => Err(anyhow!(
                    "[schedules] {strategy}_{what}_steps requires {strategy}_{what}_base"
                )),
                (None, None) => Ok(None),
            }
        };
        set.insert(
            &strategy,
            StrategySchedule {
                oversample: assemble(p.os_base, p.os_steps, "oversample")?,
                power_iter: assemble(p.pi_base, p.pi_steps, "power_iter")?,
                target_rel_err: p.target,
            },
        );
    }
    Ok(set)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# Table-1 style run
[train]
solver = "rs-kfac"
epochs = 12
batch = 64
seed = 3
targets = [0.8, 0.85]
augment = true
out_dir = "results/t1"

[model]
kind = "mlp"
widths = [768, 512, 10]

[data]
kind = "synthetic"
n_train = 1000
n_test = 200
height = 16
width = 16

[engine]
kind = "pjrt"
config = "quick"
"#;

    #[test]
    fn parses_full_config() {
        let cfg = TrainConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.solver, "rs-kfac");
        assert_eq!(cfg.epochs, 12);
        assert_eq!(cfg.batch, 64);
        assert_eq!(cfg.seed, 3);
        assert_eq!(cfg.targets, vec![0.8, 0.85]);
        assert!(cfg.augment);
        assert_eq!(cfg.model, ModelChoice::Mlp { widths: vec![768, 512, 10] });
        assert_eq!(
            cfg.data,
            DataChoice::Synthetic { n_train: 1000, n_test: 200, height: 16, width: 16, channels: 3 }
        );
        assert_eq!(cfg.engine, EngineChoice::Pjrt { config: "quick".into() });
        assert_eq!(cfg.input_dim(), 768);
    }

    #[test]
    fn defaults_without_sections() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.solver, "rs-kfac");
        assert_eq!(cfg.engine, EngineChoice::Native);
        assert!(!cfg.pipeline.enabled);
        assert_eq!(cfg.pipeline, PipelineConfig::default());
    }

    #[test]
    fn parses_pipeline_section() {
        let toml = r#"
[pipeline]
enabled = true
workers = 3
max_stale_steps = 4
schedule = "fifo"
adaptive_rank = true
adaptive_sketch = true
target_rel_err = 0.05
min_rank = 12
growth = 2.0
prop31_batch = 64
transport = "tcp"
endpoint = "127.0.0.1:7070"
connect_timeout_ms = 250
io_timeout_ms = 900
max_retries = 5
online = "rsvd"
correction_every = 8
"#;
        let cfg = TrainConfig::from_toml(toml).unwrap();
        assert!(cfg.pipeline.enabled);
        assert_eq!(cfg.pipeline.workers, 3);
        assert_eq!(cfg.pipeline.max_stale_steps, 4);
        assert_eq!(cfg.pipeline.schedule, Schedule::Fifo);
        assert!(cfg.pipeline.adaptive_rank);
        assert!(cfg.pipeline.adaptive_sketch);
        assert!((cfg.pipeline.target_rel_err - 0.05).abs() < 1e-12);
        assert_eq!(cfg.pipeline.min_rank, 12);
        assert!((cfg.pipeline.growth - 2.0).abs() < 1e-12);
        assert_eq!(cfg.pipeline.prop31_batch, 64);
        assert_eq!(cfg.pipeline.transport, TransportKind::Tcp);
        assert_eq!(cfg.pipeline.endpoint, "127.0.0.1:7070");
        assert_eq!(cfg.pipeline.connect_timeout_ms, 250);
        assert_eq!(cfg.pipeline.io_timeout_ms, 900);
        assert_eq!(cfg.pipeline.max_retries, 5);
        assert_eq!(cfg.pipeline.online, crate::pipeline::OnlineMode::Rsvd);
        assert_eq!(cfg.pipeline.correction_every, 8);
    }

    #[test]
    fn online_mode_validation() {
        // The default is off: recompute-from-scratch semantics untouched.
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.pipeline.online, crate::pipeline::OnlineMode::Off);
        let err = TrainConfig::from_toml("[pipeline]\nonline = \"turbo\"")
            .unwrap_err()
            .to_string();
        assert!(err.contains("expected \"off\", \"rsvd\", or \"auto\""), "{err}");
        let err = TrainConfig::from_toml("[pipeline]\ncorrection_every = 0")
            .unwrap_err()
            .to_string();
        assert!(err.contains("must be ≥ 1"), "{err}");
        let cfg = TrainConfig::from_toml("[pipeline]\nonline = \"auto\"").unwrap();
        assert_eq!(cfg.pipeline.online, crate::pipeline::OnlineMode::Auto);
    }

    #[test]
    fn transport_validation() {
        // Unknown transport name is rejected with the expected-values hint.
        let err =
            TrainConfig::from_toml("[pipeline]\ntransport = \"udp\"").unwrap_err().to_string();
        assert!(err.contains("expected \"local\", \"tcp\", or \"dir\""), "{err}");
        // A remote transport without an endpoint is a config error…
        let err = TrainConfig::from_toml("[pipeline]\ntransport = \"dir\"").unwrap_err().to_string();
        assert!(err.contains("needs an endpoint"), "{err}");
        // …while local needs none (the default).
        let cfg = TrainConfig::from_toml("[pipeline]\ntransport = \"local\"").unwrap();
        assert_eq!(cfg.pipeline.transport, TransportKind::Local);
        let cfg = TrainConfig::from_toml("[pipeline]\ntransport = \"dir\"\nendpoint = \"/tmp/m\"")
            .unwrap();
        assert_eq!(cfg.pipeline.transport, TransportKind::Dir);
        assert_eq!(cfg.pipeline.endpoint, "/tmp/m");
    }

    #[test]
    fn parses_obs_section() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert!(!cfg.obs.enabled, "obs is off by default");
        let cfg = TrainConfig::from_toml("[obs]\nenabled = true\nchrome_trace = false\n").unwrap();
        assert!(cfg.obs.enabled);
        assert!(cfg.obs.jsonl, "unset flags keep their defaults");
        assert!(!cfg.obs.chrome_trace);
        assert!(cfg.obs.summary);
    }

    #[test]
    fn toml_scalar_types() {
        let doc = parse_toml("a = 1\nb = 2.5\nc = \"x\"\nd = true\ne = [1, 2, 3]\n").unwrap();
        let root = &doc[""];
        assert_eq!(root["a"], TomlVal::Int(1));
        assert_eq!(root["b"], TomlVal::Float(2.5));
        assert_eq!(root["c"], TomlVal::Str("x".into()));
        assert_eq!(root["d"], TomlVal::Bool(true));
        assert_eq!(root["e"].as_usize_vec(), Some(vec![1, 2, 3]));
    }

    #[test]
    fn rejects_bad_syntax() {
        assert!(parse_toml("[unclosed").is_err());
        assert!(parse_toml("novalue").is_err());
        assert!(parse_toml("x = @@").is_err());
        assert!(TrainConfig::from_toml("[model]\nkind = \"resnet\"").is_err());
        assert!(TrainConfig::from_toml("[pipeline]\nschedule = \"lifo\"").is_err());
    }

    #[test]
    fn schedule_defaults_to_flops_stale() {
        let cfg = TrainConfig::from_toml("[pipeline]\nenabled = true\n").unwrap();
        assert_eq!(cfg.pipeline.schedule, Schedule::FlopsStale);
        let cfg2 =
            TrainConfig::from_toml("[pipeline]\nschedule = \"flops-stale\"\n").unwrap();
        assert_eq!(cfg2.pipeline.schedule, Schedule::FlopsStale);
    }

    #[test]
    fn comments_ignored() {
        let doc = parse_toml("# top\na = 1 # trailing\n[s] # section\nb = 2\n").unwrap();
        assert_eq!(doc[""]["a"], TomlVal::Int(1));
        assert_eq!(doc["s"]["b"], TomlVal::Int(2));
    }

    /// Trailing inline comments after every value shape — including after
    /// a string whose *content* contains `#`, which the old prefix-scan
    /// comment stripper rejected as an unterminated string.
    #[test]
    fn trailing_comments_after_values() {
        let doc = parse_toml(
            "a = \"res#1\" # comment after a string containing '#'\n\
             b = [1, 2] # after an array\n\
             c = -3 # after a negative int\n\
             d = \"plain\"   # after a plain string\n",
        )
        .unwrap();
        let root = &doc[""];
        assert_eq!(root["a"], TomlVal::Str("res#1".into()));
        assert_eq!(root["b"].as_usize_vec(), Some(vec![1, 2]));
        assert_eq!(root["c"], TomlVal::Int(-3));
        assert_eq!(root["d"], TomlVal::Str("plain".into()));
    }

    /// Negative (and explicitly signed) numeric literals, bare and inside
    /// arrays — the `[schedules]` step deltas depend on these.
    #[test]
    fn negative_numeric_literals() {
        let doc = parse_toml(
            "i = -5\nf = -0.25\nexp = 1e-3\npos = +7\narr = [20, -20.0, 35, -0.04]\n",
        )
        .unwrap();
        let root = &doc[""];
        assert_eq!(root["i"], TomlVal::Int(-5));
        assert_eq!(root["f"], TomlVal::Float(-0.25));
        assert_eq!(root["exp"], TomlVal::Float(1e-3));
        assert_eq!(root["pos"], TomlVal::Int(7));
        assert_eq!(root["arr"].as_f64_vec(), Some(vec![20.0, -20.0, 35.0, -0.04]));
        // Negative where a non-negative integer is required stays rejected.
        assert_eq!(root["i"].as_usize(), None);
    }

    #[test]
    fn parses_schedules_section() {
        let toml = r#"
[schedules]
rsvd_oversample_base = 10      # paper r_l
rsvd_oversample_steps = [22, 1, 30, 1]
rsvd_power_iter_base = 4
rsvd_power_iter_steps = [30, -2]   # relax late power iters
rsvd_target_rel_err = 0.03
srevd_oversample_base = 6
"#;
        let cfg = TrainConfig::from_toml(toml).unwrap();
        assert_eq!(cfg.schedules.keys(), vec!["rsvd", "srevd"]);
        let r = cfg.schedules.get("rsvd").unwrap();
        assert_eq!(r.oversample.as_ref().unwrap().at(0), 10.0);
        assert_eq!(r.oversample.as_ref().unwrap().at(31), 12.0);
        assert_eq!(r.power_iter.as_ref().unwrap().at(29), 4.0);
        assert_eq!(r.power_iter.as_ref().unwrap().at(30), 2.0);
        assert_eq!(r.target_rel_err, Some(0.03));
        let s = cfg.schedules.get("srevd").unwrap();
        assert_eq!(s.oversample.as_ref().unwrap().at(50), 6.0);
        assert!(s.power_iter.is_none());
        // Default: empty set.
        assert!(TrainConfig::from_toml("").unwrap().schedules.is_empty());
    }

    #[test]
    fn schedules_section_rejects_malformed_keys() {
        for bad in [
            "[schedules]\nrsvd_oversample = 10\n",               // unknown field
            "[schedules]\n_oversample_base = 10\n",              // empty strategy
            "[schedules]\nrsvd_oversample_steps = [22, 1, 30]\n", // odd pair list
            "[schedules]\nrsvd_oversample_steps = [22, 1]\n",    // steps w/o base
            "[schedules]\nrsvd_power_iter_base = \"four\"\n",    // non-numeric
            "[schedules]\nrsvd_oversample_steps = [-1, 2]\n",    // negative epoch
        ] {
            assert!(TrainConfig::from_toml(bad).is_err(), "should reject: {bad}");
        }
    }
}
