//! Experiment configuration: a TOML-subset parser (no serde offline) plus
//! the typed `TrainConfig` the trainer consumes.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string
//! (`"…"`), integer, float, boolean, and homogeneous arrays (`[1, 2]`,
//! `["a", "b"]`); `#` comments. This covers everything in `configs/*.toml`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::pipeline::{PipelineConfig, Schedule};

/// A parsed TOML-subset value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlVal {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlVal>),
}

impl TomlVal {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlVal::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlVal::Float(f) => Some(*f),
            TomlVal::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlVal::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlVal::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        match self {
            TomlVal::Arr(a) => a.iter().map(TomlVal::as_usize).collect(),
            _ => None,
        }
    }

    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        match self {
            TomlVal::Arr(a) => a.iter().map(TomlVal::as_f64).collect(),
            _ => None,
        }
    }
}

/// Sections → keys → values.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlVal>>;

fn parse_value(raw: &str, line_no: usize) -> Result<TomlVal> {
    let raw = raw.trim();
    if raw.starts_with('"') {
        if !raw.ends_with('"') || raw.len() < 2 {
            bail!("line {line_no}: unterminated string");
        }
        return Ok(TomlVal::Str(raw[1..raw.len() - 1].to_string()));
    }
    if raw == "true" {
        return Ok(TomlVal::Bool(true));
    }
    if raw == "false" {
        return Ok(TomlVal::Bool(false));
    }
    if raw.starts_with('[') {
        if !raw.ends_with(']') {
            bail!("line {line_no}: unterminated array");
        }
        let inner = &raw[1..raw.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part, line_no)?);
            }
        }
        return Ok(TomlVal::Arr(items));
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(TomlVal::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(TomlVal::Float(f));
    }
    bail!("line {line_no}: cannot parse value '{raw}'")
}

/// Parse a TOML-subset document.
pub fn parse_toml(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::new();
    let mut section = String::new();
    doc.insert(String::new(), BTreeMap::new());
    for (i, raw_line) in text.lines().enumerate() {
        let line_no = i + 1;
        // Strip comments (naive: '#' not inside strings — our configs don't
        // use '#' in strings).
        let line = match raw_line.find('#') {
            Some(p) if !raw_line[..p].contains('"') || raw_line[..p].matches('"').count() % 2 == 0 => {
                &raw_line[..p]
            }
            _ => raw_line,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                bail!("line {line_no}: bad section header");
            }
            section = line[1..line.len() - 1].trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| anyhow!("line {line_no}: expected key = value"))?;
        let key = line[..eq].trim().to_string();
        let val = parse_value(&line[eq + 1..], line_no)?;
        doc.get_mut(&section).unwrap().insert(key, val);
    }
    Ok(doc)
}

/// Which compute engine drives fwd/bwd.
#[derive(Clone, Debug, PartialEq)]
pub enum EngineChoice {
    /// Native Rust nn (supports conv/BN; the oracle path).
    Native,
    /// PJRT artifacts compiled from the JAX model (`mlp_step_<name>`).
    Pjrt { config: String },
}

/// Which model to train.
#[derive(Clone, Debug, PartialEq)]
pub enum ModelChoice {
    Mlp { widths: Vec<usize> },
    Vgg16Bn { scale_div: usize },
}

/// Which dataset to use.
#[derive(Clone, Debug, PartialEq)]
pub enum DataChoice {
    Synthetic { n_train: usize, n_test: usize, height: usize, width: usize, channels: usize },
    Cifar { root: String, n_train: usize, n_test: usize },
}

/// Full experiment configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub solver: String,
    pub epochs: usize,
    pub batch: usize,
    pub seed: u64,
    pub model: ModelChoice,
    pub data: DataChoice,
    pub engine: EngineChoice,
    /// Test-accuracy targets for time-to-accuracy reporting (Table 1).
    pub targets: Vec<f64>,
    /// Augmentation on/off.
    pub augment: bool,
    /// Output directory for metrics CSVs.
    pub out_dir: String,
    /// Max width hint for schedule scaling (0 = derive from model).
    pub sched_width: usize,
    /// Async factor-refresh pipeline settings (`[pipeline]` section).
    pub pipeline: PipelineConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            solver: "rs-kfac".into(),
            epochs: 10,
            batch: 128,
            seed: 0,
            model: ModelChoice::Mlp { widths: vec![768, 256, 256, 10] },
            data: DataChoice::Synthetic { n_train: 2560, n_test: 512, height: 16, width: 16, channels: 3 },
            engine: EngineChoice::Native,
            targets: vec![0.80, 0.85, 0.88],
            augment: false,
            out_dir: "results".into(),
            sched_width: 0,
            pipeline: PipelineConfig::default(),
        }
    }
}

impl TrainConfig {
    /// Load from a TOML-subset file.
    pub fn from_file(path: &str) -> Result<TrainConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> Result<TrainConfig> {
        let doc = parse_toml(text)?;
        let mut cfg = TrainConfig::default();
        if let Some(train) = doc.get("train") {
            if let Some(v) = train.get("solver").and_then(TomlVal::as_str) {
                cfg.solver = v.to_string();
            }
            if let Some(v) = train.get("epochs").and_then(TomlVal::as_usize) {
                cfg.epochs = v;
            }
            if let Some(v) = train.get("batch").and_then(TomlVal::as_usize) {
                cfg.batch = v;
            }
            if let Some(v) = train.get("seed").and_then(TomlVal::as_usize) {
                cfg.seed = v as u64;
            }
            if let Some(v) = train.get("targets").and_then(TomlVal::as_f64_vec) {
                cfg.targets = v;
            }
            if let Some(v) = train.get("augment").and_then(TomlVal::as_bool) {
                cfg.augment = v;
            }
            if let Some(v) = train.get("out_dir").and_then(TomlVal::as_str) {
                cfg.out_dir = v.to_string();
            }
            if let Some(v) = train.get("sched_width").and_then(TomlVal::as_usize) {
                cfg.sched_width = v;
            }
        }
        if let Some(model) = doc.get("model") {
            match model.get("kind").and_then(TomlVal::as_str) {
                Some("mlp") => {
                    let widths = model
                        .get("widths")
                        .and_then(TomlVal::as_usize_vec)
                        .ok_or_else(|| anyhow!("[model] mlp requires widths"))?;
                    cfg.model = ModelChoice::Mlp { widths };
                }
                Some("vgg16_bn") => {
                    let scale_div =
                        model.get("scale_div").and_then(TomlVal::as_usize).unwrap_or(8);
                    cfg.model = ModelChoice::Vgg16Bn { scale_div };
                }
                Some(other) => bail!("unknown model kind '{other}'"),
                None => {}
            }
        }
        if let Some(data) = doc.get("data") {
            match data.get("kind").and_then(TomlVal::as_str) {
                Some("synthetic") => {
                    cfg.data = DataChoice::Synthetic {
                        n_train: data.get("n_train").and_then(TomlVal::as_usize).unwrap_or(2560),
                        n_test: data.get("n_test").and_then(TomlVal::as_usize).unwrap_or(512),
                        height: data.get("height").and_then(TomlVal::as_usize).unwrap_or(16),
                        width: data.get("width").and_then(TomlVal::as_usize).unwrap_or(16),
                        channels: data.get("channels").and_then(TomlVal::as_usize).unwrap_or(3),
                    };
                }
                Some("cifar") => {
                    cfg.data = DataChoice::Cifar {
                        root: data
                            .get("root")
                            .and_then(TomlVal::as_str)
                            .unwrap_or("data/cifar-10-batches-bin")
                            .to_string(),
                        n_train: data.get("n_train").and_then(TomlVal::as_usize).unwrap_or(50000),
                        n_test: data.get("n_test").and_then(TomlVal::as_usize).unwrap_or(10000),
                    };
                }
                Some(other) => bail!("unknown data kind '{other}'"),
                None => {}
            }
        }
        if let Some(pipe) = doc.get("pipeline") {
            if let Some(v) = pipe.get("enabled").and_then(TomlVal::as_bool) {
                cfg.pipeline.enabled = v;
            }
            if let Some(v) = pipe.get("workers").and_then(TomlVal::as_usize) {
                cfg.pipeline.workers = v;
            }
            if let Some(v) = pipe.get("max_stale_steps").and_then(TomlVal::as_usize) {
                cfg.pipeline.max_stale_steps = v;
            }
            if let Some(v) = pipe.get("schedule").and_then(TomlVal::as_str) {
                cfg.pipeline.schedule = match Schedule::parse(v) {
                    Some(s) => s,
                    None => bail!(
                        "unknown [pipeline] schedule '{v}' (expected \"flops-stale\" or \"fifo\")"
                    ),
                };
            }
            if let Some(v) = pipe.get("adaptive_rank").and_then(TomlVal::as_bool) {
                cfg.pipeline.adaptive_rank = v;
            }
            if let Some(v) = pipe.get("adaptive_sketch").and_then(TomlVal::as_bool) {
                cfg.pipeline.adaptive_sketch = v;
            }
            if let Some(v) = pipe.get("target_rel_err").and_then(TomlVal::as_f64) {
                cfg.pipeline.target_rel_err = v;
            }
            if let Some(v) = pipe.get("min_rank").and_then(TomlVal::as_usize) {
                cfg.pipeline.min_rank = v;
            }
            if let Some(v) = pipe.get("growth").and_then(TomlVal::as_f64) {
                cfg.pipeline.growth = v;
            }
            if let Some(v) = pipe.get("prop31_batch").and_then(TomlVal::as_usize) {
                cfg.pipeline.prop31_batch = v;
            }
        }
        if let Some(engine) = doc.get("engine") {
            match engine.get("kind").and_then(TomlVal::as_str) {
                Some("native") => cfg.engine = EngineChoice::Native,
                Some("pjrt") => {
                    cfg.engine = EngineChoice::Pjrt {
                        config: engine
                            .get("config")
                            .and_then(TomlVal::as_str)
                            .unwrap_or("quick")
                            .to_string(),
                    };
                }
                Some(other) => bail!("unknown engine kind '{other}'"),
                None => {}
            }
        }
        Ok(cfg)
    }

    /// Input feature dimension implied by the data choice.
    pub fn input_dim(&self) -> usize {
        match &self.data {
            DataChoice::Synthetic { height, width, channels, .. } => channels * height * width,
            DataChoice::Cifar { .. } => 3072,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# Table-1 style run
[train]
solver = "rs-kfac"
epochs = 12
batch = 64
seed = 3
targets = [0.8, 0.85]
augment = true
out_dir = "results/t1"

[model]
kind = "mlp"
widths = [768, 512, 10]

[data]
kind = "synthetic"
n_train = 1000
n_test = 200
height = 16
width = 16

[engine]
kind = "pjrt"
config = "quick"
"#;

    #[test]
    fn parses_full_config() {
        let cfg = TrainConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.solver, "rs-kfac");
        assert_eq!(cfg.epochs, 12);
        assert_eq!(cfg.batch, 64);
        assert_eq!(cfg.seed, 3);
        assert_eq!(cfg.targets, vec![0.8, 0.85]);
        assert!(cfg.augment);
        assert_eq!(cfg.model, ModelChoice::Mlp { widths: vec![768, 512, 10] });
        assert_eq!(
            cfg.data,
            DataChoice::Synthetic { n_train: 1000, n_test: 200, height: 16, width: 16, channels: 3 }
        );
        assert_eq!(cfg.engine, EngineChoice::Pjrt { config: "quick".into() });
        assert_eq!(cfg.input_dim(), 768);
    }

    #[test]
    fn defaults_without_sections() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.solver, "rs-kfac");
        assert_eq!(cfg.engine, EngineChoice::Native);
        assert!(!cfg.pipeline.enabled);
        assert_eq!(cfg.pipeline, PipelineConfig::default());
    }

    #[test]
    fn parses_pipeline_section() {
        let toml = r#"
[pipeline]
enabled = true
workers = 3
max_stale_steps = 4
schedule = "fifo"
adaptive_rank = true
adaptive_sketch = true
target_rel_err = 0.05
min_rank = 12
growth = 2.0
prop31_batch = 64
"#;
        let cfg = TrainConfig::from_toml(toml).unwrap();
        assert!(cfg.pipeline.enabled);
        assert_eq!(cfg.pipeline.workers, 3);
        assert_eq!(cfg.pipeline.max_stale_steps, 4);
        assert_eq!(cfg.pipeline.schedule, Schedule::Fifo);
        assert!(cfg.pipeline.adaptive_rank);
        assert!(cfg.pipeline.adaptive_sketch);
        assert!((cfg.pipeline.target_rel_err - 0.05).abs() < 1e-12);
        assert_eq!(cfg.pipeline.min_rank, 12);
        assert!((cfg.pipeline.growth - 2.0).abs() < 1e-12);
        assert_eq!(cfg.pipeline.prop31_batch, 64);
    }

    #[test]
    fn toml_scalar_types() {
        let doc = parse_toml("a = 1\nb = 2.5\nc = \"x\"\nd = true\ne = [1, 2, 3]\n").unwrap();
        let root = &doc[""];
        assert_eq!(root["a"], TomlVal::Int(1));
        assert_eq!(root["b"], TomlVal::Float(2.5));
        assert_eq!(root["c"], TomlVal::Str("x".into()));
        assert_eq!(root["d"], TomlVal::Bool(true));
        assert_eq!(root["e"].as_usize_vec(), Some(vec![1, 2, 3]));
    }

    #[test]
    fn rejects_bad_syntax() {
        assert!(parse_toml("[unclosed").is_err());
        assert!(parse_toml("novalue").is_err());
        assert!(parse_toml("x = @@").is_err());
        assert!(TrainConfig::from_toml("[model]\nkind = \"resnet\"").is_err());
        assert!(TrainConfig::from_toml("[pipeline]\nschedule = \"lifo\"").is_err());
    }

    #[test]
    fn schedule_defaults_to_flops_stale() {
        let cfg = TrainConfig::from_toml("[pipeline]\nenabled = true\n").unwrap();
        assert_eq!(cfg.pipeline.schedule, Schedule::FlopsStale);
        let cfg2 =
            TrainConfig::from_toml("[pipeline]\nschedule = \"flops-stale\"\n").unwrap();
        assert_eq!(cfg2.pipeline.schedule, Schedule::FlopsStale);
    }

    #[test]
    fn comments_ignored() {
        let doc = parse_toml("# top\na = 1 # trailing\n[s] # section\nb = 2\n").unwrap();
        assert_eq!(doc[""]["a"], TomlVal::Int(1));
        assert_eq!(doc["s"]["b"], TomlVal::Int(2));
    }
}
