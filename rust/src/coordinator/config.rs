//! Experiment configuration: a TOML-subset parser (no serde offline) plus
//! the typed `TrainConfig` the trainer consumes.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string
//! (`"…"`), integer, float, boolean, and homogeneous arrays (`[1, 2]`,
//! `["a", "b"]`); `#` comments. This covers everything in `configs/*.toml`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::optim::{StepSchedule, StrategySchedule, StrategySchedules};
use crate::pipeline::{PipelineConfig, Schedule};

/// A parsed TOML-subset value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlVal {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlVal>),
}

impl TomlVal {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlVal::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlVal::Float(f) => Some(*f),
            TomlVal::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlVal::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlVal::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        match self {
            TomlVal::Arr(a) => a.iter().map(TomlVal::as_usize).collect(),
            _ => None,
        }
    }

    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        match self {
            TomlVal::Arr(a) => a.iter().map(TomlVal::as_f64).collect(),
            _ => None,
        }
    }
}

/// Sections → keys → values.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlVal>>;

pub(crate) fn parse_value(raw: &str, line_no: usize) -> Result<TomlVal> {
    let raw = raw.trim();
    if raw.starts_with('"') {
        if !raw.ends_with('"') || raw.len() < 2 {
            bail!("line {line_no}: unterminated string");
        }
        return Ok(TomlVal::Str(raw[1..raw.len() - 1].to_string()));
    }
    if raw == "true" {
        return Ok(TomlVal::Bool(true));
    }
    if raw == "false" {
        return Ok(TomlVal::Bool(false));
    }
    if raw.starts_with('[') {
        if !raw.ends_with(']') {
            bail!("line {line_no}: unterminated array");
        }
        let inner = &raw[1..raw.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part, line_no)?);
            }
        }
        return Ok(TomlVal::Arr(items));
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(TomlVal::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(TomlVal::Float(f));
    }
    bail!("line {line_no}: cannot parse value '{raw}'")
}

/// Strip a trailing `#` comment from one line, honouring string literals:
/// the comment starts at the first `#` that is *outside* a double-quoted
/// string, so `out_dir = "res#1"  # trailing` keeps the `#` in the value
/// and still drops the comment.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse a TOML-subset document.
pub fn parse_toml(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::new();
    let mut section = String::new();
    doc.insert(String::new(), BTreeMap::new());
    for (i, raw_line) in text.lines().enumerate() {
        let line_no = i + 1;
        let line = strip_comment(raw_line).trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                bail!("line {line_no}: bad section header");
            }
            section = line[1..line.len() - 1].trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| anyhow!("line {line_no}: expected key = value"))?;
        let key = line[..eq].trim().to_string();
        let val = parse_value(&line[eq + 1..], line_no)?;
        doc.get_mut(&section).unwrap().insert(key, val);
    }
    Ok(doc)
}

/// Which compute engine drives fwd/bwd.
#[derive(Clone, Debug, PartialEq)]
pub enum EngineChoice {
    /// Native Rust nn (supports conv/BN; the oracle path).
    Native,
    /// PJRT artifacts compiled from the JAX model (`mlp_step_<name>`).
    Pjrt { config: String },
}

/// Which model to train.
#[derive(Clone, Debug, PartialEq)]
pub enum ModelChoice {
    Mlp { widths: Vec<usize> },
    Vgg16Bn { scale_div: usize },
}

/// Which dataset to use.
#[derive(Clone, Debug, PartialEq)]
pub enum DataChoice {
    Synthetic { n_train: usize, n_test: usize, height: usize, width: usize, channels: usize },
    Cifar { root: String, n_train: usize, n_test: usize },
}

/// Full experiment configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub solver: String,
    pub epochs: usize,
    pub batch: usize,
    pub seed: u64,
    pub model: ModelChoice,
    pub data: DataChoice,
    pub engine: EngineChoice,
    /// Test-accuracy targets for time-to-accuracy reporting (Table 1).
    pub targets: Vec<f64>,
    /// Augmentation on/off.
    pub augment: bool,
    /// Output directory for metrics CSVs.
    pub out_dir: String,
    /// Max width hint for schedule scaling (0 = derive from model).
    pub sched_width: usize,
    /// Async factor-refresh pipeline settings (`[pipeline]` section).
    pub pipeline: PipelineConfig,
    /// Per-strategy epoch-indexed sketch schedules (`[schedules]` section),
    /// applied through `Decomposition::tune` at every epoch boundary.
    /// Empty = the global §5 block only (the pre-override behaviour).
    pub schedules: StrategySchedules,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            solver: "rs-kfac".into(),
            epochs: 10,
            batch: 128,
            seed: 0,
            model: ModelChoice::Mlp { widths: vec![768, 256, 256, 10] },
            data: DataChoice::Synthetic { n_train: 2560, n_test: 512, height: 16, width: 16, channels: 3 },
            engine: EngineChoice::Native,
            targets: vec![0.80, 0.85, 0.88],
            augment: false,
            out_dir: "results".into(),
            sched_width: 0,
            pipeline: PipelineConfig::default(),
            schedules: StrategySchedules::default(),
        }
    }
}

impl TrainConfig {
    /// Load from a TOML-subset file.
    pub fn from_file(path: &str) -> Result<TrainConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> Result<TrainConfig> {
        let doc = parse_toml(text)?;
        let mut cfg = TrainConfig::default();
        if let Some(train) = doc.get("train") {
            if let Some(v) = train.get("solver").and_then(TomlVal::as_str) {
                cfg.solver = v.to_string();
            }
            if let Some(v) = train.get("epochs").and_then(TomlVal::as_usize) {
                cfg.epochs = v;
            }
            if let Some(v) = train.get("batch").and_then(TomlVal::as_usize) {
                cfg.batch = v;
            }
            if let Some(v) = train.get("seed").and_then(TomlVal::as_usize) {
                cfg.seed = v as u64;
            }
            if let Some(v) = train.get("targets").and_then(TomlVal::as_f64_vec) {
                cfg.targets = v;
            }
            if let Some(v) = train.get("augment").and_then(TomlVal::as_bool) {
                cfg.augment = v;
            }
            if let Some(v) = train.get("out_dir").and_then(TomlVal::as_str) {
                cfg.out_dir = v.to_string();
            }
            if let Some(v) = train.get("sched_width").and_then(TomlVal::as_usize) {
                cfg.sched_width = v;
            }
        }
        if let Some(model) = doc.get("model") {
            match model.get("kind").and_then(TomlVal::as_str) {
                Some("mlp") => {
                    let widths = model
                        .get("widths")
                        .and_then(TomlVal::as_usize_vec)
                        .ok_or_else(|| anyhow!("[model] mlp requires widths"))?;
                    cfg.model = ModelChoice::Mlp { widths };
                }
                Some("vgg16_bn") => {
                    let scale_div =
                        model.get("scale_div").and_then(TomlVal::as_usize).unwrap_or(8);
                    cfg.model = ModelChoice::Vgg16Bn { scale_div };
                }
                Some(other) => bail!("unknown model kind '{other}'"),
                None => {}
            }
        }
        if let Some(data) = doc.get("data") {
            match data.get("kind").and_then(TomlVal::as_str) {
                Some("synthetic") => {
                    cfg.data = DataChoice::Synthetic {
                        n_train: data.get("n_train").and_then(TomlVal::as_usize).unwrap_or(2560),
                        n_test: data.get("n_test").and_then(TomlVal::as_usize).unwrap_or(512),
                        height: data.get("height").and_then(TomlVal::as_usize).unwrap_or(16),
                        width: data.get("width").and_then(TomlVal::as_usize).unwrap_or(16),
                        channels: data.get("channels").and_then(TomlVal::as_usize).unwrap_or(3),
                    };
                }
                Some("cifar") => {
                    cfg.data = DataChoice::Cifar {
                        root: data
                            .get("root")
                            .and_then(TomlVal::as_str)
                            .unwrap_or("data/cifar-10-batches-bin")
                            .to_string(),
                        n_train: data.get("n_train").and_then(TomlVal::as_usize).unwrap_or(50000),
                        n_test: data.get("n_test").and_then(TomlVal::as_usize).unwrap_or(10000),
                    };
                }
                Some(other) => bail!("unknown data kind '{other}'"),
                None => {}
            }
        }
        if let Some(pipe) = doc.get("pipeline") {
            if let Some(v) = pipe.get("enabled").and_then(TomlVal::as_bool) {
                cfg.pipeline.enabled = v;
            }
            if let Some(v) = pipe.get("workers").and_then(TomlVal::as_usize) {
                cfg.pipeline.workers = v;
            }
            if let Some(v) = pipe.get("max_stale_steps").and_then(TomlVal::as_usize) {
                cfg.pipeline.max_stale_steps = v;
            }
            if let Some(v) = pipe.get("schedule").and_then(TomlVal::as_str) {
                cfg.pipeline.schedule = match Schedule::parse(v) {
                    Some(s) => s,
                    None => bail!(
                        "unknown [pipeline] schedule '{v}' (expected \"flops-stale\" or \"fifo\")"
                    ),
                };
            }
            if let Some(v) = pipe.get("adaptive_rank").and_then(TomlVal::as_bool) {
                cfg.pipeline.adaptive_rank = v;
            }
            if let Some(v) = pipe.get("adaptive_sketch").and_then(TomlVal::as_bool) {
                cfg.pipeline.adaptive_sketch = v;
            }
            if let Some(v) = pipe.get("target_rel_err").and_then(TomlVal::as_f64) {
                cfg.pipeline.target_rel_err = v;
            }
            if let Some(v) = pipe.get("min_rank").and_then(TomlVal::as_usize) {
                cfg.pipeline.min_rank = v;
            }
            if let Some(v) = pipe.get("growth").and_then(TomlVal::as_f64) {
                cfg.pipeline.growth = v;
            }
            if let Some(v) = pipe.get("prop31_batch").and_then(TomlVal::as_usize) {
                cfg.pipeline.prop31_batch = v;
            }
        }
        if let Some(sched) = doc.get("schedules") {
            cfg.schedules = parse_schedules_section(sched)?;
        }
        if let Some(engine) = doc.get("engine") {
            match engine.get("kind").and_then(TomlVal::as_str) {
                Some("native") => cfg.engine = EngineChoice::Native,
                Some("pjrt") => {
                    cfg.engine = EngineChoice::Pjrt {
                        config: engine
                            .get("config")
                            .and_then(TomlVal::as_str)
                            .unwrap_or("quick")
                            .to_string(),
                    };
                }
                Some(other) => bail!("unknown engine kind '{other}'"),
                None => {}
            }
        }
        Ok(cfg)
    }

    /// Input feature dimension implied by the data choice.
    pub fn input_dim(&self) -> usize {
        match &self.data {
            DataChoice::Synthetic { height, width, channels, .. } => channels * height * width,
            DataChoice::Cifar { .. } => 3072,
        }
    }
}

/// The `[schedules]` key fields recognized per strategy; anything else in
/// the section is rejected with this list in the error.
const SCHED_FIELDS: [&str; 5] = [
    "oversample_base",
    "oversample_steps",
    "power_iter_base",
    "power_iter_steps",
    "target_rel_err",
];

/// Split a `[schedules]` key of the form `<strategy>_<field>` on the known
/// field suffixes (strategy keys may themselves contain underscores).
fn split_sched_key(key: &str) -> Result<(&str, &str)> {
    for field in SCHED_FIELDS {
        if let Some(strategy) =
            key.strip_suffix(field).and_then(|p| p.strip_suffix('_')).filter(|s| !s.is_empty())
        {
            return Ok((strategy, field));
        }
    }
    bail!(
        "[schedules] unrecognized key '{key}' (expected <strategy>_<field> with field one of: {})",
        SCHED_FIELDS.join(", ")
    )
}

/// Parse a flat `[e0, d0, e1, d1, …]` array into `StepSchedule` steps.
fn parse_step_pairs(key: &str, v: &TomlVal) -> Result<Vec<(usize, f64)>> {
    let arr = match v {
        TomlVal::Arr(a) => a,
        _ => bail!("[schedules] {key}: expected a flat [epoch, delta, …] array"),
    };
    if arr.len() % 2 != 0 {
        bail!("[schedules] {key}: flat (epoch, delta) list must have even length");
    }
    let mut out = Vec::with_capacity(arr.len() / 2);
    for pair in arr.chunks(2) {
        let e = pair[0]
            .as_usize()
            .ok_or_else(|| anyhow!("[schedules] {key}: epoch must be a non-negative integer"))?;
        let d = pair[1]
            .as_f64()
            .ok_or_else(|| anyhow!("[schedules] {key}: delta must be numeric"))?;
        out.push((e, d));
    }
    Ok(out)
}

/// Parse the `[schedules]` section: `<strategy>_oversample_base = 10`,
/// `<strategy>_oversample_steps = [22, 1, 30, 1]` (flat epoch/delta
/// pairs — deltas may be negative), `<strategy>_power_iter_{base,steps}`,
/// `<strategy>_target_rel_err`.
pub fn parse_schedules_section(sec: &BTreeMap<String, TomlVal>) -> Result<StrategySchedules> {
    #[derive(Default)]
    struct Partial {
        os_base: Option<f64>,
        os_steps: Option<Vec<(usize, f64)>>,
        pi_base: Option<f64>,
        pi_steps: Option<Vec<(usize, f64)>>,
        target: Option<f64>,
    }
    let mut partials: BTreeMap<String, Partial> = BTreeMap::new();
    for (key, val) in sec {
        let (strategy, field) = split_sched_key(key)?;
        let numeric =
            || val.as_f64().ok_or_else(|| anyhow!("[schedules] {key}: expected a number"));
        let p = partials.entry(strategy.to_string()).or_default();
        match field {
            "oversample_base" => p.os_base = Some(numeric()?),
            "oversample_steps" => p.os_steps = Some(parse_step_pairs(key, val)?),
            "power_iter_base" => p.pi_base = Some(numeric()?),
            "power_iter_steps" => p.pi_steps = Some(parse_step_pairs(key, val)?),
            "target_rel_err" => p.target = Some(numeric()?),
            _ => unreachable!("split_sched_key only returns known fields"),
        }
    }
    let mut set = StrategySchedules::default();
    for (strategy, p) in partials {
        let assemble = |base: Option<f64>, steps: Option<Vec<(usize, f64)>>, what: &str| {
            match (base, steps) {
                (Some(b), steps) => Ok(Some(StepSchedule::new(b, steps.unwrap_or_default()))),
                (None, Some(_)) => Err(anyhow!(
                    "[schedules] {strategy}_{what}_steps requires {strategy}_{what}_base"
                )),
                (None, None) => Ok(None),
            }
        };
        set.insert(
            &strategy,
            StrategySchedule {
                oversample: assemble(p.os_base, p.os_steps, "oversample")?,
                power_iter: assemble(p.pi_base, p.pi_steps, "power_iter")?,
                target_rel_err: p.target,
            },
        );
    }
    Ok(set)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# Table-1 style run
[train]
solver = "rs-kfac"
epochs = 12
batch = 64
seed = 3
targets = [0.8, 0.85]
augment = true
out_dir = "results/t1"

[model]
kind = "mlp"
widths = [768, 512, 10]

[data]
kind = "synthetic"
n_train = 1000
n_test = 200
height = 16
width = 16

[engine]
kind = "pjrt"
config = "quick"
"#;

    #[test]
    fn parses_full_config() {
        let cfg = TrainConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.solver, "rs-kfac");
        assert_eq!(cfg.epochs, 12);
        assert_eq!(cfg.batch, 64);
        assert_eq!(cfg.seed, 3);
        assert_eq!(cfg.targets, vec![0.8, 0.85]);
        assert!(cfg.augment);
        assert_eq!(cfg.model, ModelChoice::Mlp { widths: vec![768, 512, 10] });
        assert_eq!(
            cfg.data,
            DataChoice::Synthetic { n_train: 1000, n_test: 200, height: 16, width: 16, channels: 3 }
        );
        assert_eq!(cfg.engine, EngineChoice::Pjrt { config: "quick".into() });
        assert_eq!(cfg.input_dim(), 768);
    }

    #[test]
    fn defaults_without_sections() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.solver, "rs-kfac");
        assert_eq!(cfg.engine, EngineChoice::Native);
        assert!(!cfg.pipeline.enabled);
        assert_eq!(cfg.pipeline, PipelineConfig::default());
    }

    #[test]
    fn parses_pipeline_section() {
        let toml = r#"
[pipeline]
enabled = true
workers = 3
max_stale_steps = 4
schedule = "fifo"
adaptive_rank = true
adaptive_sketch = true
target_rel_err = 0.05
min_rank = 12
growth = 2.0
prop31_batch = 64
"#;
        let cfg = TrainConfig::from_toml(toml).unwrap();
        assert!(cfg.pipeline.enabled);
        assert_eq!(cfg.pipeline.workers, 3);
        assert_eq!(cfg.pipeline.max_stale_steps, 4);
        assert_eq!(cfg.pipeline.schedule, Schedule::Fifo);
        assert!(cfg.pipeline.adaptive_rank);
        assert!(cfg.pipeline.adaptive_sketch);
        assert!((cfg.pipeline.target_rel_err - 0.05).abs() < 1e-12);
        assert_eq!(cfg.pipeline.min_rank, 12);
        assert!((cfg.pipeline.growth - 2.0).abs() < 1e-12);
        assert_eq!(cfg.pipeline.prop31_batch, 64);
    }

    #[test]
    fn toml_scalar_types() {
        let doc = parse_toml("a = 1\nb = 2.5\nc = \"x\"\nd = true\ne = [1, 2, 3]\n").unwrap();
        let root = &doc[""];
        assert_eq!(root["a"], TomlVal::Int(1));
        assert_eq!(root["b"], TomlVal::Float(2.5));
        assert_eq!(root["c"], TomlVal::Str("x".into()));
        assert_eq!(root["d"], TomlVal::Bool(true));
        assert_eq!(root["e"].as_usize_vec(), Some(vec![1, 2, 3]));
    }

    #[test]
    fn rejects_bad_syntax() {
        assert!(parse_toml("[unclosed").is_err());
        assert!(parse_toml("novalue").is_err());
        assert!(parse_toml("x = @@").is_err());
        assert!(TrainConfig::from_toml("[model]\nkind = \"resnet\"").is_err());
        assert!(TrainConfig::from_toml("[pipeline]\nschedule = \"lifo\"").is_err());
    }

    #[test]
    fn schedule_defaults_to_flops_stale() {
        let cfg = TrainConfig::from_toml("[pipeline]\nenabled = true\n").unwrap();
        assert_eq!(cfg.pipeline.schedule, Schedule::FlopsStale);
        let cfg2 =
            TrainConfig::from_toml("[pipeline]\nschedule = \"flops-stale\"\n").unwrap();
        assert_eq!(cfg2.pipeline.schedule, Schedule::FlopsStale);
    }

    #[test]
    fn comments_ignored() {
        let doc = parse_toml("# top\na = 1 # trailing\n[s] # section\nb = 2\n").unwrap();
        assert_eq!(doc[""]["a"], TomlVal::Int(1));
        assert_eq!(doc["s"]["b"], TomlVal::Int(2));
    }

    /// Trailing inline comments after every value shape — including after
    /// a string whose *content* contains `#`, which the old prefix-scan
    /// comment stripper rejected as an unterminated string.
    #[test]
    fn trailing_comments_after_values() {
        let doc = parse_toml(
            "a = \"res#1\" # comment after a string containing '#'\n\
             b = [1, 2] # after an array\n\
             c = -3 # after a negative int\n\
             d = \"plain\"   # after a plain string\n",
        )
        .unwrap();
        let root = &doc[""];
        assert_eq!(root["a"], TomlVal::Str("res#1".into()));
        assert_eq!(root["b"].as_usize_vec(), Some(vec![1, 2]));
        assert_eq!(root["c"], TomlVal::Int(-3));
        assert_eq!(root["d"], TomlVal::Str("plain".into()));
    }

    /// Negative (and explicitly signed) numeric literals, bare and inside
    /// arrays — the `[schedules]` step deltas depend on these.
    #[test]
    fn negative_numeric_literals() {
        let doc = parse_toml(
            "i = -5\nf = -0.25\nexp = 1e-3\npos = +7\narr = [20, -20.0, 35, -0.04]\n",
        )
        .unwrap();
        let root = &doc[""];
        assert_eq!(root["i"], TomlVal::Int(-5));
        assert_eq!(root["f"], TomlVal::Float(-0.25));
        assert_eq!(root["exp"], TomlVal::Float(1e-3));
        assert_eq!(root["pos"], TomlVal::Int(7));
        assert_eq!(root["arr"].as_f64_vec(), Some(vec![20.0, -20.0, 35.0, -0.04]));
        // Negative where a non-negative integer is required stays rejected.
        assert_eq!(root["i"].as_usize(), None);
    }

    #[test]
    fn parses_schedules_section() {
        let toml = r#"
[schedules]
rsvd_oversample_base = 10      # paper r_l
rsvd_oversample_steps = [22, 1, 30, 1]
rsvd_power_iter_base = 4
rsvd_power_iter_steps = [30, -2]   # relax late power iters
rsvd_target_rel_err = 0.03
srevd_oversample_base = 6
"#;
        let cfg = TrainConfig::from_toml(toml).unwrap();
        assert_eq!(cfg.schedules.keys(), vec!["rsvd", "srevd"]);
        let r = cfg.schedules.get("rsvd").unwrap();
        assert_eq!(r.oversample.as_ref().unwrap().at(0), 10.0);
        assert_eq!(r.oversample.as_ref().unwrap().at(31), 12.0);
        assert_eq!(r.power_iter.as_ref().unwrap().at(29), 4.0);
        assert_eq!(r.power_iter.as_ref().unwrap().at(30), 2.0);
        assert_eq!(r.target_rel_err, Some(0.03));
        let s = cfg.schedules.get("srevd").unwrap();
        assert_eq!(s.oversample.as_ref().unwrap().at(50), 6.0);
        assert!(s.power_iter.is_none());
        // Default: empty set.
        assert!(TrainConfig::from_toml("").unwrap().schedules.is_empty());
    }

    #[test]
    fn schedules_section_rejects_malformed_keys() {
        for bad in [
            "[schedules]\nrsvd_oversample = 10\n",               // unknown field
            "[schedules]\n_oversample_base = 10\n",              // empty strategy
            "[schedules]\nrsvd_oversample_steps = [22, 1, 30]\n", // odd pair list
            "[schedules]\nrsvd_oversample_steps = [22, 1]\n",    // steps w/o base
            "[schedules]\nrsvd_power_iter_base = \"four\"\n",    // non-numeric
            "[schedules]\nrsvd_oversample_steps = [-1, 2]\n",    // negative epoch
        ] {
            assert!(TrainConfig::from_toml(bad).is_err(), "should reject: {bad}");
        }
    }
}
