//! Checkpointing: binary save/restore of network parameters.
//!
//! Format: magic `RKFC`, version u32, param count u64, then f64 LE values —
//! produced from / consumed by `Network::state_vector`.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::nn::Network;

const MAGIC: &[u8; 4] = b"RKFC";
const VERSION: u32 = 1;

/// Canonical checkpoint path for one `(solver, seed, epoch)` cell — the
/// naming the session's `CheckpointHook` writes and a resume tool reads.
pub fn epoch_path(
    dir: impl AsRef<Path>,
    solver: &str,
    seed: u64,
    epoch: usize,
) -> std::path::PathBuf {
    dir.as_ref().join(format!("ckpt_{solver}_{seed}_e{epoch:04}.bin"))
}

/// Save the network's full state to `path`.
pub fn save(net: &Network, path: impl AsRef<Path>) -> Result<()> {
    let state = net.state_vector();
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(state.len() as u64).to_le_bytes())?;
    for v in &state {
        f.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Restore a network's state from `path` (shapes must match).
pub fn load(net: &mut Network, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    let mut f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not a rkfac checkpoint", path.display());
    }
    let mut b4 = [0u8; 4];
    f.read_exact(&mut b4)?;
    let version = u32::from_le_bytes(b4);
    if version != VERSION {
        bail!("{}: unsupported checkpoint version {version}", path.display());
    }
    let mut b8 = [0u8; 8];
    f.read_exact(&mut b8)?;
    let n = u64::from_le_bytes(b8) as usize;
    let expect = net.state_vector().len();
    if n != expect {
        bail!("{}: checkpoint has {n} params, model needs {expect}", path.display());
    }
    let mut state = Vec::with_capacity(n);
    for _ in 0..n {
        f.read_exact(&mut b8)?;
        state.push(f64::from_le_bytes(b8));
    }
    net.load_state_vector(&state);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Pcg64;
    use crate::nn::models;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("rkfac_ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_restores_outputs() {
        let mut net = models::mlp(&[8, 6, 10], 1);
        let mut rng = Pcg64::new(2);
        let x = rng.gaussian_matrix(8, 3);
        let before = net.forward(&x, false, false);
        let p = tmp("roundtrip.bin");
        save(&net, &p).unwrap();
        // train a bit to move the weights
        net.train_batch(&x, &[0, 1, 2], false);
        let deltas: Vec<_> = net.kfac_grads().iter().map(|g| *g * (-1.0)).collect();
        net.apply_steps(&deltas, 1.0, 0.0);
        assert!(net.forward(&x, false, false).rel_err(&before) > 1e-6);
        load(&mut net, &p).unwrap();
        assert!(net.forward(&x, false, false).rel_err(&before) < 1e-14);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_wrong_model_shape() {
        let net = models::mlp(&[8, 6, 10], 1);
        let p = tmp("shape.bin");
        save(&net, &p).unwrap();
        let mut other = models::mlp(&[9, 6, 10], 1);
        assert!(load(&mut other, &p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn epoch_path_naming() {
        let p = epoch_path("/tmp/ck", "kfac+rsvd", 3, 12);
        assert_eq!(p.to_str().unwrap(), "/tmp/ck/ckpt_kfac+rsvd_3_e0012.bin");
    }

    #[test]
    fn rejects_garbage_file() {
        let p = tmp("garbage.bin");
        std::fs::write(&p, b"not a checkpoint").unwrap();
        let mut net = models::mlp(&[4, 10], 1);
        assert!(load(&mut net, &p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
