//! Crash-safe checkpointing: full training state save/restore.
//!
//! # File format
//!
//! Every checkpoint starts with the magic `RKFC` and a `u32` version; all
//! integers are little-endian.
//!
//! **v1 (params-only, legacy):**
//!
//! ```text
//! "RKFC" | u32 version = 1 | u64 n | n × f64 parameter values | EOF
//! ```
//!
//! produced from / consumed by `Network::state_vector`. The byte length is
//! validated against the declared count on load — a truncated file or one
//! with trailing bytes (e.g. a half-understood newer format) fails loudly
//! instead of loading a prefix.
//!
//! **v2 (full state, sectioned):**
//!
//! ```text
//! "RKFC" | u32 version = 2 | u32 n_sections |
//!   n_sections × ( [u8;4] tag | u64 len | len payload bytes ) | EOF
//! ```
//!
//! with exactly these sections (unknown tags are an error):
//!
//! - `PRMS` — network parameters: `u64 n` + `n × f64` (the v1 payload).
//! - `SOLV` — the solver's opaque state blob from
//!   [`Preconditioner::save_state`]: K-FAC EA factors Ā/Γ̄ and their
//!   installed decompositions, the step / refresh-round counters (the
//!   round counter positions the per-(round, block, side) decomposition
//!   RNG streams), EK-FAC scaling statistics, SGD momentum, and — when an
//!   async pipeline is attached — the per-slot published versions and
//!   rank-controller positions.
//! - `TRNR` — trainer cursor: `u64 next_epoch`, `u64 global_step`,
//!   `u64 seed` (resume refuses a config with a different seed — the RNG
//!   positions below are meaningless under another seed), `f64 wall_s`
//!   (cumulative wall-clock seconds, so time-to-accuracy statistics
//!   continue), then the raw `(state, inc)` pairs (`u128` each) of the
//!   data-stream RNG (batch shuffle + augmentation) and the network's
//!   dropout RNG.
//!
//! A run restored from a v2 checkpoint via `Session::resume` re-enters the
//! step loop at `next_epoch` and reproduces the uninterrupted run's
//! trajectory bitwise (native engine; pipeline at `max_stale_steps = 0`).
//! v1 files still load, as params-only, with a warning that the trajectory
//! will not reproduce.
//!
//! # Crash safety
//!
//! Writes go to a `.tmp` sibling first (buffered, fsync'd) and are
//! atomically renamed into place, so a crash mid-write can never leave a
//! truncated file at the canonical path a resume would look at. Loads read
//! the file in one pass and parse it with bounds-checked decoding.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::nn::Network;
use crate::optim::Preconditioner;
use crate::util::codec::{ByteReader, ByteWriter};

const MAGIC: &[u8; 4] = b"RKFC";
/// Params-only format (the seed format).
const VERSION_PARAMS: u32 = 1;
/// Sectioned full-state format.
const VERSION_FULL: u32 = 2;

const SEC_PARAMS: &[u8; 4] = b"PRMS";
const SEC_SOLVER: &[u8; 4] = b"SOLV";
const SEC_TRAINER: &[u8; 4] = b"TRNR";

/// Canonical checkpoint path for one `(solver, seed, epoch)` cell — the
/// naming the session's `CheckpointHook` writes and `--resume` reads.
pub fn epoch_path(
    dir: impl AsRef<Path>,
    solver: &str,
    seed: u64,
    epoch: usize,
) -> std::path::PathBuf {
    dir.as_ref().join(format!("ckpt_{solver}_{seed}_e{epoch:04}.bin"))
}

/// The trainer-side cursor of a v2 checkpoint: where the step loop was and
/// where its RNG streams stood when the snapshot was taken.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainerState {
    /// Epoch index the resumed run re-enters at (checkpointed epoch + 1).
    pub next_epoch: usize,
    /// Global step count at the checkpoint boundary.
    pub global_step: usize,
    /// The run's seed. Every RNG stream in the file is a position within
    /// this seed's streams, so `Session::resume` refuses a config whose
    /// seed differs — continuing under another seed would match neither
    /// trajectory, silently.
    pub seed: u64,
    /// Cumulative wall-clock seconds at the checkpoint boundary, so a
    /// resumed run's `wall_s` records (and time-to-accuracy statistics)
    /// continue instead of restarting near zero.
    pub wall_s: f64,
    /// Raw `(state, inc)` of the data-stream RNG (shuffle + augmentation).
    pub data_rng: (u128, u128),
    /// Raw `(state, inc)` of the network's dropout RNG.
    pub net_rng: (u128, u128),
}

/// What a [`load_full`] call restored.
#[derive(Debug, PartialEq)]
pub enum LoadedCheckpoint {
    /// A v1 file: parameters only. Solver statistics and RNG streams were
    /// *not* restored — the resumed trajectory will not reproduce the
    /// original run.
    ParamsOnly,
    /// A v2 file: parameters, solver state, and the trainer cursor.
    Full(TrainerState),
}

fn tmp_sibling(path: &Path) -> PathBuf {
    let mut name =
        path.file_name().map(|n| n.to_os_string()).unwrap_or_else(|| "checkpoint".into());
    name.push(".tmp");
    path.with_file_name(name)
}

/// Buffered, fsync'd write to a `.tmp` sibling, atomically renamed into
/// place on success (a crash mid-write never corrupts the canonical path).
fn write_atomic(
    path: &Path,
    body: impl FnOnce(&mut BufWriter<File>) -> Result<()>,
) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let tmp = tmp_sibling(path);
    let result: Result<()> = (|| {
        let f = File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
        let mut w = BufWriter::new(f);
        body(&mut w)?;
        w.flush()?;
        w.get_ref().sync_all()?;
        Ok(())
    })();
    if let Err(e) = result {
        std::fs::remove_file(&tmp).ok();
        return Err(e);
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    Ok(())
}

/// Save the network's parameters to `path` in the v1 (params-only) format.
/// Kept for embedders that only want weights; full-state checkpoints come
/// from [`save_full`].
pub fn save(net: &Network, path: impl AsRef<Path>) -> Result<()> {
    let state = net.state_vector();
    write_atomic(path.as_ref(), |w| {
        w.write_all(MAGIC)?;
        w.write_all(&VERSION_PARAMS.to_le_bytes())?;
        w.write_all(&(state.len() as u64).to_le_bytes())?;
        for v in &state {
            w.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    })
}

/// Save the full training state (v2): network parameters, the solver's
/// [`Preconditioner::save_state`] blob, and the trainer cursor. The
/// parameter section — the dominant payload at VGG16 scale — streams
/// straight into the buffered writer (its length is known up front)
/// instead of being staged in a second in-memory copy.
pub fn save_full(
    net: &Network,
    solver: &dyn Preconditioner,
    trainer: &TrainerState,
    path: impl AsRef<Path>,
) -> Result<()> {
    let state = net.state_vector();
    let solv = solver.save_state().unwrap_or_default();
    let mut trnr = ByteWriter::new();
    trnr.u64(trainer.next_epoch as u64);
    trnr.u64(trainer.global_step as u64);
    trnr.u64(trainer.seed);
    trnr.f64(trainer.wall_s);
    trnr.u128(trainer.data_rng.0);
    trnr.u128(trainer.data_rng.1);
    trnr.u128(trainer.net_rng.0);
    trnr.u128(trainer.net_rng.1);
    let trnr = trnr.into_bytes();
    write_atomic(path.as_ref(), |w| {
        w.write_all(MAGIC)?;
        w.write_all(&VERSION_FULL.to_le_bytes())?;
        w.write_all(&3u32.to_le_bytes())?;
        // PRMS, streamed: section payload is `u64 n` + `n × f64`.
        w.write_all(SEC_PARAMS)?;
        w.write_all(&((8 + 8 * state.len()) as u64).to_le_bytes())?;
        w.write_all(&(state.len() as u64).to_le_bytes())?;
        for v in &state {
            w.write_all(&v.to_le_bytes())?;
        }
        for (tag, payload) in [(SEC_SOLVER, &solv), (SEC_TRAINER, &trnr)] {
            w.write_all(tag)?;
            w.write_all(&(payload.len() as u64).to_le_bytes())?;
            w.write_all(payload)?;
        }
        Ok(())
    })
}

/// A parsed checkpoint file body.
enum FileBody {
    Params(Vec<f64>),
    Sections { params: Vec<f64>, solver: Vec<u8>, trainer: TrainerState },
}

fn parse_trainer(bytes: &[u8]) -> Result<TrainerState, String> {
    let mut r = ByteReader::new(bytes);
    let state = TrainerState {
        next_epoch: r.u64()? as usize,
        global_step: r.u64()? as usize,
        seed: r.u64()?,
        wall_s: r.f64()?,
        data_rng: (r.u128()?, r.u128()?),
        net_rng: (r.u128()?, r.u128()?),
    };
    r.finish()?;
    Ok(state)
}

/// Read and structurally validate a checkpoint file. Every length is
/// checked against the actual byte count: truncation, trailing garbage,
/// duplicate or unknown sections all fail here, before any state mutates.
fn read_checkpoint(path: &Path) -> Result<FileBody> {
    let bytes =
        std::fs::read(path).with_context(|| format!("opening {}", path.display()))?;
    let mut r = ByteReader::new(&bytes);
    let err = |e: String| anyhow!("{}: {e}", path.display());
    if r.bytes(4).map_err(&err)? != MAGIC {
        bail!("{}: not a rkfac checkpoint", path.display());
    }
    let version = r.u32().map_err(&err)?;
    match version {
        VERSION_PARAMS => {
            let params = r.f64s().map_err(&err)?;
            r.finish().map_err(|e| {
                anyhow!(
                    "{}: byte length does not match the declared parameter count ({e})",
                    path.display()
                )
            })?;
            Ok(FileBody::Params(params))
        }
        VERSION_FULL => {
            let n_sections = r.u32().map_err(&err)?;
            let mut params = None;
            let mut solver = None;
            let mut trainer = None;
            for _ in 0..n_sections {
                let tag: [u8; 4] = r.bytes(4).map_err(&err)?.try_into().unwrap();
                let payload = r.blob().map_err(&err)?;
                let slot = match &tag {
                    SEC_PARAMS => &mut params,
                    SEC_SOLVER => &mut solver,
                    SEC_TRAINER => &mut trainer,
                    other => bail!(
                        "{}: unknown checkpoint section '{}' (written by a newer build?)",
                        path.display(),
                        String::from_utf8_lossy(other)
                    ),
                };
                if slot.replace(payload.to_vec()).is_some() {
                    bail!(
                        "{}: duplicate checkpoint section '{}'",
                        path.display(),
                        String::from_utf8_lossy(&tag)
                    );
                }
            }
            r.finish()
                .map_err(|e| anyhow!("{}: trailing garbage after sections ({e})", path.display()))?;
            let (params, solver, trainer) = match (params, solver, trainer) {
                (Some(p), Some(s), Some(t)) => (p, s, t),
                _ => bail!(
                    "{}: v2 checkpoint is missing a required section (PRMS/SOLV/TRNR)",
                    path.display()
                ),
            };
            let params = {
                let mut pr = ByteReader::new(&params);
                let vals = pr.f64s().map_err(&err)?;
                pr.finish().map_err(&err)?;
                vals
            };
            let trainer = parse_trainer(&trainer).map_err(&err)?;
            Ok(FileBody::Sections { params, solver, trainer })
        }
        v => bail!("{}: unsupported checkpoint version {v}", path.display()),
    }
}

fn apply_params(net: &mut Network, params: &[f64], path: &Path) -> Result<()> {
    let expect = net.state_vector().len();
    if params.len() != expect {
        bail!(
            "{}: checkpoint has {} params, model needs {expect}",
            path.display(),
            params.len()
        );
    }
    net.load_state_vector(params);
    Ok(())
}

/// Restore a network's parameters from `path` (v1 or the `PRMS` section of
/// a v2 file; shapes must match). Params-only view — [`load_full`] is the
/// resume path.
pub fn load(net: &mut Network, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    let params = match read_checkpoint(path)? {
        FileBody::Params(p) => p,
        FileBody::Sections { params, .. } => params,
    };
    apply_params(net, &params, path)
}

/// Restore the full training state from `path` into a freshly-wired
/// `(net, solver)` pair. The file is structurally validated up front and
/// the network is only touched after the solver restore succeeds, so on
/// any failure the network is untouched; the *solver* may be partially
/// restored when its own `load_state` fails midway — discard it on error
/// (`Session::resume` wires a fresh pair per call, so the CLI path never
/// observes a half-restored solver). v1 files restore parameters only and
/// return [`LoadedCheckpoint::ParamsOnly`] with a warning.
pub fn load_full(
    net: &mut Network,
    solver: &mut dyn Preconditioner,
    path: impl AsRef<Path>,
) -> Result<LoadedCheckpoint> {
    let path = path.as_ref();
    match read_checkpoint(path)? {
        FileBody::Params(params) => {
            apply_params(net, &params, path)?;
            eprintln!(
                "[rkfac] warning: {} is a v1 (params-only) checkpoint — optimizer statistics \
                 and RNG streams cannot be restored, so the resumed trajectory will not \
                 reproduce the original run",
                path.display()
            );
            Ok(LoadedCheckpoint::ParamsOnly)
        }
        FileBody::Sections { params, solver: solver_blob, trainer } => {
            // Validate the cheap structural facts first, then restore the
            // solver (its loader validates strategy/shape agreement), and
            // only then touch the network.
            let expect = net.state_vector().len();
            if params.len() != expect {
                bail!(
                    "{}: checkpoint has {} params, model needs {expect}",
                    path.display(),
                    params.len()
                );
            }
            solver
                .load_state(&solver_blob)
                .map_err(|e| anyhow!("{}: restoring solver state: {e}", path.display()))?;
            net.load_state_vector(&params);
            Ok(LoadedCheckpoint::Full(trainer))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Pcg64;
    use crate::nn::models;
    use crate::optim::{build_solver, KfacSchedules};

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("rkfac_ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_restores_outputs() {
        let mut net = models::mlp(&[8, 6, 10], 1);
        let mut rng = Pcg64::new(2);
        let x = rng.gaussian_matrix(8, 3);
        let before = net.forward(&x, false, false);
        let p = tmp("roundtrip.bin");
        save(&net, &p).unwrap();
        // train a bit to move the weights
        net.train_batch(&x, &[0, 1, 2], false);
        let deltas: Vec<_> = net.kfac_grads().iter().map(|g| *g * (-1.0)).collect();
        net.apply_steps(&deltas, 1.0, 0.0);
        assert!(net.forward(&x, false, false).rel_err(&before) > 1e-6);
        load(&mut net, &p).unwrap();
        assert!(net.forward(&x, false, false).rel_err(&before) < 1e-14);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_wrong_model_shape() {
        let net = models::mlp(&[8, 6, 10], 1);
        let p = tmp("shape.bin");
        save(&net, &p).unwrap();
        let mut other = models::mlp(&[9, 6, 10], 1);
        assert!(load(&mut other, &p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn epoch_path_naming() {
        let p = epoch_path("/tmp/ck", "kfac+rsvd", 3, 12);
        assert_eq!(p.to_str().unwrap(), "/tmp/ck/ckpt_kfac+rsvd_3_e0012.bin");
    }

    #[test]
    fn rejects_garbage_file() {
        let p = tmp("garbage.bin");
        std::fs::write(&p, b"not a checkpoint").unwrap();
        let mut net = models::mlp(&[4, 10], 1);
        assert!(load(&mut net, &p).is_err());
        std::fs::remove_file(&p).ok();
    }

    /// The v1 loader validates the byte length against the declared count:
    /// trailing bytes (e.g. a newer format read by an old decoder) and
    /// truncation both fail loudly instead of loading a prefix.
    #[test]
    fn rejects_truncated_and_trailing_garbage_v1() {
        let net = models::mlp(&[6, 10], 1);
        let p = tmp("trail.bin");
        save(&net, &p).unwrap();
        let good = std::fs::read(&p).unwrap();
        let mut net2 = models::mlp(&[6, 10], 1);
        // Trailing garbage.
        let mut bad = good.clone();
        bad.extend_from_slice(b"EXTRA");
        std::fs::write(&p, &bad).unwrap();
        let err = load(&mut net2, &p).unwrap_err().to_string();
        assert!(err.contains("does not match the declared parameter count"), "{err}");
        // Truncation.
        std::fs::write(&p, &good[..good.len() - 5]).unwrap();
        assert!(load(&mut net2, &p).is_err());
        std::fs::remove_file(&p).ok();
    }

    /// No `.tmp` sibling survives a successful save (atomic rename), and
    /// the canonical file parses.
    #[test]
    fn atomic_save_leaves_no_tmp() {
        let net = models::mlp(&[5, 10], 2);
        let p = tmp("atomic.bin");
        save(&net, &p).unwrap();
        assert!(p.exists());
        assert!(!tmp_sibling(&p).exists());
        let mut net2 = models::mlp(&[5, 10], 2);
        load(&mut net2, &p).unwrap();
        std::fs::remove_file(&p).ok();
    }

    /// v2 round-trip: params + solver blob + trainer cursor restore into a
    /// freshly-wired pair; the params-only `load` view still works on the
    /// same file.
    #[test]
    fn full_state_roundtrip_v2() {
        let mut net = models::mlp(&[8, 6, 10], 3);
        let mut rng = Pcg64::new(4);
        let dims = net.kfac_dims();
        let mut solver = build_solver("kfac+rsvd", KfacSchedules::paper(), &dims, 5).unwrap();
        let labels = [0usize, 1, 2, 3];
        for _ in 0..3 {
            let x = rng.gaussian_matrix(8, 4);
            net.train_batch(&x, &labels, true);
            let caps = net.kfac_captures();
            let _ = solver.step(0, &caps);
        }
        let trainer = TrainerState {
            next_epoch: 2,
            global_step: 3,
            seed: 5,
            wall_s: 12.5,
            data_rng: rng.raw_state(),
            net_rng: net.rng.raw_state(),
        };
        let p = tmp("full.bin");
        save_full(&net, solver.as_ref(), &trainer, &p).unwrap();
        assert!(!tmp_sibling(&p).exists());

        let mut net2 = models::mlp(&[8, 6, 10], 3);
        let mut solver2 = build_solver("kfac+rsvd", KfacSchedules::paper(), &dims, 5).unwrap();
        let loaded = load_full(&mut net2, solver2.as_mut(), &p).unwrap();
        assert_eq!(loaded, LoadedCheckpoint::Full(trainer.clone()));
        assert_eq!(net2.state_vector(), net.state_vector());
        assert_eq!(solver2.diagnostics().n_decomps, solver.diagnostics().n_decomps);

        // Params-only view of the same v2 file.
        let mut net3 = models::mlp(&[8, 6, 10], 3);
        load(&mut net3, &p).unwrap();
        assert_eq!(net3.state_vector(), net.state_vector());

        // Truncated v2 fails loudly, before mutating anything.
        let good = std::fs::read(&p).unwrap();
        std::fs::write(&p, &good[..good.len() - 7]).unwrap();
        let mut net4 = models::mlp(&[8, 6, 10], 3);
        let mut solver4 = build_solver("kfac+rsvd", KfacSchedules::paper(), &dims, 5).unwrap();
        assert!(load_full(&mut net4, solver4.as_mut(), &p).is_err());
        // Trailing garbage after the sections fails too.
        let mut bad = good.clone();
        bad.push(0xAB);
        std::fs::write(&p, &bad).unwrap();
        let err = load_full(&mut net4, solver4.as_mut(), &p).unwrap_err().to_string();
        assert!(err.contains("trailing garbage"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    /// A v2 file restored by `load_full` with the wrong solver family or
    /// strategy fails loudly; a v1 file comes back params-only.
    #[test]
    fn load_full_validates_solver_and_downgrades_v1() {
        let mut net = models::mlp(&[6, 5, 10], 6);
        let dims = net.kfac_dims();
        let solver = build_solver("kfac+rsvd", KfacSchedules::paper(), &dims, 7).unwrap();
        let trainer = TrainerState {
            next_epoch: 1,
            global_step: 10,
            seed: 7,
            wall_s: 1.0,
            data_rng: (1, 3),
            net_rng: (2, 5),
        };
        let p = tmp("mismatch.bin");
        save_full(&net, solver.as_ref(), &trainer, &p).unwrap();
        // Different strategy: the solver blob embeds 'rsvd' and must refuse.
        let mut wrong = build_solver("kfac+srevd", KfacSchedules::paper(), &dims, 7).unwrap();
        let err = load_full(&mut net, wrong.as_mut(), &p).unwrap_err().to_string();
        assert!(err.contains("restoring solver state"), "{err}");
        // v1 file → ParamsOnly.
        save(&net, &p).unwrap();
        let mut solver2 = build_solver("kfac+rsvd", KfacSchedules::paper(), &dims, 7).unwrap();
        let loaded = load_full(&mut net, solver2.as_mut(), &p).unwrap();
        assert_eq!(loaded, LoadedCheckpoint::ParamsOnly);
        std::fs::remove_file(&p).ok();
    }
}
