//! Coordinator — the L3 training framework, fronted by the composable
//! Experiment/Session API.
//!
//! The layered surface (one experiment, three config layers, ordered run
//! hooks, grid execution):
//!
//! - [`experiment`]: the typed [`ExperimentSpec`] — TOML file < builder
//!   calls < `--set key=value` CLI overrides, with validation errors that
//!   cite the offending layer; wires the `[registry]` section (named
//!   solver specs + out-of-tree registrations) and the `[schedules]`
//!   per-strategy sketch schedules end-to-end.
//! - [`session`]: a [`Session`] owns the data/model/solver/pipeline wiring
//!   for one run and drives the Algorithm-1 step loop over either engine
//!   (native nn / PJRT).
//! - [`hooks`]: the ordered [`RunHook`](hooks::RunHook) observation points
//!   — metrics CSVs, rank/pipe traces, checkpointing, the Fig. 1 spectrum
//!   probe and early time-to-accuracy stopping are hook implementations,
//!   not trainer code.
//! - [`sweep`]: the [`Sweep`] runner — `{solvers × seeds}` grids from one
//!   spec, executed on [`parallel`] job workers, aggregated into Table-1
//!   [`SolverSummary`] statistics in one invocation.
//!
//! Infrastructure underneath:
//!
//! - [`config`]: TOML-subset parsing and the typed [`TrainConfig`].
//! - [`trainer`]: the legacy free-function entry points, kept as thin
//!   deprecated shims over [`Session`] (bitwise-pinned by the golden
//!   suite; see the deprecation policy in ROADMAP.md).
//! - [`metrics`]: CSV logging + Table-1 statistics (mean±std,
//!   time-to-accuracy, [`render_table1`](metrics::render_table1)).
//! - [`spectrum`]: the Fig. 1 eigen-spectrum probe.
//! - [`checkpoint`]: crash-safe binary checkpoints — v2 sectioned
//!   full-state files (params + solver state + trainer cursor/RNG
//!   streams) behind atomic writes, restored by `Session::resume` for
//!   bitwise continuation; v1 params-only files still load.
//! - [`parallel`]: synchronous data-parallel workers with allreduce, plus
//!   the order-preserving [`run_jobs`](parallel::run_jobs) pool sweeps
//!   schedule on.

pub mod checkpoint;
pub mod config;
pub mod experiment;
pub mod hooks;
pub mod metrics;
pub mod parallel;
pub mod session;
pub mod spectrum;
pub mod sweep;
pub mod trainer;

pub use config::{DataChoice, EngineChoice, FactoredConfig, ModelChoice, TrainConfig};
pub use experiment::{ConfigLayer, ExperimentBuilder, ExperimentSpec};
pub use hooks::{
    CheckpointHook, CsvMetricsHook, EarlyStopHook, HookAction, RunHook, SpectrumHook, TraceHook,
};
pub use metrics::{mean_std, summarize, CsvLogger, EpochRecord, RunResult, SolverSummary};
pub use session::Session;
pub use sweep::{Sweep, SweepResult};
