//! Coordinator — the L3 training framework.
//!
//! - [`config`]: TOML-subset experiment configs (`configs/*.toml`).
//! - [`trainer`]: the training loop over either engine (native nn / PJRT).
//! - [`metrics`]: CSV logging + Table-1 statistics (mean±std, time-to-acc).
//! - [`spectrum`]: the Fig. 1 eigen-spectrum probe.
//! - [`checkpoint`]: binary parameter save/restore.
//! - [`parallel`]: synchronous data-parallel workers with allreduce.

pub mod checkpoint;
pub mod config;
pub mod metrics;
pub mod parallel;
pub mod spectrum;
pub mod trainer;

pub use config::{DataChoice, EngineChoice, ModelChoice, TrainConfig};
pub use metrics::{mean_std, summarize, CsvLogger, EpochRecord, RunResult, SolverSummary};
