//! The typed, layered [`ExperimentSpec`] — the front door of the
//! Experiment API.
//!
//! An experiment is assembled from up to three explicit layers, merged in
//! fixed precedence order (lowest to highest, independent of call order):
//!
//! 1. **TOML** — a config file / string ([`ExperimentBuilder::toml_file`] /
//!    [`toml_str`](ExperimentBuilder::toml_str)), flattened to
//!    `section.key` assignments;
//! 2. **builder** — programmatic calls
//!    ([`solver`](ExperimentBuilder::solver),
//!    [`epochs`](ExperimentBuilder::epochs), the generic
//!    [`set`](ExperimentBuilder::set), …);
//! 3. **`--set key=value` CLI overrides**
//!    ([`override_set`](ExperimentBuilder::override_set)) — what `rkfac
//!    train --set pipeline.enabled=true` feeds through.
//!
//! Every key covers one `TrainConfig` field (all of them are reachable),
//! the `[registry]` section (solver spec + named out-of-tree
//! registrations), or the free-form `[schedules]` section. Validation
//! happens once, at [`ExperimentBuilder::build`], and every error cites
//! the layer that set the offending value — a typo'd `--set` is never
//! mistaken for a config-file bug.
//!
//! The `[registry]` section wires the open solver axes end-to-end:
//! `registry.solver = "kfac+rsvd"` names the solver spec (validated
//! against the assembled [`SolverRegistry`], with the known specs listed
//! on a typo), and `registry.extensions = ["my-backend"]` selects named
//! registration callbacks the embedder provided via
//! [`ExperimentBuilder::extension`] — the only way a static binary can let
//! a config file name out-of-tree decompositions/families.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::config::{
    apply_config, parse_toml, parse_value, ConfigSource, TomlVal, TrainConfig,
};
use crate::coordinator::session::Session;
use crate::linalg::backend::{mixed_precision_supported, Precision};
use crate::optim::SolverRegistry;

/// Which layer produced a config value (precedence: `Toml < Builder < Cli`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ConfigLayer {
    Toml,
    Builder,
    Cli,
}

impl fmt::Display for ConfigLayer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            ConfigLayer::Toml => "TOML",
            ConfigLayer::Builder => "builder",
            ConfigLayer::Cli => "--set",
        })
    }
}

/// One `key = value` contribution from one layer.
#[derive(Clone, Debug)]
struct Assignment {
    key: String,
    val: TomlVal,
    layer: ConfigLayer,
    /// Human-readable origin for error messages, e.g.
    /// `--set train.epochs=-1` or `config file 'exp.toml'`.
    origin: String,
    /// The literal input text for values that arrived unquoted (`--set` /
    /// builder `set`) — what a string-typed key hands back, so
    /// `--set train.out_dir=007` stays "007", not Int(7) re-rendered.
    raw: Option<String>,
}

fn cite(a: &Assignment) -> String {
    format!("(set by {} layer: {})", a.layer, a.origin)
}

fn show(v: &TomlVal) -> String {
    match v {
        TomlVal::Str(s) => format!("\"{s}\""),
        TomlVal::Int(i) => i.to_string(),
        TomlVal::Float(f) => f.to_string(),
        TomlVal::Bool(b) => b.to_string(),
        TomlVal::Arr(a) => format!("[{}]", a.iter().map(show).collect::<Vec<_>>().join(", ")),
    }
}

/// Every typed config key the resolver understands (the `[schedules]`
/// section is free-form and validated by its own parser).
const KNOWN_KEYS: [&str; 49] = [
    "train.solver",
    "train.epochs",
    "train.batch",
    "train.seed",
    "train.targets",
    "train.augment",
    "train.out_dir",
    "train.sched_width",
    "model.kind",
    "model.widths",
    "model.scale_div",
    "data.kind",
    "data.n_train",
    "data.n_test",
    "data.height",
    "data.width",
    "data.channels",
    "data.root",
    "engine.kind",
    "engine.config",
    "pipeline.enabled",
    "pipeline.workers",
    "pipeline.max_stale_steps",
    "pipeline.schedule",
    "pipeline.adaptive_rank",
    "pipeline.adaptive_sketch",
    "pipeline.target_rel_err",
    "pipeline.min_rank",
    "pipeline.growth",
    "pipeline.prop31_batch",
    "pipeline.transport",
    "pipeline.endpoint",
    "pipeline.connect_timeout_ms",
    "pipeline.io_timeout_ms",
    "pipeline.max_retries",
    "linalg.backend",
    "linalg.threads",
    "linalg.precision",
    "factored.mode",
    "factored.width_threshold",
    "factored.core",
    "factored.max_cols",
    "factored.col_sample",
    "obs.enabled",
    "obs.jsonl",
    "obs.chrome_trace",
    "obs.summary",
    "registry.solver",
    "registry.extensions",
];

type ExtensionInstaller = Arc<dyn Fn(&mut SolverRegistry) + Send + Sync>;

/// The merged key → winning-assignment view the resolver reads.
struct Merged(BTreeMap<String, Assignment>);

impl Merged {
    fn get(&self, key: &str) -> Option<&Assignment> {
        self.0.get(key)
    }

    fn str_vec_of(&self, key: &str) -> Result<Option<Vec<String>>> {
        match self.0.get(key) {
            None => Ok(None),
            Some(a) => {
                let arr = match &a.val {
                    TomlVal::Arr(items) => items,
                    _ => bail!(
                        "config key '{key}': expected an array of strings, got {} {}",
                        show(&a.val),
                        cite(a)
                    ),
                };
                arr.iter()
                    .map(|v| v.as_str().map(str::to_string))
                    .collect::<Option<Vec<_>>>()
                    .map(Some)
                    .ok_or_else(|| {
                        anyhow!(
                            "config key '{key}': expected an array of strings, got {} {}",
                            show(&a.val),
                            cite(a)
                        )
                    })
            }
        }
    }
}

/// The strict [`ConfigSource`]: type mismatches error citing the layer
/// that set the value, dangling companion keys error unless a
/// higher-precedence layer superseded their controller, and `[schedules]`
/// keys are collected from the flattened `schedules.*` namespace. The
/// section-by-section mapping itself is `config::apply_config` — shared
/// with the lenient legacy `TrainConfig::from_toml`.
impl ConfigSource for Merged {
    fn str_of(&self, key: &str) -> Result<Option<String>> {
        match self.0.get(key) {
            None => Ok(None),
            Some(a) => Ok(Some(match (&a.val, &a.raw) {
                (TomlVal::Str(s), _) => s.clone(),
                // Arrays are a type error from every layer — the raw
                // fallback below is for *scalars* only.
                (TomlVal::Arr(_), _) => bail!(
                    "config key '{key}': expected a string, got {} {}",
                    show(&a.val),
                    cite(a)
                ),
                // Unquoted CLI/builder values parse as scalars; a
                // string-typed key takes back the *literal* input text
                // (`--set train.out_dir=007` names the directory "007").
                (_, Some(raw)) => raw.clone(),
                (TomlVal::Int(i), None) => i.to_string(),
                (TomlVal::Float(f), None) => f.to_string(),
                (TomlVal::Bool(b), None) => b.to_string(),
            })),
        }
    }

    fn usize_of(&self, key: &str) -> Result<Option<usize>> {
        match self.0.get(key) {
            None => Ok(None),
            Some(a) => a.val.as_usize().map(Some).ok_or_else(|| {
                anyhow!(
                    "config key '{key}': expected a non-negative integer, got {} {}",
                    show(&a.val),
                    cite(a)
                )
            }),
        }
    }

    fn f64_of(&self, key: &str) -> Result<Option<f64>> {
        match self.0.get(key) {
            None => Ok(None),
            Some(a) => a.val.as_f64().map(Some).ok_or_else(|| {
                anyhow!("config key '{key}': expected a number, got {} {}", show(&a.val), cite(a))
            }),
        }
    }

    fn bool_of(&self, key: &str) -> Result<Option<bool>> {
        match self.0.get(key) {
            None => Ok(None),
            Some(a) => a.val.as_bool().map(Some).ok_or_else(|| {
                anyhow!("config key '{key}': expected a boolean, got {} {}", show(&a.val), cite(a))
            }),
        }
    }

    fn usize_vec_of(&self, key: &str) -> Result<Option<Vec<usize>>> {
        match self.0.get(key) {
            None => Ok(None),
            Some(a) => a.val.as_usize_vec().map(Some).ok_or_else(|| {
                anyhow!(
                    "config key '{key}': expected an array of non-negative integers, got {} {}",
                    show(&a.val),
                    cite(a)
                )
            }),
        }
    }

    fn f64_vec_of(&self, key: &str) -> Result<Option<Vec<f64>>> {
        match self.0.get(key) {
            None => Ok(None),
            Some(a) => a.val.as_f64_vec().map(Some).ok_or_else(|| {
                anyhow!(
                    "config key '{key}': expected an array of numbers, got {} {}",
                    show(&a.val),
                    cite(a)
                )
            }),
        }
    }

    fn schedules(&self) -> BTreeMap<String, TomlVal> {
        self.0
            .iter()
            .filter_map(|(k, a)| {
                k.strip_prefix("schedules.").map(|rest| (rest.to_string(), a.val.clone()))
            })
            .collect()
    }

    fn require_applicable(
        &self,
        key: &str,
        applies: bool,
        controller: &str,
        requirement: &str,
    ) -> Result<()> {
        if applies {
            return Ok(());
        }
        // Known keys that only apply under another key's value must not be
        // silently dropped — a highest-precedence override that does
        // nothing is worse than an error. Exception: a *higher-layer*
        // controller override (e.g. a builder `engine.kind = "native"`
        // fallback over a TOML `[engine]` pjrt block) deliberately
        // supersedes lower-layer companion keys.
        let Some(a) = self.0.get(key) else {
            return Ok(());
        };
        if let Some(c) = self.0.get(controller) {
            if a.layer < c.layer {
                return Ok(());
            }
        }
        bail!("{key} requires {requirement} {}", cite(a))
    }

    fn invalid(&self, key: &str, msg: String) -> anyhow::Error {
        match self.0.get(key) {
            Some(a) => anyhow!("{msg} {}", cite(a)),
            None => anyhow!("{msg}"),
        }
    }
}

/// Layered experiment assembly; see the module docs for the precedence
/// model.
#[derive(Default)]
pub struct ExperimentBuilder {
    assignments: Vec<Assignment>,
    extensions: BTreeMap<String, ExtensionInstaller>,
}

impl ExperimentBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    fn push(&mut self, key: &str, val: TomlVal, layer: ConfigLayer, origin: String) {
        self.assignments.push(Assignment { key: key.to_string(), val, layer, origin, raw: None });
    }

    fn push_unquoted(&mut self, key: &str, value: &str, layer: ConfigLayer, origin: String) {
        self.assignments.push(Assignment {
            key: key.to_string(),
            val: parse_flexible(value),
            layer,
            origin,
            raw: Some(value.to_string()),
        });
    }

    fn push_doc(&mut self, text: &str, origin: &str) -> Result<()> {
        let doc = parse_toml(text)?;
        for (section, keys) in &doc {
            for (key, val) in keys {
                let flat = if section.is_empty() {
                    key.clone()
                } else {
                    format!("{section}.{key}")
                };
                self.push(&flat, val.clone(), ConfigLayer::Toml, origin.to_string());
            }
        }
        Ok(())
    }

    /// Apply a TOML-subset config file as the lowest-precedence layer.
    pub fn toml_file(mut self, path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading config file '{path}': {e}"))?;
        self.push_doc(&text, &format!("config file '{path}'"))?;
        Ok(self)
    }

    /// Apply an inline TOML-subset string as the lowest-precedence layer.
    pub fn toml_str(mut self, text: &str) -> Result<Self> {
        self.push_doc(text, "inline TOML")?;
        Ok(self)
    }

    /// Generic builder-layer assignment: `set("pipeline.enabled", "true")`.
    /// Values parse with TOML scalar syntax; anything unparseable is taken
    /// as a bare string (so `set("train.solver", "kfac+rsvd")` works
    /// without quotes).
    pub fn set(mut self, key: &str, value: &str) -> Self {
        let origin = format!("set(\"{key}\", \"{value}\")");
        self.push_unquoted(key, value, ConfigLayer::Builder, origin);
        self
    }

    /// Builder-layer solver spec (`kfac+rsvd`, a legacy alias, or an
    /// out-of-tree `family+strategy`).
    pub fn solver(self, spec: &str) -> Self {
        self.set("train.solver", spec)
    }

    pub fn epochs(mut self, n: usize) -> Self {
        self.push(
            "train.epochs",
            TomlVal::Int(n as i64),
            ConfigLayer::Builder,
            format!("epochs({n})"),
        );
        self
    }

    pub fn batch(mut self, n: usize) -> Self {
        self.push(
            "train.batch",
            TomlVal::Int(n as i64),
            ConfigLayer::Builder,
            format!("batch({n})"),
        );
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.push("train.seed", TomlVal::Int(s as i64), ConfigLayer::Builder, format!("seed({s})"));
        self
    }

    pub fn out_dir(mut self, dir: &str) -> Self {
        self.push(
            "train.out_dir",
            TomlVal::Str(dir.to_string()),
            ConfigLayer::Builder,
            format!("out_dir(\"{dir}\")"),
        );
        self
    }

    pub fn augment(mut self, on: bool) -> Self {
        self.push(
            "train.augment",
            TomlVal::Bool(on),
            ConfigLayer::Builder,
            format!("augment({on})"),
        );
        self
    }

    pub fn targets(mut self, targets: &[f64]) -> Self {
        self.push(
            "train.targets",
            TomlVal::Arr(targets.iter().map(|&t| TomlVal::Float(t)).collect()),
            ConfigLayer::Builder,
            format!("targets({targets:?})"),
        );
        self
    }

    /// One `--set key=value` CLI override — the highest-precedence layer.
    pub fn override_set(mut self, assignment: &str) -> Result<Self> {
        let (key, value) = assignment.split_once('=').ok_or_else(|| {
            anyhow!("--set needs key=value, got '{assignment}' (e.g. --set train.epochs=12)")
        })?;
        let (key, value) = (key.trim(), value.trim());
        if key.is_empty() {
            bail!("--set needs key=value, got '{assignment}'");
        }
        self.push_unquoted(key, value, ConfigLayer::Cli, format!("--set {assignment}"));
        Ok(self)
    }

    /// Apply a batch of `--set` overrides in order.
    pub fn overrides<'a, I: IntoIterator<Item = &'a str>>(mut self, kvs: I) -> Result<Self> {
        for kv in kvs {
            self = self.override_set(kv)?;
        }
        Ok(self)
    }

    /// Apply CLI-layer overrides from parsed args in true command-line
    /// order: raw `--set key=value` assignments and the legacy
    /// convenience flags named in the `(flag, key)` table are interleaved
    /// exactly as the user typed them (so `--set train.solver=sgd
    /// --solver rs-kfac` trains rs-kfac, and vice versa). Flags absent
    /// from the table — `--config`, subcommand knobs — are left alone.
    /// The one flag-lowering routine the `rkfac` binary and the examples
    /// share.
    pub fn cli_args(
        mut self,
        args: &crate::util::cli::Args,
        table: &[(&str, &str)],
    ) -> Result<Self> {
        // A value-less `--set` (or convenience flag) parses as a switch;
        // silently dropping the highest-precedence override would be the
        // exact failure mode this layer exists to prevent.
        if args.has("set") {
            bail!("--set needs key=value (e.g. --set train.epochs=12)");
        }
        for (flag, _) in table {
            if args.has(flag) {
                bail!("--{flag} needs a value");
            }
        }
        for (flag, value) in &args.ordered {
            if flag == "set" {
                self = self.override_set(value)?;
            } else if let Some((_, key)) = table.iter().find(|(f, _)| f == flag) {
                self = self.override_set(&format!("{key}={value}"))?;
            }
        }
        Ok(self)
    }

    /// Register a named out-of-tree registration callback. Registering
    /// alone does nothing — the experiment opts in by listing the name in
    /// `registry.extensions` (TOML, builder `set`, or `--set`), which is
    /// what lets a *config file* name backends that live outside this
    /// crate.
    pub fn extension<F>(mut self, name: &str, installer: F) -> Self
    where
        F: Fn(&mut SolverRegistry) + Send + Sync + 'static,
    {
        self.extensions.insert(name.to_string(), Arc::new(installer));
        self
    }

    /// Names in the extension catalog (sorted).
    pub fn extension_names(&self) -> Vec<&str> {
        self.extensions.keys().map(String::as_str).collect()
    }

    /// Merge the layers, resolve every key into a typed [`TrainConfig`] +
    /// [`SolverRegistry`], and validate. Errors cite the offending layer.
    pub fn build(self) -> Result<ExperimentSpec> {
        // Merge with fixed precedence (Toml < Builder < Cli), later
        // same-layer assignments winning — independent of call order.
        let mut merged = Merged(BTreeMap::new());
        for layer in [ConfigLayer::Toml, ConfigLayer::Builder, ConfigLayer::Cli] {
            for a in self.assignments.iter().filter(|a| a.layer == layer) {
                merged.0.insert(a.key.clone(), a.clone());
            }
        }
        // Reject unknown keys up front, citing the layer that wrote them.
        // `[sweep]` axes are carved out first: each maps an *ordinary*
        // config key to a list of values (expanded per sweep cell through
        // the `--set` layer by [`ExperimentSpec::with_overrides`]), so the
        // axis target must itself be a known key.
        let mut sweep_axes: Vec<(String, Vec<String>)> = Vec::new();
        for (key, a) in &merged.0 {
            if key.starts_with("schedules.") || KNOWN_KEYS.contains(&key.as_str()) {
                continue;
            }
            if let Some(target) = key.strip_prefix("sweep.") {
                if !(target.starts_with("schedules.") || KNOWN_KEYS.contains(&target)) {
                    bail!(
                        "[sweep] axis targets unknown config key '{target}' {} — axes map \
                         ordinary config keys to value lists, e.g. \
                         pipeline.max_stale_steps = [0, 4]",
                        cite(a)
                    );
                }
                let TomlVal::Arr(items) = &a.val else {
                    bail!(
                        "[sweep] axis '{target}': expected an array of values, got {} {}",
                        show(&a.val),
                        cite(a)
                    );
                };
                if items.is_empty() {
                    bail!("[sweep] axis '{target}': value list is empty {}", cite(a));
                }
                let mut vals = Vec::with_capacity(items.len());
                for v in items {
                    vals.push(match v {
                        TomlVal::Str(s) => s.clone(),
                        TomlVal::Int(i) => i.to_string(),
                        TomlVal::Float(f) => f.to_string(),
                        TomlVal::Bool(b) => b.to_string(),
                        TomlVal::Arr(_) => bail!(
                            "[sweep] axis '{target}': nested arrays are not sweepable {}",
                            cite(a)
                        ),
                    });
                }
                sweep_axes.push((target.to_string(), vals));
                continue;
            }
            let section = key.split('.').next().unwrap_or("");
            let in_section: Vec<&str> = KNOWN_KEYS
                .iter()
                .copied()
                .filter(|k| k.split('.').next() == Some(section))
                .collect();
            let hint = if in_section.is_empty() {
                "known sections: train, model, data, engine, pipeline, linalg, factored, \
                 obs, registry, schedules, sweep"
                    .to_string()
            } else {
                format!("known '{section}' keys: {}", in_section.join(", "))
            };
            bail!("unknown config key '{key}' {} — {hint}", cite(a));
        }
        let (cfg, registry) = resolve(&merged, &self.extensions)?;
        let provenance =
            merged.0.iter().map(|(k, a)| (k.clone(), a.layer)).collect::<BTreeMap<_, _>>();
        Ok(ExperimentSpec {
            cfg,
            registry,
            provenance,
            sweep_axes,
            assignments: self.assignments,
            extensions: self.extensions,
        })
    }
}

/// Parse a scalar the way TOML would; fall back to a bare string (CLI and
/// builder values don't require quoting).
fn parse_flexible(raw: &str) -> TomlVal {
    parse_value(raw, 0).unwrap_or_else(|_| TomlVal::Str(raw.to_string()))
}

fn resolve(
    m: &Merged,
    extensions: &BTreeMap<String, ExtensionInstaller>,
) -> Result<(TrainConfig, SolverRegistry)> {
    // Every typed section ([train]/[model]/[data]/[engine]/[pipeline]/
    // [schedules]) resolves through the shared `config::apply_config`
    // mapping — the strict semantics (layer-citing type errors, dangling
    // companion-key rejection) live in Merged's `ConfigSource` impl.
    let mut cfg = apply_config(m)?;

    // [registry]: assemble the solver registry, apply selected extensions,
    // then resolve + validate the final solver spec against it.
    let mut registry = SolverRegistry::with_defaults();
    if let Some(names) = m.str_vec_of("registry.extensions")? {
        let a = m.get("registry.extensions").expect("checked above");
        for name in names {
            let installer = extensions.get(&name).ok_or_else(|| {
                anyhow!(
                    "[registry] unknown extension '{name}' {} — registered extensions: {}",
                    cite(a),
                    if extensions.is_empty() {
                        "(none)".to_string()
                    } else {
                        extensions.keys().cloned().collect::<Vec<_>>().join(", ")
                    }
                )
            })?;
            installer(&mut registry);
        }
    }
    // `registry.solver` is an alias of `train.solver`; when both are set
    // the higher-precedence *layer* wins (so a `--set train.solver=...`
    // CLI override still beats a TOML `[registry] solver`), and
    // `registry.solver` breaks same-layer ties as the more specific key.
    let reg_solver = m.str_of("registry.solver")?;
    let registry_solver_wins = match (m.get("registry.solver"), m.get("train.solver")) {
        (Some(r), Some(t)) => r.layer >= t.layer,
        (Some(_), None) => true,
        _ => false,
    };
    let solver_key = if registry_solver_wins {
        if let Some(v) = reg_solver {
            cfg.solver = v;
        }
        "registry.solver"
    } else {
        "train.solver"
    };
    let spec = registry.validate_spec(&cfg.solver).map_err(|e| match m.get(solver_key) {
        Some(a) => anyhow!("{e} {}", cite(a)),
        None => anyhow!("{e} (defaulted)"),
    })?;
    // [linalg] precision = "mixed" only changes the RNLA sketch GEMMs. A
    // spec whose strategy never sketches (exact EVD, deterministic
    // truncation) would silently run full f64 while the config claims
    // otherwise — reject the combination up front, citing the layer that
    // asked for it.
    if cfg.linalg.precision == Precision::Mixed
        && !mixed_precision_supported(spec.strategy.as_deref())
    {
        let where_set = match m.get("linalg.precision") {
            Some(a) => format!(" {}", cite(a)),
            None => String::new(),
        };
        bail!(
            "[linalg] precision = \"mixed\" has no effect on solver '{}': strategy '{}' has \
             no sketch path (it is exact/EVD-only) — drop the precision override or pick a \
             sketched solver spec (e.g. rs-kfac, sre-kfac, nys-kfac){where_set}",
            cfg.solver,
            spec.strategy.as_deref().unwrap_or("none"),
        );
    }
    // [factored] core must name a column-factoring decomposition the
    // assembled registry actually knows — a dense core (rsvd, exact, …)
    // cannot consume retained-U gradient columns.
    if cfg.factored.mode != "off" {
        let where_set = match m.get("factored.core") {
            Some(a) => format!(" {}", cite(a)),
            None => String::new(),
        };
        match registry.decompositions().get(&cfg.factored.core) {
            None => bail!(
                "[factored] core '{}' is not a registered decomposition (column-factoring \
                 strategies: {}){where_set}",
                cfg.factored.core,
                registry.column_factoring_keys().join(", "),
            ),
            Some(d) if !d.factors_columns() => bail!(
                "[factored] core '{}' is a dense decomposition — it cannot consume retained-U \
                 gradient columns (column-factoring strategies: {}){where_set}",
                cfg.factored.core,
                registry.column_factoring_keys().join(", "),
            ),
            Some(_) => {}
        }
    }
    // A column-factored *solver spec* (kfac+woodbury, kfac+sketchcore)
    // implies an active factored policy even when the [factored] section is
    // absent, so the inline-only restriction from config.rs must also hold
    // here: retained-U jobs do not ship over the factor transport wire
    // format.
    let spec_factors_columns = spec
        .strategy
        .as_deref()
        .and_then(|k| registry.decompositions().get(k))
        .is_some_and(|d| d.factors_columns());
    if spec_factors_columns && cfg.pipeline.enabled {
        let where_set = match m.get(solver_key) {
            Some(a) => format!(" {}", cite(a)),
            None => String::new(),
        };
        bail!(
            "solver '{}' uses a column-factored strategy, which is inline-only: retained-U \
             refreshes do not ship over the factor transport wire format — disable the \
             [pipeline] section for this solver{where_set}",
            cfg.solver,
        );
    }
    // [schedules] strategy keys must name decompositions the assembled
    // registry actually knows (catches typos and missing extensions).
    for key in cfg.schedules.keys() {
        if registry.decompositions().get(key).is_none() {
            bail!(
                "[schedules] names unknown decomposition strategy '{key}' (known strategies: {})",
                registry.decompositions().keys().join(", ")
            );
        }
    }
    Ok((cfg, registry))
}

/// A fully-resolved, validated experiment: typed config + assembled solver
/// registry + per-key layer provenance. The spec also retains the raw
/// layers it was built from, so [`with_overrides`](ExperimentSpec::with_overrides)
/// can derive per-sweep-cell variants without losing provenance.
#[derive(Clone)]
pub struct ExperimentSpec {
    cfg: TrainConfig,
    registry: SolverRegistry,
    provenance: BTreeMap<String, ConfigLayer>,
    /// `[sweep]` axes in sorted key order: config key → value list.
    sweep_axes: Vec<(String, Vec<String>)>,
    assignments: Vec<Assignment>,
    extensions: BTreeMap<String, ExtensionInstaller>,
}

impl ExperimentSpec {
    /// Shortcut: resolve a spec from a TOML string only.
    pub fn from_toml(text: &str) -> Result<Self> {
        ExperimentBuilder::new().toml_str(text)?.build()
    }

    pub fn cfg(&self) -> &TrainConfig {
        &self.cfg
    }

    pub fn registry(&self) -> &SolverRegistry {
        &self.registry
    }

    /// Which layer set `key` (None = still at its default).
    pub fn layer_of(&self, key: &str) -> Option<ConfigLayer> {
        self.provenance.get(key).copied()
    }

    /// The `[sweep]` axes, in sorted key order: each maps a config key to
    /// the list of values the sweep grid varies it over. Empty when the
    /// experiment declared no `[sweep]` section.
    pub fn sweep_axes(&self) -> &[(String, Vec<String>)] {
        &self.sweep_axes
    }

    /// Re-resolve this spec with extra highest-precedence overrides — how a
    /// sweep cell's axis values become a full, validated per-cell config.
    /// Every layer the original spec was built from is retained, so type
    /// errors and provenance behave exactly as if the override had been a
    /// `--set` on the command line (errors cite `sweep axis key=value`).
    pub fn with_overrides(&self, kvs: &[(String, String)]) -> Result<ExperimentSpec> {
        let mut b = ExperimentBuilder {
            assignments: self.assignments.clone(),
            extensions: self.extensions.clone(),
        };
        for (key, value) in kvs {
            b.push_unquoted(key, value, ConfigLayer::Cli, format!("sweep axis {key}={value}"));
        }
        b.build()
    }

    /// Wire a [`Session`] for this spec (data/model/solver/pipeline, the
    /// built-in trace hook; add more hooks on the returned session).
    pub fn session(&self) -> Session {
        Session::with_registry(self.cfg.clone(), self.registry.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{EngineChoice, ModelChoice};

    #[test]
    fn layer_precedence_toml_builder_cli() {
        let spec = ExperimentBuilder::new()
            .toml_str("[train]\nepochs = 4\nbatch = 16\nsolver = \"sgd\"\n")
            .unwrap()
            .epochs(6)
            .override_set("train.epochs=8")
            .unwrap()
            .build()
            .unwrap();
        assert_eq!(spec.cfg().epochs, 8, "--set beats builder beats TOML");
        assert_eq!(spec.cfg().batch, 16, "TOML value survives when unoverridden");
        assert_eq!(spec.cfg().solver, "sgd");
        assert_eq!(spec.layer_of("train.epochs"), Some(ConfigLayer::Cli));
        assert_eq!(spec.layer_of("train.batch"), Some(ConfigLayer::Toml));
        assert_eq!(spec.layer_of("train.seed"), None);
    }

    #[test]
    fn precedence_is_call_order_independent() {
        // Builder call *before* the TOML layer still wins over it.
        let spec = ExperimentBuilder::new()
            .epochs(6)
            .toml_str("[train]\nepochs = 4\n")
            .unwrap()
            .build()
            .unwrap();
        assert_eq!(spec.cfg().epochs, 6);
    }

    #[test]
    fn errors_cite_the_offending_layer() {
        let err = ExperimentBuilder::new()
            .toml_str("[train]\nepochs = 4\n")
            .unwrap()
            .override_set("train.epochs=-2")
            .unwrap()
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("--set train.epochs=-2"), "{err}");
        assert!(err.contains("non-negative integer"), "{err}");

        let err = ExperimentBuilder::new()
            .toml_str("[train]\nepochs = \"ten\"\n")
            .unwrap()
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("TOML"), "{err}");

        let err = ExperimentBuilder::new()
            .set("train.epohs", "5")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown config key 'train.epohs'"), "{err}");
        assert!(err.contains("train.epochs"), "should list section keys: {err}");
        assert!(err.contains("builder"), "{err}");
    }

    #[test]
    fn registry_solver_key_resolves_and_cites_on_typo() {
        let spec = ExperimentSpec::from_toml("[registry]\nsolver = \"kfac+rsvd\"\n").unwrap();
        assert_eq!(spec.cfg().solver, "kfac+rsvd");
        let err = ExperimentSpec::from_toml("[registry]\nsolver = \"kfac+rsvdd\"\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("known specs"), "{err}");
        assert!(err.contains("kfac+rsvd"), "{err}");
        assert!(err.contains("TOML"), "{err}");
    }

    /// A higher-precedence `train.solver` must beat a TOML
    /// `[registry] solver` — the alias participates in layering, it does
    /// not short-circuit it.
    #[test]
    fn registry_solver_respects_layer_precedence() {
        let spec = ExperimentBuilder::new()
            .toml_str("[registry]\nsolver = \"kfac+rsvd\"\n")
            .unwrap()
            .override_set("train.solver=sgd")
            .unwrap()
            .build()
            .unwrap();
        assert_eq!(spec.cfg().solver, "sgd", "--set train.solver beats TOML registry.solver");
        // Same layer: registry.solver wins as the more specific key.
        let spec = ExperimentBuilder::new()
            .toml_str("[train]\nsolver = \"sgd\"\n[registry]\nsolver = \"kfac+rsvd\"\n")
            .unwrap()
            .build()
            .unwrap();
        assert_eq!(spec.cfg().solver, "kfac+rsvd");
    }

    /// Keys that only apply under another key's value error instead of
    /// being silently dropped.
    #[test]
    fn inapplicable_known_keys_rejected() {
        for (toml, needle) in [
            ("[data]\nroot = \"/my/cifar\"\n", "data.root requires"),
            ("[data]\nkind = \"cifar\"\nheight = 64\n", "data.height requires"),
            // Kind-less sections: the lenient legacy parser ignores them,
            // so the strict resolver must refuse rather than guess.
            ("[data]\nn_train = 64\n", "data.n_train requires"),
            ("[model]\nwidths = [108, 32, 10]\n", "model.widths requires"),
            ("[model]\nscale_div = 4\n", "model.scale_div requires"),
            ("[model]\nkind = \"vgg16_bn\"\nwidths = [1, 2]\n", "model.widths requires"),
            ("[engine]\nkind = \"native\"\nconfig = \"quick\"\n", "engine.config requires"),
        ] {
            let err = ExperimentSpec::from_toml(toml).unwrap_err().to_string();
            assert!(err.contains(needle), "{toml}: {err}");
            assert!(err.contains("TOML"), "{toml}: {err}");
        }
        // The same keys resolve fine when applicable.
        let spec = ExperimentSpec::from_toml(
            "[data]\nkind = \"cifar\"\nroot = \"/my/cifar\"\n\
             [model]\nkind = \"vgg16_bn\"\nscale_div = 4\n\
             [engine]\nkind = \"pjrt\"\nconfig = \"quick\"\n",
        )
        .unwrap();
        assert_eq!(spec.cfg().model, ModelChoice::Vgg16Bn { scale_div: 4 });
        // And a *higher-layer* kind override supersedes lower-layer
        // companion keys instead of erroring (the quickstart fallback
        // pattern: TOML pjrt block, builder flips to native).
        let spec = ExperimentBuilder::new()
            .toml_str("[engine]\nkind = \"pjrt\"\nconfig = \"quick\"\n")
            .unwrap()
            .set("engine.kind", "native")
            .build()
            .unwrap();
        assert_eq!(spec.cfg().engine, EngineChoice::Native);
    }

    #[test]
    fn unknown_extension_lists_catalog() {
        let err = ExperimentBuilder::new()
            .toml_str("[registry]\nextensions = [\"nope\"]\n")
            .unwrap()
            .extension("real-ext", |_r| {})
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown extension 'nope'"), "{err}");
        assert!(err.contains("real-ext"), "{err}");
    }

    #[test]
    fn schedules_keys_must_name_known_strategies() {
        let err = ExperimentSpec::from_toml("[schedules]\nrsvdd_oversample_base = 8\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown decomposition strategy 'rsvdd'"), "{err}");
        // A valid key resolves.
        let spec = ExperimentSpec::from_toml("[schedules]\nrsvd_oversample_base = 8\n").unwrap();
        assert_eq!(spec.cfg().schedules.keys(), vec!["rsvd"]);
    }

    /// Convenience flags are sugar for `--set` on the same layer: within
    /// the CLI layer, whichever came later on the command line wins.
    #[test]
    fn cli_args_preserve_command_line_order() {
        use crate::util::cli::Args;
        let parse = |s: &str| Args::parse(s.split_whitespace().map(String::from));
        let table = [("solver", "train.solver")];
        let spec = ExperimentBuilder::new()
            .cli_args(&parse("train --set train.solver=sgd --solver rs-kfac"), &table)
            .unwrap()
            .build()
            .unwrap();
        assert_eq!(spec.cfg().solver, "rs-kfac", "later convenience flag wins");
        let spec = ExperimentBuilder::new()
            .cli_args(&parse("train --solver rs-kfac --set train.solver=sgd"), &table)
            .unwrap()
            .build()
            .unwrap();
        assert_eq!(spec.cfg().solver, "sgd", "later --set wins");
        // Untabled flags pass through untouched.
        let spec = ExperimentBuilder::new()
            .cli_args(&parse("train --config x.toml --jobs 4"), &table)
            .unwrap()
            .build()
            .unwrap();
        assert_eq!(spec.cfg().solver, "rs-kfac", "default untouched");
    }

    #[test]
    fn bare_string_values_accepted_from_set_layers() {
        let spec = ExperimentBuilder::new()
            .solver("kfac+nystrom")
            .set("train.out_dir", "results/exp")
            .override_set("data.kind=synthetic")
            .unwrap()
            .build()
            .unwrap();
        assert_eq!(spec.cfg().solver, "kfac+nystrom");
        assert_eq!(spec.cfg().out_dir, "results/exp");
        // Numeric-looking values for string-typed keys keep their literal
        // text (a date-stamped out_dir is a real directory name) — even
        // when the parsed scalar would round-trip differently.
        for (raw, want) in [("20260801", "20260801"), ("007", "007"), ("1.50", "1.50")] {
            let spec = ExperimentBuilder::new()
                .override_set(&format!("train.out_dir={raw}"))
                .unwrap()
                .build()
                .unwrap();
            assert_eq!(spec.cfg().out_dir, want);
        }
    }

    /// A value-less `--set` (parsed as a switch) errors instead of being
    /// silently dropped.
    #[test]
    fn cli_args_reject_valueless_flags() {
        use crate::util::cli::Args;
        let parse = |s: &str| Args::parse(s.split_whitespace().map(String::from));
        let table = [("solver", "train.solver")];
        let err = ExperimentBuilder::new()
            .cli_args(&parse("train --set --early-stop"), &table)
            .unwrap_err()
            .to_string();
        assert!(err.contains("--set needs key=value"), "{err}");
        let err = ExperimentBuilder::new()
            .cli_args(&parse("train --solver"), &table)
            .unwrap_err()
            .to_string();
        assert!(err.contains("--solver needs a value"), "{err}");
    }

    /// The strict resolver and the lenient legacy `TrainConfig::from_toml`
    /// are two mappings over the same key space; this pins them to
    /// identical outputs on a document exercising every section, so a key
    /// added to one side without the other fails here (full consolidation
    /// is tracked as a ROADMAP follow-up).
    #[test]
    fn resolver_matches_legacy_from_toml() {
        const DOC: &str = r#"
[train]
solver = "kfac+srevd"
epochs = 7
batch = 48
seed = 9
targets = [0.5, 0.75]
augment = true
out_dir = "results/drift"
sched_width = 256

[model]
kind = "mlp"
widths = [768, 256, 10]

[data]
kind = "synthetic"
n_train = 640
n_test = 128
height = 16
width = 16
channels = 3

[engine]
kind = "pjrt"
config = "quick"

[pipeline]
enabled = true
workers = 3
max_stale_steps = 4
schedule = "fifo"
adaptive_rank = true
adaptive_sketch = true
target_rel_err = 0.05
min_rank = 12
growth = 2.0
prop31_batch = 48
transport = "dir"
endpoint = "/tmp/rkfac-mail"
connect_timeout_ms = 400
io_timeout_ms = 1200
max_retries = 2

[linalg]
backend = "threaded"
threads = 2
precision = "mixed"

[factored]
mode = "off"
width_threshold = 9000
core = "sketchcore"
max_cols = 192
col_sample = 48

[obs]
enabled = true
jsonl = true
chrome_trace = false
summary = false

[schedules]
rsvd_oversample_base = 10
rsvd_oversample_steps = [22, 1]
rsvd_power_iter_base = 4
rsvd_target_rel_err = 0.03
"#;
        let legacy = TrainConfig::from_toml(DOC).unwrap();
        let spec = ExperimentSpec::from_toml(DOC).unwrap();
        assert_eq!(&legacy, spec.cfg());
    }

    /// `[linalg]` resolves through the shared mapping; `precision =
    /// "mixed"` on an exact/EVD-only solver spec is rejected with a cite
    /// of the layer that set it.
    #[test]
    fn linalg_mixed_precision_rejected_on_exact_specs() {
        use crate::linalg::backend::BackendKind;
        let spec = ExperimentSpec::from_toml(
            "[train]\nsolver = \"rs-kfac\"\n\
             [linalg]\nbackend = \"threaded\"\nthreads = 3\nprecision = \"mixed\"\n",
        )
        .unwrap();
        assert_eq!(spec.cfg().linalg.backend, BackendKind::Threaded);
        assert_eq!(spec.cfg().linalg.threads, 3);
        assert_eq!(spec.cfg().linalg.precision, Precision::Mixed);
        // Bare "kfac" is the exact-EVD solver: mixed has nothing to act on.
        let err = ExperimentBuilder::new()
            .toml_str("[train]\nsolver = \"kfac\"\n")
            .unwrap()
            .set("linalg.precision", "mixed")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("no sketch path"), "{err}");
        assert!(err.contains("builder"), "error must cite the layer: {err}");
        // trunc is deterministic truncation — also sketch-free.
        assert!(ExperimentSpec::from_toml(
            "[train]\nsolver = \"trunc-kfac\"\n[linalg]\nprecision = \"mixed\"\n"
        )
        .is_err());
        // Unknown enum values error through the shared `invalid` path.
        let err =
            ExperimentSpec::from_toml("[linalg]\nbackend = \"gpu\"\n").unwrap_err().to_string();
        assert!(err.contains("unknown [linalg] backend"), "{err}");
    }

    /// `[factored]` resolves through the shared mapping; the resolver
    /// rejects a dense core, an unknown core, and the column-factored ×
    /// pipeline combination (inline-only) with layer cites.
    #[test]
    fn factored_section_resolves_and_cross_checks() {
        let spec = ExperimentSpec::from_toml(
            "[train]\nsolver = \"kfac\"\n\
             [factored]\nmode = \"hybrid\"\nwidth_threshold = 2048\ncore = \"sketchcore\"\n",
        )
        .unwrap();
        assert_eq!(spec.cfg().factored.mode, "hybrid");
        assert_eq!(spec.cfg().factored.width_threshold, 2048);
        assert_eq!(spec.cfg().factored.core, "sketchcore");
        // A dense core cannot consume retained-U gradient columns.
        let err = ExperimentBuilder::new()
            .toml_str("[train]\nsolver = \"kfac\"\n[factored]\nmode = \"all\"\n")
            .unwrap()
            .set("factored.core", "rsvd")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("dense decomposition"), "{err}");
        assert!(err.contains("woodbury"), "should list column-factoring strategies: {err}");
        assert!(err.contains("builder"), "error must cite the layer: {err}");
        // Unknown core keys are caught with the same strategy listing.
        let err = ExperimentSpec::from_toml(
            "[factored]\nmode = \"all\"\ncore = \"nope\"\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("not a registered decomposition"), "{err}");
        // Unknown modes error through the shared `invalid` path.
        let err = ExperimentSpec::from_toml("[factored]\nmode = \"always\"\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown [factored] mode"), "{err}");
        // Column-factored specs are inline-only, even with no [factored]
        // section: retained-U refreshes do not ship over the transport.
        let err = ExperimentSpec::from_toml(
            "[train]\nsolver = \"kfac+woodbury\"\n[pipeline]\nenabled = true\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("inline-only"), "{err}");
        // An explicit [factored] policy × pipeline is rejected at the
        // shared-mapping layer with the same rationale.
        let err = ExperimentSpec::from_toml(
            "[factored]\nmode = \"all\"\n[pipeline]\nenabled = true\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("inline-only"), "{err}");
    }

    /// `[sweep]` axes: parsed into sorted (key, values) pairs, validated
    /// against the known key space, and expanded per cell through the
    /// `--set` layer by `with_overrides`.
    #[test]
    fn sweep_axes_parse_expand_and_reject_typos() {
        let spec = ExperimentSpec::from_toml(
            "[train]\nepochs = 2\n\
             [sweep]\npipeline.max_stale_steps = [0, 4]\ntrain.batch = [16, 32]\n",
        )
        .unwrap();
        let want: Vec<(String, Vec<String>)> = vec![
            (
                "pipeline.max_stale_steps".to_string(),
                vec!["0".to_string(), "4".to_string()],
            ),
            ("train.batch".to_string(), vec!["16".to_string(), "32".to_string()]),
        ];
        assert_eq!(spec.sweep_axes(), want.as_slice());
        // Declaring axes does not perturb the base config.
        assert_eq!(spec.cfg().epochs, 2);
        assert_eq!(spec.cfg().pipeline.max_stale_steps, 0);

        // A cell's axis values re-resolve as highest-precedence overrides,
        // with every base layer retained.
        let cell = spec
            .with_overrides(&[
                ("pipeline.max_stale_steps".to_string(), "4".to_string()),
                ("train.batch".to_string(), "32".to_string()),
            ])
            .unwrap();
        assert_eq!(cell.cfg().pipeline.max_stale_steps, 4);
        assert_eq!(cell.cfg().batch, 32);
        assert_eq!(cell.cfg().epochs, 2, "base layers are retained");
        assert_eq!(cell.layer_of("train.batch"), Some(ConfigLayer::Cli));

        // Axis targets are validated against the known key space.
        let err = ExperimentSpec::from_toml("[sweep]\npipeline.max_stale = [0]\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown config key 'pipeline.max_stale'"), "{err}");
        // Scalar axis values are a type error, not a one-cell sweep.
        let err = ExperimentSpec::from_toml("[sweep]\ntrain.batch = 16\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("expected an array"), "{err}");
        // A bad axis *value* fails at expansion, citing the axis.
        let spec = ExperimentSpec::from_toml("[sweep]\ntrain.epochs = [-1]\n").unwrap();
        let err = spec
            .with_overrides(&[("train.epochs".to_string(), "-1".to_string())])
            .unwrap_err()
            .to_string();
        assert!(err.contains("sweep axis train.epochs=-1"), "{err}");
    }

    #[test]
    fn full_spec_roundtrip_with_pipeline_and_model() {
        let spec = ExperimentBuilder::new()
            .toml_str(
                "[model]\nkind = \"mlp\"\nwidths = [108, 32, 10]\n\
                 [data]\nkind = \"synthetic\"\nn_train = 320\nn_test = 96\nheight = 6\nwidth = 6\n\
                 [pipeline]\nenabled = true\nmax_stale_steps = 0\n",
            )
            .unwrap()
            .solver("kfac+rsvd")
            .epochs(2)
            .batch(32)
            .seed(0)
            .build()
            .unwrap();
        assert!(spec.cfg().pipeline.enabled);
        assert_eq!(spec.cfg().pipeline.max_stale_steps, 0);
        assert_eq!(spec.cfg().model, ModelChoice::Mlp { widths: vec![108, 32, 10] });
        let session = spec.session();
        assert_eq!(session.cfg().solver, "kfac+rsvd");
        assert_eq!(session.hook_names(), vec!["trace"]);
    }
}
