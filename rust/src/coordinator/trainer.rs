//! Legacy trainer surface — thin shims over the Experiment API.
//!
//! **Deprecation policy (see ROADMAP.md):** the free functions here
//! (`run`, `run_native`, `run_pjrt`, plus the `load_data` /
//! `build_schedules` / eval helpers) are the pre-Experiment-API entry
//! points. They now delegate verbatim to
//! [`Session`](crate::coordinator::session::Session) — same wiring, same
//! RNG streams, same observation order — and the golden suite
//! (`rust/tests/experiment_api.rs`) pins the shim path bitwise against a
//! directly-constructed `Session`. They stay so every existing example,
//! test, bench and embedder call site keeps compiling, but new code should
//! construct an
//! [`ExperimentBuilder`](crate::coordinator::experiment::ExperimentBuilder)
//! / `Session` directly: that is the only surface that reaches the
//! `[registry]` and `[schedules]` config sections, layered `--set`
//! overrides, and run hooks.

use anyhow::Result;

use crate::coordinator::config::TrainConfig;
use crate::coordinator::metrics::RunResult;
use crate::coordinator::session::Session;
use crate::runtime::Engine;

// The data/schedule/eval helpers live with the session now; re-exported so
// `trainer::load_data`-style call sites (spectrum probe, e2e tests) keep
// working unchanged.
pub use crate::coordinator::session::{build_schedules, evaluate_native, evaluate_pjrt, load_data};

/// Train with the native Rust nn engine. Shim over [`Session::run_native`].
pub fn run_native(cfg: &TrainConfig) -> Result<RunResult> {
    Session::new(cfg.clone()).run_native()
}

/// Train through the PJRT artifact engine with an explicit engine handle.
/// Shim over [`Session::run_pjrt`].
pub fn run_pjrt(cfg: &TrainConfig, engine: std::sync::Arc<Engine>) -> Result<RunResult> {
    Session::new(cfg.clone()).run_pjrt(engine)
}

/// Dispatch on the configured engine. Shim over [`Session::run`].
pub fn run(cfg: &TrainConfig) -> Result<RunResult> {
    Session::new(cfg.clone()).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{DataChoice, EngineChoice, ModelChoice};

    fn tiny_cfg(solver: &str) -> TrainConfig {
        TrainConfig {
            solver: solver.into(),
            epochs: 3,
            batch: 32,
            seed: 1,
            model: ModelChoice::Mlp { widths: vec![108, 32, 10] },
            data: DataChoice::Synthetic { n_train: 320, n_test: 96, height: 6, width: 6, channels: 3 },
            engine: EngineChoice::Native,
            targets: vec![0.5],
            augment: false,
            out_dir: "/tmp/rkfac_trainer_test".into(),
            sched_width: 0,
            ..Default::default()
        }
    }

    #[test]
    fn native_run_learns_synthetic() {
        for solver in ["rs-kfac", "sre-kfac", "kfac", "seng", "sgd"] {
            let r = run_native(&tiny_cfg(solver)).unwrap();
            assert_eq!(r.records.len(), 3, "{solver}");
            let first = r.records.first().unwrap();
            let last = r.records.last().unwrap();
            assert!(last.test_loss.is_finite(), "{solver}");
            assert!(
                last.test_acc > 0.2 || last.test_loss < first.test_loss,
                "{solver}: no progress (acc {}, loss {} -> {})",
                last.test_acc,
                first.test_loss,
                last.test_loss,
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_native(&tiny_cfg("rs-kfac")).unwrap();
        let b = run_native(&tiny_cfg("rs-kfac")).unwrap();
        for (ra, rb) in a.records.iter().zip(b.records.iter()) {
            assert!((ra.train_loss - rb.train_loss).abs() < 1e-12);
            assert!((ra.test_acc - rb.test_acc).abs() < 1e-12);
        }
    }

    /// Canonical `family+strategy` specs work straight from the config and
    /// train identically to their legacy alias.
    #[test]
    fn canonical_solver_spec_from_config() {
        let legacy = run_native(&tiny_cfg("rs-kfac")).unwrap();
        let spec = run_native(&tiny_cfg("kfac+rsvd")).unwrap();
        for (ra, rb) in legacy.records.iter().zip(spec.records.iter()) {
            assert_eq!(ra.train_loss, rb.train_loss);
            assert_eq!(ra.test_acc, rb.test_acc);
        }
    }

    #[test]
    fn mismatched_widths_rejected() {
        let mut cfg = tiny_cfg("sgd");
        cfg.model = ModelChoice::Mlp { widths: vec![999, 32, 10] };
        assert!(run_native(&cfg).is_err());
    }

    #[test]
    fn decomp_time_tracked_for_kfac_family() {
        let r = run_native(&tiny_cfg("rs-kfac")).unwrap();
        assert!(r.records.last().unwrap().decomp_s > 0.0);
        let r2 = run_native(&tiny_cfg("sgd")).unwrap();
        assert_eq!(r2.records.last().unwrap().decomp_s, 0.0);
    }

    #[test]
    fn rank_trace_recorded_per_refresh_round() {
        let r = run_native(&tiny_cfg("rs-kfac")).unwrap();
        // Model [108, 32, 10] → 2 Kronecker blocks, ≥ 1 refresh round.
        assert!(!r.rank_trace.is_empty());
        assert_eq!(r.rank_trace[0].round, 0);
        let blocks: Vec<usize> =
            r.rank_trace.iter().filter(|t| t.round == 0).map(|t| t.block).collect();
        assert_eq!(blocks, vec![0, 1]);
        for t in &r.rank_trace {
            assert!(t.rank_a > 0 && t.rank_g > 0);
        }
        // Solvers without decompositions leave the trace empty.
        let r2 = run_native(&tiny_cfg("sgd")).unwrap();
        assert!(r2.rank_trace.is_empty());
    }

    #[test]
    fn pipelined_run_learns_and_zero_staleness_matches_sync() {
        let sync = run_native(&tiny_cfg("rs-kfac")).unwrap();
        // max_stale_steps = 0 + schedule rank → bit-identical to inline.
        let mut cfg0 = tiny_cfg("rs-kfac");
        cfg0.pipeline.enabled = true;
        cfg0.pipeline.workers = 2;
        cfg0.pipeline.max_stale_steps = 0;
        let piped0 = run_native(&cfg0).unwrap();
        for (a, b) in sync.records.iter().zip(piped0.records.iter()) {
            assert_eq!(a.train_loss, b.train_loss, "zero-staleness must match sync exactly");
            assert_eq!(a.test_acc, b.test_acc);
        }
        // Stale + adaptive variant must still learn.
        let mut cfg = tiny_cfg("rs-kfac");
        cfg.pipeline.enabled = true;
        cfg.pipeline.max_stale_steps = 8;
        cfg.pipeline.adaptive_rank = true;
        let piped = run_native(&cfg).unwrap();
        let last = piped.records.last().unwrap();
        assert!(last.test_loss.is_finite());
        assert!(
            last.test_acc > 0.2 || last.test_loss < piped.records[0].test_loss,
            "pipelined run made no progress"
        );
    }
}
