//! The trainer: the L3 event loop tying data → model (native nn or PJRT
//! artifacts) → solver → parameter update → metrics.
//!
//! Mirrors Algorithm 1 at the system level: per batch, a fused fwd/bwd
//! produces loss, gradients and fresh K-factor information; the solver owns
//! the EA factors + decomposition cadence (T_KU / T_KI); weight updates are
//! applied with the §5 schedules.

use anyhow::{bail, Context, Result};

use crate::coordinator::config::{DataChoice, EngineChoice, ModelChoice, TrainConfig};
use crate::coordinator::metrics::{EpochRecord, PipeTraceRow, RankTraceRow, RunResult};
use crate::data::{self, Augment, Batcher, Dataset};
use crate::linalg::{Matrix, Pcg64};
use crate::nn::{models, Network};
use crate::nn::loss::one_hot;
use crate::optim::{build_solver, KfacSchedules, Preconditioner};
use crate::runtime::{CompiledModel, Engine};

/// Load (train, test) datasets per the config, normalized with train stats.
pub fn load_data(cfg: &TrainConfig) -> Result<(Dataset, Dataset)> {
    let (mut train, mut test) = match &cfg.data {
        DataChoice::Synthetic { n_train, n_test, height, width, channels } => {
            let scfg = data::SyntheticConfig {
                height: *height,
                width: *width,
                channels: *channels,
                ..Default::default()
            };
            data::generate_split(&scfg, *n_train, *n_test, cfg.seed.wrapping_add(9000))
        }
        DataChoice::Cifar { root, n_train, n_test } => {
            if !data::cifar::is_available(root) {
                bail!(
                    "CIFAR-10 binaries not found under '{root}'. Download \
                     cifar-10-binary.tar.gz and extract, or use [data] kind = \"synthetic\"."
                );
            }
            let (mut tr, mut te) = data::cifar::load_standard(root)?;
            if *n_train < tr.len() {
                let drop = tr.len() - n_train;
                tr = tr.split_tail(drop).0;
            }
            if *n_test < te.len() {
                let drop = te.len() - n_test;
                te = te.split_tail(drop).0;
            }
            (tr, te)
        }
    };
    let (mean, std) = train.normalize();
    test.apply_normalization(&mean, &std);
    Ok((train, test))
}

/// Build the schedule block for the configured run length / width.
pub fn build_schedules(cfg: &TrainConfig) -> KfacSchedules {
    let width = if cfg.sched_width > 0 {
        cfg.sched_width
    } else {
        match &cfg.model {
            ModelChoice::Mlp { widths } => widths.iter().copied().max().unwrap_or(512),
            ModelChoice::Vgg16Bn { scale_div } => (512 / scale_div).max(4),
        }
    };
    KfacSchedules::scaled(cfg.epochs.max(1), width)
}

fn build_network(cfg: &TrainConfig) -> Result<Network> {
    Ok(match &cfg.model {
        ModelChoice::Mlp { widths } => {
            if widths[0] != cfg.input_dim() {
                bail!("model input width {} != data dim {}", widths[0], cfg.input_dim());
            }
            models::mlp(widths, cfg.seed)
        }
        ModelChoice::Vgg16Bn { scale_div } => {
            if cfg.input_dim() != 3 * 32 * 32 {
                bail!("vgg16_bn needs 32x32x3 inputs; set data height/width = 32");
            }
            models::vgg16_bn(10, *scale_div, cfg.seed)
        }
    })
}

/// Attach the async factor-refresh pipeline when `[pipeline] enabled`.
/// `prop31_batch = 0` (the default) leaves the Prop. 3.1 cap disabled, as
/// documented on [`crate::pipeline::PipelineConfig`]; set it to the batch
/// size in the TOML to engage the paper's `min(r_ε·n_M, d)` mode bound.
fn attach_pipeline_if_enabled(cfg: &TrainConfig, solver: &mut dyn Preconditioner) {
    if !cfg.pipeline.enabled {
        return;
    }
    if !solver.attach_pipeline(&cfg.pipeline) {
        eprintln!(
            "[rkfac] note: solver '{}' has no decomposition cadence; [pipeline] ignored",
            solver.name()
        );
    } else if cfg.pipeline.max_stale_steps == 0 {
        eprintln!(
            "[rkfac] note: [pipeline] max_stale_steps = 0 is synchronous semantics (every \
             refresh blocks for the full round) — useful for validation, but expect no \
             speedup over the inline path"
        );
    }
}

fn augment_for(cfg: &TrainConfig) -> Augment {
    let (c, h, w) = match &cfg.data {
        DataChoice::Synthetic { height, width, channels, .. } => (*channels, *height, *width),
        DataChoice::Cifar { .. } => (3, 32, 32),
    };
    if cfg.augment {
        Augment::cifar(c, h, w)
    } else {
        Augment::none(c, h, w)
    }
}

/// Collects the per-block adaptive rank trace plus — with the async
/// pipeline attached — per-round scheduler telemetry: after each step, if
/// the solver ran a refresh round since the last probe, record the
/// per-block decomposition ranks it *installed* (see
/// [`RankTraceRow`](crate::coordinator::metrics::RankTraceRow) for the
/// stale-pipeline caveat) and the pipeline's queue-depth / recovery /
/// supersede / warm-up counters for that round.
struct RankTracer {
    last_rounds: usize,
    rows: Vec<RankTraceRow>,
    pipe_rows: Vec<PipeTraceRow>,
}

impl RankTracer {
    fn new() -> Self {
        RankTracer { last_rounds: 0, rows: Vec::new(), pipe_rows: Vec::new() }
    }

    fn probe(&mut self, solver: &dyn Preconditioner, epoch: usize, step: usize) {
        let diag = solver.diagnostics();
        if diag.n_decomps <= self.last_rounds {
            return;
        }
        self.last_rounds = diag.n_decomps;
        for (block, &(rank_a, rank_g)) in diag.block_ranks.iter().enumerate() {
            self.rows.push(RankTraceRow {
                round: diag.n_decomps - 1,
                epoch,
                step,
                block,
                rank_a,
                rank_g,
            });
        }
        if let Some(p) = &diag.pipeline {
            self.pipe_rows.push(PipeTraceRow {
                round: diag.n_decomps - 1,
                epoch,
                step,
                queue_depth: p.queue_depth,
                max_queue_depth: p.max_queue_depth,
                recovered_jobs: p.recovered_jobs,
                superseded_jobs: p.superseded_jobs,
                warming_slots: p.warming_slots,
                max_staleness: p.max_staleness,
            });
        }
    }
}

/// Train with the native Rust nn engine. Returns the per-epoch record set.
pub fn run_native(cfg: &TrainConfig) -> Result<RunResult> {
    let (train, test) = load_data(cfg)?;
    let mut net = build_network(cfg)?;
    let sched = build_schedules(cfg);
    let dims = net.kfac_dims();
    let mut solver = build_solver(&cfg.solver, sched, &dims, cfg.seed).map_err(anyhow::Error::msg)?;
    attach_pipeline_if_enabled(cfg, solver.as_mut());
    let aug = augment_for(cfg);
    let mut rng = Pcg64::with_stream(cfg.seed, 31337);
    let t0 = std::time::Instant::now();
    let mut records = Vec::new();
    let mut tracer = RankTracer::new();
    let mut global_step = 0usize;
    for epoch in 0..cfg.epochs {
        let mut epoch_loss = 0.0;
        let mut nb = 0usize;
        for idx in Batcher::new(train.len(), cfg.batch, &mut rng) {
            let (mut xb, yb) = train.gather(&idx);
            aug.apply(&mut xb, &mut rng);
            let (loss, _) = net.train_batch(&xb, &yb, true);
            let deltas = {
                let caps = net.kfac_captures();
                solver.step(epoch, &caps)
            };
            let (lr, wd) = solver.lr_wd(epoch);
            net.apply_steps(&deltas, lr, wd);
            tracer.probe(solver.as_ref(), epoch, global_step);
            global_step += 1;
            epoch_loss += loss;
            nb += 1;
        }
        let (test_loss, test_acc) = evaluate_native(&mut net, &test, cfg.batch);
        records.push(EpochRecord {
            epoch,
            wall_s: t0.elapsed().as_secs_f64(),
            train_loss: epoch_loss / nb.max(1) as f64,
            test_loss,
            test_acc,
            decomp_s: solver.diagnostics().decomp_seconds,
        });
    }
    Ok(RunResult {
        solver: cfg.solver.clone(),
        seed: cfg.seed,
        records,
        total_s: t0.elapsed().as_secs_f64(),
        rank_trace: tracer.rows,
        pipe_trace: tracer.pipe_rows,
    })
}

/// Eval loop for the native engine (full batches only).
pub fn evaluate_native(net: &mut Network, test: &Dataset, batch: usize) -> (f64, f64) {
    let mut loss_sum = 0.0;
    let mut correct = 0usize;
    let mut seen = 0usize;
    let mut pos = 0;
    while pos + batch <= test.len() {
        let idx: Vec<usize> = (pos..pos + batch).collect();
        let (xb, yb) = test.gather(&idx);
        let (l, c) = net.eval_batch(&xb, &yb);
        loss_sum += l * batch as f64;
        correct += c;
        seen += batch;
        pos += batch;
    }
    if seen == 0 {
        return (f64::NAN, 0.0);
    }
    (loss_sum / seen as f64, correct as f64 / seen as f64)
}

/// Train through the PJRT artifact engine (MLP configs only; the artifact's
/// `ea_gram` Pallas kernel performs the EA blend — the solver just consumes
/// the blended factors via `step_with_factors`).
pub fn run_pjrt(cfg: &TrainConfig, engine: std::sync::Arc<Engine>) -> Result<RunResult> {
    let artifact = match &cfg.engine {
        EngineChoice::Pjrt { config } => config.clone(),
        _ => bail!("run_pjrt called with a non-PJRT engine choice"),
    };
    let model = CompiledModel::new(engine, &artifact)
        .with_context(|| format!("loading model artifact '{artifact}'"))?;
    let (train, test) = load_data(cfg)?;
    if model.widths()[0] != train.dim() {
        bail!("artifact input width {} != data dim {}", model.widths()[0], train.dim());
    }
    if model.batch() != cfg.batch {
        bail!("artifact batch {} != configured batch {}", model.batch(), cfg.batch);
    }
    let classes = *model.widths().last().unwrap();
    let sched = build_schedules(cfg);
    let dims: Vec<(usize, usize)> =
        (0..model.n_layers()).map(|l| (model.widths()[l], model.widths()[l + 1])).collect();
    let mut solver =
        build_solver(&cfg.solver, sched, &dims, cfg.seed).map_err(anyhow::Error::msg)?;
    if !solver.supports_external_factors() {
        bail!(
            "PJRT path needs a solver that accepts externally-computed factors \
             (the K-FAC engine family: kfac/rs-kfac/sre-kfac/trunc-kfac/nys-kfac); \
             '{}' does not",
            solver.name()
        );
    }
    attach_pipeline_if_enabled(cfg, solver.as_mut());
    let mut rng = Pcg64::with_stream(cfg.seed, 31338);
    let mut weights = model.init_weights(&mut rng);
    let (mut a_f, mut g_f) = model.init_factors();
    let aug = augment_for(cfg);
    let t0 = std::time::Instant::now();
    let mut records = Vec::new();
    let mut tracer = RankTracer::new();
    let mut global_step = 0usize;
    for epoch in 0..cfg.epochs {
        let mut epoch_loss = 0.0;
        let mut nb = 0usize;
        for idx in Batcher::new(train.len(), cfg.batch, &mut rng) {
            let (mut xb, yb) = train.gather(&idx);
            aug.apply(&mut xb, &mut rng);
            let y = one_hot(&yb, classes);
            let out = model.step(&weights, &a_f, &g_f, &xb, &y)?;
            a_f = out.a_factors;
            g_f = out.g_factors;
            let grads: Vec<&Matrix> = out.grads.iter().collect();
            let deltas = solver
                .step_with_factors(epoch, a_f.clone(), g_f.clone(), &grads)
                .map_err(anyhow::Error::msg)?;
            let (lr, wd) = solver.lr_wd(epoch);
            for (w, d) in weights.iter_mut().zip(deltas.iter()) {
                for (wv, dv) in w.as_mut_slice().iter_mut().zip(d.as_slice()) {
                    *wv = *wv * (1.0 - lr * wd) + dv;
                }
            }
            tracer.probe(solver.as_ref(), epoch, global_step);
            global_step += 1;
            epoch_loss += out.loss;
            nb += 1;
        }
        let (test_loss, test_acc) = evaluate_pjrt(&model, &weights, &test, classes)?;
        records.push(EpochRecord {
            epoch,
            wall_s: t0.elapsed().as_secs_f64(),
            train_loss: epoch_loss / nb.max(1) as f64,
            test_loss,
            test_acc,
            decomp_s: solver.diagnostics().decomp_seconds,
        });
    }
    Ok(RunResult {
        solver: cfg.solver.clone(),
        seed: cfg.seed,
        records,
        total_s: t0.elapsed().as_secs_f64(),
        rank_trace: tracer.rows,
        pipe_trace: tracer.pipe_rows,
    })
}

/// Eval loop for the PJRT engine.
pub fn evaluate_pjrt(
    model: &CompiledModel,
    weights: &[Matrix],
    test: &Dataset,
    classes: usize,
) -> Result<(f64, f64)> {
    let batch = model.batch();
    let mut loss_sum = 0.0;
    let mut correct = 0usize;
    let mut seen = 0usize;
    let mut pos = 0;
    while pos + batch <= test.len() {
        let idx: Vec<usize> = (pos..pos + batch).collect();
        let (xb, yb) = test.gather(&idx);
        let y = one_hot(&yb, classes);
        let (l, c) = model.eval(weights, &xb, &y)?;
        loss_sum += l * batch as f64;
        correct += c;
        seen += batch;
        pos += batch;
    }
    if seen == 0 {
        return Ok((f64::NAN, 0.0));
    }
    Ok((loss_sum / seen as f64, correct as f64 / seen as f64))
}

/// Dispatch on the configured engine.
pub fn run(cfg: &TrainConfig) -> Result<RunResult> {
    match &cfg.engine {
        EngineChoice::Native => run_native(cfg),
        EngineChoice::Pjrt { .. } => {
            let engine = std::sync::Arc::new(Engine::new("artifacts")?);
            run_pjrt(cfg, engine)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(solver: &str) -> TrainConfig {
        TrainConfig {
            solver: solver.into(),
            epochs: 3,
            batch: 32,
            seed: 1,
            model: ModelChoice::Mlp { widths: vec![108, 32, 10] },
            data: DataChoice::Synthetic { n_train: 320, n_test: 96, height: 6, width: 6, channels: 3 },
            engine: EngineChoice::Native,
            targets: vec![0.5],
            augment: false,
            out_dir: "/tmp/rkfac_trainer_test".into(),
            sched_width: 0,
            pipeline: crate::pipeline::PipelineConfig::default(),
        }
    }

    #[test]
    fn native_run_learns_synthetic() {
        for solver in ["rs-kfac", "sre-kfac", "kfac", "seng", "sgd"] {
            let r = run_native(&tiny_cfg(solver)).unwrap();
            assert_eq!(r.records.len(), 3, "{solver}");
            let first = r.records.first().unwrap();
            let last = r.records.last().unwrap();
            assert!(last.test_loss.is_finite(), "{solver}");
            assert!(
                last.test_acc > 0.2 || last.test_loss < first.test_loss,
                "{solver}: no progress (acc {}, loss {} -> {})",
                last.test_acc,
                first.test_loss,
                last.test_loss,
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_native(&tiny_cfg("rs-kfac")).unwrap();
        let b = run_native(&tiny_cfg("rs-kfac")).unwrap();
        for (ra, rb) in a.records.iter().zip(b.records.iter()) {
            assert!((ra.train_loss - rb.train_loss).abs() < 1e-12);
            assert!((ra.test_acc - rb.test_acc).abs() < 1e-12);
        }
    }

    /// Canonical `family+strategy` specs work straight from the config and
    /// train identically to their legacy alias.
    #[test]
    fn canonical_solver_spec_from_config() {
        let legacy = run_native(&tiny_cfg("rs-kfac")).unwrap();
        let spec = run_native(&tiny_cfg("kfac+rsvd")).unwrap();
        for (ra, rb) in legacy.records.iter().zip(spec.records.iter()) {
            assert_eq!(ra.train_loss, rb.train_loss);
            assert_eq!(ra.test_acc, rb.test_acc);
        }
    }

    #[test]
    fn mismatched_widths_rejected() {
        let mut cfg = tiny_cfg("sgd");
        cfg.model = ModelChoice::Mlp { widths: vec![999, 32, 10] };
        assert!(run_native(&cfg).is_err());
    }

    #[test]
    fn decomp_time_tracked_for_kfac_family() {
        let r = run_native(&tiny_cfg("rs-kfac")).unwrap();
        assert!(r.records.last().unwrap().decomp_s > 0.0);
        let r2 = run_native(&tiny_cfg("sgd")).unwrap();
        assert_eq!(r2.records.last().unwrap().decomp_s, 0.0);
    }

    #[test]
    fn rank_trace_recorded_per_refresh_round() {
        let r = run_native(&tiny_cfg("rs-kfac")).unwrap();
        // Model [108, 32, 10] → 2 Kronecker blocks, ≥ 1 refresh round.
        assert!(!r.rank_trace.is_empty());
        assert_eq!(r.rank_trace[0].round, 0);
        let blocks: Vec<usize> =
            r.rank_trace.iter().filter(|t| t.round == 0).map(|t| t.block).collect();
        assert_eq!(blocks, vec![0, 1]);
        for t in &r.rank_trace {
            assert!(t.rank_a > 0 && t.rank_g > 0);
        }
        // Solvers without decompositions leave the trace empty.
        let r2 = run_native(&tiny_cfg("sgd")).unwrap();
        assert!(r2.rank_trace.is_empty());
    }

    #[test]
    fn pipelined_run_learns_and_zero_staleness_matches_sync() {
        let sync = run_native(&tiny_cfg("rs-kfac")).unwrap();
        // max_stale_steps = 0 + schedule rank → bit-identical to inline.
        let mut cfg0 = tiny_cfg("rs-kfac");
        cfg0.pipeline.enabled = true;
        cfg0.pipeline.workers = 2;
        cfg0.pipeline.max_stale_steps = 0;
        let piped0 = run_native(&cfg0).unwrap();
        for (a, b) in sync.records.iter().zip(piped0.records.iter()) {
            assert_eq!(a.train_loss, b.train_loss, "zero-staleness must match sync exactly");
            assert_eq!(a.test_acc, b.test_acc);
        }
        // Stale + adaptive variant must still learn.
        let mut cfg = tiny_cfg("rs-kfac");
        cfg.pipeline.enabled = true;
        cfg.pipeline.max_stale_steps = 8;
        cfg.pipeline.adaptive_rank = true;
        let piped = run_native(&cfg).unwrap();
        let last = piped.records.last().unwrap();
        assert!(last.test_loss.is_finite());
        assert!(
            last.test_acc > 0.2 || last.test_loss < piped.records[0].test_loss,
            "pipelined run made no progress"
        );
    }
}
