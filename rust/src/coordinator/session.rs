//! The [`Session`]: one wired-up training run behind the Experiment API.
//!
//! A session owns the data/model/solver/pipeline wiring for a single
//! [`TrainConfig`] and drives the Algorithm-1 step loop — per batch, a
//! fused fwd/bwd produces loss, gradients and fresh K-factor information;
//! the solver owns the EA factors + decomposition cadence (T_KU / T_KI);
//! weight updates are applied with the §5 schedules. Everything
//! *observational* (metrics CSVs, rank/pipe traces, checkpoints, spectrum
//! probes, early stopping) goes through the ordered
//! [`RunHook`](crate::coordinator::hooks::RunHook) list instead of inline
//! code, so the math in this file is exactly the old
//! `coordinator::trainer` loop — the legacy free functions are now thin
//! shims over `Session` and the golden suite pins the equivalence bitwise.
//!
//! There is exactly **one** epoch/hook driver ([`drive`]): the native and
//! PJRT engines differ only in their [`EngineCore`] step/eval bodies, and
//! [`Session::resume`] re-enters the same driver mid-schedule after
//! restoring a full-state checkpoint (network parameters, solver EA
//! factors / decompositions / counters, and the RNG stream positions) —
//! so an interrupted run continued at epoch *k* reproduces the
//! uninterrupted run's trajectory bitwise.
//!
//! Solvers resolve through a [`SolverRegistry`] (defaults, or the one an
//! [`ExperimentSpec`](crate::coordinator::experiment::ExperimentSpec)
//! assembled from the `[registry]` section), and the `[schedules]`
//! per-strategy sketch overrides are routed through
//! `Preconditioner::apply_strategy_schedule` at every epoch boundary.

use anyhow::{bail, Context, Result};

use crate::coordinator::checkpoint;
use crate::coordinator::config::{DataChoice, EngineChoice, ModelChoice, TrainConfig};
use crate::coordinator::hooks::{EpochCtx, HookAction, ObsHook, RunCtx, RunHook, StepCtx, TraceHook};
use crate::coordinator::metrics::{EpochRecord, RunResult};
use crate::data::{self, Augment, Batcher, Dataset};
use crate::linalg::backend::{self, mixed_precision_supported, Precision};
use crate::linalg::{Matrix, Pcg64};
use crate::nn::loss::one_hot;
use crate::nn::{models, Network};
use crate::obs::{self, clock};
use crate::optim::{
    FactoredMode, FactoredPolicy, KfacSchedules, Preconditioner, SolverRegistry, SolverSpec,
};
use crate::pipeline::OnlineMode;
use crate::runtime::{CompiledModel, Engine};

/// Load (train, test) datasets per the config, normalized with train stats.
pub fn load_data(cfg: &TrainConfig) -> Result<(Dataset, Dataset)> {
    let (mut train, mut test) = match &cfg.data {
        DataChoice::Synthetic { n_train, n_test, height, width, channels } => {
            let scfg = data::SyntheticConfig {
                height: *height,
                width: *width,
                channels: *channels,
                ..Default::default()
            };
            data::generate_split(&scfg, *n_train, *n_test, cfg.seed.wrapping_add(9000))
        }
        DataChoice::Cifar { root, n_train, n_test } => {
            if !data::cifar::is_available(root) {
                bail!(
                    "CIFAR-10 binaries not found under '{root}'. Download \
                     cifar-10-binary.tar.gz and extract, or use [data] kind = \"synthetic\"."
                );
            }
            let (mut tr, mut te) = data::cifar::load_standard(root)?;
            if *n_train < tr.len() {
                let drop = tr.len() - n_train;
                tr = tr.split_tail(drop).0;
            }
            if *n_test < te.len() {
                let drop = te.len() - n_test;
                te = te.split_tail(drop).0;
            }
            (tr, te)
        }
    };
    let (mean, std) = train.normalize();
    test.apply_normalization(&mean, &std);
    Ok((train, test))
}

/// Build the schedule block for the configured run length / width.
pub fn build_schedules(cfg: &TrainConfig) -> KfacSchedules {
    let width = if cfg.sched_width > 0 {
        cfg.sched_width
    } else {
        match &cfg.model {
            ModelChoice::Mlp { widths } => widths.iter().copied().max().unwrap_or(512),
            ModelChoice::Vgg16Bn { scale_div } => (512 / scale_div).max(4),
        }
    };
    KfacSchedules::scaled(cfg.epochs.max(1), width)
}

/// Resolve the `[factored]` section into an [`FactoredPolicy`],
/// backstopping the inline-only restriction for sessions built directly
/// from a [`TrainConfig`] (the experiment resolver rejects the
/// combination earlier, with layer provenance).
pub fn factored_policy(cfg: &TrainConfig) -> Result<FactoredPolicy> {
    let f = &cfg.factored;
    let mode = match f.mode.as_str() {
        "off" => FactoredMode::Off,
        "all" => FactoredMode::All,
        "hybrid" => FactoredMode::Hybrid,
        other => bail!(
            "unknown [factored] mode '{other}' (expected \"off\", \"all\", or \"hybrid\")"
        ),
    };
    let policy = FactoredPolicy {
        mode,
        width_threshold: f.width_threshold,
        core: f.core.clone(),
        max_cols: f.max_cols,
        col_sample: f.col_sample,
    };
    if !policy.is_off() && cfg.pipeline.enabled {
        bail!(
            "[factored] mode = \"{}\" is incompatible with [pipeline] enabled = true: factored \
             G-side refreshes are inline-only — retained-U jobs do not ship over the factor \
             transport wire format",
            f.mode
        );
    }
    Ok(policy)
}

fn build_network(cfg: &TrainConfig) -> Result<Network> {
    Ok(match &cfg.model {
        ModelChoice::Mlp { widths } => {
            if widths[0] != cfg.input_dim() {
                bail!("model input width {} != data dim {}", widths[0], cfg.input_dim());
            }
            models::mlp(widths, cfg.seed)
        }
        ModelChoice::Vgg16Bn { scale_div } => {
            if cfg.input_dim() != 3 * 32 * 32 {
                bail!("vgg16_bn needs 32x32x3 inputs; set data height/width = 32");
            }
            models::vgg16_bn(10, *scale_div, cfg.seed)
        }
    })
}

/// Attach the async factor-refresh pipeline when `[pipeline] enabled`.
/// `prop31_batch = 0` (the default) leaves the Prop. 3.1 cap disabled, as
/// documented on [`crate::pipeline::PipelineConfig`]; set it to the batch
/// size in the TOML to engage the paper's `min(r_ε·n_M, d)` mode bound.
fn attach_pipeline_if_enabled(cfg: &TrainConfig, solver: &mut dyn Preconditioner) {
    // Online incremental refresh is configured before (and independently
    // of) pipeline attachment: `[pipeline] online` also governs the inline
    // refresh path, so `enabled = false` + `online = "rsvd"` is a valid —
    // purely synchronous — online run.
    if cfg.pipeline.online != OnlineMode::Off
        && !solver.set_online(cfg.pipeline.online, cfg.pipeline.correction_every)
    {
        eprintln!(
            "[rkfac] note: solver '{}' cannot maintain its decomposition online ([pipeline] \
             online = \"{}\"); refreshes stay recompute-from-scratch",
            solver.name(),
            cfg.pipeline.online.name()
        );
    }
    if !cfg.pipeline.enabled {
        return;
    }
    if !solver.attach_pipeline(&cfg.pipeline) {
        eprintln!(
            "[rkfac] note: solver '{}' has no decomposition cadence; [pipeline] ignored",
            solver.name()
        );
    } else if cfg.pipeline.max_stale_steps == 0 {
        eprintln!(
            "[rkfac] note: [pipeline] max_stale_steps = 0 is synchronous semantics (every \
             refresh blocks for the full round) — useful for validation, but expect no \
             speedup over the inline path"
        );
    }
}

fn augment_for(cfg: &TrainConfig) -> Augment {
    let (c, h, w) = match &cfg.data {
        DataChoice::Synthetic { height, width, channels, .. } => (*channels, *height, *width),
        DataChoice::Cifar { .. } => (3, 32, 32),
    };
    if cfg.augment {
        Augment::cifar(c, h, w)
    } else {
        Augment::none(c, h, w)
    }
}

/// Eval loop for the native engine (full batches only).
pub fn evaluate_native(net: &mut Network, test: &Dataset, batch: usize) -> (f64, f64) {
    let mut loss_sum = 0.0;
    let mut correct = 0usize;
    let mut seen = 0usize;
    let mut pos = 0;
    while pos + batch <= test.len() {
        let idx: Vec<usize> = (pos..pos + batch).collect();
        let (xb, yb) = test.gather(&idx);
        let (l, c) = net.eval_batch(&xb, &yb);
        loss_sum += l * batch as f64;
        correct += c;
        seen += batch;
        pos += batch;
    }
    if seen == 0 {
        return (f64::NAN, 0.0);
    }
    (loss_sum / seen as f64, correct as f64 / seen as f64)
}

/// Eval loop for the PJRT engine.
pub fn evaluate_pjrt(
    model: &CompiledModel,
    weights: &[Matrix],
    test: &Dataset,
    classes: usize,
) -> Result<(f64, f64)> {
    let batch = model.batch();
    let mut loss_sum = 0.0;
    let mut correct = 0usize;
    let mut seen = 0usize;
    let mut pos = 0;
    while pos + batch <= test.len() {
        let idx: Vec<usize> = (pos..pos + batch).collect();
        let (xb, yb) = test.gather(&idx);
        let y = one_hot(&yb, classes);
        let (l, c) = model.eval(weights, &xb, &y)?;
        loss_sum += l * batch as f64;
        correct += c;
        seen += batch;
        pos += batch;
    }
    if seen == 0 {
        return Ok((f64::NAN, 0.0));
    }
    Ok((loss_sum / seen as f64, correct as f64 / seen as f64))
}

/// Where the generic driver enters the epoch loop: zero for a fresh run,
/// the checkpointed cursor for a resume.
#[derive(Clone, Copy, Debug, Default)]
struct StartPoint {
    epoch: usize,
    step: usize,
    /// Wall-clock seconds already spent before this segment, added to the
    /// per-epoch `wall_s` records so time-to-accuracy statistics continue
    /// across a resume instead of restarting near zero.
    wall_offset: f64,
}

/// The per-engine body the one epoch/hook driver delegates to: a single
/// optimization step over one batch of indices, and one full evaluation
/// pass. Everything around it — hook dispatch, the `[schedules]` override
/// cadence, batching, record assembly, stop votes — lives in [`drive`]
/// and is therefore implemented exactly once for native, PJRT, and resume.
trait EngineCore {
    fn train_len(&self) -> usize;

    /// One optimization step over the batch `idx` (gather, augment,
    /// fwd/bwd, solver step, weight update); returns the batch loss.
    fn step(
        &mut self,
        epoch: usize,
        idx: &[usize],
        rng: &mut Pcg64,
        solver: &mut dyn Preconditioner,
    ) -> Result<f64>;

    /// Full test-set evaluation: `(test_loss, test_acc)`.
    fn evaluate(&mut self) -> Result<(f64, f64)>;

    /// The native-engine network, for hooks (`None` on the PJRT path).
    fn net(&self) -> Option<&Network>;
}

/// Native Rust nn engine body.
struct NativeCore {
    net: Network,
    train: Dataset,
    test: Dataset,
    aug: Augment,
    batch: usize,
}

impl EngineCore for NativeCore {
    fn train_len(&self) -> usize {
        self.train.len()
    }

    fn step(
        &mut self,
        epoch: usize,
        idx: &[usize],
        rng: &mut Pcg64,
        solver: &mut dyn Preconditioner,
    ) -> Result<f64> {
        let (xb, yb) = {
            let _sp = obs::span("step.data");
            let (mut xb, yb) = self.train.gather(idx);
            self.aug.apply(&mut xb, rng);
            (xb, yb)
        };
        let (loss, _) = {
            let _sp = obs::span("step.forward_backward");
            self.net.train_batch(&xb, &yb, true)
        };
        let deltas = {
            // Covers the solver's stats/refresh/precondition phases —
            // `kfac.refresh` (and the pipeline spans) nest under it.
            let _sp = obs::span("step.precondition");
            let caps = self.net.kfac_captures();
            solver.step(epoch, &caps)
        };
        let (lr, wd) = solver.lr_wd(epoch);
        {
            let _sp = obs::span("step.apply");
            self.net.apply_steps(&deltas, lr, wd);
        }
        Ok(loss)
    }

    fn evaluate(&mut self) -> Result<(f64, f64)> {
        Ok(evaluate_native(&mut self.net, &self.test, self.batch))
    }

    fn net(&self) -> Option<&Network> {
        Some(&self.net)
    }
}

/// PJRT artifact engine body (the artifact's `ea_gram` Pallas kernel
/// performs the EA blend — the solver consumes the blended factors via
/// `step_with_factors`).
struct PjrtCore {
    model: CompiledModel,
    weights: Vec<Matrix>,
    a_f: Vec<Matrix>,
    g_f: Vec<Matrix>,
    train: Dataset,
    test: Dataset,
    aug: Augment,
    classes: usize,
}

impl EngineCore for PjrtCore {
    fn train_len(&self) -> usize {
        self.train.len()
    }

    fn step(
        &mut self,
        epoch: usize,
        idx: &[usize],
        rng: &mut Pcg64,
        solver: &mut dyn Preconditioner,
    ) -> Result<f64> {
        let (xb, y) = {
            let _sp = obs::span("step.data");
            let (mut xb, yb) = self.train.gather(idx);
            self.aug.apply(&mut xb, rng);
            let y = one_hot(&yb, self.classes);
            (xb, y)
        };
        let out = {
            let _sp = obs::span("step.forward_backward");
            self.model.step(&self.weights, &self.a_f, &self.g_f, &xb, &y)?
        };
        self.a_f = out.a_factors;
        self.g_f = out.g_factors;
        let grads: Vec<&Matrix> = out.grads.iter().collect();
        let deltas = {
            let _sp = obs::span("step.precondition");
            solver
                .step_with_factors(epoch, self.a_f.clone(), self.g_f.clone(), &grads)
                .map_err(anyhow::Error::msg)?
        };
        let (lr, wd) = solver.lr_wd(epoch);
        {
            let _sp = obs::span("step.apply");
            for (w, d) in self.weights.iter_mut().zip(deltas.iter()) {
                for (wv, dv) in w.as_mut_slice().iter_mut().zip(d.as_slice()) {
                    *wv = *wv * (1.0 - lr * wd) + dv;
                }
            }
        }
        Ok(out.loss)
    }

    fn evaluate(&mut self) -> Result<(f64, f64)> {
        evaluate_pjrt(&self.model, &self.weights, &self.test, self.classes)
    }

    fn net(&self) -> Option<&Network> {
        None
    }
}

/// The one epoch/hook driver. Dispatches `on_run_start`, iterates epochs
/// from `start.epoch`: applies the `[schedules]` override, runs the
/// batched step loop through [`EngineCore::step`] (dispatching `on_step`),
/// evaluates, records, dispatches `on_epoch_end` (honouring stop votes),
/// then assembles the [`RunResult`] and dispatches `on_run_end`. A resume
/// enters with the checkpointed cursor and restored RNG streams — the
/// Batcher then reproduces the uninterrupted run's remaining batch order
/// exactly, which is what makes resumption bitwise.
fn drive(
    cfg: &TrainConfig,
    hooks: &mut [Box<dyn RunHook>],
    solver: &mut dyn Preconditioner,
    engine: &mut dyn EngineCore,
    rng: &mut Pcg64,
    start: StartPoint,
) -> Result<RunResult> {
    let sw = clock::Stopwatch::start();
    {
        let ctx = RunCtx {
            cfg,
            solver_name: solver.name(),
            start_rounds: solver.diagnostics().n_decomps,
            start_step: start.step,
        };
        for h in hooks.iter_mut() {
            h.on_run_start(&ctx)
                .with_context(|| format!("hook '{}' failed at run start", h.name()))?;
        }
    }
    let mut records = Vec::new();
    let mut global_step = start.step;
    // Scoped so the `run` span closes (and is recorded) before the hooks'
    // `on_run_end` snapshots the obs buffers.
    {
        let _run_sp = obs::span("run");
        'epochs: for epoch in start.epoch..cfg.epochs {
            let _ep_sp = obs::span("epoch").arg("epoch", epoch);
            if !cfg.schedules.is_empty() {
                solver.apply_strategy_schedule(epoch, &cfg.schedules);
            }
            for h in hooks.iter_mut() {
                h.on_epoch_start(epoch)?;
            }
            let mut epoch_loss = 0.0;
            let mut nb = 0usize;
            for idx in Batcher::new(engine.train_len(), cfg.batch, &mut *rng) {
                let loss = {
                    let _sp = obs::span("step").arg("step", global_step);
                    engine.step(epoch, &idx, &mut *rng, &mut *solver)?
                };
                for h in hooks.iter_mut() {
                    h.on_step(&StepCtx {
                        epoch,
                        step: global_step,
                        batch_loss: loss,
                        solver: &*solver,
                    })?;
                }
                global_step += 1;
                epoch_loss += loss;
                nb += 1;
            }
            let (test_loss, test_acc) = {
                let _sp = obs::span("epoch.evaluate");
                engine.evaluate()?
            };
            records.push(EpochRecord {
                epoch,
                wall_s: start.wall_offset + sw.elapsed_s(),
                train_loss: epoch_loss / nb.max(1) as f64,
                test_loss,
                test_acc,
                decomp_s: solver.diagnostics().decomp_seconds,
            });
            let record = records.last().unwrap();
            let mut stop = false;
            for h in hooks.iter_mut() {
                let action = h.on_epoch_end(&EpochCtx {
                    epoch,
                    step: global_step,
                    record,
                    solver: &*solver,
                    net: engine.net(),
                    data_rng: &*rng,
                })?;
                stop |= action == HookAction::Stop;
            }
            if stop {
                break 'epochs;
            }
        }
    }
    let mut result = RunResult {
        solver: cfg.solver.clone(),
        seed: cfg.seed,
        records,
        total_s: start.wall_offset + sw.elapsed_s(),
        rank_trace: Vec::new(),
        pipe_trace: Vec::new(),
    };
    for h in hooks.iter_mut() {
        h.on_run_end(&mut result)
            .with_context(|| format!("hook '{}' failed at run end", h.name()))?;
    }
    Ok(result)
}

/// One wired-up training run: config + solver registry + ordered hooks.
pub struct Session {
    cfg: TrainConfig,
    registry: SolverRegistry,
    hooks: Vec<Box<dyn RunHook>>,
}

impl Session {
    /// Session over [`SolverRegistry::with_defaults`], with the built-in
    /// [`TraceHook`] installed (so results carry rank/pipeline traces
    /// exactly like the legacy trainer).
    pub fn new(cfg: TrainConfig) -> Self {
        Self::with_registry(cfg, SolverRegistry::with_defaults())
    }

    /// Session over a custom registry (out-of-tree families/strategies, or
    /// the one an `ExperimentSpec` assembled from `[registry]`).
    pub fn with_registry(cfg: TrainConfig, registry: SolverRegistry) -> Self {
        let mut hooks: Vec<Box<dyn RunHook>> = vec![Box::new(TraceHook::new())];
        if cfg.obs.enabled {
            hooks.push(Box::new(ObsHook::new(cfg.out_dir.clone(), cfg.obs.clone())));
        }
        Session { cfg, registry, hooks }
    }

    pub fn cfg(&self) -> &TrainConfig {
        &self.cfg
    }

    pub fn registry(&self) -> &SolverRegistry {
        &self.registry
    }

    /// Append a hook (fires after the built-in trace hook, in insertion
    /// order).
    pub fn add_hook(&mut self, hook: Box<dyn RunHook>) -> &mut Self {
        self.hooks.push(hook);
        self
    }

    /// Installed hooks, in firing order (diagnostics / tests).
    pub fn hook_names(&self) -> Vec<&str> {
        self.hooks.iter().map(|h| h.name()).collect()
    }

    /// Dispatch on the configured engine.
    pub fn run(&mut self) -> Result<RunResult> {
        if matches!(self.cfg.engine, EngineChoice::Native) {
            self.run_native()
        } else {
            let engine = std::sync::Arc::new(Engine::new("artifacts")?);
            self.run_pjrt(engine)
        }
    }

    /// Install the `[linalg]` selection process-wide, backstopping the
    /// mixed-precision policy for sessions built directly from a
    /// [`TrainConfig`] (the experiment resolver rejects the combination
    /// earlier, with layer provenance). Runs before the first kernel, so
    /// pipeline workers — plain threads of this process — inherit it.
    fn install_linalg(&self) -> Result<()> {
        let l = &self.cfg.linalg;
        if l.precision == Precision::Mixed {
            let spec = SolverSpec::parse(&self.cfg.solver).map_err(anyhow::Error::msg)?;
            if !mixed_precision_supported(spec.strategy.as_deref()) {
                bail!(
                    "[linalg] precision = \"mixed\" has no effect on solver '{}': strategy \
                     '{}' has no sketch path (it is exact/EVD-only)",
                    self.cfg.solver,
                    spec.strategy.as_deref().unwrap_or("none")
                );
            }
        }
        backend::install(l.backend, l.threads, l.precision);
        Ok(())
    }

    /// Wire the native-engine run (data, network, solver, pipeline, RNG).
    fn wire_native(&self) -> Result<(NativeCore, Box<dyn Preconditioner>, Pcg64)> {
        let cfg = &self.cfg;
        self.install_linalg()?;
        let (train, test) = load_data(cfg)?;
        let net = build_network(cfg)?;
        let sched = build_schedules(cfg);
        let dims = net.kfac_dims();
        let policy = factored_policy(cfg)?;
        let mut solver = self
            .registry
            .build_with_factored(&cfg.solver, sched, &dims, cfg.seed, &policy)
            .map_err(anyhow::Error::msg)?;
        attach_pipeline_if_enabled(cfg, solver.as_mut());
        let rng = Pcg64::with_stream(cfg.seed, 31337);
        let core = NativeCore { net, train, test, aug: augment_for(cfg), batch: cfg.batch };
        Ok((core, solver, rng))
    }

    /// Train with the native Rust nn engine. Returns the per-epoch record
    /// set (partial if a hook voted [`HookAction::Stop`]).
    pub fn run_native(&mut self) -> Result<RunResult> {
        let (mut core, mut solver, mut rng) = self.wire_native()?;
        drive(
            &self.cfg,
            &mut self.hooks,
            solver.as_mut(),
            &mut core,
            &mut rng,
            StartPoint::default(),
        )
    }

    /// Resume a checkpointed run: wire the session exactly like
    /// [`Session::run_native`], restore the network parameters, the
    /// solver's full state, and the RNG stream positions from the
    /// checkpoint at `path` (a [`checkpoint::save_full`] v2 file, as
    /// written by `CheckpointHook` / `rkfac train --checkpoint-every`),
    /// then re-enter the step loop at the checkpointed epoch. The
    /// continuation reproduces the uninterrupted run bitwise — metrics,
    /// rank traces and pipeline traces — for the native engine, inline or
    /// pipelined at `max_stale_steps = 0`.
    ///
    /// v1 (params-only) checkpoints still load: the run restarts from
    /// epoch 0 with the checkpointed weights and a clear warning that the
    /// trajectory will not reproduce the original.
    pub fn resume(&mut self, path: impl AsRef<std::path::Path>) -> Result<RunResult> {
        let path = path.as_ref();
        if !matches!(self.cfg.engine, EngineChoice::Native) {
            bail!(
                "Session::resume supports the native engine only — the PJRT path keeps its \
                 weights outside a Network and writes no checkpoints"
            );
        }
        if self.cfg.pipeline.enabled && self.cfg.pipeline.max_stale_steps > 0 {
            // In-flight factor jobs are not checkpointed: at positive
            // staleness the continuation is best-effort, not bitwise (see
            // docs/distributed.md, "Resuming under staleness").
            eprintln!(
                "[rkfac] note: resuming with pipeline.max_stale_steps = {} — in-flight \
                 factor jobs were not checkpointed, so the continuation is best-effort \
                 (bitwise reproduction holds only at max_stale_steps = 0)",
                self.cfg.pipeline.max_stale_steps
            );
        }
        let (mut core, mut solver, mut rng) = self.wire_native()?;
        let start = match checkpoint::load_full(&mut core.net, solver.as_mut(), path)? {
            checkpoint::LoadedCheckpoint::Full(ts) => {
                if ts.seed != self.cfg.seed {
                    bail!(
                        "{} was written by a run with seed {} but this run has seed {} — \
                         every restored RNG stream is a position within the original seed's \
                         streams, so continuing would match neither trajectory; resume with \
                         train.seed = {} (or start a fresh run)",
                        path.display(),
                        ts.seed,
                        self.cfg.seed,
                        ts.seed
                    );
                }
                if ts.next_epoch >= self.cfg.epochs {
                    bail!(
                        "{} was taken at the end of epoch {} and [train] epochs = {} — the \
                         schedule is already complete; raise train.epochs to continue \
                         training",
                        path.display(),
                        ts.next_epoch.saturating_sub(1),
                        self.cfg.epochs
                    );
                }
                rng = Pcg64::from_raw(ts.data_rng.0, ts.data_rng.1);
                core.net.rng = Pcg64::from_raw(ts.net_rng.0, ts.net_rng.1);
                StartPoint {
                    epoch: ts.next_epoch,
                    step: ts.global_step,
                    wall_offset: ts.wall_s,
                }
            }
            checkpoint::LoadedCheckpoint::ParamsOnly => StartPoint::default(),
        };
        drive(&self.cfg, &mut self.hooks, solver.as_mut(), &mut core, &mut rng, start)
    }

    /// Train through the PJRT artifact engine (MLP configs only; the
    /// artifact's `ea_gram` Pallas kernel performs the EA blend — the
    /// solver just consumes the blended factors via `step_with_factors`).
    pub fn run_pjrt(&mut self, engine: std::sync::Arc<Engine>) -> Result<RunResult> {
        let cfg = &self.cfg;
        let artifact = match &cfg.engine {
            EngineChoice::Pjrt { config } => config.clone(),
            _ => bail!("run_pjrt called with a non-PJRT engine choice"),
        };
        self.install_linalg()?;
        let model = CompiledModel::new(engine, &artifact)
            .with_context(|| format!("loading model artifact '{artifact}'"))?;
        let (train, test) = load_data(cfg)?;
        if model.widths()[0] != train.dim() {
            bail!("artifact input width {} != data dim {}", model.widths()[0], train.dim());
        }
        if model.batch() != cfg.batch {
            bail!("artifact batch {} != configured batch {}", model.batch(), cfg.batch);
        }
        let classes = *model.widths().last().unwrap();
        // The PJRT path streams externally-computed dense factor matrices;
        // there is no retained-U stats feed for a factored block to consume.
        if !factored_policy(cfg)?.is_off() {
            bail!(
                "[factored] mode = \"{}\" is native-engine only: the PJRT artifact path streams \
                 dense factor matrices, which the factored G-side path never materializes — \
                 set factored.mode = \"off\" or use [engine] kind = \"native\"",
                cfg.factored.mode
            );
        }
        let sched = build_schedules(cfg);
        let dims: Vec<(usize, usize)> =
            (0..model.n_layers()).map(|l| (model.widths()[l], model.widths()[l + 1])).collect();
        let mut solver =
            self.registry.build(&cfg.solver, sched, &dims, cfg.seed).map_err(anyhow::Error::msg)?;
        if !solver.supports_external_factors() {
            bail!(
                "PJRT path needs a solver that accepts externally-computed factors \
                 (the K-FAC engine family: kfac/rs-kfac/sre-kfac/trunc-kfac/nys-kfac); \
                 '{}' does not",
                solver.name()
            );
        }
        attach_pipeline_if_enabled(cfg, solver.as_mut());
        let mut rng = Pcg64::with_stream(cfg.seed, 31338);
        let weights = model.init_weights(&mut rng);
        let (a_f, g_f) = model.init_factors();
        let mut core = PjrtCore {
            model,
            weights,
            a_f,
            g_f,
            train,
            test,
            aug: augment_for(cfg),
            classes,
        };
        drive(
            &self.cfg,
            &mut self.hooks,
            solver.as_mut(),
            &mut core,
            &mut rng,
            StartPoint::default(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::hooks::{CheckpointHook, EarlyStopHook};

    /// Deterministic interrupt: vote Stop at the end of epoch `.0` —
    /// unlike an accuracy-based stop, this cuts the run at a known epoch
    /// so the resume golden has a fixed comparison point.
    struct StopAfterEpoch(usize);

    impl RunHook for StopAfterEpoch {
        fn name(&self) -> &str {
            "stop-after"
        }

        fn on_epoch_end(&mut self, ctx: &EpochCtx<'_>) -> Result<HookAction> {
            Ok(if ctx.epoch >= self.0 { HookAction::Stop } else { HookAction::Continue })
        }
    }

    fn tiny_cfg(solver: &str) -> TrainConfig {
        TrainConfig {
            solver: solver.into(),
            epochs: 3,
            batch: 32,
            seed: 1,
            model: ModelChoice::Mlp { widths: vec![108, 32, 10] },
            data: DataChoice::Synthetic {
                n_train: 320,
                n_test: 96,
                height: 6,
                width: 6,
                channels: 3,
            },
            engine: EngineChoice::Native,
            targets: vec![0.5],
            augment: false,
            out_dir: "/tmp/rkfac_session_test".into(),
            sched_width: 0,
            ..Default::default()
        }
    }

    #[test]
    fn default_session_has_trace_hook() {
        let s = Session::new(tiny_cfg("rs-kfac"));
        assert_eq!(s.hook_names(), vec!["trace"]);
    }

    /// `[obs] enabled = true` installs the obs hook after the trace hook;
    /// the default hook list is untouched when obs is off.
    #[test]
    fn obs_config_installs_obs_hook() {
        let mut cfg = tiny_cfg("rs-kfac");
        cfg.obs.enabled = true;
        let s = Session::new(cfg);
        assert_eq!(s.hook_names(), vec!["trace", "obs"]);
    }

    #[test]
    fn early_stop_hook_truncates_run() {
        // A 0.0-accuracy target is hit at epoch 0 → exactly one record.
        let mut s = Session::new(tiny_cfg("sgd"));
        s.add_hook(Box::new(EarlyStopHook::new(0.0)));
        let r = s.run().unwrap();
        assert_eq!(r.records.len(), 1);
        // Unreachable target → full run.
        let mut s2 = Session::new(tiny_cfg("sgd"));
        s2.add_hook(Box::new(EarlyStopHook::new(2.0)));
        let r2 = s2.run().unwrap();
        assert_eq!(r2.records.len(), 3);
    }

    /// Running the same session twice must reproduce the run bitwise —
    /// the built-in trace hook restarts from round 0, it does not carry
    /// the first run's counters into the second.
    #[test]
    fn session_rerun_reproduces_traces() {
        let mut s = Session::new(tiny_cfg("rs-kfac"));
        let a = s.run().unwrap();
        let b = s.run().unwrap();
        assert_eq!(a.records.len(), b.records.len());
        for (ra, rb) in a.records.iter().zip(b.records.iter()) {
            assert_eq!(ra.train_loss, rb.train_loss);
        }
        assert_eq!(a.rank_trace.len(), b.rank_trace.len());
        assert!(!b.rank_trace.is_empty());
        assert_eq!(b.rank_trace[0].round, 0, "second run's trace restarts at round 0");
    }

    /// `[schedules]` overrides ride the session loop: the run still learns
    /// and the installed ranks follow the per-strategy schedule.
    #[test]
    fn strategy_schedules_applied_per_epoch() {
        use crate::optim::{StepSchedule, StrategySchedule};
        let mut cfg = tiny_cfg("rs-kfac");
        cfg.schedules.insert(
            "rsvd",
            StrategySchedule {
                oversample: Some(StepSchedule::new(4.0, vec![(1, 2.0)])),
                power_iter: Some(StepSchedule::constant(1.0)),
                target_rel_err: None,
            },
        );
        let r = Session::new(cfg).run().unwrap();
        assert_eq!(r.records.len(), 3);
        assert!(r.records.last().unwrap().test_loss.is_finite());
        assert!(!r.rank_trace.is_empty());
    }

    /// `resume` from a checkpoint at epoch 0 continues to the configured
    /// end and reproduces the uninterrupted run's tail bitwise (the full
    /// suite lives in `rust/tests/resume.rs`; this pins the in-module
    /// smoke path).
    #[test]
    fn resume_smoke_reproduces_tail() {
        let dir = std::env::temp_dir()
            .join(format!("rkfac_session_resume_{}", std::process::id()));
        let full = Session::new(tiny_cfg("rs-kfac")).run().unwrap();
        let mut first = Session::new(tiny_cfg("rs-kfac"));
        first.add_hook(Box::new(CheckpointHook::new(dir.to_str().unwrap(), 1)));
        first.add_hook(Box::new(StopAfterEpoch(0)));
        let partial = first.run().unwrap();
        assert_eq!(partial.records.len(), 1);
        let ckpt = checkpoint::epoch_path(&dir, "rs-kfac", 1, 0);
        let resumed = Session::new(tiny_cfg("rs-kfac")).resume(&ckpt).unwrap();
        assert_eq!(resumed.records.len(), 2, "epochs 1 and 2 remain");
        for (r, f) in resumed.records.iter().zip(full.records[1..].iter()) {
            assert_eq!(r.epoch, f.epoch);
            assert_eq!(r.train_loss, f.train_loss, "epoch {}", r.epoch);
            assert_eq!(r.test_loss, f.test_loss, "epoch {}", r.epoch);
            assert_eq!(r.test_acc, f.test_acc, "epoch {}", r.epoch);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Resuming on a non-native engine choice fails up front.
    #[test]
    fn resume_rejects_pjrt_engine() {
        let mut cfg = tiny_cfg("rs-kfac");
        cfg.engine = EngineChoice::Pjrt { config: "quick".into() };
        let err = Session::new(cfg).resume("/nonexistent.bin").unwrap_err().to_string();
        assert!(err.contains("native engine only"), "{err}");
    }
}
